"""Partition-rule unit tests (mesh-shape logic; real placement in test_distributed)."""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import data_axes
from repro.models.model import build_model
from repro.sharding.rules import batch_pspec, cache_pspecs, param_pspecs


class _FakeMesh:
    """Shape-only stand-in (avoids needing 256 devices in-process)."""

    def __init__(self, sizes):
        self._sizes = sizes
        self.axis_names = tuple(sizes)
        self.devices = np.zeros(tuple(sizes.values()))


MESH = _FakeMesh({"data": 16, "model": 16})
MESH3 = _FakeMesh({"pod": 2, "data": 16, "model": 16})


class TestParamRules:
    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_all_assignments_divisible(self, arch):
        cfg = get_config(arch)
        bundle = build_model(cfg)
        tree = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
        specs = param_pspecs(tree, MESH)

        def check(leaf, spec):
            for i, ax in enumerate(spec):
                if ax is None:
                    continue
                size = int(np.prod([MESH._sizes[a] for a in (ax if isinstance(ax, tuple) else (ax,))]))
                assert leaf.shape[i] % size == 0, (arch, leaf.shape, spec)

        jax.tree.map(check, tree, specs, is_leaf=lambda x: isinstance(x, P))

    def test_big_tensors_are_sharded(self):
        """Embedding and MLP weights must not end up fully replicated."""
        cfg = get_config("qwen2-7b")
        tree = jax.eval_shape(build_model(cfg).init, jax.random.PRNGKey(0))
        specs = param_pspecs(tree, MESH)
        assert specs["embed"] != P(None, None)
        # tree_flatten_with_path spans jax versions (jax.tree.leaves_with_path
        # arrived later)
        flat, _ = jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, P)
        )
        mlp = [s for p, s in flat if "w_gu" in str(p)]
        assert all(s[-1] == "model" for s in mlp)

    def test_leading_stack_axis_unsharded(self):
        cfg = get_config("qwen2-7b")
        tree = jax.eval_shape(build_model(cfg).init, jax.random.PRNGKey(0))
        specs = param_pspecs(tree, MESH)
        wq = specs["layers"]["attn"]["wqkv"]
        assert wq[0] is None and len(wq) == 4  # (layer, d_model, H_total, hd)


class TestBatchRules:
    def test_divisible_batch_uses_all_dp(self):
        spec = batch_pspec({"tokens": jax.ShapeDtypeStruct((256, 128), np.int32)}, MESH3)
        assert spec["tokens"][0] == ("pod", "data")

    def test_batch_1_replicates(self):
        spec = batch_pspec({"tokens": jax.ShapeDtypeStruct((1, 128), np.int32)}, MESH)
        assert spec["tokens"] == P(None, None)

    def test_partial_dp_prefix(self):
        # batch 2 on (pod=2, data=16): only the pod axis fits
        spec = batch_pspec({"tokens": jax.ShapeDtypeStruct((2, 8), np.int32)}, MESH3)
        assert spec["tokens"][0] in ("pod", ("pod",))


class TestCacheRules:
    def test_kv_heads_sharded_when_divisible(self):
        cache = {
            "k": jax.ShapeDtypeStruct((4, 32, 16, 1024, 64), np.float32),
            "v": jax.ShapeDtypeStruct((4, 32, 16, 1024, 64), np.float32),
            "pos": jax.ShapeDtypeStruct((4,), np.int32),
        }
        specs = cache_pspecs(cache, MESH)
        assert specs["k"][2] == "model"
        assert specs["pos"] == P(None)

    def test_kv_headdim_fallback(self):
        cache = {"k": jax.ShapeDtypeStruct((4, 32, 2, 1024, 64), np.float32)}
        specs = cache_pspecs(cache, MESH)
        assert specs["k"][2] is None and specs["k"][4] == "model"

    def test_mesh_data_axes(self):
        import jax as _jax

        class M:
            axis_names = ("pod", "data", "model")

        assert data_axes(M()) == ("pod", "data")
