"""Durable live-session gates (ISSUE 10).

The session contract in test form:

  journal      ``FFJR`` records round trip; truncation/bit flips at ANY byte
               never raise from :func:`parse_journal` — they only shorten the
               durable prefix.  A CLOSE record ends the log.
  idempotency  a duplicate seq with identical content returns the cached
               receipt (``duplicate=True``); different content, gaps, and
               negative seqs raise :class:`SessionSequenceError`.
  recovery     an intact journal restores bitwise — finalize after recovery
               equals the uninterrupted container byte for byte; damaged
               tails (truncated / bit-flipped) drop to the durable prefix
               and the resumed stream still decodes within the claimed
               bound; an unreplayable chain degrades by keyframe groups.
  WAL          a frame's receipt is minted only after its journal record is
               durable: an injected journal failure leaves the frame pending
               and the retry re-journals WITHOUT re-encoding.
  leases       expiry finalizes to a valid partial FFCS container (fetchable
               from the tombstone); appends refresh the lease.
  admission    ``max_sessions`` and the service's ``max_queue`` reject with
               :class:`ResourceExhausted` at admission; history memory
               pressure spills idle sessions to their journals and the next
               append restores them, bitwise-neutrally.
"""

import hashlib

import numpy as np
import pytest

from repro.compressors import get_compressor
from repro.core.errors import (
    BlobCorruptError,
    ResourceExhausted,
    SessionError,
    SessionNotFound,
    SessionSequenceError,
    StreamStateError,
)
from repro.core.ffcz import FFCzConfig
from repro.core.temporal import TemporalCodec, TemporalConfig, TemporalStream
from repro.runtime.faults import FaultConfig, FaultInjector
from repro.serving import sessions as sz
from repro.serving.ffcz_service import FFCzService, ServiceConfig
from repro.serving.sessions import (
    FileJournal,
    MemoryJournal,
    StreamSessionManager,
    parse_journal,
)

pytestmark = pytest.mark.timeout(180)


def _frames(n, shape=(16, 16), seed=0, drift=0.05):
    rng = np.random.default_rng(seed)
    base = (rng.standard_normal(shape) * 0.5 + 4.0).cumsum(axis=0)
    mode = np.cos(np.linspace(0, 2 * np.pi, base.size)).reshape(shape)
    out = []
    for t in range(n):
        x = base + drift * t * mode + 0.01 * rng.standard_normal(shape)
        out.append(np.ascontiguousarray(x, dtype=np.float32))
    return out


FRAMES = _frames(6)

# warm_start stays at its False default: the bitwise-recovery claims below
# hold because cold re-encodes are deterministic
CFG = FFCzConfig(E_rel=1e-3, Delta_rel=1e-3, max_iters=200)
STREAM = TemporalConfig(mode="field", predictor="linear", keyframe_interval=2)


def _manager(**kw):
    return StreamSessionManager(get_compressor("szlike"), **kw)


def _codec():
    return TemporalCodec(get_compressor("szlike"), CFG, stream=STREAM)


@pytest.fixture(scope="module")
def ref_container():
    """The uninterrupted whole-sequence container — the bitwise oracle."""
    return _codec().compress_stream(FRAMES)


@pytest.fixture(scope="module")
def partial_journal():
    """Journal bytes of a live session that appended frames 0..3 and then
    "crashed" (no CLOSE record)."""
    mgr = _manager()
    jrn = MemoryJournal()
    sid = mgr.open_session(CFG, STREAM, journal=jrn)
    for t in range(4):
        mgr.append_frame(sid, t, FRAMES[t])
    return jrn.read()


def _assert_bound(container, frames):
    """Every decoded frame within the stream header's claimed bound."""
    s = TemporalStream.from_bytes(container)
    dec = _codec().decompress_stream(container)
    assert len(dec) == len(frames)
    for x, d in zip(frames, dec):
        err = np.max(np.abs(d.astype(np.float64) - np.asarray(x, np.float64)))
        assert err <= s.E * (1 + 1e-9)


# -- journal wire format -----------------------------------------------------


class TestJournalWire:
    def test_roundtrip(self, partial_journal):
        parsed = parse_journal(partial_journal)
        assert not parsed.damaged and parsed.closed is None
        assert parsed.open_info["stream"]["keyframe_interval"] == 2
        assert [f.seq for f in parsed.frames] == [0, 1, 2, 3]
        # keyframe flags follow the interval; digests match what was sent
        assert [f.keyframe for f in parsed.frames] == [True, False, True, False]
        for t, f in enumerate(parsed.frames):
            assert f.frame_digest == hashlib.sha256(FRAMES[t].tobytes()).digest()
            assert f.shape == (16, 16)

    def test_truncation_never_raises(self, partial_journal):
        # every truncation point: parse never raises and the durable frame
        # count shrinks monotonically with the cut
        prev = len(parse_journal(partial_journal).frames)
        for keep in range(len(partial_journal), -1, -1):
            parsed = parse_journal(partial_journal[:keep])
            assert len(parsed.frames) <= prev
            prev = len(parsed.frames)

    def test_bitflip_keeps_prefix(self, partial_journal):
        full = parse_journal(partial_journal)
        step = max(1, len(partial_journal) // 97)
        for pos in range(0, len(partial_journal), step):
            bad = bytearray(partial_journal)
            bad[pos] ^= 0x40
            parsed = parse_journal(bytes(bad))
            # a flip damages exactly one record; the walk stops there, so the
            # surviving frames are a byte-exact prefix of the original log —
            # a CRC failure never fabricates, alters, or reorders a frame
            assert parsed.damaged
            assert len(parsed.frames) < len(full.frames)
            for got, want in zip(parsed.frames, full.frames):
                assert got.seq == want.seq and got.payload == want.payload

    def test_close_ends_log(self):
        data = (
            sz._record(sz._J_OPEN, b'{"v": 1}')
            + sz._record(sz._J_CLOSE, bytes([1]))
            + sz._record(sz._J_OPEN, b'{"v": 2}')
        )
        parsed = parse_journal(data)
        assert parsed.closed == "finalized"
        assert parsed.open_info == {"v": 1}

    def test_unknown_record_type_stops_walk(self):
        data = sz._record(sz._J_OPEN, b'{"v": 1}') + sz._record(9, b"??")
        parsed = parse_journal(data)
        assert parsed.damaged and parsed.open_info == {"v": 1}

    def test_file_journal(self, tmp_path):
        path = str(tmp_path / "s.wal")
        j = FileJournal(path)
        j.append(sz._record(sz._J_OPEN, b'{"v": 1}'))
        assert j.size() == len(j.read())
        j.close()
        # reopen appends, does not truncate (a restarted service resumes)
        j2 = FileJournal(path)
        j2.append(sz._record(sz._J_CLOSE, bytes([2])))
        parsed = parse_journal(j2.read())
        j2.close()
        assert parsed.open_info == {"v": 1} and parsed.closed == "aborted"


# -- idempotent append -------------------------------------------------------


class TestIdempotentAppend:
    @pytest.fixture()
    def live(self):
        mgr = _manager()
        sid = mgr.open_session(CFG, STREAM)
        for t in range(3):
            mgr.append_frame(sid, t, FRAMES[t])
        return mgr, sid

    def test_receipts(self, live):
        mgr, sid = live
        assert mgr.next_seq(sid) == 3
        st = mgr.session_stats(sid)
        assert st.n_frames == 3 and st.state == "open"

    def test_duplicate_returns_cached_receipt(self, live):
        mgr, sid = live
        first = mgr.append_frame(sid, 1, FRAMES[1])
        assert first.duplicate
        again = mgr.append_frame(sid, 1, FRAMES[1])
        assert again.duplicate and again.digest == first.digest
        assert again.frame_digest == hashlib.sha256(FRAMES[1].tobytes()).hexdigest()
        assert mgr.counters["duplicates"] == 2
        # the duplicate did not append anything
        assert mgr.next_seq(sid) == 3

    def test_duplicate_with_different_content_rejects(self, live):
        mgr, sid = live
        with pytest.raises(SessionSequenceError) as ei:
            mgr.append_frame(sid, 1, FRAMES[1] + 1.0)
        assert ei.value.expected == 3 and ei.value.got == 1
        assert mgr.counters["sequence_rejects"] == 1

    def test_gap_rejects(self, live):
        mgr, sid = live
        with pytest.raises(SessionSequenceError) as ei:
            mgr.append_frame(sid, 5, FRAMES[4])
        assert ei.value.expected == 3 and ei.value.got == 5
        # the session survives a sequence reject: the right seq still lands
        r = mgr.append_frame(sid, 3, FRAMES[3])
        assert r.seq == 3 and not r.duplicate

    def test_negative_seq_rejects(self, live):
        mgr, sid = live
        with pytest.raises(SessionSequenceError):
            mgr.append_frame(sid, -1, FRAMES[0])

    def test_append_after_finalize_rejects(self, live):
        mgr, sid = live
        container = mgr.finalize(sid)
        assert container[:4] == b"FFCS"
        with pytest.raises(SessionNotFound):
            mgr.append_frame(sid, 3, FRAMES[3])
        assert mgr.closed_info(sid)["container"] == container

    def test_empty_finalize_rejects(self):
        mgr = _manager()
        sid = mgr.open_session(CFG, STREAM)
        with pytest.raises(SessionError):
            mgr.finalize(sid)
        mgr.abort(sid)
        assert mgr.closed_info(sid)["reason"] == "aborted"


# -- session container vs the whole-sequence oracle --------------------------


class TestSessionContainer:
    def test_bitwise_equals_compress_stream(self, ref_container):
        mgr = _manager()
        sid = mgr.open_session(CFG, STREAM)
        for t, x in enumerate(FRAMES):
            r = mgr.append_frame(sid, t, x)
            assert r.seq == t and r.keyframe == (t % 2 == 0)
        assert mgr.finalize(sid) == ref_container

    def test_journal_payloads_match_container(self, ref_container):
        mgr = _manager()
        jrn = MemoryJournal()
        sid = mgr.open_session(CFG, STREAM, journal=jrn)
        for t, x in enumerate(FRAMES):
            mgr.append_frame(sid, t, x)
        mgr.finalize(sid)
        parsed = parse_journal(jrn.read())
        assert parsed.closed == "finalized"
        s = TemporalStream.from_bytes(ref_container)
        for t, f in enumerate(parsed.frames):
            assert f.payload == s.frame_payload(t)


# -- crash recovery (the acceptance gate) ------------------------------------


class TestRecovery:
    def test_intact_journal_restores_bitwise(self, partial_journal, ref_container):
        mgr = _manager()
        sid = mgr.recover(partial_journal)
        assert mgr.next_seq(sid) == 4
        assert mgr.counters["recoveries"] == 1
        assert mgr.counters["recovered_frames"] == 4
        assert mgr.counters["resyncs"] == 0
        # recovered receipts are marked; a client retry of an already-durable
        # seq is still idempotent across the crash
        dup = mgr.append_frame(sid, 1, FRAMES[1])
        assert dup.duplicate and dup.restored
        for t in range(4, 6):
            r = mgr.append_frame(sid, t, FRAMES[t])
            assert not r.restored
        assert mgr.finalize(sid) == ref_container

    @pytest.mark.parametrize("damage", ["truncate", "bitflip"])
    def test_damaged_tail_resumes_from_durable_prefix(
        self, damage, partial_journal, ref_container
    ):
        if damage == "truncate":
            data = partial_journal[:-10]
        else:
            bad = bytearray(partial_journal)
            bad[-20] ^= 0x10  # inside the last FRAME record
            data = bytes(bad)
        mgr = _manager()
        out = MemoryJournal()
        sid = mgr.recover(data, journal_out=out)
        # the damaged record is exactly the last frame: CRC drops it
        assert mgr.next_seq(sid) == 3
        # the compacted journal holds only the durable prefix
        parsed = parse_journal(out.read())
        assert not parsed.damaged and len(parsed.frames) == 3
        # the client resumes from next_seq; the result is the same stream
        for t in range(3, 6):
            mgr.append_frame(sid, t, FRAMES[t])
        container = mgr.finalize(sid)
        assert container == ref_container
        _assert_bound(container, FRAMES)

    def test_unreplayable_chain_drops_keyframe_group(self, partial_journal):
        # rebuild the journal with frame 3's payload replaced by garbage
        # under a VALID record CRC: parse keeps it, replay cannot decode it,
        # so recovery degrades to the previous keyframe group (frames 0..1)
        parsed = parse_journal(partial_journal)
        f0 = parsed.frames[0]
        data = sz._record(
            sz._J_OPEN,
            sz._config_json(CFG, STREAM, str(parsed.open_info["session_id"])),
        )
        for f in parsed.frames[:3]:
            data += sz._frame_record(
                f.seq, f.keyframe, f.frame_digest, f.E0, f.Delta0,
                f.shape, f.block, f.payload,
            )
        data += sz._frame_record(
            3, False, b"\x00" * 32, f0.E0, f0.Delta0, f0.shape, f0.block,
            b"not a frame payload",
        )
        mgr = _manager()
        sid = mgr.recover(data)
        assert mgr.next_seq(sid) == 2
        assert mgr.counters["resyncs"] == 1
        assert mgr.counters["recovered_frames"] == 2
        # the session is live and bound-conformant from the durable prefix
        for t in range(2, 4):
            mgr.append_frame(sid, t, FRAMES[t])
        _assert_bound(mgr.finalize(sid), FRAMES[:4])

    def test_closed_journal_rejects(self):
        mgr = _manager()
        jrn = MemoryJournal()
        sid = mgr.open_session(CFG, STREAM, journal=jrn)
        mgr.append_frame(sid, 0, FRAMES[0])
        mgr.finalize(sid)
        with pytest.raises(SessionNotFound):
            _manager().recover(jrn.read())

    def test_garbage_journal_rejects(self):
        with pytest.raises(BlobCorruptError):
            _manager().recover(b"not a journal at all")

    def test_open_record_without_config_rejects(self):
        data = sz._record(sz._J_OPEN, b'{"v": 1}')
        with pytest.raises(BlobCorruptError):
            _manager().recover(data)

    def test_recover_respects_admission(self, partial_journal):
        mgr = _manager(max_sessions=1)
        mgr.open_session(CFG, STREAM, session_id="occupant")
        with pytest.raises(ResourceExhausted):
            mgr.recover(partial_journal)


# -- write-ahead discipline under injected journal faults --------------------


class TestWalDiscipline:
    def test_journal_fault_leaves_frame_pending_then_replays(self, ref_container):
        inj = FaultInjector(
            FaultConfig(p_session_journal=1.0, max_per_site=1), seed=3
        )
        mgr = _manager(injector=inj)
        sid = mgr.open_session(CFG, STREAM)
        # every first attempt's WAL write fails AFTER the frame encoded —
        # the frame is never acked and stays pending
        with pytest.raises(OSError):
            mgr.append_frame(sid, 0, FRAMES[0], fire_uid="a0")
        assert mgr.next_seq(sid) == 0
        # the retry re-journals the pending encode instead of re-encoding
        r = mgr.append_frame(sid, 0, FRAMES[0], fire_uid="a0")
        assert r.seq == 0 and not r.duplicate
        assert mgr.session_stats(sid).pending_replays == 1
        # a retry with DIFFERENT content against the pending frame rejects
        with pytest.raises(OSError):
            mgr.append_frame(sid, 1, FRAMES[1], fire_uid="a1")
        with pytest.raises(SessionSequenceError):
            mgr.append_frame(sid, 1, FRAMES[1] + 1.0, fire_uid="a1")
        mgr.append_frame(sid, 1, FRAMES[1], fire_uid="a1")
        for t in range(2, 6):
            with pytest.raises(OSError):
                mgr.append_frame(sid, t, FRAMES[t], fire_uid=f"a{t}")
            mgr.append_frame(sid, t, FRAMES[t], fire_uid=f"a{t}")
        assert mgr.session_stats(sid).pending_replays == 6
        # finalize's CLOSE write hits the same site, then its retry lands;
        # pending replays never double-commit: still the oracle container
        with pytest.raises(OSError):
            mgr.finalize(sid, fire_uid="fin")
        assert mgr.finalize(sid, fire_uid="fin") == ref_container

    def test_finalize_close_fault_is_retryable(self):
        inj = FaultInjector(
            FaultConfig(p_session_journal=1.0, max_per_site=1), seed=3
        )
        mgr = _manager(injector=inj)
        sid = mgr.open_session(CFG, STREAM)
        with pytest.raises(OSError):
            mgr.append_frame(sid, 0, FRAMES[0], fire_uid="b0")
        mgr.append_frame(sid, 0, FRAMES[0], fire_uid="b0")
        with pytest.raises(OSError):
            mgr.finalize(sid, fire_uid="fin")
        # the container was assembled; the session is sealed against appends
        with pytest.raises(SessionNotFound):
            mgr.append_frame(sid, 1, FRAMES[1], fire_uid="b1")
        # the finalize retry does not call finish() twice
        container = mgr.finalize(sid, fire_uid="fin")
        _assert_bound(container, FRAMES[:1])


# -- leases ------------------------------------------------------------------


class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


class TestLeases:
    def test_expiry_finalizes_partial_container(self):
        clk = _Clock()
        mgr = _manager(lease_s=10.0, clock=clk)
        sid = mgr.open_session(CFG, STREAM)
        mgr.append_frame(sid, 0, FRAMES[0])
        mgr.append_frame(sid, 1, FRAMES[1])
        clk.t = 100.0
        assert mgr.sweep() == [sid]
        assert mgr.counters["lease_evictions"] == 1
        with pytest.raises(SessionNotFound):
            mgr.append_frame(sid, 2, FRAMES[2])
        tomb = mgr.closed_info(sid)
        assert tomb["reason"] == "lease_expired" and tomb["n_frames"] == 2
        # the evicted session is a VALID partial stream, fetchable post-mortem
        _assert_bound(tomb["container"], FRAMES[:2])

    def test_empty_session_expiry_aborts(self):
        clk = _Clock()
        mgr = _manager(lease_s=10.0, clock=clk)
        sid = mgr.open_session(CFG, STREAM)
        clk.t = 11.0
        assert mgr.sweep() == [sid]
        tomb = mgr.closed_info(sid)
        assert tomb["reason"] == "lease_expired" and tomb["container"] is None

    def test_append_refreshes_lease(self):
        clk = _Clock()
        mgr = _manager(lease_s=10.0, clock=clk)
        sid = mgr.open_session(CFG, STREAM)
        for t, at in enumerate((0.0, 8.0, 16.0)):
            clk.t = at
            mgr.append_frame(sid, t, FRAMES[t])
        clk.t = 25.0  # 9s after the last append: still leased
        assert mgr.sweep() == []
        assert mgr.session_stats(sid).lease_remaining_s > 0
        clk.t = 27.0
        assert mgr.sweep() == [sid]

    def test_expired_sessions_swept_at_admission(self):
        clk = _Clock()
        mgr = _manager(lease_s=10.0, clock=clk, max_sessions=1)
        mgr.open_session(CFG, STREAM)
        clk.t = 11.0
        # the expired session frees its slot before the admission check
        sid2 = mgr.open_session(CFG, STREAM)
        assert mgr.live_sessions == [sid2]


# -- admission + memory pressure ---------------------------------------------


class TestAdmissionAndSpill:
    def test_max_sessions_rejects_at_admission(self):
        mgr = _manager(max_sessions=2)
        a = mgr.open_session(CFG, STREAM)
        mgr.open_session(CFG, STREAM)
        with pytest.raises(ResourceExhausted) as ei:
            mgr.open_session(CFG, STREAM)
        assert ei.value.stage == "admit"
        mgr.append_frame(a, 0, FRAMES[0])
        mgr.finalize(a)
        mgr.open_session(CFG, STREAM)  # slot freed

    def test_service_max_queue_rejects_at_admission(self):
        svc = FFCzService(
            get_compressor("szlike"),
            config=ServiceConfig(max_queue=2, pipeline_depth=1),
        )
        svc.submit_compress(FRAMES[0], CFG)
        svc.submit_compress(FRAMES[1], CFG)
        with pytest.raises(ResourceExhausted) as ei:
            svc.submit_compress(FRAMES[2], CFG)
        assert ei.value.stage == "admit"
        assert all(r.ok for r in svc.drain().values())
        svc.close()

    def test_service_creates_journal_dir(self, tmp_path):
        # --session-journal-dir may point at a directory that does not exist
        # yet (fresh deploy); the service must create it, not crash the
        # first open_session
        jdir = tmp_path / "wal" / "journals"
        svc = FFCzService(
            get_compressor("szlike"),
            config=ServiceConfig(
                pipeline_depth=1, session_journal_dir=str(jdir)
            ),
        )
        sid = svc.open_session(CFG, STREAM, session_id="jd")
        uid = svc.submit_append(sid, 0, FRAMES[0])
        assert svc.drain()[uid].ok
        assert (jdir / "jd.wal").exists()
        svc.close()

    def test_roi_config_rejected_for_sessions(self):
        mgr = _manager()
        roi_cfg = FFCzConfig(
            E_rel=1e-3, Delta_rel=1e-3, E_roi=np.ones((16, 16), bool)
        )
        with pytest.raises(ValueError):
            mgr.open_session(roi_cfg, STREAM)
        assert mgr.live_sessions == []

    def test_spill_and_resume_is_bitwise_neutral(self):
        # one 16x16 float32 frame is 1 KiB of history; a session holds at
        # most two.  3000 bytes forces the idle session out when the second
        # one starts appending.
        mgr = _manager(max_history_bytes=3000)
        other = _frames(2, seed=9)
        s1 = mgr.open_session(CFG, STREAM)
        mgr.append_frame(s1, 0, FRAMES[0])
        mgr.append_frame(s1, 1, FRAMES[1])
        s2 = mgr.open_session(CFG, STREAM)
        mgr.append_frame(s2, 0, other[0])
        assert mgr.counters["spills"] == 1
        assert mgr.session_stats(s1).state == "spilled"
        # the next append to the spilled session restores it from its journal
        r = mgr.append_frame(s1, 2, FRAMES[2])
        assert r.seq == 2
        st = mgr.session_stats(s1)
        assert st.state == "open" and st.restores == 1
        assert mgr.counters["restores"] == 1
        ref = _codec().compress_stream(FRAMES[:3])
        assert mgr.finalize(s1) == ref
