"""Hermitian rFFT fast path: oracle parity, weighted counts, batched entry,
half-spectrum serialization and legacy-blob backward compatibility."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.compressors import get_compressor
from repro.core.blockwise import blockwise_correct, correct_batch
from repro.core.cubes import (
    fcube_violations,
    project_box_relaxed,
    rfft_pair_weights,
    rfft_shape,
)
from repro.core.edits import EncodedEdits, decode_edits, encode_edits
from repro.core.ffcz import FFCz, FFCzBlob, FFCzConfig
from repro.core.pocs import alternating_projection

# 1D/2D/3D, odd and even last axis — the N//2+1 edge cases
SHAPES = [(128,), (127,), (32, 32), (31, 17), (12, 10, 16), (8, 9, 15)]


def _mismatch(a, b):
    return float(np.abs(np.asarray(a) - np.asarray(b)).max())


class TestRfftOracleParity:
    @pytest.mark.parametrize("shape", SHAPES)
    def test_matches_complex_fft_scalar_delta(self, shape, rng):
        E = 0.1
        eps0 = np.clip(rng.standard_normal(shape) * 0.05, -E, E).astype(np.float32)
        Delta = 0.4 * np.abs(np.fft.fftn(eps0)).max()
        r_c = alternating_projection(jnp.asarray(eps0), E, Delta, max_iters=500, use_rfft=False)
        r_r = alternating_projection(jnp.asarray(eps0), E, Delta, max_iters=500, use_rfft=True)
        assert int(r_c.iterations) == int(r_r.iterations)
        assert bool(r_c.converged) and bool(r_r.converged)
        assert _mismatch(r_c.eps, r_r.eps) < 1e-6
        assert _mismatch(r_c.spat_edits, r_r.spat_edits) < 1e-6
        # freq edits agree after transforming back to the spatial basis
        full = np.fft.ifftn(np.asarray(r_c.freq_edits)).real
        half = np.fft.irfftn(
            np.asarray(r_r.freq_edits), s=shape, axes=tuple(range(len(shape)))
        )
        assert np.abs(full - half).max() < 1e-6
        assert np.asarray(r_r.freq_edits).shape == rfft_shape(shape)

    @pytest.mark.parametrize("shape", [(256,), (255,), (24, 18)])
    def test_matches_complex_fft_pointwise_delta(self, shape, rng):
        E = 0.1
        eps0 = np.clip(rng.standard_normal(shape) * 0.05, -E, E).astype(np.float32)
        d0 = np.abs(np.fft.fftn(eps0))
        Delta_full = np.maximum(0.5 * d0, 0.1 * d0.max()).astype(np.float32)
        Delta_half = Delta_full[..., : shape[-1] // 2 + 1]
        r_c = alternating_projection(
            jnp.asarray(eps0), E, jnp.asarray(Delta_full), max_iters=1000, use_rfft=False
        )
        # both the half grid and the auto-sliced full grid must work
        for Delta in (jnp.asarray(Delta_half), jnp.asarray(Delta_full)):
            r_r = alternating_projection(jnp.asarray(eps0), E, Delta, max_iters=1000, use_rfft=True)
            assert int(r_c.iterations) == int(r_r.iterations)
            assert _mismatch(r_c.eps, r_r.eps) < 1e-6

    @pytest.mark.parametrize("shape", SHAPES)
    def test_weighted_violation_counts_match_full_spectrum(self, shape, rng):
        eps = rng.standard_normal(shape).astype(np.float32)
        d_full = np.fft.fftn(eps)
        d_half = np.fft.rfftn(eps)
        w = rfft_pair_weights(shape)
        assert int(np.sum(np.broadcast_to(np.asarray(w), rfft_shape(shape)))) == int(
            np.prod(shape)
        )
        for Delta in (0.25, 1.0, 4.0):
            v_full = int(fcube_violations(jnp.asarray(d_full), Delta))
            v_half = int(fcube_violations(jnp.asarray(d_half), Delta, w))
            assert v_full == v_half

    def test_weighted_violations_kernel_path(self, rng):
        from repro.kernels.fcube.ops import project_fcube_fused

        shape = (24, 18)
        d_half = np.fft.rfftn(rng.standard_normal(shape)).astype(np.complex64)
        w = rfft_pair_weights(shape)
        _, _, viol = project_fcube_fused(jnp.asarray(d_half), 0.7, weight=w)
        expected = int(fcube_violations(jnp.asarray(d_half), 0.7, w))
        assert int(viol) == expected

    def test_final_violations_full_spectrum_semantics(self, rng):
        """A non-converged run reports full-spectrum violation counts."""
        eps0 = (rng.standard_normal(64) * 0.1).astype(np.float32)
        E = 1.0  # s-cube never binds -> first f-check decides
        Delta = 1e-9  # everything violates; cannot converge in 1 iter
        r_c = alternating_projection(jnp.asarray(eps0), E, Delta, max_iters=1, use_rfft=False)
        r_r = alternating_projection(jnp.asarray(eps0), E, Delta, max_iters=1, use_rfft=True)
        assert int(r_r.final_violations) == int(r_c.final_violations) > 0


class TestRelaxedProjectionClosedForm:
    def test_one_clip_matches_double_projection(self, rng):
        x = (rng.standard_normal(4096) * 2).astype(np.float32)
        for relax in (1.0, 1.3, 1.7, 1.95):
            fused = np.asarray(project_box_relaxed(jnp.asarray(x), 0.5, relax))
            first = np.clip(x, -0.5, 0.5)
            over = x + relax * (first - x)
            oracle = np.clip(over, -0.5, 0.5)
            np.testing.assert_allclose(fused, oracle, atol=1e-6)

    def test_pointwise_bound(self, rng):
        x = (rng.standard_normal(512) * 2).astype(np.float32)
        b = (0.1 + np.abs(rng.standard_normal(512))).astype(np.float32)
        fused = np.asarray(project_box_relaxed(jnp.asarray(x), jnp.asarray(b), 1.4))
        oracle = np.clip(x + 1.4 * (np.clip(x, -b, b) - x), -b, b)
        np.testing.assert_allclose(fused, oracle, atol=1e-6)


class TestCorrectBatch:
    def test_matches_per_tensor_blockwise(self, rng):
        tensors = [
            (rng.standard_normal((1000,)) * 0.01).astype(np.float32),
            (rng.standard_normal((64, 48)) * 0.02).astype(np.float32),
            (rng.standard_normal((3000,)) * 0.005).astype(np.float32),
        ]
        Es = [0.02, 0.03, 0.01]
        Ds = [0.5, 0.4, 0.3]
        outs, stats = correct_batch(
            [jnp.asarray(t) for t in tensors], Es, Ds, block=512, max_iters=50
        )
        for t, E, D, o in zip(tensors, Es, Ds, outs):
            ref = blockwise_correct(jnp.asarray(t), E, D, block=512, max_iters=50)
            assert _mismatch(ref, o) == 0.0
            assert np.asarray(o).shape == t.shape

    def test_per_instance_iteration_counts(self, rng):
        # one already-feasible tensor (1 iteration) + one needing work
        easy = (rng.standard_normal(512) * 1e-6).astype(np.float32)
        hard = (rng.standard_normal(512) * 0.05).astype(np.float32)
        Delta_hard = 0.3 * np.abs(np.fft.fft(hard)).max()
        outs, stats = correct_batch(
            [jnp.asarray(easy), jnp.asarray(hard)],
            [1.0, 0.06],
            [1e9, float(Delta_hard)],
            block=512,
            max_iters=100,
        )
        iters = np.asarray(stats.iterations)
        assert iters[0] == 1  # containment case
        assert iters[1] >= 1
        assert np.asarray(stats.converged).all()
        # the easy instance is untouched
        assert _mismatch(outs[0], easy) == 0.0

    def test_edit_streams_reconstruct(self, rng):
        t = (rng.standard_normal(1500) * 0.02).astype(np.float32)
        E, D = 0.04, 0.6
        outs, edits, stats = correct_batch(
            [jnp.asarray(t)], E, D, block=512, max_iters=50, return_edits=True
        )
        spat, freq = edits[0]
        tiles = np.pad(t, (0, 36)).reshape(-1, 512)
        recon = tiles + np.fft.irfft(np.asarray(freq), n=512, axis=-1) + np.asarray(spat)
        # the identity holds on the stored region (pad-tail values are loop
        # state the unpack discards — see correct_batch docstring)
        assert np.abs(recon.reshape(-1)[: t.size] - np.asarray(outs[0])).max() < 1e-6

    def test_empty_batch(self):
        outs, stats = correct_batch([], 0.1, 0.1)
        assert outs == [] and stats.iterations.shape == (0,)


class TestHalfSpectrumSerialization:
    def test_format_flag_roundtrips(self, rng):
        freq = (rng.standard_normal((10, 9)) + 1j * rng.standard_normal((10, 9))) * 0.01
        enc = encode_edits(freq, 0.2, m=16, half_spectrum=True)
        back = EncodedEdits.from_bytes(enc.to_bytes())
        assert back.half_spectrum and back.is_complex
        assert back.quant_bits == 16
        assert back.shape == (10, 9)
        # legacy streams (bit 7 clear) parse as full-spectrum
        enc_legacy = encode_edits(freq, 0.2, m=16)
        assert not EncodedEdits.from_bytes(enc_legacy.to_bytes()).half_spectrum

    def test_nbytes_is_exact(self, rng):
        for edits in (
            np.zeros(100),
            (rng.standard_normal(333) * 0.01).astype(np.float64),
            (rng.standard_normal((7, 11)) + 1j * rng.standard_normal((7, 11))) * 0.01,
        ):
            enc = encode_edits(edits, 0.5, m=16)
            assert enc.nbytes() == len(enc.to_bytes())

    def test_ffcz_blob_freq_stream_is_half_spectrum(self):
        from repro.data.fields import make_field

        x = make_field("nyx-like")[:16, :16, :16]
        c = FFCz(get_compressor("szlike"), FFCzConfig(E_rel=1e-3, Delta_rel=1e-3))
        _, blob = c.roundtrip(x)
        assert blob.freq_edits.half_spectrum
        assert blob.freq_edits.shape == rfft_shape(x.shape)
        blob2 = FFCzBlob.from_bytes(blob.to_bytes())
        assert blob2.freq_edits.half_spectrum


class TestLegacyBlobBackwardCompat:
    """Blobs written by the pre-rfft pipeline (full-spectrum freq edits, no
    format flag) must still decompress byte-identically."""

    def _legacy_blob(self, blob: FFCzBlob, shape) -> FFCzBlob:
        """Re-encode a modern blob the way the old pipeline serialized it."""
        if blob.pointwise_delta is not None:
            half_delta = np.frombuffer(blob.pointwise_delta, dtype=np.float32).reshape(
                rfft_shape(shape)
            )
            full_delta = np.zeros(shape, dtype=np.float32)
            full_delta[..., : shape[-1] // 2 + 1] = half_delta
            for k in range(1, shape[-1] // 2 + 1):
                if (shape[-1] - k) > shape[-1] // 2:
                    full_delta[..., shape[-1] - k] = half_delta[..., k]
            Delta_full = full_delta
            pw = full_delta.tobytes()
        else:
            Delta_full = blob.Delta_scalar
            pw = None
        half = decode_edits(blob.freq_edits, (
            np.frombuffer(blob.pointwise_delta, dtype=np.float32).reshape(rfft_shape(shape))
            if blob.pointwise_delta is not None else blob.Delta_scalar
        ))
        # rebuild the full Hermitian spectrum the old pipeline stored
        spatial = np.fft.irfftn(half, s=shape, axes=tuple(range(len(shape))))
        full = np.fft.fftn(spatial)
        fe = encode_edits(full, Delta_full, m=blob.freq_edits.quant_bits, half_spectrum=False)
        return dataclasses.replace(blob, freq_edits=fe, pointwise_delta=pw, stats=None)

    @pytest.mark.parametrize("pspec", [False, True])
    def test_legacy_full_spectrum_blob_decodes(self, pspec, rng):
        x = (rng.standard_normal((24, 20)).astype(np.float32)).cumsum(axis=0)
        cfg = (
            FFCzConfig(E_rel=1e-3, Delta_rel=None, pspec_rel=1e-2, max_iters=500)
            if pspec
            else FFCzConfig(E_rel=1e-3, Delta_rel=1e-3, max_iters=500)
        )
        c = FFCz(get_compressor("szlike"), cfg)
        blob = c.compress(x)
        modern = c.decompress(blob)
        legacy = self._legacy_blob(blob, x.shape)
        # through serialization: the flag byte must survive the wire
        legacy_wire = FFCzBlob.from_bytes(legacy.to_bytes())
        assert not legacy_wire.freq_edits.half_spectrum
        out = c.decompress(legacy_wire)
        # identical up to the (coarser) re-quantization of the freq stream
        E = float(blob.E)
        assert np.abs(out.astype(np.float64) - modern.astype(np.float64)).max() <= E
        # and the legacy reconstruction still honors the spatial bound
        assert np.abs(out.astype(np.float64) - x.astype(np.float64)).max() <= E * (1 + 1e-6)


class TestFftImplSelector:
    """fft_impl='packed'/'pallas' loop parity vs the XLA transforms."""

    @pytest.mark.parametrize("impl", ["packed", "pallas"])
    @pytest.mark.parametrize("shape", [(128,), (32, 32), (12, 10, 16)])
    def test_matches_xla_loop(self, impl, shape, rng):
        E = 0.1
        eps0 = np.clip(rng.standard_normal(shape) * 0.05, -E, E).astype(np.float32)
        Delta = 0.4 * np.abs(np.fft.fftn(eps0)).max()
        r_x = alternating_projection(jnp.asarray(eps0), E, Delta, max_iters=500)
        r_i = alternating_projection(jnp.asarray(eps0), E, Delta, max_iters=500, fft_impl=impl)
        # packed transforms round differently at float32 level, so the
        # trajectory may differ by rounding; the fixed point must agree
        assert bool(r_i.converged)
        assert abs(int(r_i.iterations) - int(r_x.iterations)) <= 1
        assert _mismatch(r_x.eps, r_i.eps) < 1e-5
        assert np.abs(np.asarray(r_i.eps)).max() <= E

    @pytest.mark.parametrize("impl", ["packed", "pallas"])
    def test_pointwise_delta(self, impl, rng):
        shape = (24, 18)
        E = 0.1
        eps0 = np.clip(rng.standard_normal(shape) * 0.05, -E, E).astype(np.float32)
        d0 = np.abs(np.fft.rfftn(eps0))
        Delta = np.maximum(0.5 * d0, 0.1 * d0.max()).astype(np.float32)
        r_x = alternating_projection(jnp.asarray(eps0), E, jnp.asarray(Delta), max_iters=1000)
        r_i = alternating_projection(
            jnp.asarray(eps0), E, jnp.asarray(Delta), max_iters=1000, fft_impl=impl
        )
        assert bool(r_i.converged)
        assert _mismatch(r_x.eps, r_i.eps) < 1e-5

    @pytest.mark.parametrize("impl", ["packed", "pallas"])
    def test_odd_last_axis_falls_back(self, impl, rng):
        """Odd shapes statically fall back; 'packed' becomes the exact XLA
        path, 'pallas' the XLA transforms + fused projection kernels."""
        shape = (31, 17)
        E = 0.1
        eps0 = np.clip(rng.standard_normal(shape) * 0.05, -E, E).astype(np.float32)
        Delta = 0.4 * np.abs(np.fft.fftn(eps0)).max()
        r_x = alternating_projection(jnp.asarray(eps0), E, Delta, max_iters=500)
        r_i = alternating_projection(jnp.asarray(eps0), E, Delta, max_iters=500, fft_impl=impl)
        assert int(r_i.iterations) == int(r_x.iterations)
        assert _mismatch(r_x.eps, r_i.eps) == 0.0

    def test_invalid_combinations_raise(self, rng):
        eps0 = jnp.zeros((16,), jnp.float32)
        with pytest.raises(ValueError, match="fft_impl"):
            alternating_projection(eps0, 0.1, 0.1, fft_impl="duff")
        with pytest.raises(ValueError, match="rfft"):
            alternating_projection(eps0, 0.1, 0.1, fft_impl="packed", use_rfft=False)
        with pytest.raises(ValueError, match="use_kernels"):
            alternating_projection(eps0, 0.1, 0.1, fft_impl="pallas", use_kernels=True)
        with pytest.raises(ValueError, match="relax"):
            alternating_projection(eps0, 0.1, 0.1, fft_impl="pallas", relax=1.3)
        with pytest.raises(ValueError, match="check_every"):
            alternating_projection(eps0, 0.1, 0.1, check_every=0)

    @pytest.mark.parametrize("impl", ["packed", "pallas"])
    def test_blockwise_backends_take_fft_impl(self, impl, rng):
        """The vmapped pencil program lifts the packed transforms unchanged."""
        eps = (rng.standard_normal(512) * 0.02).astype(np.float32)
        base = np.asarray(blockwise_correct(jnp.asarray(eps), 0.03, 0.05, block=128, max_iters=60))
        got = np.asarray(
            blockwise_correct(jnp.asarray(eps), 0.03, 0.05, block=128, max_iters=60, fft_impl=impl)
        )
        assert np.abs(got).max() <= 0.03
        assert np.abs(got - base).max() < 1e-6


class TestCheckEveryCadence:
    def test_cadenced_loop_converges_and_holds_bounds(self, rng):
        shape = (32, 32)
        E = 0.05
        eps0 = np.clip(rng.standard_normal(shape) * 0.03, -E, E).astype(np.float32)
        Delta = 0.3 * np.abs(np.fft.fftn(eps0)).max()
        r1 = alternating_projection(jnp.asarray(eps0), E, Delta, max_iters=500)
        for k in (2, 5):
            rk = alternating_projection(jnp.asarray(eps0), E, Delta, max_iters=500, check_every=k)
            assert bool(rk.converged)
            # convergence is declared at the first check at-or-after the true
            # iteration (extra iterations are safe no-ops)
            assert int(r1.iterations) <= int(rk.iterations) < int(r1.iterations) + k
            assert int(rk.final_violations) == 0
            assert np.abs(np.asarray(rk.eps)).max() <= E
            d = np.fft.rfftn(np.asarray(rk.eps, dtype=np.float64))
            tol = Delta * 2e-5
            assert max(np.abs(d.real).max(), np.abs(d.imag).max()) <= Delta + tol

    def test_final_iteration_always_checks(self, rng):
        """A max_iters exit reports a real violation count, never a stale one.

        Adversarial never-feasible configuration (the bench's forced-iteration
        workload): every point sits on an s-cube face with an imbalanced sign
        pattern and the f-cube pins the DC component, so the s-projection
        restores the DC violation every iteration.
        """
        E = 0.05
        sgn = np.where(rng.random(64) < 0.7, 1.0, -1.0)
        eps0 = (E * sgn).astype(np.float32)
        Delta = (1e9 * np.ones(33)).astype(np.float32)
        Delta[0] = 1e-4 * abs(float(eps0.sum()))
        r = alternating_projection(
            jnp.asarray(eps0), E, jnp.asarray(Delta), max_iters=5, check_every=4
        )
        assert not bool(r.converged)
        assert int(r.final_violations) > 0
