# NOTE: deliberately no XLA_FLAGS here — smoke tests and benches must see the
# real single CPU device.  Multi-device distribution tests run in a
# subprocess that sets xla_force_host_platform_device_count itself
# (tests/test_distributed.py).
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
