# NOTE: deliberately no XLA_FLAGS here — smoke tests and benches must see the
# real single CPU device.  Multi-device distribution tests run in a
# subprocess that sets xla_force_host_platform_device_count itself
# (tests/test_distributed.py).
import os

import numpy as np
import pytest

# Deterministic hypothesis profile for CI: fixed derivation (derandomize) so
# the randomized conformance suite reproduces identically across runs and
# pytest-xdist workers, with a CI-scoped example budget.  Loaded whenever CI
# is set (GitHub Actions exports CI=true); override with HYPOTHESIS_PROFILE.
# Tests that pass their own @settings keep those values — the profile fills
# the unspecified ones.
try:
    from hypothesis import settings as _hyp_settings

    _hyp_settings.register_profile(
        "ci", max_examples=20, derandomize=True, deadline=None, print_blob=True
    )
    if os.environ.get("CI"):
        _hyp_settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))
except ModuleNotFoundError:
    pass


def pytest_configure(config):
    # The chaos suite tags itself with @pytest.mark.timeout (a no-hang bound
    # enforced when pytest-timeout is installed, e.g. in CI).  Register the
    # marker so environments without the plugin run warning-free.
    config.addinivalue_line(
        "markers", "timeout(seconds): per-test wall-clock bound (pytest-timeout)"
    )


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
