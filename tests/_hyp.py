"""Optional-hypothesis shim: property tests skip (not error) when absent.

``from _hyp import given, settings, st`` gives the real hypothesis API when
it is installed (see requirements-dev.txt).  Without it, ``@given`` turns the
test into a skip and ``st.*`` strategy builders become inert placeholders, so
plain unit tests in the same module keep running — the suite degrades to
skips instead of dying at collection.
"""

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ModuleNotFoundError:
    import pytest

    class _InertStrategies:
        def __getattr__(self, name):
            return lambda *args, **kwargs: None

    st = _InertStrategies()

    def given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis not installed (see requirements-dev.txt)")

    def settings(*args, **kwargs):
        return lambda fn: fn
