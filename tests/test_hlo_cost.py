"""Trip-count-aware HLO cost analyzer (the roofline's measurement tool)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_cost import analyze_hlo


def _flops(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    return analyze_hlo(c.as_text())


class TestHloCost:
    def test_scan_trip_count_multiplied(self):
        """XLA's cost_analysis counts a scan body once; ours multiplies."""
        x = jnp.zeros((128, 128))
        w = jnp.zeros((128, 128))

        def f(x, w):
            def body(c, _):
                return c @ w, None

            c, _ = jax.lax.scan(body, x, None, length=10)
            return c

        cost = _flops(f, x, w)
        expect = 10 * 2 * 128**3
        assert 0.95 * expect < cost.flops < 1.1 * expect, cost.flops

    def test_nested_scan(self):
        x = jnp.zeros((64, 64))
        w = jnp.zeros((64, 64))

        def f(x, w):
            def outer(c, _):
                def inner(d, _):
                    return d @ w, None

                d, _ = jax.lax.scan(inner, c, None, length=5)
                return d, None

            c, _ = jax.lax.scan(outer, x, None, length=4)
            return c

        cost = _flops(f, x, w)
        expect = 20 * 2 * 64**3
        assert 0.9 * expect < cost.flops < 1.2 * expect

    def test_fft_flops_counted(self):
        cost = _flops(lambda v: jnp.fft.fft(v), jnp.zeros(4096, jnp.complex64))
        expect = 5 * 4096 * np.log2(4096)
        assert 0.9 * expect < cost.flops < 1.5 * expect

    def test_dynamic_while_flagged(self):
        def f(n):
            def body(c):
                i, v = c
                return (i + 1, v * 1.5)

            return jax.lax.while_loop(lambda c: c[0] < n, body, (0, 1.0))

        cost = _flops(f, jnp.int32(7))
        assert cost.unknown_trips >= 1

    def test_collective_attribution_keys(self):
        # single-device module: no collectives, attribution empty
        cost = _flops(lambda a, b: a @ b, jnp.zeros((32, 32)), jnp.zeros((32, 32)))
        assert cost.collectives == {} and cost.coll_by_name == {}
