"""GPipe pipeline parallelism: schedule equivalence vs sequential execution.

Runs in a subprocess with 8 fake host devices (XLA_FLAGS before jax import).
"""

import json
import os
import subprocess
import sys

import pytest

from repro.sharding.pipeline import bubble_fraction

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from repro.sharding.pipeline import pipeline_apply

mesh = jax.make_mesh((4, 2), ("pipe", "data"))
S, LPS, M, MB, D = 4, 2, 6, 3, 16  # stages, layers/stage, microbatches, mb size, width
rng = np.random.default_rng(0)
Ws = jnp.asarray(rng.standard_normal((S, LPS, D, D)) * 0.3, dtype=jnp.float32)
bs = jnp.asarray(rng.standard_normal((S, LPS, D)) * 0.1, dtype=jnp.float32)
x = jnp.asarray(rng.standard_normal((M, MB, D)), dtype=jnp.float32)

def stage_fn(params, h):
    W, b = params
    def layer(h, wb):
        w, bb = wb
        return jnp.tanh(h @ w + bb), None
    h, _ = jax.lax.scan(layer, h, (W, b))
    return h

# sequential reference: all S*LPS layers in order
def reference(x):
    h = x
    for s in range(S):
        h = stage_fn((Ws[s], bs[s]), h)
    return h

with mesh:
    out = pipeline_apply(stage_fn, (Ws, bs), x, mesh, axis="pipe")
ref = jax.vmap(reference)(x.reshape(M, MB, D)).reshape(M, MB, D) if False else reference(x)
err = float(jnp.abs(out - ref).max())
print("RESULTS:" + json.dumps({"err": err}))
"""


class TestPipeline:
    def test_bubble_fraction(self):
        assert bubble_fraction(4, 6) == pytest.approx(3 / 9)
        assert bubble_fraction(1, 8) == 0.0

    def test_schedule_matches_sequential(self):
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
        proc = subprocess.run(
            [sys.executable, "-c", _SCRIPT], capture_output=True, text=True, env=env, timeout=600
        )
        assert proc.returncode == 0, proc.stderr[-3000:]
        line = [l for l in proc.stdout.splitlines() if l.startswith("RESULTS:")][0]
        res = json.loads(line[len("RESULTS:"):])
        assert res["err"] < 1e-5, res
