"""Unit + property tests for the entropy-coding layer."""

import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis or skip-stubs (requirements-dev.txt)

from repro.coding import (
    huffman_decode,
    huffman_encode,
    lossless_compress,
    lossless_decompress,
    pack_bits,
    unpack_bits,
)
from repro.coding.quantize import bound_shrink, dequantize_uniform, quantize_uniform


class TestBitpack:
    def test_roundtrip(self, rng):
        flags = rng.random(1000) < 0.1
        assert (unpack_bits(pack_bits(flags), 1000) == flags).all()

    def test_empty(self):
        assert unpack_bits(pack_bits(np.zeros(0, bool)), 0).size == 0

    @given(st.lists(st.booleans(), max_size=200))
    @settings(max_examples=50, deadline=None)
    def test_roundtrip_property(self, bits):
        arr = np.array(bits, dtype=bool)
        assert (unpack_bits(pack_bits(arr), len(bits)) == arr).all()


class TestHuffman:
    def test_roundtrip_uniform(self, rng):
        s = rng.integers(-100, 100, 5000)
        assert (huffman_decode(huffman_encode(s)) == s).all()

    def test_roundtrip_skewed(self, rng):
        s = np.rint(rng.standard_normal(5000) * 2).astype(np.int64)
        enc = huffman_encode(s)
        assert (huffman_decode(enc) == s).all()
        # skewed stream must compress below 8 bytes/sym baseline
        assert len(enc) < s.size * 8

    def test_single_symbol(self):
        s = np.zeros(100, dtype=np.int64)
        assert (huffman_decode(huffman_encode(s)) == s).all()

    def test_empty(self):
        assert huffman_decode(huffman_encode(np.zeros(0))).size == 0

    @given(st.lists(st.integers(-(2**40), 2**40), max_size=300))
    @settings(max_examples=50, deadline=None)
    def test_roundtrip_property(self, vals):
        s = np.array(vals, dtype=np.int64)
        assert (huffman_decode(huffman_encode(s)) == s).all()


class TestLossless:
    @pytest.mark.parametrize("codec", ["huffman+zlib", "zlib"])
    def test_roundtrip(self, codec, rng):
        s = rng.integers(-1000, 1000, 3000)
        assert (lossless_decompress(lossless_compress(s, codec=codec)) == s).all()

    def test_bad_codec(self):
        with pytest.raises(ValueError):
            lossless_compress(np.zeros(3), codec="nope")


class TestQuantize:
    @given(
        st.floats(1e-6, 1e6),
        st.integers(4, 24),
        st.lists(st.floats(-100, 100), min_size=1, max_size=100),
    )
    @settings(max_examples=100, deadline=None)
    def test_error_bound(self, bound, m, vals):
        v = np.array(vals)
        codes = quantize_uniform(v, bound, m)
        back = dequantize_uniform(codes, bound, m)
        # round-to-nearest: |err| <= step/2 = bound * 2^-m, plus the float64
        # resolution of v/step itself (binds when |v|/bound ~ 2^52-ish —
        # found by hypothesis at bound=1e-6, m=23, v=33.7)
        tol = bound * 2.0 ** (-m) * (1 + 1e-12) + 8 * np.finfo(np.float64).eps * np.abs(v)
        assert np.all(np.abs(back - v) <= tol)

    def test_pointwise_bound_array(self, rng):
        v = rng.standard_normal(64)
        b = np.abs(rng.standard_normal(64)) + 0.1
        back = dequantize_uniform(quantize_uniform(v, b, 8), b, 8)
        assert np.all(np.abs(back - v) <= b * 2.0**-8 * (1 + 1e-12))

    def test_zero_bound_is_zero_codes(self):
        codes = quantize_uniform(np.ones(4), 0.0, 16)
        assert (codes == 0).all()

    def test_bound_shrink(self):
        assert bound_shrink(1.0, 16) == 1.0 - 2.0**-16
