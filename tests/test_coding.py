"""Unit + property tests for the entropy-coding layer."""

import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis or skip-stubs (requirements-dev.txt)

from repro.coding import (
    huffman_decode,
    huffman_encode,
    lossless_compress,
    lossless_decompress,
    pack_bits,
    unpack_bits,
)
from repro.coding.quantize import bound_shrink, dequantize_uniform, quantize_uniform


class TestBitpack:
    def test_roundtrip(self, rng):
        flags = rng.random(1000) < 0.1
        assert (unpack_bits(pack_bits(flags), 1000) == flags).all()

    def test_empty(self):
        assert unpack_bits(pack_bits(np.zeros(0, bool)), 0).size == 0

    @given(st.lists(st.booleans(), max_size=200))
    @settings(max_examples=50, deadline=None)
    def test_roundtrip_property(self, bits):
        arr = np.array(bits, dtype=bool)
        assert (unpack_bits(pack_bits(arr), len(bits)) == arr).all()


class TestHuffman:
    def test_roundtrip_uniform(self, rng):
        s = rng.integers(-100, 100, 5000)
        assert (huffman_decode(huffman_encode(s)) == s).all()

    def test_roundtrip_skewed(self, rng):
        s = np.rint(rng.standard_normal(5000) * 2).astype(np.int64)
        enc = huffman_encode(s)
        assert (huffman_decode(enc) == s).all()
        # skewed stream must compress below 8 bytes/sym baseline
        assert len(enc) < s.size * 8

    def test_single_symbol(self):
        s = np.zeros(100, dtype=np.int64)
        assert (huffman_decode(huffman_encode(s)) == s).all()

    def test_empty(self):
        assert huffman_decode(huffman_encode(np.zeros(0))).size == 0

    @given(st.lists(st.integers(-(2**40), 2**40), max_size=300))
    @settings(max_examples=50, deadline=None)
    def test_roundtrip_property(self, vals):
        s = np.array(vals, dtype=np.int64)
        assert (huffman_decode(huffman_encode(s)) == s).all()


def _decode_walk_reference(data: bytes) -> np.ndarray:
    """The pre-vectorization per-symbol LUT walk (ISSUE 5 regression oracle)."""
    import struct

    from repro.coding.huffman import _canonical_codes

    (n_alpha,) = struct.unpack_from("<I", data, 0)
    off = 4
    if n_alpha == 0:
        return np.zeros(0, dtype=np.int64)
    alphabet = np.frombuffer(data, dtype="<i8", count=n_alpha, offset=off).copy()
    off += 8 * n_alpha
    lengths = np.frombuffer(data, dtype=np.uint8, count=n_alpha, offset=off).copy()
    off += n_alpha
    n_syms, n_bits = struct.unpack_from("<QQ", data, off)
    off += 16
    bits = np.unpackbits(np.frombuffer(data, dtype=np.uint8, offset=off), count=n_bits)
    codes = _canonical_codes(lengths)
    max_len = int(lengths.max())
    table_sym = np.zeros(1 << max_len, dtype=np.int64)
    table_len = np.zeros(1 << max_len, dtype=np.int64)
    for sym in range(n_alpha):
        ln = int(lengths[sym])
        base = int(codes[sym]) << (max_len - ln)
        table_sym[base : base + (1 << (max_len - ln))] = sym
        table_len[base : base + (1 << (max_len - ln))] = ln
    padded = np.concatenate([bits, np.zeros(max_len, dtype=np.uint8)])
    weights = (1 << np.arange(max_len - 1, -1, -1)).astype(np.int64)
    out = np.empty(n_syms, dtype=np.int64)
    pos = 0
    for i in range(int(n_syms)):
        window = int(padded[pos : pos + max_len] @ weights)
        out[i] = table_sym[window]
        pos += int(table_len[window])
    return alphabet[out]


class TestHuffmanVectorizedDecode:
    """ISSUE 5 satellite: the decode LUT walk is numpy-vectorized
    (windowed u32 reads + pointer-doubling chain) and byte-exact."""

    @pytest.mark.parametrize(
        "make",
        [
            lambda rng: rng.geometric(0.3, 20000) - 1,
            lambda rng: rng.integers(-5, 6, 20000),
            lambda rng: np.where(rng.random(20000) < 0.97, 0, rng.integers(-999, 999, 20000)),
            lambda rng: rng.integers(0, 5000, 20000),  # wide alphabet, long codes
            lambda rng: np.array([42]),
            lambda rng: np.zeros(7, dtype=np.int64),  # single-symbol alphabet
        ],
    )
    def test_matches_reference_walk(self, make, rng):
        s = np.asarray(make(rng), dtype=np.int64)
        enc = huffman_encode(s)
        got = huffman_decode(enc)
        assert np.array_equal(got, s)
        assert np.array_equal(got, _decode_walk_reference(enc))

    def test_chunked_decode_crosses_boundaries(self, rng, monkeypatch):
        """The decoder's temporaries are bounded by DECODE_CHUNK_BITS; a
        tiny odd chunk forces many boundary crossings (codes straddling the
        chunk edge seed the next chunk with their exact start bit)."""
        import repro.coding.huffman as hm

        s = np.where(rng.random(20000) < 0.9, 0, rng.integers(-500, 500, 20000))
        enc = huffman_encode(s)
        want = huffman_decode(enc)
        for chunk in (1, 7, 257):
            monkeypatch.setattr(hm, "DECODE_CHUNK_BITS", chunk)
            assert np.array_equal(huffman_decode(enc), want)

    def test_truncated_stream_raises(self, rng):
        """The vectorized path keeps the old unpackbits length guard: a
        truncated payload fails loudly instead of decoding missing bits as
        zeros."""
        s = rng.integers(-50, 50, 5000)
        enc = huffman_encode(s)
        for cut in (1, 3, 16):
            with pytest.raises(ValueError, match="[Tt]runcated"):
                huffman_decode(enc[:-cut])

    def test_faster_than_reference_walk(self, rng):
        """Regression-timed: the vectorized walk must beat the per-symbol
        Python loop it replaced (>10x in practice; assert 2x to stay robust
        to CI noise)."""
        import time

        s = rng.geometric(0.25, 200000) - 1
        enc = huffman_encode(s)
        huffman_decode(enc)  # warm caches / allocator
        t0 = time.perf_counter()
        got = huffman_decode(enc)
        t_vec = time.perf_counter() - t0
        t0 = time.perf_counter()
        want = _decode_walk_reference(enc)
        t_ref = time.perf_counter() - t0
        assert np.array_equal(got, want)
        assert t_vec < t_ref / 2, f"vectorized {t_vec:.3f}s vs loop {t_ref:.3f}s"


class TestLossless:
    @pytest.mark.parametrize("codec", ["huffman+zlib", "zlib"])
    def test_roundtrip(self, codec, rng):
        s = rng.integers(-1000, 1000, 3000)
        assert (lossless_decompress(lossless_compress(s, codec=codec)) == s).all()

    def test_bad_codec(self):
        with pytest.raises(ValueError):
            lossless_compress(np.zeros(3), codec="nope")


class TestQuantize:
    @given(
        st.floats(1e-6, 1e6),
        st.integers(4, 24),
        st.lists(st.floats(-100, 100), min_size=1, max_size=100),
    )
    @settings(max_examples=100, deadline=None)
    def test_error_bound(self, bound, m, vals):
        v = np.array(vals)
        codes = quantize_uniform(v, bound, m)
        back = dequantize_uniform(codes, bound, m)
        # round-to-nearest: |err| <= step/2 = bound * 2^-m, plus the float64
        # resolution of v/step itself (binds when |v|/bound ~ 2^52-ish —
        # found by hypothesis at bound=1e-6, m=23, v=33.7)
        tol = bound * 2.0 ** (-m) * (1 + 1e-12) + 8 * np.finfo(np.float64).eps * np.abs(v)
        assert np.all(np.abs(back - v) <= tol)

    def test_pointwise_bound_array(self, rng):
        v = rng.standard_normal(64)
        b = np.abs(rng.standard_normal(64)) + 0.1
        back = dequantize_uniform(quantize_uniform(v, b, 8), b, 8)
        assert np.all(np.abs(back - v) <= b * 2.0**-8 * (1 + 1e-12))

    def test_zero_bound_is_zero_codes(self):
        codes = quantize_uniform(np.ones(4), 0.0, 16)
        assert (codes == 0).all()

    def test_bound_shrink(self):
        assert bound_shrink(1.0, 16) == 1.0 - 2.0**-16
