"""SSD/Mamba2: chunked scan vs sequential oracle, decode recurrence, caches."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis or skip-stubs (requirements-dev.txt)

from repro.configs import get_smoke_config
from repro.models.ssm import (
    init_mamba_cache,
    mamba2_apply,
    mamba2_init,
    ssd_chunked,
    ssd_decode_step,
    ssd_ref,
)


def _ssd_inputs(rng, b=2, l=37, h=4, p=8, g=2, n=16):
    return (
        jnp.asarray(rng.standard_normal((b, l, h, p)), dtype=jnp.float32),
        jnp.asarray(rng.uniform(0.01, 0.2, (b, l, h)), dtype=jnp.float32),
        jnp.asarray(rng.uniform(0.5, 2.0, (h,)), dtype=jnp.float32),
        jnp.asarray(rng.standard_normal((b, l, g, n)), dtype=jnp.float32),
        jnp.asarray(rng.standard_normal((b, l, g, n)), dtype=jnp.float32),
    )


class TestSSD:
    @pytest.mark.parametrize("chunk", [4, 8, 64])
    def test_chunked_matches_sequential(self, chunk, rng):
        x, dt, A, B, C = _ssd_inputs(rng)
        y1, S1 = ssd_chunked(x, dt, A, B, C, chunk=chunk)
        y2, S2 = ssd_ref(x, dt, A, B, C)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
        np.testing.assert_allclose(np.asarray(S1), np.asarray(S2), atol=2e-4)

    def test_state_continuation(self, rng):
        x, dt, A, B, C = _ssd_inputs(rng, l=48)
        y_full, _ = ssd_ref(x, dt, A, B, C)
        _, S = ssd_chunked(x[:, :32], dt[:, :32], A, B[:, :32], C[:, :32], chunk=8)
        y2, _ = ssd_chunked(x[:, 32:], dt[:, 32:], A, B[:, 32:], C[:, 32:], chunk=8, initial_state=S)
        np.testing.assert_allclose(np.asarray(y2), np.asarray(y_full)[:, 32:], atol=2e-4)

    def test_decode_step_matches(self, rng):
        x, dt, A, B, C = _ssd_inputs(rng, l=10)
        y_ref, _ = ssd_ref(x, dt, A, B, C)
        S = jnp.zeros((2, 4, 8, 16), dtype=jnp.float32)
        ys = []
        for t in range(10):
            y, S = ssd_decode_step(S, x[:, t], dt[:, t], A, B[:, t], C[:, t])
            ys.append(np.asarray(y))
        np.testing.assert_allclose(np.stack(ys, 1), np.asarray(y_ref), atol=2e-4)

    @given(st.integers(0, 1000), st.sampled_from([4, 16]))
    @settings(max_examples=15, deadline=None)
    def test_chunk_invariance_property(self, seed, chunk):
        """Output must not depend on the chunking (state-space duality)."""
        rng = np.random.default_rng(seed)
        x, dt, A, B, C = _ssd_inputs(rng, b=1, l=23, h=2, p=4, g=1, n=8)
        y1, _ = ssd_chunked(x, dt, A, B, C, chunk=chunk)
        y2, _ = ssd_chunked(x, dt, A, B, C, chunk=23)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)


class TestSegsumGradients:
    def test_finite_grads_under_overflowing_masked_exponent(self, rng):
        """Regression: zamba2-7b smoke NaN grads (ci/known_failures.txt burn-down).

        The masked-out (i < j) entries of the segsum decay matrix are
        *positive* sums of |dt * A|; once one exceeds ~88.7 the float32 exp
        overflows to inf and the old single-where produced inf * 0 = NaN in
        the backward pass while the forward stayed finite.  Pin gradients
        finite on inputs that force exactly that regime.
        """
        x, dt, A, B, C = _ssd_inputs(rng, l=32)
        # dt * A summed over a 32-long chunk must exceed the float32 exp
        # overflow threshold: 32 steps * 0.35 * 16 = 179 >> 88.7
        dt = jnp.full_like(dt, 0.35)
        A = jnp.full_like(A, 16.0)

        def loss(x):
            y, S = ssd_chunked(x, dt, A, B, C, chunk=32)
            return jnp.sum(y**2) + jnp.sum(S**2)

        val, grad = jax.value_and_grad(loss)(x)
        assert np.isfinite(float(val))
        assert np.isfinite(np.asarray(grad)).all()

    def test_zamba2_smoke_train_step_grads_finite(self):
        """The original failing config end to end: one value_and_grad on the
        zamba2-7b smoke model must produce finite loss and gradients."""
        from repro.models.model import build_model

        cfg = get_smoke_config("zamba2-7b")
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)}
        loss, grads = jax.value_and_grad(m.loss)(params, batch)
        assert np.isfinite(float(loss))
        for leaf in jax.tree.leaves(grads):
            assert np.isfinite(np.asarray(leaf, dtype=np.float32)).all()


class TestMamba2Block:
    def test_prefill_decode_consistency(self, rng):
        cfg = get_smoke_config("mamba2-2.7b")
        params = mamba2_init(jax.random.PRNGKey(0), cfg)
        x = jnp.asarray(rng.standard_normal((1, 9, cfg.d_model)), dtype=jnp.float32)
        full, _ = mamba2_apply(params, x, cfg, cache=None)

        cache = init_mamba_cache(1, cfg, jnp.float32)
        out_pre, cache = mamba2_apply(params, x[:, :8], cfg, cache=cache)
        out_dec, cache = mamba2_apply(params, x[:, 8:9], cfg, cache=cache)
        np.testing.assert_allclose(np.asarray(out_pre), np.asarray(full)[:, :8], atol=2e-3)
        np.testing.assert_allclose(np.asarray(out_dec), np.asarray(full)[:, 8:9], atol=2e-3)

    def test_cache_is_o1(self):
        """Decode state size must be independent of sequence length."""
        cfg = get_smoke_config("mamba2-2.7b")
        c = init_mamba_cache(4, cfg, jnp.float32)
        total = sum(np.asarray(l).nbytes for l in jax.tree.leaves(c))
        assert total < 1e6  # constant, tiny — the long_500k superpower
