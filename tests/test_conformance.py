"""Property-based bound-conformance suite (ISSUE 4).

FFCz's value claim is that the spatial and spectral error bounds hold jointly
for ANY regular-grid field — unconditionally on shape (survey literature:
Di et al. 2023; Cappello et al. 2019).  This suite verifies that claim on
randomized shapes (odd, prime, and mesh-non-divisible axes), input dtypes,
and bound kinds (``Delta_abs`` / ``Delta_rel`` / ``pspec``), across the
``local``/``batched``/``sharded`` execution paths:

* whole-field compress -> decompress round trips must hold the spatial bound
  unconditionally and the frequency bound whenever the loop converged (the
  paper contract), verified independently in float64 against the bounds the
  blob STORES — not the ones the test requested;
* the parity tri-state of :func:`repro.sharding.dist_fft.classify_parity`
  must be honored per shape: ``"bitwise"``-class shapes reproduce the
  single-device blob payload byte for byte from a sharded field,
  ``"bound"``-class shapes hold the bounds without byte parity, and
  requesting ``parity="bitwise"`` on a ``"bound"`` shape is the error state;
* pencil-batch corrections are bitwise identical across engine backends;
* the ``fft_impl`` dimension (ISSUE 5): the packed / pallas-interpret loop
  transforms must conform on the same randomized odd/prime/dtype/bound
  matrix — including the float64 recheck against STORED bounds — and their
  parity classification is honest: non-``"xla"`` impls are ``"bound"``-class
  (requesting ``parity="bitwise"`` with them is the error state), while
  pencil corrections remain bitwise identical ACROSS backends for every
  impl (the three backends run the same per-block program).

Sharded cases run in-process and are exercised by the multi-device CI leg
(``XLA_FLAGS=--xla_force_host_platform_device_count=8`` is set for the whole
pytest process there); on a 1-device process they degenerate to a 1-slab
mesh, which still runs the padded-decomposition code path.

Property tests draw through the ``tests/_hyp`` shim: with hypothesis
installed they randomize under the deterministic CI profile registered in
``conftest.py`` (fixed seed via ``derandomize``, CI-scoped example budget);
without it they skip and the deterministic conformance cases below still
gate every shape class.
"""

import jax
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis or skip-stubs (requirements-dev.txt)

from repro.compressors import get_compressor
from repro.core.cubes import rfft_shape
from repro.core.engine import CorrectionEngine
from repro.core.ffcz import FFCz, FFCzBlob, FFCzConfig, ShardedField
from repro.sharding.dist_fft import classify_parity

_N_DEV = len(jax.devices())

# deterministic shape corpus: evenly divisible control, uneven power-of-two,
# odd, prime, and mesh-non-divisible axes, 2-D and 3-D
FIELD_SHAPES = [
    (32, 16, 12),  # divisible + pow2: the PR 3 bitwise contract
    (4, 16, 12),  # axis 0 smaller than an 8-way mesh (uneven pow2 slabs)
    (30, 14, 10),  # even but non-pow2, non-divisible by 8
    (15, 14, 10),  # odd axis 0: non-divisible by every mesh size
    (13, 11, 7),  # all axes prime
    (9, 11),  # 2-D odd/prime
    (32, 48),  # 2-D pow2 axis 0, uneven half axis (H=25)
]
BOUND_KINDS = ["Delta_abs", "Delta_rel", "pspec"]
FFT_IMPLS = ["packed", "pallas"]
# even-last-axis (pack-trick) + odd-last-axis (static fallback) + 2-D
IMPL_SHAPES = [(30, 14, 10), (13, 11, 7), (32, 48)]


def _field(shape, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    f = (rng.standard_normal(shape) * 0.5 + 4.0).cumsum(axis=0)
    return np.ascontiguousarray(f, dtype=dtype)


def _cfg(kind, x, **kw) -> FFCzConfig:
    if kind == "Delta_abs":
        d = float(np.abs(np.fft.rfftn(np.asarray(x, np.float32))).max() * 1e-3)
        return FFCzConfig(E_rel=1e-3, Delta_rel=None, Delta_abs=d, **kw)
    if kind == "Delta_rel":
        return FFCzConfig(E_rel=1e-3, Delta_rel=1e-3, **kw)
    return FFCzConfig(E_rel=1e-3, Delta_rel=None, pspec_rel=1e-3, max_iters=1500, **kw)


def _assert_round_trip_conforms(x, blob, dec):
    """The paper contract, checked in float64 against the STORED bounds:
    spatial bound unconditional; frequency bound whenever converged.  ROI
    blobs are checked against their stored per-point grid — every region's
    own E_n, not just the global envelope."""
    x32 = np.asarray(x, np.float32)
    assert dec.shape == x32.shape and dec.dtype == np.float32
    eps = dec.astype(np.float64) - x32.astype(np.float64)
    if blob.roi_bound is not None:
        grid = np.frombuffer(blob.roi_bound, np.float32).reshape(blob.shape)
        assert (np.abs(eps) <= grid.astype(np.float64)).all(), "ROI spatial bound violated"
        assert float(grid.max()) <= blob.E + 1e-12  # header E stays a global envelope
    assert np.abs(eps).max() <= blob.E, "spatial bound violated"
    assert blob.stats is None or blob.stats.converged, "POCS did not converge"
    d = np.fft.rfftn(eps)
    if blob.pointwise_delta is not None:
        delta = np.frombuffer(blob.pointwise_delta, np.float32)
        delta = delta.reshape(rfft_shape(blob.shape)).astype(np.float64)
    else:
        delta = blob.Delta_scalar
    assert (np.abs(d.real) <= delta).all(), "frequency bound violated (Re)"
    assert (np.abs(d.imag) <= delta).all(), "frequency bound violated (Im)"


class TestWholeFieldConformance:
    @pytest.mark.parametrize("kind", BOUND_KINDS)
    @pytest.mark.parametrize("shape", FIELD_SHAPES, ids=str)
    def test_single_device_round_trip(self, shape, kind):
        x = _field(shape, seed=sum(shape))
        c = FFCz(get_compressor("szlike"), _cfg(kind, x))
        blob = c.compress(x)
        _assert_round_trip_conforms(x, blob, c.decompress(blob))

    @pytest.mark.parametrize("kind", BOUND_KINDS)
    @pytest.mark.parametrize("shape", FIELD_SHAPES, ids=str)
    def test_sharded_round_trip_and_parity_class(self, shape, kind):
        """Sharded compress must conform on EVERY shape — and match the
        single-device blob payload byte for byte exactly when the shape's
        parity class says so."""
        x = _field(shape, seed=sum(shape))
        c = FFCz(get_compressor("szlike"), _cfg(kind, x))
        field = ShardedField.shard(x)
        assert field.parity == classify_parity(x.shape, _N_DEV)
        blob = c.compress(field)
        _assert_round_trip_conforms(x, blob, c.decompress(blob))
        blob_single = c.compress(x)
        if field.parity == "bitwise":
            assert blob.payload_bytes() == blob_single.to_bytes()
        # pad metadata appears exactly when the slab decomposition padded
        assert (blob.pad_meta is not None) == (field.padded_shape != field.shape)

    def test_parity_tri_state_request(self):
        """parity='bitwise' is honored on bitwise-class shapes and is the
        ERROR state on bound-class ones; 'auto' accepts everything."""
        ok = _field((32, 16, 12))
        f = ShardedField.shard(ok, parity="bitwise")
        assert f.parity == "bitwise"
        bad = _field((30, 14, 10))
        with pytest.raises(ValueError, match="power of two"):
            ShardedField.shard(bad, parity="bitwise")
        assert ShardedField.shard(bad).parity == "bound"
        # legacy bool aliases still work
        assert ShardedField.shard(bad, strict_bitwise=False).parity == "bound"
        with pytest.raises(ValueError, match="power of two"):
            ShardedField.shard(bad, strict_bitwise=True)

    @pytest.mark.parametrize("dtype", [np.float32, np.float64, np.float16], ids=str)
    def test_input_dtypes_conform(self, dtype):
        """The codec contract is float32; other input dtypes cast through."""
        x = _field((15, 14, 10), seed=5, dtype=dtype)
        c = FFCz(get_compressor("szlike"), _cfg("Delta_rel", x))
        blob = c.compress(ShardedField.shard(x))
        _assert_round_trip_conforms(x, blob, c.decompress(blob))


class TestPencilBackendConformance:
    # error tensors INSIDE the s-cube (the base-compressor contract POCS
    # starts from), with frequency bounds tight enough to force clipping
    _E = [0.03, 0.02]
    _D = [0.05, 0.03]
    _BLOCK = 128

    def _tensors(self, seed=0):
        # block-aligned sizes: the per-pencil frequency guarantee applies to
        # the internal tiles INCLUDING tail-pad cells (which untiling
        # discards), so only whole tiles can be rechecked from the corrected
        # tensor alone
        rng = np.random.default_rng(seed)
        raw = [
            rng.standard_normal(640).astype(np.float32),
            rng.standard_normal((8, 32)).astype(np.float32),
        ]
        return [t * np.float32(0.9 * e / np.abs(t).max()) for t, e in zip(raw, self._E)]

    def test_backends_bitwise_and_bounded(self):
        """local/batched/sharded pencil corrections are bitwise identical
        (sharded runs whatever mesh this process has — 8-way on the
        multi-device CI leg) and hold both per-pencil bounds."""
        tensors = self._tensors()
        outs, stats = {}, {}
        for backend in ("local", "batched", "sharded"):
            c, s = CorrectionEngine(backend).correct(
                tensors, self._E, self._D, block=self._BLOCK, max_iters=80
            )
            outs[backend] = [np.asarray(t) for t in c]
            stats[backend] = s
        for backend in ("local", "sharded"):
            for a, b in zip(outs["batched"], outs[backend]):
                assert np.array_equal(a, b), backend
        assert np.asarray(stats["batched"].converged).all()
        assert int(np.asarray(stats["batched"].iterations).max()) > 1  # work happened
        for t, e, d in zip(outs["batched"], self._E, self._D):
            assert np.abs(t).max() <= e  # exact: the loop's last op is an s-clip
            flat = t.reshape(-1)
            pad = (-flat.size) % self._BLOCK
            tiles = np.pad(flat, (0, pad)).reshape(-1, self._BLOCK)
            spec = np.fft.rfft(tiles.astype(np.float64), axis=-1)
            # raw float32 device loop (the float64 polish runs at encode):
            # converged means the f-cube check passed at float32 resolution
            tol = d * 2e-4
            assert np.abs(spec.real).max() <= d + tol
            assert np.abs(spec.imag).max() <= d + tol


class TestFftImplConformance:
    """ISSUE 5: the packed / pallas transforms gate on the same matrix."""

    @pytest.mark.parametrize("kind", BOUND_KINDS)
    @pytest.mark.parametrize("impl", FFT_IMPLS)
    @pytest.mark.parametrize("shape", IMPL_SHAPES, ids=str)
    def test_single_device_round_trip(self, shape, impl, kind):
        x = _field(shape, seed=sum(shape))
        c = FFCz(get_compressor("szlike"), _cfg(kind, x, fft_impl=impl))
        blob = c.compress(x)
        _assert_round_trip_conforms(x, blob, c.decompress(blob))

    @pytest.mark.parametrize("kind", ["Delta_rel", "pspec"])
    @pytest.mark.parametrize("shape", IMPL_SHAPES, ids=str)
    def test_sharded_packed_round_trip(self, shape, kind):
        """fft_impl='packed' composes with the distributed local last-axis
        pass; bounds hold on every shape class (no byte-parity claim — the
        packed inverse is 'bound'-parity by construction)."""
        x = _field(shape, seed=sum(shape))
        c = FFCz(get_compressor("szlike"), _cfg(kind, x, fft_impl="packed"))
        blob = c.compress(ShardedField.shard(x))
        _assert_round_trip_conforms(x, blob, c.decompress(blob))

    def test_parity_classification_is_honest(self):
        """Non-'xla' impls are 'bound'-parity whatever the shape class:
        requesting parity='bitwise' with them is the error state, even on a
        shape whose xla classification would be 'bitwise'."""
        x = _field((32, 16, 12))  # all c2c axes pow2: xla would be bitwise
        field = ShardedField.shard(x, parity="bitwise")
        assert field.parity == "bitwise"
        c = FFCz(get_compressor("szlike"), _cfg("Delta_rel", x, fft_impl="packed"))
        with pytest.raises(ValueError, match="bitwise"):
            c.compress(field)
        # auto parity accepts and conforms
        blob = c.compress(ShardedField.shard(x))
        _assert_round_trip_conforms(x, blob, c.decompress(blob))
        # pallas is rejected for sharded whole fields outright
        c2 = FFCz(get_compressor("szlike"), _cfg("Delta_rel", x, fft_impl="pallas"))
        with pytest.raises(ValueError, match="pallas"):
            c2.compress(ShardedField.shard(x))

    @pytest.mark.parametrize("impl", FFT_IMPLS)
    def test_pencil_backends_bitwise_per_impl(self, impl):
        """local/batched/sharded run the identical per-block program for
        every fft_impl, so cross-backend parity stays bitwise."""
        rng = np.random.default_rng(3)
        tensors = [
            rng.standard_normal(640).astype(np.float32) * 0.02,
            rng.standard_normal((8, 32)).astype(np.float32) * 0.02,
        ]
        outs = {}
        for backend in ("local", "batched", "sharded"):
            c, s = CorrectionEngine(backend, fft_impl=impl).correct(
                [t.copy() for t in tensors], 0.03, 0.05, block=128, max_iters=80
            )
            outs[backend] = [np.asarray(t) for t in c]
            assert np.asarray(s.converged).all()
        for backend in ("local", "sharded"):
            for a, b in zip(outs["batched"], outs[backend]):
                assert np.array_equal(a, b), (impl, backend)

    def test_check_every_cadence_conforms(self):
        """check_every > 1 only delays the convergence declaration; the
        round-trip contract is unchanged (extra iterations are safe)."""
        x = _field((30, 14, 10), seed=7)
        c = FFCz(get_compressor("szlike"), _cfg("pspec", x, check_every=4))
        blob = c.compress(x)
        _assert_round_trip_conforms(x, blob, c.decompress(blob))


# ---------------------------------------------------------------------------
# region-aware ROI bounds (ISSUE 9)


def _roi_mask(shape, seed=0):
    """A deterministic box ROI covering roughly the central eighth."""
    mask = np.zeros(shape, dtype=bool)
    sl = tuple(slice(n // 4, max(n // 4 + 1, n // 2)) for n in shape)
    mask[sl] = True
    return mask


class TestRoiConformance:
    """The tentpole claim: a per-point E_n grid rides PLAN -> POCS -> blob,
    and the decoded field satisfies the STORED grid (float64 recheck) AND
    the frequency bound simultaneously, on every backend."""

    @pytest.mark.parametrize("kind", BOUND_KINDS)
    @pytest.mark.parametrize("shape", [(30, 14, 10), (13, 11, 7), (9, 11)], ids=str)
    def test_single_device_round_trip(self, shape, kind):
        x = _field(shape, seed=sum(shape))
        mask = _roi_mask(shape)
        c = FFCz(get_compressor("szlike"), _cfg(kind, x, E_roi=mask, E_roi_scale=0.25))
        blob = c.compress(x)
        assert blob.roi_bound is not None
        _assert_round_trip_conforms(x, blob, c.decompress(blob))
        # the stored grid is exactly the resolved mask values
        grid = np.frombuffer(blob.roi_bound, np.float32).reshape(shape)
        assert set(np.unique(grid)) == {np.float32(blob.E), np.float32(blob.E * 0.25)}

    @pytest.mark.parametrize("kind", ["Delta_rel", "pspec"])
    @pytest.mark.parametrize("shape", [(32, 16, 12), (30, 14, 10), (13, 11, 7)], ids=str)
    def test_sharded_round_trip_and_parity_class(self, shape, kind):
        """The ROI grid enters shard_map as a slab-sharded operand (pad rows
        carry the background bound); blobs keep the parity-class contract."""
        x = _field(shape, seed=sum(shape))
        mask = _roi_mask(shape)
        c = FFCz(get_compressor("szlike"), _cfg(kind, x, E_roi=mask, E_roi_scale=0.25))
        field = ShardedField.shard(x)
        blob = c.compress(field)
        _assert_round_trip_conforms(x, blob, c.decompress(blob))
        blob_single = c.compress(x)
        if field.parity == "bitwise":
            assert blob.payload_bytes() == blob_single.to_bytes()

    @pytest.mark.parametrize("impl", FFT_IMPLS)
    def test_fft_impls_round_trip(self, impl):
        """The kernel epilogues consume the pointwise E grid (packed's fused
        unpack s-clip, pallas' tiled bound) like the f-cube's Delta_k."""
        shape = (30, 14, 10)
        x = _field(shape, seed=3)
        mask = _roi_mask(shape)
        c = FFCz(
            get_compressor("szlike"),
            _cfg("Delta_rel", x, E_roi=mask, E_roi_scale=0.25, fft_impl=impl),
        )
        blob = c.compress(x)
        _assert_round_trip_conforms(x, blob, c.decompress(blob))

    def test_float_grid_roi(self):
        """Float per-point grids: positive entries used (clamped to E),
        non-positive entries mean background."""
        shape = (15, 14, 10)
        x = _field(shape, seed=9)
        g = np.zeros(shape, np.float32)
        g[2:8, 3:9, 1:6] = 1e-4
        c = FFCz(
            get_compressor("szlike"),
            FFCzConfig(E_abs=5e-3, E_rel=None, Delta_rel=1e-3, max_iters=800, E_roi=g),
        )
        blob = c.compress(x)
        _assert_round_trip_conforms(x, blob, c.decompress(blob))
        grid = np.frombuffer(blob.roi_bound, np.float32).reshape(shape)
        assert grid[3, 4, 2] == np.float32(1e-4)
        assert grid[0, 0, 0] == np.float32(5e-3)

    def test_uniform_blob_byte_identical_without_roi(self):
        """The FFCR section is strictly additive: a config without E_roi
        writes bytes identical to a pre-ROI writer (golden-fixture class)."""
        x = _field((15, 14, 10), seed=11)
        c = FFCz(get_compressor("szlike"), _cfg("Delta_rel", x))
        blob = c.compress(x)
        assert blob.roi_bound is None
        raw = blob.to_bytes()
        reparsed = FFCzBlob.from_bytes(raw)
        assert reparsed.roi_bound is None and reparsed.to_bytes() == raw

    def test_trivially_converged_base_still_clipped_to_roi(self):
        """A base error already inside the f-cube (trivial convergence) must
        STILL be projected onto the tighter ROI s-cube — the cold start
        pre-projects pointwise grids (repro.core.pocs)."""
        from repro.core.pocs import alternating_projection

        eps0 = np.zeros((8, 8), np.float32)
        eps0[2, 2] = 0.05  # inside a loose f-cube, outside the tight ROI cell
        E_grid = np.full((8, 8), 0.1, np.float32)
        E_grid[2, 2] = 0.01
        res = alternating_projection(eps0, E_grid, np.float32(1e3), max_iters=50)
        assert bool(res.converged)
        assert (np.abs(np.asarray(res.eps)) <= E_grid).all()

    def test_verify_pspec_shell_recheck(self):
        """Opt-in derived-quantity verify: float64 per-shell power ratios of
        the decoded field stay inside the claimed pspec_rel ribbon on a
        live-shell (white-ish) field, surfaced through FFCzStats."""
        rng = np.random.default_rng(17)
        x = (rng.standard_normal((24, 18)) * 0.5 + 4.0).astype(np.float32)
        cfg = FFCzConfig(
            E_rel=1e-3, Delta_rel=None, pspec_rel=1e-3, max_iters=1500, verify_pspec=True
        )
        blob = FFCz(get_compressor("szlike"), cfg).compress(x)
        assert blob.stats.pspec_shell_err is not None
        assert blob.stats.pspec_shell_err <= 1e-3
        assert blob.stats.pspec_shell_ok is True
        # non-pspec configs never run the recheck
        blob2 = FFCz(get_compressor("szlike"), _cfg("Delta_rel", x)).compress(x)
        assert blob2.stats.pspec_shell_err is None and blob2.stats.pspec_shell_ok is None

    @given(st.data())
    @settings(max_examples=8, deadline=None)
    def test_random_mask_shapes_round_trip(self, data):
        """Hypothesis sweep over mask shapes (odd/prime extents included) and
        scales: the stored-grid contract holds for every draw."""
        shape = _draw_shape(data)
        seed = data.draw(st.integers(0, 2**16))
        scale = data.draw(st.sampled_from([0.1, 0.25, 0.5, 1.0]))
        x = _field(shape, seed=seed)
        rng = np.random.default_rng(seed)
        mask = rng.random(shape) < 0.2
        c = FFCz(
            get_compressor("szlike"),
            _cfg("Delta_rel", x, E_roi=mask, E_roi_scale=scale, max_iters=1500),
        )
        blob = c.compress(x)
        _assert_round_trip_conforms(x, blob, c.decompress(blob))


class TestDegenerateFieldConformance:
    """ISSUE 9 satellite: constant and all-zero fields either round-trip
    cleanly or reject with a structured InfeasibleBound naming the cause —
    never a cryptic downstream failure."""

    @pytest.mark.parametrize("kind", BOUND_KINDS)
    def test_constant_field_e_rel_rejects_structured(self, kind):
        from repro.core.errors import InfeasibleBound

        x = np.full((8, 8), 3.0, np.float32)
        c = FFCz(get_compressor("szlike"), _cfg(kind, x))
        with pytest.raises(InfeasibleBound, match="constant field"):
            c.compress(x)

    @pytest.mark.parametrize("kind", BOUND_KINDS)
    def test_constant_field_e_abs_round_trips(self, kind):
        """With an absolute spatial bound, constant fields are legitimate
        inputs for the Delta kinds; pspec still rejects (no spectrum)."""
        from repro.core.errors import InfeasibleBound

        x = np.full((8, 8), 3.0, np.float32)
        if kind == "Delta_abs":
            cfg = FFCzConfig(E_abs=1e-3, E_rel=None, Delta_rel=None, Delta_abs=1.0)
        elif kind == "Delta_rel":
            cfg = FFCzConfig(E_abs=1e-3, E_rel=None, Delta_rel=1e-3)
        else:
            cfg = FFCzConfig(E_abs=1e-3, E_rel=None, Delta_rel=None, pspec_rel=1e-3)
        c = FFCz(get_compressor("szlike"), cfg)
        if kind == "Delta_rel":
            # Delta_rel on a constant field: max|X| = |DC| > 0, resolvable
            blob = c.compress(x)
            _assert_round_trip_conforms(x, blob, c.decompress(blob))
        elif kind == "Delta_abs":
            blob = c.compress(x)
            _assert_round_trip_conforms(x, blob, c.decompress(blob))
        else:
            # constant field pspec: grid = t|X|/sqrt2 is nonzero only at DC —
            # resolvable in principle; accept either a clean round trip or a
            # structured rejection, never an unstructured crash
            try:
                blob = c.compress(x)
                _assert_round_trip_conforms(x, blob, c.decompress(blob))
            except InfeasibleBound:
                pass

    def test_all_zero_field_pspec_rejects_structured(self):
        from repro.core.errors import InfeasibleBound

        x = np.zeros((8, 8), np.float32)
        c = FFCz(
            get_compressor("szlike"),
            FFCzConfig(E_abs=1e-3, E_rel=None, Delta_rel=None, pspec_rel=1e-3),
        )
        with pytest.raises(InfeasibleBound, match="all-zero"):
            c.compress(x)

    @pytest.mark.parametrize("kind", ["Delta_abs", "Delta_rel"])
    def test_all_zero_field_delta_kinds(self, kind):
        """All-zero fields with absolute E: Delta_abs round-trips exactly;
        Delta_rel resolves Delta = 0 and rejects structurally."""
        from repro.core.errors import InfeasibleBound

        x = np.zeros((8, 8), np.float32)
        if kind == "Delta_abs":
            cfg = FFCzConfig(E_abs=1e-3, E_rel=None, Delta_rel=None, Delta_abs=1.0)
            c = FFCz(get_compressor("szlike"), cfg)
            blob = c.compress(x)
            dec = c.decompress(blob)
            _assert_round_trip_conforms(x, blob, dec)
        else:
            cfg = FFCzConfig(E_abs=1e-3, E_rel=None, Delta_rel=1e-3)
            c = FFCz(get_compressor("szlike"), cfg)
            try:
                blob = c.compress(x)
                _assert_round_trip_conforms(x, blob, c.decompress(blob))
            except InfeasibleBound:
                pass

    def test_sharded_constant_field_rejects_structured(self):
        from repro.core.errors import InfeasibleBound

        x = np.full((16, 8), 2.0, np.float32)
        c = FFCz(get_compressor("szlike"), _cfg("Delta_rel", x))
        with pytest.raises(InfeasibleBound, match="constant field"):
            c.compress(ShardedField.shard(x))


# ---------------------------------------------------------------------------
# randomized property layer (hypothesis; skips without it)


def _draw_shape(data):
    rank = data.draw(st.sampled_from([2, 3]))
    return tuple(data.draw(st.integers(3, 18)) for _ in range(rank))


class TestRandomizedConformance:
    @given(st.data())
    @settings(max_examples=12, deadline=None)
    def test_random_shape_dtype_bound_round_trip(self, data):
        shape = _draw_shape(data)
        kind = data.draw(st.sampled_from(BOUND_KINDS))
        dtype = data.draw(st.sampled_from([np.float32, np.float64]))
        impl = data.draw(st.sampled_from(["xla", "packed", "pallas"]))
        seed = data.draw(st.integers(0, 2**16))
        x = _field(shape, seed=seed, dtype=dtype)
        c = FFCz(get_compressor("szlike"), _cfg(kind, x, fft_impl=impl))
        blob = c.compress(x)
        _assert_round_trip_conforms(x, blob, c.decompress(blob))

    @given(st.data())
    @settings(max_examples=8, deadline=None)
    def test_random_sharded_round_trip_matches_parity_class(self, data):
        shape = _draw_shape(data)
        kind = data.draw(st.sampled_from(["Delta_abs", "Delta_rel"]))
        seed = data.draw(st.integers(0, 2**16))
        x = _field(shape, seed=seed)
        field = ShardedField.shard(x)
        c = FFCz(get_compressor("szlike"), _cfg(kind, x))
        blob = c.compress(field)
        _assert_round_trip_conforms(x, blob, c.decompress(blob))
        if field.parity == "bitwise":
            assert blob.payload_bytes() == c.compress(x).to_bytes()

    @given(st.data())
    @settings(max_examples=10, deadline=None)
    def test_classify_parity_is_total_on_supported_ranks(self, data):
        """Classification never errors on any positive 2-D/3-D extent and
        matches the power-of-two rule (divisibility plays no role)."""
        shape = _draw_shape(data)
        n_dev = data.draw(st.sampled_from([1, 2, 3, 5, 8]))
        parity = classify_parity(shape, n_dev)
        pow2 = all(n & (n - 1) == 0 for n in shape[:-1])
        assert parity == ("bitwise" if pow2 else "bound")


# ---------------------------------------------------------------------------
# temporal stream conformance (ISSUE 8)


class TestStreamConformance:
    """The stream-level dual-bound claim: EVERY frame of an FFCS round trip
    — keyframe and residual alike, warm-started or not — holds the spatial
    and spectral bounds the container header claims, rechecked in float64."""

    def _frames(self, n, shape, seed=0):
        rng = np.random.default_rng(seed)
        base = _field(shape, seed=seed)
        mode = np.cos(np.linspace(0, 2 * np.pi, base.size)).reshape(shape)
        return [
            np.ascontiguousarray(
                base + 0.1 * t * mode + 0.01 * rng.standard_normal(shape), np.float32
            )
            for t in range(n)
        ]

    @pytest.mark.parametrize("warm", [False, True], ids=["cold", "warm"])
    @pytest.mark.parametrize("shape", [(15, 14, 10), (9, 11)], ids=str)
    def test_field_stream_every_frame_conforms(self, shape, warm):
        from repro.core.temporal import TemporalCodec, TemporalConfig, TemporalStream

        frames = self._frames(7, shape, seed=sum(shape))
        codec = TemporalCodec(
            get_compressor("szlike"),
            FFCzConfig(E_rel=1e-3, Delta_rel=1e-3, max_iters=600, warm_start=warm),
            TemporalConfig(mode="field", keyframe_interval=3),
        )
        data = codec.compress_stream(frames)
        s = TemporalStream.from_bytes(data)
        for t, (x, d) in enumerate(zip(frames, codec.decompress_stream(data))):
            eps = d.astype(np.float64) - x.astype(np.float64)
            assert np.abs(eps).max() <= s.E, (t, s.is_keyframe(t))
            spec = np.fft.rfftn(eps)
            assert np.abs(spec.real).max() <= s.Delta, (t, s.is_keyframe(t))
            assert np.abs(spec.imag).max() <= s.Delta, (t, s.is_keyframe(t))

    @pytest.mark.parametrize("predictor", ["identity", "linear"])
    def test_pencil_stream_every_frame_conforms(self, predictor):
        """EEG-style channels x time routing: block=0 makes one pencil per
        channel row, so the per-tile spectral recheck needs no tail pad."""
        from repro.core.temporal import TemporalCodec, TemporalConfig, TemporalStream

        shape = (12, 64)
        frames = self._frames(7, shape, seed=21)
        codec = TemporalCodec(
            get_compressor("szlike"),
            FFCzConfig(E_rel=1e-3, Delta_rel=1e-3, max_iters=600, warm_start=True),
            TemporalConfig(mode="pencils", predictor=predictor, keyframe_interval=3),
        )
        data = codec.compress_stream(frames)
        s = TemporalStream.from_bytes(data)
        assert s.block == shape[-1]
        for t, (x, d) in enumerate(zip(frames, codec.decompress_stream(data))):
            eps = d.astype(np.float64) - x.astype(np.float64)
            assert np.abs(eps).max() <= s.E, (t, s.is_keyframe(t))
            tiles = eps.reshape(-1, s.block)
            spec = np.fft.rfft(tiles, axis=-1)
            assert np.abs(spec.real).max() <= s.Delta, (t, s.is_keyframe(t))
            assert np.abs(spec.imag).max() <= s.Delta, (t, s.is_keyframe(t))
