"""Property-based bound-conformance suite (ISSUE 4).

FFCz's value claim is that the spatial and spectral error bounds hold jointly
for ANY regular-grid field — unconditionally on shape (survey literature:
Di et al. 2023; Cappello et al. 2019).  This suite verifies that claim on
randomized shapes (odd, prime, and mesh-non-divisible axes), input dtypes,
and bound kinds (``Delta_abs`` / ``Delta_rel`` / ``pspec``), across the
``local``/``batched``/``sharded`` execution paths:

* whole-field compress -> decompress round trips must hold the spatial bound
  unconditionally and the frequency bound whenever the loop converged (the
  paper contract), verified independently in float64 against the bounds the
  blob STORES — not the ones the test requested;
* the parity tri-state of :func:`repro.sharding.dist_fft.classify_parity`
  must be honored per shape: ``"bitwise"``-class shapes reproduce the
  single-device blob payload byte for byte from a sharded field,
  ``"bound"``-class shapes hold the bounds without byte parity, and
  requesting ``parity="bitwise"`` on a ``"bound"`` shape is the error state;
* pencil-batch corrections are bitwise identical across engine backends;
* the ``fft_impl`` dimension (ISSUE 5): the packed / pallas-interpret loop
  transforms must conform on the same randomized odd/prime/dtype/bound
  matrix — including the float64 recheck against STORED bounds — and their
  parity classification is honest: non-``"xla"`` impls are ``"bound"``-class
  (requesting ``parity="bitwise"`` with them is the error state), while
  pencil corrections remain bitwise identical ACROSS backends for every
  impl (the three backends run the same per-block program).

Sharded cases run in-process and are exercised by the multi-device CI leg
(``XLA_FLAGS=--xla_force_host_platform_device_count=8`` is set for the whole
pytest process there); on a 1-device process they degenerate to a 1-slab
mesh, which still runs the padded-decomposition code path.

Property tests draw through the ``tests/_hyp`` shim: with hypothesis
installed they randomize under the deterministic CI profile registered in
``conftest.py`` (fixed seed via ``derandomize``, CI-scoped example budget);
without it they skip and the deterministic conformance cases below still
gate every shape class.
"""

import jax
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis or skip-stubs (requirements-dev.txt)

from repro.compressors import get_compressor
from repro.core.cubes import rfft_shape
from repro.core.engine import CorrectionEngine
from repro.core.ffcz import FFCz, FFCzConfig, ShardedField
from repro.sharding.dist_fft import classify_parity

_N_DEV = len(jax.devices())

# deterministic shape corpus: evenly divisible control, uneven power-of-two,
# odd, prime, and mesh-non-divisible axes, 2-D and 3-D
FIELD_SHAPES = [
    (32, 16, 12),  # divisible + pow2: the PR 3 bitwise contract
    (4, 16, 12),  # axis 0 smaller than an 8-way mesh (uneven pow2 slabs)
    (30, 14, 10),  # even but non-pow2, non-divisible by 8
    (15, 14, 10),  # odd axis 0: non-divisible by every mesh size
    (13, 11, 7),  # all axes prime
    (9, 11),  # 2-D odd/prime
    (32, 48),  # 2-D pow2 axis 0, uneven half axis (H=25)
]
BOUND_KINDS = ["Delta_abs", "Delta_rel", "pspec"]
FFT_IMPLS = ["packed", "pallas"]
# even-last-axis (pack-trick) + odd-last-axis (static fallback) + 2-D
IMPL_SHAPES = [(30, 14, 10), (13, 11, 7), (32, 48)]


def _field(shape, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    f = (rng.standard_normal(shape) * 0.5 + 4.0).cumsum(axis=0)
    return np.ascontiguousarray(f, dtype=dtype)


def _cfg(kind, x, **kw) -> FFCzConfig:
    if kind == "Delta_abs":
        d = float(np.abs(np.fft.rfftn(np.asarray(x, np.float32))).max() * 1e-3)
        return FFCzConfig(E_rel=1e-3, Delta_rel=None, Delta_abs=d, **kw)
    if kind == "Delta_rel":
        return FFCzConfig(E_rel=1e-3, Delta_rel=1e-3, **kw)
    return FFCzConfig(E_rel=1e-3, Delta_rel=None, pspec_rel=1e-3, max_iters=1500, **kw)


def _assert_round_trip_conforms(x, blob, dec):
    """The paper contract, checked in float64 against the STORED bounds:
    spatial bound unconditional; frequency bound whenever converged."""
    x32 = np.asarray(x, np.float32)
    assert dec.shape == x32.shape and dec.dtype == np.float32
    eps = dec.astype(np.float64) - x32.astype(np.float64)
    assert np.abs(eps).max() <= blob.E, "spatial bound violated"
    assert blob.stats is None or blob.stats.converged, "POCS did not converge"
    d = np.fft.rfftn(eps)
    if blob.pointwise_delta is not None:
        delta = np.frombuffer(blob.pointwise_delta, np.float32)
        delta = delta.reshape(rfft_shape(blob.shape)).astype(np.float64)
    else:
        delta = blob.Delta_scalar
    assert (np.abs(d.real) <= delta).all(), "frequency bound violated (Re)"
    assert (np.abs(d.imag) <= delta).all(), "frequency bound violated (Im)"


class TestWholeFieldConformance:
    @pytest.mark.parametrize("kind", BOUND_KINDS)
    @pytest.mark.parametrize("shape", FIELD_SHAPES, ids=str)
    def test_single_device_round_trip(self, shape, kind):
        x = _field(shape, seed=sum(shape))
        c = FFCz(get_compressor("szlike"), _cfg(kind, x))
        blob = c.compress(x)
        _assert_round_trip_conforms(x, blob, c.decompress(blob))

    @pytest.mark.parametrize("kind", BOUND_KINDS)
    @pytest.mark.parametrize("shape", FIELD_SHAPES, ids=str)
    def test_sharded_round_trip_and_parity_class(self, shape, kind):
        """Sharded compress must conform on EVERY shape — and match the
        single-device blob payload byte for byte exactly when the shape's
        parity class says so."""
        x = _field(shape, seed=sum(shape))
        c = FFCz(get_compressor("szlike"), _cfg(kind, x))
        field = ShardedField.shard(x)
        assert field.parity == classify_parity(x.shape, _N_DEV)
        blob = c.compress(field)
        _assert_round_trip_conforms(x, blob, c.decompress(blob))
        blob_single = c.compress(x)
        if field.parity == "bitwise":
            assert blob.payload_bytes() == blob_single.to_bytes()
        # pad metadata appears exactly when the slab decomposition padded
        assert (blob.pad_meta is not None) == (field.padded_shape != field.shape)

    def test_parity_tri_state_request(self):
        """parity='bitwise' is honored on bitwise-class shapes and is the
        ERROR state on bound-class ones; 'auto' accepts everything."""
        ok = _field((32, 16, 12))
        f = ShardedField.shard(ok, parity="bitwise")
        assert f.parity == "bitwise"
        bad = _field((30, 14, 10))
        with pytest.raises(ValueError, match="power of two"):
            ShardedField.shard(bad, parity="bitwise")
        assert ShardedField.shard(bad).parity == "bound"
        # legacy bool aliases still work
        assert ShardedField.shard(bad, strict_bitwise=False).parity == "bound"
        with pytest.raises(ValueError, match="power of two"):
            ShardedField.shard(bad, strict_bitwise=True)

    @pytest.mark.parametrize("dtype", [np.float32, np.float64, np.float16], ids=str)
    def test_input_dtypes_conform(self, dtype):
        """The codec contract is float32; other input dtypes cast through."""
        x = _field((15, 14, 10), seed=5, dtype=dtype)
        c = FFCz(get_compressor("szlike"), _cfg("Delta_rel", x))
        blob = c.compress(ShardedField.shard(x))
        _assert_round_trip_conforms(x, blob, c.decompress(blob))


class TestPencilBackendConformance:
    # error tensors INSIDE the s-cube (the base-compressor contract POCS
    # starts from), with frequency bounds tight enough to force clipping
    _E = [0.03, 0.02]
    _D = [0.05, 0.03]
    _BLOCK = 128

    def _tensors(self, seed=0):
        # block-aligned sizes: the per-pencil frequency guarantee applies to
        # the internal tiles INCLUDING tail-pad cells (which untiling
        # discards), so only whole tiles can be rechecked from the corrected
        # tensor alone
        rng = np.random.default_rng(seed)
        raw = [
            rng.standard_normal(640).astype(np.float32),
            rng.standard_normal((8, 32)).astype(np.float32),
        ]
        return [t * np.float32(0.9 * e / np.abs(t).max()) for t, e in zip(raw, self._E)]

    def test_backends_bitwise_and_bounded(self):
        """local/batched/sharded pencil corrections are bitwise identical
        (sharded runs whatever mesh this process has — 8-way on the
        multi-device CI leg) and hold both per-pencil bounds."""
        tensors = self._tensors()
        outs, stats = {}, {}
        for backend in ("local", "batched", "sharded"):
            c, s = CorrectionEngine(backend).correct(
                tensors, self._E, self._D, block=self._BLOCK, max_iters=80
            )
            outs[backend] = [np.asarray(t) for t in c]
            stats[backend] = s
        for backend in ("local", "sharded"):
            for a, b in zip(outs["batched"], outs[backend]):
                assert np.array_equal(a, b), backend
        assert np.asarray(stats["batched"].converged).all()
        assert int(np.asarray(stats["batched"].iterations).max()) > 1  # work happened
        for t, e, d in zip(outs["batched"], self._E, self._D):
            assert np.abs(t).max() <= e  # exact: the loop's last op is an s-clip
            flat = t.reshape(-1)
            pad = (-flat.size) % self._BLOCK
            tiles = np.pad(flat, (0, pad)).reshape(-1, self._BLOCK)
            spec = np.fft.rfft(tiles.astype(np.float64), axis=-1)
            # raw float32 device loop (the float64 polish runs at encode):
            # converged means the f-cube check passed at float32 resolution
            tol = d * 2e-4
            assert np.abs(spec.real).max() <= d + tol
            assert np.abs(spec.imag).max() <= d + tol


class TestFftImplConformance:
    """ISSUE 5: the packed / pallas transforms gate on the same matrix."""

    @pytest.mark.parametrize("kind", BOUND_KINDS)
    @pytest.mark.parametrize("impl", FFT_IMPLS)
    @pytest.mark.parametrize("shape", IMPL_SHAPES, ids=str)
    def test_single_device_round_trip(self, shape, impl, kind):
        x = _field(shape, seed=sum(shape))
        c = FFCz(get_compressor("szlike"), _cfg(kind, x, fft_impl=impl))
        blob = c.compress(x)
        _assert_round_trip_conforms(x, blob, c.decompress(blob))

    @pytest.mark.parametrize("kind", ["Delta_rel", "pspec"])
    @pytest.mark.parametrize("shape", IMPL_SHAPES, ids=str)
    def test_sharded_packed_round_trip(self, shape, kind):
        """fft_impl='packed' composes with the distributed local last-axis
        pass; bounds hold on every shape class (no byte-parity claim — the
        packed inverse is 'bound'-parity by construction)."""
        x = _field(shape, seed=sum(shape))
        c = FFCz(get_compressor("szlike"), _cfg(kind, x, fft_impl="packed"))
        blob = c.compress(ShardedField.shard(x))
        _assert_round_trip_conforms(x, blob, c.decompress(blob))

    def test_parity_classification_is_honest(self):
        """Non-'xla' impls are 'bound'-parity whatever the shape class:
        requesting parity='bitwise' with them is the error state, even on a
        shape whose xla classification would be 'bitwise'."""
        x = _field((32, 16, 12))  # all c2c axes pow2: xla would be bitwise
        field = ShardedField.shard(x, parity="bitwise")
        assert field.parity == "bitwise"
        c = FFCz(get_compressor("szlike"), _cfg("Delta_rel", x, fft_impl="packed"))
        with pytest.raises(ValueError, match="bitwise"):
            c.compress(field)
        # auto parity accepts and conforms
        blob = c.compress(ShardedField.shard(x))
        _assert_round_trip_conforms(x, blob, c.decompress(blob))
        # pallas is rejected for sharded whole fields outright
        c2 = FFCz(get_compressor("szlike"), _cfg("Delta_rel", x, fft_impl="pallas"))
        with pytest.raises(ValueError, match="pallas"):
            c2.compress(ShardedField.shard(x))

    @pytest.mark.parametrize("impl", FFT_IMPLS)
    def test_pencil_backends_bitwise_per_impl(self, impl):
        """local/batched/sharded run the identical per-block program for
        every fft_impl, so cross-backend parity stays bitwise."""
        rng = np.random.default_rng(3)
        tensors = [
            rng.standard_normal(640).astype(np.float32) * 0.02,
            rng.standard_normal((8, 32)).astype(np.float32) * 0.02,
        ]
        outs = {}
        for backend in ("local", "batched", "sharded"):
            c, s = CorrectionEngine(backend, fft_impl=impl).correct(
                [t.copy() for t in tensors], 0.03, 0.05, block=128, max_iters=80
            )
            outs[backend] = [np.asarray(t) for t in c]
            assert np.asarray(s.converged).all()
        for backend in ("local", "sharded"):
            for a, b in zip(outs["batched"], outs[backend]):
                assert np.array_equal(a, b), (impl, backend)

    def test_check_every_cadence_conforms(self):
        """check_every > 1 only delays the convergence declaration; the
        round-trip contract is unchanged (extra iterations are safe)."""
        x = _field((30, 14, 10), seed=7)
        c = FFCz(get_compressor("szlike"), _cfg("pspec", x, check_every=4))
        blob = c.compress(x)
        _assert_round_trip_conforms(x, blob, c.decompress(blob))


# ---------------------------------------------------------------------------
# randomized property layer (hypothesis; skips without it)


def _draw_shape(data):
    rank = data.draw(st.sampled_from([2, 3]))
    return tuple(data.draw(st.integers(3, 18)) for _ in range(rank))


class TestRandomizedConformance:
    @given(st.data())
    @settings(max_examples=12, deadline=None)
    def test_random_shape_dtype_bound_round_trip(self, data):
        shape = _draw_shape(data)
        kind = data.draw(st.sampled_from(BOUND_KINDS))
        dtype = data.draw(st.sampled_from([np.float32, np.float64]))
        impl = data.draw(st.sampled_from(["xla", "packed", "pallas"]))
        seed = data.draw(st.integers(0, 2**16))
        x = _field(shape, seed=seed, dtype=dtype)
        c = FFCz(get_compressor("szlike"), _cfg(kind, x, fft_impl=impl))
        blob = c.compress(x)
        _assert_round_trip_conforms(x, blob, c.decompress(blob))

    @given(st.data())
    @settings(max_examples=8, deadline=None)
    def test_random_sharded_round_trip_matches_parity_class(self, data):
        shape = _draw_shape(data)
        kind = data.draw(st.sampled_from(["Delta_abs", "Delta_rel"]))
        seed = data.draw(st.integers(0, 2**16))
        x = _field(shape, seed=seed)
        field = ShardedField.shard(x)
        c = FFCz(get_compressor("szlike"), _cfg(kind, x))
        blob = c.compress(field)
        _assert_round_trip_conforms(x, blob, c.decompress(blob))
        if field.parity == "bitwise":
            assert blob.payload_bytes() == c.compress(x).to_bytes()

    @given(st.data())
    @settings(max_examples=10, deadline=None)
    def test_classify_parity_is_total_on_supported_ranks(self, data):
        """Classification never errors on any positive 2-D/3-D extent and
        matches the power-of-two rule (divisibility plays no role)."""
        shape = _draw_shape(data)
        n_dev = data.draw(st.sampled_from([1, 2, 3, 5, 8]))
        parity = classify_parity(shape, n_dev)
        pow2 = all(n & (n - 1) == 0 for n in shape[:-1])
        assert parity == ("bitwise" if pow2 else "bound")


# ---------------------------------------------------------------------------
# temporal stream conformance (ISSUE 8)


class TestStreamConformance:
    """The stream-level dual-bound claim: EVERY frame of an FFCS round trip
    — keyframe and residual alike, warm-started or not — holds the spatial
    and spectral bounds the container header claims, rechecked in float64."""

    def _frames(self, n, shape, seed=0):
        rng = np.random.default_rng(seed)
        base = _field(shape, seed=seed)
        mode = np.cos(np.linspace(0, 2 * np.pi, base.size)).reshape(shape)
        return [
            np.ascontiguousarray(
                base + 0.1 * t * mode + 0.01 * rng.standard_normal(shape), np.float32
            )
            for t in range(n)
        ]

    @pytest.mark.parametrize("warm", [False, True], ids=["cold", "warm"])
    @pytest.mark.parametrize("shape", [(15, 14, 10), (9, 11)], ids=str)
    def test_field_stream_every_frame_conforms(self, shape, warm):
        from repro.core.temporal import TemporalCodec, TemporalConfig, TemporalStream

        frames = self._frames(7, shape, seed=sum(shape))
        codec = TemporalCodec(
            get_compressor("szlike"),
            FFCzConfig(E_rel=1e-3, Delta_rel=1e-3, max_iters=600, warm_start=warm),
            TemporalConfig(mode="field", keyframe_interval=3),
        )
        data = codec.compress_stream(frames)
        s = TemporalStream.from_bytes(data)
        for t, (x, d) in enumerate(zip(frames, codec.decompress_stream(data))):
            eps = d.astype(np.float64) - x.astype(np.float64)
            assert np.abs(eps).max() <= s.E, (t, s.is_keyframe(t))
            spec = np.fft.rfftn(eps)
            assert np.abs(spec.real).max() <= s.Delta, (t, s.is_keyframe(t))
            assert np.abs(spec.imag).max() <= s.Delta, (t, s.is_keyframe(t))

    @pytest.mark.parametrize("predictor", ["identity", "linear"])
    def test_pencil_stream_every_frame_conforms(self, predictor):
        """EEG-style channels x time routing: block=0 makes one pencil per
        channel row, so the per-tile spectral recheck needs no tail pad."""
        from repro.core.temporal import TemporalCodec, TemporalConfig, TemporalStream

        shape = (12, 64)
        frames = self._frames(7, shape, seed=21)
        codec = TemporalCodec(
            get_compressor("szlike"),
            FFCzConfig(E_rel=1e-3, Delta_rel=1e-3, max_iters=600, warm_start=True),
            TemporalConfig(mode="pencils", predictor=predictor, keyframe_interval=3),
        )
        data = codec.compress_stream(frames)
        s = TemporalStream.from_bytes(data)
        assert s.block == shape[-1]
        for t, (x, d) in enumerate(zip(frames, codec.decompress_stream(data))):
            eps = d.astype(np.float64) - x.astype(np.float64)
            assert np.abs(eps).max() <= s.E, (t, s.is_keyframe(t))
            tiles = eps.reshape(-1, s.block)
            spec = np.fft.rfft(tiles, axis=-1)
            assert np.abs(spec.real).max() <= s.Delta, (t, s.is_keyframe(t))
            assert np.abs(spec.imag).max() <= s.Delta, (t, s.is_keyframe(t))
