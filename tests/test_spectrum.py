"""Spectrum metrics: Parseval, SSNR/PSNR, power-spectrum identities."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis or skip-stubs (requirements-dev.txt)

from repro.core.bounds import power_spectrum_delta, resolve_bounds, resolve_roi_bound_grid
from repro.core.errors import InfeasibleBound
from repro.core.spectrum import (
    bitrate,
    power_spectrum,
    power_spectrum_relative_error,
    psnr,
    relative_frequency_error,
    shell_ratio_error,
    ssnr,
    ssnr_spatial,
)


class TestPowerSpectrum:
    def test_pure_tone_peak(self):
        """A single harmonic must put (almost) all power in its shell."""
        n = 64
        t = np.arange(n)
        x = 1.0 + 0.5 * np.cos(2 * np.pi * 8 * t / n)
        k, p = power_spectrum(jnp.asarray(x))
        p = np.asarray(p)
        assert int(np.argmax(p[1:])) + 1 == 8

    def test_parseval_motivation(self, rng):
        """MSE is FFT-invariant (why the paper uses SSNR, not freq-PSNR)."""
        x = rng.standard_normal(256)
        y = x + rng.standard_normal(256) * 0.01
        mse_s = np.mean((x - y) ** 2)
        mse_f = np.mean(np.abs(np.fft.fft(x) - np.fft.fft(y)) ** 2) / 256
        np.testing.assert_allclose(mse_s, mse_f, rtol=1e-6)

    def test_relative_error_zero_for_identical(self, rng):
        x = rng.standard_normal((16, 16, 16)).astype(np.float32)
        _, rel = power_spectrum_relative_error(x, x)
        assert np.abs(rel).max() == 0


class TestMetrics:
    def test_ssnr_infinite_for_exact(self, rng):
        x = jnp.asarray(rng.standard_normal(128), dtype=jnp.float32)
        assert float(ssnr_spatial(x, x)) > 100

    def test_ssnr_monotone_in_noise(self, rng):
        x = rng.standard_normal(512).astype(np.float32)
        noisy = lambda s: jnp.asarray(x + rng.standard_normal(512).astype(np.float32) * s)
        assert float(ssnr_spatial(noisy(1e-3), jnp.asarray(x))) > float(
            ssnr_spatial(noisy(1e-1), jnp.asarray(x))
        )

    def test_psnr_known_value(self):
        x = np.zeros(100, np.float32)
        x[0] = 1.0  # range 1
        y = x + 0.01
        val = float(psnr(jnp.asarray(y), jnp.asarray(x)))
        np.testing.assert_allclose(val, 40.0, atol=0.1)

    def test_rfe_normalization(self, rng):
        X = jnp.asarray(rng.standard_normal(64) + 1j * rng.standard_normal(64))
        rfe = relative_frequency_error(X, X * 0 + X)  # zero error
        assert np.abs(np.asarray(rfe)).max() == 0

    def test_bitrate(self):
        assert bitrate(100, 100) == 8.0

    def test_psnr_constant_field_finite(self):
        """Regression (ISSUE 9): constant reference => range 0 used to make
        log10 return -inf/NaN; the clamp degrades to a finite value."""
        x = np.full((8, 8), 3.0, np.float32)
        exact = float(psnr(jnp.asarray(x), jnp.asarray(x)))
        noisy = float(psnr(jnp.asarray(x + 0.1), jnp.asarray(x)))
        assert np.isfinite(exact) and np.isfinite(noisy)
        assert noisy < exact  # still ordered: noise must not raise the metric

    def test_rfe_zero_field_finite(self):
        """Regression (ISSUE 9): all-zero reference spectrum divided by
        max|X| == 0; the clamp yields zeros for exact reconstruction and
        finite values otherwise."""
        Z = jnp.zeros((5, 5), dtype=jnp.complex64)
        assert np.abs(np.asarray(relative_frequency_error(Z, Z))).max() == 0
        off = np.asarray(relative_frequency_error(Z + (0.5 + 0j), Z))
        assert np.all(np.isfinite(off))


class TestShellRatioError:
    def test_identity_is_zero(self, rng):
        x = rng.standard_normal((12, 10)).astype(np.float32) + 4.0
        assert shell_ratio_error(x, x) == 0.0

    def test_detects_scaled_spectrum(self, rng):
        """Scaling the fluctuations by (1 + a) scales every shell's power by
        (1 + a)^2, so the max ratio error must be ~(1+a)^2 - 1."""
        x = rng.standard_normal((16, 16)) + 10.0
        a = 0.01
        x_hat = x.mean() + (x - x.mean()) * (1.0 + a)
        err = shell_ratio_error(x_hat, x)
        np.testing.assert_allclose(err, (1 + a) ** 2 - 1, rtol=1e-6)

    def test_all_zero_fields(self):
        assert shell_ratio_error(np.zeros((6, 6)), np.zeros((6, 6))) == 0.0


class TestBounds:
    def test_resolve_relative(self, rng):
        x = jnp.asarray(rng.standard_normal((8, 8)), dtype=jnp.float32)
        b = resolve_bounds(x, E_rel=0.01, Delta_rel=0.1)
        rng_x = float(jnp.max(x) - jnp.min(x))
        np.testing.assert_allclose(float(b.E), 0.01 * rng_x, rtol=1e-6)

    def test_resolve_validates(self, rng):
        x = jnp.zeros((4,))
        with pytest.raises(ValueError):
            resolve_bounds(x, E_abs=1.0, E_rel=1.0, Delta_rel=0.1)

    def test_resolve_constant_field_e_rel_raises(self):
        """Regression (ISSUE 9): E_rel on a constant field used to resolve
        E = 0 and fail much later with a cryptic representability error."""
        x = jnp.full((6, 6), 2.5)
        with pytest.raises(InfeasibleBound, match="constant field"):
            resolve_bounds(x, E_rel=1e-3, Delta_rel=1e-3)
        # E_abs on the same field stays fine
        b = resolve_bounds(x, E_abs=1e-3, Delta_abs=1.0)
        assert float(b.E) == 1e-3

    @given(st.floats(1e-4, 0.5))
    @settings(max_examples=30, deadline=None)
    def test_pspec_delta_guarantee(self, rel):
        """The derivation in power_spectrum_delta: |delta| <= t|X| ensures
        relative power error <= rel, exactly (worst case check)."""
        t = np.sqrt(1.0 + rel) - 1.0
        X = 1.0 + 0j
        worst_hi = abs(X + t * X) ** 2  # (1+t)^2
        worst_lo = abs(X - t * X) ** 2  # (1-t)^2
        assert worst_hi <= (1 + rel) * (1 + 1e-12)
        assert worst_lo >= (1 - rel) * (1 - 1e-12)


class TestRoiBoundGrid:
    def test_boolean_mask(self):
        mask = np.zeros((4, 6), dtype=bool)
        mask[1:3, 2:5] = True
        grid = resolve_roi_bound_grid(mask, 0.8, (4, 6), scale=0.25)
        assert grid.dtype == np.float32
        np.testing.assert_allclose(grid[mask], np.float32(0.8 * 0.25))
        np.testing.assert_allclose(grid[~mask], np.float32(0.8))

    def test_float_grid_clamps_to_global(self):
        g = np.zeros((3, 3), np.float32)
        g[0, 0] = 0.01  # used directly
        g[1, 1] = 5.0  # clamped: ROI bounds only tighten
        grid = resolve_roi_bound_grid(g, 0.5, (3, 3))
        assert grid[0, 0] == np.float32(0.01)
        assert grid[1, 1] == np.float32(0.5)
        assert grid[2, 2] == np.float32(0.5)  # <= 0 means background

    def test_shape_mismatch_rejected(self):
        with pytest.raises(ValueError, match="must match the field shape"):
            resolve_roi_bound_grid(np.zeros((2, 2), dtype=bool), 1.0, (4, 4))

    def test_bad_scale_rejected(self):
        m = np.zeros((2, 2), dtype=bool)
        for s in (0.0, -0.5, 1.5):
            with pytest.raises(ValueError, match="E_roi_scale"):
                resolve_roi_bound_grid(m, 1.0, (2, 2), scale=s)
