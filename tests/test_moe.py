"""MoE dispatch: capacity buffer vs dense oracle, drops, shared expert."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import capacity_of, moe_apply, moe_init, moe_ref


@pytest.fixture(scope="module")
def setup():
    p = moe_init(jax.random.PRNGKey(0), 32, 64, 8, False, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    return p, x


class TestMoE:
    @pytest.mark.parametrize("top_k", [1, 2, 4])
    def test_matches_dense_oracle_no_drops(self, top_k, setup):
        p, x = setup
        out = moe_apply(p, x, top_k=top_k, capacity_factor=8.0)  # capacity >> load
        ref = moe_ref(p, x, top_k=top_k)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)

    def test_tight_capacity_finite_and_bounded(self, setup):
        p, x = setup
        out = moe_apply(p, x, top_k=2, capacity_factor=0.5)
        assert np.isfinite(np.asarray(out)).all()
        # dropped tokens shrink output toward zero, never blow up
        ref = moe_ref(p, x, top_k=2)
        assert np.abs(np.asarray(out)).max() <= np.abs(np.asarray(ref)).max() * 3

    def test_shared_expert(self):
        p = moe_init(jax.random.PRNGKey(0), 32, 64, 8, True, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32))
        out = moe_apply(p, x, top_k=1, capacity_factor=8.0)
        ref = moe_ref(p, x, top_k=1)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)

    def test_capacity_alignment(self):
        assert capacity_of(1000, 2, 8, 1.25) % 8 == 0
        assert capacity_of(1, 1, 64, 1.0) >= 8

    def test_grad_flows(self, setup):
        p, x = setup

        def f(pp):
            return jnp.sum(moe_apply(pp, x, top_k=2, capacity_factor=4.0) ** 2)

        g = jax.grad(f)(p)
        # router must receive gradient (it is the load-balancing control)
        assert np.abs(np.asarray(g["router"])).max() > 0
        assert all(np.isfinite(np.asarray(l)).all() for l in jax.tree.leaves(g))
