"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs (assignment requirement), plus decode paths."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config, get_smoke_config
from repro.models.model import build_model
from repro.optim.adamw import AdamW


def _batch_for(cfg, b=2, s=32, seed=1):
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(seed), (b, s), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            jax.random.PRNGKey(seed + 1), (b, cfg.vision_tokens, cfg.vision_dim)
        )
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(seed + 2), (b, cfg.encoder_seq, cfg.d_model)
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_and_train_step(self, arch):
        cfg = get_smoke_config(arch)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        batch = _batch_for(cfg)
        opt = AdamW(warmup_steps=2)
        state = opt.init(params)

        @jax.jit
        def step(p, s, b):
            loss, grads = jax.value_and_grad(m.loss)(p, b)
            p, s = opt.update(grads, s, p)
            return p, s, loss

        params, state, loss = step(params, state, batch)
        assert np.isfinite(float(loss)), arch
        for leaf in jax.tree.leaves(params):
            assert np.isfinite(np.asarray(leaf, dtype=np.float32)).all(), arch

    def test_prefill_decode_shapes(self, arch):
        cfg = get_smoke_config(arch)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        b, s = 2, 16
        batch = _batch_for(cfg, b=b, s=s)
        cache = m.init_cache(b, 48)
        logits, cache = m.prefill(params, batch, cache)
        assert logits.shape == (b, 1, cfg.vocab_padded), arch
        assert np.isfinite(np.asarray(logits, dtype=np.float32)).all(), arch
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        assert int(tok.max()) < cfg.vocab, "padded vocab ids must be masked"
        logits2, cache = m.decode(params, tok, cache)
        assert logits2.shape == (b, 1, cfg.vocab_padded), arch
        assert np.isfinite(np.asarray(logits2, dtype=np.float32)).all(), arch


class TestDecodeMatchesTeacherForcing:
    @pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-2.7b", "zamba2-7b"])
    def test_incremental_equals_full(self, arch):
        """Prefill+decode logits must match full-sequence forward logits."""
        cfg = dataclasses.replace(get_smoke_config(arch), attention_impl="naive")
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        b, s = 1, 12
        toks = jax.random.randint(jax.random.PRNGKey(5), (b, s), 0, cfg.vocab)

        # full forward via prefill over the whole sequence (cache len == s)
        cache = m.init_cache(b, s)
        full_logits, _ = m.prefill(params, {"tokens": toks}, cache)

        # chunked: prefill s-1 then decode the last token
        cache2 = m.init_cache(b, s)
        _, cache2 = m.prefill(params, {"tokens": toks[:, : s - 1]}, cache2)
        step_logits, _ = m.decode(params, toks[:, s - 1 :], cache2)
        np.testing.assert_allclose(
            np.asarray(full_logits[:, -1], dtype=np.float32),
            np.asarray(step_logits[:, -1], dtype=np.float32),
            atol=2e-2, rtol=1e-2,
        )


class TestFullConfigsInstantiable:
    """FULL configs are exercised via the dry-run (abstract only) — here we
    just check config invariants hold for every published entry."""

    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_config_sanity(self, arch):
        cfg = get_config(arch)
        assert cfg.name == arch
        assert cfg.vocab_padded % 128 == 0 and cfg.vocab_padded >= cfg.vocab
        if cfg.family in ("dense", "moe", "vlm", "audio", "hybrid"):
            assert cfg.n_heads % cfg.n_kv_heads == 0
        if cfg.family == "moe":
            assert 0 < cfg.top_k <= cfg.n_experts
            assert cfg.n_layers % cfg.moe_every == 0
        if cfg.family in ("ssm", "hybrid"):
            assert cfg.d_inner % cfg.ssm_headdim == 0
        cells = cfg.cells()
        assert ("long_500k" in cells) == (cfg.family in ("ssm", "hybrid"))
        for c in cells:
            assert c in SHAPES

    def test_param_count_llama4(self):
        """llama4-maverick should land near 400B total."""
        cfg = get_config("llama4-maverick-400b-a17b")
        m = build_model(cfg)
        tree = jax.eval_shape(m.init, jax.random.PRNGKey(0))
        n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))
        assert 3.5e11 < n < 4.6e11, n

    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_abstract_init(self, arch):
        """Full config param tree builds abstractly (no allocation).."""
        cfg = get_config(arch)
        m = build_model(cfg)
        tree = jax.eval_shape(m.init, jax.random.PRNGKey(0))
        n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))
        # every full model is at least 10M params (whisper-tiny is 39M)
        assert n > 1e7, (arch, n)
