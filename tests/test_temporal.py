"""Temporal stream codec gates (ISSUE 8).

The stream contract in test form:

  container   ``FFCS`` round trips; ``decode_frame`` (seek from the latest
              keyframe) is bitwise ``decompress_stream(data)[t]`` for every
              frame; corrupt bytes (magic, truncation, CRC, a non-keyframe
              first frame) raise :class:`BlobCorruptError`, never garbage.
  predictor   residuals are taken against the predictor evaluated on
              DECODED history, so per-frame error never accumulates along a
              long residual chain — rechecked in float64 against the bounds
              the stream header claims.
  warm start  ``warm_start=False`` (the default) is bitwise-neutral: the
              engine ignores any ``warm_freq`` and reproduces the legacy
              cold trajectory; ``warm_start=True`` still conforms.
  service     ``submit_stream`` preserves submission order through the
              FRONT/BACK pipeline at depths 1 and 2, and FFCS bytes decode
              through ``submit_decompress`` to the stacked frames.
"""

import struct
import zlib

import numpy as np
import pytest

from repro.compressors import get_compressor
from repro.core.engine import default_engine
from repro.core.errors import BlobCorruptError, FFCzError, StreamStateError
from repro.core.ffcz import FFCz, FFCzConfig
from repro.core.temporal import TemporalCodec, TemporalConfig, TemporalStream
from repro.serving.ffcz_service import FFCzService, ServiceConfig

pytestmark = pytest.mark.timeout(180)


def _frames(n, shape=(16, 16), seed=0, drift=0.05):
    """Coherent synthetic sequence: a fixed field plus a slowly drifting
    structured mode plus small per-frame noise (what a predictor can win on)."""
    rng = np.random.default_rng(seed)
    base = (rng.standard_normal(shape) * 0.5 + 4.0).cumsum(axis=0)
    mode = np.cos(np.linspace(0, 2 * np.pi, base.size)).reshape(shape)
    out = []
    for t in range(n):
        x = base + drift * t * mode + 0.01 * rng.standard_normal(shape)
        out.append(np.ascontiguousarray(x, dtype=np.float32))
    return out


def _codec(mode="field", warm_start=True, predictor="linear", interval=4, **cfg_kw):
    cfg = dict(E_rel=1e-3, Delta_rel=1e-3, max_iters=300, warm_start=warm_start)
    cfg.update(cfg_kw)
    return TemporalCodec(
        get_compressor("szlike"),
        FFCzConfig(**cfg),
        TemporalConfig(mode=mode, predictor=predictor, keyframe_interval=interval),
    )


class TestContainer:
    @pytest.mark.parametrize("mode", ["field", "pencils"])
    def test_round_trip_and_keyframe_cadence(self, mode):
        frames = _frames(9)
        codec = _codec(mode, interval=4)
        data = codec.compress_stream(frames)
        s = TemporalStream.from_bytes(data)
        assert s.n_frames == 9 and s.shape == frames[0].shape
        assert [s.is_keyframe(t) for t in range(9)] == [t % 4 == 0 for t in range(9)]
        dec = codec.decompress_stream(data)
        assert len(dec) == 9
        for x, d in zip(frames, dec):
            assert d.shape == x.shape and d.dtype == np.float32
            assert np.abs(d.astype(np.float64) - x.astype(np.float64)).max() <= s.E

    @pytest.mark.parametrize("mode", ["field", "pencils"])
    def test_seek_matches_full_decode_bitwise(self, mode):
        """decode_frame walks from the latest keyframe only — resync means
        that chain reproduces the full sequential decode exactly."""
        frames = _frames(10, seed=3)
        codec = _codec(mode, interval=4)
        data = codec.compress_stream(frames)
        full = codec.decompress_stream(data)
        for t in range(10):
            assert np.array_equal(codec.decode_frame(data, t), full[t]), t
        with pytest.raises(IndexError):
            codec.decode_frame(data, 10)
        with pytest.raises(IndexError):
            codec.decode_frame(data, -1)

    def test_decoder_is_header_driven(self):
        """Any codec instance decodes any stream: the container header, not
        the decoder's own TemporalConfig, names mode/predictor/interval."""
        frames = _frames(6, seed=5)
        data = _codec("pencils", predictor="linear", interval=3).compress_stream(frames)
        other = _codec("field", predictor="identity", interval=8, warm_start=False)
        dec = other.decompress_stream(data)
        E = TemporalStream.from_bytes(data).E
        for x, d in zip(frames, dec):
            assert np.abs(d.astype(np.float64) - x.astype(np.float64)).max() <= E

    def test_corrupt_bytes_raise(self):
        data = _codec("field", interval=2).compress_stream(_frames(4))
        with pytest.raises(BlobCorruptError, match="magic"):
            TemporalStream.from_bytes(b"XXCS" + data[4:])
        for keep in (0, 3, 5, 12, len(data) // 2):
            with pytest.raises(BlobCorruptError):
                TemporalStream.from_bytes(data[:keep])
        # flip one bit inside the CRC-covered header region
        bad = bytearray(data)
        bad[8] ^= 0x10
        with pytest.raises(BlobCorruptError):
            TemporalStream.from_bytes(bytes(bad))

    def test_first_frame_must_be_keyframe(self):
        """A stream whose index marks frame 0 as a residual is structurally
        corrupt (there is no predecessor to predict from) — rebuild the
        header with the flag cleared and a fresh CRC to prove the parser
        rejects it rather than the CRC merely masking the case."""
        data = _codec("field", interval=2).compress_stream(_frames(4))
        s = TemporalStream.from_bytes(data)
        index_end = s.frames_base - 4
        entry = struct.calcsize("<QQB")
        first_entry = index_end - s.n_frames * entry
        bad = bytearray(data)
        bad[first_entry + 16] = 0  # clear frame 0's keyframe flag
        bad[index_end : index_end + 4] = struct.pack("<I", zlib.crc32(bytes(bad[:index_end])))
        with pytest.raises(BlobCorruptError, match="keyframe"):
            TemporalStream.from_bytes(bytes(bad))

    def test_empty_and_mismatched_frames_rejected(self):
        codec = _codec()
        with pytest.raises(ValueError, match="empty"):
            codec.compress_stream([])
        enc = codec.open_stream()
        with pytest.raises(ValueError, match="empty"):
            enc.add_frame(np.zeros((0, 4), np.float32))
        enc.add_frame(_frames(1)[0])
        with pytest.raises(ValueError, match="shape"):
            enc.add_frame(np.zeros((4, 4), np.float32))

    def test_config_validation(self):
        with pytest.raises(ValueError, match="predictor"):
            TemporalConfig(predictor="quadratic")
        with pytest.raises(ValueError, match="mode"):
            TemporalConfig(mode="blocks")
        with pytest.raises(ValueError, match="keyframe_interval"):
            TemporalConfig(keyframe_interval=0)
        with pytest.raises(ValueError, match="pspec"):
            TemporalCodec(
                get_compressor("szlike"),
                FFCzConfig(E_rel=1e-3, Delta_rel=None, pspec_rel=1e-3),
            )


class TestPredictorSelfCorrection:
    @pytest.mark.parametrize("predictor", ["identity", "linear"])
    def test_long_residual_chain_holds_bounds(self, predictor):
        """24 residual frames off one keyframe: predicting from DECODED
        history makes the chain self-correcting, so the float64-rechecked
        per-frame error stays inside the stream's claimed bounds at frame 24
        exactly as at frame 1 — no accumulation."""
        frames = _frames(25, shape=(12, 12), seed=11)
        codec = _codec("field", predictor=predictor, interval=32)
        data = codec.compress_stream(frames)
        s = TemporalStream.from_bytes(data)
        assert [s.is_keyframe(t) for t in range(25)] == [True] + [False] * 24
        dec = codec.decompress_stream(data)
        for t, (x, d) in enumerate(zip(frames, dec)):
            eps = d.astype(np.float64) - x.astype(np.float64)
            assert np.abs(eps).max() <= s.E, f"spatial bound violated at frame {t}"
            spec = np.fft.rfftn(eps)
            assert np.abs(spec.real).max() <= s.Delta, f"freq bound (Re) at frame {t}"
            assert np.abs(spec.imag).max() <= s.Delta, f"freq bound (Im) at frame {t}"

    def test_encoder_history_matches_decoder(self):
        """The encoder's committed decoded history IS the decoder's output —
        the property the self-correction argument rests on."""
        frames = _frames(8, seed=2)  # keyframes at 0/3/6, so 6..7 is history
        codec = _codec("field", interval=3)
        enc = codec.open_stream()
        for x in frames:
            enc.add_frame(x)
        dec = codec.decompress_stream(enc.finish())
        # the last two decoded frames are exactly the encoder's history
        assert np.array_equal(enc._history[-1], dec[-1])
        assert np.array_equal(enc._history[-2], dec[-2])


class TestWarmStart:
    def test_disabled_is_bitwise_neutral(self):
        """A cold plan (warm_start=False, the default) ignores any supplied
        warm spectrum: the POCS trajectory, and hence the encoded stream
        bytes, are bit-for-bit the legacy ones."""
        rng = np.random.default_rng(7)
        x = _frames(1, seed=7)[0]
        eng = default_engine()
        plan = eng.plan_field(x, FFCzConfig(E_rel=1e-3, Delta_rel=1e-3))
        assert plan.warm_start is False
        eps0 = (0.3 * plan.E * rng.standard_normal(x.shape)).astype(np.float32)
        cold = eng.execute_field(eps0, plan)
        junk = (rng.standard_normal(np.asarray(cold.freq).shape) * 1e-3).astype(np.complex64)
        again = eng.execute_field(eps0, plan, warm_freq=junk)
        assert np.array_equal(np.asarray(cold.freq), np.asarray(again.freq))
        assert int(cold.iterations) == int(again.iterations)

    def test_disabled_stream_keyframes_equal_plain_ffcz(self):
        """With warm_start off and interval 1, every frame is an independent
        cold keyframe — frame 0's payload is byte-identical to what the
        plain per-frame FFCz path produces for the same input."""
        frames = _frames(3, seed=9)
        cfg = FFCzConfig(E_rel=1e-3, Delta_rel=1e-3, max_iters=300)
        codec = TemporalCodec(
            get_compressor("szlike"), cfg, TemporalConfig(mode="field", keyframe_interval=1)
        )
        data = codec.compress_stream(frames)
        s = TemporalStream.from_bytes(data)
        plain = FFCz(get_compressor("szlike"), cfg).compress(frames[0])
        assert s.frame_payload(0) == plain.to_bytes()

    def test_enabled_still_conforms(self):
        """Warm residual frames converge and hold the same claimed bounds —
        the warm state is an initial guess, never a correctness input.  (The
        measured iteration win lives in the stream/warm-vs-cold bench row.)"""
        frames = _frames(8, seed=13, drift=0.2)
        for mode in ("field", "pencils"):
            codec = _codec(mode, warm_start=True, interval=8)
            enc = codec.open_stream()
            for x in frames:
                enc.add_frame(x)
            assert all(st["converged"] for st in enc.frame_stats), mode
            data = enc.finish()
            s = TemporalStream.from_bytes(data)
            dec = codec.decompress_stream(data)
            for t, (x, d) in enumerate(zip(frames, dec)):
                eps = d.astype(np.float64) - x.astype(np.float64)
                assert np.abs(eps).max() <= s.E, (mode, t)


class TestServiceStream:
    def _service(self, depth):
        return FFCzService(
            get_compressor("szlike"),
            config=ServiceConfig(max_batch=4, block=64, seed=1, pipeline_depth=depth),
            clock=lambda: 0.0,
            sleep=lambda s: None,
        )

    @pytest.mark.parametrize("depth", [1, 2])
    def test_stream_kind_ordering_and_decode(self, depth):
        svc = self._service(depth)
        cfg = FFCzConfig(E_rel=1e-3, Delta_rel=1e-3, max_iters=300, warm_start=True)
        frames = _frames(5, shape=(12, 12), seed=4)
        rng = np.random.default_rng(4)
        uids = [
            svc.submit_stream(frames, cfg, TemporalConfig(mode="field", keyframe_interval=2)),
            svc.submit_compress(rng.standard_normal((12, 12)).astype(np.float32),
                                FFCzConfig(E_rel=1e-3, Delta_rel=1e-3, max_iters=300)),
            svc.submit_stream(frames, cfg,
                              TemporalConfig(mode="pencils", predictor="identity")),
        ]
        res = svc.drain()
        assert list(res) == uids  # submission-ordered, streams interleaved with fields
        assert all(r.ok for r in res.values())
        ffcs = res[uids[0]].payload
        assert ffcs[:4] == b"FFCS"
        # FFCS bytes decode through the service to the stacked frames,
        # matching the library decoder exactly
        d = svc.submit_decompress(ffcs)
        out = svc.drain()[d].payload
        lib = np.stack(
            TemporalCodec(get_compressor("szlike"), cfg).decompress_stream(ffcs)
        )
        assert np.array_equal(out, lib)

    def test_stream_submit_validation(self):
        svc = self._service(1)
        with pytest.raises(ValueError, match="empty"):
            svc.submit_stream([], FFCzConfig(E_rel=1e-3, Delta_rel=1e-3))
        # pspec bounds cannot back a stream claim: rejected as a response,
        # not a hang or a crash
        u = svc.submit_stream(
            _frames(2), FFCzConfig(E_rel=1e-3, Delta_rel=None, pspec_rel=1e-3)
        )
        res = svc.drain()[u]
        assert not res.ok and "pspec" in str(res.error)


class TestEncoderTerminalState:
    """``finish()`` is terminal (ISSUE 10): committed state can neither be
    mutated nor re-emitted afterwards — the session layer's finalize-vs-append
    serialization rests on this raising structurally instead of corrupting."""

    def _finished(self):
        codec = _codec("field", warm_start=False, interval=2)
        enc = codec.open_stream()
        frames = _frames(3, shape=(12, 12), seed=6)
        for x in frames:
            enc.add_frame(x)
        return codec, enc, frames, enc.finish()

    def test_add_frame_after_finish_raises(self):
        _codec_, enc, frames, _data = self._finished()
        assert enc.finished
        with pytest.raises(StreamStateError, match="finished stream"):
            enc.add_frame(frames[0])
        # structured: a service/session layer catches it as an FFCzError
        with pytest.raises(FFCzError):
            enc.add_frame(frames[0])

    def test_double_finish_raises(self):
        _codec_, enc, _frames_, data = self._finished()
        with pytest.raises(StreamStateError, match="twice"):
            enc.finish()
        # the first container stays valid — the guard protects, not poisons
        assert TemporalStream.from_bytes(data).n_frames == 3

    def test_failed_add_frame_is_retryable_not_terminal(self):
        codec, enc, frames, _data = self._finished()
        enc2 = codec.open_stream()
        enc2.add_frame(frames[0])
        with pytest.raises(ValueError, match="shape"):
            enc2.add_frame(np.zeros((4, 4), np.float32))
        # a FAILED add_frame never finishes the stream: the retry lands
        assert not enc2.finished
        enc2.add_frame(frames[1])
        assert enc2.n_frames == 2

    def test_export_restore_roundtrip_is_bitwise(self):
        frames = _frames(6, shape=(12, 12), seed=8)
        codec = _codec("field", warm_start=False, interval=2)
        ref = codec.compress_stream(frames)
        enc = codec.open_stream()
        for x in frames[:4]:
            enc.add_frame(x)
        state = enc.export_state()
        enc2 = codec.restore_stream(
            state["frames"],
            shape=state["shape"],
            block=state["block"],
            E0=state["E0"],
            Delta0=state["Delta0"],
        )
        for x in frames[4:]:
            enc2.add_frame(x)
        assert enc2.finish() == ref

    def test_restore_rejects_foreign_keyframe_cadence(self):
        frames = _frames(4, shape=(12, 12), seed=8)
        codec = _codec("field", warm_start=False, interval=2)
        enc = codec.open_stream()
        for x in frames:
            enc.add_frame(x)
        state = enc.export_state()
        other = _codec("field", warm_start=False, interval=3)
        with pytest.raises(BlobCorruptError, match="different stream config"):
            other.restore_stream(
                state["frames"],
                shape=state["shape"],
                E0=state["E0"],
                Delta0=state["Delta0"],
            )
        with pytest.raises(ValueError, match="empty"):
            codec.restore_stream(
                [], shape=state["shape"], E0=state["E0"], Delta0=state["Delta0"]
            )
