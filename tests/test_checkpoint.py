"""Checkpoint manager: atomicity, retention, restore, FFCz codec."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.codec import CheckpointCodec
from repro.checkpoint.manager import CheckpointManager


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": jax.random.normal(k, (64, 32)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32), "s": jnp.float32(3.5)},
    }


class TestManager:
    def test_save_restore_exact(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        st = _state()
        mgr.save(3, st)
        got = mgr.restore(3, jax.eval_shape(lambda: st))
        for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_and_retention(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, _state(s))
        assert mgr.latest_step() == 4
        assert mgr.committed_steps() == [3, 4]  # older GC'd

    def test_uncommitted_dir_ignored(self, tmp_path):
        """A crash mid-save (no _COMMITTED) must be invisible to restore."""
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, _state())
        fake = tmp_path / "step_000000000009"
        fake.mkdir()
        (fake / "manifest.json").write_text("{}")
        assert mgr.latest_step() == 1

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(7, _state(), blocking=False)
        mgr.wait()
        assert mgr.latest_step() == 7

    def test_restore_empty_is_none(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        assert mgr.restore_latest(_state()) is None

    def test_shape_mismatch_raises(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"w": jnp.zeros((4, 4))})
        with pytest.raises(ValueError):
            mgr.restore(1, {"w": jnp.zeros((5, 4))})


class TestCodec:
    def test_ffcz_codec_bounds(self, rng):
        codec = CheckpointCodec(enabled=True, E_rel=1e-4, Delta_rel=1e-4)
        w = rng.standard_normal((128, 64)).astype(np.float32)
        back = codec.decode(codec.encode(w))
        assert np.abs(back - w).max() <= 1e-4 * np.ptp(w) * (1 + 1e-5)

    def test_ffcz_codec_compresses_smooth(self):
        from repro.data.fields import make_field

        codec = CheckpointCodec(enabled=True, E_rel=1e-3, Delta_rel=1e-3)
        w = make_field("s3d-like").reshape(64, -1)
        assert len(codec.encode(w)) < w.nbytes / 2

    def test_small_and_int_passthrough(self):
        codec = CheckpointCodec(enabled=True)
        for arr in (np.arange(10), np.float32([1.5]), np.zeros((3, 3), np.int64)):
            back = codec.decode(codec.encode(arr))
            np.testing.assert_array_equal(back, arr)

    def test_manager_with_codec_roundtrip(self, tmp_path, rng):
        codec = CheckpointCodec(enabled=True, E_rel=1e-5, Delta_rel=1e-5)
        mgr = CheckpointManager(str(tmp_path), codec=codec)
        st = {"w": jnp.asarray(rng.standard_normal((128, 128)), dtype=jnp.float32)}
        mgr.save(1, st)
        got = mgr.restore(1, jax.eval_shape(lambda: st))
        err = np.abs(np.asarray(got["w"]) - np.asarray(st["w"])).max()
        assert err <= 1e-5 * np.ptp(np.asarray(st["w"])) * (1 + 1e-5)


class TestBatchCodec:
    """Blockwise-batched encode path (tag B): one device program per save."""

    def test_encode_batch_mixed_leaves(self, rng):
        codec = CheckpointCodec(enabled=True, E_rel=1e-4, Delta_rel=1e-4, block=1024)
        arrays = [
            rng.standard_normal((128, 64)).astype(np.float32),
            np.cumsum(rng.standard_normal((4, 8, 16, 32)), axis=-1).astype(np.float32),  # rank 4
            rng.standard_normal((5000,)).astype(np.float64),
            np.arange(10),  # raw passthrough
            np.float32([1.5]),  # too small
        ]
        blobs = codec.encode_batch(arrays)
        for a, b in zip(arrays, blobs):
            back = codec.decode(b)
            assert back.shape == a.shape and back.dtype == a.dtype
            if a.dtype in (np.float32, np.float64) and a.size >= 4096:
                E = 1e-4 * np.ptp(a.astype(np.float32))
                diff = back.astype(np.float64) - a.astype(np.float32).astype(np.float64)
                assert np.abs(diff).max() <= E * (1 + 1e-9)
            else:
                np.testing.assert_array_equal(back, a)

    def test_frequency_bound_per_full_pencil(self, rng):
        block = 512
        codec = CheckpointCodec(enabled=True, E_rel=1e-4, Delta_rel=1e-4, block=block)
        a = np.cumsum(rng.standard_normal((16, 512)), axis=-1).astype(np.float32)
        [blob] = codec.encode_batch([a])
        back = codec.decode(blob)
        diff = (back.astype(np.float64) - a.astype(np.float64)).reshape(-1, block)
        tiles = a.reshape(-1, block)
        u32 = float(np.finfo(np.float32).eps)
        slack = 4 * u32 * np.sqrt((tiles.astype(np.float64) ** 2).sum(axis=-1).max())
        Delta = max(1e-4 * np.abs(np.fft.rfft(tiles, axis=-1)).max(), 4 * slack)
        d = np.fft.rfft(diff, axis=-1)
        assert max(np.abs(d.real).max(), np.abs(d.imag).max()) <= Delta * (1 + 1e-9)

    def test_manager_uses_batched_path(self, tmp_path, rng):
        codec = CheckpointCodec(enabled=True, E_rel=1e-5, Delta_rel=1e-5)
        mgr = CheckpointManager(str(tmp_path), codec=codec)
        st = {
            "w": jnp.asarray(rng.standard_normal((128, 128)), dtype=jnp.float32),
            "conv": jnp.asarray(rng.standard_normal((4, 4, 32, 32)), dtype=jnp.float32),
            "step": jnp.int32(7),
        }
        mgr.save(1, st)
        # eligible leaves are stored with the blockwise tag
        tags = set()
        step_dir = tmp_path / "step_000000000001"
        for i in range(3):
            tags.add((step_dir / f"{i}.bin").read_bytes()[:1])
        assert b"B" in tags and b"R" in tags
        got = mgr.restore(1, jax.eval_shape(lambda: st))
        for k in ("w", "conv"):
            err = np.abs(np.asarray(got[k]) - np.asarray(st[k])).max()
            assert err <= 1e-5 * np.ptp(np.asarray(st[k])) * (1 + 1e-5)
        assert int(got["step"]) == 7
