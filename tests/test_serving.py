"""Serving engine + FFCz KV-cache compression."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CompressionConfig, get_smoke_config
from repro.serving.engine import ServeConfig, ServingEngine
from repro.serving.kv_compress import compress_cache, compress_kv_tensor


class TestEngine:
    def test_batched_completion(self):
        cfg = get_smoke_config("qwen2-0.5b")
        eng = ServingEngine(cfg, ServeConfig(max_batch=4))
        uids = [eng.submit(np.arange(4 + i) % cfg.vocab, max_new_tokens=5) for i in range(3)]
        res = eng.step()
        assert sorted(r["uid"] for r in res) == sorted(uids)
        assert all(len(r["tokens"]) == 5 for r in res)
        assert all(0 <= t < cfg.vocab for r in res for t in r["tokens"])

    def test_queue_overflow_spills(self):
        cfg = get_smoke_config("qwen2-0.5b")
        eng = ServingEngine(cfg, ServeConfig(max_batch=2))
        for i in range(5):
            eng.submit(np.arange(4), max_new_tokens=2)
        assert len(eng.step()) == 2
        assert len(eng.queue) == 3

    def test_submit_rejects_invalid_requests(self):
        """Regression: an empty prompt used to be admitted and crash
        _make_batch's max() several steps later, inside a batch shared with
        valid requests; out-of-vocab ids would index garbage embeddings."""
        cfg = get_smoke_config("qwen2-0.5b")
        eng = ServingEngine(cfg, ServeConfig(max_batch=2))
        with pytest.raises(ValueError, match="non-empty"):
            eng.submit(np.array([], dtype=np.int32))
        with pytest.raises(ValueError, match="non-empty"):
            eng.submit(np.zeros((2, 3), dtype=np.int32))  # wrong rank
        with pytest.raises(ValueError, match="vocab|range"):
            eng.submit(np.array([0, cfg.vocab], dtype=np.int32))
        with pytest.raises(ValueError, match="vocab|range"):
            eng.submit(np.array([-1, 0], dtype=np.int32))
        with pytest.raises(ValueError, match="max_new_tokens"):
            eng.submit(np.arange(4), max_new_tokens=0)
        with pytest.raises(ValueError, match="max_len"):
            eng.submit(np.zeros(eng.serve.max_len + 1, dtype=np.int32))
        # nothing invalid was queued; a valid mixed batch still serves
        assert not eng.queue
        uid = eng.submit(np.arange(4), max_new_tokens=2)
        res = eng.step()
        assert [r["uid"] for r in res] == [uid]

    def test_greedy_determinism(self):
        cfg = get_smoke_config("qwen2-0.5b")
        eng = ServingEngine(cfg, ServeConfig(max_batch=1))
        eng.submit(np.arange(8), max_new_tokens=6)
        a = eng.step()[0]["tokens"]
        eng.submit(np.arange(8), max_new_tokens=6)
        b = eng.step()[0]["tokens"]
        assert a == b


class TestKVCompression:
    def test_dual_bounds(self, rng):
        kv = jnp.asarray(rng.standard_normal((2, 2, 256, 16)), dtype=jnp.float32)
        out = compress_kv_tensor(kv, bits=8, E_rel=1e-2, Delta_rel=1e-2, block=256)
        err = np.asarray(out - kv, dtype=np.float64)
        E = 1e-2 * np.abs(np.asarray(kv)).max()
        assert np.abs(err).max() <= E * 1.001
        # frequency bound along the sequence dim per pencil
        errt = np.swapaxes(err, 2, 3).reshape(-1, 256)
        d = np.fft.fft(errt, axis=-1)
        Delta = 1e-2 * 256 * E
        assert max(np.abs(d.real).max(), np.abs(d.imag).max()) <= Delta * 1.01

    def test_compress_cache_tree(self, rng):
        cache = {
            "k": jnp.asarray(rng.standard_normal((3, 2, 2, 64, 16)), dtype=jnp.float32),
            "v": jnp.asarray(rng.standard_normal((3, 2, 2, 64, 16)), dtype=jnp.float32),
            "pos": jnp.int32(64),
        }
        comp = CompressionConfig(kv_cache_compression=True, kv_E_rel=1e-2, kv_Delta_rel=1e-2)
        out = compress_cache(cache, comp)
        assert int(out["pos"]) == 64  # untouched
        assert not np.array_equal(np.asarray(out["k"]), np.asarray(cache["k"]))  # lossy
        E = 1e-2 * np.abs(np.asarray(cache["k"])).max()
        assert np.abs(np.asarray(out["k"]) - np.asarray(cache["k"])).max() <= E * 1.01

    def test_end_to_end_logit_drift_small(self):
        """KV compression must barely move the decode logits."""
        comp = CompressionConfig(kv_cache_compression=True, kv_E_rel=1e-3, kv_Delta_rel=1e-2)
        cfg = dataclasses.replace(get_smoke_config("qwen2-0.5b"), compression=comp)
        cfg_ref = get_smoke_config("qwen2-0.5b")
        prompt = np.arange(12) % cfg.vocab

        outs = {}
        for name, c in (("ref", cfg_ref), ("comp", cfg)):
            eng = ServingEngine(c, ServeConfig(max_batch=1), rng_seed=0)
            eng.submit(prompt, max_new_tokens=4)
            outs[name] = eng.step()[0]["tokens"]
        # greedy tokens should agree at this bound
        assert outs["ref"] == outs["comp"], outs

    def test_ssm_inapplicable_path(self):
        """mamba2 has no KV cache: engine must serve with compression flag on."""
        comp = CompressionConfig(kv_cache_compression=True)
        cfg = dataclasses.replace(get_smoke_config("mamba2-2.7b"), compression=comp)
        eng = ServingEngine(cfg, ServeConfig(max_batch=1))
        eng.submit(np.arange(8), max_new_tokens=3)
        assert len(eng.step()[0]["tokens"]) == 3
