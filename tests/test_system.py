"""End-to-end system behaviour: the paper's pipeline + the framework around it."""

import dataclasses

import numpy as np
import pytest

from repro.compressors import get_compressor
from repro.configs import get_smoke_config
from repro.core.ffcz import FFCz, FFCzConfig
from repro.core.spectrum import psnr, ssnr_spatial
from repro.data.fields import make_field


class TestPaperClaims:
    """Spot-checks of the paper's key observations on synthetic analogues."""

    def test_obs1_edit_overhead_modest(self):
        """Obs 1: edits reduce compression ratio only modestly vs the base."""
        x = make_field("nyx-like")
        base = get_compressor("szlike")
        E = 1e-3 * np.ptp(x)
        base_bytes = len(base.compress(x, E))
        _, blob = FFCz(base, FFCzConfig(E_rel=1e-3, Delta_rel=1e-2, max_iters=500)).roundtrip(x)
        overhead = blob.stats.edit_bytes / blob.stats.total_bytes
        assert overhead < 0.6, overhead  # modest, not dominating
        assert blob.stats.base_bytes <= base_bytes * 1.01

    def test_obs2_cheaper_than_trial_and_error_at_equal_guarantee(self):
        """Obs 2 / Table II core claim: enforcing the SAME dual-domain
        guarantee via edits costs far fewer bytes than tightening the base
        compressor's spatial bound until the frequency bound happens to hold.

        Regime note (EXPERIMENTS.md §Reproduction): the claim holds when the
        base compressor violates the bound at a sparse set of components —
        the paper's 512^3 real fields are in that regime; among our
        container-sized synthetics the diffraction-spot field is, so the
        assertion runs there (cut=10x), and the full field x base sweep is
        reported, not asserted, by benchmarks/table2_ratio.py."""
        x = make_field("hedm-like")
        base = get_compressor("szlike")

        def max_freq_err(xh):
            d = np.fft.fftn(xh.astype(np.float64)) - np.fft.fftn(x.astype(np.float64))
            return max(np.abs(d.real).max(), np.abs(d.imag).max())

        native = base.decompress(base.compress(x, 1e-3 * np.ptp(x)))
        Delta = max_freq_err(native) / 10.0
        c = FFCz(base, FFCzConfig(E_rel=1e-3, Delta_abs=float(Delta), E_abs=None,
                                  Delta_rel=None, max_iters=1000))
        xh, blob = c.roundtrip(x)
        assert max_freq_err(xh) <= Delta * 1.001  # guarantee held

        # trial-and-error: tighten E until the same frequency bound holds
        E = 1e-3 * np.ptp(x)
        blob_t = base.compress(x, E)
        for _ in range(20):
            if max_freq_err(base.decompress(blob_t)) <= Delta:
                break
            E *= 0.5
            blob_t = base.compress(x, E)
        assert blob.stats.total_bytes <= len(blob_t) * 1.05, (
            blob.stats.total_bytes, len(blob_t))

    def test_obs4_power_spectrum_within_ribbon(self):
        """Obs 4 (Fig. 10): with pointwise bounds, the reconstructed power
        spectrum stays within the requested relative ribbon everywhere."""
        from repro.core.spectrum import power_spectrum_relative_error

        x = make_field("nyx-like")[:32, :32, :32]
        c = FFCz(
            get_compressor("szlike"),
            FFCzConfig(E_rel=1e-3, Delta_rel=None, pspec_rel=1e-3, max_iters=1500),
        )
        xh, _ = c.roundtrip(x)
        _, rel = power_spectrum_relative_error(xh, x)
        assert np.abs(rel[1:]).max() <= 1e-3 * 1.05

    def test_table3_iteration_regimes(self):
        """Table III: tiny Delta (f-cube inside s-cube) converges in 1 iter
        with zero active spatial edits; moderate Delta needs more."""
        x = make_field("eeg-like").astype(np.float32)[:4096]
        base = get_compressor("szlike")

        tiny = FFCz(base, FFCzConfig(E_rel=1e-3, Delta_rel=1e-7, max_iters=400)).compress(x)
        assert tiny.stats.n_active_spatial == 0

        mod = FFCz(base, FFCzConfig(E_rel=1e-3, Delta_rel=1e-4, max_iters=400)).compress(x)
        assert mod.stats.iterations >= tiny.stats.iterations


class TestFrameworkIntegration:
    def test_quickstart_path(self, tmp_path):
        """Train a smoke model briefly, serve from its weights."""
        from repro.runtime.trainer import Trainer, TrainerConfig
        from repro.serving.engine import ServeConfig, ServingEngine

        cfg = get_smoke_config("granite-3-2b")
        tr = Trainer(cfg, TrainerConfig(seq_len=32, global_batch=4, ckpt_dir=str(tmp_path), ckpt_every=10, ckpt_async=False))
        out = tr.train(10)
        assert np.isfinite(out["final_loss"])
        eng = ServingEngine(cfg, ServeConfig(max_batch=2), params=tr.params)
        eng.submit(np.arange(6), max_new_tokens=4)
        assert len(eng.step()[0]["tokens"]) == 4

    def test_checkpoint_compression_end_to_end(self, tmp_path):
        """FFCz-compressed checkpoints restore within bound and still train."""
        comp_cfg = get_smoke_config("qwen2-0.5b")
        comp = dataclasses.replace(
            comp_cfg,
            compression=dataclasses.replace(comp_cfg.compression, checkpoint_compression=True,
                                            ckpt_E_rel=1e-5, ckpt_Delta_rel=1e-5),
        )
        from repro.runtime.trainer import Trainer, TrainerConfig

        tr = Trainer(comp, TrainerConfig(seq_len=32, global_batch=4, ckpt_dir=str(tmp_path), ckpt_every=5, ckpt_async=False))
        tr.train(5)
        tr2 = Trainer(comp, TrainerConfig(seq_len=32, global_batch=4, ckpt_dir=str(tmp_path), ckpt_every=5, ckpt_async=False))
        assert tr2.start_step == 5
        out = tr2.train(5)
        assert np.isfinite(out["final_loss"])
