"""Base compressors: the pointwise L-inf contract, all dims and dtypes."""

import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis or skip-stubs (requirements-dev.txt)

from repro.compressors import get_compressor

NAMES = ["szlike", "zfplike", "sperrlike", "identity"]


def _field(shape, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape).astype(np.float32).cumsum(axis=0)


class TestBoundContract:
    @pytest.mark.parametrize("name", NAMES)
    @pytest.mark.parametrize("shape", [(257,), (33, 21), (17, 12, 9)])
    @pytest.mark.parametrize("E", [1e-1, 1e-3])
    def test_linf_bound(self, name, shape, E):
        x = _field(shape)
        c = get_compressor(name)
        xh = c.decompress(c.compress(x, E))
        assert xh.shape == x.shape
        assert np.abs(xh - x).max() <= E * (1 + 1e-5), name

    @pytest.mark.parametrize("name", NAMES)
    def test_compresses(self, name):
        """Smooth data must compress below raw float32 size."""
        x = _field((64, 64))
        blob = get_compressor(name).compress(x, 1e-2)
        if name != "identity":
            assert len(blob) < x.nbytes / 2, (name, len(blob))

    @pytest.mark.parametrize("name", ["szlike", "zfplike", "sperrlike"])
    def test_rejects_nonpositive_bound(self, name):
        with pytest.raises(ValueError):
            get_compressor(name).compress(_field((8, 8)), 0.0)

    def test_unknown_name(self):
        with pytest.raises(ValueError):
            get_compressor("nope")

    @pytest.mark.parametrize("name", ["szlike", "zfplike"])
    @given(st.integers(1, 3), st.integers(0, 1000))
    @settings(max_examples=20, deadline=None)
    def test_bound_property(self, name, ndim, seed):
        rng = np.random.default_rng(seed)
        shape = tuple(int(rng.integers(3, 24)) for _ in range(ndim))
        x = (rng.standard_normal(shape) * rng.uniform(0.1, 10)).astype(np.float32)
        E = float(rng.uniform(1e-4, 1e-1)) * (np.ptp(x) + 1e-6)
        c = get_compressor(name)
        xh = c.decompress(c.compress(x, E))
        assert np.abs(xh - x).max() <= E * (1 + 1e-5)


class TestRatioOrdering:
    def test_smoothness_helps(self):
        """zfplike should beat identity/zlib on smooth fields (decorrelation)."""
        from repro.data.fields import make_field

        x = make_field("s3d-like")
        z = get_compressor("zfplike").compress(x, 1e-3 * np.ptp(x))
        i = get_compressor("identity").compress(x, 1e-3)
        assert len(z) < len(i)
