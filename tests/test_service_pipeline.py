"""Pipelined-serving gates: depth parity, drain ordering, async engine twins.

ISSUE 7's contract in test form:

  parity      with a frozen clock, every response field — payload bytes,
              edit streams (inside the blobs), error dicts, RequestStats —
              is byte-identical between ``pipeline_depth=1`` (serial) and
              ``pipeline_depth=2`` (overlapped), with and without chaos.
  ordering    drain() returns responses keyed AND ordered by submission,
              regardless of bucket fusion or ring retirement order.
  async twins engine.execute_field_async / correct_async produce bitwise
              the results of their synchronous counterparts, and the packed
              path honours caller-provided staging buffers.
"""

import numpy as np
import pytest

from repro.compressors import get_compressor
from repro.core import blockwise
from repro.core.engine import default_engine
from repro.core.ffcz import FFCzConfig
from repro.runtime.faults import FaultConfig, FaultInjector
from repro.serving.ffcz_service import FFCzService, ServiceConfig

SEED = 20260809

pytestmark = pytest.mark.timeout(120)


def _service(depth, injector=None, **cfg_kw):
    defaults = dict(max_batch=4, block=64, seed=SEED, pipeline_depth=depth)
    defaults.update(cfg_kw)
    # frozen clock + no-op sleep: latency_s is identically 0.0 in both modes,
    # so whole RequestStats objects (not just outcome fields) must compare
    # equal for parity to hold
    return FFCzService(
        get_compressor("szlike"),
        config=ServiceConfig(**defaults),
        injector=injector,
        clock=lambda: 0.0,
        sleep=lambda s: None,
    )


def _field_cfg(**kw):
    defaults = dict(E_rel=1e-3, Delta_rel=1e-3, max_iters=300, verify=False)
    defaults.update(kw)
    return FFCzConfig(**defaults)


def _submit_mixed(svc, rng, n_fields=2, n_pencils=6):
    uids = []
    for i in range(max(n_fields, n_pencils)):
        if i < n_fields:
            x = rng.standard_normal((12, 12)).astype(np.float32)
            uids.append(svc.submit_compress(x, _field_cfg()))
        if i < n_pencils:
            size = int(rng.integers(40, 300))
            uids.append(
                svc.submit_pencils(rng.standard_normal(size).astype(np.float32), 1e-3, 1e-3)
            )
    return uids


class TestDepthParity:
    def _run(self, depth, injector_cfg=None):
        inj = FaultInjector(injector_cfg, seed=SEED) if injector_cfg else None
        svc = _service(depth, injector=inj)
        rng = np.random.default_rng(SEED)
        uids = _submit_mixed(svc, rng)
        res = svc.drain()
        svc.close()
        return uids, res, dict(svc.counters)

    def test_clean_responses_byte_identical(self):
        u1, r1, c1 = self._run(1)
        u2, r2, c2 = self._run(2)
        assert u1 == u2 and list(r1) == list(r2)
        assert c1 == c2
        for u in u1:
            assert r1[u].ok and r2[u].ok
            assert r1[u].payload == r2[u].payload, f"payload bytes differ for {u}"
            assert r1[u].stats == r2[u].stats, f"stats differ for {u}"

    def test_chaos_responses_byte_identical(self):
        cfg = FaultConfig(p_codec=0.5, p_dispatch=0.5, p_oom=0.5, max_per_site=2)
        u1, r1, c1 = self._run(1, cfg)
        u2, r2, c2 = self._run(2, cfg)
        assert u1 == u2 and list(r1) == list(r2)
        assert c1 == c2
        for u in u1:
            a, b = r1[u], r2[u]
            assert (a.ok, a.payload, a.error, a.stats) == (b.ok, b.payload, b.error, b.stats)

    def test_depth_one_has_no_worker_thread(self):
        svc = _service(1)
        rng = np.random.default_rng(SEED)
        _submit_mixed(svc, rng, n_fields=1, n_pencils=2)
        svc.drain()
        assert svc._worker is None, "serial mode must not spin up the encode worker"

    def test_pipelined_decode_roundtrip(self):
        svc = _service(2)
        rng = np.random.default_rng(SEED)
        x = rng.standard_normal(200).astype(np.float32)
        u = svc.submit_pencils(x, 1e-3, 1e-3)
        blob = svc.drain()[u].payload
        d = svc.submit_decompress(blob)
        out = svc.drain()[d].payload
        svc.close()
        assert out.shape == x.shape
        assert np.max(np.abs(out.astype(np.float64) - x)) <= 2e-3 * np.ptp(x)


class TestDrainOrdering:
    def test_responses_ordered_by_submission(self):
        """Regression (ISSUE 7 satellite): bucket fusion retires pencil
        requests together and fields singly, so retirement order interleaves
        differently from submission order — drain() must hide that."""
        for depth in (1, 2):
            svc = _service(depth, max_batch=3)
            rng = np.random.default_rng(SEED)
            uids = []
            # pencil, field, pencil, field, ... : the three pencils of each
            # fused bucket retire together, ahead of interleaved fields
            for i in range(9):
                if i % 2 == 0:
                    uids.append(
                        svc.submit_pencils(
                            rng.standard_normal(100).astype(np.float32), 1e-3, 1e-3
                        )
                    )
                else:
                    x = rng.standard_normal((10, 10)).astype(np.float32)
                    uids.append(svc.submit_compress(x, _field_cfg()))
            res = svc.drain()
            svc.close()
            assert list(res) == uids, f"depth={depth}: drain order != submission order"
            assert all(res[u].ok for u in uids)

    def test_step_returns_bucket_in_submission_order(self):
        svc = _service(2)
        rng = np.random.default_rng(SEED)
        uids = [
            svc.submit_pencils(rng.standard_normal(80).astype(np.float32), 1e-3, 1e-3)
            for _ in range(4)
        ]
        got = [r.uid for r in svc.step()]
        svc.close()
        assert got == uids


class TestAsyncEngineTwins:
    def test_correct_async_bitwise_matches_correct(self):
        eng = default_engine()
        rng = np.random.default_rng(SEED)
        ts = [rng.standard_normal(n).astype(np.float32) * 0.01 for n in (100, 250, 64)]
        E = [0.01, 0.02, 0.01]
        D = [0.01, 0.01, 0.02]
        c1, e1, s1 = eng.correct(
            ts, E, D, block=64, max_iters=20, return_edits=True, return_corrected=True
        )
        h = eng.correct_async(
            ts, E, D, block=64, max_iters=20, return_edits=True, return_corrected=True
        )
        c2, e2, s2 = h.result()
        assert h.result() is not None  # idempotent re-read
        for a, b in zip(c1, c2):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        for (a_s, a_f), (b_s, b_f) in zip(e1, e2):
            assert np.array_equal(np.asarray(a_s), np.asarray(b_s))
            assert np.array_equal(np.asarray(a_f), np.asarray(b_f))
        assert np.array_equal(np.asarray(s1.iterations), np.asarray(s2.iterations))
        assert np.array_equal(np.asarray(s1.converged), np.asarray(s2.converged))

    def test_execute_field_async_bitwise_matches_sync(self):
        eng = default_engine()
        rng = np.random.default_rng(SEED)
        x = rng.standard_normal((24, 24)).astype(np.float32)
        plan = eng.plan_field(x, _field_cfg())
        eps0 = (x * 0.001).astype(np.float32)
        r_sync = eng.execute_field(eps0, plan)
        r_async = eng.execute_field_async(eps0, plan).result()
        assert np.array_equal(r_sync.eps, r_async.eps)
        assert np.array_equal(r_sync.spat, r_async.spat)
        assert np.array_equal(r_sync.freq, r_async.freq)
        assert (r_sync.converged, r_sync.iterations) == (r_async.converged, r_async.iterations)

    def test_pack_batch_reuses_staging(self):
        rng = np.random.default_rng(SEED)
        ts = [rng.standard_normal(n).astype(np.float32) for n in (100, 200)]
        packed, counts, pads = blockwise.pack_batch(ts, 64)
        again, counts2, pads2 = blockwise.pack_batch(ts, 64, out=packed)
        assert again is packed and counts == counts2 and pads == pads2
        # mismatched shape: allocates fresh rather than corrupting
        other, _, _ = blockwise.pack_batch(ts[:1], 64, out=packed)
        assert other is not packed

    def test_empty_batch_handle(self):
        eng = default_engine()
        h = eng.correct_async([], [], [], block=64, return_edits=True)
        corrected, edits, stats = h.result()
        assert corrected == [] and edits == []
        assert np.asarray(stats.converged).size == 0

    def test_service_staging_cache_populates_and_reuses(self):
        svc = _service(2)
        rng = np.random.default_rng(SEED)
        for _ in range(2):
            uids = [
                svc.submit_pencils(rng.standard_normal(100).astype(np.float32), 1e-3, 1e-3)
                for _ in range(4)
            ]
            res = svc.drain()
            assert all(res[u].ok for u in uids)
        svc.close()
        # 4 tensors x ceil(100/64)=2 rows -> one cached (8, 64) buffer, reused
        assert list(svc._staging) == [(8, 64)]


class TestSessionFifo:
    """ISSUE 10 satellite: session ops run entirely on the single ordered
    worker, so a finalize racing queued appends — across TWO interleaved
    sessions, with field/pencil traffic mixed in — retires strictly after
    them at every pipeline depth, and the containers are bitwise the
    whole-sequence oracle."""

    def _frames(self, n, seed):
        rng = np.random.default_rng(seed)
        base = (rng.standard_normal((12, 12)) * 0.5 + 4.0).cumsum(axis=0)
        return [
            np.ascontiguousarray(
                base + 0.05 * t + 0.01 * rng.standard_normal((12, 12)), np.float32
            )
            for t in range(n)
        ]

    @pytest.mark.parametrize("depth", [1, 2])
    def test_interleaved_sessions_finalize_after_queued_appends(self, depth):
        from repro.core.temporal import TemporalCodec, TemporalConfig

        svc = _service(depth)
        cfg = _field_cfg()
        stream = TemporalConfig(mode="field", keyframe_interval=2)
        a_frames, b_frames = self._frames(4, seed=3), self._frames(4, seed=5)
        sa = svc.open_session(cfg, stream, session_id="a")
        sb = svc.open_session(cfg, stream, session_id="b")
        rng = np.random.default_rng(SEED)
        uids, appends = [], {"a": [], "b": []}
        # interleave the two sessions' appends with unrelated traffic, then
        # queue BOTH finalizes while every append is still queued
        for t in range(4):
            appends["a"].append(svc.submit_append(sa, t, a_frames[t]))
            if t == 1:
                uids.append(
                    svc.submit_pencils(
                        rng.standard_normal(100).astype(np.float32), 1e-3, 1e-3
                    )
                )
            appends["b"].append(svc.submit_append(sb, t, b_frames[t]))
            uids += [appends["a"][-1], appends["b"][-1]]
        fa, fb = svc.submit_finalize(sa), svc.submit_finalize(sb)
        res = svc.drain()
        svc.close()
        assert set(res) == set(uids) | {fa, fb}
        assert all(r.ok for r in res.values()), {
            u: r.error for u, r in res.items() if not r.ok
        }
        # every append acked with its own seq, in per-session FIFO order
        for sid in ("a", "b"):
            assert [res[u].payload.seq for u in appends[sid]] == [0, 1, 2, 3]
            assert not any(res[u].payload.duplicate for u in appends[sid])
        # the finalized containers are bitwise the whole-sequence oracle
        codec = TemporalCodec(get_compressor("szlike"), cfg, stream=stream)
        assert res[fa].payload == codec.compress_stream(a_frames)
        assert res[fb].payload == codec.compress_stream(b_frames)

    @pytest.mark.parametrize("depth", [1, 2])
    def test_append_after_finalize_rejects_structurally(self, depth):
        from repro.core.temporal import TemporalConfig

        svc = _service(depth)
        cfg = _field_cfg()
        frames = self._frames(2, seed=7)
        sid = svc.open_session(cfg, TemporalConfig(mode="field", keyframe_interval=2))
        u0 = svc.submit_append(sid, 0, frames[0])
        uf = svc.submit_finalize(sid)
        # queued BEFORE the finalize retires, but ordered after it: the
        # session is closed by the time this append runs
        u1 = svc.submit_append(sid, 1, frames[1])
        res = svc.drain()
        svc.close()
        assert res[u0].ok and res[uf].ok
        assert not res[u1].ok
        assert res[u1].error["type"] == "SessionNotFound"
        assert svc.counters["rejected"] == 1
