"""FFCz gradient compression: error bounds + learning signal preservation."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.grad_compress import compress_gradients


class TestGradCompression:
    def test_spatial_bound(self, rng):
        g = {"w": jnp.asarray(rng.standard_normal((512, 16)), dtype=jnp.float32)}
        out = compress_gradients(g, bits=8, E_rel=1e-2, Delta_rel=1e-1, block=1024)
        err = np.asarray(out["w"] - g["w"], dtype=np.float64)
        E = 1e-2 * np.abs(np.asarray(g["w"])).max()
        assert np.abs(err).max() <= E * 1.001

    def test_frequency_bound_per_block(self, rng):
        g = {"w": jnp.asarray(rng.standard_normal(2048), dtype=jnp.float32)}
        block = 512
        out = compress_gradients(g, bits=6, E_rel=5e-2, Delta_rel=1e-2, block=block, max_iters=30)
        err = np.asarray(out["w"] - g["w"], dtype=np.float64).reshape(-1, block)
        d = np.fft.fft(err, axis=-1)
        E = 5e-2 * np.abs(np.asarray(g["w"])).max()
        Delta = 1e-2 * block * E
        assert max(np.abs(d.real).max(), np.abs(d.imag).max()) <= Delta * 1.02

    def test_direction_preserved(self, rng):
        """Compressed gradient must stay well-aligned with the original."""
        g = {"w": jnp.asarray(rng.standard_normal(4096), dtype=jnp.float32)}
        out = compress_gradients(g, bits=8, E_rel=1e-2, Delta_rel=1e-1)
        a, b = np.asarray(g["w"]), np.asarray(out["w"])
        cos = a @ b / (np.linalg.norm(a) * np.linalg.norm(b))
        assert cos > 0.999

    def test_tiny_leaves_passthrough(self):
        g = {"scalar": jnp.float32(2.0)}
        out = compress_gradients(g)
        assert float(out["scalar"]) == 2.0
