"""Alternating-projection invariants (paper Alg. 1 / §III), incl. property tests."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis or skip-stubs (requirements-dev.txt)

from repro.core.cubes import fcube_violations, project_fcube, project_scube
from repro.core.pocs import alternating_projection


def _feasible(eps, E, Delta, tol=1e-3):
    eps = np.asarray(eps, dtype=np.float64)
    d = np.fft.fftn(eps)
    ok_s = np.all(np.abs(eps) <= np.asarray(E) * (1 + tol))
    ok_f = np.all(np.maximum(np.abs(d.real), np.abs(d.imag)) <= np.asarray(Delta) * (1 + tol))
    return ok_s and ok_f


class TestProjections:
    def test_scube_is_projection(self, rng):
        x = jnp.asarray(rng.standard_normal(100), dtype=jnp.float32)
        c, disp = project_scube(x, 0.5)
        assert np.abs(np.asarray(c)).max() <= 0.5
        assert np.allclose(np.asarray(c), np.asarray(x) + np.asarray(disp))
        # idempotent
        c2, d2 = project_scube(c, 0.5)
        assert np.allclose(c2, c) and np.abs(np.asarray(d2)).max() == 0

    def test_fcube_preserves_hermitian(self, rng):
        """Clipping Re/Im with a symmetric bound keeps IFFT real (paper §IV-D)."""
        eps = rng.standard_normal((16, 16)).astype(np.float32)
        d = jnp.asarray(np.fft.fftn(eps))
        clipped, _ = project_fcube(d, 0.5)
        back = np.fft.ifftn(np.asarray(clipped))
        assert np.abs(back.imag).max() < 1e-5

    def test_fcube_exact_euclidean_projection(self, rng):
        """FFT->clip->IFFT is the exact projection because the DFT rows are
        orthogonal: verify the displacement is orthogonal to the face."""
        eps = rng.standard_normal(32).astype(np.float64)
        d = np.fft.fft(eps)
        Delta = 0.5 * max(np.abs(d.real).max(), np.abs(d.imag).max())
        clipped = np.clip(d.real, -Delta, Delta) + 1j * np.clip(d.imag, -Delta, Delta)
        proj = np.fft.ifft(clipped).real
        # projection property: ||eps - proj||^2 + ||proj - y||^2 <= ||eps - y||^2
        # for any y in the f-cube; test with y = 0 (always feasible)
        assert np.sum((eps - proj) ** 2) + np.sum(proj**2) <= np.sum(eps**2) + 1e-9


class TestAlternatingProjection:
    def test_terminates_inside_both_cubes(self, rng):
        E = 0.1
        eps0 = np.clip(rng.standard_normal((32, 32)) * 0.05, -E, E).astype(np.float32)
        Delta = 0.4 * np.abs(np.fft.fftn(eps0)).max()
        res = alternating_projection(jnp.asarray(eps0), E, Delta, max_iters=500)
        assert bool(res.converged)
        assert _feasible(res.eps, E, Delta)

    def test_edit_identity(self, rng):
        """eps_final == eps0 + IRFFT(freq_edits) + spat_edits (decoder contract).

        freq_edits live on the rfft half-spectrum (the Hermitian fast path).
        """
        E = 0.1
        eps0 = np.clip(rng.standard_normal(512) * 0.05, -E, E).astype(np.float32)
        Delta = 0.5 * np.abs(np.fft.fft(eps0)).max()
        res = alternating_projection(jnp.asarray(eps0), E, Delta, max_iters=500)
        recon = eps0 + np.fft.irfft(np.asarray(res.freq_edits), n=512) + np.asarray(res.spat_edits)
        assert np.abs(recon - np.asarray(res.eps)).max() < 1e-4

    def test_inside_fcube_one_iteration(self, rng):
        """Huge Delta => already feasible => 1 iteration, zero edits (Table III)."""
        eps0 = (rng.standard_normal(64) * 0.01).astype(np.float32)
        res = alternating_projection(jnp.asarray(eps0), 0.1, 1e9, max_iters=100)
        assert int(res.iterations) == 1
        assert np.abs(np.asarray(res.spat_edits)).max() == 0
        assert np.abs(np.asarray(res.freq_edits)).max() == 0

    def test_kernel_path_matches(self, rng):
        E = 0.1
        eps0 = np.clip(rng.standard_normal((24, 24)) * 0.05, -E, E).astype(np.float32)
        Delta = 0.5 * np.abs(np.fft.fftn(eps0)).max()
        r1 = alternating_projection(jnp.asarray(eps0), E, Delta, max_iters=300, use_kernels=False)
        r2 = alternating_projection(jnp.asarray(eps0), E, Delta, max_iters=300, use_kernels=True)
        assert int(r1.iterations) == int(r2.iterations)
        assert np.allclose(np.asarray(r1.eps), np.asarray(r2.eps), atol=1e-6)

    def test_pointwise_delta(self, rng):
        E = 0.1
        eps0 = np.clip(rng.standard_normal(256) * 0.05, -E, E).astype(np.float32)
        d0 = np.abs(np.fft.fft(eps0))
        Delta = np.maximum(0.5 * d0, 0.1 * d0.max()).astype(np.float32)
        res = alternating_projection(jnp.asarray(eps0), E, jnp.asarray(Delta), max_iters=1000)
        assert _feasible(res.eps, E, Delta)

    @given(st.integers(0, 10_000), st.floats(0.2, 0.95))
    @settings(max_examples=25, deadline=None)
    def test_feasibility_property(self, seed, frac):
        """For any start inside the s-cube and Delta = frac * max|FFT|, POCS
        lands in the intersection (0 is always in both cubes => nonempty)."""
        rng = np.random.default_rng(seed)
        E = 0.1
        eps0 = np.clip(rng.standard_normal(128) * 0.07, -E, E).astype(np.float32)
        Delta = max(frac * np.abs(np.fft.fft(eps0)).max(), 1e-6)
        res = alternating_projection(jnp.asarray(eps0), E, Delta, max_iters=2000)
        assert _feasible(res.eps, E, Delta, tol=1e-2)

    def test_violations_counter(self, rng):
        d = jnp.asarray((rng.standard_normal(64) + 1j * rng.standard_normal(64)).astype(np.complex64))
        v = fcube_violations(d, 0.5)
        expected = np.sum((np.abs(np.asarray(d).real) > 0.5) | (np.abs(np.asarray(d).imag) > 0.5))
        assert int(v) == int(expected)
