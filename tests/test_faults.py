"""Chaos suite: the FFCz service under deterministic fault injection.

The drain contract under test: every submitted request retires as exactly one
of completed-within-bounds or rejected-with-structured-reason — the service
never hangs (each step retires >= 1 request; CI additionally wraps this file
in a wall-clock timeout) and never lets a raw exception escape.

All randomness flows from FFCZ_FAULT_SEED (env, default fixed) so a CI
failure replays locally bit-for-bit.
"""

import os

import numpy as np
import pytest

from repro.compressors import get_compressor
from repro.core.engine import CorrectionEngine
from repro.core.errors import BlobCorruptError, FFCzError
from repro.core.ffcz import FFCz, FFCzBlob, FFCzConfig
from repro.runtime.faults import FaultConfig, FaultInjector
from repro.serving.ffcz_service import FFCzService, ServiceConfig, decode_pencil_blob

SEED = int(os.environ.get("FFCZ_FAULT_SEED", "20260809"))
DATA = os.path.join(os.path.dirname(__file__), "data")

pytestmark = pytest.mark.timeout(60)


def _service(injector=None, **cfg_kw):
    defaults = dict(max_batch=4, block=64, deadline_s=30.0, seed=SEED)
    defaults.update(cfg_kw)
    return FFCzService(
        get_compressor("szlike"), config=ServiceConfig(**defaults), injector=injector
    )


def _field_cfg(**kw):
    defaults = dict(E_rel=1e-3, Delta_rel=1e-3, max_iters=300, verify=False)
    defaults.update(kw)
    return FFCzConfig(**defaults)


def _mixed_workload(svc, rng, n_fields=3, n_pencils=6):
    uids = []
    for _ in range(n_fields):
        x = rng.standard_normal((12, 12)).astype(np.float32)
        uids.append(svc.submit_compress(x, _field_cfg()))
    for _ in range(n_pencils):
        size = int(rng.integers(40, 300))
        uids.append(svc.submit_pencils(rng.standard_normal(size).astype(np.float32), 1e-3, 1e-3))
    return uids


class TestChaosDrain:
    @pytest.mark.parametrize("depth", [1, 2])
    def test_drains_under_all_fault_sites(self, depth):
        """Mixed faults at every site: the queue still fully drains, each
        request completing or rejecting with a structured reason — in serial
        mode and with the two-stage pipeline in flight alike."""
        inj = FaultInjector(
            FaultConfig(
                p_codec=0.4, p_dispatch=0.4, p_oom=0.4, p_slow=0.2, slow_s=120.0, max_per_site=2
            ),
            seed=SEED,
        )
        svc = _service(inj, deadline_s=20.0, pipeline_depth=depth)
        rng = np.random.default_rng(SEED)
        uids = _mixed_workload(svc, rng)
        # plus decode work, some of it deliberately corrupt
        blob = FFCz(get_compressor("szlike"), _field_cfg()).compress(
            rng.standard_normal((10, 10)).astype(np.float32)
        ).to_bytes()
        uids.append(svc.submit_decompress(blob))
        uids.append(svc.submit_decompress(inj.flip_bit(blob)))
        uids.append(svc.submit_decompress(inj.truncate(blob)))
        uids.append(svc.submit_decompress(b"\x00garbage"))

        responses = svc.drain()
        assert not svc._queue, "drain left requests behind"
        assert set(responses) == set(uids), "a request vanished without a response"
        for uid in uids:
            r = responses[uid]
            if r.ok:
                assert r.payload is not None
            else:
                # structured rejection: full taxonomy fields, no raw traceback
                assert r.error["type"] and r.error["disposition"] in (
                    "retry", "bisect", "reject", "timeout",
                ), r.error
            assert r.stats is not None
        assert svc.counters["completed"] + svc.counters["rejected"] == len(uids)

    def test_chaos_is_deterministic(self):
        """Same seed -> identical outcomes, rung sequences, and error types."""

        def run():
            inj = FaultInjector(
                FaultConfig(p_codec=0.5, p_dispatch=0.5, p_oom=0.5, max_per_site=2), seed=SEED
            )
            svc = _service(inj)
            rng = np.random.default_rng(SEED)
            uids = _mixed_workload(svc, rng, n_fields=2, n_pencils=4)
            res = svc.drain()
            return [
                (u, res[u].ok, res[u].stats.rungs, None if res[u].ok else res[u].error["type"])
                for u in uids
            ]

        assert run() == run()

    def test_pipelined_matches_serial_counters(self):
        """The pipelined drain is scheduling-invariant: for the same fault
        seed, per-request outcomes, rung sequences, attempt counts, and the
        service's failure-machinery counters all match the serial run (the
        injector draws from per-request substreams, so thread interleaving
        cannot change which faults fire)."""

        def run(depth):
            inj = FaultInjector(
                FaultConfig(p_codec=0.5, p_dispatch=0.5, p_oom=0.5, max_per_site=2), seed=SEED
            )
            svc = _service(inj, pipeline_depth=depth)
            rng = np.random.default_rng(SEED)
            uids = _mixed_workload(svc, rng, n_fields=2, n_pencils=6)
            res = svc.drain()
            svc.close()
            per_request = [
                (
                    u,
                    res[u].ok,
                    res[u].stats.rungs,
                    res[u].stats.attempts,
                    None if res[u].ok else res[u].error["type"],
                )
                for u in uids
            ]
            return per_request, dict(svc.counters)

        serial, serial_counters = run(1)
        pipelined, pipelined_counters = run(2)
        assert serial == pipelined
        assert serial_counters == pipelined_counters

    def test_oom_evicts_staging_buffer_before_bisect(self):
        """Donated-buffer cache hygiene: the injected allocation failure on a
        fused bucket drops the cached full-size (B, block) staging buffer
        before the bisected halves run, so they never allocate against it."""
        inj = FaultInjector(FaultConfig(p_oom=1.0, max_per_site=1), seed=SEED)
        svc = _service(inj, pipeline_depth=2)
        rng = np.random.default_rng(SEED)
        uids = [
            svc.submit_pencils(rng.standard_normal(150).astype(np.float32), 1e-3, 1e-3)
            for _ in range(4)
        ]
        res = svc.drain()
        svc.close()
        assert all(res[u].ok for u in uids)
        assert svc.counters["bisects"] >= 1
        assert svc.counters["buffer_evictions"] >= 1
        # the evicted full-bucket key is gone; only shapes cached after the
        # bisect (the halves re-dispatch without staging) may remain
        full_rows = sum(-(-150 // 64) for _ in uids)
        assert (full_rows, 64) not in svc._staging


class TestDegradationLadder:
    def test_oom_bisects_bucket(self):
        """Guaranteed allocation failure on the fused call splits the bucket;
        the halves (post fire-cap) complete."""
        inj = FaultInjector(FaultConfig(p_oom=1.0, max_per_site=1), seed=SEED)
        svc = _service(inj)
        rng = np.random.default_rng(SEED)
        uids = [
            svc.submit_pencils(rng.standard_normal(150).astype(np.float32), 1e-3, 1e-3)
            for _ in range(4)
        ]
        res = svc.drain()
        assert all(res[u].ok for u in uids)
        assert all("bisect" in res[u].stats.rungs for u in uids)
        assert svc.counters["bisects"] >= 1

    def test_single_request_oom_rejects_structured(self):
        """A bucket of one cannot bisect: structured ResourceExhausted."""
        inj = FaultInjector(FaultConfig(p_oom=1.0, max_per_site=100), seed=SEED)
        svc = _service(inj)
        rng = np.random.default_rng(SEED)
        u = svc.submit_pencils(rng.standard_normal(100).astype(np.float32), 1e-3, 1e-3)
        r = svc.drain()[u]
        assert not r.ok
        assert r.error["type"] == "ResourceExhausted"
        assert r.error["disposition"] == "bisect"

    def test_fft_impl_ladder_descends_to_xla(self):
        """A transform that keeps failing walks pallas -> packed -> xla."""

        class FlakyTransformEngine(CorrectionEngine):
            # the service dispatches through the async API (sync
            # execute_field routes through it too), so the dispatch hook is
            # the one injection point covering both modes
            def execute_field_async(self, eps0, plan):
                if plan.fft_impl != "xla":
                    raise RuntimeError(f"injected transform failure ({plan.fft_impl})")
                return super().execute_field_async(eps0, plan)

        svc = FFCzService(
            get_compressor("szlike"),
            engine=FlakyTransformEngine(backend="local"),
            config=ServiceConfig(block=64, max_retries=0, seed=SEED),
        )
        rng = np.random.default_rng(SEED)
        u = svc.submit_compress(
            rng.standard_normal((12, 12)).astype(np.float32), _field_cfg(fft_impl="pallas")
        )
        r = svc.drain()[u]
        assert r.ok, r.error
        assert r.stats.fft_impl == "xla"
        assert ("fallback:packed", "fallback:xla") == tuple(
            g for g in r.stats.rungs if g.startswith("fallback")
        )

    def test_nonconvergence_takes_relax_rung(self):
        """POCS budget exhaustion triggers the relaxed re-run and the final
        converged flag + violation count surface in the response stats."""
        svc = _service()
        rng = np.random.default_rng(SEED)
        x = rng.standard_normal((16, 16)).astype(np.float32).cumsum(axis=0)
        u = svc.submit_compress(x, _field_cfg(Delta_rel=1e-7, max_iters=1))
        r = svc.drain()[u]
        assert r.ok, r.error
        assert "relax" in r.stats.rungs
        assert r.stats.converged is not None
        if not r.stats.converged:
            assert r.stats.final_violations > 0

    def test_transient_codec_fault_retries_to_success(self):
        inj = FaultInjector(FaultConfig(p_codec=1.0, max_per_site=2), seed=SEED)
        svc = _service(inj)
        rng = np.random.default_rng(SEED)
        u = svc.submit_compress(rng.standard_normal((10, 10)).astype(np.float32), _field_cfg())
        r = svc.drain()[u]
        assert r.ok, r.error
        assert any(g.startswith("retry:") for g in r.stats.rungs)
        assert r.stats.attempts >= 1


class TestRejections:
    def test_infeasible_bound_rejects_structured(self):
        """A constant field has zero range: E_rel resolves an empty s-cube,
        diagnosed at bound-resolution time (ISSUE 9) — a request property,
        rejected not crashed."""
        svc = _service()
        u = svc.submit_compress(np.zeros((8, 8), np.float32), _field_cfg())
        r = svc.drain()[u]
        assert not r.ok
        assert r.error["type"] == "InfeasibleBound"
        assert r.error["stage"] == "plan"
        assert r.error["disposition"] == "reject"

    def test_slow_request_exceeds_deadline(self):
        """Injected slowness is charged against the deadline clock: the
        request times out structurally without the test actually sleeping."""
        inj = FaultInjector(FaultConfig(p_slow=1.0, slow_s=999.0, max_per_site=1), seed=SEED)
        svc = _service(inj, deadline_s=1.0)
        rng = np.random.default_rng(SEED)
        u = svc.submit_compress(rng.standard_normal((10, 10)).astype(np.float32), _field_cfg())
        r = svc.drain()[u]
        assert not r.ok
        assert r.error["type"] == "DeadlineExceeded"
        assert r.error["disposition"] == "timeout"
        assert svc.counters["timeouts"] == 1

    def test_admission_validation(self):
        svc = _service()
        with pytest.raises(ValueError, match="empty"):
            svc.submit_compress(np.zeros((0, 4), np.float32), _field_cfg())
        with pytest.raises(ValueError, match="positive"):
            svc.submit_pencils(np.ones(8, np.float32), -1e-3, 1e-3)


class TestBlobDecodeHardening:
    """Satellite (a): every malformed input to blob decode raises the
    structured BlobCorruptError (a ValueError subclass), never a raw
    struct/zlib/index crash — fuzzed over the golden fixtures in tests/data."""

    FIXTURES = ["legacy_blob_v0.bin", "padfree_v1_blob.bin", "uneven_v1_blob.bin"]

    def _load(self, name):
        with open(os.path.join(DATA, name), "rb") as f:
            return f.read()

    @pytest.mark.parametrize("name", FIXTURES)
    def test_truncations_never_crash(self, name):
        raw = self._load(name)
        rng = np.random.default_rng(SEED)
        cuts = set(rng.integers(0, len(raw), 60).tolist()) | {0, 1, 4, 5, len(raw) - 1}
        for keep in cuts:
            try:
                FFCzBlob.from_bytes(raw[:keep])
            except BlobCorruptError:
                pass  # the only acceptable failure mode

    @pytest.mark.parametrize("name", FIXTURES)
    def test_bit_flips_never_crash(self, name):
        """A flip may decode to different values (that is what CRC mode is
        for) but must never raise anything outside the taxonomy."""
        raw = self._load(name)
        base = get_compressor("szlike")
        ffcz = FFCz(base, FFCzConfig())
        inj = FaultInjector(seed=SEED)
        for _ in range(40):
            flipped = inj.flip_bit(raw)
            try:
                ffcz.decompress(FFCzBlob.from_bytes(flipped))
            except FFCzError:
                pass

    def test_garbage_rejected(self):
        for junk in [b"", b"\x00", b"FFCZ", os.urandom(64), b"A" * 1000]:
            with pytest.raises((BlobCorruptError, ValueError)):
                FFCzBlob.from_bytes(junk)

    @pytest.mark.parametrize("name", FIXTURES)
    def test_appended_trailing_bytes_rejected(self, name):
        """Regression (ISSUE 9): bytes past the declared sections used to be
        silently ignored; they must reject as corruption while the FFCP/FFCR/
        FFCC tail sniff keeps working on unmodified blobs."""
        raw = self._load(name)
        FFCzBlob.from_bytes(raw)  # the pristine fixture still parses
        for tail in [b"\x00", b"garbage", os.urandom(17), b"FFCQ" + b"\x00" * 8]:
            with pytest.raises(BlobCorruptError):
                FFCzBlob.from_bytes(raw + tail)

    def test_edit_stream_trailing_bytes_rejected(self):
        """EncodedEdits.from_bytes rejects surplus bytes past its declared
        flag/payload sections (the container slices exactly)."""
        from repro.core.edits import EncodedEdits, encode_edits

        edits = np.zeros(64)
        edits[3] = 0.25
        raw = encode_edits(edits, 0.5).to_bytes()
        assert EncodedEdits.from_bytes(raw).n_active == 1
        with pytest.raises(BlobCorruptError, match="trailing"):
            EncodedEdits.from_bytes(raw + b"\x00")
        with pytest.raises(BlobCorruptError):
            EncodedEdits.from_bytes(raw + os.urandom(9))

    def test_legacy_fixtures_still_decode(self):
        """Hardening must not reject a single valid legacy byte stream, and
        re-encoding a current-version fixture stays byte-identical."""
        base = get_compressor("szlike")
        for name in self.FIXTURES:
            raw = self._load(name)
            blob = FFCzBlob.from_bytes(raw)
            out = FFCz(base, FFCzConfig()).decompress(blob)
            out_name = name.replace("_blob.bin", "_output.npy").replace(".bin", "_output.npy")
            golden = np.load(os.path.join(DATA, out_name))
            assert np.array_equal(out, golden)
            if name != "legacy_blob_v0.bin":  # v0 re-encodes as v1 (magic added)
                assert blob.to_bytes() == raw

    def test_pencil_blob_corruption(self, rng):
        svc = _service()
        u = svc.submit_pencils(rng.standard_normal(200).astype(np.float32), 1e-3, 1e-3)
        payload = svc.drain()[u].payload
        base = get_compressor("szlike")
        assert decode_pencil_blob(payload, base).shape == (200,)
        inj = FaultInjector(seed=SEED)
        for _ in range(30):
            with pytest.raises(BlobCorruptError):
                corrupted = inj.flip_bit(payload)
                if corrupted == payload:  # pragma: no cover - rng cannot return equal
                    continue
                decode_pencil_blob(corrupted, base)
        for keep in [0, 5, len(payload) // 2, len(payload) - 1]:
            with pytest.raises(BlobCorruptError):
                decode_pencil_blob(payload[:keep], base)


def _session_frames(n, seed, shape=(12, 12)):
    rng = np.random.default_rng(seed)
    base = (rng.standard_normal(shape) * 0.5 + 4.0).cumsum(axis=0)
    return [
        np.ascontiguousarray(
            base + 0.05 * t + 0.01 * rng.standard_normal(shape), np.float32
        )
        for t in range(n)
    ]


class TestSessionChaos:
    """ISSUE 10: live sessions under injected append/journal faults.

    The gated claims: the mixed session workload fully drains with
    structured outcomes at both pipeline depths with IDENTICAL per-request
    results and counters (the session sites fire from per-uid substreams);
    a duplicate-append retry stays idempotent under chaos; and admission
    (``max_sessions``) rejects with ResourceExhausted at both depths.
    """

    # max_per_site=1 keeps every injected failure within the retry budget
    # (one append fires at most one append-site + one journal-site fault),
    # so appends always land and the bitwise-oracle claim stays checkable
    CHAOS = FaultConfig(
        p_session_append=0.4, p_session_journal=0.4, p_codec=0.3, max_per_site=1
    )

    def _run(self, depth):
        from repro.core.errors import ResourceExhausted
        from repro.core.temporal import TemporalConfig

        inj = FaultInjector(self.CHAOS, seed=SEED)
        svc = _service(inj, pipeline_depth=depth, max_sessions=2, max_queue=64)
        rng = np.random.default_rng(SEED)
        cfg = _field_cfg()
        stream = TemporalConfig(mode="field", keyframe_interval=2)
        a = _session_frames(4, seed=3)
        b = _session_frames(4, seed=5)
        sa = svc.open_session(cfg, stream, session_id="sa")
        sb = svc.open_session(cfg, stream, session_id="sb")
        # admission is chaos-gated too: the third live session rejects
        # identically at every depth
        with pytest.raises(ResourceExhausted) as admit:
            svc.open_session(cfg, stream, session_id="sc")
        assert admit.value.stage == "admit"
        # everything below queues BEFORE the drain so queue depth (and any
        # admission decision) cannot depend on pipeline depth
        uids = []
        for t in range(4):
            uids.append(svc.submit_append(sa, t, a[t], uid=f"sa-{t}"))
            uids.append(svc.submit_append(sb, t, b[t], uid=f"sb-{t}"))
            if t == 1:
                uids.append(
                    svc.submit_pencils(
                        rng.standard_normal(100).astype(np.float32), 1e-3, 1e-3
                    )
                )
        # a client retry after an ambiguous failure: same seq, same content
        dup = svc.submit_append(sa, 3, a[3], uid="sa-dup")
        # and a buggy client: a gap, rejected structurally
        gap = svc.submit_append(sb, 9, b[3], uid="sb-gap")
        fin = svc.submit_finalize(sa, uid="sa-fin")
        ab = svc.submit_abort(sb, uid="sb-abort")
        uids += [dup, gap, fin, ab]
        res = svc.drain()
        svc.close()
        per_request = [
            (
                u,
                res[u].ok,
                res[u].stats.rungs,
                res[u].stats.attempts,
                None if res[u].ok else res[u].error["type"],
            )
            for u in uids
        ]
        return per_request, dict(svc.counters), dict(svc.sessions.counters), res

    @pytest.mark.parametrize("depth", [1, 2])
    def test_session_workload_drains_structured(self, depth):
        per_request, counters, scounters, res = self._run(depth)
        assert counters["completed"] + counters["rejected"] == len(per_request)
        assert counters["retries"] > 0, "chaos probabilities never fired"
        # duplicate-append idempotency holds under chaos: cached receipt,
        # original digest, nothing re-appended
        assert res["sa-dup"].ok
        assert res["sa-dup"].payload.duplicate
        assert res["sa-dup"].payload.digest == res["sa-3"].payload.digest
        # the gap rejects structurally, and the session survives to abort
        assert not res["sb-gap"].ok
        assert res["sb-gap"].error["type"] == "SessionSequenceError"
        assert res["sb-abort"].ok
        # injected faults never corrupt the stream: the finalized container
        # is bitwise the fault-free whole-sequence oracle
        from repro.core.temporal import TemporalCodec, TemporalConfig

        codec = TemporalCodec(
            get_compressor("szlike"), _field_cfg(),
            stream=TemporalConfig(mode="field", keyframe_interval=2),
        )
        assert res["sa-fin"].payload == codec.compress_stream(_session_frames(4, seed=3))
        assert scounters["duplicates"] == 1
        assert scounters["sequence_rejects"] == 1
        assert scounters["finalized"] == 1 and scounters["aborted"] == 1

    def test_depth_parity(self):
        """Same fault seed -> identical per-request outcomes, rung
        sequences, attempt counts, and both counter families, serial vs
        pipelined — the session sites draw from per-uid substreams."""
        serial = self._run(1)
        pipelined = self._run(2)
        assert serial[0] == pipelined[0]
        assert serial[1] == pipelined[1]
        assert serial[2] == pipelined[2]


class TestStreamContainerFuzz:
    """ISSUE 10 satellite: FFCS container fuzz over a multi-keyframe stream.

    Truncation at every frame boundary and index bit flips reject at parse;
    a payload bit flip either leaves a frame's decode chain intact (bitwise
    the original) or raises BlobCorruptError — NEVER silently wrong data.
    Field mode runs with crc=True (payload CRC tails are what detect the
    flip); pencil payloads carry an unconditional CRC.
    """

    def _stream(self, mode):
        from repro.core.temporal import TemporalCodec, TemporalConfig, TemporalStream

        cfg_kw = dict(crc=True) if mode == "field" else {}
        codec = TemporalCodec(
            get_compressor("szlike"),
            _field_cfg(**cfg_kw),
            stream=TemporalConfig(mode=mode, keyframe_interval=2),
        )
        frames = _session_frames(6, seed=11)
        data = codec.compress_stream(frames)
        return codec, frames, data, TemporalStream.from_bytes(data)

    @pytest.mark.parametrize("mode", ["field", "pencils"])
    def test_truncation_at_every_frame_boundary_rejects(self, mode):
        from repro.core.temporal import TemporalStream

        codec, _frames_, data, s = self._stream(mode)
        boundaries = [s.frames_base + off for off, _len, _k in s.entries]
        for cut in boundaries:
            with pytest.raises(BlobCorruptError):
                TemporalStream.from_bytes(data[:cut])
            with pytest.raises(BlobCorruptError):
                codec.decompress_stream(data[:cut])

    @pytest.mark.parametrize("mode", ["field", "pencils"])
    def test_index_bit_flips_reject_at_parse(self, mode):
        from repro.core.temporal import TemporalStream

        _codec_, _frames_, data, s = self._stream(mode)
        rng = np.random.default_rng(SEED)
        # anywhere in the CRC'd header+index prefix, incl. the offset table
        for pos in rng.integers(5, s.frames_base - 4, 25).tolist():
            bad = bytearray(data)
            bad[pos] ^= 1 << int(rng.integers(0, 8))
            with pytest.raises(BlobCorruptError):
                TemporalStream.from_bytes(bytes(bad))

    @pytest.mark.parametrize("mode", ["field", "pencils"])
    def test_payload_bit_flips_never_decode_wrong_data(self, mode):
        codec, _frames_, data, s = self._stream(mode)
        original = codec.decompress_stream(data)
        rng = np.random.default_rng(SEED)
        for j in range(s.n_frames):
            off, length, _k = s.entries[j]
            start = s.frames_base + off
            for pos in rng.integers(start, start + length, 3).tolist():
                bad = bytearray(data)
                bad[pos] ^= 1 << int(rng.integers(0, 8))
                bad = bytes(bad)
                for t in range(s.n_frames):
                    chain = range(s.latest_keyframe(t), t + 1)
                    if j in chain:
                        # the damaged frame is in t's decode chain: the
                        # payload CRC must catch it
                        with pytest.raises(BlobCorruptError):
                            codec.decode_frame(bad, t)
                    else:
                        # seek decode from the latest intact keyframe is
                        # untouched by the damage — bitwise the original
                        assert np.array_equal(codec.decode_frame(bad, t), original[t])


class TestCrcTail:
    def test_crc_roundtrip_and_parity(self, rng):
        x = rng.standard_normal((16, 16)).astype(np.float32)
        plain = FFCz(get_compressor("szlike"), _field_cfg())
        withcrc = FFCz(get_compressor("szlike"), _field_cfg(crc=True))
        b0, b1 = plain.compress(x), withcrc.compress(x)
        raw0, raw1 = b0.to_bytes(), b1.to_bytes()
        assert raw1.startswith(raw0) and len(raw1) > len(raw0)
        # the CRC tail is excluded from the cross-backend parity unit
        assert b1.payload_bytes() == raw0
        blob = FFCzBlob.from_bytes(raw1)
        assert blob.crc and blob.to_bytes() == raw1  # decode -> re-encode stable
        assert np.array_equal(withcrc.decompress(blob), plain.decompress(b0))

    def test_crc_catches_every_sampled_bit_flip(self, rng):
        """Without CRC a flip can silently change decoded values; with the
        tail, every sampled single-bit flip is detected at parse time."""
        x = rng.standard_normal((12, 12)).astype(np.float32)
        raw = FFCz(get_compressor("szlike"), _field_cfg(crc=True)).compress(x).to_bytes()
        inj = FaultInjector(seed=SEED)
        for _ in range(80):
            with pytest.raises((BlobCorruptError, ValueError)):
                FFCzBlob.from_bytes(inj.flip_bit(raw))
