"""Distributed pencil-decomposed rFFT: bitwise parity with the single-device
path, on 2- and 8-device CPU meshes.

The multi-device checks run in a subprocess (XLA_FLAGS must be set before jax
imports — same pattern as tests/test_distributed.py) and report JSON; the
shape-validation checks are pure functions and run in-process.

The parity bar extends PR 2's batched-vs-sharded discipline to whole fields:
``pencil_rfftn`` must equal the fused ``jnp.fft.rfftn`` bit for bit, and
``FFCz.compress`` of a :class:`ShardedField` must emit the byte-identical
blob the single-device path emits, for scalar (``Delta_abs``) and pointwise
(``pspec_rel``) bounds alike.
"""

import json
import os
import subprocess
import sys

import pytest

from repro.sharding.dist_fft import local_freq_shape, validate_pencil_shape

_CHILD_SCRIPT = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
import json
import numpy as np
import jax
import jax.numpy as jnp
from repro.compressors import get_compressor
from repro.core.ffcz import FFCz, FFCzConfig, ShardedField
from repro.core.spectrum import power_spectrum
from repro.sharding.dist_fft import pencil_irfftn, pencil_rfftn

out = {"n_dev": len(jax.devices())}
rng = np.random.default_rng(7)

# --- transform parity: decomposed+distributed == fused single-device, bitwise
x3 = rng.standard_normal((32, 16, 12)).astype(np.float32)
x2 = rng.standard_normal((32, 62)).astype(np.float32)
for name, x in (("3d", x3), ("2d", x2)):
    field = ShardedField.shard(x)
    X = pencil_rfftn(field)
    fused = jnp.fft.rfftn(jnp.asarray(x))
    out[f"fwd_bitwise_{name}"] = bool(np.array_equal(np.asarray(X), np.asarray(fused)))
    back = pencil_irfftn(X, x.shape, field.mesh, field.axis_name)
    ref = jnp.fft.irfftn(fused, s=x.shape).astype(jnp.float32)
    out[f"inv_bitwise_{name}"] = bool(np.array_equal(np.asarray(back), np.asarray(ref)))
    out[f"roundtrip_close_{name}"] = bool(
        np.allclose(np.asarray(back), x, atol=1e-5 * np.abs(x).max())
    )

# --- FFCz blob parity: sharded compress == single-device compress, bytewise
f3 = (rng.standard_normal((32, 16, 12)) * 0.5 + 5.0).astype(np.float32).cumsum(axis=0)
cfgs = {
    "Delta_abs": FFCzConfig(
        E_rel=1e-3,
        Delta_rel=None,
        Delta_abs=float(np.abs(np.fft.fftn(f3)).max() * 1e-3),
    ),
    "pspec": FFCzConfig(E_rel=1e-3, Delta_rel=None, pspec_rel=1e-3, max_iters=1500),
}
for name, cfg in cfgs.items():
    c = FFCz(get_compressor("szlike"), cfg)
    blob_single = c.compress(f3)
    blob_sharded = c.compress(ShardedField.shard(f3))
    out[f"blob_bitwise_{name}"] = blob_single.to_bytes() == blob_sharded.to_bytes()
    out[f"converged_{name}"] = bool(blob_sharded.stats.converged)
    out[f"margins_ok_{name}"] = bool(
        blob_sharded.stats.spatial_margin >= 0 and blob_sharded.stats.frequency_margin >= 0
    )
    dec = c.decompress(blob_single)
    dec_sharded = c.decompress_sharded(blob_sharded)
    out[f"decompress_bitwise_{name}"] = bool(
        np.array_equal(np.asarray(dec_sharded.array), dec)
    )

# 2-D field through the full codec as well (half axis is the sharded one)
f2 = (rng.standard_normal((32, 62)) * 0.1).astype(np.float32).cumsum(axis=1)
c = FFCz(get_compressor("zfplike"), FFCzConfig(E_rel=1e-3, Delta_rel=1e-3))
out["blob_bitwise_2d"] = c.compress(f2).to_bytes() == c.compress(ShardedField.shard(f2)).to_bytes()

# non-power-of-two c2c axes: outside the bitwise contract (strict_bitwise
# rejects them), but with the opt-out the bounds must still hold exactly —
# and the blob must stay decodable to a mesh-resident field (the scatter
# runs no distributed FFT, so decompress_sharded skips the strict check)
f4 = (rng.standard_normal((24, 24, 10)) * 0.3 + 4.0).astype(np.float32).cumsum(axis=2)
c = FFCz(get_compressor("szlike"), FFCzConfig(E_rel=1e-3, Delta_rel=1e-3))
blob_ns = c.compress(ShardedField.shard(f4, strict_bitwise=False))
out["nonstrict_bounds_hold"] = bool(
    blob_ns.stats.spatial_margin >= 0 and blob_ns.stats.frequency_margin >= 0
)
out["nonstrict_decompress_bitwise"] = bool(
    np.array_equal(np.asarray(c.decompress_sharded(blob_ns).array), c.decompress(blob_ns))
)

# --- sharded power spectrum: same shells to float tolerance (metric, not bound)
k_ref, p_ref = power_spectrum(f3)
k_sh, p_sh = power_spectrum(ShardedField.shard(f3))
p_ref, p_sh = np.asarray(p_ref, np.float64), np.asarray(p_sh, np.float64)
# shell 0 is the mean-normalized DC: ~0 by construction, pure cancellation noise
out["pspec_shells_close"] = bool(
    np.array_equal(np.asarray(k_ref), np.asarray(k_sh))
    and np.allclose(p_ref[1:], p_sh[1:], rtol=1e-4)
    and abs(p_sh[0]) <= 1e-6 * p_ref[1:].max()
)

print("RESULTS:" + json.dumps(out))
"""


@pytest.fixture(scope="module", params=[2, 8])
def dist_results(request):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD_SCRIPT % request.param],
        capture_output=True,
        text=True,
        env=env,
        timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines() if ln.startswith("RESULTS:")][0]
    return json.loads(line[len("RESULTS:") :]), request.param


class TestPencilTransformParity:
    def test_mesh_size(self, dist_results):
        results, n_dev = dist_results
        assert results["n_dev"] == n_dev

    def test_rfftn_bitwise_equals_fused(self, dist_results):
        results, _ = dist_results
        assert results["fwd_bitwise_3d"]
        assert results["fwd_bitwise_2d"]

    def test_irfftn_bitwise_equals_fused(self, dist_results):
        results, _ = dist_results
        assert results["inv_bitwise_3d"]
        assert results["inv_bitwise_2d"]

    def test_roundtrip_recovers_field(self, dist_results):
        results, _ = dist_results
        assert results["roundtrip_close_3d"]
        assert results["roundtrip_close_2d"]


class TestShardedCompressParity:
    def test_delta_abs_blob_bitwise(self, dist_results):
        results, _ = dist_results
        assert results["blob_bitwise_Delta_abs"]
        assert results["converged_Delta_abs"] and results["margins_ok_Delta_abs"]

    def test_pspec_blob_bitwise(self, dist_results):
        results, _ = dist_results
        assert results["blob_bitwise_pspec"]
        assert results["converged_pspec"] and results["margins_ok_pspec"]

    def test_2d_blob_bitwise(self, dist_results):
        results, _ = dist_results
        assert results["blob_bitwise_2d"]

    def test_decompress_sharded_bitwise(self, dist_results):
        results, _ = dist_results
        assert results["decompress_bitwise_Delta_abs"]
        assert results["decompress_bitwise_pspec"]


class TestShardedPowerSpectrum:
    def test_shells_match_gathered(self, dist_results):
        results, _ = dist_results
        assert results["pspec_shells_close"]


class TestNonStrictBitwise:
    def test_bounds_hold_outside_bitwise_contract(self, dist_results):
        results, _ = dist_results
        assert results["nonstrict_bounds_hold"]

    def test_nonstrict_blob_decodes_to_mesh(self, dist_results):
        results, _ = dist_results
        assert results["nonstrict_decompress_bitwise"]


class TestShapeValidation:
    def test_rank_rejected(self):
        with pytest.raises(ValueError, match="rank"):
            validate_pencil_shape((128,), 2)
        with pytest.raises(ValueError, match="rank"):
            validate_pencil_shape((8, 8, 8, 8), 2)

    def test_axis0_divisibility_message(self):
        with pytest.raises(ValueError, match="axis 0 .30. is not divisible"):
            validate_pencil_shape((30, 16, 12), 8)

    def test_axis1_divisibility_message(self):
        with pytest.raises(ValueError, match="axis 1 .12. is not divisible"):
            validate_pencil_shape((32, 12, 16), 8)

    def test_2d_half_axis_message(self):
        # N1 = 48 -> 25 half components: not divisible by 8
        with pytest.raises(ValueError, match="half axis"):
            validate_pencil_shape((32, 48), 8)

    def test_non_power_of_two_c2c_axis_rejected_when_strict(self):
        # divisible by the mesh, but the fused inverse's 1/24 normalization
        # is not placement-invariant -> bitwise parity unattainable
        with pytest.raises(ValueError, match="power of two"):
            validate_pencil_shape((24, 16, 12), 8)
        with pytest.raises(ValueError, match="power of two"):
            validate_pencil_shape((32, 24, 12), 8)

    def test_non_power_of_two_accepted_with_opt_out(self):
        validate_pencil_shape((24, 24, 10), 8, strict_bitwise=False)

    def test_last_axis_unconstrained(self):
        # the c2r axis scale sits inside one final pass either way: any
        # length is bitwise-safe (12 and 15 are not powers of two)
        validate_pencil_shape((32, 16, 12), 8)
        validate_pencil_shape((32, 16, 15), 8)

    def test_divisible_shapes_accepted(self):
        validate_pencil_shape((32, 16, 12), 8)
        validate_pencil_shape((32, 62), 8)  # H = 32

    def test_local_freq_shape(self):
        assert local_freq_shape((32, 16, 12), (4, 16, 12)) == (4, 16, 7)
        assert local_freq_shape((32, 62), (4, 62)) == (32, 4)
