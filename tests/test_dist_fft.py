"""Distributed pencil-decomposed rFFT: generalized (uneven, padded) slab
decomposition, parity tri-state, and bitwise parity with the single-device
path on 2- and 8-device CPU meshes.

The multi-device checks run in a subprocess (XLA_FLAGS must be set before jax
imports — same pattern as tests/test_distributed.py) and report JSON; the
shape-classification checks are pure functions and run in-process.

The parity bar extends PR 2's batched-vs-sharded discipline to whole fields:
``pencil_rfftn`` must equal the fused ``jnp.fft.rfftn`` bit for bit, and
``FFCz.compress`` of a :class:`ShardedField` must emit the byte-identical
blob payload the single-device path emits, for scalar (``Delta_abs``) and
pointwise (``pspec_rel``) bounds alike — now on uneven (non-divisible) slabs
too, where axis extents classify as ``"bitwise"``.  ``"bound"``-class shapes
(non-power-of-two c2c axes) must hold both bounds without byte parity, and
divisibility is no longer an error anywhere.
"""

import json
import os
import subprocess
import sys

import pytest

from repro.sharding.dist_fft import (
    classify_parity,
    local_freq_shape,
    padded_freq_shape,
    padded_spatial_shape,
    validate_pencil_shape,
)

_TRANSFORM_CASES = (
    "3d",
    "2d",
    "3d_uneven_pow2",
    "3d_uneven",
    "2d_uneven",
    "2d_uneven_pow2",
)

_CHILD_SCRIPT = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
import json
import numpy as np
import jax
import jax.numpy as jnp
from repro.compressors import get_compressor
from repro.core.ffcz import FFCz, FFCzConfig, ShardedField
from repro.core.spectrum import power_spectrum
from repro.sharding.dist_fft import DistSpec, pencil_irfftn, pencil_rfftn

out = {"n_dev": len(jax.devices())}
n_dev = len(jax.devices())
rng = np.random.default_rng(7)

# --- transform parity: decomposed+distributed == fused single-device, bitwise
# (32,16,12)/(32,62): evenly divisible (the PR 3 contract); (4,16,12): uneven
# pow2 slabs (axis 0 < mesh size); (30,14,10)/(30,48): uneven AND non-pow2
cases = {
    "3d": rng.standard_normal((32, 16, 12)).astype(np.float32),
    "2d": rng.standard_normal((32, 62)).astype(np.float32),
    "3d_uneven_pow2": rng.standard_normal((4, 16, 12)).astype(np.float32),
    "3d_uneven": rng.standard_normal((30, 14, 10)).astype(np.float32),
    "2d_uneven": rng.standard_normal((30, 48)).astype(np.float32),
    "2d_uneven_pow2": rng.standard_normal((32, 48)).astype(np.float32),
}
for name, x in cases.items():
    field = ShardedField.shard(x)
    out[f"parity_class_{name}"] = field.parity
    X = pencil_rfftn(field)
    fused = jnp.fft.rfftn(jnp.asarray(x))
    out[f"fwd_bitwise_{name}"] = bool(
        np.array_equal(np.asarray(field.unpad_freq(X)), np.asarray(fused))
    )
    back = pencil_irfftn(X, x.shape, field.mesh, field.axis_name)
    ref = jnp.fft.irfftn(fused, s=x.shape).astype(jnp.float32)
    if field.parity == "bitwise":
        out[f"inv_bitwise_{name}"] = bool(np.array_equal(np.asarray(back), np.asarray(ref)))
    out[f"roundtrip_close_{name}"] = bool(
        np.allclose(np.asarray(back), x, atol=1e-5 * np.abs(x).max())
    )

# --- cross-mesh spectrum layouts: a foreign (larger) writer mesh's padded
# layout and the true-extent layout both decode on THIS mesh
x = cases["2d_uneven"]
X_true = np.fft.rfftn(x).astype(np.complex64)
X_foreign = np.pad(X_true, [(0, 0), (0, 7)])  # some other mesh's transit pad
fld = ShardedField.shard(x)
out["cross_mesh_irfftn"] = all(
    bool(
        np.allclose(
            np.asarray(pencil_irfftn(spec, x.shape, fld.mesh, fld.axis_name)),
            np.fft.irfftn(X_true, s=x.shape),
            atol=1e-5 * np.abs(x).max(),
        )
    )
    for spec in (X_true, X_foreign)
)

# --- overlapped (double-buffered) transposes are bitwise-neutral
x = cases["3d_uneven"]
X1 = pencil_rfftn(ShardedField.shard(x, overlap_chunks=1))
X2 = pencil_rfftn(ShardedField.shard(x, overlap_chunks=2))
X3 = pencil_rfftn(ShardedField.shard(x, overlap_chunks=3))
out["overlap_bitwise"] = bool(
    np.array_equal(np.asarray(X1), np.asarray(X2))
    and np.array_equal(np.asarray(X1), np.asarray(X3))
)

# --- FFCz blob parity: sharded compress == single-device compress, bytewise
f3 = (rng.standard_normal((32, 16, 12)) * 0.5 + 5.0).astype(np.float32).cumsum(axis=0)
cfgs = {
    "Delta_abs": FFCzConfig(
        E_rel=1e-3,
        Delta_rel=None,
        Delta_abs=float(np.abs(np.fft.fftn(f3)).max() * 1e-3),
    ),
    "pspec": FFCzConfig(E_rel=1e-3, Delta_rel=None, pspec_rel=1e-3, max_iters=1500),
}
for name, cfg in cfgs.items():
    c = FFCz(get_compressor("szlike"), cfg)
    blob_single = c.compress(f3)
    blob_sharded = c.compress(ShardedField.shard(f3))
    out[f"blob_bitwise_{name}"] = blob_single.to_bytes() == blob_sharded.to_bytes()
    out[f"converged_{name}"] = bool(blob_sharded.stats.converged)
    out[f"margins_ok_{name}"] = bool(
        blob_sharded.stats.spatial_margin >= 0 and blob_sharded.stats.frequency_margin >= 0
    )
    dec = c.decompress(blob_single)
    dec_sharded = c.decompress_sharded(blob_sharded)
    out[f"decompress_bitwise_{name}"] = bool(
        np.array_equal(np.asarray(dec_sharded.to_host()), dec)
    )

# uneven pow2 slabs: the blob PAYLOAD stays byte-identical; the pad-metadata
# tail records the decomposition and survives a wire round trip
f_up = (rng.standard_normal((4, 16, 12)) * 0.5 + 5.0).astype(np.float32).cumsum(axis=0)
c = FFCz(get_compressor("szlike"), FFCzConfig(E_rel=1e-3, Delta_rel=1e-3))
b_single = c.compress(f_up)
b_sh = c.compress(ShardedField.shard(f_up))
out["uneven_payload_bitwise"] = b_sh.payload_bytes() == b_single.to_bytes()

# pad-metadata section: (15, 14, 10) is non-divisible by every mesh size the
# matrix runs (15 %% 2 == 1, 15 %% 8 == 7), so the FFCP tail is always written
f_pm = (rng.standard_normal((15, 14, 10)) * 0.5 + 5.0).astype(np.float32).cumsum(axis=0)
f_pm_sh = ShardedField.shard(f_pm)
b_pm = c.compress(f_pm_sh)
out["uneven_pad_meta"] = (
    b_pm.pad_meta is not None
    and b_pm.pad_meta.n_dev == n_dev
    and tuple(b_pm.pad_meta.padded_shape) == f_pm_sh.padded_shape
)
from repro.core.ffcz import FFCzBlob
b_rt = FFCzBlob.from_bytes(b_pm.to_bytes())
out["uneven_pad_meta_wire"] = b_rt.pad_meta == b_pm.pad_meta and bool(
    np.array_equal(c.decompress(b_rt), c.decompress(b_pm))
)

# 2-D field through the full codec as well (half axis is the sharded one)
f2 = (rng.standard_normal((32, 62)) * 0.1).astype(np.float32).cumsum(axis=1)
c = FFCz(get_compressor("zfplike"), FFCzConfig(E_rel=1e-3, Delta_rel=1e-3))
out["blob_bitwise_2d"] = c.compress(f2).to_bytes() == c.compress(ShardedField.shard(f2)).to_bytes()

# "bound"-class shapes (non-power-of-two c2c axes, uneven slabs): outside the
# bitwise contract but the dual bounds must hold exactly, and the blob must
# stay decodable to a mesh-resident field
f4 = (rng.standard_normal((30, 14, 10)) * 0.3 + 4.0).astype(np.float32).cumsum(axis=2)
c = FFCz(get_compressor("szlike"), FFCzConfig(E_rel=1e-3, Delta_rel=1e-3))
blob_ns = c.compress(ShardedField.shard(f4))
out["bound_class_bounds_hold"] = bool(
    blob_ns.stats.spatial_margin >= 0 and blob_ns.stats.frequency_margin >= 0
)
out["bound_class_decompress_bitwise"] = bool(
    np.array_equal(np.asarray(c.decompress_sharded(blob_ns).to_host()), c.decompress(blob_ns))
)

# acceptance shape class: non-power-of-two axes at realistic scale, tight
# pointwise-POCS-exercising Delta; compress+decompress with both bounds held
f5 = (rng.standard_normal((96, 80, 56)) * 0.5 + 5.0).astype(np.float32).cumsum(axis=0)
c = FFCz(get_compressor("szlike"), FFCzConfig(E_rel=1e-3, Delta_rel=2e-5, max_iters=400))
blob5 = c.compress(ShardedField.shard(f5))
out["accept_96_80_56"] = bool(
    blob5.stats.spatial_margin >= 0 and blob5.stats.frequency_margin >= 0
)
dec5 = c.decompress(blob5)
d5 = np.fft.rfftn(dec5.astype(np.float64) - f5.astype(np.float64))
out["accept_bounds_recheck"] = bool(
    np.abs(dec5.astype(np.float64) - f5).max() <= blob5.E
    and max(np.abs(d5.real).max(), np.abs(d5.imag).max()) <= blob5.Delta_scalar
)

# --- sharded power spectrum: same shells to float tolerance (metric, not bound)
for name, fld in (("", f3), ("_uneven", f4)):
    k_ref, p_ref = power_spectrum(fld)
    k_sh, p_sh = power_spectrum(ShardedField.shard(fld))
    p_ref, p_sh = np.asarray(p_ref, np.float64), np.asarray(p_sh, np.float64)
    # shell 0 is the mean-normalized DC: ~0 by construction, cancellation noise
    out[f"pspec_shells_close{name}"] = bool(
        np.array_equal(np.asarray(k_ref), np.asarray(k_sh))
        and np.allclose(p_ref[1:], p_sh[1:], rtol=1e-4)
        and abs(p_sh[0]) <= 1e-6 * p_ref[1:].max()
    )

print("RESULTS:" + json.dumps(out))
"""


@pytest.fixture(scope="module", params=[2, 8])
def dist_results(request):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD_SCRIPT % request.param],
        capture_output=True,
        text=True,
        env=env,
        timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines() if ln.startswith("RESULTS:")][0]
    return json.loads(line[len("RESULTS:") :]), request.param


class TestPencilTransformParity:
    def test_mesh_size(self, dist_results):
        results, n_dev = dist_results
        assert results["n_dev"] == n_dev

    def test_parity_classification(self, dist_results):
        results, _ = dist_results
        assert results["parity_class_3d"] == "bitwise"
        assert results["parity_class_3d_uneven_pow2"] == "bitwise"
        assert results["parity_class_3d_uneven"] == "bound"
        assert results["parity_class_2d_uneven"] == "bound"  # 30 not a pow2
        assert results["parity_class_2d_uneven_pow2"] == "bitwise"  # axis 0 = 32

    def test_rfftn_bitwise_equals_fused(self, dist_results):
        """The FORWARD transform is bitwise for every shape class: padding
        is zeros-only and the per-axis passes run at true lengths."""
        results, _ = dist_results
        for name in _TRANSFORM_CASES:
            assert results[f"fwd_bitwise_{name}"], name

    def test_irfftn_bitwise_equals_fused(self, dist_results):
        """The INVERSE is bitwise exactly on "bitwise"-class shapes."""
        results, _ = dist_results
        assert results["inv_bitwise_3d"]
        assert results["inv_bitwise_2d"]
        assert results["inv_bitwise_3d_uneven_pow2"]
        assert results["inv_bitwise_2d_uneven_pow2"]

    def test_roundtrip_recovers_field(self, dist_results):
        results, _ = dist_results
        for name in _TRANSFORM_CASES:
            assert results[f"roundtrip_close_{name}"], name

    def test_overlapped_transposes_bitwise_neutral(self, dist_results):
        results, _ = dist_results
        assert results["overlap_bitwise"]

    def test_cross_mesh_spectrum_layouts_decode(self, dist_results):
        results, _ = dist_results
        assert results["cross_mesh_irfftn"]


class TestShardedCompressParity:
    def test_delta_abs_blob_bitwise(self, dist_results):
        results, _ = dist_results
        assert results["blob_bitwise_Delta_abs"]
        assert results["converged_Delta_abs"] and results["margins_ok_Delta_abs"]

    def test_pspec_blob_bitwise(self, dist_results):
        results, _ = dist_results
        assert results["blob_bitwise_pspec"]
        assert results["converged_pspec"] and results["margins_ok_pspec"]

    def test_2d_blob_bitwise(self, dist_results):
        results, _ = dist_results
        assert results["blob_bitwise_2d"]

    def test_decompress_sharded_bitwise(self, dist_results):
        results, _ = dist_results
        assert results["decompress_bitwise_Delta_abs"]
        assert results["decompress_bitwise_pspec"]

    def test_uneven_pow2_payload_bitwise_with_pad_meta(self, dist_results):
        """Uneven slabs of a pow2-class shape keep byte-identical payloads;
        the optional FFCP section records the decomposition."""
        results, _ = dist_results
        assert results["uneven_payload_bitwise"]
        assert results["uneven_pad_meta"]
        assert results["uneven_pad_meta_wire"]


class TestBoundClassShapes:
    def test_bounds_hold_outside_bitwise_contract(self, dist_results):
        results, _ = dist_results
        assert results["bound_class_bounds_hold"]

    def test_bound_class_blob_decodes_to_mesh(self, dist_results):
        results, _ = dist_results
        assert results["bound_class_decompress_bitwise"]

    def test_acceptance_shape_96_80_56(self, dist_results):
        """ISSUE 4 acceptance: FFCz.compress/decompress succeed on a
        slab-sharded non-power-of-two field at realistic scale with both
        bounds verified."""
        results, _ = dist_results
        assert results["accept_96_80_56"]
        assert results["accept_bounds_recheck"]


class TestShardedPowerSpectrum:
    def test_shells_match_gathered(self, dist_results):
        results, _ = dist_results
        assert results["pspec_shells_close"]
        assert results["pspec_shells_close_uneven"]


class TestShapeClassification:
    def test_rank_rejected(self):
        with pytest.raises(ValueError, match="rank"):
            classify_parity((128,), 2)
        with pytest.raises(ValueError, match="rank"):
            classify_parity((8, 8, 8, 8), 2)

    def test_degenerate_extent_rejected(self):
        with pytest.raises(ValueError, match="degenerate"):
            classify_parity((0, 8, 8), 2)

    def test_non_divisible_shapes_are_not_errors(self):
        """The PR 3 divisibility errors are a removed code path: any extent
        slab-decomposes (padded), classification only reflects parity."""
        assert classify_parity((30, 16, 12), 8) == "bound"  # 30 not pow2
        assert classify_parity((32, 12, 16), 8) == "bound"  # 12 not pow2
        assert classify_parity((4, 16, 12), 8) == "bitwise"  # uneven but pow2
        assert classify_parity((30, 48), 8) == "bound"  # 2-D, axis 0 not pow2

    def test_2d_c2c_axis_is_axis0_only(self):
        # 2-D: only axis 0 is a c2c pass; the last axis is r2c/c2r and
        # unconstrained (62 is not a power of two, 25 half columns uneven)
        assert classify_parity((32, 62), 8) == "bitwise"
        assert classify_parity((32, 48), 8) == "bitwise"

    def test_strict_bitwise_tri_state(self):
        # bitwise: accepted and classified
        assert validate_pencil_shape((32, 16, 12), 8) == "bitwise"
        assert validate_pencil_shape((4, 16, 12), 8) == "bitwise"
        # bound: error under strict, accepted (and classified) with opt-out
        with pytest.raises(ValueError, match="power of two"):
            validate_pencil_shape((24, 16, 12), 8)
        with pytest.raises(ValueError, match="power of two"):
            validate_pencil_shape((32, 24, 12), 8)
        assert validate_pencil_shape((24, 24, 10), 8, strict_bitwise=False) == "bound"
        # error: raised regardless of strictness
        with pytest.raises(ValueError, match="rank"):
            validate_pencil_shape((128,), 8, strict_bitwise=False)

    def test_last_axis_unconstrained(self):
        # the c2r axis scale sits inside one final pass either way: any
        # length is bitwise-safe (12 and 15 are not powers of two)
        assert validate_pencil_shape((32, 16, 12), 8) == "bitwise"
        assert validate_pencil_shape((32, 16, 15), 8) == "bitwise"

    def test_local_freq_shape(self):
        assert local_freq_shape((32, 16, 12), 8) == (4, 16, 7)
        assert local_freq_shape((32, 62), 8) == (32, 4)
        # uneven: slab rows and half columns round up
        assert local_freq_shape((30, 14, 10), 8) == (4, 14, 6)
        assert local_freq_shape((30, 48), 8) == (30, 4)  # H=25 -> ceil(25/8)=4

    def test_padded_shapes(self):
        assert padded_spatial_shape((30, 14, 10), 8) == (32, 14, 10)
        assert padded_spatial_shape((32, 16, 12), 8) == (32, 16, 12)
        assert padded_freq_shape((30, 14, 10), 8) == (32, 14, 6)
        assert padded_freq_shape((30, 48), 8) == (30, 32)  # H=25 -> 32
