"""Over-relaxed POCS (§Perf FFCz-iter F2): same guarantees, fewer iterations."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pocs import alternating_projection


def _feasible(eps, E, Delta, tol=1e-3):
    eps = np.asarray(eps, dtype=np.float64)
    d = np.fft.fftn(eps)
    return np.all(np.abs(eps) <= np.asarray(E) * (1 + tol)) and np.all(
        np.maximum(np.abs(d.real), np.abs(d.imag)) <= np.asarray(Delta) * (1 + tol)
    )


class TestRelaxedPOCS:
    @pytest.mark.parametrize("relax", [1.0, 1.3, 1.6])
    def test_feasibility_preserved(self, relax, rng):
        E = 0.1
        eps0 = np.clip(rng.standard_normal((32, 32)) * 0.06, -E, E).astype(np.float32)
        Delta = 0.4 * np.abs(np.fft.fftn(eps0)).max()
        res = alternating_projection(jnp.asarray(eps0), E, Delta, max_iters=1000, relax=relax)
        assert bool(res.converged)
        assert _feasible(res.eps, E, Delta)

    def test_relax_reduces_iterations_hard_case(self, rng):
        """Pointwise near-tangential bounds: the regime the paper flags as
        slow; over-relaxation must not be slower and typically collapses the
        count by orders of magnitude."""
        E = 0.01
        eps0 = np.clip(rng.standard_normal(4096) * 0.006, -E, E).astype(np.float32)
        d0 = np.abs(np.fft.fft(eps0))
        Delta = np.maximum(0.3 * d0, 0.02 * d0.max()).astype(np.float32)
        r_plain = alternating_projection(jnp.asarray(eps0), E, jnp.asarray(Delta), max_iters=800, relax=1.0)
        r_relax = alternating_projection(jnp.asarray(eps0), E, jnp.asarray(Delta), max_iters=800, relax=1.3)
        assert _feasible(r_relax.eps, E, Delta, tol=1e-2)
        assert int(r_relax.iterations) <= int(r_plain.iterations)

    def test_edit_identity_still_holds(self, rng):
        E = 0.1
        eps0 = np.clip(rng.standard_normal(256) * 0.05, -E, E).astype(np.float32)
        Delta = 0.5 * np.abs(np.fft.fft(eps0)).max()
        res = alternating_projection(jnp.asarray(eps0), E, Delta, max_iters=500, relax=1.3)
        recon = eps0 + np.fft.irfft(np.asarray(res.freq_edits), n=eps0.size) + np.asarray(res.spat_edits)
        assert np.abs(recon - np.asarray(res.eps)).max() < 1e-4
