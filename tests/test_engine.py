"""CorrectionEngine: backend parity (incl. shard_map on a multi-device CPU
mesh), engine-vs-legacy golden compression stats, plan-stage spectrum
laziness, and the versioned FFCzBlob wire format (legacy blob fixture)."""

import json
import os
import struct
import subprocess
import sys

import numpy as np
import pytest

from repro.compressors import get_compressor
from repro.core.engine import CorrectionEngine, default_engine
from repro.core.ffcz import FFCz, FFCzBlob, FFCzConfig

_DATA = os.path.join(os.path.dirname(__file__), "data")

# ---------------------------------------------------------------------------
# sharded backend parity: >= 2 fake CPU devices, so a subprocess (XLA_FLAGS
# must be set before jax import — same pattern as tests/test_distributed.py)

_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import json
import jax
import numpy as np
from repro.core.engine import CorrectionEngine

rng = np.random.default_rng(7)
# block counts 5 + 3 + 1 = 9: odd total exercises the sharded backend's
# pad-to-axis-multiple path
tensors = [
    (rng.standard_normal(2500) * 0.02).astype(np.float32),
    (rng.standard_normal((32, 48)) * 0.01).astype(np.float32),
    (rng.standard_normal(100) * 0.01).astype(np.float32),
]
E, D = [0.03, 0.02, 0.05], [0.4, 0.5, 0.2]

eng_b = CorrectionEngine("batched")
eng_s = CorrectionEngine("sharded")
out = {"n_dev": len(jax.devices())}

cb, eb, sb = eng_b.correct(tensors, E, D, block=512, max_iters=50, return_edits=True)
cs, es, ss = eng_s.correct(tensors, E, D, block=512, max_iters=50, return_edits=True)

out["corrected_bitwise"] = all(
    np.array_equal(np.asarray(a), np.asarray(b)) for a, b in zip(cb, cs)
)
out["edits_bitwise"] = all(
    np.array_equal(np.asarray(s1), np.asarray(s2)) and np.array_equal(np.asarray(f1), np.asarray(f2))
    for (s1, f1), (s2, f2) in zip(eb, es)
)
out["iters_equal"] = bool(np.array_equal(np.asarray(sb.iterations), np.asarray(ss.iterations)))
out["block_stats_equal"] = bool(
    np.array_equal(np.asarray(sb.block_iterations), np.asarray(ss.block_iterations))
    and np.array_equal(np.asarray(sb.block_converged), np.asarray(ss.block_converged))
)
out["n_blocks"] = int(np.asarray(sb.block_iterations).shape[0])
out["all_converged"] = bool(np.asarray(sb.converged).all())
print("RESULTS:" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def sharded_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SHARDED_SCRIPT], capture_output=True, text=True, env=env, timeout=600
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULTS:")][0]
    return json.loads(line[len("RESULTS:"):])


class TestShardedBackend:
    def test_runs_on_multi_device_mesh(self, sharded_results):
        assert sharded_results["n_dev"] == 2
        assert sharded_results["n_blocks"] == 9  # odd: pad path exercised

    def test_bit_identical_to_batched(self, sharded_results):
        assert sharded_results["corrected_bitwise"]
        assert sharded_results["edits_bitwise"]

    def test_stats_identical_to_batched(self, sharded_results):
        assert sharded_results["iters_equal"]
        assert sharded_results["block_stats_equal"]
        assert sharded_results["all_converged"]

    def test_sharded_requires_mesh_arg(self):
        from repro.core.blockwise import correct_batch

        with pytest.raises(ValueError, match="mesh"):
            correct_batch([np.zeros(8, np.float32)], 0.1, 0.1, backend="sharded")


class TestLocalBackendParity:
    def test_local_matches_batched(self, rng):
        tensors = [
            (rng.standard_normal(1200) * 0.02).astype(np.float32),
            (rng.standard_normal((16, 40)) * 0.01).astype(np.float32),
        ]
        E, D = [0.03, 0.02], [0.4, 0.5]
        cb, sb = CorrectionEngine("batched").correct(tensors, E, D, block=256, max_iters=50)
        cl, sl = CorrectionEngine("local").correct(tensors, E, D, block=256, max_iters=50)
        for a, b in zip(cb, cl):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        assert np.array_equal(np.asarray(sb.iterations), np.asarray(sl.iterations))


# ---------------------------------------------------------------------------
# engine compression parity vs the pre-refactor host-numpy FFCz pipeline.
# Golden stats recorded from the pre-engine FFCz.compress on this corpus.


@pytest.fixture(scope="module")
def nyx():
    from repro.data.fields import make_field

    return make_field("nyx-like")[:32, :32, :32]


class TestGoldenCompressionParity:
    def test_delta_rel_stats_match_legacy_pipeline(self, nyx):
        """Scalar-Delta config: byte-identical to the pre-refactor pipeline
        (same bounds, same edits, same payload bytes; the only wire change
        is the 5-byte magic+version header)."""
        c = FFCz(get_compressor("szlike"), FFCzConfig(E_rel=1e-3, Delta_rel=1e-3, max_iters=1000))
        blob = c.compress(nyx)
        st = blob.stats
        assert int(st.iterations) == 2 and st.converged
        assert st.n_active_spatial == 3
        assert st.n_active_frequency == 2
        assert st.base_bytes == 25571
        assert st.edit_bytes == 229
        assert blob.E == pytest.approx(0.38016030192375183, rel=1e-12)
        assert blob.Delta_scalar == pytest.approx(116.1599349975586, rel=1e-12)
        # pre-refactor blob was 25873 bytes; +5 = magic + version byte
        assert blob.nbytes() == 25873 + 5

    def test_pspec_stats_match_legacy_pipeline(self, nyx):
        """pspec config: equal bounds and active sets vs the pre-refactor
        pipeline.  The Delta_k grid is now built from a device (float32)
        rfft rather than a host float64 one, so grid values may differ at
        float32-rounding level (~1e-7 relative) — active counts and margins
        are unchanged; payload bytes may shift by a few quantization codes."""
        cfg = FFCzConfig(E_rel=1e-3, Delta_rel=None, pspec_rel=1e-3, max_iters=1500)
        c = FFCz(get_compressor("szlike"), cfg)
        blob = c.compress(nyx)
        st = blob.stats
        assert int(st.iterations) == 2 and st.converged
        assert st.n_active_spatial == 0
        assert st.n_active_frequency == 17407
        assert st.base_bytes == 25571
        assert blob.E == pytest.approx(0.38016030192375183, rel=1e-12)
        assert st.spatial_margin == pytest.approx(0.37088, abs=1e-4)
        assert st.spatial_margin >= 0 and st.frequency_margin >= 0


# ---------------------------------------------------------------------------
# plan stage computes only the spectra it consumes (satellite: skip the
# wasted forward rfftn under Delta_abs)


class TestPlanSpectrumLaziness:
    def _count_rfftn(self, monkeypatch):
        import jax.numpy as jnp

        calls = {"n": 0}
        real = jnp.fft.rfftn

        def counting(*a, **k):
            calls["n"] += 1
            return real(*a, **k)

        monkeypatch.setattr(jnp.fft, "rfftn", counting)
        return calls

    def test_delta_abs_plan_skips_forward_fft(self, rng, monkeypatch):
        calls = self._count_rfftn(monkeypatch)
        x = rng.standard_normal((32, 32)).astype(np.float32)
        default_engine().plan_field(x, FFCzConfig(E_rel=1e-3, Delta_rel=None, Delta_abs=0.5))
        assert calls["n"] == 0

    def test_delta_rel_plan_computes_forward_fft(self, rng, monkeypatch):
        calls = self._count_rfftn(monkeypatch)
        x = rng.standard_normal((32, 32)).astype(np.float32)
        plan = default_engine().plan_field(x, FFCzConfig(E_rel=1e-3, Delta_rel=1e-3))
        assert calls["n"] == 1 and plan.Delta > 0

    def test_delta_abs_end_to_end(self, rng):
        x = rng.standard_normal((48, 32)).astype(np.float32).cumsum(axis=0)
        cfg = FFCzConfig(E_rel=1e-3, Delta_rel=None, Delta_abs=float(np.abs(np.fft.fftn(x)).max() * 1e-3))
        c = FFCz(get_compressor("zfplike"), cfg)
        _, blob = c.roundtrip(x)
        assert blob.stats.spatial_margin >= 0 and blob.stats.frequency_margin >= 0


# ---------------------------------------------------------------------------
# versioned wire format + legacy (v0, magic-less) blob fixture


class TestBlobWireFormat:
    def test_v1_magic_and_version(self, nyx):
        c = FFCz(get_compressor("szlike"), FFCzConfig(E_rel=1e-3, Delta_rel=1e-3))
        _, blob = c.roundtrip(nyx)
        raw = blob.to_bytes()
        assert raw[:4] == b"FFCZ" and raw[4] == 1
        back = FFCzBlob.from_bytes(raw)
        assert back.shape == blob.shape and back.base_blob == blob.base_blob

    def test_truncated_raises(self, nyx):
        c = FFCz(get_compressor("szlike"), FFCzConfig(E_rel=1e-3, Delta_rel=1e-3))
        raw = c.compress(nyx).to_bytes()
        for cut in (3, 4, 20, len(raw) - 1):
            with pytest.raises(ValueError):
                FFCzBlob.from_bytes(raw[:cut])

    def test_foreign_bytes_raise(self):
        for junk in (b"", b"junk", b"\x00" * 64, os.urandom(256)):
            with pytest.raises(ValueError):
                FFCzBlob.from_bytes(junk)

    def test_unknown_version_raises(self, nyx):
        c = FFCz(get_compressor("szlike"), FFCzConfig(E_rel=1e-3, Delta_rel=1e-3))
        raw = bytearray(c.compress(nyx).to_bytes())
        raw[4] = 9
        with pytest.raises(ValueError, match="version"):
            FFCzBlob.from_bytes(bytes(raw))

    def test_golden_legacy_v0_blob_roundtrip(self):
        """A checked-in magic-less blob written by the pre-version wire
        format must decode bit-identically to its recorded reconstruction."""
        data = open(os.path.join(_DATA, "legacy_blob_v0.bin"), "rb").read()
        assert data[:4] != b"FFCZ"  # genuinely magic-less
        blob = FFCzBlob.from_bytes(data)
        x = np.load(os.path.join(_DATA, "legacy_blob_v0_input.npy"))
        expected = np.load(os.path.join(_DATA, "legacy_blob_v0_output.npy"))
        c = FFCz(get_compressor("szlike"), FFCzConfig(E_rel=1e-3, Delta_rel=1e-3))
        got = c.decompress(blob)
        assert np.array_equal(got, expected)
        # and the legacy guarantee still holds against the original field
        assert np.abs(got - x).max() <= blob.E * (1 + 1e-9)

    def test_rewritten_legacy_blob_gains_magic(self):
        data = open(os.path.join(_DATA, "legacy_blob_v0.bin"), "rb").read()
        blob = FFCzBlob.from_bytes(data)
        raw = blob.to_bytes()
        assert raw[:4] == b"FFCZ" and len(raw) == len(data) + 5
        assert np.array_equal(
            struct.unpack_from("<dd", raw, 5), struct.unpack_from("<dd", data, 0)
        )

    def test_golden_uneven_v1_blob_with_pad_metadata(self):
        """A checked-in v1 blob written from an uneven (15, 14, 10)
        ShardedField on an 8-way mesh: its FFCP pad-metadata section must
        parse, survive a rewrite byte-exactly, and decode bit-identically to
        the recorded reconstruction — with both stored bounds holding."""
        data = open(os.path.join(_DATA, "uneven_v1_blob.bin"), "rb").read()
        blob = FFCzBlob.from_bytes(data)
        assert blob.pad_meta is not None
        assert blob.pad_meta.n_dev == 8
        assert blob.pad_meta.padded_shape == (16, 14, 10)
        assert blob.shape == (15, 14, 10)
        assert blob.to_bytes() == data  # decode -> re-encode is stable
        x = np.load(os.path.join(_DATA, "uneven_v1_input.npy"))
        expected = np.load(os.path.join(_DATA, "uneven_v1_output.npy"))
        c = FFCz(get_compressor("szlike"), FFCzConfig(E_rel=1e-3, Delta_rel=1e-3))
        got = c.decompress(blob)
        assert np.array_equal(got, expected)
        eps = got.astype(np.float64) - x.astype(np.float64)
        assert np.abs(eps).max() <= blob.E
        d = np.fft.rfftn(eps)
        assert max(np.abs(d.real).max(), np.abs(d.imag).max()) <= blob.Delta_scalar

    def test_golden_padfree_v1_blob_still_decodes_byte_exactly(self):
        """The pad-free v1 fixture (same field, single-device writer) has no
        FFCP tail and must keep decoding byte-exactly now that the parser
        sniffs for one."""
        data = open(os.path.join(_DATA, "padfree_v1_blob.bin"), "rb").read()
        blob = FFCzBlob.from_bytes(data)
        assert blob.pad_meta is None
        assert blob.to_bytes() == data
        expected = np.load(os.path.join(_DATA, "padfree_v1_output.npy"))
        c = FFCz(get_compressor("szlike"), FFCzConfig(E_rel=1e-3, Delta_rel=1e-3))
        assert np.array_equal(c.decompress(blob), expected)

    def test_pad_metadata_tail_corruption_raises(self):
        data = open(os.path.join(_DATA, "uneven_v1_blob.bin"), "rb").read()
        for junk in (data + b"x", data[:-1]):
            with pytest.raises(ValueError):
                FFCzBlob.from_bytes(junk)
        # foreign (non-FFCP) tail on a pad-free blob is corruption too
        clean = open(os.path.join(_DATA, "padfree_v1_blob.bin"), "rb").read()
        with pytest.raises(ValueError, match="pad-metadata|corrupt"):
            FFCzBlob.from_bytes(clean + b"JUNKJUNKJUNK")
