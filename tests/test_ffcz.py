"""End-to-end FFCz codec: dual-domain guarantees, serialization, edits."""

import dataclasses

import numpy as np
import pytest

from repro.compressors import get_compressor
from repro.core.edits import decode_edits, encode_edits
from repro.core.ffcz import FFCz, FFCzBlob, FFCzConfig
from repro.data.fields import make_field


@pytest.fixture(scope="module")
def nyx():
    return make_field("nyx-like")[:32, :32, :32]


BASES = ["szlike", "zfplike", "sperrlike"]


class TestDualDomainGuarantee:
    @pytest.mark.parametrize("base", BASES)
    def test_scalar_bounds_hold(self, base, nyx):
        c = FFCz(get_compressor(base), FFCzConfig(E_rel=1e-3, Delta_rel=1e-3, max_iters=1000))
        _, blob = c.roundtrip(nyx)
        st = blob.stats
        assert st.spatial_margin >= 0, st
        assert st.frequency_margin >= 0, st

    def test_pspec_bounds_hold(self, nyx):
        cfg = FFCzConfig(E_rel=1e-3, Delta_rel=None, pspec_rel=1e-3, max_iters=1500)
        c = FFCz(get_compressor("szlike"), cfg)
        xh, blob = c.roundtrip(nyx)
        assert blob.stats.spatial_margin >= 0
        assert blob.stats.frequency_margin >= 0
        # the actual guarantee of Observation 4: relative power-spectrum error
        from repro.core.spectrum import power_spectrum_relative_error

        _, rel = power_spectrum_relative_error(xh, nyx)
        assert np.abs(rel[1:]).max() <= 1e-3 * 1.05

    @pytest.mark.parametrize("dims", [(2048,), (64, 48)])
    def test_other_ranks(self, dims):
        rng = np.random.default_rng(3)
        x = rng.standard_normal(dims).astype(np.float32).cumsum(axis=0)
        c = FFCz(get_compressor("zfplike"), FFCzConfig(E_rel=1e-3, Delta_rel=1e-3, max_iters=500))
        _, blob = c.roundtrip(x)
        assert blob.stats.spatial_margin >= 0 and blob.stats.frequency_margin >= 0


class TestSerialization:
    def test_blob_roundtrip(self, nyx):
        c = FFCz(get_compressor("szlike"), FFCzConfig(E_rel=1e-3, Delta_rel=1e-3))
        xh, blob = c.roundtrip(nyx)
        blob2 = FFCzBlob.from_bytes(blob.to_bytes())
        xh2 = c.decompress(blob2)
        assert np.array_equal(xh, xh2)

    def test_edits_roundtrip_sparse(self, rng):
        edits = np.zeros(10_000)
        idx = rng.integers(0, 10_000, 50)
        edits[idx] = rng.standard_normal(50) * 0.01
        enc = encode_edits(edits, 0.05, m=16)
        back = decode_edits(enc, 0.05)
        assert np.abs(back - edits).max() <= 0.05 * 2.0**-16 * (1 + 1e-9)
        assert enc.n_active <= 50

    def test_edits_roundtrip_complex(self, rng):
        edits = (rng.standard_normal(500) + 1j * rng.standard_normal(500)) * 0.01
        enc = encode_edits(edits, 0.2, m=16)
        back = decode_edits(enc, 0.2)
        assert np.abs(back - edits).max() <= 0.2 * 2.0**-16 * np.sqrt(2) * (1 + 1e-9)


class TestEditsAreSparse:
    def test_edit_overhead_modest(self, nyx):
        """Paper Obs. 1: edits cost a modest fraction on top of the base."""
        c = FFCz(get_compressor("szlike"), FFCzConfig(E_rel=1e-3, Delta_rel=1e-2, max_iters=500))
        _, blob = c.roundtrip(nyx)
        st = blob.stats
        n = nyx.size
        assert st.n_active_spatial < n * 0.2
        # flags dominate the floor: edit bytes should be well under raw data
        assert st.edit_bytes < nyx.nbytes / 2


class TestConfigValidation:
    def test_requires_exactly_one_spatial(self):
        with pytest.raises(ValueError):
            FFCzConfig(E_abs=1.0, E_rel=1.0, Delta_rel=1e-3, Delta_abs=None)

    def test_requires_exactly_one_frequency(self):
        with pytest.raises(ValueError):
            FFCzConfig(E_rel=1e-3, Delta_rel=1e-3, pspec_rel=1e-3)

    def test_identity_base_zero_iterations(self, nyx):
        c = FFCz(get_compressor("identity"), FFCzConfig(E_rel=1e-3, Delta_rel=1e-3))
        _, blob = c.roundtrip(nyx)
        assert blob.stats.iterations == 1  # converges at the first check
        assert blob.stats.n_active_spatial == 0


class TestNonConvergenceSurfacing:
    def test_too_tight_bound_pair_reports_violations(self, rng):
        """A starved POCS budget on a too-tight frequency bound must not fail
        silently: stats carry converged=False plus the pair-weighted count of
        components still outside the shrunk f-cube after the polish."""
        x = rng.standard_normal((24, 24)).astype(np.float32).cumsum(axis=0)
        c = FFCz(get_compressor("szlike"), FFCzConfig(E_rel=1e-3, Delta_rel=1e-7, max_iters=1))
        _, blob = c.roundtrip(x)
        st = blob.stats
        assert st.converged is False
        assert st.final_violations > 0
        # the spatial bound still holds by construction (final state is
        # inside the s-cube); only the frequency bound is violated
        assert st.spatial_margin >= 0

    def test_converged_run_reports_zero_violations(self, nyx):
        c = FFCz(get_compressor("szlike"), FFCzConfig(E_rel=1e-3, Delta_rel=1e-3, max_iters=1000))
        _, blob = c.roundtrip(nyx)
        assert blob.stats.converged is True
        assert blob.stats.final_violations == 0
