"""Pallas kernel sweeps: shapes x dtypes against the pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.block_transform.ops import block_transform_quantize
from repro.kernels.block_transform.ref import block_transform_quantize_ref
from repro.kernels.fcube.ops import project_fcube_fused
from repro.kernels.fcube.ref import project_fcube_fused_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.quantize.ops import quantize_edits
from repro.kernels.quantize.ref import quantize_edits_ref
from repro.kernels.scube.ops import project_scube_fused
from repro.kernels.scube.ref import project_scube_fused_ref

SHAPES = [(64,), (100,), (256, 128), (33, 17, 5)]


class TestFCubeKernel:
    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("pointwise", [False, True])
    def test_matches_ref(self, shape, pointwise, rng):
        d = (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(np.complex64)
        Delta = (np.abs(d.real) * 0.8 + 0.05).astype(np.float32) if pointwise else np.float32(0.7)
        c1, e1, v1 = project_fcube_fused(jnp.asarray(d), jnp.asarray(Delta))
        c2, e2, v2 = project_fcube_fused_ref(jnp.asarray(d), jnp.asarray(Delta))
        np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=1e-6, atol=1e-7)
        assert int(v1) == int(v2)


class TestSCubeKernel:
    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("dtype", [np.float32, np.float64])
    def test_matches_ref(self, shape, dtype, rng):
        x = rng.standard_normal(shape).astype(dtype)
        c1, e1 = project_scube_fused(jnp.asarray(x), 0.4)
        c2, e2 = project_scube_fused_ref(jnp.asarray(x), 0.4)
        np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=1e-6, atol=1e-7)

    def test_pointwise_E(self, rng):
        x = rng.standard_normal(300).astype(np.float32)
        E = (np.abs(rng.standard_normal(300)) * 0.3 + 0.05).astype(np.float32)
        c1, e1 = project_scube_fused(jnp.asarray(x), jnp.asarray(E))
        c2, e2 = project_scube_fused_ref(jnp.asarray(x), jnp.asarray(E))
        np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), rtol=1e-6)


class TestQuantizeKernel:
    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("m", [8, 16])
    def test_matches_ref(self, shape, m, rng):
        v = rng.standard_normal(shape).astype(np.float32)
        c1, f1 = quantize_edits(jnp.asarray(v), 0.5, m=m)
        c2, f2 = quantize_edits_ref(jnp.asarray(v), 0.5, m=m)
        assert np.array_equal(np.asarray(c1), np.asarray(c2))
        assert np.array_equal(np.asarray(f1), np.asarray(f2))


class TestBlockTransformKernel:
    @pytest.mark.parametrize("nb", [1, 64, 777])
    @pytest.mark.parametrize("B", [64, 128])
    def test_matches_ref(self, nb, B, rng):
        blocks = rng.standard_normal((nb, B)).astype(np.float32)
        mat = np.linalg.qr(rng.standard_normal((B, B)))[0].astype(np.float32)
        c1 = block_transform_quantize(jnp.asarray(blocks), jnp.asarray(mat), 0.01)
        c2 = block_transform_quantize_ref(jnp.asarray(blocks), jnp.asarray(mat), 0.01)
        diff = np.abs(np.asarray(c1) - np.asarray(c2))
        assert (diff <= 1).all() and (diff > 0).mean() < 1e-3  # fp32 rint ties


class TestFlashAttentionKernel:
    @pytest.mark.parametrize(
        "b,hq,hkv,sq,sk,d",
        [
            (2, 4, 2, 128, 128, 64),
            (1, 2, 1, 256, 256, 128),
            (1, 4, 4, 1, 384, 64),  # decode
            (2, 8, 2, 100, 100, 64),  # unaligned
            (1, 2, 1, 100, 260, 64),  # suffix queries
            (1, 14, 2, 64, 512, 64),  # qwen-ish GQA
        ],
    )
    def test_matches_ref(self, b, hq, hkv, sq, sk, d, rng):
        q = rng.standard_normal((b, hq, sq, d)).astype(np.float32) * 0.5
        k = rng.standard_normal((b, hkv, sk, d)).astype(np.float32) * 0.5
        v = rng.standard_normal((b, hkv, sk, d)).astype(np.float32)
        o1 = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), block_q=128, block_k=128)
        o2 = attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=3e-5)

    def test_bf16(self, rng):
        q = jnp.asarray(rng.standard_normal((1, 4, 128, 64)), dtype=jnp.bfloat16)
        k = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), dtype=jnp.bfloat16)
        v = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), dtype=jnp.bfloat16)
        o1 = flash_attention(q, k, v)
        o2 = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32))
        assert np.abs(np.asarray(o1, dtype=np.float32) - np.asarray(o2)).max() < 0.03

    def test_rejects_sq_gt_sk(self, rng):
        q = jnp.zeros((1, 2, 16, 32))
        k = jnp.zeros((1, 2, 8, 32))
        with pytest.raises(ValueError):
            flash_attention(q, k, k)
