"""Pallas kernel sweeps: shapes x dtypes against the pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.block_transform.ops import block_transform_quantize
from repro.kernels.block_transform.ref import block_transform_quantize_ref
from repro.kernels.fcube.ops import project_fcube_fused
from repro.kernels.fcube.ref import project_fcube_fused_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.quantize.ops import quantize_edits
from repro.kernels.quantize.ref import quantize_edits_ref
from repro.kernels.rfft import ops as rfft_ops
from repro.kernels.rfft import ref as rfft_ref
from repro.kernels.scube.ops import project_scube_fused
from repro.kernels.scube.ref import project_scube_fused_ref

SHAPES = [(64,), (100,), (256, 128), (33, 17, 5)]


class TestFCubeKernel:
    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("pointwise", [False, True])
    def test_matches_ref(self, shape, pointwise, rng):
        d = (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(np.complex64)
        Delta = (np.abs(d.real) * 0.8 + 0.05).astype(np.float32) if pointwise else np.float32(0.7)
        c1, e1, v1 = project_fcube_fused(jnp.asarray(d), jnp.asarray(Delta))
        c2, e2, v2 = project_fcube_fused_ref(jnp.asarray(d), jnp.asarray(Delta))
        np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=1e-6, atol=1e-7)
        assert int(v1) == int(v2)


class TestSCubeKernel:
    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("dtype", [np.float32, np.float64])
    def test_matches_ref(self, shape, dtype, rng):
        x = rng.standard_normal(shape).astype(dtype)
        c1, e1 = project_scube_fused(jnp.asarray(x), 0.4)
        c2, e2 = project_scube_fused_ref(jnp.asarray(x), 0.4)
        np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=1e-6, atol=1e-7)

    def test_pointwise_E(self, rng):
        x = rng.standard_normal(300).astype(np.float32)
        E = (np.abs(rng.standard_normal(300)) * 0.3 + 0.05).astype(np.float32)
        c1, e1 = project_scube_fused(jnp.asarray(x), jnp.asarray(E))
        c2, e2 = project_scube_fused_ref(jnp.asarray(x), jnp.asarray(E))
        np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), rtol=1e-6)


class TestQuantizeKernel:
    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("m", [8, 16])
    def test_matches_ref(self, shape, m, rng):
        v = rng.standard_normal(shape).astype(np.float32)
        c1, f1 = quantize_edits(jnp.asarray(v), 0.5, m=m)
        c2, f2 = quantize_edits_ref(jnp.asarray(v), 0.5, m=m)
        assert np.array_equal(np.asarray(c1), np.asarray(c2))
        assert np.array_equal(np.asarray(f1), np.asarray(f2))


class TestBlockTransformKernel:
    @pytest.mark.parametrize("nb", [1, 64, 777])
    @pytest.mark.parametrize("B", [64, 128])
    def test_matches_ref(self, nb, B, rng):
        blocks = rng.standard_normal((nb, B)).astype(np.float32)
        mat = np.linalg.qr(rng.standard_normal((B, B)))[0].astype(np.float32)
        c1 = block_transform_quantize(jnp.asarray(blocks), jnp.asarray(mat), 0.01)
        c2 = block_transform_quantize_ref(jnp.asarray(blocks), jnp.asarray(mat), 0.01)
        diff = np.abs(np.asarray(c1) - np.asarray(c2))
        assert (diff <= 1).all() and (diff > 0).mean() < 1e-3  # fp32 rint ties


class TestFlashAttentionKernel:
    @pytest.mark.parametrize(
        "b,hq,hkv,sq,sk,d",
        [
            (2, 4, 2, 128, 128, 64),
            (1, 2, 1, 256, 256, 128),
            (1, 4, 4, 1, 384, 64),  # decode
            (2, 8, 2, 100, 100, 64),  # unaligned
            (1, 2, 1, 100, 260, 64),  # suffix queries
            (1, 14, 2, 64, 512, 64),  # qwen-ish GQA
        ],
    )
    def test_matches_ref(self, b, hq, hkv, sq, sk, d, rng):
        q = rng.standard_normal((b, hq, sq, d)).astype(np.float32) * 0.5
        k = rng.standard_normal((b, hkv, sk, d)).astype(np.float32) * 0.5
        v = rng.standard_normal((b, hkv, sk, d)).astype(np.float32)
        o1 = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), block_q=128, block_k=128)
        o2 = attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=3e-5)

    def test_bf16(self, rng):
        q = jnp.asarray(rng.standard_normal((1, 4, 128, 64)), dtype=jnp.bfloat16)
        k = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), dtype=jnp.bfloat16)
        v = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), dtype=jnp.bfloat16)
        o1 = flash_attention(q, k, v)
        o2 = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32))
        assert np.abs(np.asarray(o1, dtype=np.float32) - np.asarray(o2)).max() < 0.03

    def test_rejects_sq_gt_sk(self, rng):
        q = jnp.zeros((1, 2, 16, 32))
        k = jnp.zeros((1, 2, 8, 32))
        with pytest.raises(ValueError):
            flash_attention(q, k, k)


# shapes with an even last axis (the pack-trick domain); 1-D through 3-D
RFFT_SHAPES = [(64,), (100,), (16, 48), (31, 22), (12, 10, 8), (33, 17, 6)]


class TestPackedTransforms:
    @pytest.mark.parametrize("shape", RFFT_SHAPES)
    def test_packed_rfftn_matches_fft(self, shape, rng):
        x = rng.standard_normal(shape).astype(np.float32)
        X = np.asarray(rfft_ops.packed_rfftn(jnp.asarray(x)))
        want = np.fft.rfftn(x)
        scale = max(np.abs(want).max(), 1.0)
        np.testing.assert_allclose(X, want, atol=1e-5 * scale)
        # and the float64 numpy ref twin
        np.testing.assert_allclose(
            rfft_ref.packed_rfftn_ref(x.astype(np.float64)), want, atol=1e-6 * scale
        )

    @pytest.mark.parametrize("shape", RFFT_SHAPES)
    def test_packed_irfftn_matches_ifft(self, shape, rng):
        x = rng.standard_normal(shape).astype(np.float32)
        X = np.fft.rfftn(x).astype(np.complex64)
        out = np.asarray(rfft_ops.packed_irfftn(jnp.asarray(X), shape))
        np.testing.assert_allclose(out, x, atol=2e-6 * max(np.abs(x).max(), 1.0))
        np.testing.assert_allclose(rfft_ref.packed_irfftn_ref(X, shape), x, atol=1e-6)

    def test_packed_irfft_lastaxis_lines(self, rng):
        """The per-line C2R the distributed transform composes."""
        x = rng.standard_normal((7, 32)).astype(np.float32)
        X = np.fft.rfft(x, axis=-1).astype(np.complex64)
        out = np.asarray(rfft_ops.packed_irfft(jnp.asarray(X), 32))
        np.testing.assert_allclose(out, x, atol=2e-6)

    def test_twiddle_plan_registry_caches(self):
        a = rfft_ops.twiddle_plan(64, "float32")
        b = rfft_ops.twiddle_plan(64, "float32")
        assert a[0] is b[0]  # lru_cache hit: same host constant
        assert rfft_ops.twiddle_plan(64, "float64")[0] is not a[0]
        with pytest.raises(ValueError, match="even"):
            rfft_ops.twiddle_plan(33)

    def test_supports_packed(self):
        assert rfft_ops.supports_packed((16, 48))
        assert not rfft_ops.supports_packed((16, 47))
        assert not rfft_ops.supports_packed(())


class TestRfftFwdEpilogueKernel:
    @pytest.mark.parametrize("shape", [(48,), (12, 34), (6, 10, 16)])
    @pytest.mark.parametrize("pointwise", [False, True])
    def test_matches_ref(self, shape, pointwise, rng):
        from repro.core.cubes import rfft_pair_weights

        h = shape[:-1] + (shape[-1] // 2 + 1,)
        d = (rng.standard_normal(h) + 1j * rng.standard_normal(h)).astype(np.complex64)
        Delta = (np.abs(d.real) * 0.8 + 0.05).astype(np.float32) if pointwise else np.float32(0.7)
        w = np.broadcast_to(np.asarray(rfft_pair_weights(shape)), h)
        c1, e1, z1, v1 = rfft_ops.fwd_epilogue_fused(
            jnp.asarray(d), jnp.asarray(Delta), weight=jnp.asarray(w),
            check_tol=1e-5, check_slack=1e-4,
        )
        c2, e2, z2, v2 = rfft_ref.fwd_epilogue_ref(d, Delta, weight=w, check_tol=1e-5, check_slack=1e-4)
        np.testing.assert_allclose(np.asarray(c1), c2, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(e1), e2, rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(np.asarray(z1), z2, atol=1e-6)
        assert int(v1) == int(v2)

    def test_fused_z_completes_the_inverse(self, rng):
        """ifftn of the kernel's Z slice == irfftn of the clipped spectrum."""
        shape = (16, 32)
        x = rng.standard_normal(shape).astype(np.float32) * 0.1
        d = jnp.fft.rfftn(jnp.asarray(x))
        _, _, Z, _ = rfft_ops.fwd_epilogue_fused(d, 0.05)
        z = jnp.fft.ifftn(Z[..., : shape[-1] // 2])
        got, _ = rfft_ops.unpack_sclip_fused(z, jnp.asarray(np.float32(np.inf)), shape)
        clip = jnp.clip(d.real, -0.05, 0.05) + 1j * jnp.clip(d.imag, -0.05, 0.05)
        want = np.fft.irfftn(np.asarray(clip), s=shape, axes=(0, 1))
        np.testing.assert_allclose(np.asarray(got), want, atol=1e-6)


class TestUnpackSclipKernel:
    @pytest.mark.parametrize("pointwise", [False, True])
    def test_matches_ref(self, pointwise, rng):
        shape = (10, 64)
        z = (rng.standard_normal((10, 32)) + 1j * rng.standard_normal((10, 32))).astype(np.complex64)
        E = (np.abs(rng.standard_normal(shape)) * 0.5 + 0.1).astype(np.float32) if pointwise else np.float32(0.6)
        c1, d1 = rfft_ops.unpack_sclip_fused(jnp.asarray(z), jnp.asarray(E), shape)
        c2, d2 = rfft_ref.unpack_sclip_ref(z, E, shape)
        np.testing.assert_allclose(np.asarray(c1), c2, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(d1), d2, rtol=1e-6, atol=1e-7)

    def test_kernels_trace_under_x64(self, rng):
        """int32 violation sums must not promote under jax_enable_x64 (the
        store into the int32 out ref / loop carry fails at trace time)."""
        from repro.core.pocs import alternating_projection

        x = (rng.standard_normal((8, 16)) * 0.04).astype(np.float32)
        with jax.experimental.enable_x64():
            r = alternating_projection(jnp.asarray(x), 0.05, 0.4, max_iters=20, fft_impl="pallas")
            assert bool(r.converged)
            r = alternating_projection(jnp.asarray(x), 0.05, 0.4, max_iters=20, use_kernels=True)
            assert bool(r.converged)

    def test_vmap_lifts(self, rng):
        """The pencil backends vmap the fused epilogues; gate the batch rule."""
        z = (rng.standard_normal((3, 16)) + 1j * rng.standard_normal((3, 16))).astype(np.complex64)
        c, d = jax.vmap(lambda t: rfft_ops.unpack_sclip_fused(t, 0.4, (32,)))(jnp.asarray(z))
        for i in range(3):
            c2, d2 = rfft_ref.unpack_sclip_ref(z[i], np.float32(0.4), (32,))
            np.testing.assert_allclose(np.asarray(c)[i], c2, rtol=1e-6)
