"""Elastic re-planning: mesh factorization edge cases and pod-loss math."""

import numpy as np
import pytest

from repro.runtime.elastic import plan_mesh_shape, replan_mesh, survivors_after_pod_loss


class TestPlanMeshShape:
    def test_single_device(self):
        shape, axes = plan_mesh_shape(1)
        assert shape == (1, 1)
        assert axes == ("data", "model")

    @pytest.mark.parametrize("n", [2, 3, 5, 7, 11, 13, 127])
    def test_prime_counts_fall_back_to_pure_dp_or_full_tp(self, n):
        """A prime device count only factors as 1 x n or n x 1: the model
        degree is either n itself (if <= preferred) or collapses to 1."""
        (dp, mp), _ = plan_mesh_shape(n, preferred_model=16)
        assert dp * mp == n
        assert mp == (n if n <= 16 else 1)

    def test_preferred_larger_than_devices_clamps(self):
        (dp, mp), _ = plan_mesh_shape(8, preferred_model=64)
        assert (dp, mp) == (1, 8)

    def test_preferred_respected_when_divisible(self):
        (dp, mp), _ = plan_mesh_shape(64, preferred_model=16)
        assert (dp, mp) == (4, 16)

    def test_nondivisible_preferred_steps_down(self):
        # 24 % 16 != 0; the largest divisor <= 16 is 12
        (dp, mp), _ = plan_mesh_shape(24, preferred_model=16)
        assert (dp, mp) == (2, 12)

    @pytest.mark.parametrize("n", range(1, 65))
    @pytest.mark.parametrize("preferred", [1, 2, 16])
    def test_factorization_property(self, n, preferred):
        """mp * dp == n, mp <= preferred, and mp is the LARGEST such divisor."""
        (dp, mp), _ = plan_mesh_shape(n, preferred_model=preferred)
        assert dp * mp == n
        assert 1 <= mp <= preferred
        larger = [m for m in range(mp + 1, preferred + 1) if n % m == 0]
        assert not larger, f"planner picked mp={mp}, but {larger} also divide {n}"

    def test_replan_mesh_smoke(self):
        mesh = replan_mesh(1, preferred_model=4)
        assert mesh.devices.size == 1
        assert mesh.axis_names == ("data", "model")


class TestSurvivorsAfterPodLoss:
    def test_default_halves(self):
        assert survivors_after_pod_loss() == 256

    def test_no_loss_keeps_all(self):
        assert survivors_after_pod_loss(512, 4, 0) == 512

    def test_all_pods_lost(self):
        assert survivors_after_pod_loss(512, 4, 4) == 0

    @pytest.mark.parametrize("total,pods", [(512, 2), (512, 4), (96, 3), (8, 8)])
    def test_survivor_property(self, total, pods):
        """Survivors decrease linearly by total/pods per lost pod, stay
        non-negative, and always yield a plannable mesh factorization."""
        sizes = [survivors_after_pod_loss(total, pods, lost) for lost in range(pods + 1)]
        assert sizes[0] == total and sizes[-1] == 0
        steps = np.diff(sizes)
        assert np.all(steps == -(total // pods))
        for n in sizes[:-1]:
            (dp, mp), _ = plan_mesh_shape(n)
            assert dp * mp == n
