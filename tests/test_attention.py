"""Attention impl equivalence + KV-cache semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import attention_apply, attention_init, init_kv_cache

H, HKV, HD, D = 4, 2, 16, 64


@pytest.fixture(scope="module")
def params():
    return attention_init(jax.random.PRNGKey(0), D, H, HKV, HD, True, jnp.float32)


def _run(params, x, impl, **kw):
    o, c = attention_apply(params, x, n_heads=H, n_kv_heads=HKV, head_dim=HD, impl=impl, **kw)
    return np.asarray(o), c


class TestImplEquivalence:
    @pytest.mark.parametrize("s", [8, 37, 130, 1030])
    def test_three_impls_agree(self, s, params, rng):
        x = jnp.asarray(rng.standard_normal((2, s, D)), dtype=jnp.float32)
        naive, _ = _run(params, x, "naive")
        flash, _ = _run(params, x, "xla_flash")
        pallas, _ = _run(params, x, "pallas")
        np.testing.assert_allclose(naive, flash, atol=3e-5)
        np.testing.assert_allclose(naive, pallas, atol=3e-5)

    def test_causal_scheduling_identical(self, params, rng):
        x = jnp.asarray(rng.standard_normal((1, 700, D)), dtype=jnp.float32)
        a, _ = _run(params, x, "xla_flash", causal_scheduling=True)
        b, _ = _run(params, x, "xla_flash", causal_scheduling=False)
        np.testing.assert_allclose(a, b, atol=1e-6)

    def test_grad_through_causal_scheduling(self, params, rng):
        x = jnp.asarray(rng.standard_normal((1, 64, D)), dtype=jnp.float32)

        def f(p):
            o, _ = attention_apply(p, x, n_heads=H, n_kv_heads=HKV, head_dim=HD,
                                   impl="xla_flash", causal_scheduling=True)
            return jnp.sum(o * o)

        g = jax.grad(f)(params)
        assert all(np.isfinite(np.asarray(l)).all() for l in jax.tree.leaves(g))


class TestCacheSemantics:
    def test_incremental_matches_full(self, params, rng):
        x = jnp.asarray(rng.standard_normal((2, 21, D)), dtype=jnp.float32)
        full, _ = _run(params, x, "naive")
        cache = init_kv_cache(2, HKV, 32, HD, jnp.float32)
        outs = []
        for t in range(21):
            o, cache = _run(params, x[:, t : t + 1], "naive", cache=cache)
            outs.append(o)
        np.testing.assert_allclose(np.concatenate(outs, 1), full, atol=1e-5)

    def test_chunked_prefill_matches_full(self, params, rng):
        x = jnp.asarray(rng.standard_normal((1, 40, D)), dtype=jnp.float32)
        full, _ = _run(params, x, "xla_flash")
        cache = init_kv_cache(1, HKV, 40, HD, jnp.float32)
        o1, cache = _run(params, x[:, :25], "xla_flash", cache=cache)
        o2, cache = _run(params, x[:, 25:], "xla_flash", cache=cache)
        np.testing.assert_allclose(np.concatenate([o1, o2], 1), full, atol=3e-5)
        assert int(cache["pos"]) == 40

    def test_cross_attention(self, params, rng):
        x = jnp.asarray(rng.standard_normal((2, 5, D)), dtype=jnp.float32)
        k = jnp.asarray(rng.standard_normal((2, HKV, 9, HD)), dtype=jnp.float32)
        v = jnp.asarray(rng.standard_normal((2, HKV, 9, HD)), dtype=jnp.float32)
        o, c = attention_apply(
            params, x, n_heads=H, n_kv_heads=HKV, head_dim=HD, impl="naive", cross_kv=(k, v)
        )
        assert o.shape == (2, 5, D) and c is None
