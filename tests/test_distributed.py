"""True multi-device distribution semantics, in a subprocess with 8 fake
host devices (XLA_FLAGS must be set before jax import, so not in-process)."""

import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_smoke_config
from repro.launch.steps import make_train_step
from repro.models.model import build_model
from repro.optim.adamw import AdamW
from repro.optim.grad_compress import compressed_psum
from repro.sharding.rules import batch_pspec, cache_pspecs, param_pspecs, to_shardings

results = {}
mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = get_smoke_config("qwen2-0.5b")
bundle = build_model(cfg)
opt = AdamW(warmup_steps=2)

params = bundle.init(jax.random.PRNGKey(0))
p_spec = param_pspecs(jax.eval_shape(bundle.init, jax.random.PRNGKey(0)), mesh)
p_sh = to_shardings(p_spec, mesh)
opt_state = opt.init(params)
o_sh = to_shardings(opt.state_pspecs(p_spec), mesh)
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab)}
b_sh = to_shardings(batch_pspec(batch, mesh), mesh)

params = jax.device_put(params, p_sh)
opt_state = jax.device_put(opt_state, o_sh)
batch = jax.device_put(batch, b_sh)

step = jax.jit(make_train_step(bundle, opt), in_shardings=(p_sh, o_sh, b_sh))
with mesh:
    # distributed loss must equal single-device loss
    loss_dist = float(step(params, opt_state, batch)[2])
results["loss_dist"] = loss_dist

# single-device reference
params_1 = bundle.init(jax.random.PRNGKey(0))
loss_ref = float(bundle.loss(params_1, {"tokens": np.asarray(batch["tokens"])}))
results["loss_ref"] = loss_ref

# compressed integer all-reduce (shard_map collective)
x = jnp.asarray(np.random.default_rng(0).standard_normal(64), dtype=jnp.float32)
with mesh:
    out = compressed_psum(x, mesh, axis="data", bits=12, E_rel=1e-2)
results["psum_err"] = float(jnp.abs(out - x).max())
results["psum_bound"] = float(1e-2 * jnp.abs(x).max())

# decode step with sharded cache
cache = bundle.init_cache(8, 16)
c_sh = to_shardings(cache_pspecs(jax.eval_shape(lambda: bundle.init_cache(8, 16)), mesh), mesh)
cache = jax.device_put(cache, c_sh)
tok = jnp.zeros((8, 1), dtype=jnp.int32)
with mesh:
    logits, cache = jax.jit(bundle.decode, in_shardings=(p_sh, None, c_sh))(params, tok, cache)
results["decode_finite"] = bool(np.isfinite(np.asarray(logits, dtype=np.float32)).all())

print("RESULTS:" + json.dumps(results))
"""


@pytest.fixture(scope="module")
def dist_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True, env=env, timeout=600
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULTS:")][0]
    return json.loads(line[len("RESULTS:"):])


class TestDistributed:
    def test_distributed_loss_matches_single_device(self, dist_results):
        assert abs(dist_results["loss_dist"] - dist_results["loss_ref"]) < 5e-2

    def test_compressed_psum_bound(self, dist_results):
        # single participant => psum mean == dequantized value; error <= E
        assert dist_results["psum_err"] <= dist_results["psum_bound"] * 1.01

    def test_sharded_decode_runs(self, dist_results):
        assert dist_results["decode_finite"]
