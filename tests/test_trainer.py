"""Trainer fault tolerance: restart, failure injection, determinism, progress."""

import dataclasses

import numpy as np
import pytest

from repro.configs import CompressionConfig, get_smoke_config
from repro.runtime.elastic import plan_mesh_shape, survivors_after_pod_loss
from repro.runtime.trainer import SimulatedFailure, Trainer, TrainerConfig


def _run_cfg(td, **kw):
    base = dict(seq_len=32, global_batch=4, ckpt_dir=str(td), ckpt_every=5,
                ckpt_async=False, log_every=5)
    base.update(kw)
    return TrainerConfig(**base)


class TestFaultTolerance:
    def test_failure_then_restart_resumes(self, tmp_path):
        cfg = get_smoke_config("qwen2-0.5b")
        tr = Trainer(cfg, _run_cfg(tmp_path, inject_failure_at=7))
        with pytest.raises(SimulatedFailure):
            tr.train(20)
        tr2 = Trainer(cfg, _run_cfg(tmp_path))
        assert tr2.start_step == 5  # last committed checkpoint
        out = tr2.train(5)
        assert out["final_step"] == 10

    def test_restart_is_deterministic(self, tmp_path):
        """Uninterrupted run and crash+resume must produce the same loss
        (counter-mode data pipeline + checkpointed optimizer state)."""
        cfg = get_smoke_config("qwen2-0.5b")
        tr = Trainer(cfg, _run_cfg(tmp_path / "a", ckpt_every=100))
        ref = tr.train(10)["final_loss"]

        tr1 = Trainer(cfg, _run_cfg(tmp_path / "b", ckpt_every=5))
        tr1.train(5)
        tr2 = Trainer(cfg, _run_cfg(tmp_path / "b", ckpt_every=5))
        assert tr2.start_step == 5
        out = tr2.train(5)
        np.testing.assert_allclose(out["final_loss"], ref, rtol=1e-4)

    def test_loss_decreases(self, tmp_path):
        cfg = get_smoke_config("qwen2-0.5b")
        tr = Trainer(cfg, _run_cfg(tmp_path, ckpt_every=1000, log_every=1))
        out = tr.train(30)
        first = out["metrics"][0]["loss"]
        last = out["metrics"][-1]["loss"]
        assert last < first, (first, last)

    def test_grad_compression_still_learns(self, tmp_path):
        comp = CompressionConfig(grad_compression=True, grad_E_rel=1e-2, grad_Delta_rel=1e-1, grad_block=512)
        cfg = dataclasses.replace(get_smoke_config("qwen2-0.5b"), compression=comp)
        tr = Trainer(cfg, _run_cfg(tmp_path, ckpt_every=1000, log_every=1))
        out = tr.train(30)
        assert out["metrics"][-1]["loss"] < out["metrics"][0]["loss"]

    def test_straggler_tracking(self, tmp_path):
        cfg = get_smoke_config("qwen2-0.5b")
        tr = Trainer(cfg, _run_cfg(tmp_path, ckpt_every=1000))
        tr.step_times = [0.1] * 10
        tr._track_straggler(11, 1.0)  # 10x median
        assert tr.straggler_events and tr.straggler_events[-1]["step"] == 11


class TestElastic:
    def test_plan_keeps_tp_when_divisible(self):
        assert plan_mesh_shape(512, 16)[0] == (32, 16)
        assert plan_mesh_shape(256, 16)[0] == (16, 16)

    def test_plan_degrades_tp(self):
        shape, _ = plan_mesh_shape(24, 16)
        assert shape[0] * shape[1] == 24 and shape[1] <= 16

    def test_pod_loss(self):
        assert survivors_after_pod_loss(512, 2, 1) == 256
