"""Tier-1 gate for CI: run the ROADMAP test command and fail on NEW failures
(regressions) relative to a known-failures list — AND on stale entries
(known failures that now pass), so the list can only shrink.

Known failures are environment-dependent seed-era issues tracked for
burn-down; anything not on the list fails the build, and a list entry that
passes fails the build too, forcing the entry to be pruned in the same
change that fixed it (otherwise the list silently stops gating the test).

Each CI leg passes its own list (``--known``), so the single-device and
multi-device matrix legs gate independently; the leg's environment (e.g.
``XLA_FLAGS=--xla_force_host_platform_device_count=8``) is inherited by the
pytest subprocess as-is.

Usage:  PYTHONPATH=src python ci/check_tier1.py [--known FILE] [--junit FILE]
"""

from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--known",
        default=os.path.join(HERE, "known_failures.txt"),
        help="per-leg known-failures list (default: ci/known_failures.txt)",
    )
    ap.add_argument(
        "--junit",
        default=None,
        help="also write a junit xml report here (uploaded as a CI artifact)",
    )
    ap.add_argument(
        "--xdist",
        action="store_true",
        help="run the suite under pytest-xdist (-n auto); the deterministic "
        "hypothesis CI profile (tests/conftest.py) keeps randomized tests "
        "reproducible across workers",
    )
    ap.add_argument(
        "--select",
        nargs="+",
        default=None,
        metavar="PATH",
        help="run only these test paths (e.g. tests/test_faults.py for the "
        "chaos-smoke leg) instead of the whole suite",
    )
    ap.add_argument(
        "--wall-timeout",
        type=float,
        default=None,
        metavar="SECONDS",
        help="kill the pytest subprocess after this many seconds and report "
        "FAILURE — a hung chaos test must fail the build, not stall the "
        "runner until the job-level timeout reaps it",
    )
    ap.add_argument(
        "--per-test-timeout",
        type=float,
        default=None,
        metavar="SECONDS",
        help="pass --timeout=N to pytest when pytest-timeout is installed "
        "(silently skipped otherwise, so the gate runs in minimal envs)",
    )
    args = ap.parse_args()

    with open(args.known) as f:
        known = {ln.strip() for ln in f if ln.strip() and not ln.startswith("#")}

    cmd = [sys.executable, "-m", "pytest", "-q", "--tb=no", "-rEf"]
    if args.xdist:
        cmd += ["-n", "auto"]
    if args.per_test_timeout is not None:
        import importlib.util

        if importlib.util.find_spec("pytest_timeout") is not None:
            cmd.append(f"--timeout={args.per_test_timeout}")
        else:
            print("pytest-timeout not installed; per-test timeout not enforced")
    if args.junit:
        cmd.append(f"--junitxml={args.junit}")
    if args.select:
        cmd += args.select
    try:
        proc = subprocess.run(
            cmd,
            cwd=os.path.dirname(HERE),
            capture_output=True,
            text=True,
            timeout=args.wall_timeout,
        )
    except subprocess.TimeoutExpired as e:
        partial = e.stdout or ""
        if isinstance(partial, bytes):
            partial = partial.decode(errors="replace")
        print(partial[-2000:])
        print(f"\nHANG: pytest exceeded the {args.wall_timeout:g}s wall timeout — failing")
        return 1
    out = proc.stdout + proc.stderr
    print(out[-4000:])

    # pytest exit codes: 0 = all passed, 1 = some tests failed; anything else
    # (2 interrupted, 3 internal error, 4 usage error, 5 nothing collected)
    # means the suite did not actually run — never report that as green
    if proc.returncode not in (0, 1):
        print(f"\npytest exited with code {proc.returncode} — suite did not run")
        return 1
    m = re.search(r"(\d+) passed", out)
    if not m or int(m.group(1)) == 0:
        print("\nno tests passed — suite did not run")
        return 1

    failed = set()
    for line in out.splitlines():
        m = re.match(r"^(?:FAILED|ERROR)\s+(\S+)", line)
        if m:
            failed.add(m.group(1).split(" ")[0].rstrip(":"))

    new = sorted(failed - known)
    fixed = sorted(known - failed)
    rc = 0
    if fixed:
        print(f"\nSTALE: {len(fixed)} known failure(s) now pass — prune:")
        print(f"  (in {args.known})")
        for t in fixed:
            print(f"  {t}")
        rc = 1
    if new:
        print(f"\nREGRESSION: {len(new)} new failing test(s):")
        for t in new:
            print(f"  {t}")
        rc = 1
    if rc == 0:
        print(f"\ntier-1 OK: {len(failed)} failures, all known ({len(known)} listed)")
    return rc


if __name__ == "__main__":
    sys.exit(main())
