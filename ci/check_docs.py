"""Docs gate: cross-reference link check, flag-table drift, example smoke.

Three checks over the ``docs/`` tree (all run by the CI docs leg):

1. **Link check** — every relative markdown link in ``docs/*.md`` (and the
   docs pointers in README-level files that name docs pages) must resolve to
   an existing file after stripping any ``#anchor``.  External ``http(s)``
   links are not fetched.

2. **Flag-table drift** — the flag reference in docs/serving.md between the
   ``FLAG_TABLE_START`` / ``FLAG_TABLE_END`` markers must equal the output
   of ``repro.launch.serve_ffcz.flag_table()`` (generated from the shared
   ``add_*_args`` builders).  ``--write-flag-table`` regenerates it in
   place; CI runs the diff.

3. **Example smoke** — ``examples/quickstart.py --quick`` and
   ``examples/stream_eeg.py --quick`` must exit 0 (skipped with
   ``--no-examples``).

Usage::

    PYTHONPATH=src python ci/check_docs.py
    PYTHONPATH=src python ci/check_docs.py --write-flag-table
"""

from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS = os.path.join(REPO, "docs")
SERVING_MD = os.path.join(DOCS, "serving.md")
MARK_START = "<!-- FLAG_TABLE_START -->"
MARK_END = "<!-- FLAG_TABLE_END -->"
EXAMPLES = ("examples/quickstart.py", "examples/stream_eeg.py")

# [text](target) — excluding images; target split from any title/anchor
_LINK_RE = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")


def check_links() -> list:
    errors = []
    for name in sorted(os.listdir(DOCS)):
        if not name.endswith(".md"):
            continue
        path = os.path.join(DOCS, name)
        with open(path, encoding="utf-8") as f:
            text = f.read()
        for m in _LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            rel = target.split("#", 1)[0]
            if not rel:  # pure in-page anchor
                continue
            resolved = os.path.normpath(os.path.join(DOCS, rel))
            if not os.path.exists(resolved):
                errors.append(f"{name}: broken link -> {target}")
    return errors


def _split_serving_md() -> tuple:
    with open(SERVING_MD, encoding="utf-8") as f:
        text = f.read()
    try:
        head, rest = text.split(MARK_START, 1)
        table, tail = rest.split(MARK_END, 1)
    except ValueError:
        return None, None, None, None
    return head, table.strip("\n"), tail, text


def check_flag_table(write: bool) -> list:
    sys.path.insert(0, os.path.join(REPO, "src"))
    from repro.launch.serve_ffcz import flag_table

    expected = flag_table()
    head, current, tail, _text = _split_serving_md()
    if head is None:
        return [f"docs/serving.md: missing {MARK_START} / {MARK_END} markers"]
    if current == expected:
        return []
    if write:
        with open(SERVING_MD, "w", encoding="utf-8") as f:
            f.write(head + MARK_START + "\n" + expected + "\n" + MARK_END + tail)
        print("docs/serving.md: flag table rewritten")
        return []
    return [
        "docs/serving.md: flag table drifted from repro.launch.serve_ffcz "
        "add_*_args builders — regenerate with "
        "`PYTHONPATH=src python ci/check_docs.py --write-flag-table`"
    ]


def run_examples() -> list:
    errors = []
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    for rel in EXAMPLES:
        cmd = [sys.executable, os.path.join(REPO, rel), "--quick"]
        print(f"running {rel} --quick ...")
        proc = subprocess.run(cmd, cwd=REPO, env=env, capture_output=True, text=True)
        if proc.returncode != 0:
            tail = (proc.stdout + proc.stderr).strip().splitlines()[-15:]
            errors.append(f"{rel} --quick exited {proc.returncode}:\n  " + "\n  ".join(tail))
    return errors


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--write-flag-table", action="store_true",
                    help="regenerate the docs/serving.md flag table in place")
    ap.add_argument("--no-examples", action="store_true",
                    help="skip the example smoke runs (link + drift checks only)")
    args = ap.parse_args()

    errors = check_links()
    errors += check_flag_table(write=args.write_flag_table)
    if not args.no_examples:
        errors += run_examples()

    if errors:
        print("\nDOCS CHECK FAILED:")
        for e in errors:
            print(f"  - {e}")
        return 1
    print("docs check OK (links, flag table, examples)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
