"""Bench regression guard: case-kind coverage + minimum-speedup thresholds.

``benchmarks/bench_pocs.py`` (POCS kernels) and ``benchmarks/bench_serve.py``
(pipelined serving) anchor the perf claims in ROADMAP; this check gates them
two ways:

1. **Coverage** — smoke-runs both benchmarks in ``--quick`` mode (small
   shapes, few repeats — a correctness run, not a measurement) into scratch
   files and fails if any emitted ``(bench, path)`` case kind is missing
   from the checked-in BENCH_pocs.json, or if a recorded kind is no longer
   emitted (a silently dead case / failed subprocess leg).  Shapes/sizes
   are not compared: quick mode deliberately shrinks them.

2. **Thresholds** — the COMMITTED BENCH_pocs.json (the measured full run,
   not the quick smoke) must meet the per-case-kind minimum speedups in
   ``THRESHOLDS`` below.  Someone refreshing the record after a perf
   regression fails CI here instead of silently lowering the anchor.  Every
   row of a kind must clear its bar (each recorded shape is a claim).

   Noisy-container override: set ``FFCZ_BENCH_MIN_SCALE`` (a float in
   (0, 1], e.g. ``0.85``) to scale all thresholds down when refreshing the
   record on shared/noisy hardware, and say so in the commit message.  The
   knob relaxes the gate; it never disables the coverage check.

Usage:  PYTHONPATH=src python ci/check_bench.py
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)
RECORDED = os.path.join(ROOT, "BENCH_pocs.json")

# (bench, path) -> (speedup field, minimum value, optional shape selector).
# Bars sit under the values measured on the CI container so ordinary
# run-to-run noise passes while a real regression (or a stale record after
# one) fails:
#   single/rfft            recorded ~1.37-1.49x  -> bar 1.25
#   single/rfft-packed     the ISSUE 5 acceptance floor for the pack-trick
#                          C2R path, pinned to the 512^2 case the criterion
#                          names -> bar 1.15 there, sanity 1.0 elsewhere
#                          (the C2R-vs-r2c gap the trick attacks swings
#                          with the container's memory weather)
#   engine_field           recorded ~1.15-2.07x  -> bar 1.05
#   batched                recorded ~1.10-1.26x  -> bar 0.85 (CPU is
#                          ~parity by design; the row guards collapse)
#   single/roi-vs-uniform  the ROI bound grid (ISSUE 9) swaps a scalar clip
#                          for a broadcast pointwise clip — elementwise O(N)
#                          against the loop's FFTs, so the ratio sits near
#                          1.0; bar 0.5 is a collapse guard (a pointwise
#                          clip falling off the fused path), not a speedup
#                          claim
#   stream/warm-vs-cold    the ISSUE 8 acceptance floor: warm-starting POCS
#                          from the previous frame's converged spectrum must
#                          cut mean iterations >= 1.2x on a coherent
#                          sequence (recorded ~10x; the ratio is an
#                          iteration count, so it is noise-free — the bar
#                          guards the warm path going dead, not jitter)
#   serve/session-append   per-frame session arrival vs one submit_stream
#                          over the same frames (ISSUE 10).  The session
#                          path adds WAL journaling, receipt bookkeeping,
#                          and one drain per frame on top of the same encode
#                          work, so the ratio sits near (below) 1.0; bar 0.4
#                          is a no-collapse floor (e.g. the journal path
#                          re-encoding frames, or appends losing bucket
#                          reuse), not a speedup claim.  Like every entry it
#                          scales with FFCZ_BENCH_MIN_SCALE on refresh, but
#                          a refresh needing < 0.4 here means the session
#                          path itself regressed — fix it, don't scale it.
# Interpret-mode pallas rows and fake-device sharded rows carry no bar:
# their CPU numbers price emulation/core-sharing, not the claim.
THRESHOLDS = {
    ("single", "rfft"): [("speedup_rfft_vs_complex", 1.25, None)],
    ("single", "rfft-packed"): [
        ("speedup_packed_vs_xla", 1.15, [512, 512]),
        ("speedup_packed_vs_xla", 1.0, None),
    ],
    ("single", "roi-vs-uniform"): [("speedup_roi_vs_uniform", 0.5, None)],
    ("engine_field", "engine-device"): [("speedup_engine_vs_host", 1.05, None)],
    ("batched", "correct_batch"): [("speedup_batched_vs_loop", 0.85, None)],
    ("stream", "warm-vs-cold"): [("iter_reduction_warm_vs_cold", 1.2, None)],
    ("serve", "session-append"): [("speedup_session_vs_stream", 0.4, None)],
}

# serve/pipelined-vs-serial (benchmarks/bench_serve.py): the ISSUE 7
# acceptance floor — pipelined step() must sustain >= 1.3x serial throughput
# at saturating load.  Overlapping host ENCODE with device EXECUTE needs a
# second core to run the encode worker on; a single-core host serializes the
# threads by construction and cannot exceed ~1.0x, so rows recorded there
# carry a sanity floor instead: pipelining must not COST more than 15%.
# The row's own cpu_count field (stamped by the bench at measurement time)
# picks the bar, so a record refreshed on a 1-core container and checked on
# a many-core runner still gets the bar its measurement could meet.
SERVE_KIND = ("serve", "pipelined-vs-serial")
SERVE_FIELD = "speedup_pipelined_vs_serial"
SERVE_FLOOR_MULTICORE = 1.3
SERVE_FLOOR_SINGLECORE = 0.85


def case_kinds(rows) -> set:
    return {(r.get("bench", "?"), r.get("path", "?")) for r in rows}


def check_thresholds(rows) -> int:
    scale = float(os.environ.get("FFCZ_BENCH_MIN_SCALE", "1.0"))
    if not (0.0 < scale <= 1.0):
        print(f"FFCZ_BENCH_MIN_SCALE must be in (0, 1], got {scale}")
        return 1
    rc = 0
    checked = 0
    matched = {
        (kind, i): 0
        for kind, entries in THRESHOLDS.items()
        for i in range(len(entries))
    }
    for row in rows:
        kind = (row.get("bench", "?"), row.get("path", "?"))
        if kind not in THRESHOLDS:
            continue
        size = row.get("shape", row.get("size"))
        where = f"bench={kind[0]} path={kind[1]} shape/size={size}"
        for i, (field, floor, shape_sel) in enumerate(THRESHOLDS[kind]):
            if shape_sel is not None and row.get("shape") != shape_sel:
                continue
            matched[(kind, i)] += 1
            floor *= scale
            got = row.get(field)
            if got is None:
                print(f"MISSING SPEEDUP FIELD: {where} has no {field!r}")
                rc = 1
                continue
            checked += 1
            if got < floor:
                scaled = ""
                if scale != 1.0:
                    scaled = f" (scaled by FFCZ_BENCH_MIN_SCALE={scale})"
                print(
                    f"SPEEDUP BELOW THRESHOLD: {where}: "
                    f"{field}={got:.3f} < {floor:.3f}{scaled}"
                )
                rc = 1
    # every threshold entry must have matched at least one row — otherwise a
    # shape change (or a kind vanishing from the record) would silently
    # retire its bar while CI stays green
    for (kind, i), n in sorted(matched.items()):
        if n == 0:
            field, floor, shape_sel = THRESHOLDS[kind][i]
            sel = f" shape={shape_sel}" if shape_sel is not None else ""
            print(
                f"THRESHOLD MATCHED NO ROW: bench={kind[0]} path={kind[1]}{sel} "
                f"({field} >= {floor}) — the record no longer carries the case "
                f"this bar gates"
            )
            rc = 1
    if rc == 0:
        print(f"thresholds OK: {checked} recorded row(s) meet their minimum speedups")
    return rc


def check_serve_threshold(rows) -> int:
    """The cpu_count-gated pipelined-vs-serial floor (see SERVE_* above)."""
    scale = float(os.environ.get("FFCZ_BENCH_MIN_SCALE", "1.0"))
    rc = 0
    matched = 0
    for row in rows:
        if (row.get("bench"), row.get("path")) != SERVE_KIND:
            continue
        matched += 1
        cpus = int(row.get("cpu_count") or 1)
        floor = (SERVE_FLOOR_MULTICORE if cpus >= 2 else SERVE_FLOOR_SINGLECORE) * scale
        got = row.get(SERVE_FIELD)
        where = f"bench=serve path=pipelined-vs-serial shape={row.get('shape')}"
        if got is None:
            print(f"MISSING SPEEDUP FIELD: {where} has no {SERVE_FIELD!r}")
            rc = 1
            continue
        if got < floor:
            kind = "multicore" if cpus >= 2 else "single-core sanity"
            print(
                f"SPEEDUP BELOW THRESHOLD: {where}: {SERVE_FIELD}={got:.3f} < "
                f"{floor:.3f} ({kind} floor, cpu_count={cpus}"
                + (f", scaled by FFCZ_BENCH_MIN_SCALE={scale}" if scale != 1.0 else "")
                + ")"
            )
            rc = 1
    if matched == 0:
        print(
            "THRESHOLD MATCHED NO ROW: bench=serve path=pipelined-vs-serial — "
            "the record carries no pipelined-vs-serial measurement (run "
            "benchmarks/bench_serve.py without --quick)"
        )
        rc = 1
    if rc == 0:
        print(f"serve threshold OK: {matched} pipelined-vs-serial row(s) meet their floor")
    return rc


def main() -> int:
    with open(RECORDED) as f:
        recorded_rows = json.load(f)["rows"]
    recorded = case_kinds(recorded_rows)

    rc = check_thresholds(recorded_rows)
    rc |= check_serve_threshold(recorded_rows)

    emitted = set()
    with tempfile.TemporaryDirectory() as tmp:
        # both benchmarks smoke-run in --quick mode; coverage below checks
        # the UNION of their emitted kinds against the committed record
        for name in ("bench_pocs.py", "bench_serve.py"):
            bench = os.path.join(ROOT, "benchmarks", name)
            out = os.path.join(tmp, name + ".json")
            proc = subprocess.run(
                [sys.executable, bench, "--quick", "--out", out],
                cwd=ROOT,
                capture_output=True,
                text=True,
                timeout=1800,
            )
            print(proc.stdout[-3000:])
            if proc.returncode != 0:
                print(f"{name} --quick failed (exit {proc.returncode}):")
                print(proc.stderr[-3000:])
                return 1
            with open(out) as f:
                emitted |= case_kinds(json.load(f)["rows"])

    if not emitted:
        print("benchmark emitted no rows — smoke run did not measure anything")
        return 1
    missing = sorted(emitted - recorded)
    if missing:
        print(
            f"\nSTALE BENCH RECORD: {len(missing)} case(s) emitted by the benchmark"
            " but absent from BENCH_pocs.json — refresh it (run bench_pocs.py"
            " without --quick):"
        )
        for kind in missing:
            print(f"  bench={kind[0]} path={kind[1]}")
        rc = 1
    # the other direction catches silently-lost coverage: bench_pocs degrades
    # gracefully when e.g. the multi-device subprocess dies (it just drops
    # those rows), which must not read as a passing smoke run
    dropped = sorted(recorded - emitted)
    if dropped:
        print(
            f"\nLOST BENCH COVERAGE: {len(dropped)} recorded case(s) the smoke run"
            " no longer emits — the benchmark degraded (dead case, failed"
            " subprocess leg?):"
        )
        for kind in dropped:
            print(f"  bench={kind[0]} path={kind[1]}")
        rc = 1
    if rc == 0:
        print(
            f"\nbench record OK: {len(emitted)} emitted case kind(s), all recorded"
            f" ({len(recorded)} in BENCH_pocs.json)"
        )
    return rc


if __name__ == "__main__":
    sys.exit(main())
