"""Bench regression guard: the recorded BENCH_pocs.json must cover every
case the benchmark emits.

``benchmarks/bench_pocs.py`` is the anchor for the perf claims in ROADMAP;
when someone adds a bench case without refreshing the recorded numbers, the
JSON silently stops describing the benchmark.  This check smoke-runs the
benchmark in ``--quick`` mode (small shapes, few repeats — a correctness run,
not a measurement) into a scratch file and fails if any emitted
``(bench, path)`` case kind is missing from the checked-in BENCH_pocs.json.
Shapes/sizes are not compared: quick mode deliberately shrinks them.

Usage:  PYTHONPATH=src python ci/check_bench.py
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)
RECORDED = os.path.join(ROOT, "BENCH_pocs.json")


def case_kinds(rows) -> set:
    return {(r.get("bench", "?"), r.get("path", "?")) for r in rows}


def main() -> int:
    with open(RECORDED) as f:
        recorded = case_kinds(json.load(f)["rows"])

    bench = os.path.join(ROOT, "benchmarks", "bench_pocs.py")
    with tempfile.TemporaryDirectory() as tmp:
        out = os.path.join(tmp, "bench.json")
        proc = subprocess.run(
            [sys.executable, bench, "--quick", "--out", out],
            cwd=ROOT,
            capture_output=True,
            text=True,
            timeout=1800,
        )
        print(proc.stdout[-3000:])
        if proc.returncode != 0:
            print(f"bench_pocs.py --quick failed (exit {proc.returncode}):")
            print(proc.stderr[-3000:])
            return 1
        with open(out) as f:
            emitted = case_kinds(json.load(f)["rows"])

    if not emitted:
        print("benchmark emitted no rows — smoke run did not measure anything")
        return 1
    rc = 0
    missing = sorted(emitted - recorded)
    if missing:
        print(
            f"\nSTALE BENCH RECORD: {len(missing)} case(s) emitted by the benchmark"
            " but absent from BENCH_pocs.json — refresh it (run bench_pocs.py"
            " without --quick):"
        )
        for kind in missing:
            print(f"  bench={kind[0]} path={kind[1]}")
        rc = 1
    # the other direction catches silently-lost coverage: bench_pocs degrades
    # gracefully when e.g. the multi-device subprocess dies (it just drops
    # those rows), which must not read as a passing smoke run
    dropped = sorted(recorded - emitted)
    if dropped:
        print(
            f"\nLOST BENCH COVERAGE: {len(dropped)} recorded case(s) the smoke run"
            " no longer emits — the benchmark degraded (dead case, failed"
            " subprocess leg?):"
        )
        for kind in dropped:
            print(f"  bench={kind[0]} path={kind[1]}")
        rc = 1
    if rc == 0:
        print(
            f"\nbench record OK: {len(emitted)} emitted case kind(s), all recorded"
            f" ({len(recorded)} in BENCH_pocs.json)"
        )
    return rc


if __name__ == "__main__":
    sys.exit(main())
