"""Paper Fig. 6: SSNR vs bitrate, base compressor vs FFCz-augmented.

Sweep the base spatial bound to trace the rate curve; FFCz points add edits
on the eps(%)=0.1 operating point with progressively tighter Delta.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import BASES, save_results
from repro.compressors import get_compressor
from repro.core.ffcz import FFCz, FFCzConfig
from repro.core.spectrum import bitrate, ssnr_spatial
from repro.data.fields import make_field


def run(quick: bool = False):
    rows = []
    x = make_field("nyx-like")
    xj = jnp.asarray(x)
    bases = BASES[:1] if quick else BASES
    for bname in bases:
        base = get_compressor(bname)
        for e_rel in ([1e-3] if quick else [1e-2, 1e-3, 1e-4]):
            E = e_rel * np.ptp(x)
            blob = base.compress(x, E)
            xh = base.decompress(blob)
            rows.append({
                "bench": "fig6", "base": bname, "method": "native", "E_rel": e_rel,
                "bitrate": bitrate(len(blob), x.size),
                "ssnr_db": float(ssnr_spatial(jnp.asarray(xh), xj)),
            })
        for d_rel in ([1e-3] if quick else [1e-2, 1e-3, 1e-4]):
            c = FFCz(base, FFCzConfig(E_rel=1e-3, Delta_rel=d_rel, max_iters=1500))
            xh, blob = c.roundtrip(x)
            rows.append({
                "bench": "fig6", "base": bname, "method": "ffcz", "Delta_rel": d_rel,
                "bitrate": bitrate(blob.stats.total_bytes, x.size),
                "ssnr_db": float(ssnr_spatial(jnp.asarray(xh), xj)),
                "iterations": blob.stats.iterations,
            })
    save_results("fig6_ssnr", rows)
    return rows


COLUMNS = ["bench", "base", "method", "E_rel", "Delta_rel", "bitrate", "ssnr_db", "iterations"]
