"""Paper Fig. 8: PSNR vs bitrate in the SPATIAL domain — FFCz edits must not
degrade spatial quality at matched bitrate."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_results
from repro.compressors import get_compressor
from repro.core.ffcz import FFCz, FFCzConfig
from repro.core.spectrum import bitrate, psnr
from repro.data.fields import make_field


def run(quick: bool = False):
    rows = []
    x = make_field("nyx-like")
    xj = jnp.asarray(x)
    base = get_compressor("szlike")
    for e_rel in ([1e-3] if quick else [1e-2, 1e-3, 1e-4]):
        E = e_rel * np.ptp(x)
        blob = base.compress(x, E)
        xh = base.decompress(blob)
        rows.append({
            "bench": "fig8", "method": "sz-native", "E_rel": e_rel,
            "bitrate": bitrate(len(blob), x.size),
            "psnr_db": float(psnr(jnp.asarray(xh), xj)),
        })
        c = FFCz(base, FFCzConfig(E_rel=e_rel, Delta_rel=1e-3, max_iters=1500))
        xh2, fblob = c.roundtrip(x)
        rows.append({
            "bench": "fig8", "method": "ffcz", "E_rel": e_rel,
            "bitrate": bitrate(fblob.stats.total_bytes, x.size),
            "psnr_db": float(psnr(jnp.asarray(xh2), xj)),
        })
    save_results("fig8_psnr", rows)
    return rows


COLUMNS = ["bench", "method", "E_rel", "bitrate", "psnr_db"]
