"""Benchmark harness: one module per paper table/figure (DESIGN.md §9).

Prints ``name,us_per_call,derived`` CSV summary lines plus each table's own
CSV; JSON artifacts land in benchmarks/results/.

  table2_ratio      Table II   compression ratios (native / trial-and-error / FFCz)
  fig6_ssnr         Fig. 6     SSNR vs bitrate
  fig7_throughput   Fig. 7     stage throughputs + pipeline bottleneck
  fig8_psnr         Fig. 8     spatial PSNR vs bitrate
  table3_iters      Table III  iterations / active edits vs Delta
  table4_kernels    Table IV   kernel-level breakdown
  fig10_pspec       Fig. 10    power-spectrum ribbon
  roofline          —          dry-run roofline terms (EXPERIMENTS.md §Roofline)

``python -m benchmarks.run [--quick] [--only name]``
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="reduced sweeps (CI)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (
        fig6_ssnr,
        fig7_throughput,
        fig8_psnr,
        fig10_pspec,
        roofline,
        table2_ratio,
        table3_iters,
        table4_kernels,
    )
    from benchmarks.common import print_csv

    modules = {
        "table2_ratio": table2_ratio,
        "fig6_ssnr": fig6_ssnr,
        "fig7_throughput": fig7_throughput,
        "fig8_psnr": fig8_psnr,
        "table3_iters": table3_iters,
        "table4_kernels": table4_kernels,
        "fig10_pspec": fig10_pspec,
        "roofline": roofline,
    }
    if args.only:
        modules = {k: v for k, v in modules.items() if k in args.only.split(",")}

    print("name,us_per_call,derived")
    for name, mod in modules.items():
        t0 = time.perf_counter()
        rows = mod.run(quick=args.quick)
        dt = time.perf_counter() - t0
        print(f"{name},{dt * 1e6 / max(len(rows), 1):.1f},{len(rows)} rows")
        print_csv(rows, mod.COLUMNS)
        print()


if __name__ == "__main__":
    main()
