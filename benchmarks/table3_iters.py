"""Paper Table III: iterations / active edits / time vs frequency bound.

Reproduces the regime structure: moderate Delta => many iterations and few
active edits; tiny Delta (f-cube inside s-cube) => 1 iteration, zero spatial
edits, many frequency edits.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import save_results
from repro.compressors import get_compressor
from repro.core.ffcz import FFCz, FFCzConfig
from repro.data.fields import make_field


def run(quick: bool = False):
    rows = []
    x = make_field("nyx-like")
    base = get_compressor("szlike")
    deltas = [1e-2, 1e-3] if quick else [1e-2, 1e-3, 1e-4, 1e-5]
    for d_rel in deltas:
        c = FFCz(base, FFCzConfig(E_rel=1e-3, Delta_rel=d_rel, max_iters=3000, verify=False))
        t0 = time.perf_counter()
        blob = c.compress(x)
        dt = time.perf_counter() - t0
        # stats disabled (verify=False) -> recompute actives from the blobs
        rows.append({
            "bench": "table3", "delta_rel": d_rel,
            "n_active_spat": blob.spat_edits.n_active,
            "n_active_freq": blob.freq_edits.n_active,
            "time_ms": dt * 1e3,
        })
    # iterations need verify=True (stats); sample the two regimes
    for d_rel in ([1e-3] if quick else [1e-2, 1e-5]):
        c = FFCz(base, FFCzConfig(E_rel=1e-3, Delta_rel=d_rel, max_iters=3000))
        blob = c.compress(x)
        rows.append({
            "bench": "table3", "delta_rel": d_rel, "iterations": blob.stats.iterations,
            "n_active_spat": blob.stats.n_active_spatial,
            "n_active_freq": blob.stats.n_active_frequency,
        })
    save_results("table3_iters", rows)
    return rows


COLUMNS = ["bench", "delta_rel", "iterations", "n_active_spat", "n_active_freq", "time_ms"]
