"""Roofline analysis from the compiled dry-run artifacts (deliverable g).

Per (arch x shape x mesh) cell, from dryrun_*.json (per-DEVICE numbers — the
compiled module is the SPMD per-device program):

  compute term    = HLO_FLOPs / peak_FLOPs          (197 TF/s bf16, v5e)
  memory term     = HLO_bytes  / HBM_bw             (819 GB/s)
  collective term = collective_bytes / link_bw      (~50 GB/s/link ICI)

plus MODEL_FLOPS (6*N_active*tokens for train, 2*N_active*tokens for
inference) vs HLO_FLOPs — the useful-compute ratio that exposes remat and
masked-causal waste.
"""

from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

import jax
import numpy as np

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
LINK_BW = 50e9  # bytes/s / ICI link

_PARAM_CACHE: Dict[str, Dict[str, float]] = {}


def _param_counts(arch: str) -> Dict[str, float]:
    """(total, active, embed) param counts from the abstract init tree."""
    if arch in _PARAM_CACHE:
        return _PARAM_CACHE[arch]
    from repro.configs import get_config
    from repro.models.model import build_model

    cfg = get_config(arch)
    tree = jax.eval_shape(build_model(cfg).init, jax.random.PRNGKey(0))
    total = active = embed = 0.0
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        n = float(np.prod(leaf.shape))
        names = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
        total += n
        if any(k in names for k in ("embed", "lm_head")):
            embed += n
            active += n
            continue
        if "moe" in names and names[-1] in ("w_gate", "w_up", "w_down"):
            active += n * cfg.top_k / cfg.n_experts
        else:
            active += n
    out = {"total": total, "active": active, "embed": embed}
    _PARAM_CACHE[arch] = out
    return out


def _attn_layers(cfg) -> int:
    if cfg.family == "ssm":
        return 0
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_every
    if cfg.family == "audio":
        return cfg.n_layers + cfg.encoder_layers  # + cross attn ~ self-sized
    return cfg.n_layers


def model_flops(arch: str, shape_id: str, n_devices: int) -> float:
    """Per-device useful model FLOPs: 6/2 * N_active * tokens (matmul params)
    plus causal attention score/value GEMMs (2 * b * s^2/2 * heads*hd * 2
    GEMMs, x3 for train's fwd+bwd)."""
    from repro.configs import SHAPES, get_config

    seq, batch, kind = SHAPES[shape_id]
    cfg = get_config(arch)
    pc = _param_counts(arch)
    n_active = pc["active"] - pc["embed"]  # matmul-participating params
    la = _attn_layers(cfg)
    hqd = cfg.n_heads * cfg.resolved_head_dim if (cfg.n_heads and la) else 0
    if kind == "train":
        tokens = seq * batch
        attn = 3.0 * la * 2.0 * batch * (seq**2 / 2.0) * hqd * 2.0
        return (6.0 * n_active * tokens + attn) / n_devices
    if kind == "prefill":
        tokens = seq * batch
        attn = la * 2.0 * batch * (seq**2 / 2.0) * hqd * 2.0
        return (2.0 * n_active * tokens + attn) / n_devices
    # decode: one token per sequence against a seq-long cache
    attn = la * 2.0 * batch * seq * hqd * 2.0
    return (2.0 * n_active * batch + attn) / n_devices


def model_memory_bytes(arch: str, shape_id: str, n_devices: int) -> float:
    """Per-device HBM bytes per step — analytic, assuming a well-fused TPU
    program (flash attention resident in VMEM, fused elementwise).

    train:   weights bf16 read fwd + bwd + remat re-read (3 x 2B x P) +
             grads fp32 R/W (8B) + AdamW moments fp32 R+W (16B) + master
             params R/W (8B) + activation checkpoints (~6 x b*s*d per layer)
    prefill: weights 2B x P + KV writes + 2 x b*s*d activations per layer
    decode:  weights 2B x P + full KV cache read + 1-token write
    """
    from repro.configs import SHAPES, get_config

    seq, batch, kind = SHAPES[shape_id]
    cfg = get_config(arch)
    pc = _param_counts(arch)
    P = pc["total"] / n_devices
    # per-device batch: batch is sharded over the DP axes (16 or 32 ways)
    dp = 16 if n_devices == 256 else 32
    b_dev = max(batch // dp, 1)
    la = _attn_layers(cfg)
    kv_row = 2 * cfg.n_kv_heads * cfg.resolved_head_dim * 2 if la else 0  # k+v, bf16
    d = cfg.d_model
    L = cfg.n_layers
    if kind == "train":
        weights = 3 * 2 * P
        opt = (8 + 16 + 8) * P
        acts = 6 * L * b_dev * seq * d * 2
        return weights + opt + acts
    if kind == "prefill":
        weights = 2 * P
        kv = la * b_dev * seq * kv_row
        acts = 2 * L * b_dev * seq * d * 2
        return weights + kv + acts
    # decode
    weights = 2 * P
    kv_read = la * b_dev * seq * kv_row
    ssm_state = 0.0
    if cfg.family in ("ssm", "hybrid"):
        ssm_state = L * b_dev * cfg.ssm_nheads * cfg.ssm_headdim * cfg.ssm_state * 4 * 2
    return weights + kv_read + ssm_state


def analyze(results_path: str) -> List[Dict]:
    with open(results_path) as f:
        cells = json.load(f)
    rows = []
    for c in cells:
        if not c.get("ok"):
            if c.get("skipped"):
                rows.append({"arch": c["arch"], "shape": c["shape"], "skipped": True,
                             "reason": c.get("reason", "")})
            continue
        n_dev = 512 if c["mesh"] == "2x16x16" else 256
        t_comp = c["flops"] / PEAK_FLOPS
        # memory term: analytic well-fused model (the HLO byte walk assumes
        # zero fusion and is kept in the record as an upper bound only)
        t_mem = model_memory_bytes(c["arch"], c["shape"], n_dev) / HBM_BW
        coll = sum(c["collective_bytes"].values())
        t_coll = coll / LINK_BW
        terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
        dominant = max(terms, key=terms.get)
        mf = model_flops(c["arch"], c["shape"], n_dev)
        ratio = mf / c["flops"] if c["flops"] else 0.0
        bound_time = max(terms.values())
        mfu = (mf / PEAK_FLOPS) / bound_time if bound_time > 0 else 0.0
        rows.append({
            "arch": c["arch"], "shape": c["shape"], "mesh": c["mesh"],
            "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
            "dominant": dominant,
            "model_flops": mf, "hlo_flops": c["flops"],
            "useful_ratio": ratio,
            "roofline_fraction": mfu,
            "collective_breakdown": c["collective_bytes"],
            "hint": _hint(dominant, ratio, c),
        })
    return rows


def _hint(dominant: str, ratio: float, c: Dict) -> str:
    if dominant == "collective":
        big = max(c["collective_bytes"], key=c["collective_bytes"].get) if c["collective_bytes"] else "?"
        return f"cut {big} volume (resharding/FSDP schedule) to move the collective term down"
    if dominant == "memory":
        return "fuse/cached-layout the dominant HBM streams (KV cache, activations) to move the memory term down"
    if ratio < 0.4:
        return "compute-bound with low useful ratio: kill remat/masked-causal waste first"
    return "compute-bound near useful peak: only kernel-level wins (MXU util) remain"


def markdown_table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | dominant "
           "| useful ratio | roofline frac |\n|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        if r.get("skipped"):
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | skipped | — | — |\n")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | {r['dominant']} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} |\n"
        )
    return "".join(out)


def run(quick: bool = False):
    path = os.environ.get("DRYRUN_RESULTS", "dryrun_single.json")
    if not os.path.exists(path):
        return [{"bench": "roofline", "note": f"no dry-run results at {path}; run repro.launch.dryrun first"}]
    rows = analyze(path)
    from benchmarks.common import save_results

    save_results("roofline", rows)
    out = []
    for r in rows:
        if r.get("skipped"):
            continue
        out.append({"bench": "roofline", "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
                    "dominant": r["dominant"], "useful_ratio": round(r["useful_ratio"], 3),
                    "roofline_fraction": round(r["roofline_fraction"], 3)})
    return out


COLUMNS = ["bench", "arch", "shape", "mesh", "dominant", "useful_ratio", "roofline_fraction", "note"]
