"""POCS core throughput: complex-FFT oracle vs Hermitian rFFT fast path,
single-field vs batched multi-tenant correction.

Emits ``BENCH_pocs.json`` (repo root / cwd) with iterations/s and MB/s per
configuration — the anchor for the rFFT fast-path speedup claimed in
ROADMAP.  Both paths run the *same* iteration count (a deliberately
infeasible-in-N-iterations bound configuration), so wall-clock ratios are
per-iteration ratios.

Usage:  PYTHONPATH=src python benchmarks/bench_pocs.py [--quick]
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blockwise import blockwise_correct, correct_batch
from repro.core.pocs import alternating_projection


def _bench(fn, repeat: int = 5):
    fn()  # warmup / compile
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _bench_pair(fa, fb, repeat: int = 10):
    """Interleaved best-of timing: both candidates sample the same background
    load windows, so contention noise cancels out of the ratio."""
    fa(), fb()  # warmup / compile
    best_a = best_b = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fa())
        best_a = min(best_a, time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fb())
        best_b = min(best_b, time.perf_counter() - t0)
    return best_a, best_b


def bench_single(shape, max_iters: int, repeat: int):
    """Complex vs rfft path on one field, identical forced iteration count.

    The bound configuration is the paper's slow nearly-tangential regime
    (§III), built adversarially: every point sits on an s-cube face with an
    imbalanced sign pattern (nonzero mean), and the f-cube pins the DC
    component — POCS crawls, needing ~18+ iterations, so with a smaller
    ``max_iters`` cap both paths run *exactly* ``max_iters`` iterations and
    wall-clock ratios are per-iteration ratios.
    """
    rng = np.random.default_rng(0)
    E = 0.05
    sgn = np.where(rng.random(shape) < 0.52, 1.0, -1.0)
    eps0_np = (E * sgn * (1 - 1e-4 * rng.random(shape))).astype(np.float32)
    F = np.abs(np.fft.fftn(eps0_np))
    Delta_np = (1e9 * np.ones(shape)).astype(np.float32)
    Delta_np.reshape(-1)[0] = 0.01 * F.reshape(-1)[0]
    eps0 = jnp.asarray(eps0_np)
    Delta = jnp.asarray(Delta_np)

    for use_rfft in (False, True):
        res = alternating_projection(eps0, E, Delta, max_iters=max_iters, use_rfft=use_rfft)
        iters = int(res.iterations)
        assert iters == max_iters, f"hit feasibility at {iters} < {max_iters}; retune the bench"

    t_c, t_r = _bench_pair(
        lambda: alternating_projection(eps0, E, Delta, max_iters=max_iters, use_rfft=False).eps,
        lambda: alternating_projection(eps0, E, Delta, max_iters=max_iters, use_rfft=True).eps,
        repeat,
    )
    speedup = t_c / t_r
    mb = eps0.size * 4 / 1e6
    rows = [
        {
            "bench": "single",
            "path": path,
            "shape": list(shape),
            "iterations": max_iters,
            "wall_s": t,
            "iters_per_s": max_iters / t,
            "mb_per_s": mb * max_iters / t,
            "speedup_rfft_vs_complex": speedup,
        }
        for path, t in (("complex", t_c), ("rfft", t_r))
    ]
    return rows, speedup


def bench_batched(n_tensors: int, size: int, block: int, max_iters: int, repeat: int):
    """Per-tensor dispatch loop vs one batched correct_batch device program."""
    rng = np.random.default_rng(1)
    # host-side arrays: correct_batch donates its inputs, so both paths get a
    # fresh device copy per call (transfer cost counted identically for both)
    tensors_np = [rng.standard_normal(size).astype(np.float32) * 0.01 for _ in range(n_tensors)]
    E, Delta = 0.02, 0.02  # tight Delta => real iteration work per block

    def loop():
        return [
            blockwise_correct(jnp.asarray(t), E, Delta, block=block, max_iters=max_iters)
            for t in tensors_np
        ]

    def batched():
        outs, _stats = correct_batch(tensors_np, E, Delta, block=block, max_iters=max_iters)
        return outs

    t_loop, t_batch = _bench_pair(loop, batched, repeat)
    mb = n_tensors * size * 4 / 1e6
    speedup = t_loop / t_batch
    return [
        {
            "bench": "batched",
            "path": "per-tensor-loop",
            "n_tensors": n_tensors,
            "size": size,
            "block": block,
            "wall_s": t_loop,
            "mb_per_s": mb / t_loop,
            "speedup_batched_vs_loop": speedup,
        },
        {
            "bench": "batched",
            "path": "correct_batch",
            "n_tensors": n_tensors,
            "size": size,
            "block": block,
            "wall_s": t_batch,
            "mb_per_s": mb / t_batch,
            "speedup_batched_vs_loop": speedup,
        },
    ], speedup


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="smaller shapes / fewer repeats")
    ap.add_argument("--out", default="BENCH_pocs.json")
    args = ap.parse_args()

    repeat = 3 if args.quick else 16
    max_iters = 8 if args.quick else 20  # below the config's ~22-iteration natural count
    # production-scale fields: the FFT's N log N term dominates the linear
    # elementwise stages, so these show the fast path's real ratio
    shapes = [(512, 512), (128, 128, 64)] if not args.quick else [(128, 128)]

    rows = []
    for shape in shapes:
        r, s = bench_single(shape, max_iters, repeat)
        rows += r
        print(f"single {shape}: rfft vs complex speedup = {s:.2f}x")
    # Multi-tenant regime: many small tensors, one block each.  On CPU this
    # lands at ~parity (XLA dispatch is cheap there); the point of
    # correct_batch is eliminating per-tensor dispatch + host sync on
    # accelerators, where launch overhead dominates small corrections.
    br, bs = bench_batched(
        n_tensors=16 if args.quick else 64,
        size=4096,
        block=4096,
        max_iters=8,
        repeat=repeat,
    )
    rows += br
    print(f"batched: correct_batch vs per-tensor loop speedup = {bs:.2f}x")

    meta = {
        "backend": jax.default_backend(),
        "device": str(jax.devices()[0]),
    }
    with open(args.out, "w") as f:
        json.dump({"meta": meta, "rows": rows}, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
