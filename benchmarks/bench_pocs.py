"""POCS core throughput: complex-FFT oracle vs Hermitian rFFT fast path,
the fft_impl transform selector (XLA vs pack-trick C2R vs fused Pallas
epilogues — ISSUE 5), single-field vs batched multi-tenant correction,
engine device path vs the legacy host-numpy loop, and batched vs sharded
engine backends.

Emits ``BENCH_pocs.json`` (repo root / cwd) with iterations/s and MB/s per
configuration — the anchor for the rFFT fast-path speedup claimed in
ROADMAP.  Both paths of each pair run the *same* iteration count (a
deliberately infeasible-in-N-iterations bound configuration), so wall-clock
ratios are per-iteration ratios.

The sharded-backend case needs >1 device, so it runs in a subprocess with
``--xla_force_host_platform_device_count`` set (fake CPU devices share the
same physical cores, so the row measures shard_map overhead/parity on CPU;
real distribution wins land on a multi-chip mesh).

Usage:  PYTHONPATH=src python benchmarks/bench_pocs.py [--quick]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blockwise import blockwise_correct, correct_batch
from repro.core.pocs import alternating_projection


def _bench(fn, repeat: int = 5):
    fn()  # warmup / compile
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _bench_pair(fa, fb, repeat: int = 10):
    """Interleaved best-of timing: both candidates sample the same background
    load windows, so contention noise cancels out of the ratio."""
    fa(), fb()  # warmup / compile
    best_a = best_b = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fa())
        best_a = min(best_a, time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fb())
        best_b = min(best_b, time.perf_counter() - t0)
    return best_a, best_b


def bench_single(shape, max_iters: int, repeat: int):
    """Complex vs rfft path on one field, identical forced iteration count.

    The bound configuration is the paper's slow nearly-tangential regime
    (§III), built adversarially: every point sits on an s-cube face with an
    imbalanced sign pattern (nonzero mean), and the f-cube pins the DC
    component — POCS crawls, needing ~18+ iterations, so with a smaller
    ``max_iters`` cap both paths run *exactly* ``max_iters`` iterations and
    wall-clock ratios are per-iteration ratios.
    """
    eps0_np, E, Delta_np = _adversarial_field(shape)
    eps0 = jnp.asarray(eps0_np)
    Delta = jnp.asarray(Delta_np)

    for use_rfft in (False, True):
        res = alternating_projection(eps0, E, Delta, max_iters=max_iters, use_rfft=use_rfft)
        iters = int(res.iterations)
        assert iters == max_iters, f"hit feasibility at {iters} < {max_iters}; retune the bench"

    t_c, t_r = _bench_pair(
        lambda: alternating_projection(eps0, E, Delta, max_iters=max_iters, use_rfft=False).eps,
        lambda: alternating_projection(eps0, E, Delta, max_iters=max_iters, use_rfft=True).eps,
        repeat,
    )
    speedup = t_c / t_r
    mb = eps0.size * 4 / 1e6
    rows = [
        {
            "bench": "single",
            "path": path,
            "shape": list(shape),
            "iterations": max_iters,
            "wall_s": t,
            "iters_per_s": max_iters / t,
            "mb_per_s": mb * max_iters / t,
            "speedup_rfft_vs_complex": speedup,
        }
        for path, t in (("complex", t_c), ("rfft", t_r))
    ]
    return rows, speedup


def bench_roi(shape, max_iters: int, repeat: int):
    """Scalar-E vs pointwise ROI-grid s-cube clip, identical forced iterations.

    The ROI bound path (ISSUE 9) swaps the scalar ``clip(eps, -E, E)`` for a
    broadcast clip against a field-shaped bound grid, plus one cold-start
    pre-projection before iteration 0.  Both are elementwise O(N) against the
    loop's O(N log N) FFTs, so the ratio should sit near 1.0 — the row exists
    to catch a pointwise-clip implementation accidentally falling off the
    fused path (the CI floor is a collapse guard, not a speedup claim).
    """
    eps0_np, E, Delta_np = _adversarial_field(shape)
    eps0 = jnp.asarray(eps0_np)
    Delta = jnp.asarray(Delta_np)
    E_grid_np = np.full(shape, E, dtype=np.float32)
    sl = tuple(slice(0, n // 4) for n in shape)
    E_grid_np[sl] = 0.5 * E  # a corner-block ROI with a 2x tighter bound
    E_grid = jnp.asarray(E_grid_np)

    for bound in (E, E_grid):
        res = alternating_projection(eps0, bound, Delta, max_iters=max_iters)
        iters = int(res.iterations)
        assert iters == max_iters, f"hit feasibility at {iters} < {max_iters}; retune the bench"

    t_u, t_r = _bench_pair(
        lambda: alternating_projection(eps0, E, Delta, max_iters=max_iters).eps,
        lambda: alternating_projection(eps0, E_grid, Delta, max_iters=max_iters).eps,
        repeat,
    )
    speedup = t_u / t_r
    mb = eps0.size * 4 / 1e6
    rows = [
        {
            "bench": "single",
            "path": "roi-vs-uniform",
            "shape": list(shape),
            "iterations": max_iters,
            "wall_s": t_r,
            "wall_s_uniform": t_u,
            "iters_per_s": max_iters / t_r,
            "mb_per_s": mb * max_iters / t_r,
            "speedup_roi_vs_uniform": speedup,
        }
    ]
    return rows, speedup


def bench_batched(n_tensors: int, size: int, block: int, max_iters: int, repeat: int):
    """Per-tensor dispatch loop vs one batched correct_batch device program."""
    rng = np.random.default_rng(1)
    # host-side arrays: correct_batch donates its inputs, so both paths get a
    # fresh device copy per call (transfer cost counted identically for both)
    tensors_np = [rng.standard_normal(size).astype(np.float32) * 0.01 for _ in range(n_tensors)]
    E, Delta = 0.02, 0.02  # tight Delta => real iteration work per block

    def loop():
        return [
            blockwise_correct(jnp.asarray(t), E, Delta, block=block, max_iters=max_iters)
            for t in tensors_np
        ]

    def batched():
        outs, _stats = correct_batch(tensors_np, E, Delta, block=block, max_iters=max_iters)
        return outs

    t_loop, t_batch = _bench_pair(loop, batched, repeat)
    mb = n_tensors * size * 4 / 1e6
    speedup = t_loop / t_batch
    return [
        {
            "bench": "batched",
            "path": "per-tensor-loop",
            "n_tensors": n_tensors,
            "size": size,
            "block": block,
            "wall_s": t_loop,
            "mb_per_s": mb / t_loop,
            "speedup_batched_vs_loop": speedup,
        },
        {
            "bench": "batched",
            "path": "correct_batch",
            "n_tensors": n_tensors,
            "size": size,
            "block": block,
            "wall_s": t_batch,
            "mb_per_s": mb / t_batch,
            "speedup_batched_vs_loop": speedup,
        },
    ], speedup


def _adversarial_field(shape, E=0.05):
    """The forced-iteration workload of bench_single (see its docstring)."""
    rng = np.random.default_rng(0)
    sgn = np.where(rng.random(shape) < 0.52, 1.0, -1.0)
    eps0 = (E * sgn * (1 - 1e-4 * rng.random(shape))).astype(np.float32)
    F = np.abs(np.fft.fftn(eps0))
    Delta = (1e9 * np.ones(shape)).astype(np.float32)
    Delta.reshape(-1)[0] = 0.01 * F.reshape(-1)[0]
    return eps0, E, Delta


def bench_fft_impls(shape, max_iters: int, repeat: int):
    """POCS transform selector: fft_impl='xla' vs 'packed' vs 'pallas'.

    The forced-iteration adversarial field of :func:`bench_single` (both
    paths run exactly ``max_iters`` iterations — asserted), so the ratio is
    a per-iteration cost ratio isolating the transform swap: XLA's C2R
    inverse custom call vs the pack-trick inverse of
    :mod:`repro.kernels.rfft` (the forward keeps XLA's r2c on both sides).

    Emits the ``rfft-xla`` / ``rfft-packed`` pair (the ISSUE 5 acceptance
    anchor: packed >= 1.15x on the 512^2 CPU case, gated by
    ``ci/check_bench.py``) plus the ``rfft-pallas-fused`` row — the fused
    clip+count+twiddle epilogue kernels, which run EMULATED (interpret mode)
    on CPU: that row prices the emulation, not the kernels; the fusion win
    is a TPU/Mosaic claim, benched here only for conformance freshness.
    """
    eps0_np, E, Delta_np = _adversarial_field(shape)
    eps0 = jnp.asarray(eps0_np)
    Delta = jnp.asarray(Delta_np[..., : shape[-1] // 2 + 1])

    for impl in ("xla", "packed", "pallas"):
        res = alternating_projection(eps0, E, Delta, max_iters=max_iters, fft_impl=impl)
        iters = int(res.iterations)
        assert iters == max_iters, f"{impl}: hit feasibility at {iters}; retune the bench"

    run = lambda impl: alternating_projection(  # noqa: E731
        eps0, E, Delta, max_iters=max_iters, fft_impl=impl
    ).eps
    # the packed pair is the thresholded acceptance row: extra repeats keep
    # the best-of estimate stable on noisy shared-core containers
    t_x, t_p = _bench_pair(lambda: run("xla"), lambda: run("packed"), repeat * 3 // 2)
    t_x2, t_pl = _bench_pair(lambda: run("xla"), lambda: run("pallas"), max(repeat // 2, 2))
    s_packed = t_x / t_p
    s_pallas = t_x2 / t_pl
    mb = eps0.size * 4 / 1e6
    rows = [
        {
            "bench": "single",
            "path": path,
            "shape": list(shape),
            "iterations": max_iters,
            "wall_s": t,
            "iters_per_s": max_iters / t,
            "mb_per_s": mb * max_iters / t,
            "speedup_packed_vs_xla": s_packed,
        }
        for path, t in (("rfft-xla", t_x), ("rfft-packed", t_p))
    ]
    rows.append(
        {
            "bench": "single",
            "path": "rfft-pallas-fused",
            "shape": list(shape),
            "iterations": max_iters,
            "wall_s": t_pl,
            "iters_per_s": max_iters / t_pl,
            "mb_per_s": mb * max_iters / t_pl,
            "speedup_pallas_vs_xla": s_pallas,
            "interpret_mode": jax.default_backend() == "cpu",
        }
    )
    return rows, s_packed, s_pallas


def bench_engine_field(shape, max_iters: int, repeat: int):
    """Engine EXECUTE device program vs a host-numpy POCS oracle loop.

    NOT a before/after of the engine refactor: the POCS loop was already a
    jitted device program pre-engine (only bound resolution and the polish
    lived on host).  This row anchors what a host-orchestrated numpy loop —
    the paper's CPU reference shape, and the style of the float64 polish —
    costs per iteration relative to the device-resident program, i.e. the
    price of ever falling off the device path.  Both sides run exactly
    ``max_iters`` iterations on the adversarial field (the exact float64
    polish is excluded: its cost is O(convergence residual) in production,
    and the forced-iteration workload is deliberately never convergent).
    """
    eps0_np, E, Delta_np = _adversarial_field(shape)
    Delta_half = Delta_np[..., : shape[-1] // 2 + 1]
    eps0 = jnp.asarray(eps0_np)
    Delta = jnp.asarray(Delta_np)

    def host_loop():
        # host-numpy oracle: the same rfft loop at float32 storage
        eps = eps0_np
        for _ in range(max_iters):
            d = np.fft.rfftn(eps)
            clipped = np.clip(d.real, -Delta_half, Delta_half) + 1j * np.clip(
                d.imag, -Delta_half, Delta_half
            )
            eps = np.clip(
                np.fft.irfftn(clipped, s=shape, axes=tuple(range(len(shape)))), -E, E
            ).astype(np.float32)
        return eps

    def engine_device():
        return alternating_projection(eps0, E, Delta, max_iters=max_iters).eps

    res = alternating_projection(eps0, E, Delta, max_iters=max_iters)
    assert int(res.iterations) == max_iters, "retune the bench"
    t_host, t_dev = _bench_pair(host_loop, engine_device, repeat)
    mb = eps0.size * 4 / 1e6
    speedup = t_host / t_dev
    rows = [
        {
            "bench": "engine_field",
            "path": path,
            "shape": list(shape),
            "iterations": max_iters,
            "wall_s": t,
            "iters_per_s": max_iters / t,
            "mb_per_s": mb * max_iters / t,
            "speedup_engine_vs_host": speedup,
        }
        for path, t in (("host-numpy-oracle", t_host), ("engine-device", t_dev))
    ]
    return rows, speedup


def bench_stream(shape, n_frames: int, repeat: int):
    """POCS warm start vs cold start along a coherent temporal sequence.

    The temporal codec (ISSUE 8) seeds frame *t*'s ``freq_edits`` accumulator
    with frame *t-1*'s converged spectrum.  This row measures that win at the
    ``alternating_projection`` level, isolated from base-codec and container
    cost: a sequence of adversarial fields (the DC-pinned slow-convergence
    regime of :func:`bench_single`, ~20-30 cold iterations) sharing a slowly
    drifting structured component.  Cold runs every residual frame from
    scratch; warm chains each frame off the previous warm frame's spectrum —
    exactly the codec's wiring.  ``iter_reduction_warm_vs_cold`` (mean cold /
    mean warm iterations over the residual frames, deterministic) is the
    ISSUE 8 acceptance anchor, gated >= 1.2x by ``ci/check_bench.py``; the
    wall-clock pair is reported alongside but carries no bar (per-iteration
    cost is identical — fewer iterations IS the win).
    """
    eps0_np, E, Delta_np = _adversarial_field(shape)
    drift = np.cos(np.linspace(0, 2 * np.pi, eps0_np.size)).reshape(shape).astype(np.float32)
    frames = [
        np.clip(eps0_np + 0.02 * E * t * drift, -E, E).astype(np.float32)
        for t in range(n_frames)
    ]
    Delta = jnp.asarray(Delta_np)
    max_iters = 200

    def run(f, warm=None):
        return alternating_projection(jnp.asarray(f), E, Delta, max_iters=max_iters, warm_freq=warm)

    cold_iters, warm_iters = [], []
    warm = None
    for t, f in enumerate(frames):
        rc = run(f)
        rw = run(f, warm) if warm is not None else rc
        assert bool(rc.converged) and bool(rw.converged), "stream bench frame diverged; retune"
        if t > 0:
            cold_iters.append(int(rc.iterations))
            warm_iters.append(int(rw.iterations))
        warm = rw.freq_edits
    ratio = float(np.mean(cold_iters) / np.mean(warm_iters))

    warm0 = run(frames[0]).freq_edits

    def cold_seq():
        return [run(f).eps for f in frames[1:]]

    def warm_seq():
        w, outs = warm0, []
        for f in frames[1:]:
            r = run(f, w)
            w = r.freq_edits
            outs.append(r.eps)
        return outs

    t_cold, t_warm = _bench_pair(cold_seq, warm_seq, repeat)
    return [
        {
            "bench": "stream",
            "path": "warm-vs-cold",
            "shape": list(shape),
            "n_frames": n_frames,
            "max_iters": max_iters,
            "mean_iters_cold": float(np.mean(cold_iters)),
            "mean_iters_warm": float(np.mean(warm_iters)),
            "iter_reduction_warm_vs_cold": ratio,
            "wall_s_cold": t_cold,
            "wall_s": t_warm,
            "speedup_warm_vs_cold_wall": t_cold / t_warm,
        }
    ], ratio


def bench_stream_eeg(n_frames: int, channels: int, samples: int, repeat: int):
    """End-to-end TemporalCodec throughput on the EEG routing: channels x
    time frames through the pencil ``correct_batch`` path (block = the time
    axis, one pencil per channel row), linear predictor, warm starts on.
    Reports wall-clock, MB/s and the compressed-size ratio; no threshold —
    the measured warm-start claim lives in the ``warm-vs-cold`` row, and
    absolute CPU throughput here prices the whole stack (base codec, POCS,
    entropy coding, container)."""
    from repro.compressors import get_compressor
    from repro.core.ffcz import FFCzConfig
    from repro.core.temporal import TemporalCodec, TemporalConfig

    rng = np.random.default_rng(3)
    base = (rng.standard_normal((channels, samples)) * 0.3).cumsum(axis=1)
    shared = np.sin(np.linspace(0, 6 * np.pi, samples))[None, :]
    frames = [
        np.ascontiguousarray(
            base + 0.05 * t * shared + 0.01 * rng.standard_normal((channels, samples)),
            np.float32,
        )
        for t in range(n_frames)
    ]
    codec = TemporalCodec(
        get_compressor("szlike"),
        FFCzConfig(E_rel=1e-3, Delta_rel=1e-3, max_iters=300, warm_start=True),
        TemporalConfig(mode="pencils", predictor="linear", keyframe_interval=8),
    )
    data = codec.compress_stream(frames)  # warmup / compile

    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        codec.compress_stream(frames)
        best = min(best, time.perf_counter() - t0)
    raw_mb = n_frames * channels * samples * 4 / 1e6
    enc = codec.open_stream()
    for f in frames:
        enc.add_frame(f)
    iters = [s["iterations"] for s in enc.frame_stats]
    return [
        {
            "bench": "stream",
            "path": "eeg-pencils",
            "shape": [channels, samples],
            "n_frames": n_frames,
            "wall_s": best,
            "mb_per_s": raw_mb / best,
            "compressed_ratio": raw_mb * 1e6 / len(data),
            "mean_iters": float(np.mean(iters)),
            "converged": all(s["converged"] for s in enc.frame_stats),
        }
    ], raw_mb / best


_BACKEND_CHILD = "--_backend-child"


def bench_dist_field_child(n_devices: int, shape, max_iters: int, repeat: int, suffix: str = ""):
    """Whole-field POCS: fused single-device loop vs the pencil-sharded loop.

    Runs inside the multi-device subprocess.  Both sides run exactly
    ``max_iters`` forced iterations on the adversarial field (asserted), so
    the ratio is a per-iteration cost ratio.  On fake CPU devices the shards
    share physical cores, so this row measures the all_to_all transpose
    overhead and gates parity; distribution wins land on a real mesh where
    the slabs live on different HBMs.

    ``suffix`` distinguishes case kinds: the ``-uneven`` rows run a padded
    uneven (non-divisible, non-power-of-two) slab decomposition — parity
    there is bound-holding, not bitwise, so they compare to float32
    tolerance and price the pad/slice overhead of the generalized transposes.
    """
    from jax.sharding import NamedSharding

    # the engine's own compiled program, so the bench measures exactly what
    # FFCz.compress ships (one shared builder, no hand-copied shard_map spec)
    from repro.core.engine import _sharded_field_pocs_fn
    from repro.sharding.dist_fft import ShardedField

    eps0_np, E, Delta_np = _adversarial_field(shape)
    Delta_half = Delta_np[..., : shape[-1] // 2 + 1]
    eps0 = jnp.asarray(eps0_np)
    Delta = jnp.asarray(Delta_np)

    field = ShardedField.shard(eps0_np)
    eps_sh = field.array
    delta_sh = jax.device_put(
        field.pad_freq_np(Delta_half), NamedSharding(field.mesh, field.freq_spec)
    )
    E32, slack32 = np.float32(E), np.float32(0.0)
    pocs = _sharded_field_pocs_fn(field.mesh, field.dist_spec, True, max_iters, 1.0)

    r_single = alternating_projection(eps0, E, Delta, max_iters=max_iters)
    r_dist = pocs(eps_sh, delta_sh, E32, slack32)
    assert int(r_single.iterations) == max_iters, "retune the bench"
    assert int(r_dist.iterations) == max_iters, "dist loop diverged from fused loop"
    eps_dist = np.asarray(field.unpad_spatial(r_dist.eps))
    if field.parity == "bitwise":
        assert np.array_equal(np.asarray(r_single.eps), eps_dist), "parity"
    else:
        assert np.allclose(np.asarray(r_single.eps), eps_dist, atol=2e-6 * E), "parity"

    t_single, t_dist = _bench_pair(
        lambda: alternating_projection(eps0, E, Delta, max_iters=max_iters).eps,
        lambda: pocs(eps_sh, delta_sh, E32, slack32).eps,
        repeat,
    )
    mb = eps0.size * 4 / 1e6
    ratio = t_single / t_dist
    return [
        {
            "bench": "dist_field",
            "path": path + suffix,
            "n_devices": n_devices,
            "shape": list(shape),
            "parity": field.parity,
            "iterations": max_iters,
            "wall_s": t,
            "iters_per_s": max_iters / t,
            "mb_per_s": mb * max_iters / t,
            "speedup_pencil_vs_fused": ratio,
        }
        for path, t in (("fused-single-device", t_single), ("pencil-sharded", t_dist))
    ]


def bench_backends_child(n_devices: int, n_tensors: int, size: int, block: int, max_iters: int, repeat: int):
    """Runs inside the multi-device subprocess: batched vs sharded backend."""
    from repro.core.engine import CorrectionEngine

    rng = np.random.default_rng(1)
    tensors_np = [rng.standard_normal(size).astype(np.float32) * 0.01 for _ in range(n_tensors)]
    E, Delta = 0.02, 0.02
    eng_b = CorrectionEngine("batched")
    eng_s = CorrectionEngine("sharded")

    t_b, t_s = _bench_pair(
        lambda: eng_b.correct(tensors_np, E, Delta, block=block, max_iters=max_iters)[0],
        lambda: eng_s.correct(tensors_np, E, Delta, block=block, max_iters=max_iters)[0],
        repeat,
    )
    mb = n_tensors * size * 4 / 1e6
    ratio = t_b / t_s
    return [
        {
            "bench": "backend",
            "path": path,
            "n_devices": n_devices,
            "n_tensors": n_tensors,
            "size": size,
            "block": block,
            "wall_s": t,
            "mb_per_s": mb / t,
            "speedup_sharded_vs_batched": ratio,
        }
        for path, t in (("batched", t_b), ("sharded", t_s))
    ]


def bench_backends(n_devices: int, quick: bool):
    """Spawn the sharded-vs-batched comparison on a fake multi-device mesh
    (XLA_FLAGS must be set before jax import, hence the subprocess)."""
    env = dict(os.environ)
    # append so caller-supplied compiler flags apply to this row too
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + f" --xla_force_host_platform_device_count={n_devices}"
    ).strip()
    cmd = [sys.executable, os.path.abspath(__file__), _BACKEND_CHILD, str(n_devices)]
    if quick:
        cmd.append("--quick")
    try:
        proc = subprocess.run(capture_output=True, text=True, env=env, args=cmd, timeout=600)
    except subprocess.TimeoutExpired:
        print("backend bench subprocess timed out; skipping the backend rows")
        return []
    if proc.returncode != 0:
        print(f"backend bench subprocess failed:\n{proc.stderr[-2000:]}")
        return []
    lines = [l for l in proc.stdout.splitlines() if l.startswith("ROWS:")]
    if not lines:
        print("backend bench subprocess produced no ROWS line; skipping")
        return []
    return json.loads(lines[0][len("ROWS:"):])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="smaller shapes / fewer repeats")
    ap.add_argument("--out", default="BENCH_pocs.json")
    ap.add_argument(_BACKEND_CHILD, type=int, default=0, dest="backend_child", help=argparse.SUPPRESS)
    ap.add_argument("--devices", type=int, default=8, help="fake device count for the sharded-backend case")
    args = ap.parse_args()

    if args.backend_child:
        rows = bench_backends_child(
            n_devices=args.backend_child,
            n_tensors=16 if args.quick else 64,
            size=4096,
            block=4096,
            max_iters=8,
            repeat=3 if args.quick else 16,
        )
        rows += bench_dist_field_child(
            n_devices=args.backend_child,
            shape=(64, 32, 16) if args.quick else (128, 128, 64),
            max_iters=8 if args.quick else 20,
            repeat=3 if args.quick else 16,
        )
        # uneven padded decomposition: axis 0 non-divisible by the mesh and
        # non-power-of-two (the generalized-slab code path end to end)
        rows += bench_dist_field_child(
            n_devices=args.backend_child,
            shape=(30, 32, 16) if args.quick else (100, 80, 56),
            max_iters=8 if args.quick else 20,
            repeat=3 if args.quick else 16,
            suffix="-uneven",
        )
        print("ROWS:" + json.dumps(rows))
        return

    repeat = 3 if args.quick else 16
    max_iters = 8 if args.quick else 20  # below the config's ~22-iteration natural count
    # production-scale fields: the FFT's N log N term dominates the linear
    # elementwise stages, so these show the fast path's real ratio
    shapes = [(512, 512), (128, 128, 64)] if not args.quick else [(128, 128)]

    rows = []
    for shape in shapes:
        r, s = bench_single(shape, max_iters, repeat)
        rows += r
        print(f"single {shape}: rfft vs complex speedup = {s:.2f}x")
    for shape in shapes:
        r, s = bench_roi(shape, max_iters, repeat)
        rows += r
        print(f"single {shape}: roi-grid vs uniform-E clip ratio = {s:.2f}x")
    for shape in shapes:
        r, sp, spl = bench_fft_impls(shape, max_iters, repeat)
        rows += r
        print(
            f"fft_impl {shape}: packed vs xla = {sp:.2f}x, "
            f"pallas(interpret) vs xla = {spl:.2f}x"
        )
    for shape in shapes:
        r, s = bench_engine_field(shape, max_iters, repeat)
        rows += r
        print(f"engine {shape}: device execute vs host-numpy oracle = {s:.2f}x")
    # Multi-tenant regime: many small tensors, one block each.  On CPU this
    # lands at ~parity (XLA dispatch is cheap there); the point of
    # correct_batch is eliminating per-tensor dispatch + host sync on
    # accelerators, where launch overhead dominates small corrections.
    br, bs = bench_batched(
        n_tensors=16 if args.quick else 64,
        size=4096,
        block=4096,
        max_iters=8,
        repeat=repeat,
    )
    rows += br
    print(f"batched: correct_batch vs per-tensor loop speedup = {bs:.2f}x")
    sr, s_ratio = bench_stream(
        shape=(128, 128) if args.quick else (256, 256),
        n_frames=4 if args.quick else 8,
        repeat=max(repeat // 2, 2),
    )
    rows += sr
    print(f"stream: warm vs cold POCS iteration reduction = {s_ratio:.2f}x")
    er, e_mbps = bench_stream_eeg(
        n_frames=4 if args.quick else 16,
        channels=8 if args.quick else 32,
        samples=128 if args.quick else 512,
        repeat=2 if args.quick else 5,
    )
    rows += er
    print(f"stream: eeg-pencils end-to-end = {e_mbps:.2f} MB/s")
    backend_rows = bench_backends(args.devices, args.quick)
    rows += backend_rows
    if backend_rows:
        print(
            f"backends ({args.devices} fake devices): sharded vs batched = "
            f"{backend_rows[0]['speedup_sharded_vs_batched']:.2f}x"
        )
        dist_rows = [
            r
            for r in backend_rows
            if r["bench"] == "dist_field" and r["path"].startswith("fused")
        ]
        for r in dist_rows:
            kind = "uneven " if r["path"].endswith("-uneven") else ""
            print(
                f"dist_field {kind}({args.devices} fake devices, shape "
                f"{tuple(r['shape'])}, parity {r['parity']}): pencil-sharded vs "
                f"fused single-device = {r['speedup_pencil_vs_fused']:.2f}x"
            )

    meta = {
        "backend": jax.default_backend(),
        "device": str(jax.devices()[0]),
    }
    with open(args.out, "w") as f:
        json.dump({"meta": meta, "rows": rows}, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
