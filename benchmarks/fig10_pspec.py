"""Paper Fig. 10: power-spectrum preservation with pointwise bounds.

FFCz with pspec_rel=0.1% must keep every shell of P(k) within the ribbon;
the base compressor at the same bitrate drifts outside.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import save_results
from repro.compressors import get_compressor
from repro.core.ffcz import FFCz, FFCzConfig
from repro.core.spectrum import bitrate, power_spectrum_relative_error
from repro.data.fields import make_field

PSPEC_REL = 1e-3


def run(quick: bool = False):
    rows = []
    x = make_field("nyx-like")[:48, :48, :48] if not quick else make_field("nyx-like")[:32, :32, :32]
    base = get_compressor("szlike")
    c = FFCz(base, FFCzConfig(E_rel=1e-3, Delta_rel=None, pspec_rel=PSPEC_REL, max_iters=2500))
    xh, blob = c.roundtrip(x)
    _, rel_ours = power_spectrum_relative_error(xh, x)
    rate = bitrate(blob.stats.total_bytes, x.size)

    # base at the same bitrate: loosen E until bytes match
    E = 1e-3 * np.ptp(x)
    target = blob.stats.total_bytes
    bb = base.compress(x, E)
    for _ in range(12):
        if len(bb) <= target * 1.05:
            break
        E *= 1.5
        bb = base.compress(x, E)
    xb = base.decompress(bb)
    _, rel_base = power_spectrum_relative_error(xb, x)

    rows.append({
        "bench": "fig10", "method": "ffcz", "bitrate": rate,
        "max_abs_rel_pspec_err": float(np.abs(rel_ours[1:]).max()),
        "within_ribbon": bool(np.abs(rel_ours[1:]).max() <= PSPEC_REL * 1.05),
        "iterations": blob.stats.iterations,
    })
    rows.append({
        "bench": "fig10", "method": "sz-native", "bitrate": bitrate(len(bb), x.size),
        "max_abs_rel_pspec_err": float(np.abs(rel_base[1:]).max()),
        "within_ribbon": bool(np.abs(rel_base[1:]).max() <= PSPEC_REL * 1.05),
    })
    save_results("fig10_pspec", rows)
    return rows


COLUMNS = ["bench", "method", "bitrate", "max_abs_rel_pspec_err", "within_ribbon", "iterations"]
