"""Serving throughput: pipelined (host ENCODE || device EXECUTE) vs serial.

Drives matched :class:`~repro.serving.ffcz_service.FFCzService` pairs —
``pipeline_depth=1`` (serial) vs ``pipeline_depth=2`` (pipelined) — over the
same seeded workload, two ways:

  saturating     the whole workload is queued up front (offered load is
                 infinite), so sustained throughput is requests / drain wall
                 time.  This is the ISSUE 7 acceptance measurement, recorded
                 as ``serve/pipelined-vs-serial``.
  offered-load   an open-loop arrival process at each ``--arrival-rates``
                 rate: requests are admitted on a clock while the driver
                 steps the service between arrivals, measuring achieved
                 throughput and p50/p99 latency under that offered load
                 (``serve/load-sweep`` rows).
  session-append per-frame incremental arrival through the durable session
                 path (open / append / finalize, one drain per frame — the
                 live-arrival model) vs one ``submit_stream`` over the same
                 frames, recorded as ``serve/session-append`` with the
                 wall-ratio ``speedup_session_vs_stream``; the finalize
                 container is asserted byte-identical to the stream path.

Workload mix, bounds, and fault probabilities reuse the
``launch/serve_ffcz.py`` flag groups, so any chaos configuration the service
CLI can serve, the bench can measure.  Pencil sizes are FIXED (2x block) so
bucket shapes repeat and jit compilation amortizes — the bench measures
steady-state serving, not compile time (a warmup drain precedes every timed
run for the same reason).

Rows merge into ``BENCH_pocs.json`` (replacing prior ``serve`` rows, keeping
every other bench's), with host/device busy fractions from the service's
stage timers and the host ``cpu_count`` — a single-core container cannot
overlap host and device work, and ``ci/check_bench.py`` gates the speedup
floor on that field.

Usage:  PYTHONPATH=src python benchmarks/bench_serve.py [--quick]
        PYTHONPATH=src python benchmarks/bench_serve.py --arrival-rates 5,20,80
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core.temporal import TemporalConfig
from repro.launch.serve_ffcz import (
    add_fault_args,
    add_service_args,
    add_workload_args,
    build_service,
    field_config,
)


def _submit_one(svc, rng, args, cfg):
    """One request from the bench mix: fixed-size pencil or fixed-size field,
    so every bucket shape repeats and the jit cache stays warm."""
    if rng.random() < args.pencil_frac:
        x = rng.standard_normal(2 * args.block).astype(np.float32)
        return svc.submit_pencils(x, args.e_rel, args.delta_rel)
    edge = args.field_size
    return svc.submit_compress(rng.standard_normal((edge, edge)).astype(np.float32), cfg)


def _warmup(svc, args, rng_seed, n):
    """Replay the exact timed submission sequence once: bucket shapes depend
    on the pencil/field interleaving, and every distinct shape is a jit
    compilation — the timed run must only ever hit the warm cache."""
    cfg = field_config(args)
    rng = np.random.default_rng(rng_seed)
    for _ in range(n):
        _submit_one(svc, rng, args, cfg)
    svc.drain()


def _fractions(svc, wall):
    host = svc.timers["front_s"] + svc.timers["encode_s"] + svc.timers["decode_s"]
    return {
        "host_busy_frac": round(host / wall, 4),
        "device_wait_frac": round(svc.timers["execute_s"] / wall, 4),
    }


def _percentiles(lats):
    lats = np.asarray(lats, dtype=np.float64)
    return {
        "p50_ms": round(float(np.percentile(lats, 50)) * 1e3, 3),
        "p99_ms": round(float(np.percentile(lats, 99)) * 1e3, 3),
    }


def run_saturating(args, depth, n_requests):
    """Closed-loop: everything queued up front, drain, measure the wall."""
    svc = build_service(args, pipeline_depth=depth)
    _warmup(svc, args, rng_seed=args.seed + 1, n=n_requests)
    for k in svc.timers:
        svc.timers[k] = 0.0
    cfg = field_config(args)
    rng = np.random.default_rng(args.seed + 1)
    t0 = time.perf_counter()
    uids = [_submit_one(svc, rng, args, cfg) for _ in range(n_requests)]
    res = svc.drain()
    wall = time.perf_counter() - t0
    svc.close()
    assert set(res) == set(uids) and all(res[u].ok for u in uids), (
        "bench workload must fully complete; rejections mean the measurement "
        "is comparing different work"
    )
    lats = [res[u].stats.latency_s for u in uids]
    return wall, lats, _fractions(svc, wall)


def run_open_loop(args, depth, n_requests, rate_rps):
    """Open-loop arrival process at ``rate_rps``; the driver steps the
    service between arrivals so in-flight work progresses while the next
    request is still 'in the network'."""
    svc = build_service(args, pipeline_depth=depth)
    _warmup(svc, args, rng_seed=args.seed + 2, n=n_requests)
    cfg = field_config(args)
    rng = np.random.default_rng(args.seed + 2)
    interval = 1.0 / rate_rps
    res = {}
    t0 = time.perf_counter()
    uids = []
    for i in range(n_requests):
        due = t0 + i * interval
        while time.perf_counter() < due:
            if svc.pending:
                for r in svc.step():
                    res[r.uid] = r
            else:
                time.sleep(min(2e-4, max(0.0, due - time.perf_counter())))
        uids.append(_submit_one(svc, rng, args, cfg))
    res.update(svc.drain())
    wall = time.perf_counter() - t0
    svc.close()
    lats = [res[u].stats.latency_s for u in uids]
    return wall, lats


def _session_workload(args, n_frames, seed):
    """A coherent drifting field sequence, same shape discipline as the
    request mix: one fixed ``--field-size`` edge so jit stays warm."""
    rng = np.random.default_rng(seed)
    edge = args.field_size
    x = rng.standard_normal((edge, edge)).astype(np.float32)
    frames = [x]
    for _ in range(n_frames - 1):
        x = x + 0.05 * rng.standard_normal((edge, edge)).astype(np.float32)
        frames.append(x)
    return frames


def run_session_bench(args, n_frames):
    """serve/session-append: incremental per-frame arrival through the
    durable session path (open / append+drain per frame / finalize) vs one
    ``submit_stream`` over the same frames.  The session path prices
    admission, per-append journaling, and receipt bookkeeping on top of the
    same encode work, so the ratio sits near (below) 1.0 — the recorded
    ``speedup_session_vs_stream`` guards that overhead against collapse,
    it is not a speedup claim.  Appends drain one at a time because that is
    the live-arrival model the session exists for: the next frame does not
    exist until the previous ack."""
    svc = build_service(args, pipeline_depth=2)
    cfg = field_config(args)
    stream = TemporalConfig(mode="field", predictor="linear", keyframe_interval=4)
    frames = _session_workload(args, n_frames, args.seed + 3)

    def one_session():
        sid = svc.open_session(cfg, stream)
        lats = []
        for t, frame in enumerate(frames):
            t0 = time.perf_counter()
            uid = svc.submit_append(sid, t, frame)
            res = svc.drain()
            lats.append(time.perf_counter() - t0)
            assert res[uid].ok, f"bench append failed: {res[uid].error}"
        fin = svc.submit_finalize(sid)
        res = svc.drain()
        assert res[fin].ok
        return lats, res[fin].payload

    def one_stream():
        uid = svc.submit_stream(frames, cfg, stream)
        res = svc.drain()
        assert res[uid].ok
        return res[uid].payload

    one_session()  # warmup: first session compiles every bucket shape
    one_stream()
    for k in svc.timers:
        svc.timers[k] = 0.0

    t0 = time.perf_counter()
    lats, session_container = one_session()
    session_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    stream_container = one_stream()
    stream_wall = time.perf_counter() - t0
    svc.close()
    assert session_container == stream_container, (
        "session finalize must be byte-identical to submit_stream over the "
        "same frames (warm_start=False); the paths diverged"
    )
    return session_wall, stream_wall, lats


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="tiny run: emits every serve/* row kind for the CI "
                         "coverage check, measures nothing trustworthy")
    ap.add_argument("--out", default="BENCH_pocs.json",
                    help="merge serve rows into this bench record")
    ap.add_argument("--requests-per-run", type=int, default=0,
                    help="requests per timed run (0 = 12 quick / 96 full)")
    ap.add_argument("--arrival-rates", default="",
                    help="comma-separated offered loads (req/s) for the "
                         "open-loop sweep (default: one mid rate)")
    add_service_args(ap)
    add_workload_args(ap)
    add_fault_args(ap)
    args = ap.parse_args()

    n = args.requests_per_run or (12 if args.quick else 96)
    rates = [float(r) for r in args.arrival_rates.split(",") if r] or [20.0]
    if args.quick:
        rates = rates[:1]
    cpu_count = os.cpu_count() or 1
    shape = [n, args.max_batch, args.block, args.field_size]
    common = {"cpu_count": cpu_count, "pencil_frac": args.pencil_frac}

    rows = []
    rps = {}
    for path, depth in (("serial", 1), ("pipelined", 2)):
        wall, lats, fracs = run_saturating(args, depth, n)
        rps[path] = n / wall
        rows.append({
            "bench": "serve", "path": path, "shape": shape,
            "pipeline_depth": depth, "wall_s": round(wall, 4),
            "rps": round(rps[path], 2), **_percentiles(lats), **fracs, **common,
        })
        print(f"saturating {path:>9} (depth {depth}): {rps[path]:7.2f} req/s  "
              f"host_busy={fracs['host_busy_frac']:.2f} "
              f"device_wait={fracs['device_wait_frac']:.2f}")

    speedup = rps["pipelined"] / rps["serial"]
    rows.append({
        "bench": "serve", "path": "pipelined-vs-serial", "shape": shape,
        "rps_serial": round(rps["serial"], 2),
        "rps_pipelined": round(rps["pipelined"], 2),
        "speedup_pipelined_vs_serial": round(speedup, 4), **common,
    })
    print(f"pipelined vs serial at saturating load: {speedup:.2f}x "
          f"({cpu_count} cpu core(s))")

    for rate in rates:
        wall, lats = run_open_loop(args, 2, n, rate)
        achieved = n / wall
        pct = _percentiles(lats)
        rows.append({
            "bench": "serve", "path": "load-sweep", "shape": shape,
            "pipeline_depth": 2, "offered_rps": rate,
            "achieved_rps": round(achieved, 2), **pct, **common,
        })
        print(f"open loop @ {rate:6.1f} req/s offered: {achieved:7.2f} achieved  "
              f"p50={pct['p50_ms']:.1f}ms p99={pct['p99_ms']:.1f}ms")

    n_frames = 4 if args.quick else 16
    session_wall, stream_wall, append_lats = run_session_bench(args, n_frames)
    # throughput ratio == wall ratio (same frame count both ways); near 1.0
    # means the session machinery (journal, receipts, admission) is cheap
    # next to the encode work, well below means it collapsed
    session_speedup = stream_wall / session_wall
    pct = _percentiles(append_lats)
    rows.append({
        "bench": "serve", "path": "session-append",
        "shape": [n_frames, args.field_size],
        "wall_session_s": round(session_wall, 4),
        "wall_stream_s": round(stream_wall, 4),
        "appends_per_s": round(n_frames / session_wall, 2),
        "speedup_session_vs_stream": round(session_speedup, 4),
        **pct, **common,
    })
    print(f"session append ({n_frames} frames): "
          f"{n_frames / session_wall:7.2f} appends/s  "
          f"vs stream {session_speedup:.2f}x  p99={pct['p99_ms']:.1f}ms")

    record = {"meta": {}, "rows": []}
    if os.path.exists(args.out):
        with open(args.out) as f:
            record = json.load(f)
    kept = [r for r in record.get("rows", []) if r.get("bench") != "serve"]
    record["rows"] = kept + rows
    record.setdefault("meta", {})["serve_cpu_count"] = cpu_count
    with open(args.out, "w") as f:
        json.dump(record, f, indent=1)
    print(f"wrote {len(rows)} serve rows into {args.out}")


if __name__ == "__main__":
    main()
