"""Perf-iteration probe: compile one cell and print the trip-aware collective
attribution + roofline terms.  The §Perf hillclimb's measurement tool.

  PYTHONPATH=src python -m benchmarks.perf_probe --arch qwen2-7b --shape train_4k
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import json  # noqa: E402

import jax  # noqa: E402


def probe(arch: str, shape: str, multi_pod: bool = False, overrides=None, top: int = 14):
    from repro.configs import get_config
    from repro.launch.hlo_cost import analyze_hlo
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import make_step

    cfg = get_config(arch, **(overrides or {}))
    mesh = make_production_mesh(multi_pod=multi_pod)
    step, args, in_sh, out_sh = make_step(cfg, shape, mesh)
    with mesh:
        compiled = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh).lower(*args).compile()
    cost = analyze_hlo(compiled.as_text())

    from benchmarks.roofline import HBM_BW, LINK_BW, PEAK_FLOPS, model_flops, model_memory_bytes

    n_dev = 512 if multi_pod else 256
    coll = sum(cost.collectives.values())
    mf = model_flops(arch, shape, n_dev)
    terms = {
        "compute_s": cost.flops / PEAK_FLOPS,
        "memory_s": model_memory_bytes(arch, shape, n_dev) / HBM_BW,
        "collective_s": coll / LINK_BW,
    }
    bound = max(terms.values())
    print(f"== {arch} x {shape} ==")
    for k, v in terms.items():
        print(f"  {k:14s} {v:.4e}")
    print(f"  dominant       {max(terms, key=terms.get)}")
    print(f"  useful_ratio   {mf / cost.flops:.3f}")
    print(f"  roofline_frac  {(mf / PEAK_FLOPS) / bound:.4f}")
    print(f"  collective breakdown (trip-aware, top {top}):")
    items = sorted(cost.coll_by_name.items(), key=lambda kv: -kv[1])[:top]
    for (kind, name), b in items:
        print(f"    {b:.3e} B  {kind:12s} {name[:110]}")
    return cost, terms


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--top", type=int, default=14)
    args = ap.parse_args()
    probe(args.arch, args.shape, args.multi, top=args.top)


if __name__ == "__main__":
    main()
