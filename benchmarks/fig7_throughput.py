"""Paper Fig. 7: throughput of the base compressors vs the FFCz edit stage.

The key claim (Obs. 3): the edit stage is NOT the pipeline bottleneck —
compression of instance i+1 overlaps editing of instance i.  We time both
stages and report MB/s (CPU numbers; the paper's A100 table is reproduced
structurally, with the hardware column recorded).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import BASES, save_results, timer
from repro.compressors import get_compressor
from repro.core.ffcz import FFCz, FFCzConfig
from repro.data.fields import make_field


def run(quick: bool = False):
    rows = []
    x = make_field("nyx-like")
    mb = x.nbytes / 1e6
    bases = BASES[:1] if quick else BASES
    for bname in bases:
        base = get_compressor(bname)
        E = 1e-3 * np.ptp(x)
        blob, t_comp = timer(lambda: base.compress(x, E), repeat=1 if quick else 2)
        xh, t_dec = timer(lambda: base.decompress(blob), repeat=1 if quick else 2)

        codec = FFCz(base, FFCzConfig(E_rel=1e-3, Delta_rel=1e-3, max_iters=500, verify=False))

        def edit_only():
            return codec.compress(x)

        edit_only()  # warm-up: exclude jit compilation from the throughput
        fb, t_full = timer(edit_only, repeat=1)
        t_edit = max(t_full - t_comp - t_dec, 1e-9)  # edit stage excl. base (paper's metric)
        rows.append({
            "bench": "fig7", "base": bname,
            "base_compress_MBps": mb / t_comp,
            "edit_stage_MBps": mb / t_edit,
            "edit_over_base_speedup": t_comp / t_edit,
            "pipeline_bottleneck": "base" if t_edit < t_comp else "edit",
        })
    save_results("fig7_throughput", rows)
    return rows


COLUMNS = ["bench", "base", "base_compress_MBps", "edit_stage_MBps",
           "edit_over_base_speedup", "pipeline_bottleneck"]
