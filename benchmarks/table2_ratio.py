"""Paper Table II: compression ratios — native base (eps only) vs
trial-and-error (eps AND delta via tightened spatial bound) vs FFCz edit.

On each synthetic field: the native base compressor bounds only eps; the
trial-and-error column tightens E until the max frequency error reaches the
same target FFCz enforces; FFCz augments the native output with edits.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import BASES, FIELD_SET, save_results
from repro.compressors import get_compressor
from repro.core.ffcz import FFCz, FFCzConfig
from repro.data.fields import make_field

E_REL = 1e-3


def _max_freq_err(x, xh):
    d = np.fft.fftn(xh.astype(np.float64)) - np.fft.fftn(x.astype(np.float64))
    return max(np.abs(d.real).max(), np.abs(d.imag).max())


def run(quick: bool = False):
    rows = []
    fields = FIELD_SET[:2] if quick else FIELD_SET
    bases = BASES[:1] if quick else BASES
    for fname in fields:
        x = make_field(fname)
        raw = x.nbytes
        for bname in bases:
            base = get_compressor(bname)
            E = E_REL * np.ptp(x)

            # (1) native: eps only
            blob_native = base.compress(x, E)
            xh = base.decompress(blob_native)
            native_ratio = raw / len(blob_native)
            native_ferr = _max_freq_err(x, xh)

            # FFCz target: cut the native max frequency error by 100x (paper §V-B)
            target = native_ferr / 100.0

            # (2) trial-and-error: tighten E until the frequency target holds
            E_t = E
            blob_t = blob_native
            for _ in range(20):
                xh_t = base.decompress(blob_t)
                if _max_freq_err(x, xh_t) <= target:
                    break
                E_t *= 0.5
                blob_t = base.compress(x, E_t)
            trial_ratio = raw / len(blob_t)

            # (3) our augmentation
            c = FFCz(base, FFCzConfig(E_rel=E_REL, Delta_abs=target, E_abs=None,
                                      Delta_rel=None, max_iters=2000))
            _, blob = c.roundtrip(x)
            aug_ratio = raw / blob.stats.total_bytes

            rows.append({
                "bench": "table2", "dataset": fname, "base": bname,
                "ratio_eps_only": native_ratio,
                "ratio_trial_and_error": trial_ratio,
                "ratio_our_aug": aug_ratio,
                "iterations": blob.stats.iterations,
                "freq_err_cut": native_ferr / max(_max_freq_err(x, c.decompress(blob)), 1e-30),
            })
    save_results("table2_ratio", rows)
    return rows


COLUMNS = ["bench", "dataset", "base", "ratio_eps_only", "ratio_trial_and_error",
           "ratio_our_aug", "iterations", "freq_err_cut"]
