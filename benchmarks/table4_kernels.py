"""Paper Table IV: per-kernel timing/AI breakdown of the editing pipeline.

The paper reports A100 CUDA kernels vs a 64-core EPYC.  This container is a
CPU running the Pallas kernels in interpret mode, so absolute numbers are
NOT comparable; what we preserve is the structural breakdown (which stage
dominates) and the arithmetic-intensity accounting.  FFT/IFFT timings use
the XLA CPU FFT (the stage that dominates on GPU too, 68.7% in the paper).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_results, timer
from repro.core.cubes import project_fcube, project_scube
from repro.data.fields import make_field


def run(quick: bool = False):
    rows = []
    x = make_field("nyx-like").astype(np.float32)
    n = x.size
    eps = jnp.asarray((np.random.default_rng(0).standard_normal(x.shape) * 1e-3).astype(np.float32))

    fft = jax.jit(jnp.fft.fftn)
    ifft = jax.jit(lambda d: jnp.real(jnp.fft.ifftn(d)))
    delta = fft(eps)
    fproj = jax.jit(lambda d: project_fcube(d, 1.0)[0])
    sproj = jax.jit(lambda e: project_scube(e, 1e-3)[0])

    def bench(name, fn, arg, flops_per_el, bytes_per_el):
        fn(arg).block_until_ready()
        _, t = timer(lambda: fn(arg).block_until_ready(), repeat=2 if quick else 3)
        rows.append({
            "bench": "table4", "kernel": name, "time_ms": t * 1e3,
            "GFLOPS": flops_per_el * n / t / 1e9,
            "BW_GBps": bytes_per_el * n / t / 1e9,
            "AI_flops_per_byte": flops_per_el / bytes_per_el,
        })

    logn = np.log2(n)
    bench("forwardFFT", fft, eps, 5 * logn, 12.0)  # ~5NlogN flops, cplx out
    bench("inverseFFT", ifft, delta, 5 * logn, 12.0)
    bench("ProjectOntoFCube", fproj, delta, 4.0, 16.0)
    bench("ProjectOntoSCube", sproj, eps, 2.0, 8.0)

    fft_ms = rows[0]["time_ms"] + rows[1]["time_ms"]
    total = sum(r["time_ms"] for r in rows)
    rows.append({"bench": "table4", "kernel": "fft_share_of_total", "time_ms": total,
                 "GFLOPS": 0.0, "BW_GBps": 0.0, "AI_flops_per_byte": fft_ms / total})
    save_results("table4_kernels", rows)
    return rows


COLUMNS = ["bench", "kernel", "time_ms", "GFLOPS", "BW_GBps", "AI_flops_per_byte"]
