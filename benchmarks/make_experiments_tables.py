"""Regenerate the roofline tables inside EXPERIMENTS.md from dry-run JSONs."""

import re

from benchmarks.roofline import analyze, markdown_table


def main():
    single = markdown_table(analyze("dryrun_single.json"))
    try:
        multi_rows = analyze("dryrun_multi.json")
        multi = markdown_table(multi_rows)
    except FileNotFoundError:
        multi = "(multi-pod sweep pending)\n"

    with open("EXPERIMENTS.md") as f:
        text = f.read()
    text = text.replace("TABLE-PLACEHOLDER-SINGLE", single.rstrip())
    text = text.replace("TABLE-PLACEHOLDER-MULTI",
                        "Same cells on the 2x16x16 (512-chip) mesh — proves the pod axis\n"
                        "shards (batch over (pod, data); gradient all-reduce crosses pods):\n\n"
                        + multi.rstrip())
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    print("EXPERIMENTS.md tables updated")


if __name__ == "__main__":
    main()
