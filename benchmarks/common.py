"""Shared helpers for the paper-table benchmarks."""

from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List

import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

FIELD_SET = ["nyx-like", "s3d-like", "hedm-like", "eeg-like"]
BASES = ["szlike", "zfplike", "sperrlike"]


def timer(fn: Callable, repeat: int = 1):
    """Return (result, best seconds)."""
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return out, best


def save_results(name: str, rows: List[Dict]):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(rows, f, indent=1, default=_np_safe)


def _np_safe(o):
    if isinstance(o, (np.floating, np.integer)):
        return o.item()
    raise TypeError(type(o))


def print_csv(rows: List[Dict], cols: List[str]):
    print(",".join(cols))
    for r in rows:
        print(",".join(_fmt(r.get(c)) for c in cols))


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)
