"""Data layer: deterministic sharded token pipeline + synthetic science fields."""

from repro.data.fields import make_field
from repro.data.pipeline import TokenPipeline

__all__ = ["TokenPipeline", "make_field"]
