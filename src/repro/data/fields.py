"""Synthetic scientific fields with controlled spectra (paper Table I analogues)."""

from __future__ import annotations

import numpy as np

from repro.configs.ffcz_fields import FIELDS, FieldConfig


def make_field(name_or_cfg) -> np.ndarray:
    cfg: FieldConfig = FIELDS[name_or_cfg] if isinstance(name_or_cfg, str) else name_or_cfg
    rng = np.random.default_rng(cfg.seed)
    if cfg.kind == "lognormal":
        # Nyx-like baryon density: lognormal transform of a power-law GRF
        # (captures the real field's huge dynamic range, which is what makes
        # trial-and-error bound tightening expensive on the real data)
        g = _grf(cfg.shape, cfg.alpha, rng)
        return np.exp(1.5 * g).astype(np.float32)
    if cfg.kind == "powerlaw":
        return _grf(cfg.shape, cfg.alpha, rng) + 3.0
    if cfg.kind == "exponential":
        return _smooth_exp(cfg.shape, cfg.alpha, rng)
    if cfg.kind == "spots":
        return _spots(cfg.shape, rng)
    if cfg.kind == "pink":
        return _grf(cfg.shape, cfg.alpha, rng)
    raise ValueError(cfg.kind)


def _kgrid(shape):
    axes = [np.fft.fftfreq(n) * n for n in shape]
    grids = np.meshgrid(*axes, indexing="ij")
    return np.sqrt(sum(g.astype(np.float64) ** 2 for g in grids))


def _grf(shape, alpha, rng) -> np.ndarray:
    """Gaussian random field with P(k) ~ k^-alpha (Nyx/EEG-like)."""
    k = _kgrid(shape)
    with np.errstate(divide="ignore"):
        amp = np.where(k > 0, k ** (-alpha / 2.0), 0.0)
    noise = rng.standard_normal(shape) + 1j * rng.standard_normal(shape)
    f = np.fft.ifftn(amp * noise).real
    return (f / (f.std() + 1e-30)).astype(np.float32)


def _smooth_exp(shape, k0, rng) -> np.ndarray:
    """Smooth field with exponentially decaying spectrum (S3D-like)."""
    k = _kgrid(shape)
    amp = np.exp(-k / max(k0, 1e-3))
    noise = rng.standard_normal(shape) + 1j * rng.standard_normal(shape)
    f = np.fft.ifftn(amp * noise).real
    return (f / (f.std() + 1e-30)).astype(np.float32) + 1.0


def _spots(shape, rng, n_spots: int = 60) -> np.ndarray:
    """Sparse bright diffraction spots on a weak noise floor (HEDM-like)."""
    f = rng.standard_normal(shape).astype(np.float32) * 1e-3
    coords = [rng.integers(2, n - 2, n_spots) for n in shape]
    grids = np.meshgrid(*[np.arange(n) for n in shape], indexing="ij")
    for i in range(n_spots):
        c = [cc[i] for cc in coords]
        r2 = sum((g - ci) ** 2 for g, ci in zip(grids, c))
        f += rng.uniform(0.5, 5.0) * np.exp(-r2 / 2.0).astype(np.float32)
    return f
