"""Deterministic, sharded, restart-safe token pipeline.

Every batch is a pure function of (seed, step, shard) — counter-mode PRNG —
so restart-from-checkpoint resumes the exact stream with no iterator state to
persist, and each data-parallel shard generates only its slice (no host
broadcast).  Synthetic "language" is Zipf-distributed token draws with a
Markov smoothing pass so the loss signal is learnable (perplexity decreases),
which the quickstart example demonstrates.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1
    shard: int = 0
    # modality stubs
    vision_tokens: int = 0
    vision_dim: int = 0
    audio_frames: int = 0
    audio_dim: int = 0

    @property
    def shard_batch(self) -> int:
        assert self.global_batch % self.n_shards == 0
        return self.global_batch // self.n_shards

    def batch_at(self, step: int) -> Dict[str, Any]:
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), step), self.shard
        )
        kt, kv, ka = jax.random.split(key, 3)
        # Zipf-ish marginal via exponential transform of uniforms
        u = jax.random.uniform(kt, (self.shard_batch, self.seq_len), minval=1e-6, maxval=1.0)
        ranks = jnp.floor(jnp.exp(jnp.log(float(self.vocab)) * u)) - 1
        tokens = ranks.astype(jnp.int32) % self.vocab
        # Markov smoothing: with p=0.5 copy previous token (learnable bigrams)
        keep = jax.random.bernoulli(kt, 0.5, tokens.shape)
        tokens = jnp.where(keep, tokens, jnp.roll(tokens, 1, axis=1))
        out: Dict[str, Any] = {"tokens": tokens}
        if self.vision_tokens:
            out["patches"] = jax.random.normal(
                kv, (self.shard_batch, self.vision_tokens, self.vision_dim), dtype=jnp.float32
            )
        if self.audio_frames:
            out["frames"] = jax.random.normal(
                ka, (self.shard_batch, self.audio_frames, self.audio_dim), dtype=jnp.float32
            )
        return out


def pipeline_for(cfg, seq_len: int, global_batch: int, seed: int = 0, n_shards: int = 1, shard: int = 0) -> TokenPipeline:
    kw = dict(
        vocab=cfg.vocab, seq_len=seq_len, global_batch=global_batch,
        seed=seed, n_shards=n_shards, shard=shard,
    )
    if cfg.family == "vlm":
        kw.update(vision_tokens=cfg.vision_tokens, vision_dim=cfg.vision_dim)
        kw["seq_len"] = seq_len - cfg.vision_tokens
    if cfg.family == "audio":
        kw.update(audio_frames=cfg.encoder_seq, audio_dim=cfg.d_model)
    return TokenPipeline(**kw)
