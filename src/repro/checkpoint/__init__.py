"""Checkpointing: atomic, resharding-capable, optionally FFCz-compressed."""

from repro.checkpoint.codec import CheckpointCodec
from repro.checkpoint.manager import CheckpointManager

__all__ = ["CheckpointManager", "CheckpointCodec"]
