"""FFCz-compressed array codec for checkpoints (DESIGN.md §3 integration #1).

Float arrays are compressed with a base compressor + FFCz dual-domain
correction: the spatial bound controls pointwise weight error (restart
quality), the frequency bound preserves each tensor's spectrum — for weight
matrices that is the quantity tied to the layer's singular-value structure.
Non-float / tiny arrays pass through raw.
"""

from __future__ import annotations

import io
import struct
from typing import Tuple

import numpy as np

from repro.compressors import get_compressor
from repro.core.ffcz import FFCz, FFCzBlob, FFCzConfig

_RAW = b"R"
_FFZ = b"F"


class CheckpointCodec:
    def __init__(
        self,
        enabled: bool = True,
        E_rel: float = 1e-4,
        Delta_rel: float = 1e-4,
        base: str = "szlike",
        min_size: int = 4096,
        max_iters: int = 50,
    ):
        self.enabled = enabled
        self.min_size = min_size
        self.ffcz = FFCz(
            get_compressor(base),
            FFCzConfig(E_rel=E_rel, Delta_rel=Delta_rel, max_iters=max_iters, codec="zlib", verify=False),
        )

    def encode(self, arr: np.ndarray) -> bytes:
        arr = np.asarray(arr)
        use_ffcz = (
            self.enabled
            and arr.dtype in (np.float32, np.float64)
            and arr.size >= self.min_size
            and np.ptp(arr) > 0
        )
        if not use_ffcz:
            buf = io.BytesIO()
            np.save(buf, arr, allow_pickle=False)
            return _RAW + buf.getvalue()
        blob = self.ffcz.compress(arr.astype(np.float32))
        payload = blob.to_bytes()
        header = struct.pack("<B", {"float32": 0, "float64": 1}[str(arr.dtype)])
        return _FFZ + header + payload

    def decode(self, data: bytes) -> np.ndarray:
        tag, body = data[:1], data[1:]
        if tag == _RAW:
            return np.load(io.BytesIO(body), allow_pickle=False)
        (dt_code,) = struct.unpack_from("<B", body, 0)
        blob = FFCzBlob.from_bytes(body[1:])
        out = self.ffcz.decompress(blob)
        return out.astype(np.float64 if dt_code == 1 else np.float32)
