"""FFCz-compressed array codec for checkpoints (DESIGN.md §3 integration #1).

Float arrays are compressed with a base compressor + FFCz dual-domain
correction: the spatial bound controls pointwise weight error (restart
quality), the frequency bound preserves each tensor's spectrum — for weight
matrices that is the quantity tied to the layer's singular-value structure.
Non-float / tiny arrays pass through raw.

Both encode paths are clients of :class:`repro.core.engine.CorrectionEngine`
and share the wire envelope; this module owns ONLY workload shaping and byte
assembly (bound discipline, POCS, bit-width and pair-weight math all live in
the engine):

``encode``        — tag ``F``: whole-array FFCz (the paper pipeline via
                    :class:`repro.core.ffcz.FFCz`; the frequency bound
                    applies to the array's global spectrum).
``encode_batch``  — tag ``B``: blockwise FFCz for a whole checkpoint at
                    once.  Per leaf, ``engine.plan_pencils`` resolves the
                    per-pencil bounds, then ALL leaves' base-compression
                    errors are corrected by a single batched (or, with a
                    sharded engine, ``shard_map``-distributed) device
                    program via ``engine.correct``, and
                    ``engine.encode_pencils`` polishes + serializes each
                    leaf's rfft half-spectrum edit streams.

Both tags decode through :meth:`CheckpointCodec.decode`; raw arrays use
tag ``R``.
"""

from __future__ import annotations

import io
import struct
from typing import List, Optional, Sequence

import numpy as np

from repro.compressors import get_compressor
from repro.core.edits import EncodedEdits, decode_edits
from repro.core.engine import CorrectionEngine, default_engine
from repro.core.ffcz import FFCz, FFCzBlob, FFCzConfig

_RAW = b"R"
_FFZ = b"F"
_FFB = b"B"  # blockwise-batched FFCz (rfft half-spectrum edit streams)

_DTYPE_CODES = {"float32": 0, "float64": 1}


class CheckpointCodec:
    def __init__(
        self,
        enabled: bool = True,
        E_rel: float = 1e-4,
        Delta_rel: float = 1e-4,
        base: str = "szlike",
        min_size: int = 4096,
        max_iters: int = 50,
        block: int = 4096,
        engine: Optional[CorrectionEngine] = None,
    ):
        self.enabled = enabled
        self.min_size = min_size
        self.E_rel = E_rel
        self.Delta_rel = Delta_rel
        self.max_iters = max_iters
        self.block = block
        self.base = get_compressor(base)
        self.engine = engine or default_engine()
        self.ffcz = FFCz(
            self.base,
            FFCzConfig(E_rel=E_rel, Delta_rel=Delta_rel, max_iters=max_iters, codec="zlib", verify=False),
            engine=self.engine,
        )

    def _eligible(self, arr: np.ndarray) -> bool:
        return (
            self.enabled
            and arr.dtype in (np.float32, np.float64)
            and arr.size >= self.min_size
            and np.ptp(arr) > 0
        )

    @staticmethod
    def _raw(arr: np.ndarray) -> bytes:
        buf = io.BytesIO()
        np.save(buf, arr, allow_pickle=False)
        return _RAW + buf.getvalue()

    # -- whole-array path (paper pipeline) ---------------------------------

    def encode(self, arr: np.ndarray) -> bytes:
        arr = np.asarray(arr)
        if not self._eligible(arr):
            return self._raw(arr)
        blob = self.ffcz.compress(arr.astype(np.float32))
        payload = blob.to_bytes()
        header = struct.pack("<B", _DTYPE_CODES[str(arr.dtype)])
        return _FFZ + header + payload

    # -- batched blockwise path --------------------------------------------

    def encode_batch(self, arrays: Sequence[np.ndarray]) -> List[bytes]:
        """Encode a whole checkpoint's leaves with ONE batched correction.

        Semantics differ from :meth:`encode` only in the frequency bound's
        scope: Delta applies to each ``block``-length pencil's local rfft
        spectrum (Delta = Delta_rel * max |RFFT(pencil of x)|, per array)
        instead of the array's global spectrum.  The spatial bound E holds
        at every point; the frequency bound holds per *full* pencil (an
        array whose size is not a multiple of ``block`` has its tail pencil
        corrected on a zero-padded extension that decode discards).
        """
        arrays = [np.asarray(a) for a in arrays]
        idx = [i for i, a in enumerate(arrays) if self._eligible(a)]
        eligible = set(idx)
        out: List[bytes] = [b"" for _ in arrays]
        for i, a in enumerate(arrays):
            if i not in eligible:
                out[i] = self._raw(a)
        if not idx:
            return out

        block = self.block
        errs = []  # base-compression error tensors, consumed by engine.correct
        work = []  # (leaf index, base_blob, float64 tiling, PencilPlan)
        for i in idx:
            x32 = arrays[i].astype(np.float32)
            plan = self.engine.plan_pencils(
                x32, E_rel=self.E_rel, Delta_rel=self.Delta_rel, block=block
            )
            if plan is None:
                # range below float32 representability — store raw instead
                out[i] = self._raw(arrays[i])
                continue
            base_blob = self.base.compress(x32, plan.E_proj)
            x_hat = np.asarray(self.base.decompress(base_blob), dtype=np.float32)
            eps0 = x_hat - x32
            # float64 tiling captured up front: the polish rebuilds the loop
            # state from it, so eps0 itself need not outlive the batched call
            tiles0 = self.engine.tile_f64(eps0, block)
            errs.append(eps0)
            work.append((i, base_blob, tiles0, plan))

        if not work:
            return out
        _corr, edits, _stats = self.engine.correct(
            errs,
            [w[3].E_proj for w in work],
            [w[3].Delta_proj for w in work],
            block=block,
            max_iters=self.max_iters,
            return_edits=True,
            return_corrected=False,  # only the edit streams are serialized
        )
        del errs  # free the float32 error copies; tiles0 carries the state

        for (i, base_blob, tiles0, plan), (spat_t, freq_t) in zip(work, edits):
            se, fe = self.engine.encode_pencils(spat_t, freq_t, tiles0, plan, codec="zlib")
            se_b, fe_b = se.to_bytes(), fe.to_bytes()
            arr = arrays[i]
            header = struct.pack(
                "<BddIB",
                _DTYPE_CODES[str(arr.dtype)],
                plan.E,
                plan.Delta,
                block,
                arr.ndim,
            )
            header += struct.pack(f"<{arr.ndim}Q", *arr.shape)
            header += struct.pack("<QQQ", len(base_blob), len(se_b), len(fe_b))
            out[i] = _FFB + header + base_blob + se_b + fe_b
        return out

    def _decode_ffb(self, body: bytes) -> np.ndarray:
        dt_code, E, Delta, block, ndim = struct.unpack_from("<BddIB", body, 0)
        off = struct.calcsize("<BddIB")
        shape = struct.unpack_from(f"<{ndim}Q", body, off)
        off += 8 * ndim
        nb, ns, nf = struct.unpack_from("<QQQ", body, off)
        off += struct.calcsize("<QQQ")
        base_blob = body[off : off + nb]
        off += nb
        se = EncodedEdits.from_bytes(body[off : off + ns])
        off += ns
        fe = EncodedEdits.from_bytes(body[off : off + nf])
        x_hat = np.asarray(self.base.decompress(base_blob), dtype=np.float32)
        spat = decode_edits(se, E)  # (n_blocks, block)
        freq = decode_edits(fe, Delta)  # (n_blocks, block//2+1) half-spectra
        complete = spat + np.fft.irfft(freq, n=block, axis=-1)
        size = int(np.prod(shape)) if shape else 1
        x = x_hat.astype(np.float64).reshape(-1) + complete.reshape(-1)[:size]
        out = x.reshape(shape).astype(np.float32)
        return out.astype(np.float64 if dt_code == 1 else np.float32)

    # -- decode (all tags) -------------------------------------------------

    def decode(self, data: bytes) -> np.ndarray:
        tag, body = data[:1], data[1:]
        if tag == _RAW:
            return np.load(io.BytesIO(body), allow_pickle=False)
        if tag == _FFB:
            return self._decode_ffb(body)
        (dt_code,) = struct.unpack_from("<B", body, 0)
        blob = FFCzBlob.from_bytes(body[1:])
        out = self.ffcz.decompress(blob)
        return out.astype(np.float64 if dt_code == 1 else np.float32)
