"""Atomic, resharding-capable checkpoint manager.

Layout (one directory per step):

    <dir>/step_<N>/
        manifest.json       {step, keys, codec, leaf dtypes/shapes}
        <leaf-index>.bin    one file per pytree leaf (codec-encoded)
        _COMMITTED          sentinel written last (atomic rename)

Fault-tolerance properties:
  * atomicity: tmp dir + rename; readers only trust _COMMITTED dirs,
    so a host dying mid-save never corrupts restore state.
  * resharding/elasticity: leaves are saved as FULL (host-gathered) arrays;
    restoring onto any mesh re-shards via the step function's in_shardings —
    a checkpoint saved on 16x16 restores on 2x16x16 or on 1 CPU device.
  * async: save() can run in a background thread (overlaps the next step).
  * retention: keeps the newest ``keep`` committed checkpoints.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint.codec import CheckpointCodec


class CheckpointManager:
    def __init__(self, directory: str, codec: Optional[CheckpointCodec] = None, keep: int = 3):
        self.dir = directory
        self.codec = codec or CheckpointCodec(enabled=False)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, state: Any, blocking: bool = True) -> None:
        leaves, treedef = jax.tree.flatten(state)
        host_leaves = [np.asarray(leaf) for leaf in leaves]  # gather to host
        if blocking:
            self._write(step, host_leaves, str(treedef))
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host_leaves, str(treedef)), daemon=True
            )
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, leaves, treedef_str: str) -> None:
        final = os.path.join(self.dir, f"step_{step:012d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {
            "step": step,
            "n_leaves": len(leaves),
            "treedef": treedef_str,
            "dtypes": [str(l.dtype) for l in leaves],
            "shapes": [list(l.shape) for l in leaves],
        }
        # one batched encode for the whole state: all leaves' POCS corrections
        # run in a single device program (see CheckpointCodec.encode_batch)
        blobs = self.codec.encode_batch(leaves)
        for i, blob in enumerate(blobs):
            with open(os.path.join(tmp, f"{i}.bin"), "wb") as f:
                f.write(blob)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.committed_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:012d}"), ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def committed_steps(self):
        out = []
        for name in sorted(os.listdir(self.dir)):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "_COMMITTED")):
                    out.append(int(name[5:]))
        return out

    def latest_step(self) -> Optional[int]:
        steps = self.committed_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any) -> Any:
        """Restore into the structure of ``like`` (abstract or concrete pytree).

        Cast/reshape mismatches are errors — resharding happens downstream
        when the restored host arrays enter a jitted step with in_shardings.
        """
        path = os.path.join(self.dir, f"step_{step:012d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        leaves_like, treedef = jax.tree.flatten(like)
        assert manifest["n_leaves"] == len(leaves_like), "checkpoint/tree structure mismatch"
        out = []
        for i, ref in enumerate(leaves_like):
            with open(os.path.join(path, f"{i}.bin"), "rb") as f:
                arr = self.codec.decode(f.read())
            arr = arr.astype(manifest["dtypes"][i]).reshape(manifest["shapes"][i])
            if tuple(arr.shape) != tuple(np.shape(ref)):
                raise ValueError(f"leaf {i}: ckpt {arr.shape} vs expected {np.shape(ref)}")
            out.append(arr.astype(ref.dtype) if hasattr(ref, "dtype") else arr)
        return jax.tree.unflatten(treedef, out)

    def restore_latest(self, like: Any) -> Optional[Tuple[int, Any]]:
        step = self.latest_step()
        if step is None:
            return None
        return step, self.restore(step, like)
