"""Optimizers + FFCz-compressed gradient aggregation."""

from repro.optim.adamw import AdamW
from repro.optim.grad_compress import compress_gradients, compressed_psum

__all__ = ["AdamW", "compress_gradients", "compressed_psum"]
