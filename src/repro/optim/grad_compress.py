"""FFCz-compressed gradient aggregation (DESIGN.md §3, distributed-opt trick).

Two pieces:

``compress_gradients``  — jit-safe transform applied to the gradient pytree
inside train_step: per-tensor int-quantization to ``bits`` with error bound
E = E_rel * ||g||_inf, followed by FFCz blockwise dual-domain correction so
the *spectrum* of the quantized gradient stays within Delta = Delta_rel *
max|FFT| of each block.  The correction executes through
:meth:`repro.core.engine.CorrectionEngine.correct` (this module owns only
the quantizer and bound derivation).  Semantically this is what each worker
sends into the compressed all-reduce; keeping it inside the pjit program
means GSPMD still owns the actual reduction.

``compressed_psum``     — the explicit collective pattern for deployments
that want the wire-format win too: a shard_map region that quantizes to int32
codes, psums the *codes* (integer all-reduce = bits on the wire scale with
``bits``, not 32), and dequantizes + FFCz-corrects the mean.  Exact-sum
property of integer codes means no quantization-noise accumulation across
workers beyond the single-quantizer bound.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.engine import CorrectionEngine, default_engine
from repro.sharding.shardmap import shard_map


def _quantize_dequantize(g: jnp.ndarray, bits: int, E_rel: float):
    """Uniform symmetric quantizer with bound E = E_rel * max|g| (per tensor)."""
    g32 = g.astype(jnp.float32)
    gmax = jnp.max(jnp.abs(g32))
    E = E_rel * gmax
    # round-to-nearest on a grid of step 2E/2^bits => |dequant - g| <= E*2^-bits+;
    # the *bound* we guarantee downstream is E (coarse grid = fewer wire bits)
    step = jnp.maximum(2.0 * E / (2.0**bits), 1e-30)
    codes = jnp.rint(g32 / step)
    return (codes * step).astype(g.dtype), codes, step


def compress_gradients(
    grads: Any,
    *,
    bits: int = 8,
    E_rel: float = 1e-2,
    Delta_rel: float = 1e-2,
    block: int = 4096,
    max_iters: int = 8,
    engine: Optional[CorrectionEngine] = None,
) -> Any:
    """Quantize + FFCz-correct every gradient tensor (dual-domain bounded).

    The correction bounds the *error spectrum* of each ``block``-length pencil:
    spatial |err| <= E and |Re/Im FFT(err)| <= Delta, with
    E = E_rel * max|g| and Delta = Delta_rel * N_block * E (frequency errors
    of a length-N pencil live on a N*E scale).

    All tensors of the gradient pytree are corrected by batched
    ``engine.correct`` device calls — one per distinct effective pencil
    length (tensors smaller than ``block`` keep their tighter
    ``size``-length pencil) — instead of one dispatch per tensor.
    """
    engine = engine or default_engine()
    leaves, treedef = jax.tree.flatten(grads)
    work = []  # (leaf_idx, err, E, Delta, effective block)
    for i, g in enumerate(leaves):
        if g.size < 2:
            continue
        gq, _codes, _step = _quantize_dequantize(g, bits, E_rel)
        err = (gq - g).astype(jnp.float32)
        gmax = jnp.max(jnp.abs(g.astype(jnp.float32)))
        E = E_rel * gmax
        Delta = Delta_rel * block * E
        work.append((i, err, E, Delta, min(block, max(g.size, 2))))

    out = list(leaves)
    for blk in sorted({w[4] for w in work}):
        group = [w for w in work if w[4] == blk]
        corrected, _stats = engine.correct(
            [w[1] for w in group],
            [w[2] for w in group],
            [w[3] for w in group],
            block=blk,
            max_iters=max_iters,
        )
        for (i, _err, _E, _D, _b), corr in zip(group, corrected):
            g = leaves[i]
            out[i] = (g.astype(jnp.float32) + corr).astype(g.dtype)
    return jax.tree.unflatten(treedef, out)


def compressed_psum(x: jnp.ndarray, mesh, axis: str = "data", *, bits: int = 8, E_rel: float = 1e-2):
    """Integer-code all-reduce under shard_map: the explicit collective form.

    x is the local shard of a gradient tensor, replicated-summed over
    ``axis``.  Codes are psum'd as int32; the result is the dequantized mean.
    """

    def _inner(v):
        v32 = v.astype(jnp.float32)
        gmax = jax.lax.pmax(jnp.max(jnp.abs(v32)), axis)
        step = jnp.maximum(2.0 * E_rel * gmax / (2.0**bits), 1e-30)
        codes = jnp.rint(v32 / step).astype(jnp.int32)
        total = jax.lax.psum(codes, axis)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
        return (total.astype(jnp.float32) * step / n).astype(v.dtype)

    return shard_map(_inner, mesh=mesh, in_specs=P(), out_specs=P())(x)
