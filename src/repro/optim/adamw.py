"""AdamW with decoupled weight decay; fp32 moments regardless of param dtype.

Moment tensors inherit the parameter PartitionSpecs (TP+FSDP sharded), so the
optimizer state is fully distributed (ZeRO-ish by construction: the FSDP
"data" axis already shards every large tensor).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100

    def init(self, params: Any) -> Any:
        zeros = lambda p: jnp.zeros(p.shape, dtype=jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.int32(0),
        }

    def _schedule(self, step: jnp.ndarray) -> jnp.ndarray:
        warm = jnp.minimum(1.0, (step + 1) / max(self.warmup_steps, 1))
        return self.lr * warm

    def update(self, grads: Any, state: Any, params: Any) -> Tuple[Any, Any]:
        step = state["step"] + 1
        lr = self._schedule(step)

        # global-norm clip (fp32)
        gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        gnorm = jnp.sqrt(gsq)
        scale = jnp.minimum(1.0, self.grad_clip / jnp.maximum(gnorm, 1e-12))

        b1, b2 = self.b1, self.b2
        bc1 = 1.0 - b1**step.astype(jnp.float32)
        bc2 = 1.0 - b2**step.astype(jnp.float32)

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32) * scale
            m_new = b1 * m + (1 - b1) * g32
            v_new = b2 * v + (1 - b2) * g32 * g32
            mh = m_new / bc1
            # clamp: lossily-restored (FFCz checkpoint codec) moments can be
            # epsilon-negative; sqrt would NaN the whole update
            vh = jnp.maximum(v_new / bc2, 0.0)
            delta = mh / (jnp.sqrt(vh) + self.eps) + self.weight_decay * p.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - lr * delta
            return p_new.astype(p.dtype), m_new, v_new

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state["m"])
        flat_v = jax.tree.leaves(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
        new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
        new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "step": step}

    def state_pspecs(self, param_pspecs: Any) -> Any:
        from jax.sharding import PartitionSpec as P

        return {
            "m": param_pspecs,
            "v": param_pspecs,
            "step": P(),
        }
