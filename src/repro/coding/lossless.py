"""Lossless back-end: Huffman followed by a byte-stream coder (paper: ZSTD [38]).

ZSTD is unavailable in this offline container; ``zlib`` (DEFLATE) is the
stand-in with an identical bytes->bytes interface — documented in
DESIGN.md §6.  ``codec="zlib"`` skips the explicit Huffman stage (DEFLATE
already entropy-codes) and is the fast path used by the throughput benches;
``codec="huffman+zlib"`` is the paper-faithful chain.
"""

from __future__ import annotations

import struct
import zlib

import numpy as np

from repro.coding.huffman import huffman_decode, huffman_encode

_MAGIC_HUFF = b"FH"
_MAGIC_RAW = b"FR"


def lossless_compress(symbols: np.ndarray, codec: str = "huffman+zlib", level: int = 6) -> bytes:
    """Compress an integer symbol stream to bytes."""
    symbols = np.asarray(symbols).astype(np.int64).ravel()
    if codec == "huffman+zlib":
        body = huffman_encode(symbols)
        return _MAGIC_HUFF + zlib.compress(body, level)
    if codec == "zlib":
        # int64 is wasteful on the wire; narrow to the smallest dtype that fits.
        dtype = _narrowest_dtype(symbols)
        body = struct.pack("<cQ", dtype.char.encode(), symbols.size) + symbols.astype(dtype).tobytes()
        return _MAGIC_RAW + zlib.compress(body, level)
    raise ValueError(f"unknown codec {codec!r}")


def lossless_decompress(data: bytes) -> np.ndarray:
    """Inverse of :func:`lossless_compress`."""
    magic, body = data[:2], zlib.decompress(data[2:])
    if magic == _MAGIC_HUFF:
        return huffman_decode(body)
    if magic == _MAGIC_RAW:
        char, n = struct.unpack_from("<cQ", body, 0)
        dtype = np.dtype(char.decode())
        return np.frombuffer(body, dtype=dtype, count=n, offset=9).astype(np.int64)
    raise ValueError("bad magic in lossless stream")


def _narrowest_dtype(symbols: np.ndarray) -> np.dtype:
    if symbols.size == 0:
        return np.dtype(np.int8)
    lo, hi = int(symbols.min()), int(symbols.max())
    for dt in (np.int8, np.int16, np.int32):
        info = np.iinfo(dt)
        if info.min <= lo and hi <= info.max:
            return np.dtype(dt)
    return np.dtype(np.int64)
