"""Uniform quantization of edit values (paper §IV-B).

The paper quantizes each compact edit by dividing each axis of the s-cube or
f-cube into ``2^m`` intervals (m = 16 bits by default).  The cube axis for the
s-cube spans ``[-E, E]`` so the quantization step is ``2*E / 2^m``; likewise
``2*Delta / 2^m`` for the f-cube.  Round-to-nearest gives a reconstruction
error of at most ``bound * 2^-m`` per edit, which is exactly the slack
reclaimed by shrinking the initial error bounds to ``bound * (1 - 2^-m)``.

Edits can (rarely) exceed the cube span because they are *accumulated*
displacements, so codes are stored as int32 rather than uint16; the entropy
coder absorbs the near-zero-centred distribution either way.
"""

from __future__ import annotations

import numpy as np

DEFAULT_QUANT_BITS = 16


def quant_step(bound, m: int = DEFAULT_QUANT_BITS):
    """Quantization step: cube diameter 2*bound split into 2^m intervals.

    ``bound`` may be a scalar (global bound) or an array of per-component
    bounds (pointwise ``Delta_k`` mode, Observation 4) — the grid is then
    per-component so quantization error stays within each component's margin.
    """
    return 2.0 * np.asarray(bound, dtype=np.float64) / float(2**m)


def quantize_uniform(values: np.ndarray, bound, m: int = DEFAULT_QUANT_BITS) -> np.ndarray:
    """Round-to-nearest uniform quantization; returns int64 codes.

    int64 because FFCz widens ``m`` adaptively (up to ~48 bits) to keep
    cross-domain quantization leakage inside the shrink margin — see
    ``repro.core.ffcz`` — so codes may exceed int32 range.
    """
    step = quant_step(bound, m)
    safe = np.where(step == 0.0, 1.0, step)
    codes = np.rint(np.asarray(values, dtype=np.float64) / safe)
    return np.where(step == 0.0, 0.0, codes).astype(np.int64)


def dequantize_uniform(codes: np.ndarray, bound, m: int = DEFAULT_QUANT_BITS) -> np.ndarray:
    """Inverse of :func:`quantize_uniform` (centroid reconstruction)."""
    step = quant_step(bound, m)
    return np.asarray(codes, dtype=np.float64) * step


def bound_shrink(bound: float, m: int = DEFAULT_QUANT_BITS, roundoff_slack: float = 0.0) -> float:
    """Shrunk error bound fed to the projection so quantized edits still land
    inside the user's cube: ``bound * (1 - 2^-m - roundoff_slack)``.

    ``roundoff_slack`` additionally absorbs float32 FFT round-off when the
    correction runs in single precision (the paper runs FP32 on A100; we keep
    the same discipline and verify the final bounds post-hoc in FFCz.encode).
    """
    return float(bound) * (1.0 - 2.0 ** (-m) - roundoff_slack)
