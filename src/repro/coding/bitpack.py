"""Bit packing for binary flag vectors (paper §IV-B: flags packed into 8-bit ints)."""

from __future__ import annotations

import numpy as np


def pack_bits(flags: np.ndarray) -> bytes:
    """Pack a boolean/0-1 vector into bytes (8 flags per byte, MSB first)."""
    flags = np.asarray(flags).astype(bool).ravel()
    return np.packbits(flags).tobytes()


def unpack_bits(data: bytes, n: int) -> np.ndarray:
    """Inverse of :func:`pack_bits`; returns a boolean vector of length ``n``."""
    bits = np.unpackbits(np.frombuffer(data, dtype=np.uint8), count=n)
    return bits.astype(bool)
