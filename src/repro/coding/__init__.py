"""Entropy coding and bit packing for FFCz edit streams and base compressors."""

from repro.coding.bitpack import pack_bits, unpack_bits
from repro.coding.huffman import huffman_decode, huffman_encode
from repro.coding.lossless import lossless_compress, lossless_decompress
from repro.coding.quantize import dequantize_uniform, quantize_uniform

__all__ = [
    "pack_bits",
    "unpack_bits",
    "huffman_encode",
    "huffman_decode",
    "lossless_compress",
    "lossless_decompress",
    "quantize_uniform",
    "dequantize_uniform",
]
