"""Canonical Huffman coding for quantized edit streams (paper §IV-B, [37]).

Encoder is fully vectorized (bit scatter over numpy); decoder is a fully
vectorized canonical-code LUT walk: code windows at EVERY bit position are
extracted at once from 32-bit reads of the packed stream, the LUT turns them
into per-position (symbol, advance) pairs, and the sequential chain of
decode positions is expanded with pointer doubling (log2(n) gather rounds)
instead of a per-symbol Python loop.  The paper chains Huffman with ZSTD;
see :mod:`repro.coding.lossless` for the chained entry points.

Wire format (little-endian):
  u32  n_symbols_in_alphabet
  i64  per-alphabet-symbol raw value   (n_symbols entries, int64)
  u8   per-alphabet-symbol code length (n_symbols entries)
  u64  n_encoded_symbols
  u64  n_bits
  u8[] bitstream (MSB first within each byte)
"""

from __future__ import annotations

import heapq
import struct

import numpy as np

#: Bit-range chunk size of the vectorized decoder: bounds its per-position
#: temporaries (~50 bytes live per bit, so ~50 MB per chunk at this size)
#: however large the stream is.  Streams at most this long decode in one
#: chunk.
DECODE_CHUNK_BITS = 1 << 20


def _code_lengths(freqs: np.ndarray) -> np.ndarray:
    """Huffman code lengths from symbol frequencies (heap merge)."""
    n = len(freqs)
    if n == 1:
        return np.array([1], dtype=np.uint8)
    # heap entries: (freq, tiebreak, set-of-symbol-indices)
    heap = [(int(f), i, [i]) for i, f in enumerate(freqs)]
    heapq.heapify(heap)
    lengths = np.zeros(n, dtype=np.int64)
    tiebreak = n
    while len(heap) > 1:
        fa, _, sa = heapq.heappop(heap)
        fb, _, sb = heapq.heappop(heap)
        for s in sa:
            lengths[s] += 1
        for s in sb:
            lengths[s] += 1
        heapq.heappush(heap, (fa + fb, tiebreak, sa + sb))
        tiebreak += 1
    return lengths.astype(np.uint8)


def _canonical_codes(lengths: np.ndarray) -> np.ndarray:
    """Canonical Huffman code values (uint64) given code lengths.

    Symbols are ranked by (length, symbol-index); codes assigned in canonical
    order so the decoder only needs the lengths.
    """
    order = np.lexsort((np.arange(len(lengths)), lengths))
    codes = np.zeros(len(lengths), dtype=np.uint64)
    code = 0
    prev_len = int(lengths[order[0]])
    for rank, sym in enumerate(order):
        ln = int(lengths[sym])
        if rank > 0:
            code = (code + 1) << (ln - prev_len)
        codes[sym] = code
        prev_len = ln
    return codes


def huffman_encode(symbols: np.ndarray) -> bytes:
    """Encode an integer symbol stream; returns self-describing bytes."""
    symbols = np.asarray(symbols).astype(np.int64).ravel()
    if symbols.size == 0:
        return struct.pack("<I", 0) + struct.pack("<QQ", 0, 0)
    alphabet, inverse, counts = np.unique(symbols, return_inverse=True, return_counts=True)
    lengths = _code_lengths(counts)
    codes = _canonical_codes(lengths)

    sym_lengths = lengths[inverse].astype(np.int64)
    sym_codes = codes[inverse]
    offsets = np.concatenate(([0], np.cumsum(sym_lengths)))
    total_bits = int(offsets[-1])

    bits = np.zeros(total_bits, dtype=np.uint8)
    max_len = int(lengths.max())
    # Vectorized scatter: for bit j of each code (MSB first), write where len > j.
    for j in range(max_len):
        mask = sym_lengths > j
        if not mask.any():
            break
        shift = (sym_lengths[mask] - 1 - j).astype(np.uint64)
        bitvals = ((sym_codes[mask] >> shift) & np.uint64(1)).astype(np.uint8)
        bits[offsets[:-1][mask] + j] = bitvals

    payload = np.packbits(bits).tobytes()
    header = struct.pack("<I", len(alphabet))
    header += alphabet.astype("<i8").tobytes()
    header += lengths.astype(np.uint8).tobytes()
    header += struct.pack("<QQ", symbols.size, total_bits)
    return header + payload


def huffman_decode(data: bytes) -> np.ndarray:
    """Inverse of :func:`huffman_encode`; returns int64 symbols.

    Vectorized canonical LUT walk (no per-symbol Python loop):

    1. every bit position's next-``max_len``-bit window is read at once from
       four-byte little loads of the packed stream (``max_len + 7 <= 32``);
    2. the canonical LUT maps each window to its (symbol, code length), so
       ``jump[p] = p + len`` is the whole decode automaton as one array;
    3. the sequential position chain ``p_{i+1} = jump[p_i]`` is expanded by
       pointer doubling — after round r the first ``2^r`` positions are
       known and ``jump`` composes with itself, so ``n_syms`` positions
       materialize in ``ceil(log2 n_syms)`` numpy gather rounds.

    Decodes the exact byte streams the encoder writes (regression-gated
    against the reference walk in ``tests/test_coding.py``).
    """
    (n_alpha,) = struct.unpack_from("<I", data, 0)
    off = 4
    if n_alpha == 0:
        return np.zeros(0, dtype=np.int64)
    alphabet = np.frombuffer(data, dtype="<i8", count=n_alpha, offset=off).copy()
    off += 8 * n_alpha
    lengths = np.frombuffer(data, dtype=np.uint8, count=n_alpha, offset=off).copy()
    off += n_alpha
    n_syms, n_bits = struct.unpack_from("<QQ", data, off)
    off += 16
    if n_syms == 0:
        return np.zeros(0, dtype=np.int64)
    if n_bits > 8 * (len(data) - off):
        # the guard np.unpackbits(count=n_bits) used to provide: a truncated
        # payload must fail loudly, not decode missing bits as zeros
        raise ValueError(
            f"truncated Huffman stream: header wants {n_bits} bits, "
            f"payload has {8 * (len(data) - off)}"
        )

    codes = _canonical_codes(lengths)
    max_len = int(lengths.max())
    if max_len <= 20:
        # Full lookup table: next `max_len` bits -> (symbol index, code length).
        table_sym = np.zeros(1 << max_len, dtype=np.int64)
        table_len = np.zeros(1 << max_len, dtype=np.int64)
        for sym in range(n_alpha):
            ln = int(lengths[sym])
            base = int(codes[sym]) << (max_len - ln)
            span = 1 << (max_len - ln)
            table_sym[base : base + span] = sym
            table_len[base : base + span] = ln

        # Decode in bit-range chunks so the per-position temporaries stay
        # O(chunk) however large the stream is (the automaton arrays cost
        # ~50 bytes per payload bit while live).
        payload = np.frombuffer(data, dtype=np.uint8, offset=off)
        buf = np.zeros(len(payload) + 8, dtype=np.uint8)
        buf[: len(payload)] = payload
        mask = np.uint32((1 << max_len) - 1)
        out = np.empty(n_syms, dtype=np.int64)
        filled = 0
        abs_pos = 0
        while filled < n_syms:
            lo = abs_pos
            dom = min(DECODE_CHUNK_BITS, n_bits - lo)
            if dom <= 0:
                raise ValueError("corrupt Huffman stream: ran out of bits")
            # (1) window at every chunk position, from overlapping 32-bit
            # big-endian reads (the zero pad covers the trailing overreads)
            pos = np.arange(lo, lo + dom, dtype=np.int64)
            byte0 = pos >> 3
            word = (
                (buf[byte0].astype(np.uint32) << np.uint32(24))
                | (buf[byte0 + 1].astype(np.uint32) << np.uint32(16))
                | (buf[byte0 + 2].astype(np.uint32) << np.uint32(8))
                | buf[byte0 + 3].astype(np.uint32)
            )
            shift = (np.uint32(32 - max_len) - (pos & 7).astype(np.uint32)).astype(
                np.uint32
            )
            window = ((word >> shift) & mask).astype(np.int64)
            # (2) the chunk-relative decode automaton: jump[r] = r + code len
            # at position lo + r.  Values are EXACT even past the chunk end
            # (the window reads don't stop at dom), which is what hands the
            # next chunk its exact start; composition below treats >= dom as
            # absorbing so those values survive the doubling untouched.
            sym_at = table_sym[window]
            jump = pos + table_len[window] - lo
            # (3) pointer-doubling expansion of the position chain: cap + 1
            # entries so the first out-of-chunk position (the continuation)
            # is materialized alongside the in-chunk symbol starts
            cap = min(n_syms - filled, dom)
            length = cap + 1
            chain = np.empty(length, dtype=np.int64)
            chain[0] = 0
            m = 1
            while m < length:
                take = min(m, length - m)
                src = chain[:take]
                safe = np.minimum(src, dom - 1)
                chain[m : m + take] = np.where(src >= dom, src, jump[safe])
                m += take
                if m < length:
                    safe = np.minimum(jump, dom - 1)
                    jump = np.where(jump >= dom, jump, jump[safe])
            # positions are non-decreasing (code lengths >= 1, absorbing past
            # dom), so the first out-of-chunk entry is a searchsorted away
            k = min(int(np.searchsorted(chain, dom)), cap)
            out[filled : filled + k] = sym_at[chain[:k]]
            filled += k
            if filled < n_syms:
                abs_pos = lo + int(chain[k])
        return alphabet[out]
    # Fallback: per-bit canonical walk (rare: pathological length > 20).
    bits = np.unpackbits(np.frombuffer(data, dtype=np.uint8, offset=off), count=n_bits)
    out = np.empty(n_syms, dtype=np.int64)
    pos = 0
    lut = {(int(lengths[s]), int(codes[s])): s for s in range(n_alpha)}
    for i in range(n_syms):
        code = 0
        ln = 0
        while True:
            code = (code << 1) | int(bits[pos])
            pos += 1
            ln += 1
            sym = lut.get((ln, code))
            if sym is not None:
                out[i] = sym
                break
    return alphabet[out]
