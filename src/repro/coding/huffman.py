"""Canonical Huffman coding for quantized edit streams (paper §IV-B, [37]).

Encoder is fully vectorized (bit scatter over numpy); decoder uses a
lookup-table walk.  The paper chains Huffman with ZSTD; see
:mod:`repro.coding.lossless` for the chained entry points.

Wire format (little-endian):
  u32  n_symbols_in_alphabet
  i64  per-alphabet-symbol raw value   (n_symbols entries, int64)
  u8   per-alphabet-symbol code length (n_symbols entries)
  u64  n_encoded_symbols
  u64  n_bits
  u8[] bitstream (MSB first within each byte)
"""

from __future__ import annotations

import heapq
import struct

import numpy as np


def _code_lengths(freqs: np.ndarray) -> np.ndarray:
    """Huffman code lengths from symbol frequencies (heap merge)."""
    n = len(freqs)
    if n == 1:
        return np.array([1], dtype=np.uint8)
    # heap entries: (freq, tiebreak, set-of-symbol-indices)
    heap = [(int(f), i, [i]) for i, f in enumerate(freqs)]
    heapq.heapify(heap)
    lengths = np.zeros(n, dtype=np.int64)
    tiebreak = n
    while len(heap) > 1:
        fa, _, sa = heapq.heappop(heap)
        fb, _, sb = heapq.heappop(heap)
        for s in sa:
            lengths[s] += 1
        for s in sb:
            lengths[s] += 1
        heapq.heappush(heap, (fa + fb, tiebreak, sa + sb))
        tiebreak += 1
    return lengths.astype(np.uint8)


def _canonical_codes(lengths: np.ndarray) -> np.ndarray:
    """Canonical Huffman code values (uint64) given code lengths.

    Symbols are ranked by (length, symbol-index); codes assigned in canonical
    order so the decoder only needs the lengths.
    """
    order = np.lexsort((np.arange(len(lengths)), lengths))
    codes = np.zeros(len(lengths), dtype=np.uint64)
    code = 0
    prev_len = int(lengths[order[0]])
    for rank, sym in enumerate(order):
        ln = int(lengths[sym])
        if rank > 0:
            code = (code + 1) << (ln - prev_len)
        codes[sym] = code
        prev_len = ln
    return codes


def huffman_encode(symbols: np.ndarray) -> bytes:
    """Encode an integer symbol stream; returns self-describing bytes."""
    symbols = np.asarray(symbols).astype(np.int64).ravel()
    if symbols.size == 0:
        return struct.pack("<I", 0) + struct.pack("<QQ", 0, 0)
    alphabet, inverse, counts = np.unique(symbols, return_inverse=True, return_counts=True)
    lengths = _code_lengths(counts)
    codes = _canonical_codes(lengths)

    sym_lengths = lengths[inverse].astype(np.int64)
    sym_codes = codes[inverse]
    offsets = np.concatenate(([0], np.cumsum(sym_lengths)))
    total_bits = int(offsets[-1])

    bits = np.zeros(total_bits, dtype=np.uint8)
    max_len = int(lengths.max())
    # Vectorized scatter: for bit j of each code (MSB first), write where len > j.
    for j in range(max_len):
        mask = sym_lengths > j
        if not mask.any():
            break
        shift = (sym_lengths[mask] - 1 - j).astype(np.uint64)
        bitvals = ((sym_codes[mask] >> shift) & np.uint64(1)).astype(np.uint8)
        bits[offsets[:-1][mask] + j] = bitvals

    payload = np.packbits(bits).tobytes()
    header = struct.pack("<I", len(alphabet))
    header += alphabet.astype("<i8").tobytes()
    header += lengths.astype(np.uint8).tobytes()
    header += struct.pack("<QQ", symbols.size, total_bits)
    return header + payload


def huffman_decode(data: bytes) -> np.ndarray:
    """Inverse of :func:`huffman_encode`; returns int64 symbols."""
    (n_alpha,) = struct.unpack_from("<I", data, 0)
    off = 4
    if n_alpha == 0:
        return np.zeros(0, dtype=np.int64)
    alphabet = np.frombuffer(data, dtype="<i8", count=n_alpha, offset=off).copy()
    off += 8 * n_alpha
    lengths = np.frombuffer(data, dtype=np.uint8, count=n_alpha, offset=off).copy()
    off += n_alpha
    n_syms, n_bits = struct.unpack_from("<QQ", data, off)
    off += 16
    bits = np.unpackbits(np.frombuffer(data, dtype=np.uint8, offset=off), count=n_bits)

    codes = _canonical_codes(lengths)
    max_len = int(lengths.max())
    if max_len <= 20:
        # Full lookup table: next `max_len` bits -> (symbol index, code length).
        table_sym = np.zeros(1 << max_len, dtype=np.int64)
        table_len = np.zeros(1 << max_len, dtype=np.int64)
        for sym in range(n_alpha):
            ln = int(lengths[sym])
            base = int(codes[sym]) << (max_len - ln)
            span = 1 << (max_len - ln)
            table_sym[base : base + span] = sym
            table_len[base : base + span] = ln
        # Pad the bitstream so the final window read never overruns.
        padded = np.concatenate([bits, np.zeros(max_len, dtype=np.uint8)])
        weights = (1 << np.arange(max_len - 1, -1, -1)).astype(np.int64)
        out = np.empty(n_syms, dtype=np.int64)
        pos = 0
        for i in range(n_syms):
            window = int(padded[pos : pos + max_len] @ weights)
            sym = table_sym[window]
            out[i] = sym
            pos += int(table_len[window])
        return alphabet[out]
    # Fallback: per-bit canonical walk (rare: pathological length > 20).
    # first_code/first_rank per length, symbols in canonical order.
    order = np.lexsort((np.arange(n_alpha), lengths))
    out = np.empty(n_syms, dtype=np.int64)
    pos = 0
    code_of = {int(codes[s]): None for s in range(n_alpha)}  # noqa: F841 (doc)
    lut = {(int(lengths[s]), int(codes[s])): s for s in range(n_alpha)}
    for i in range(n_syms):
        code = 0
        ln = 0
        while True:
            code = (code << 1) | int(bits[pos])
            pos += 1
            ln += 1
            sym = lut.get((ln, code))
            if sym is not None:
                out[i] = sym
                break
    return alphabet[out]
