"""Pure-jnp oracle for the blockwise decorrelating transform + quantize."""

from __future__ import annotations

import jax.numpy as jnp


def block_transform_quantize_ref(blocks: jnp.ndarray, matrix: jnp.ndarray, q: float):
    """blocks: (nb, B) flattened blocks; matrix: (B, B) separable transform
    (already Kronecker-expanded); q: quantization step.

    Returns int32 coefficient codes (nb, B).
    """
    coeffs = blocks @ matrix.T
    return jnp.rint(coeffs / q).astype(jnp.int32)
