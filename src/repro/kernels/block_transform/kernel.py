"""Blockwise decorrelating transform + quantize Pallas TPU kernel.

The zfplike base compressor's hot loop: every 4^d (or 8^d) block is hit with a
separable orthonormal transform and its coefficients quantized.  On TPU we
flatten each block to a row and Kronecker-expand the separable transform into
one (B, B) matrix, turning the whole stage into a single MXU GEMM fused with
the quantizer: (block_rows, B) x (B, B) per grid step — MXU-aligned since
B = 64 (4^3) or 128 (4^2 pairs) after the ops.py padding, and block_rows is a
multiple of 8.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 512


def _bt_kernel(x_ref, mat_ref, codes_ref, *, q: float):
    x = x_ref[...]
    mat = mat_ref[...]
    coeffs = jnp.dot(x, mat.T, preferred_element_type=jnp.float32)
    codes_ref[...] = jnp.rint(coeffs / q).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("q", "interpret", "block_rows"))
def block_transform_pallas(
    blocks: jnp.ndarray,
    matrix: jnp.ndarray,
    *,
    q: float,
    interpret: bool = False,
    block_rows: int = BLOCK_ROWS,
):
    nb, B = blocks.shape
    assert nb % block_rows == 0 and matrix.shape == (B, B)
    grid = (nb // block_rows,)
    return pl.pallas_call(
        functools.partial(_bt_kernel, q=q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, B), lambda i: (i, 0)),
            pl.BlockSpec((B, B), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, B), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, B), jnp.int32),
        interpret=interpret,
    )(blocks, matrix)
