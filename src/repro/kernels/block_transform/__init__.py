from repro.kernels.block_transform import ops, ref
from repro.kernels.block_transform.ops import block_transform_quantize

__all__ = ["ops", "ref", "block_transform_quantize"]
