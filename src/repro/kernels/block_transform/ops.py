"""jit'd wrapper for the blockwise transform+quantize kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.block_transform.kernel import BLOCK_ROWS, block_transform_pallas


def _is_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("q", "block_rows", "interpret"))
def block_transform_quantize(
    blocks: jnp.ndarray,
    matrix: jnp.ndarray,
    q: float,
    block_rows: int = BLOCK_ROWS,
    interpret: bool | None = None,
):
    """(nb, B) blocks -> int32 codes via one fused GEMM+quantize kernel."""
    if interpret is None:
        interpret = _is_cpu()
    nb, B = blocks.shape
    pad = (-nb) % block_rows
    x = jnp.pad(blocks.astype(jnp.float32), ((0, pad), (0, 0)))
    codes = block_transform_pallas(
        x, matrix.astype(jnp.float32), q=float(q), interpret=interpret, block_rows=block_rows
    )
    return codes[:nb]
