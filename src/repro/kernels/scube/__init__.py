from repro.kernels.scube import ops, ref
from repro.kernels.scube.ops import project_scube_fused

__all__ = ["ops", "ref", "project_scube_fused"]
