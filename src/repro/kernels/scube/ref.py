"""Pure-jnp oracle for the fused s-cube projection (paper Alg. 1 lines 12-14)."""

from __future__ import annotations

import jax.numpy as jnp


def project_scube_fused_ref(eps: jnp.ndarray, E):
    """Clip spatial errors to +-E; returns (clipped, displacement)."""
    clipped = jnp.clip(eps, -E, E)
    return clipped, clipped - eps
