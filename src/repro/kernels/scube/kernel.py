"""Fused s-cube projection Pallas TPU kernel (paper §IV-D ProjectOntoSCube).

One (rows, 128) VMEM pass: clip to +-E and emit the edit displacement.
E is scalar ((1,1) block) or pointwise (tiled like the data).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256
LANES = 128


def _scube_kernel(x_ref, e_ref, out_ref, edit_ref):
    x = x_ref[...]
    e = e_ref[...]
    c = jnp.clip(x, -e, e)
    out_ref[...] = c
    edit_ref[...] = c - x


@functools.partial(jax.jit, static_argnames=("pointwise", "interpret", "block_rows"))
def scube_pallas(
    eps: jnp.ndarray,
    E: jnp.ndarray,
    *,
    pointwise: bool,
    interpret: bool = False,
    block_rows: int = BLOCK_ROWS,
):
    rows = eps.shape[0]
    assert eps.shape[1] == LANES and rows % block_rows == 0
    grid = (rows // block_rows,)
    data_spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    e_spec = data_spec if pointwise else pl.BlockSpec((1, 1), lambda i: (0, 0))
    return pl.pallas_call(
        _scube_kernel,
        grid=grid,
        in_specs=[data_spec, e_spec],
        out_specs=[data_spec, data_spec],
        out_shape=[jax.ShapeDtypeStruct(eps.shape, eps.dtype)] * 2,
        interpret=interpret,
    )(eps, E)
