"""jit'd wrapper for the fused s-cube projection kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.scube.kernel import BLOCK_ROWS, LANES, scube_pallas


def _is_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def project_scube_fused(
    eps: jnp.ndarray,
    E,
    block_rows: int = BLOCK_ROWS,
    interpret: bool | None = None,
):
    """Drop-in replacement for core.cubes.project_scube: (clipped, displacement)."""
    if interpret is None:
        interpret = _is_cpu()
    shape, dtype = eps.shape, eps.dtype
    flat = eps.astype(jnp.float32).reshape(-1)
    chunk = block_rows * LANES
    pad = (-flat.size) % chunk
    flat = jnp.pad(flat, (0, pad))
    tiled = flat.reshape(-1, LANES)
    E_arr = jnp.asarray(E, dtype=jnp.float32)
    pointwise = E_arr.ndim > 0
    if pointwise:
        e_flat = jnp.pad(jnp.broadcast_to(E_arr, shape).astype(jnp.float32).reshape(-1), (0, pad), constant_values=jnp.inf)
        e_in = e_flat.reshape(-1, LANES)
    else:
        e_in = E_arr.reshape(1, 1)
    c, ed = scube_pallas(tiled, e_in, pointwise=pointwise, interpret=interpret, block_rows=block_rows)

    def untile(t):
        f = t.reshape(-1)
        if pad:
            f = f[:-pad]
        return f.reshape(shape).astype(dtype)

    return untile(c), untile(ed)
