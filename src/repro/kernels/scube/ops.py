"""jit'd wrapper for the fused s-cube projection kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.scube.kernel import BLOCK_ROWS, scube_pallas
from repro.kernels.tiling import is_cpu as _is_cpu
from repro.kernels.tiling import tile as _tile
from repro.kernels.tiling import tile_bound as _tile_bound
from repro.kernels.tiling import untile as _untile


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def project_scube_fused(
    eps: jnp.ndarray,
    E,
    block_rows: int = BLOCK_ROWS,
    interpret: bool | None = None,
):
    """Drop-in replacement for core.cubes.project_scube: (clipped, displacement)."""
    if interpret is None:
        interpret = _is_cpu()
    shape, dtype = eps.shape, eps.dtype
    tiled, pad = _tile(eps.astype(jnp.float32), block_rows)
    E_arr = jnp.asarray(E, dtype=jnp.float32)
    pointwise = E_arr.ndim > 0
    if pointwise:
        e_in = _tile_bound(E_arr, shape, block_rows, pad)
    else:
        e_in = E_arr.reshape(1, 1)
    c, ed = scube_pallas(tiled, e_in, pointwise=pointwise, interpret=interpret, block_rows=block_rows)
    return _untile(c, shape, pad).astype(dtype), _untile(ed, shape, pad).astype(dtype)
