"""Pallas TPU kernels for FFCz hot spots + the transformer attention hot path.

Each kernel subpackage follows the repo convention:

  <name>/kernel.py  pl.pallas_call with explicit BlockSpec VMEM tiling
  <name>/ops.py     jit'd public wrapper (padding, tiling, dtype handling)
  <name>/ref.py     pure-jnp oracle used by the allclose test sweeps

Kernels (paper §IV-D Table IV → TPU adaptation, DESIGN.md §2):

  fcube            fused CheckConvergence + ProjectOntoFCube (one VMEM pass)
  scube            fused s-cube projection + violation count
  quantize         QuantizeEdits (uniform grid, int codes + flags)
  block_transform  4^d decorrelating transform of the zfplike base compressor
  flash_attention  causal GQA flash attention (framework serving/training hot
                   path; FFCz itself is FFT-dominated and XLA owns the FFT)

All kernels are TPU-targeted (MXU/VPU-aligned block shapes) and validated on
CPU with ``interpret=True``.
"""
