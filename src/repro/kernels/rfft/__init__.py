"""Fused rFFT kernel suite: pack-trick C2R/R2C + projection epilogues."""

from repro.kernels.rfft.ops import (
    fwd_epilogue_fused,
    mirror_half_spectrum,
    packed_irfft,
    packed_irfftn,
    packed_rfftn,
    supports_packed,
    twiddle_plan,
    unpack_sclip_fused,
)

__all__ = [
    "fwd_epilogue_fused",
    "mirror_half_spectrum",
    "packed_irfft",
    "packed_irfftn",
    "packed_rfftn",
    "supports_packed",
    "twiddle_plan",
    "unpack_sclip_fused",
]
