"""Fused rFFT kernel suite: pack-trick C2R/R2C + projection epilogues.

The POCS hot loop spends its time in the inverse/forward real transforms
that bracket each projection pair.  This package provides the two faster
``fft_impl`` rungs behind the engine's selector (docs/architecture.md):

  ``packed``   pack-trick transforms (:mod:`repro.kernels.rfft.ops`): an
               N-point real transform rides an N/2-point complex FFT via
               twiddle recombination (``twiddle_plan``), restricted to
               even last axes (``supports_packed``); 1.16-1.20x per
               iteration over the stock ``jnp.fft`` path, bitwise-gated
               against :mod:`repro.kernels.rfft.ref`.
  ``pallas``   the packed transform with the POCS projection epilogue
               fused into a Pallas kernel (:mod:`repro.kernels.rfft.kernel`):
               ``unpack_sclip_fused`` fuses C2R unpacking with the s-cube
               clip, ``fwd_epilogue_fused`` fuses R2C packing with the
               f-cube projection — eliminating one HBM round trip per loop
               iteration.  Compiles via Mosaic on TPU; interpret mode
               elsewhere (priced honestly in BENCH_pocs.json).

Both impls produce the same per-block program across the local / batched /
sharded backends, and both accept the temporal warm-start state
(docs/streaming.md) unchanged — the warm spectrum enters as loop state, not
as a transform input.
"""

from repro.kernels.rfft.ops import (
    fwd_epilogue_fused,
    mirror_half_spectrum,
    packed_irfft,
    packed_irfftn,
    packed_rfftn,
    supports_packed,
    twiddle_plan,
    unpack_sclip_fused,
)

__all__ = [
    "fwd_epilogue_fused",
    "mirror_half_spectrum",
    "packed_irfft",
    "packed_irfftn",
    "packed_rfftn",
    "supports_packed",
    "twiddle_plan",
    "unpack_sclip_fused",
]
