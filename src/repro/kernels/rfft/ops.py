"""Pack-trick C2R/R2C transforms + fused projection epilogues (POCS hot loop).

Why this exists: the POCS loop is transform-bound, and on every measured
backend the *C2R inverse* is the slow half — XLA's ``irfftn`` custom call
costs ~2.1x the R2C forward on the CI CPU (the forward DUCC r2c already
implements the pack trick internally).  The pack trick computes an N-point
real transform via an N/2-point *complex* transform plus O(N) twiddle work:

  forward (R2C):  pack ``z[n] = x[2n] + i x[2n+1]``, take the complex FFT
    ``Z`` over ALL axes, and recombine ``X[k] = E[k] + w_fwd[k] O[k]`` with
    ``E = (Z + conj(Z~))/2``, ``O = (Z - conj(Z~))/(2i)``, where ``Z~`` is
    the Hermitian mirror ``Z[-k0, .., Nh-k]`` (leading axes negated mod N_a,
    last axis reflected; the even/odd sample fields are real, so their
    spectra are Hermitian) and ``w_fwd[k] = exp(-2 pi i k / N)``.
  inverse (C2R):  ``E = (X + conj(X~))/2``, ``O = w_inv (X - conj(X~))/2``
    with ``w_inv[k] = exp(+2 pi i k / N)``, then ``z = ifftn(E + iO)`` over
    all axes at half the last-axis length, and de-interleave
    ``x[2n] = Re z[n]``, ``x[2n+1] = Im z[n]``.

Both run on any rank with only jnp primitives (vmap-safe, so the pencil
backends lift them for free).  Twiddles come from a cached plan registry
(:func:`twiddle_plan`, keyed by last-axis length + dtype) so repeated shapes
never rebuild them.

Measured on the CI container CPU (512^2 / 128x128x64 POCS loop, the
committed ``BENCH_pocs.json`` record): swapping ONLY the inverse for
:func:`packed_irfftn` is 1.20x / 1.16x per iteration — the forward keeps
``jnp.fft.rfftn`` because DUCC's r2c is already packed and beats
:func:`packed_rfftn` (which is still provided: it is the fallback-free R2C
for backends without a native r2c, and the oracle the tests pin).

The ``pallas`` variant (:func:`fwd_epilogue_fused`,
:func:`unpack_sclip_fused`) goes further: the forward epilogue fuses the
f-cube clip + pair-weighted violation count + the inverse pack twiddle into
one VMEM pass over the spectrum, and the inverse epilogue fuses the s-cube
clip into the de-interleave — one pass over the data instead of
FFT-then-clip, eliminating the two per-iteration HBM round-trips the
unfused loop pays (kernels in :mod:`repro.kernels.rfft.kernel`; interpret
mode on CPU, Mosaic on TPU).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.rfft.kernel import (
    BLOCK_ROWS,
    rfft_fwd_epilogue_pallas,
    unpack_sclip_pallas,
)
from repro.kernels.tiling import is_cpu as _is_cpu
from repro.kernels.tiling import tile as _tile
from repro.kernels.tiling import tile_bound as _tile_bound
from repro.kernels.tiling import untile as _untile


# ---------------------------------------------------------------------------
# twiddle-plan registry


@functools.lru_cache(maxsize=None)
def twiddle_plan(n: int, dtype_name: str = "float32") -> Tuple[np.ndarray, np.ndarray]:
    """Cached pack-trick twiddles for an even last-axis length ``n``.

    Returns ``(w_fwd, w_inv)``, each of shape ``(n // 2 + 1,)``:
    ``w_fwd[k] = exp(-2 pi i k / n)`` (forward recombination) and its
    conjugate (inverse).  Keyed by ``(n, dtype)`` — the twiddles are the only
    shape-dependent precompute the packed transforms need, so caching here
    makes every same-shape trace reuse one host constant (embedded once per
    compiled program).  Built in float64 and rounded once to the working
    precision.
    """
    if n % 2:
        raise ValueError(f"pack-trick transforms need an even last axis, got {n}")
    k = np.arange(n // 2 + 1)
    w = np.exp((-2j * np.pi / n) * k)
    cdtype = np.complex64 if dtype_name == "float32" else np.complex128
    return w.astype(cdtype), np.conj(w).astype(cdtype)


def supports_packed(shape: Tuple[int, ...]) -> bool:
    """True when the pack trick applies: even last axis of at least 2."""
    return len(shape) >= 1 and shape[-1] >= 2 and shape[-1] % 2 == 0


def mirror_half_spectrum(a: jnp.ndarray) -> jnp.ndarray:
    """Hermitian mirror index map ``a[k0, .., k] -> a[-k0, .., Nh-k]``.

    Leading axes are negated modulo their extent (flip + roll); the last
    (half-spectrum, ``Nh + 1``-long) axis is reflected in place.  Combined
    with a ``conj`` this maps each stored half-spectrum component to its
    conjugate partner's stored image — the gather both pack-trick
    recombinations share.
    """
    for ax in range(a.ndim - 1):
        a = jnp.roll(jnp.flip(a, axis=ax), 1, axis=ax)
    return a[..., ::-1]


def _interleave_last(even: jnp.ndarray, odd: jnp.ndarray) -> jnp.ndarray:
    """Riffle two (..., Nh) planes into (..., 2*Nh): out[2n]=even, out[2n+1]=odd."""
    out = jnp.stack([even, odd], axis=-1)
    return out.reshape(*even.shape[:-1], even.shape[-1] * 2)


# ---------------------------------------------------------------------------
# pure-XLA packed transforms (fft_impl="packed")


def packed_rfftn(x: jnp.ndarray) -> jnp.ndarray:
    """``jnp.fft.rfftn`` via the pack trick (complex FFT at half the last axis).

    Matches ``jnp.fft.rfftn`` to float-rounding level on any rank with an
    even last axis.  Provided as the R2C half of the suite (and the oracle
    the kernel tests pin); the POCS loop's ``"packed"`` path keeps XLA's
    forward — DUCC's r2c is already packed internally — and only swaps the
    inverse, where the measured gap is.
    """
    n = x.shape[-1]
    w_fwd, _ = twiddle_plan(n, x.dtype.name)
    z = jax.lax.complex(x[..., 0::2], x[..., 1::2])
    Z = jnp.fft.fftn(z)
    Zf = jnp.concatenate([Z, Z[..., :1]], axis=-1)  # periodic extension to k=Nh
    Zm = jnp.conj(mirror_half_spectrum(Zf))
    E = 0.5 * (Zf + Zm)
    O = -0.5j * (Zf - Zm)
    return E + jnp.asarray(w_fwd) * O


def packed_irfftn(X: jnp.ndarray, shape: Tuple[int, ...]) -> jnp.ndarray:
    """``jnp.fft.irfftn(X, s=shape)`` via the pack trick — the C2R fast path.

    One Hermitian-mirror gather, one elementwise twiddle recombination, one
    complex ``ifftn`` at half the last-axis length, one de-interleave.  On
    the CI CPU this replaces XLA's C2R custom call at ~1.55x; inside the
    POCS loop the swap is worth 1.2-1.3x per iteration (see module
    docstring).  ``shape`` is the true spatial shape (even last axis).
    """
    n = shape[-1]
    _, w_inv = twiddle_plan(n, "float32" if X.dtype == jnp.complex64 else "float64")
    # slice to the Nh-wide packed domain BEFORE the twiddle recombination:
    # Z only needs k = 0..Nh-1, so the k = Nh column never enters the math
    Xm = jnp.conj(mirror_half_spectrum(X))[..., : n // 2]
    Xs = X[..., : n // 2]
    w = jnp.asarray(w_inv)[: n // 2]
    Z = 0.5 * ((Xs + Xm) + 1j * (w * (Xs - Xm)))
    z = jnp.fft.ifftn(Z)
    return _interleave_last(z.real, z.imag)


def packed_irfft(X: jnp.ndarray, n: int) -> jnp.ndarray:
    """Last-axis-only pack-trick C2R: ``jnp.fft.irfft(X, n, axis=-1)``.

    Each last-axis line must be the half-spectrum of a real line (true after
    the leading c2c axes have been inverse-transformed), so the Hermitian
    mirror reduces to the in-line reflection.  This is the form the
    distributed pencil transform composes: :func:`...dist_fft.irfftn_local`
    swaps exactly its final local last-axis pass for this one.
    """
    _, w_inv = twiddle_plan(n, "float32" if X.dtype == jnp.complex64 else "float64")
    Xm = jnp.conj(X[..., ::-1])[..., : n // 2]
    Xs = X[..., : n // 2]
    w = jnp.asarray(w_inv)[: n // 2]
    Z = 0.5 * ((Xs + Xm) + 1j * (w * (Xs - Xm)))
    z = jnp.fft.ifft(Z, axis=-1)
    return _interleave_last(z.real, z.imag)


# ---------------------------------------------------------------------------
# fused Pallas epilogues (fft_impl="pallas"); plane tiling + padding contract
# shared with the fcube/scube suites via repro.kernels.tiling


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret", "check_tol"))
def fwd_epilogue_fused(
    delta: jnp.ndarray,
    Delta,
    Delta_m=None,
    weight=None,
    block_rows: int = BLOCK_ROWS,
    interpret: bool | None = None,
    check_tol: float = 0.0,
    check_slack=0.0,
):
    """Fused forward epilogue: f-clip + pair-weighted count + inverse twiddle.

    One kernel pass over the half-spectrum ``delta`` replaces the loop's
    ``project_fcube`` + ``fcube_violations`` + the inverse pack-twiddle
    prologue.  ``Delta_m`` is the Hermitian-mirrored pointwise bound
    (loop-invariant — mirror it once outside the while body; ``None`` for
    scalar bounds).  ``weight`` is the conjugate-pair multiplicity plane
    (None counts each component once).

    Returns ``(clipped, displacement, Z, violations)`` where ``Z`` is the
    full-grid packed spectrum — slice ``Z[..., :N//2]`` and ``ifftn`` it to
    finish the inverse.
    """
    if interpret is None:
        interpret = _is_cpu()
    shape = delta.shape
    n = 2 * (shape[-1] - 1)  # true last-axis length (even by construction)
    _, w_inv = twiddle_plan(n, "float32" if delta.dtype == jnp.complex64 else "float64")
    w_grid = jnp.broadcast_to(jnp.asarray(w_inv), shape)
    mirrored = mirror_half_spectrum(delta)

    re, pad = _tile(delta.real.astype(jnp.float32), block_rows)
    im, _ = _tile(delta.imag.astype(jnp.float32), block_rows)
    mr, _ = _tile(mirrored.real.astype(jnp.float32), block_rows)
    mi, _ = _tile(mirrored.imag.astype(jnp.float32), block_rows)
    wr, _ = _tile(w_grid.real.astype(jnp.float32), block_rows)
    wi, _ = _tile(w_grid.imag.astype(jnp.float32), block_rows)
    Delta_arr = jnp.asarray(Delta, dtype=jnp.float32)
    pointwise = Delta_arr.ndim > 0
    if pointwise:
        if Delta_m is None:
            Delta_m = mirror_half_spectrum(jnp.broadcast_to(Delta_arr, shape))
        dt = _tile_bound(Delta_arr, shape, block_rows, pad)
        dtm = _tile_bound(jnp.asarray(Delta_m, dtype=jnp.float32), shape, block_rows, pad)
    else:
        dt = dtm = Delta_arr.reshape(1, 1)
    if weight is not None:
        # zero-pad: padded lanes carry weight 0 and never count
        wt, _ = _tile(jnp.broadcast_to(jnp.asarray(weight, dtype=jnp.int32), shape), block_rows)
    else:
        wt, _ = _tile(jnp.ones(shape, dtype=jnp.int32), block_rows)
    slk = jnp.asarray(check_slack, dtype=jnp.float32).reshape(1, 1)

    cr, ci, er, ei, zr, zi, viol = rfft_fwd_epilogue_pallas(
        re, im, mr, mi, dt, dtm, wr, wi, wt, slk,
        pointwise=pointwise, interpret=interpret, block_rows=block_rows,
        check_tol=check_tol,
    )
    clipped = (_untile(cr, shape, pad) + 1j * _untile(ci, shape, pad)).astype(delta.dtype)
    edits = (_untile(er, shape, pad) + 1j * _untile(ei, shape, pad)).astype(delta.dtype)
    Z = (_untile(zr, shape, pad) + 1j * _untile(zi, shape, pad)).astype(delta.dtype)
    # dtype pinned so the loop carry stays int32 under jax_enable_x64
    return clipped, edits, Z, jnp.sum(viol, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("shape", "block_rows", "interpret"))
def unpack_sclip_fused(
    z: jnp.ndarray,
    E,
    shape: Tuple[int, ...],
    block_rows: int = BLOCK_ROWS,
    interpret: bool | None = None,
):
    """Fused inverse epilogue: s-cube clip on packed planes + de-interleave.

    ``z`` is the half-length complex ``ifftn`` output (its Re/Im planes are
    the even/odd spatial samples of the true ``shape``-sized field); the
    elementwise s-clip commutes with the de-interleave, so one kernel pass
    clips both planes and emits the displacement before the riffle.

    Returns ``(eps_clipped, displacement)``, both real with ``shape``.
    """
    if interpret is None:
        interpret = _is_cpu()
    zr, pad = _tile(z.real.astype(jnp.float32), block_rows)
    zi, _ = _tile(z.imag.astype(jnp.float32), block_rows)
    E_arr = jnp.asarray(E, dtype=jnp.float32)
    pointwise = E_arr.ndim > 0
    if pointwise:
        Eb = jnp.broadcast_to(E_arr, shape)
        ee = _tile_bound(Eb[..., 0::2], z.shape, block_rows, pad)
        eo = _tile_bound(Eb[..., 1::2], z.shape, block_rows, pad)
    else:
        ee = eo = E_arr.reshape(1, 1)
    ce, co, de, do = unpack_sclip_pallas(
        zr, zi, ee, eo, pointwise=pointwise, interpret=interpret, block_rows=block_rows
    )
    eps = _interleave_last(_untile(ce, z.shape, pad), _untile(co, z.shape, pad))
    disp = _interleave_last(_untile(de, z.shape, pad), _untile(do, z.shape, pad))
    return eps.reshape(shape), disp.reshape(shape)
