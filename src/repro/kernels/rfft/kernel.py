"""Fused rFFT-epilogue Pallas TPU kernels for the POCS hot loop.

The loop's per-iteration transform+clip sequence (paper §IV-D, our Alg. 1
body) is ``rfftn -> f-cube clip -> irfftn -> s-cube clip``.  XLA's FFTs are
custom calls, so the clips around them are separate HBM passes.  These
kernels close that gap by fusing every elementwise stage *between* the FFT
custom calls into single VMEM sweeps:

``_rfft_fwd_epilogue_kernel``
    One (rows, 128)-tiled pass over the forward half-spectrum that performs
    the f-cube clip, accumulates the edit displacement, reduces the
    pair-weighted violation count (the fused CheckConvergence of
    :mod:`repro.kernels.fcube`), AND applies the inverse pack-trick twiddle
    (``Z = E + iO`` with ``E = (X + conj(X~))/2``, ``O = w_inv (X -
    conj(X~))/2`` — see :mod:`repro.kernels.rfft.ops`) so the output feeds a
    half-length complex ``ifftn`` directly.  The mirrored spectrum arrives as
    a separate *unclipped* operand plus its mirrored bound: ``clip`` commutes
    with the Hermitian mirror when the bound is mirrored too, so the kernel
    clips both views locally instead of waiting on its own output.

``_unpack_sclip_kernel``
    The inverse epilogue: the pack-trick inverse ends with a complex
    half-length ``ifftn`` whose real/imag planes are the even/odd spatial
    samples.  The s-cube clip is elementwise and therefore commutes with the
    de-interleave, so one pass clips both planes and emits the clipped
    samples plus the spatial edit displacement, still in packed layout; the
    ops wrapper interleaves.

Complex data is carried as separate Re/Im planes (TPU has no complex VREGs).
Bounds come scalar ((1, 1) blocks) or pointwise (tiled like the data),
selected statically.  Padded lanes carry zero data, +inf pointwise bounds and
zero pair weights, so they never clip, never count, and produce zero Z.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VPU-aligned tile, shared with the fcube/scube kernels.  The forward
# epilogue holds 17 live (rows, 128) float32 planes per grid step:
# 256*128*4B * 17 ~ 2.2 MiB << VMEM.
BLOCK_ROWS = 256
LANES = 128


def _rfft_fwd_epilogue_kernel(
    xr_ref, xi_ref, mr_ref, mi_ref, dlt_ref, dltm_ref, wr_ref, wi_ref, pw_ref, slk_ref,
    cr_ref, ci_ref, er_ref, ei_ref, zr_ref, zi_ref, viol_ref,
    *, check_tol: float
):
    xr = xr_ref[...]
    xi = xi_ref[...]
    d = dlt_ref[...]  # (rows,128) pointwise or (1,1) scalar — broadcasts
    dm = dltm_ref[...]  # mirrored bound (same flavour as d)
    # f-cube projection + edit displacement (ProjectOntoFCube)
    cr = jnp.clip(xr, -d, d)
    ci = jnp.clip(xi, -d, d)
    cr_ref[...] = cr
    ci_ref[...] = ci
    er_ref[...] = cr - xr
    ei_ref[...] = ci - xi
    # the clipped Hermitian mirror, from the unclipped mirror operand:
    # clip(mirror(X), mirror(D)) == mirror(clip(X, D)) elementwise
    cmr = jnp.clip(mr_ref[...], -dm, dm)
    cmi = jnp.clip(mi_ref[...], -dm, dm)
    # inverse pack-trick twiddle: Z = E + iO with conj(mirror) = (cmr, -cmi)
    Er = 0.5 * (cr + cmr)
    Ei = 0.5 * (ci - cmi)
    tr = cr - cmr
    ti = ci + cmi
    wr = wr_ref[...]
    wi = wi_ref[...]
    Or = 0.5 * (wr * tr - wi * ti)
    Oi = 0.5 * (wr * ti + wi * tr)
    zr_ref[...] = Er - Oi
    zi_ref[...] = Ei + Or
    # fused CheckConvergence (see kernels/fcube): float32-resolution relative
    # tolerance + the caller's absolute slack, pair-weighted so the
    # half-spectrum count keeps full-spectrum semantics
    dt = d * (1.0 + check_tol) + slk_ref[...]
    viol = ((jnp.abs(xr) > dt) | (jnp.abs(xi) > dt)).astype(jnp.int32) * pw_ref[...]
    # dtype pinned: under jax_enable_x64 a bare sum promotes to int64 and
    # the store into the int32 out ref fails at trace time
    viol_ref[0] = jnp.sum(viol, dtype=jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("pointwise", "interpret", "block_rows", "check_tol")
)
def rfft_fwd_epilogue_pallas(
    delta_re: jnp.ndarray,
    delta_im: jnp.ndarray,
    mirror_re: jnp.ndarray,
    mirror_im: jnp.ndarray,
    Delta: jnp.ndarray,
    Delta_m: jnp.ndarray,
    w_re: jnp.ndarray,
    w_im: jnp.ndarray,
    weight: jnp.ndarray,
    check_slack: jnp.ndarray,
    *,
    pointwise: bool,
    interpret: bool = False,
    block_rows: int = BLOCK_ROWS,
    check_tol: float = 0.0,
):
    """Tiled forward epilogue: (R, 128) planes, R a multiple of ``block_rows``.

    ``mirror_re/im`` are the UNCLIPPED Hermitian-mirrored spectrum planes and
    ``Delta_m`` the mirrored bound (scalar bounds pass the same (1, 1) block
    twice).  ``w_re/im`` are the inverse pack twiddle planes (always tiled),
    ``weight`` the int32 pair-weight plane, ``check_slack`` a (1, 1) absolute
    convergence allowance.

    Returns ``(clip_re, clip_im, edit_re, edit_im, z_re, z_im,
    viol_per_block)``.
    """
    rows = delta_re.shape[0]
    assert delta_re.shape[1] == LANES and rows % block_rows == 0
    grid = (rows // block_rows,)
    data_spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    scalar_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    delta_spec = data_spec if pointwise else scalar_spec
    out_specs = [data_spec] * 6 + [pl.BlockSpec((1,), lambda i: (i,))]
    out_shapes = [jax.ShapeDtypeStruct((rows, LANES), delta_re.dtype) for _ in range(6)] + [
        jax.ShapeDtypeStruct(grid, jnp.int32)
    ]
    return pl.pallas_call(
        functools.partial(_rfft_fwd_epilogue_kernel, check_tol=check_tol),
        grid=grid,
        in_specs=[
            data_spec, data_spec, data_spec, data_spec,  # X, mirror(X)
            delta_spec, delta_spec,  # Delta, mirror(Delta)
            data_spec, data_spec,  # inverse twiddle planes
            data_spec,  # pair weights
            scalar_spec,  # check slack
        ],
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=interpret,
    )(
        delta_re, delta_im, mirror_re, mirror_im, Delta, Delta_m, w_re, w_im,
        weight, check_slack,
    )


def _unpack_sclip_kernel(zr_ref, zi_ref, ee_ref, eo_ref, ce_ref, co_ref, de_ref, do_ref):
    zr = zr_ref[...]  # even spatial samples (Re of the half-length ifftn)
    zi = zi_ref[...]  # odd spatial samples (Im)
    ee = ee_ref[...]
    eo = eo_ref[...]
    ce = jnp.clip(zr, -ee, ee)
    co = jnp.clip(zi, -eo, eo)
    ce_ref[...] = ce
    co_ref[...] = co
    de_ref[...] = ce - zr
    do_ref[...] = co - zi


@functools.partial(jax.jit, static_argnames=("pointwise", "interpret", "block_rows"))
def unpack_sclip_pallas(
    z_re: jnp.ndarray,
    z_im: jnp.ndarray,
    E_even: jnp.ndarray,
    E_odd: jnp.ndarray,
    *,
    pointwise: bool,
    interpret: bool = False,
    block_rows: int = BLOCK_ROWS,
):
    """Tiled inverse epilogue: s-cube clip on packed even/odd sample planes.

    ``E_even``/``E_odd`` are the de-interleaved pointwise bounds (or the same
    (1, 1) scalar block twice).  Returns ``(clip_even, clip_odd, edit_even,
    edit_odd)`` in packed layout; the caller interleaves.
    """
    rows = z_re.shape[0]
    assert z_re.shape[1] == LANES and rows % block_rows == 0
    grid = (rows // block_rows,)
    data_spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    e_spec = data_spec if pointwise else pl.BlockSpec((1, 1), lambda i: (0, 0))
    return pl.pallas_call(
        _unpack_sclip_kernel,
        grid=grid,
        in_specs=[data_spec, data_spec, e_spec, e_spec],
        out_specs=[data_spec] * 4,
        out_shape=[jax.ShapeDtypeStruct((rows, LANES), z_re.dtype)] * 4,
        interpret=interpret,
    )(z_re, z_im, E_even, E_odd)
