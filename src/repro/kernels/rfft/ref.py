"""Pure-numpy oracles for the fused rFFT kernel suite (test references)."""

from __future__ import annotations

from typing import Tuple

import numpy as np


def mirror_half_spectrum_ref(a: np.ndarray) -> np.ndarray:
    """Numpy twin of :func:`repro.kernels.rfft.ops.mirror_half_spectrum`."""
    for ax in range(a.ndim - 1):
        a = np.roll(np.flip(a, axis=ax), 1, axis=ax)
    return a[..., ::-1]


def packed_rfftn_ref(x: np.ndarray) -> np.ndarray:
    """Pack-trick R2C in float64 numpy (independent of the jnp path)."""
    n = x.shape[-1]
    k = np.arange(n // 2 + 1)
    w_fwd = np.exp((-2j * np.pi / n) * k)
    z = x[..., 0::2] + 1j * x[..., 1::2]
    Z = np.fft.fftn(z)
    Zf = np.concatenate([Z, Z[..., :1]], axis=-1)
    Zm = np.conj(mirror_half_spectrum_ref(Zf))
    return 0.5 * (Zf + Zm) + w_fwd * (-0.5j) * (Zf - Zm)


def packed_irfftn_ref(X: np.ndarray, shape: Tuple[int, ...]) -> np.ndarray:
    """Pack-trick C2R in float64 numpy (independent of the jnp path)."""
    n = shape[-1]
    k = np.arange(n // 2 + 1)
    w_inv = np.exp((+2j * np.pi / n) * k)
    Xm = np.conj(mirror_half_spectrum_ref(X))
    E = 0.5 * (X + Xm)
    O = 0.5 * w_inv * (X - Xm)
    z = np.fft.ifftn((E + 1j * O)[..., : n // 2])
    out = np.empty(shape, dtype=z.real.dtype)
    out[..., 0::2] = z.real
    out[..., 1::2] = z.imag
    return out


def fwd_epilogue_ref(
    delta: np.ndarray,
    Delta,
    weight=None,
    check_tol: float = 0.0,
    check_slack: float = 0.0,
):
    """Reference of :func:`repro.kernels.rfft.ops.fwd_epilogue_fused`.

    Built from the projection oracles' definitions: clip, displacement,
    pair-weighted count, then the inverse pack twiddle applied to the
    *clipped* spectrum (the kernel clips a mirrored operand instead, which
    is the same map because clip commutes with the Hermitian mirror).
    """
    n = 2 * (delta.shape[-1] - 1)
    k = np.arange(n // 2 + 1)
    w_inv = np.exp((+2j * np.pi / n) * k)
    D = np.broadcast_to(np.asarray(Delta, dtype=np.float32), delta.shape)
    clipped = np.clip(delta.real, -D, D) + 1j * np.clip(delta.imag, -D, D)
    clipped = clipped.astype(delta.dtype)
    disp = clipped - delta
    dt = D * (1.0 + check_tol) + check_slack
    vb = (np.abs(delta.real) > dt) | (np.abs(delta.imag) > dt)
    w = np.ones_like(vb, dtype=np.int64) if weight is None else np.broadcast_to(weight, vb.shape)
    viol = int((vb * w).sum())
    Xm = np.conj(mirror_half_spectrum_ref(clipped))
    E = 0.5 * (clipped + Xm)
    O = 0.5 * w_inv.astype(np.complex64) * (clipped - Xm)
    Z = (E + 1j * O).astype(delta.dtype)
    return clipped, disp, Z, viol


def unpack_sclip_ref(z: np.ndarray, E, shape: Tuple[int, ...]):
    """Reference of :func:`repro.kernels.rfft.ops.unpack_sclip_fused`."""
    x = np.empty(shape, dtype=z.real.dtype)
    x[..., 0::2] = z.real
    x[..., 1::2] = z.imag
    Eb = np.broadcast_to(np.asarray(E, dtype=x.dtype), shape)
    clipped = np.clip(x, -Eb, Eb)
    return clipped, clipped - x
