"""Pure-jnp oracle for QuantizeEdits (paper Alg. 1 line 17-18)."""

from __future__ import annotations

import jax.numpy as jnp


def quantize_edits_ref(values: jnp.ndarray, bound, m: int):
    """Uniform round-to-nearest quantization on the 2^m cube grid.

    Returns (codes int32, flags int32 of nonzero codes).
    """
    step = 2.0 * jnp.asarray(bound, dtype=jnp.float32) / (2.0**m)
    safe = jnp.where(step == 0.0, 1.0, step)
    codes = jnp.where(step == 0.0, 0.0, jnp.rint(values / safe)).astype(jnp.int32)
    return codes, (codes != 0).astype(jnp.int32)
