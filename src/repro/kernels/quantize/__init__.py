from repro.kernels.quantize import ops, ref
from repro.kernels.quantize.ops import quantize_edits

__all__ = ["ops", "ref", "quantize_edits"]
