"""QuantizeEdits Pallas TPU kernel (paper §IV-D, one thread per edit -> one
(rows, 128) VPU tile per grid step).  Emits int32 codes and nonzero flags in
the same pass — the flags feed the prefix-sum compaction, so fusing them here
saves the extra read the A100 pipeline does in CompactEdits.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256
LANES = 128


def _quantize_kernel(v_ref, b_ref, codes_ref, flags_ref, *, m: int):
    v = v_ref[...]
    b = b_ref[...]
    step = 2.0 * b / (2.0**m)
    safe = jnp.where(step == 0.0, 1.0, step)
    codes = jnp.where(step == 0.0, 0.0, jnp.rint(v / safe)).astype(jnp.int32)
    codes_ref[...] = codes
    flags_ref[...] = (codes != 0).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("m", "pointwise", "interpret", "block_rows"))
def quantize_pallas(
    values: jnp.ndarray,
    bound: jnp.ndarray,
    *,
    m: int,
    pointwise: bool,
    interpret: bool = False,
    block_rows: int = BLOCK_ROWS,
):
    rows = values.shape[0]
    assert values.shape[1] == LANES and rows % block_rows == 0
    grid = (rows // block_rows,)
    data_spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    b_spec = data_spec if pointwise else pl.BlockSpec((1, 1), lambda i: (0, 0))
    return pl.pallas_call(
        functools.partial(_quantize_kernel, m=m),
        grid=grid,
        in_specs=[data_spec, b_spec],
        out_specs=[data_spec, data_spec],
        out_shape=[
            jax.ShapeDtypeStruct(values.shape, jnp.int32),
            jax.ShapeDtypeStruct(values.shape, jnp.int32),
        ],
        interpret=interpret,
    )(values, bound)
