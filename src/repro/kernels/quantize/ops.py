"""jit'd wrapper for the QuantizeEdits kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.quantize.kernel import BLOCK_ROWS, LANES, quantize_pallas


def _is_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("m", "block_rows", "interpret"))
def quantize_edits(
    values: jnp.ndarray,
    bound,
    m: int = 16,
    block_rows: int = BLOCK_ROWS,
    interpret: bool | None = None,
):
    """Quantize an edit tensor on the 2^m cube grid; returns (codes, flags)."""
    if interpret is None:
        interpret = _is_cpu()
    shape = values.shape
    flat = values.astype(jnp.float32).reshape(-1)
    chunk = block_rows * LANES
    pad = (-flat.size) % chunk
    flat = jnp.pad(flat, (0, pad))
    tiled = flat.reshape(-1, LANES)
    b_arr = jnp.asarray(bound, dtype=jnp.float32)
    pointwise = b_arr.ndim > 0
    if pointwise:
        bf = jnp.pad(jnp.broadcast_to(b_arr, shape).astype(jnp.float32).reshape(-1), (0, pad))
        b_in = bf.reshape(-1, LANES)
    else:
        b_in = b_arr.reshape(1, 1)
    codes, flags = quantize_pallas(
        tiled, b_in, m=m, pointwise=pointwise, interpret=interpret, block_rows=block_rows
    )

    def untile(t):
        f = t.reshape(-1)
        if pad:
            f = f[:-pad]
        return f.reshape(shape)

    return untile(codes), untile(flags)
