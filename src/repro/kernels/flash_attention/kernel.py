"""Causal GQA flash-attention forward Pallas TPU kernel.

TPU-native adaptation of FlashAttention: grid (batch, q_head, q_blocks,
kv_blocks) with the kv dimension innermost ("arbitrary" semantics), online
softmax state (running max m, normalizer l, accumulator acc) held in VMEM
scratch that persists across the kv sweep.  The MXU sees two GEMMs per step:
(bq, d) x (d, bk) for scores and (bq, bk) x (bk, d) for the value gather.
GQA is expressed in the K/V BlockSpec index maps (q head h reads kv head
h // group) — no repeat/materialization of K/V per q head.

m and l are carried lane-replicated as (bq, 128) tiles (TPU VREG layout needs
the trailing-128 lane dim; column 0 is authoritative).

Causality supports the decode/suffix convention: queries are the last ``sq``
positions of the ``sk``-long kv stream (offset = sk - sq), which serves both
full prefill (sq == sk) and chunked decode (sq << sk).  Fully-masked kv
blocks are skipped via pl.when on the block-level causal test — the classic
flash skip, which halves prefill FLOPs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 256
DEFAULT_BLOCK_K = 256
_NEG_INF = -1e30
_LANES = 128

# jax renamed pltpu.TPUCompilerParams -> pltpu.CompilerParams; accept both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)


def _flash_kernel(
    q_ref,  # (1, 1, bq, d)
    k_ref,  # (1, 1, bk, d)
    v_ref,  # (1, 1, bk, d)
    o_ref,  # (1, 1, bq, d)
    m_scr,  # (bq, 128)
    l_scr,  # (bq, 128)
    acc_scr,  # (bq, d)
    *,
    scale: float,
    causal: bool,
    block_q: int,
    block_k: int,
    kv_offset: int,
):
    i_q = pl.program_id(2)
    i_k = pl.program_id(3)
    n_k = pl.num_programs(3)

    @pl.when(i_k == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Block-level causal skip: kv block strictly after the last query row.
    q_last_row = (i_q + 1) * block_q - 1 + kv_offset
    should_run = (i_k * block_k <= q_last_row) if causal else jnp.bool_(True)

    @pl.when(should_run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)
        if causal:
            rows = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) + i_q * block_q + kv_offset
            cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) + i_k * block_k
            s = jnp.where(cols <= rows, s, _NEG_INF)
        m_prev = m_scr[...][:, :1]  # (bq, 1)
        l_prev = l_scr[...][:, :1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # (bq, bk)
        l_corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * l_corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * l_corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(i_k == n_k - 1)
    def _finish():
        l = l_scr[...][:, :1]
        o_ref[0, 0, :, :] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "interpret", "kv_offset"),
)
def flash_attention_pallas(
    q: jnp.ndarray,  # (b, hq, sq, d)
    k: jnp.ndarray,  # (b, hkv, sk, d)
    v: jnp.ndarray,  # (b, hkv, sk, d)
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
    kv_offset: int | None = None,
) -> jnp.ndarray:
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0, "GQA requires hq % hkv == 0"
    group = hq // hkv
    assert sq % block_q == 0 and sk % block_k == 0, "ops.py pads to block multiples"
    if scale is None:
        scale = float(1.0 / (d**0.5))
    if kv_offset is None:
        kv_offset = sk - sq  # suffix convention (row i is kv position offset+i)

    grid = (b, hq, sq // block_q, sk // block_k)
    q_spec = pl.BlockSpec((1, 1, block_q, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0))
    kv_spec = pl.BlockSpec(
        (1, 1, block_k, d), lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)
    )
    out_spec = pl.BlockSpec((1, 1, block_q, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0))

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        block_q=block_q,
        block_k=block_k,
        kv_offset=kv_offset,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
