"""jit'd wrapper for the flash-attention kernel: padding + backend dispatch."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas


def _is_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(
    jax.jit, static_argnames=("causal", "scale", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Causal GQA flash attention; shapes (b,hq,sq,d) / (b,hkv,sk,d).

    Pads sq/sk up to block multiples (padded kv columns are masked by the
    causal test for suffix queries; for non-causal use, padded columns are
    masked explicitly with a -inf additive K-row marker).
    """
    if interpret is None:
        interpret = _is_cpu()
    b, hq, sq, d = q.shape
    sk = k.shape[2]
    if causal and sq > sk:
        raise ValueError("suffix-causal attention requires sq <= sk")
    if not causal and (sk % min(block_k, _round_up(sk)) != 0):
        raise NotImplementedError("non-causal padding requires explicit kv mask")
    block_q = min(block_q, _round_up(sq))
    block_k = min(block_k, _round_up(sk))
    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k

    # Back-pad both streams; the kernel receives the REAL kv offset, so real
    # queries (rows < sq) keep exact causal semantics, padded query rows
    # compute discarded garbage, and padded kv columns (cols >= sk) sit
    # strictly in the causal future of every real query.
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    out = flash_attention_pallas(
        q, k, v,
        causal=causal, scale=scale, block_q=block_q, block_k=block_k,
        interpret=interpret, kv_offset=sk - sq,
    )
    return out[:, :, :sq, :]


def _round_up(n: int, mult: int = 128) -> int:
    return max(mult, ((n + mult - 1) // mult) * mult)
