"""Pure-jnp oracle for causal GQA attention."""

from __future__ import annotations

import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray,  # (b, hq, sq, d)
    k: jnp.ndarray,  # (b, hkv, sk, d)
    v: jnp.ndarray,  # (b, hkv, sk, d)
    causal: bool = True,
    scale: float | None = None,
) -> jnp.ndarray:
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    if scale is None:
        scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    kq = jnp.repeat(k, group, axis=1)
    vq = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kq.astype(jnp.float32)) * scale
    if causal:
        sk = k.shape[2]
        # decode-style: query block is the *suffix* of the kv sequence
        offset = sk - sq
        row = jnp.arange(sq)[:, None] + offset
        col = jnp.arange(sk)[None, :]
        s = jnp.where(col <= row, s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vq.astype(jnp.float32))
    return out.astype(q.dtype)
