"""Shared (rows, 128) plane tiling for the VPU-aligned Pallas kernel suites.

Every elementwise kernel in :mod:`repro.kernels` (fcube, scube, rfft)
flattens arbitrary-rank tensors into ``(rows, LANES)`` float planes with
``rows`` padded to a block multiple, and reassembles afterwards.  The
padding contract lives HERE, once: data pads with zeros (never a violation
under a positive bound), pointwise bounds pad with ``+inf`` (padded lanes
never clip or count), and weight planes pad with zeros (padded lanes never
count).  ``is_cpu`` is the shared interpret-mode default probe.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

#: VPU lane width shared by every (rows, 128) kernel tile.
LANES = 128


def is_cpu() -> bool:
    """Default interpret-mode probe: emulate kernels off-TPU."""
    return jax.default_backend() == "cpu"


def tile(x: jnp.ndarray, block_rows: int):
    """Flatten to (rows, 128) with rows % block_rows == 0; returns (tiled, pad)."""
    flat = x.reshape(-1)
    chunk = block_rows * LANES
    pad = (-flat.size) % chunk
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, LANES), pad


def tile_bound(b: jnp.ndarray, shape, block_rows: int, pad: int):
    """Tile a pointwise bound, padding with +inf so pad lanes never clip/count."""
    t, _ = tile(jnp.broadcast_to(b, shape).astype(jnp.float32), block_rows)
    if pad:
        t = t.reshape(-1).at[-pad:].set(jnp.inf).reshape(-1, LANES)
    return t


def untile(t: jnp.ndarray, shape, pad: int):
    """Inverse of :func:`tile`: strip the pad and restore ``shape``."""
    flat = t.reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)
