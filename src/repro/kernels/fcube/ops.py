"""jit'd wrapper for the fused f-cube projection kernel.

Handles flattening an arbitrary-rank complex frequency-error tensor into the
(rows, 128) float planes the kernel tiles, padding (with in-bound zeros so
padded lanes never count as violations), and reassembly.  On CPU the kernel
runs in interpret mode; on TPU it compiles via Mosaic.

The rFFT fast path passes a conjugate-pair ``weight`` plane (see
``core.cubes.rfft_pair_weights``); padded weight lanes are 0, so the fused
violation reduction stays exact over the half-spectrum.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.fcube.kernel import BLOCK_ROWS, LANES, fcube_pallas
from repro.kernels.tiling import is_cpu as _is_cpu
from repro.kernels.tiling import tile as _tile
from repro.kernels.tiling import tile_bound as _tile_bound
from repro.kernels.tiling import untile as _untile


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret", "check_tol"))
def project_fcube_fused(
    delta: jnp.ndarray,
    Delta,
    weight=None,
    block_rows: int = BLOCK_ROWS,
    interpret: bool | None = None,
    check_tol: float = 0.0,
    check_slack=0.0,
):
    """Drop-in replacement for core.cubes.project_fcube + fcube_violations.

    ``weight``: optional int pair-weight array broadcastable to
    ``delta.shape`` (rfft half-spectrum counting); None counts each
    component once.  ``check_slack``: absolute allowance added to the
    convergence bound (matches the pure-jnp oracle's float32-noise slack
    for near-floor pointwise Delta_k).

    Returns (clipped complex, displacement complex, violation count int32).
    """
    if interpret is None:
        interpret = _is_cpu()
    shape = delta.shape
    re, pad = _tile(delta.real.astype(jnp.float32), block_rows)
    im, _ = _tile(delta.imag.astype(jnp.float32), block_rows)
    Delta_arr = jnp.asarray(Delta, dtype=jnp.float32)
    pointwise = Delta_arr.ndim > 0
    if pointwise:
        # pad pointwise bounds with +inf so padded zero lanes are never violations
        dt = _tile_bound(Delta_arr, shape, block_rows, pad)
    else:
        dt = Delta_arr.reshape(1, 1)
    weighted = weight is not None
    if weighted:
        w = jnp.broadcast_to(jnp.asarray(weight, dtype=jnp.int32), shape)
        # zero-pad: padded lanes carry weight 0 and never count
        wt, _ = _tile(w, block_rows)
    else:
        wt = jnp.ones((1, 1), dtype=jnp.int32)
    slk = jnp.asarray(check_slack, dtype=jnp.float32).reshape(1, 1)
    cr, ci, er, ei, viol = fcube_pallas(
        re, im, dt, wt, slk, pointwise=pointwise, weighted=weighted, interpret=interpret,
        block_rows=block_rows, check_tol=check_tol,
    )
    clipped = (_untile(cr, shape, pad) + 1j * _untile(ci, shape, pad)).astype(delta.dtype)
    edits = (_untile(er, shape, pad) + 1j * _untile(ei, shape, pad)).astype(delta.dtype)
    # dtype pinned so the loop carry stays int32 under jax_enable_x64
    return clipped, edits, jnp.sum(viol, dtype=jnp.int32)
