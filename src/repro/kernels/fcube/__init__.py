from repro.kernels.fcube import ops, ref
from repro.kernels.fcube.ops import project_fcube_fused

__all__ = ["ops", "ref", "project_fcube_fused"]
