"""Fused f-cube projection Pallas TPU kernel.

Fuses the paper's two GPU kernels — CheckConvergence and ProjectOntoFCube
(§IV-D) — into one VMEM pass: the A100 implementation reads the frequency
error vector twice (once to test convergence, once to clip); on TPU we clip,
accumulate the edit displacement, and reduce the violation count in a single
(rows, 128)-tiled sweep, halving HBM traffic for the projection stage.

Complex data is carried as separate Re/Im planes (TPU has no complex VREGs).
``Delta`` comes in two flavours selected statically by ``pointwise``:
scalar (a (1,1) block re-read by every grid step) or a full per-component
array tiled like the data (Observation 4's pointwise bounds).

The rFFT fast path feeds *half-spectrum* Re/Im tiles plus a pair-weight
plane (``weighted=True``): each component's violation indicator is scaled by
its conjugate-pair multiplicity (1 on the self-conjugate planes, 2
elsewhere), so the fused CheckConvergence reduction over the half-spectrum
reports full-spectrum violation counts.  Padded lanes carry weight 0 and
never count.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VPU-aligned tile: (rows, 128) float32.  9 live buffers per grid step
# (re/im in, delta, weight, re/im out, edit re/im, viol) * 256*128*4B ~ 1.1 MiB << VMEM.
BLOCK_ROWS = 256
LANES = 128


def _fcube_kernel(
    dr_ref, di_ref, dlt_ref, w_ref, slk_ref, cr_ref, ci_ref, er_ref, ei_ref, viol_ref,
    *, check_tol: float
):
    re = dr_ref[...]
    im = di_ref[...]
    d = dlt_ref[...]  # (rows,128) pointwise or (1,1) scalar — broadcasts
    w = w_ref[...]  # (rows,128) pair weights or (1,1) scalar 1 — broadcasts
    cre = jnp.clip(re, -d, d)
    cim = jnp.clip(im, -d, d)
    cr_ref[...] = cre
    ci_ref[...] = cim
    er_ref[...] = cre - re
    ei_ref[...] = cim - im
    # fused CheckConvergence with a float32-resolution tolerance (see
    # core.pocs: violations below ~1e-5 relative oscillate at fp32 FFT
    # round-off; the float64 polish owns the last digits) plus the caller's
    # absolute slack for near-floor pointwise Delta_k
    dt = d * (1.0 + check_tol) + slk_ref[...]
    viol = ((jnp.abs(re) > dt) | (jnp.abs(im) > dt)).astype(jnp.int32) * w
    # dtype pinned: under jax_enable_x64 a bare sum promotes to int64 and
    # the store into the int32 out ref fails at trace time
    viol_ref[0] = jnp.sum(viol, dtype=jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("pointwise", "weighted", "interpret", "block_rows", "check_tol")
)
def fcube_pallas(
    delta_re: jnp.ndarray,
    delta_im: jnp.ndarray,
    Delta: jnp.ndarray,
    weight: jnp.ndarray,
    check_slack: jnp.ndarray = None,
    *,
    pointwise: bool,
    weighted: bool = False,
    interpret: bool = False,
    block_rows: int = BLOCK_ROWS,
    check_tol: float = 0.0,
):
    """Tiled inputs: (R, 128) planes, R a multiple of ``block_rows``.

    ``weight`` is an int32 pair-weight plane tiled like the data
    (``weighted=True``) or a (1, 1) scalar 1 (plain per-component counting).
    ``check_slack`` is a (1, 1) absolute convergence allowance added on top
    of the relative ``check_tol`` (defaults to 0).

    Returns (clipped_re, clipped_im, edit_re, edit_im, viol_per_block).
    """
    rows = delta_re.shape[0]
    assert delta_re.shape[1] == LANES and rows % block_rows == 0
    if check_slack is None:
        check_slack = jnp.zeros((1, 1), dtype=delta_re.dtype)
    grid = (rows // block_rows,)
    data_spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    scalar_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    delta_spec = data_spec if pointwise else scalar_spec
    weight_spec = data_spec if weighted else scalar_spec
    out_specs = [data_spec] * 4 + [pl.BlockSpec((1,), lambda i: (i,))]
    out_shapes = [jax.ShapeDtypeStruct((rows, LANES), delta_re.dtype) for _ in range(4)] + [
        jax.ShapeDtypeStruct(grid, jnp.int32)
    ]
    return pl.pallas_call(
        functools.partial(_fcube_kernel, check_tol=check_tol),
        grid=grid,
        in_specs=[data_spec, data_spec, delta_spec, weight_spec, scalar_spec],
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=interpret,
    )(delta_re, delta_im, Delta, weight, check_slack)
