"""Pure-jnp oracle for the fused f-cube projection (paper Alg. 1 lines 6-10)."""

from __future__ import annotations

import jax.numpy as jnp


def project_fcube_fused_ref(delta: jnp.ndarray, Delta, weight=None):
    """Clip complex frequency errors to +-Delta (Re/Im independently), return
    (clipped, displacement, violation_count).

    ``Delta`` is a scalar or an array broadcastable to ``delta.shape``.
    ``weight`` optionally scales each component's violation contribution
    (rfft half-spectrum pair multiplicities).
    """
    ind = (jnp.abs(delta.real) > Delta) | (jnp.abs(delta.imag) > Delta)
    if weight is None:
        viol = jnp.sum(ind)
    else:
        viol = jnp.sum(ind.astype(jnp.int32) * jnp.asarray(weight, dtype=jnp.int32))
    re = jnp.clip(delta.real, -Delta, Delta)
    im = jnp.clip(delta.imag, -Delta, Delta)
    clipped = (re + 1j * im).astype(delta.dtype)
    return clipped, clipped - delta, viol.astype(jnp.int32)
