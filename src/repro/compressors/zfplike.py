"""ZFP/SPERR-style error-bounded compressors: blockwise orthogonal transform.

ZFP [14], [16] decorrelates fixed 4^d blocks with a (near-)orthogonal
transform and codes the coefficients; SPERR [15] applies a deeper multi-level
wavelet.  We implement the shared algorithmic core — blockwise orthonormal
transform + uniform coefficient quantization + entropy coding — with:

  * ``ZFPLikeCompressor``:  4^d blocks, 4-point orthonormal DCT-II
  * ``SperrLikeCompressor``: 8^d blocks, 3-level orthonormal Haar (deeper,
    wavelet-like multi-resolution decorrelation)

The pointwise L-inf bound is enforced through the worst-case inverse-transform
gain: if every coefficient error is <= q/2 then every value error is
<= (q/2) * g^d with g = max_n sum_k |Binv[n, k]| (L-inf operator norm of the
inverse, exact for separable transforms).  We set q = 2E / g^d.

This matches the paper's taxonomy: transform-based bases exploit correlation
over a wider support, so they natively retain more frequency structure than
the prediction-based SZ path (§V-B Obs. 1) — visible in our benches too.
"""

from __future__ import annotations

import struct
from typing import Tuple

import numpy as np

from repro.coding.lossless import lossless_compress, lossless_decompress


def _dct_matrix(n: int) -> np.ndarray:
    """Orthonormal DCT-II matrix (rows = basis functions)."""
    k = np.arange(n)[:, None]
    j = np.arange(n)[None, :]
    mat = np.cos(np.pi * (2 * j + 1) * k / (2 * n))
    mat[0] *= np.sqrt(1.0 / n)
    mat[1:] *= np.sqrt(2.0 / n)
    return mat


def _haar_matrix(n: int, levels: int) -> np.ndarray:
    """Orthonormal multi-level Haar analysis matrix for length ``n`` (pow 2)."""
    mat = np.eye(n)
    size = n
    for _ in range(levels):
        if size < 2:
            break
        h = np.zeros((size, size))
        half = size // 2
        for i in range(half):
            h[i, 2 * i] = h[i, 2 * i + 1] = 1.0 / np.sqrt(2.0)
            h[half + i, 2 * i] = 1.0 / np.sqrt(2.0)
            h[half + i, 2 * i + 1] = -1.0 / np.sqrt(2.0)
        step = np.eye(n)
        step[:size, :size] = h
        mat = step @ mat
        size = half
    return mat


class _BlockTransformCompressor:
    """Common machinery: pad -> blockify -> separable transform -> quantize."""

    name = "blocktransform"
    block: int = 4

    def __init__(self, codec: str = "zlib"):
        self.codec = codec
        self._fwd = self._matrix()
        self._inv = self._fwd.T  # orthonormal
        # worst-case L-inf gain of the separable inverse transform, per axis
        self._gain1 = float(np.max(np.abs(self._inv).sum(axis=1)))

    def _matrix(self) -> np.ndarray:
        raise NotImplementedError

    # -- blocking helpers --------------------------------------------------

    def _pad(self, x: np.ndarray) -> Tuple[np.ndarray, Tuple[int, ...]]:
        b = self.block
        pads = [(0, (-n) % b) for n in x.shape]
        return np.pad(x, pads, mode="edge"), x.shape

    def _blockify(self, x: np.ndarray) -> np.ndarray:
        """(n1,...,nd) -> (nblocks, b, b, ..., b)."""
        b = self.block
        d = x.ndim
        new_shape = []
        for n in x.shape:
            new_shape += [n // b, b]
        y = x.reshape(new_shape)
        # interleave: (n1/b, b, n2/b, b, ...) -> (n1/b, n2/b, ..., b, b, ...)
        perm = list(range(0, 2 * d, 2)) + list(range(1, 2 * d, 2))
        y = y.transpose(perm)
        return y.reshape((-1,) + (b,) * d)

    def _unblockify(self, blocks: np.ndarray, padded_shape: Tuple[int, ...]) -> np.ndarray:
        b = self.block
        d = len(padded_shape)
        grid = tuple(n // b for n in padded_shape)
        y = blocks.reshape(grid + (b,) * d)
        perm = []
        for i in range(d):
            perm += [i, d + i]
        y = y.transpose(perm)
        return y.reshape(padded_shape)

    def _transform(self, blocks: np.ndarray, mat: np.ndarray) -> np.ndarray:
        d = blocks.ndim - 1
        out = blocks
        for axis in range(1, d + 1):
            out = np.moveaxis(np.tensordot(mat, out, axes=([1], [axis])), 0, axis)
        return out

    # -- public API ---------------------------------------------------------

    def compress(self, x: np.ndarray, E: float) -> bytes:
        x = np.asarray(x, dtype=np.float32)
        E = float(E)
        if E <= 0:
            raise ValueError("E must be positive")
        padded, orig_shape = self._pad(x)
        d = x.ndim
        q = 2.0 * E / (self._gain1**d)
        blocks = self._blockify(padded.astype(np.float64))
        coeffs = self._transform(blocks, self._fwd)
        codes = np.rint(coeffs / q).astype(np.int64)
        payload = lossless_compress(codes.ravel(), codec=self.codec)
        header = struct.pack("<dB", E, d) + struct.pack(f"<{d}Q", *orig_shape)
        return header + payload

    def decompress(self, blob: bytes) -> np.ndarray:
        E, d = struct.unpack_from("<dB", blob, 0)
        off = struct.calcsize("<dB")
        orig_shape = struct.unpack_from(f"<{d}Q", blob, off)
        off += 8 * d
        codes = lossless_decompress(blob[off:])
        b = self.block
        padded_shape = tuple(n + ((-n) % b) for n in orig_shape)
        q = 2.0 * E / (self._gain1**d)
        coeffs = codes.reshape((-1,) + (b,) * d).astype(np.float64) * q
        blocks = self._transform(coeffs, self._inv)
        padded = self._unblockify(blocks, padded_shape)
        out = padded[tuple(slice(0, n) for n in orig_shape)]
        return out.astype(np.float32)


class ZFPLikeCompressor(_BlockTransformCompressor):
    """4^d-block DCT transform compressor (ZFP-like, fixed-accuracy mode)."""

    name = "zfplike"
    block = 4

    def _matrix(self) -> np.ndarray:
        return _dct_matrix(4)


class SperrLikeCompressor(_BlockTransformCompressor):
    """8^d-block 3-level Haar wavelet compressor (SPERR-like)."""

    name = "sperrlike"
    block = 8

    def _matrix(self) -> np.ndarray:
        return _haar_matrix(8, levels=3)
