"""Zero-error base compressor (stores float32 verbatim + zlib).

Useful as (a) a degenerate baseline, (b) the base stage when FFCz is used
purely as a spectral editor, and (c) a correctness anchor in tests.
"""

from __future__ import annotations

import struct
import zlib

import numpy as np


class IdentityCompressor:
    name = "identity"

    def compress(self, x: np.ndarray, E: float) -> bytes:
        x = np.asarray(x, dtype=np.float32)
        header = struct.pack("<B", x.ndim) + struct.pack(f"<{x.ndim}Q", *x.shape)
        return header + zlib.compress(x.tobytes(), 1)

    def decompress(self, blob: bytes) -> np.ndarray:
        (ndim,) = struct.unpack_from("<B", blob, 0)
        shape = struct.unpack_from(f"<{ndim}Q", blob, 1)
        data = zlib.decompress(blob[1 + 8 * ndim :])
        return np.frombuffer(data, dtype=np.float32).reshape(shape).copy()
