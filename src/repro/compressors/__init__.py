"""Error-bounded base compressors, reimplemented in JAX/numpy (paper §V-A).

All compressors satisfy the pointwise contract ``|decompress(compress(x, E)) -
x| <= E`` and are pluggable into :class:`repro.core.ffcz.FFCz`.
"""

from repro.compressors.identity import IdentityCompressor
from repro.compressors.szlike import SZLikeCompressor
from repro.compressors.zfplike import SperrLikeCompressor, ZFPLikeCompressor

_REGISTRY = {
    "szlike": SZLikeCompressor,
    "zfplike": ZFPLikeCompressor,
    "sperrlike": SperrLikeCompressor,
    "identity": IdentityCompressor,
}


def get_compressor(name: str, **kwargs):
    """Instantiate a registered base compressor by name."""
    try:
        return _REGISTRY[name](**kwargs)
    except KeyError:
        raise ValueError(f"unknown base compressor {name!r}; have {sorted(_REGISTRY)}") from None


__all__ = [
    "SZLikeCompressor",
    "ZFPLikeCompressor",
    "SperrLikeCompressor",
    "IdentityCompressor",
    "get_compressor",
]
