"""SZ3-style error-bounded compressor: multi-level interpolation prediction.

Implements the algorithmic core of SZ3's interpolation mode [13], [2]:
coarse-to-fine grid refinement where each new point is predicted by linear
interpolation of already-*reconstructed* neighbors along one axis, and the
residual is quantized with a uniform quantizer of step ``2E`` (error <= E,
codes entropy-coded).  Prediction from reconstructed values keeps the bound
non-compounding, exactly as in SZ.

The paper's characterization (§V-B, Obs. 1) — prediction-based, local
neighbors, weak at preserving global frequency content — applies verbatim to
this implementation, which is what makes it the interesting base for FFCz.

Vectorized per (level, axis) pass; encode and decode share the same
deterministic pass schedule, so the code stream needs no per-point metadata.
"""

from __future__ import annotations

import struct
from typing import Iterator, List, Tuple

import numpy as np

from repro.coding.lossless import lossless_compress, lossless_decompress


def _pass_schedule(shape: Tuple[int, ...]) -> Iterator[Tuple[int, int]]:
    """Yield (stride, axis) passes from coarsest to finest level."""
    n_max = max(shape)
    s = 1
    while s * 2 < n_max:
        s *= 2
    while s >= 1:
        for axis in range(len(shape)):
            yield s, axis
        s //= 2


def _coarse_stride(shape: Tuple[int, ...]) -> int:
    n_max = max(shape)
    s = 1
    while s * 2 < n_max:
        s *= 2
    return 2 * s  # the grid known *before* the first (s, axis=0) pass


def _pass_indices(shape, stride: int, axis: int):
    """Index grids (np.ix_) for one interpolation pass.

    Targets: coordinates ``stride (mod 2*stride)`` along ``axis``; axes before
    ``axis`` already refined to ``stride``; axes after still at ``2*stride``.
    Returns (target ix_ tuple, left ix_ tuple, right ix_ tuple) or None if
    the pass is empty.
    """
    n_a = shape[axis]
    tgt = np.arange(stride, n_a, 2 * stride)
    if tgt.size == 0:
        return None
    left = tgt - stride
    right = np.where(tgt + stride < n_a, tgt + stride, tgt - stride)
    others: List[np.ndarray] = []
    for a, n in enumerate(shape):
        if a < axis:
            others.append(np.arange(0, n, stride))
        elif a > axis:
            others.append(np.arange(0, n, 2 * stride))
    def with_axis(ax_idx):
        full = list(others[:axis]) + [ax_idx] + list(others[axis:])
        return np.ix_(*full)
    return with_axis(tgt), with_axis(left), with_axis(right)


class SZLikeCompressor:
    """Interpolation-predictor error-bounded compressor (SZ3-like)."""

    name = "szlike"

    def __init__(self, codec: str = "zlib"):
        self.codec = codec

    def compress(self, x: np.ndarray, E: float) -> bytes:
        x = np.asarray(x, dtype=np.float32)
        E = float(E)
        if E <= 0:
            raise ValueError("E must be positive")
        shape = x.shape
        step = 2.0 * E
        r = np.zeros(shape, dtype=np.float64)
        s0 = _coarse_stride(shape)
        coarse_ix = np.ix_(*[np.arange(0, n, s0) for n in shape])
        coarse_vals = x[coarse_ix].astype(np.float32)
        r[coarse_ix] = coarse_vals  # coarsest anchors stored losslessly

        codes_all: List[np.ndarray] = []
        for stride, axis in _pass_schedule(shape):
            idx = _pass_indices(shape, stride, axis)
            if idx is None:
                continue
            tgt, left, right = idx
            pred = 0.5 * (r[left] + r[right])
            codes = np.rint((x[tgt].astype(np.float64) - pred) / step)
            r[tgt] = pred + codes * step
            codes_all.append(codes.astype(np.int64).ravel())

        codes_flat = np.concatenate(codes_all) if codes_all else np.zeros(0, dtype=np.int64)
        payload = lossless_compress(codes_flat, codec=self.codec)
        header = struct.pack("<dB", E, x.ndim) + struct.pack(f"<{x.ndim}Q", *shape)
        header += struct.pack("<I", coarse_vals.size) + coarse_vals.tobytes()
        return header + payload

    def decompress(self, blob: bytes) -> np.ndarray:
        E, ndim = struct.unpack_from("<dB", blob, 0)
        off = struct.calcsize("<dB")
        shape = struct.unpack_from(f"<{ndim}Q", blob, off)
        off += 8 * ndim
        (n_coarse,) = struct.unpack_from("<I", blob, off)
        off += 4
        coarse_vals = np.frombuffer(blob, dtype=np.float32, count=n_coarse, offset=off)
        off += 4 * n_coarse
        codes_flat = lossless_decompress(blob[off:])

        step = 2.0 * E
        r = np.zeros(shape, dtype=np.float64)
        s0 = _coarse_stride(shape)
        coarse_ix = np.ix_(*[np.arange(0, n, s0) for n in shape])
        r[coarse_ix] = coarse_vals.reshape(r[coarse_ix].shape)

        pos = 0
        for stride, axis in _pass_schedule(shape):
            idx = _pass_indices(shape, stride, axis)
            if idx is None:
                continue
            tgt, left, right = idx
            pred = 0.5 * (r[left] + r[right])
            n = pred.size
            codes = codes_flat[pos : pos + n].reshape(pred.shape)
            pos += n
            r[tgt] = pred + codes * step
        return r.astype(np.float32)
