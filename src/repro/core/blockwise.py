"""Block-parallel FFCz for mesh-scale fields (DESIGN.md §2).

The paper corrects one field per GPU.  At pod scale, fields (or framework
tensors: weights, gradients, KV blocks) are tiled into pencils/blocks and each
block is corrected independently — the frequency bound then applies to each
block's local spectrum.  Correction is a single jitted, vmapped (and, under
``shard_map``, fully distributed) alternating projection; there is no
host round-trip per block.

``blockwise_correct`` is the workhorse used by gradient compression
(optim/grad_compress.py), checkpoint compression (checkpoint/codec.py) and
KV-cache compression (serving/kv_compress.py).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.pocs import alternating_projection


def tile_1d(x: jnp.ndarray, block: int) -> Tuple[jnp.ndarray, int]:
    """Flatten to 1D and tile into (n_blocks, block); zero-pad the tail."""
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, block), pad


def untile_1d(blocks: jnp.ndarray, shape, pad: int) -> jnp.ndarray:
    flat = blocks.reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


@functools.partial(jax.jit, static_argnames=("block", "max_iters"))
def blockwise_correct(
    eps: jnp.ndarray,
    E,
    Delta,
    block: int = 4096,
    max_iters: int = 50,
) -> jnp.ndarray:
    """Dual-domain-bound a spatial error tensor, blockwise.

    Returns the corrected error tensor (same shape as ``eps``) whose every
    ``block``-length pencil satisfies |eps_n| <= E and |Re/Im(FFT(eps))_k| <=
    Delta.  E/Delta are scalars or broadcastable against the (n_blocks, block)
    tiling.
    """
    tiles, pad = tile_1d(eps, block)

    def correct_one(t):
        res = alternating_projection(t, E, Delta, max_iters=max_iters)
        return res.eps

    corrected = jax.vmap(correct_one)(tiles)
    return untile_1d(corrected, eps.shape, pad)


@functools.partial(jax.jit, static_argnames=("block", "max_iters"))
def blockwise_correct_with_edits(
    eps: jnp.ndarray,
    E,
    Delta,
    block: int = 4096,
    max_iters: int = 50,
):
    """Like :func:`blockwise_correct` but also returns (spat_edits, freq_edits,
    iterations-per-block, converged-per-block) for serialization paths."""
    tiles, pad = tile_1d(eps, block)
    res = jax.vmap(lambda t: alternating_projection(t, E, Delta, max_iters=max_iters))(tiles)
    corrected = untile_1d(res.eps, eps.shape, pad)
    return corrected, res.spat_edits, res.freq_edits, res.iterations, res.converged
