"""Blockwise EXECUTE stage of the CorrectionEngine (DESIGN.md §2).

The paper corrects one field per GPU.  At pod scale, fields (or framework
tensors: weights, gradients, KV blocks) are tiled into pencils/blocks and each
block is corrected independently — the frequency bound then applies to each
block's local spectrum.  This module is the pencil-tiling *execute* stage of
:class:`repro.core.engine.CorrectionEngine`: the plan stage
(:meth:`CorrectionEngine.plan_pencils`) resolves bounds and tiling, this
module runs the device program, and :mod:`repro.core.edits` serializes the
result.  Three execution backends share the same packed ``(B, block)``
layout:

``local``    — one :func:`blockwise_correct` dispatch per tensor (the
               pre-batching behaviour; kept for comparison and tiny batches).
``batched``  — MANY heterogeneous tensors in ONE device program
               (:func:`correct_batch`): each tensor is flattened, padded and
               tiled into shared ``(B, block)`` buffers (inputs donated when
               corrected outputs are produced, so each output aliases its
               input), per-tensor bounds become per-block bound vectors, and
               a single vmapped POCS while_loop corrects everything.
               Per-instance convergence is masked inside the loop (a
               converged block's state is frozen while stragglers iterate),
               and per-tensor iteration counts / convergence flags are
               reported.
``sharded``  — the batched program's vmapped POCS runs inside a
               ``shard_map`` region over a device mesh axis: the packed
               block buffer is sharded along its leading (blocks) axis, each
               device corrects only its resident pencils, and nothing is
               gathered to one host.  Blocks are independent, so no
               collectives run inside the region; results are bitwise
               identical to the batched backend.

Framework integrations (optim/grad_compress, serving/kv_compress,
checkpoint/codec) reach these backends through the engine, so multi-tensor
workloads stop paying per-tensor dispatch and pick up distribution for free.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.pocs import alternating_projection
from repro.sharding.shardmap import shard_map


def tile_1d(x: jnp.ndarray, block: int) -> Tuple[jnp.ndarray, int]:
    """Flatten to 1D and tile into (n_blocks, block); zero-pad the tail."""
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, block), pad


def untile_1d(blocks: jnp.ndarray, shape, pad: int) -> jnp.ndarray:
    flat = blocks.reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


@functools.partial(jax.jit, static_argnames=("block", "max_iters", "fft_impl"))
def blockwise_correct(
    eps: jnp.ndarray,
    E,
    Delta,
    block: int = 4096,
    max_iters: int = 50,
    fft_impl: str = "xla",
) -> jnp.ndarray:
    """Dual-domain-bound a spatial error tensor, blockwise.

    Returns the corrected error tensor (same shape as ``eps``) whose every
    ``block``-length pencil satisfies |eps_n| <= E and |Re/Im(FFT(eps))_k| <=
    Delta.  E/Delta are scalars or broadcastable against the (n_blocks, block)
    tiling.  ``fft_impl`` selects the loop transforms (see
    :mod:`repro.core.pocs`; the packed/pallas paths are vmap-safe).
    """
    tiles, pad = tile_1d(eps, block)

    def correct_one(t):
        res = alternating_projection(t, E, Delta, max_iters=max_iters, fft_impl=fft_impl)
        return res.eps

    corrected = jax.vmap(correct_one)(tiles)
    return untile_1d(corrected, eps.shape, pad)


@functools.partial(jax.jit, static_argnames=("block", "max_iters", "fft_impl"))
def blockwise_correct_with_edits(
    eps: jnp.ndarray,
    E,
    Delta,
    block: int = 4096,
    max_iters: int = 50,
    fft_impl: str = "xla",
    warm: Optional[jnp.ndarray] = None,
):
    """Like :func:`blockwise_correct` but also returns (spat_edits, freq_edits,
    iterations-per-block, converged-per-block) for serialization paths.
    ``freq_edits`` are per-block rfft half-spectra, shape (n_blocks, block//2+1).
    ``warm`` optionally seeds each block's loop with a prior edit spectrum of
    that same layout (see ``pocs.alternating_projection`` ``warm_freq``)."""
    tiles, pad = tile_1d(eps, block)
    if warm is None:
        res = jax.vmap(
            lambda t: alternating_projection(t, E, Delta, max_iters=max_iters, fft_impl=fft_impl)
        )(tiles)
    else:
        res = jax.vmap(
            lambda t, w: alternating_projection(
                t, E, Delta, max_iters=max_iters, fft_impl=fft_impl, warm_freq=w
            )
        )(tiles, warm)
    corrected = untile_1d(res.eps, eps.shape, pad)
    return corrected, res.spat_edits, res.freq_edits, res.iterations, res.converged


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BatchCorrectionStats:
    """Per-instance accounting for one :func:`correct_batch` call."""

    iterations: Any  # (n_tensors,) int32: max POCS iterations over the tensor's blocks
    converged: Any  # (n_tensors,) bool: every block of the tensor converged
    block_iterations: Any  # (total_blocks,) int32
    block_converged: Any  # (total_blocks,) bool


def _pocs_batched(packed, E_blk, D_blk, max_iters, fft_impl="xla", warm=None):
    """Vmapped POCS over a packed (B, block) buffer (the batched backend).

    ``warm``, when given, is a packed ``(B, block//2+1)`` complex buffer of
    per-block warm-start spectra aligned with ``packed``'s rows."""
    if warm is None:
        return jax.vmap(
            lambda t, e, d: alternating_projection(
                t, e, d, max_iters=max_iters, fft_impl=fft_impl
            )
        )(packed, E_blk, D_blk)
    return jax.vmap(
        lambda t, e, d, w: alternating_projection(
            t, e, d, max_iters=max_iters, fft_impl=fft_impl, warm_freq=w
        )
    )(packed, E_blk, D_blk, warm)


def _pocs_sharded(packed, E_blk, D_blk, max_iters, mesh, axis, fft_impl="xla", warm=None):
    """The batched POCS program under ``shard_map`` over ``mesh[axis]``.

    The leading (blocks) axis is sharded; each device runs the vmapped
    while_loop over its resident pencils only.  Blocks are independent, so
    the region needs no collectives and the math is bitwise identical to
    :func:`_pocs_batched`.  The block count is padded to a multiple of the
    axis size with already-feasible zero blocks (E = Delta = 1), which
    converge at the first check and are sliced off before stats.
    """
    n_dev = mesh.shape[axis]
    nb = packed.shape[0]
    pad = (-nb) % n_dev
    if pad:
        packed = jnp.concatenate([packed, jnp.zeros((pad, packed.shape[1]), packed.dtype)])
        E_blk = jnp.concatenate([E_blk, jnp.ones((pad,), E_blk.dtype)])
        D_blk = jnp.concatenate([D_blk, jnp.ones((pad,), D_blk.dtype)])
        if warm is not None:
            # zero warm rows keep the pad blocks exactly feasible (clip of
            # zero is zero), so they still converge at the first check
            warm = jnp.concatenate([warm, jnp.zeros((pad, warm.shape[1]), warm.dtype)])
    if warm is None:
        res = shard_map(
            lambda t, e, d: _pocs_batched(t, e, d, max_iters, fft_impl),
            mesh=mesh,
            in_specs=(P(axis), P(axis), P(axis)),
            out_specs=P(axis),
        )(packed, E_blk, D_blk)
    else:
        res = shard_map(
            lambda t, e, d, w: _pocs_batched(t, e, d, max_iters, fft_impl, w),
            mesh=mesh,
            in_specs=(P(axis), P(axis), P(axis), P(axis)),
            out_specs=P(axis),
        )(packed, E_blk, D_blk, warm)
    if pad:
        res = jax.tree.map(lambda a: a[:nb], res)
    return res


def _correct_batch_core(
    tensors, E_arr, Delta_arr, block, max_iters, return_edits, return_corrected,
    backend="batched", mesh=None, axis="data", fft_impl="xla", warm=None,
):
    """The whole batched correction — pack, vmapped POCS (optionally sharded
    over a mesh axis), unpack, per-instance stats — as ONE device program
    (no per-tensor dispatch)."""
    n = len(tensors)
    tiles_list, pads, counts = [], [], []
    for t in tensors:
        tiles, pad = tile_1d(t.astype(jnp.float32), block)
        tiles_list.append(tiles)
        pads.append(pad)
        counts.append(tiles.shape[0])
    packed = jnp.concatenate(tiles_list, axis=0)
    seg = jnp.asarray(np.repeat(np.arange(n), counts), dtype=jnp.int32)
    E_blk = E_arr.astype(jnp.float32)[seg]
    D_blk = Delta_arr.astype(jnp.float32)[seg]

    warm_packed = None
    if warm is not None:
        # per-tensor warm tiles concatenated to align with packed's rows; a
        # row-count mismatch fails loudly at the vmap axis check
        warm_packed = jnp.concatenate(
            [jnp.asarray(w).astype(jnp.complex64) for w in warm], axis=0
        )
    if backend == "sharded":
        res = _pocs_sharded(packed, E_blk, D_blk, max_iters, mesh, axis, fft_impl, warm_packed)
    else:
        res = _pocs_batched(packed, E_blk, D_blk, max_iters, fft_impl, warm_packed)

    corrected, edits = [], []
    offset = 0
    for t, pad, nb in zip(tensors, pads, counts):
        sl = slice(offset, offset + nb)
        if return_corrected:
            corrected.append(untile_1d(res.eps[sl], t.shape, pad).astype(t.dtype))
        if return_edits:
            edits.append((res.spat_edits[sl], res.freq_edits[sl]))
        offset += nb
    stats = BatchCorrectionStats(
        iterations=jax.ops.segment_max(res.iterations, seg, num_segments=n),
        converged=jax.ops.segment_min(res.converged.astype(jnp.int32), seg, num_segments=n) == 1,
        block_iterations=res.iterations,
        block_converged=res.converged,
    )
    return tuple(corrected), tuple(edits), stats


def batch_layout(sizes: Sequence[int], block: int) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
    """Per-tensor (block counts, tail pads) for a packed ``(B, block)`` batch."""
    counts = tuple(-(-s // block) for s in sizes)
    pads = tuple((-s) % block for s in sizes)
    return counts, pads


def pack_batch(tensors: Sequence[Any], block: int, out: Optional[np.ndarray] = None):
    """Stage a heterogeneous batch into ONE host ``(B, block)`` float32 buffer.

    The host-side twin of the packing that :func:`_correct_batch_core` traces
    on device: each tensor is flattened, cast to float32 (the same IEEE
    rounding ``tile_1d``'s device cast applies) and zero-padded into
    ``block``-length rows, all tensors concatenated along the rows axis.

    ``out`` is an optional reusable staging buffer: when its shape matches
    the batch's ``(B, block)`` layout it is filled in place and returned, so
    a serving loop retiring same-shaped buckets step after step stops
    reallocating (and re-faulting) the packed buffer every step — the
    service keys its staging ring by exactly this shape.

    Returns ``(packed, counts, pads)`` with ``counts[i]`` rows belonging to
    ``tensors[i]`` and ``pads[i]`` trailing zeros in its last row.
    """
    sizes = [int(np.asarray(t).size) for t in tensors]
    counts, pads = batch_layout(sizes, block)
    B = sum(counts)
    if out is None or out.shape != (B, block) or out.dtype != np.float32:
        out = np.empty((B, block), dtype=np.float32)
    row = 0
    for t, nb, pad in zip(tensors, counts, pads):
        flat = np.asarray(t, dtype=np.float32).reshape(-1)
        dest = out[row : row + nb].reshape(-1)
        dest[: flat.size] = flat
        if pad:
            dest[flat.size :] = 0.0
        row += nb
    return out, counts, pads


@functools.partial(
    jax.jit,
    static_argnames=("n", "max_iters", "backend", "mesh", "axis", "fft_impl"),
    donate_argnums=(0,),
)
def _packed_pocs_with_stats(
    packed, E_arr, D_arr, seg, warm=None, *, n, max_iters, backend="batched", mesh=None,
    axis="data", fft_impl="xla",
):
    """The vmapped POCS + per-instance stat reductions on a pre-packed buffer.

    The device half of the packed EXECUTE path: packing happens on host
    (:func:`pack_batch`, reusable staging), this jit runs the exact same
    ``_pocs_batched`` / ``_pocs_sharded`` program as ``correct_batch`` and
    the exact same segment reductions, so results are interchangeable with
    the pack-on-device path.  The packed buffer is DONATED — the device
    allocation is recycled into the same-shaped edit outputs instead of
    accumulating a fresh ``(B, block)`` buffer per serving step.
    """
    E_blk = E_arr.astype(jnp.float32)[seg]
    D_blk = D_arr.astype(jnp.float32)[seg]
    if backend == "sharded":
        res = _pocs_sharded(packed, E_blk, D_blk, max_iters, mesh, axis, fft_impl, warm)
    else:
        res = _pocs_batched(packed, E_blk, D_blk, max_iters, fft_impl, warm)
    stats = BatchCorrectionStats(
        iterations=jax.ops.segment_max(res.iterations, seg, num_segments=n),
        converged=jax.ops.segment_min(res.converged.astype(jnp.int32), seg, num_segments=n) == 1,
        block_iterations=res.iterations,
        block_converged=res.converged,
    )
    return res, stats


def correct_packed(
    packed: np.ndarray,
    counts: Sequence[int],
    E,
    Delta,
    max_iters: int = 50,
    backend: str = "batched",
    mesh: Optional[Any] = None,
    axis: str = "data",
    fft_impl: str = "xla",
    warm: Optional[Any] = None,
):
    """Dispatch the packed POCS program; returns ``(res, stats)`` un-fenced.

    ``packed`` is a :func:`pack_batch` staging buffer (or any ``(B, block)``
    float32 array with ``counts[i]`` rows per instance); ``E``/``Delta`` as
    in :func:`correct_batch`.  The returned arrays are in-flight device
    values — callers overlap host work with the device EXECUTE and fence
    with ``jax.block_until_ready`` when they actually need the bytes.
    """
    n = len(counts)
    seg = jnp.asarray(np.repeat(np.arange(n), counts), dtype=jnp.int32)
    return _packed_pocs_with_stats(
        jnp.asarray(packed),
        _as_bound_array(E, n),
        _as_bound_array(Delta, n),
        seg,
        None if warm is None else jnp.asarray(warm).astype(jnp.complex64),
        n=n,
        max_iters=max_iters,
        backend=backend,
        mesh=mesh,
        axis=axis,
        fft_impl=fft_impl,
    )


_BATCH_STATICS = (
    "block", "max_iters", "return_edits", "return_corrected", "backend", "mesh", "axis",
    "fft_impl",
)
# donating makes each corrected output alias its input buffer; without
# corrected outputs there is nothing to alias, so donation would only warn
_correct_batch_donated = functools.partial(
    jax.jit, static_argnames=_BATCH_STATICS, donate_argnums=(0,)
)(_correct_batch_core)
_correct_batch_plain = functools.partial(jax.jit, static_argnames=_BATCH_STATICS)(
    _correct_batch_core
)


def _as_bound_array(v, n: int) -> jnp.ndarray:
    if isinstance(v, (list, tuple)):
        if len(v) != n:
            # must raise (not assert): a short list would otherwise apply the
            # wrong bounds silently via JAX's out-of-range index clamping
            raise ValueError(f"expected {n} per-tensor bounds, got {len(v)}")
        return jnp.stack([jnp.asarray(x, dtype=jnp.float32) for x in v])
    return jnp.broadcast_to(jnp.asarray(v, dtype=jnp.float32), (n,))


def correct_batch(
    tensors: Sequence[jnp.ndarray],
    E,
    Delta,
    block: int = 4096,
    max_iters: int = 50,
    return_edits: bool = False,
    return_corrected: bool = True,
    backend: str = "batched",
    mesh: Optional[Any] = None,
    axis: str = "data",
    fft_impl: str = "xla",
    warm_freq: Optional[Sequence[Any]] = None,
):
    """Correct a heterogeneous batch of error tensors in one device program.

    Args:
      tensors: arbitrary-shape real tensors (each flattened + zero-padded
        into ``block``-length pencils; padded tails are discarded on unpack).
        When ``return_corrected`` (the default), top-level callers' buffers
        are DONATED — each corrected output aliases its input, so don't
        reuse the passed arrays afterwards.  Edits-only calls
        (``return_corrected=False``) leave inputs intact.
      E, Delta: scalar bounds, or per-tensor sequences of scalars.
      block: pencil length shared by the whole batch.
      max_iters: POCS iteration cap (shared).
      return_edits: also return, per tensor, the padded-tile edit streams
        ``(spat_edits (n_blocks, block), freq_edits (n_blocks, block//2+1))``
        for serialization paths (half-spectrum rfft layout).
      return_corrected: set False (with ``return_edits``) to skip
        materializing the per-tensor corrected outputs when only the edit
        streams are consumed — ``corrected`` is then an empty list.
      backend: ``"batched"`` (default) runs the vmapped POCS on one device;
        ``"sharded"`` runs it under ``shard_map`` with the packed block
        buffer sharded over ``mesh[axis]`` — a multi-device batch is
        corrected without gathering the pencils to one device, with bitwise
        identical results.
      mesh, axis: device mesh and axis name for the sharded backend
        (required when ``backend == "sharded"``).
      fft_impl: POCS transform selector shared by every block (``"xla"`` |
        ``"packed"`` | ``"pallas"``, see :mod:`repro.core.pocs`); identical
        across backends, so backend parity is impl-independent.
      warm_freq: optional per-tensor warm-start spectra — ``warm_freq[i]`` is
        a ``(n_blocks_i, block//2+1)`` complex array seeding each of
        ``tensors[i]``'s blocks with a prior converged edit spectrum
        (temporal streams pass the previous frame's ``freq_edits`` tiles;
        see :mod:`repro.core.temporal`).  ``None`` is the bitwise-identical
        cold start.

    Returns ``(corrected, stats)`` — or ``(corrected, edits, stats)`` with
    ``return_edits`` — where ``corrected[i]`` has ``tensors[i]``'s shape and
    dtype and ``stats`` is a :class:`BatchCorrectionStats`.

    The packing, the vmapped POCS while_loop (per-instance convergence
    masked), the unpack and the per-instance stat reductions compile into a
    single jitted program; callable from inside a larger jitted program too.
    """
    n = len(tensors)
    if backend == "sharded" and mesh is None:
        raise ValueError("backend='sharded' requires a mesh")
    if n == 0:
        stats = BatchCorrectionStats(
            iterations=jnp.zeros((0,), jnp.int32),
            converged=jnp.zeros((0,), bool),
            block_iterations=jnp.zeros((0,), jnp.int32),
            block_converged=jnp.zeros((0,), bool),
        )
        return ([], [], stats) if return_edits else ([], stats)
    tensors = tuple(jnp.asarray(t) for t in tensors)
    if warm_freq is not None:
        if len(warm_freq) != n:
            raise ValueError(f"expected {n} per-tensor warm spectra, got {len(warm_freq)}")
        warm_freq = tuple(jnp.asarray(w) for w in warm_freq)
    impl = _correct_batch_donated if return_corrected else _correct_batch_plain
    corrected, edits, stats = impl(
        tensors,
        _as_bound_array(E, n),
        _as_bound_array(Delta, n),
        block=block,
        max_iters=max_iters,
        return_edits=return_edits,
        return_corrected=return_corrected,
        backend=backend,
        mesh=mesh,
        axis=axis,
        fft_impl=fft_impl,
        warm=warm_freq,
    )
    if return_edits:
        return list(corrected), list(edits), stats
    return list(corrected), stats
