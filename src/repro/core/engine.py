"""Device-resident CorrectionEngine: the one FFCz pipeline every workload shares.

The paper's Alg. 1 is a single correction loop, but each integration
(whole-field codec, checkpoint batch codec, KV-cache compression, gradient
compression) needs the same scaffolding around it: bound resolution,
float32/quantization bound discipline, the jitted POCS program, pair-weighted
bit-width selection, and edit-stream serialization.  This module factors that
scaffolding into three explicit stages behind one engine object:

  PLAN     resolve user bounds to absolute dual bounds, apply the shared
           :func:`float32_bound_discipline`, pick whole-field vs pencil
           tiling, and fix quantization widths' base ``m``.  Spectra are
           computed on device and ONLY when a bound actually consumes them
           (``Delta_abs`` needs no forward FFT at all).
  EXECUTE  one jitted device program: FFT + POCS via
           :func:`repro.core.pocs.alternating_projection` (whole field) or
           the packed vmapped program of
           :func:`repro.core.blockwise.correct_batch` (pencils), plus the
           exact float64 polish.  Three pluggable backends:
             ``local``    single-device, one dispatch per tensor;
             ``batched``  donated, vmapped, one program per batch (default);
             ``sharded``  the batched program under ``jax.shard_map`` over a
                          mesh axis — a multi-device batch is corrected where
                          it lives, never gathered to one host.
  ENCODE   pair-weight accounting, :func:`adaptive_quant_bits`, and
           edit-stream serialization through :mod:`repro.core.edits`.

Clients hold no private copies of this math: :class:`repro.core.ffcz.FFCz`
is a thin plan/execute/encode client (plus base-compressor I/O and byte
assembly), and ``checkpoint/codec``, ``serving/kv_compress``,
``optim/grad_compress``, and the temporal stream codec
(:class:`repro.core.temporal.TemporalCodec`, which threads per-frame
``warm_freq`` spectra into EXECUTE) route their corrections through
:meth:`CorrectionEngine.correct` / :meth:`CorrectionEngine.execute_field`.
A new scenario is a new engine client, not a fifth pipeline.

The prose version of this page — stage diagram, backend matrix, parity
tri-state — is docs/architecture.md; keep the two in sync.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.coding.quantize import DEFAULT_QUANT_BITS
from repro.core import blockwise
from repro.core.bounds import power_spectrum_delta_rfft, resolve_bounds, resolve_roi_bound_grid
from repro.core.errors import FFCzError, InfeasibleBound, classify_exception
from repro.core.cubes import rfft_pair_weights
from repro.core.edits import EncodedEdits, encode_edits
from repro.core.pocs import (
    AlternatingProjectionResult,
    _alternating_projection,
    alternating_projection,
)
from repro.sharding import dist_fft
from repro.sharding.dist_fft import ShardedField
from repro.sharding.shardmap import shard_map

_BACKENDS = ("local", "batched", "sharded")


# ---------------------------------------------------------------------------
# shared guarantee math (one home; FFCz re-exports for backward compat)


def polish_pocs_float64(eps, spat, freq, E, Delta, axes=None, max_iters: int = 30):
    """Exact (float64) POCS iterations to absorb float32 FFT round-off.

    Runs on the rfft half-spectrum over ``axes`` (default: all axes —
    whole-field polish; the pencil path passes the pencil axis), with
    ``freq`` the matching half-spectrum accumulator.  Residual violations
    after the float32 loop are O(eps32 * ||delta||_inf), orders of magnitude
    below the bounds, so this converges in a handful of iterations and
    contributes negligibly to the edit payload.
    """
    axes = tuple(range(eps.ndim)) if axes is None else tuple(axes)
    s = [eps.shape[a] for a in axes]
    for _ in range(max_iters):
        delta = np.fft.rfftn(eps, axes=axes)
        re = np.clip(delta.real, -Delta, Delta)
        im = np.clip(delta.imag, -Delta, Delta)
        clipped = re + 1j * im
        if np.array_equal(clipped, delta):
            break
        freq = freq + (clipped - delta)
        eps_f = np.fft.irfftn(clipped, s=s, axes=axes)
        eps_s = np.clip(eps_f, -E, E)
        spat = spat + (eps_s - eps_f)
        eps = eps_s
    return eps, spat, freq


def _host_l2_norm(x32: np.ndarray) -> float:
    """Sharding-invariant l2 norm feeding the cast-noise slack.

    Computed as a float64 numpy pairwise sum on the host staging copy, so
    the single-device and sharded plans resolve bitwise-identical bounds (an
    on-device XLA reduction would re-order — and so re-round — with the
    sharding; every other plan reduction is a max/min, which is exact in any
    order).
    """
    if not x32.size:
        return 0.0
    x64 = np.asarray(x32, dtype=np.float64)
    return float(np.sqrt(np.sum(x64 * x64)))


def float32_bound_discipline(E, Delta, m: int, l2_norm: float, abs_max: float):
    """Shrink user bounds for quantization + float32-storage round-off.

    Reserves 2x the direct quantization term (one for the stream's own
    noise, one for the other stream's cross-domain leakage — matched by
    :func:`adaptive_quant_bits`), subtracts the absolute float32 slack
    (casting the reconstruction perturbs each frequency component by
    ~u32*l2_norm, 4-sigma statistical budget, and each point by
    u32*abs_max), and clamps Delta at 4x the frequency slack so the bound
    stays representable.  ``Delta`` may be a scalar or a pointwise grid.
    Shared by every engine plan (whole-field and pencil), so the guarantee
    math lives in one place.

    Returns ``(E_proj, Delta_proj, Delta_floored, slack_f)``.
    """
    u32 = float(np.finfo(np.float32).eps)
    shrink = 1.0 - 2.0 ** (-m) - 2.0 ** (-m)
    slack_f = 4.0 * u32 * float(l2_norm)
    slack_s = u32 * float(abs_max)
    Delta = np.maximum(Delta, 4.0 * slack_f)
    return E * shrink - slack_s, Delta * shrink - slack_f, Delta, slack_f


def adaptive_quant_bits(m: int, k_s: int, E: float, min_delta: float, sum_w_delta: float, n: int, cap: int = 48):
    """Closed-form edit-stream bit-widths covering cross-domain quant leakage.

    The base width ``m`` covers each stream's *direct* quantization term;
    the widened widths also fit the cross terms inside the same reserved
    margin: ``k_s`` quantized spatial edits perturb every frequency
    component by up to ``k_s * E * 2^-m_s`` after the FFT (kept under
    ``min_delta * 2^-m``), and the active frequency edits — ``sum_w_delta``
    being their conjugate-pair-weighted Delta sum — perturb every spatial
    point by up to ``(sqrt2/n) * sum_w_delta * 2^-m_f`` after the IFFT
    (kept under ``E * 2^-m``).  Shared by the engine's whole-field and
    pencil encode stages, so the guarantee math lives in one place.
    """
    m_s = m
    if k_s > 0 and min_delta > 0 and E > 0:
        m_s = m + max(0, int(np.ceil(np.log2(max(k_s * E / min_delta, 1.0)))))
    m_f = m
    if sum_w_delta > 0 and E > 0 and n > 0:
        ratio = np.sqrt(2.0) * sum_w_delta / (n * E)
        m_f = m + max(0, int(np.ceil(np.log2(max(ratio, 1.0)))))
    return min(m_s, cap), min(m_f, cap)


# ---------------------------------------------------------------------------
# plan objects


@dataclasses.dataclass(frozen=True)
class FieldPlan:
    """PLAN-stage output for one whole-field correction.

    ``Delta`` is the representability-floored bound the edits are encoded
    against (scalar, or a float32 half-spectrum ``Delta_k`` grid in
    ``pspec`` mode); ``E_proj``/``Delta_proj`` are the shrunk bounds the
    projection actually runs with (see :func:`float32_bound_discipline`).
    """

    shape: Tuple[int, ...]
    E: float
    Delta: Union[float, np.ndarray]
    E_proj: float
    Delta_proj: Union[float, np.ndarray]
    slack_f: float
    pointwise: bool
    quant_bits: int
    max_iters: int
    relax: float
    use_kernels: bool
    codec: str
    # POCS loop transform selector ("xla" | "packed" | "pallas") and
    # convergence-check cadence — see repro.core.pocs.  Defaults preserve the
    # legacy trajectory (and blob bytes) exactly.
    fft_impl: str = "xla"
    check_every: int = 1
    # Temporal warm start (ISSUE 8): when True, execute_field applies a
    # caller-supplied warm_freq spectrum as the loop's initial freq_edits
    # state (see repro.core.pocs).  False ignores any warm_freq — the
    # bitwise-identical cold start.
    warm_start: bool = False
    # ROI bounds (ISSUE 9): per-point spatial bound grid resolved from
    # FFCzConfig.E_roi (float32, field-shaped, every entry <= E) and its
    # disciplined projection twin.  None keeps the uniform-E paths (and
    # blob bytes) exactly as before.
    E_grid: Optional[np.ndarray] = None
    E_grid_proj: Optional[np.ndarray] = None

    @property
    def roi(self) -> bool:
        """True when the plan carries a per-point spatial bound grid."""
        return self.E_grid is not None

    @property
    def delta_scalar(self) -> float:
        """Scalar Delta for the blob header (nan when pointwise)."""
        return float("nan") if self.pointwise else float(self.Delta)

    def pointwise_bytes(self) -> Optional[bytes]:
        """float32 half-spectrum Delta_k grid for the blob, or None."""
        if not self.pointwise:
            return None
        return np.asarray(self.Delta, dtype=np.float32).tobytes()

    def roi_bytes(self) -> Optional[bytes]:
        """float32 spatial E_n grid for the blob's FFCR section, or None."""
        if self.E_grid is None:
            return None
        return np.asarray(self.E_grid, dtype=np.float32).tobytes()


@dataclasses.dataclass(frozen=True)
class PencilPlan:
    """PLAN-stage output for one tensor's pencil-tiled correction.

    The frequency bound applies to each ``block``-length pencil's local
    rfft spectrum: ``Delta = Delta_rel * max_k |RFFT(pencil of x)_k|``.
    """

    block: int
    quant_bits: int
    E: float
    Delta: float
    E_proj: float
    Delta_proj: float


@dataclasses.dataclass
class FieldResult:
    """EXECUTE-stage output: float64-exact loop state ready to encode.

    ``converged`` is the device loop's flag; when it is False,
    ``final_violations`` is the pair-weighted full-spectrum count of
    frequency components still outside the (shrunk) f-cube *after* the
    float64 polish — the number a caller needs to decide whether to retry
    with relaxed knobs, reject, or encode-with-warning.  Encoding a
    non-converged result is safe for the spatial bound (the final state is
    inside the s-cube by construction) but the frequency bound may be
    violated at exactly these components.
    """

    eps: np.ndarray  # final error vector (float64, inside the s-cube)
    spat: np.ndarray  # spatial edit accumulator (float64)
    freq: np.ndarray  # frequency edit accumulator (complex128, rfft layout)
    iterations: int
    converged: bool
    final_violations: int = 0


# ---------------------------------------------------------------------------
# async EXECUTE handles (pipelined serving, ISSUE 7)
#
# JAX dispatch is asynchronous: a jitted call returns in-flight device arrays
# before the program finishes.  The engine exposes that seam explicitly so a
# serving loop can overlap batch i's host ENCODE with batch i+1's device
# EXECUTE: the *_async entry points dispatch and return a handle immediately
# (classifying dispatch-time failures), and ``handle.result()`` is the
# ``jax.block_until_ready`` fence plus every host-side completion step (state
# staging, float64 polish, violation recount) — classified again, because an
# async device failure surfaces at the fence, possibly on another thread.


class FieldExecuteHandle:
    """One in-flight whole-field EXECUTE; ``result()`` fences and polishes.

    ``result()`` is idempotent (the finalized :class:`FieldResult` — or the
    classified error — is cached) and may be called from a different thread
    than the dispatching one: every failure re-raises as the same classified
    :class:`~repro.core.errors.FFCzError` on every caller.
    """

    def __init__(self, engine: "CorrectionEngine", raw, eps0, plan: FieldPlan):
        self._engine = engine
        self._raw = raw  # AlternatingProjectionResult of in-flight device arrays
        self._eps0 = eps0  # the ShardedField when sharded, else None
        self._plan = plan
        self._value: Optional[FieldResult] = None
        self._exc: Optional[FFCzError] = None

    def result(self) -> FieldResult:
        if self._exc is not None:
            raise self._exc
        if self._value is None:
            try:
                self._value = self._engine._finalize_field(self._raw, self._eps0, self._plan)
            except FFCzError as err:
                self._exc = err
                raise
            finally:
                self._raw = None  # drop the device references either way
        return self._value


class PencilBatchHandle:
    """One in-flight fused pencil EXECUTE over a packed ``(B, block)`` buffer.

    ``result()`` fences the device program and returns the same
    ``(corrected, edits, stats)`` tuple :meth:`CorrectionEngine.correct`
    produces, with per-tensor slices of the packed outputs.  Idempotent and
    thread-agnostic, like :class:`FieldExecuteHandle`.
    """

    def __init__(self, raw, stats, specs, counts, pads, block, return_edits, return_corrected):
        self._raw = raw
        self._stats = stats
        self._specs = specs  # [(shape, dtype)] per tensor
        self._counts = counts
        self._pads = pads
        self._block = block
        self._return_edits = return_edits
        self._return_corrected = return_corrected
        self._value = None
        self._exc: Optional[FFCzError] = None

    def result(self):
        if self._exc is not None:
            raise self._exc
        if self._value is None:
            try:
                res, stats = jax.block_until_ready((self._raw, self._stats))
                corrected, edits = [], []
                offset = 0
                for (shape, dtype), nb, pad in zip(self._specs, self._counts, self._pads):
                    sl = slice(offset, offset + nb)
                    if self._return_corrected:
                        corrected.append(
                            blockwise.untile_1d(res.eps[sl], shape, pad).astype(dtype)
                        )
                    if self._return_edits:
                        edits.append((res.spat_edits[sl], res.freq_edits[sl]))
                    offset += nb
                if self._return_edits:
                    self._value = (corrected, edits, stats)
                else:
                    self._value = (corrected, stats)
            except FFCzError as err:
                self._exc = err
                raise
            except (RuntimeError, MemoryError) as e:
                self._exc = classify_exception(e, "execute")
                raise self._exc from e
            finally:
                self._raw = self._stats = None
        return self._value


class _FenceHandle:
    """Generic handle over already-structured (but still in-flight) outputs:
    ``result()`` is just the classified ``block_until_ready`` fence.  Used by
    the ``local`` backend, whose per-tensor dispatches happen eagerly."""

    def __init__(self, value):
        self._value = value
        self._fenced = False
        self._exc: Optional[FFCzError] = None

    def result(self):
        if self._exc is not None:
            raise self._exc
        if not self._fenced:
            try:
                jax.block_until_ready(self._value)
                self._fenced = True
            except (RuntimeError, MemoryError) as e:
                self._exc = classify_exception(e, "execute")
                self._value = None
                raise self._exc from e
        return self._value


# ---------------------------------------------------------------------------
# the engine


@functools.lru_cache(maxsize=None)
def _sharded_field_pocs_fn(
    mesh,
    spec,
    pointwise: bool,
    max_iters: int,
    relax: float,
    fft_impl: str = "xla",
    check_every: int = 1,
    warm: bool = False,
    roi: bool = False,
):
    """Compiled sharded whole-field POCS program, cached per (mesh, DistSpec).

    Scalar bounds enter as replicated operands so re-planning the same field
    shape (or a new field of the same shape) reuses the compiled while_loop
    instead of retracing — the whole-field analogue of ``_pencil_fft_fn``.
    Arrays cross the boundary in the PADDED device layout; slab-pad rows are
    exactly zero and stay zero through the loop (see
    :mod:`repro.sharding.dist_fft`).  ``roi`` switches the spatial bound
    operand from a replicated scalar to a slab-sharded per-point grid (padded
    with the background bound so pad rows stay at zero through the clip).
    """
    ax = spec.axis_name
    fspec = dist_fft.freq_partition_spec(len(spec.gshape), ax)
    d_spec = fspec if pointwise else P()
    e_spec = P(ax) if roi else P()

    if warm:
        # the warm spectrum enters as a local half-spectrum block in the
        # padded device layout (pad rows zero), like a pointwise Delta grid
        def run(e_loc, d_loc, E, slack, w_loc):
            return _alternating_projection(
                e_loc,
                E,
                d_loc,
                max_iters=max_iters,
                relax=relax,
                check_slack=slack,
                dist=spec,
                fft_impl=fft_impl,
                check_every=check_every,
                warm_freq=w_loc,
            )

        in_specs = (P(ax), d_spec, e_spec, P(), fspec)
    else:

        def run(e_loc, d_loc, E, slack):
            return _alternating_projection(
                e_loc,
                E,
                d_loc,
                max_iters=max_iters,
                relax=relax,
                check_slack=slack,
                dist=spec,
                fft_impl=fft_impl,
                check_every=check_every,
            )

        in_specs = (P(ax), d_spec, e_spec, P())

    out_specs = AlternatingProjectionResult(
        eps=P(ax),
        spat_edits=P(ax),
        freq_edits=fspec,
        iterations=P(),
        converged=P(),
        final_violations=P(),
    )
    return jax.jit(
        shard_map(run, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    )


class CorrectionEngine:
    """Plan / execute / encode FFCz corrections on a pluggable backend.

    Args:
      backend: ``"local"`` (one dispatch per tensor), ``"batched"`` (one
        donated vmapped program per batch; the default), or ``"sharded"``
        (the batched program under ``shard_map`` over ``mesh[axis]``).
      mesh: device mesh for the sharded backend.  Defaults to a 1-D mesh
        over all local devices, built lazily on first use so engine
        construction never touches jax device state.
      axis: mesh axis name the packed block buffer is sharded over.
      fft_impl: default POCS transform selector for the *pencil* paths
        (``"xla"`` | ``"packed"`` | ``"pallas"``, see
        :mod:`repro.core.pocs`); whole-field corrections take theirs from
        ``FFCzConfig.fft_impl`` via the plan.  All three backends thread it
        into the loop — the packed/pallas transforms are vmap-safe, so the
        batched and sharded programs lift them unchanged.
    """

    def __init__(
        self,
        backend: str = "batched",
        mesh: Optional[Any] = None,
        axis: str = "data",
        fft_impl: str = "xla",
    ):
        if backend not in _BACKENDS:
            raise ValueError(f"backend must be one of {_BACKENDS}, got {backend!r}")
        if fft_impl not in ("xla", "packed", "pallas"):
            raise ValueError(f"fft_impl must be 'xla', 'packed' or 'pallas', got {fft_impl!r}")
        self.backend = backend
        self.axis = axis
        self.fft_impl = fft_impl
        self._mesh = mesh

    # Engines compare by configuration, not identity, so jitted functions
    # taking an engine as a static argument (e.g. compress_kv_tensor) hit
    # one cache entry for equivalent engines instead of retracing per
    # instance.  A lazily-built default mesh changes the key once on first
    # sharded use (one extra retrace), never corrupts a cache.
    def _key(self):
        return (self.backend, self.axis, self.fft_impl, self._mesh)

    def __eq__(self, other):
        return isinstance(other, CorrectionEngine) and self._key() == other._key()

    def __hash__(self):
        return hash(self._key())

    @property
    def mesh(self):
        if self._mesh is None:
            self._mesh = jax.make_mesh((len(jax.devices()),), (self.axis,))
        return self._mesh

    # -- PLAN --------------------------------------------------------------

    def plan_field(self, x: Union[np.ndarray, ShardedField], cfg) -> FieldPlan:
        """Resolve one whole field's bounds on device (cfg: FFCzConfig).

        The forward spectrum is computed (as a device rfft) only when a
        bound consumes it: ``pspec_rel`` needs the pointwise grid,
        ``Delta_rel`` needs ``max_k |X_k|``, and ``Delta_abs`` needs no
        forward FFT at all.

        A :class:`repro.sharding.dist_fft.ShardedField` keeps the spectrum
        sharded: the forward transform is the pencil-decomposed distributed
        rfftn and the bound grid is built on the sharded half-spectrum.  All
        plan reductions are sharding-invariant (max/min, or the host-staged
        :func:`_host_l2_norm`), so the resulting :class:`FieldPlan` is
        bitwise identical to planning the gathered field on one device.

        Precision note: the device rfft runs in float32, so relative bounds
        resolved from it (``Delta_rel`` / ``pspec_rel``) can differ from a
        host-float64 resolution — and across device backends — at float32
        rounding level (~1e-7 relative).  The blob stores the resolved
        values it was built with and all guarantees are verified against
        those stored values, so the bound contract is unaffected; byte
        identity of blobs only holds within one backend.  (The pencil path
        keeps host-float64 resolution — see :meth:`plan_pencils` — because
        its per-pencil Delta is a convention external tools recompute.)
        """
        sharded = isinstance(x, ShardedField)
        E_abs_eff, E_rel_eff = cfg.E_abs, cfg.E_rel
        if sharded:
            x32, x_dev = x.to_host(), x.array
            rfftn = lambda _dev: dist_fft.pencil_rfftn(x)  # noqa: E731
            if E_abs_eff is None and E_rel_eff is not None:
                # The device array carries zero slab-pad rows, which would
                # corrupt the E_rel range reduction (min picks up the pad).
                # max/min/subtract/multiply are all single correctly-rounded
                # float32 ops, so the host staging copy reproduces the
                # on-device reduction of the unpadded field bitwise.
                rng32 = np.max(x32) - np.min(x32)
                if float(rng32) == 0.0:
                    # mirror resolve_bounds' constant-field diagnosis — the
                    # sharded branch resolves E before ever reaching it
                    raise InfeasibleBound(
                        f"E_rel={float(cfg.E_rel):g} on a constant field: range(x) == 0 "
                        "resolves the spatial bound to E = 0 (an empty s-cube); pass "
                        "E_abs for constant fields",
                        stage="plan",
                    )
                E_abs_eff, E_rel_eff = np.float32(cfg.E_rel) * np.float32(rng32), None
        else:
            x32 = np.asarray(x, dtype=np.float32)
            x_dev = jnp.asarray(x32)
            rfftn = jnp.fft.rfftn
        if cfg.pspec_rel is not None:
            # the padded sharded spectrum's pad rows are exactly zero, so the
            # grid max / floor / DC reductions below see the same values as
            # the single-device path; the stored grid is sliced to the true
            # half-spectrum extents
            X = rfftn(x_dev)
            grid = power_spectrum_delta_rfft(X, cfg.pspec_rel)
            gmax = float(jnp.max(grid))
            if gmax <= 0:
                # grid = t*|X|/sqrt(2) with floor 0, so gmax == 0 iff the
                # field is all-zero: every Delta_k resolves to 0 and any
                # published "pspec_rel" guarantee would be meaningless
                raise InfeasibleBound(
                    f"pspec_rel={float(cfg.pspec_rel):g} on an all-zero field: every "
                    "Delta_k resolves to 0 (no spectrum to preserve); use Delta_abs "
                    "for zero fields",
                    stage="plan",
                )
            floor = gmax * cfg.pspec_floor_rel
            Delta_user = np.asarray(jnp.maximum(grid, floor), dtype=np.float32)
            if sharded:
                Delta_user = x.unpad_freq(Delta_user)
            bounds = resolve_bounds(x_dev, E_abs=E_abs_eff, E_rel=E_rel_eff, Delta_abs=1.0)
            pointwise = True
        elif cfg.Delta_abs is not None:
            bounds = resolve_bounds(x_dev, E_abs=E_abs_eff, E_rel=E_rel_eff, Delta_abs=cfg.Delta_abs)
            Delta_user = float(bounds.Delta)
            pointwise = False
        else:
            # Delta_rel needs max_k |X_k|: zero pad rows never raise a max
            X = rfftn(x_dev)
            bounds = resolve_bounds(x_dev, E_abs=E_abs_eff, E_rel=E_rel_eff, Delta_rel=cfg.Delta_rel, X=X)
            Delta_user = float(bounds.Delta)
            pointwise = False
        E = float(bounds.E)
        l2_norm = _host_l2_norm(x32)
        abs_max = float(jnp.max(jnp.abs(x_dev))) if x32.size else 0.0
        E_proj, Delta_proj, Delta, slack_f = float32_bound_discipline(
            E, Delta_user, cfg.quant_bits, l2_norm, abs_max
        )
        # ROI bounds (ISSUE 9): resolve the user's mask / per-point grid into
        # the float32 E_n grid the blob stores, then re-run the (elementwise)
        # discipline on it so every point gets its own shrunk projection
        # bound — exactly how the pointwise Delta_k grid is treated.
        E_grid = E_grid_proj = None
        E_roi = getattr(cfg, "E_roi", None)
        if E_roi is not None:
            E_grid = resolve_roi_bound_grid(
                E_roi, E, tuple(x32.shape), scale=getattr(cfg, "E_roi_scale", 0.1)
            )
            E_grid_proj, _, _, _ = float32_bound_discipline(
                E_grid, Delta_user, cfg.quant_bits, l2_norm, abs_max
            )
            E_grid_proj = np.asarray(E_grid_proj, dtype=np.float32)
            if float(np.min(E_grid_proj)) <= 0:
                raise InfeasibleBound(
                    f"tightest ROI bound E_n={float(np.min(E_grid)):g} below float32 "
                    "representability for this data",
                    stage="plan",
                )
        if not pointwise:
            Delta_proj = float(Delta_proj)
            Delta = float(Delta)
        # Infeasible spatial∩frequency intersection is a *request* property:
        # reject structurally (stage + disposition) instead of letting a bare
        # exception escape the engine into a serving loop.
        if E_proj <= 0:
            raise InfeasibleBound(
                f"spatial bound E={E:g} below float32 representability for this data",
                stage="plan",
            )
        if float(np.min(Delta_proj)) <= 0:
            raise InfeasibleBound(
                f"frequency bound Delta={float(np.min(np.asarray(Delta_user))):g} below float32 "
                f"representability after the quantization shrink (quant_bits={cfg.quant_bits})",
                stage="plan",
            )
        return FieldPlan(
            shape=tuple(x32.shape),
            E=E,
            Delta=Delta,
            E_proj=float(E_proj),
            Delta_proj=Delta_proj,
            slack_f=float(slack_f),
            pointwise=pointwise,
            quant_bits=cfg.quant_bits,
            max_iters=cfg.max_iters,
            relax=cfg.relax,
            use_kernels=cfg.use_kernels,
            codec=cfg.codec,
            fft_impl=getattr(cfg, "fft_impl", "xla"),
            check_every=getattr(cfg, "check_every", 1),
            warm_start=getattr(cfg, "warm_start", False),
            E_grid=E_grid,
            E_grid_proj=E_grid_proj,
        )

    def plan_pencils(
        self,
        x32: np.ndarray,
        *,
        E_rel: Optional[float] = None,
        Delta_rel: Optional[float] = None,
        block: int,
        quant_bits: int = DEFAULT_QUANT_BITS,
        E_abs: Optional[float] = None,
        Delta_abs: Optional[float] = None,
        E_roi=None,
        E_roi_scale: float = 0.1,
    ) -> Optional[PencilPlan]:
        """Resolve one tensor's pencil-tiled bounds; None if E underflows.

        Bound resolution here stays in host float64 (``np.fft.rfft``): the
        per-pencil ``Delta`` is the published guarantee other tools
        recompute exactly, so it must not pick up float32-FFT jitter.  The
        cast-noise slack uses per-pencil norms (the noise lands on each
        pencil's local spectrum).

        ``E_abs``/``Delta_abs`` override the relative resolution with
        already-absolute bounds (each independently): temporal residual
        frames carry bounds resolved once on the stream's first frame, so
        re-deriving them from each residual's own range would drift.  An
        absolute Delta needs no forward FFT at all.

        ``E_roi`` (mask or per-point grid, see
        :func:`repro.core.bounds.resolve_roi_bound_grid`) collapses to the
        *tightest* resolved bound as the effective uniform ``E``: pencil
        tiling scrambles spatial adjacency across blocks, so a per-point
        grid cannot ride the tiled streams — the whole-field path
        (:meth:`plan_field`) keeps the full grid.
        """
        flat = x32.reshape(-1)
        tiles = np.pad(flat, (0, (-flat.size) % block)).reshape(-1, block)
        if E_abs is not None:
            E = float(E_abs)
        else:
            if E_rel is None:
                raise ValueError("plan_pencils needs E_rel or E_abs")
            E = E_rel * float(np.ptp(x32))
        if E_roi is not None:
            grid = resolve_roi_bound_grid(E_roi, E, tuple(x32.shape), scale=E_roi_scale)
            E = float(np.min(grid))
        if Delta_abs is not None:
            Delta = float(Delta_abs)
        else:
            if Delta_rel is None:
                raise ValueError("plan_pencils needs Delta_rel or Delta_abs")
            Delta = Delta_rel * float(np.abs(np.fft.rfft(tiles, axis=-1)).max())
        E_proj, Delta_proj, Delta, _slack_f = float32_bound_discipline(
            E,
            Delta,
            quant_bits,
            np.sqrt((tiles.astype(np.float64) ** 2).sum(axis=-1).max()),
            np.max(np.abs(x32)) if x32.size else 0.0,
        )
        if E_proj <= 0:
            return None
        return PencilPlan(
            block=block,
            quant_bits=quant_bits,
            E=E,
            Delta=float(Delta),
            E_proj=float(E_proj),
            Delta_proj=float(Delta_proj),
        )

    @staticmethod
    def tile_f64(eps0: np.ndarray, block: int) -> np.ndarray:
        """Float64 (n_blocks, block) tiling of an error tensor — the exact
        loop state the pencil polish rebuilds from, captured up front so the
        float32 original need not outlive the batched device call."""
        flat = np.asarray(eps0, dtype=np.float64).reshape(-1)
        return np.pad(flat, (0, (-flat.size) % block)).reshape(-1, block)

    # -- EXECUTE -----------------------------------------------------------

    def execute_field(
        self,
        eps0: Union[np.ndarray, ShardedField],
        plan: FieldPlan,
        warm_freq: Optional[np.ndarray] = None,
    ) -> FieldResult:
        """One jitted device POCS program + the exact float64 polish.

        The jitted loop runs in float32 (the TPU perf path, as the paper
        runs FP32 on A100); its convergence check is therefore only
        float32-exact.  A few exact host-side POCS iterations absorb the
        FFT round-off so the *shrunk* bounds hold in float64, leaving the
        full quantization margin intact.

        A :class:`ShardedField` ``eps0`` runs the same while_loop on local
        slabs inside ``shard_map``, with the pencil-decomposed distributed
        transforms in the loop body — the field-sized float32 state never
        gathers to one device.  The loop trajectory is bitwise identical to
        the single-device program (see :mod:`repro.sharding.dist_fft`), so
        the edit streams — and the blobs built from them — match exactly.

        ``warm_freq`` (complex half-spectrum, the previous stream frame's
        converged ``FieldResult.freq``) seeds the loop's ``freq_edits``
        accumulator — consumed only when ``plan.warm_start`` is True, so a
        cold-configured plan stays bitwise identical whatever the caller
        passes (the temporal neutrality switch).
        """
        return self.execute_field_async(eps0, plan, warm_freq=warm_freq).result()

    def execute_field_async(
        self,
        eps0: Union[np.ndarray, ShardedField],
        plan: FieldPlan,
        warm_freq: Optional[np.ndarray] = None,
    ) -> FieldExecuteHandle:
        """Dispatch the whole-field POCS program; return before the fence.

        The returned :class:`FieldExecuteHandle` owns the in-flight device
        arrays; ``handle.result()`` runs ``jax.block_until_ready`` plus the
        host half of :meth:`execute_field` (state staging, float64 polish,
        violation recount) and may run on a different thread — the pipelined
        service fences batch *i* on its encode worker while this thread
        dispatches batch *i+1*.  Dispatch-time device failures classify and
        raise here; fence-time failures classify inside ``result()``.
        """
        sharded = isinstance(eps0, ShardedField)
        if not plan.warm_start:
            warm_freq = None  # neutrality: cold plans never see a warm state
        try:
            if sharded:
                res = self._pocs_field_sharded(eps0, plan, warm_freq)
            else:
                E_op = (
                    plan.E_proj
                    if plan.E_grid_proj is None
                    else jnp.asarray(plan.E_grid_proj)
                )
                res = alternating_projection(
                    jnp.asarray(eps0, dtype=jnp.float32),
                    E_op,
                    jnp.asarray(plan.Delta_proj),
                    max_iters=plan.max_iters,
                    use_kernels=plan.use_kernels,
                    relax=plan.relax,
                    check_slack=0.5 * plan.slack_f,
                    fft_impl=plan.fft_impl,
                    check_every=plan.check_every,
                    warm_freq=None if warm_freq is None
                    else jnp.asarray(warm_freq, dtype=jnp.complex64),
                )
        except (RuntimeError, MemoryError) as e:
            # device dispatch / allocation failures carry stage + disposition
            # (OOM -> "bisect") so serving loops can act without string-matching
            raise classify_exception(e, "execute") from e
        return FieldExecuteHandle(self, res, eps0 if sharded else None, plan)

    def _finalize_field(self, res, sharded_field, plan: FieldPlan) -> FieldResult:
        """The fence + host half of EXECUTE (see :meth:`execute_field_async`)."""
        sharded = sharded_field is not None
        try:
            # edit state -> host: this is the encode/serialization staging (the
            # single-device path stages identically); the float64 polish is a
            # handful of host FFT round trips on the O(residual) edit state.
            # Sharded state arrives in the padded device layout — slab-pad
            # rows/columns are exactly zero; slicing them away here restores the
            # single-device shapes (and values, bitwise on "bitwise"-parity
            # shapes) before the polish and encode stages.
            jax.block_until_ready(res)
            spat = np.asarray(res.spat_edits, dtype=np.float64)
            freq = np.asarray(res.freq_edits, dtype=np.complex128)
            eps_f = np.asarray(res.eps, dtype=np.float64)
        except (RuntimeError, MemoryError) as e:
            # an async device failure surfaces at the fence, not at dispatch
            raise classify_exception(e, "execute") from e
        if sharded:
            eps0 = sharded_field
            spat = eps0.unpad_spatial(spat)
            eps_f = eps0.unpad_spatial(eps_f)
            freq = eps0.unpad_freq(freq)
        E_pol = (
            plan.E_proj
            if plan.E_grid_proj is None
            else np.asarray(plan.E_grid_proj, dtype=np.float64)
        )
        eps_f, spat, freq = polish_pocs_float64(
            eps_f, spat, freq, E_pol, np.asarray(plan.Delta_proj, dtype=np.float64)
        )
        converged = bool(res.converged)
        final_violations = 0
        if not converged:
            # Surface non-convergence with an exact post-polish count: the
            # float32 loop's exit count may overstate what the float64 polish
            # could not absorb.  Pair weights keep full-spectrum semantics,
            # matching the loop's own violation accounting.  (Converged runs
            # skip the extra host rfftn — the default path pays nothing.)
            d = np.fft.rfftn(eps_f)
            tol = np.asarray(plan.Delta_proj, dtype=np.float64)
            bad = (np.abs(d.real) > tol) | (np.abs(d.imag) > tol)
            w = np.broadcast_to(np.asarray(rfft_pair_weights(plan.shape)), bad.shape)
            final_violations = int(np.sum(w * bad))
        return FieldResult(
            eps=eps_f,
            spat=spat,
            freq=freq,
            iterations=int(res.iterations),
            converged=converged,
            final_violations=final_violations,
        )

    def _pocs_field_sharded(self, eps0: ShardedField, plan: FieldPlan, warm_freq=None):
        """The whole-field POCS while_loop under ``shard_map`` (dist mode)."""
        if plan.use_kernels:
            raise ValueError("use_kernels is not supported for sharded whole fields")
        if plan.fft_impl == "pallas":
            raise ValueError(
                "fft_impl='pallas' is not supported for sharded whole fields "
                "(the fused epilogues assume the whole spectrum; use 'packed')"
            )
        if plan.fft_impl != "xla" and eps0.parity_requested == "bitwise":
            # honest tri-state: the packed inverse places its roundings
            # differently from the fused single-device irfftn, so blobs can
            # only be bound-parity whatever the shape class
            raise ValueError(
                "parity='bitwise' requires fft_impl='xla': packed transforms "
                "diverge from the single-device path at float32-rounding "
                "level (bounds still hold; request parity='auto')"
            )
        mesh = eps0.mesh
        if plan.pointwise:
            # pre-round the float64 plan grid to float32 on host (the same
            # IEEE rounding jnp.asarray applies on the single-device path),
            # zero-pad it to the device layout (pad components are exactly
            # zero in the loop, so their bound value is inert), then scatter
            # straight into the frequency layout
            delta_op = jax.device_put(
                eps0.pad_freq_np(np.asarray(plan.Delta_proj, dtype=np.float32)),
                NamedSharding(mesh, eps0.freq_spec),
            )
        else:
            delta_op = jnp.float32(plan.Delta_proj)
        if plan.E_grid_proj is not None:
            # ROI grid enters as a slab-sharded spatial operand; pad rows
            # carry the (positive) background projection bound so the zero
            # pad rows of the field stay exactly zero through the clip
            e_op = jax.device_put(
                eps0.pad_spatial_np(
                    np.asarray(plan.E_grid_proj, dtype=np.float32),
                    fill=np.float32(plan.E_proj),
                ),
                NamedSharding(mesh, eps0.spec),
            )
        else:
            e_op = np.float32(plan.E_proj)
        warm_op = None
        if warm_freq is not None:
            # same device layout as a pointwise Delta grid: zero-padded to
            # the local half-spectrum blocks (pad rows stay zero in the loop)
            warm_op = jax.device_put(
                eps0.pad_freq_np(np.asarray(warm_freq, dtype=np.complex64)),
                NamedSharding(mesh, eps0.freq_spec),
            )
        fn = _sharded_field_pocs_fn(
            mesh,
            eps0.dist_spec,
            plan.pointwise,
            plan.max_iters,
            plan.relax,
            plan.fft_impl,
            plan.check_every,
            warm_op is not None,
            plan.E_grid_proj is not None,
        )
        # scalar bounds ride as replicated operands (pre-rounded to the f32
        # values the single-device trace uses), so same-shape fields with
        # different bounds share one compiled program
        args = (eps0.array, delta_op, e_op, np.float32(0.5 * plan.slack_f))
        if warm_op is not None:
            args = args + (warm_op,)
        return fn(*args)

    def correct(
        self,
        tensors: Sequence[Any],
        E,
        Delta,
        block: int = 4096,
        max_iters: int = 50,
        return_edits: bool = False,
        return_corrected: bool = True,
        fft_impl: Optional[str] = None,
        warm_freq: Optional[Sequence[Any]] = None,
    ):
        """Pencil-tiled correction of a heterogeneous batch on this backend.

        Same contract as :func:`repro.core.blockwise.correct_batch` (which
        implements the ``batched`` and ``sharded`` backends); the ``local``
        backend dispatches one program per tensor.  Jit-safe on the batched
        backend, so jitted integrations can call through unchanged.
        ``fft_impl`` overrides the engine default for this call;
        ``warm_freq`` optionally seeds each tensor's blocks with prior edit
        spectra (``(n_blocks_i, block//2+1)`` per tensor — the temporal
        stream path).
        """
        fft_impl = self.fft_impl if fft_impl is None else fft_impl
        try:
            if self.backend == "local":
                return self._correct_local(
                    tensors, E, Delta, block, max_iters, return_edits, return_corrected,
                    fft_impl, warm_freq,
                )
            return blockwise.correct_batch(
                tensors,
                E,
                Delta,
                block=block,
                max_iters=max_iters,
                return_edits=return_edits,
                return_corrected=return_corrected,
                backend=self.backend,
                mesh=self.mesh if self.backend == "sharded" else None,
                axis=self.axis,
                fft_impl=fft_impl,
                warm_freq=warm_freq,
            )
        except (RuntimeError, MemoryError) as e:
            raise classify_exception(e, "execute") from e

    def correct_async(
        self,
        tensors: Sequence[Any],
        E,
        Delta,
        block: int = 4096,
        max_iters: int = 50,
        return_edits: bool = False,
        return_corrected: bool = True,
        fft_impl: Optional[str] = None,
        staging: Optional[np.ndarray] = None,
        warm_freq: Optional[Sequence[Any]] = None,
    ):
        """Dispatch a pencil-batch correction; return a handle before the fence.

        The async twin of :meth:`correct`: packing happens on host
        (:func:`repro.core.blockwise.pack_batch` — ``staging`` optionally
        reuses a caller-cached ``(B, block)`` buffer so steady-state serving
        buckets stop reallocating it), the packed POCS program is dispatched
        with the device buffer DONATED, and the returned
        :class:`PencilBatchHandle`'s ``result()`` fences + slices per tensor,
        yielding exactly :meth:`correct`'s return structure.  The packed
        values, the vmapped while_loop and the stat reductions are the same
        program as :meth:`correct`'s, so results are interchangeable.

        Dispatch-time failures (including allocation failure on the packed
        buffer) classify and raise here; async failures classify inside
        ``result()``, which may run on another thread.
        """
        fft_impl = self.fft_impl if fft_impl is None else fft_impl
        if len(tensors) == 0:
            empty = blockwise.BatchCorrectionStats(
                iterations=jnp.zeros((0,), jnp.int32),
                converged=jnp.zeros((0,), bool),
                block_iterations=jnp.zeros((0,), jnp.int32),
                block_converged=jnp.zeros((0,), bool),
            )
            return _FenceHandle(([], [], empty) if return_edits else ([], empty))
        if self.backend == "local":
            # per-tensor dispatches happen eagerly; the handle is just the fence
            try:
                return _FenceHandle(
                    self._correct_local(
                        tensors, E, Delta, block, max_iters, return_edits,
                        return_corrected, fft_impl, warm_freq,
                    )
                )
            except (RuntimeError, MemoryError) as e:
                raise classify_exception(e, "execute") from e
        specs = [(np.asarray(t).shape, np.asarray(t).dtype) for t in tensors]
        try:
            packed, counts, pads = blockwise.pack_batch(tensors, block, out=staging)
            warm = None
            if warm_freq is not None:
                warm = np.concatenate(
                    [np.asarray(w, dtype=np.complex64) for w in warm_freq], axis=0
                )
            res, stats = blockwise.correct_packed(
                packed,
                counts,
                E,
                Delta,
                max_iters=max_iters,
                backend=self.backend,
                mesh=self.mesh if self.backend == "sharded" else None,
                axis=self.axis,
                fft_impl=fft_impl,
                warm=warm,
            )
        except (RuntimeError, MemoryError) as e:
            raise classify_exception(e, "execute") from e
        return PencilBatchHandle(
            res, stats, specs, counts, pads, block, return_edits, return_corrected
        )

    def _correct_local(
        self, tensors, E, Delta, block, max_iters, return_edits, return_corrected,
        fft_impl="xla", warm_freq=None,
    ):
        """Per-tensor dispatch (the pre-batching behaviour, kept for
        comparison benches and single-tensor calls).  Bounds go through the
        same resolver as the batched/sharded backends so the scalar-vs-
        per-tensor contract cannot diverge."""
        n = len(tensors)
        Es = blockwise._as_bound_array(E, n)
        Ds = blockwise._as_bound_array(Delta, n)
        warms = [None] * n if warm_freq is None else list(warm_freq)
        if len(warms) != n:
            raise ValueError(f"expected {n} per-tensor warm spectra, got {len(warms)}")
        corrected, edits, it_blocks, conv_blocks, it_t, conv_t = [], [], [], [], [], []
        for t, e, d, w in zip(tensors, Es, Ds, warms):
            t = jnp.asarray(t)
            corr, spat, freq, iters, conv = blockwise.blockwise_correct_with_edits(
                t, e, d, block=block, max_iters=max_iters, fft_impl=fft_impl,
                warm=None if w is None else jnp.asarray(w),
            )
            if return_corrected:
                corrected.append(corr.astype(t.dtype))
            if return_edits:
                edits.append((spat, freq))
            it_blocks.append(iters)
            conv_blocks.append(conv)
            it_t.append(jnp.max(iters))
            conv_t.append(jnp.all(conv))
        stats = blockwise.BatchCorrectionStats(
            iterations=jnp.stack(it_t) if n else jnp.zeros((0,), jnp.int32),
            converged=jnp.stack(conv_t) if n else jnp.zeros((0,), bool),
            block_iterations=jnp.concatenate(it_blocks) if n else jnp.zeros((0,), jnp.int32),
            block_converged=jnp.concatenate(conv_blocks) if n else jnp.zeros((0,), bool),
        )
        if return_edits:
            return corrected, edits, stats
        return corrected, stats

    # -- ENCODE ------------------------------------------------------------

    def encode_field(self, result: FieldResult, plan: FieldPlan) -> Tuple[EncodedEdits, EncodedEdits]:
        """Serialize a whole field's edit streams with adaptive bit-widths.

        K_s and the active pair-weighted Delta sum are known exactly
        post-projection, so the widths come from the closed form in
        :func:`adaptive_quant_bits` (beyond-paper; the paper fixes m = 16
        which covers only the direct term).  The Delta sum runs over the
        *full* spectrum, so each active half-spectrum edit contributes with
        its conjugate-pair multiplicity.
        """
        k_s = int(np.count_nonzero(result.spat))
        pair_w = np.broadcast_to(np.asarray(rfft_pair_weights(plan.shape)), result.freq.shape)
        delta_b = np.broadcast_to(np.asarray(plan.Delta), result.freq.shape)
        sum_active_delta = float(np.sum((pair_w * delta_b)[result.freq != 0]))
        n = int(np.prod(plan.shape)) if plan.shape else 1
        m_s, m_f = adaptive_quant_bits(
            plan.quant_bits,
            k_s,
            plan.E,
            float(np.min(plan.Delta)),
            sum_active_delta,
            n,
        )
        if plan.roi:
            # Per-point spatial bounds split the cross-term accounting:
            # m_s stays from the call above (spatial edits are bounded by
            # their own per-point bound <= E, so the global-E width covers
            # the FFT leakage of the quantized stream), while m_f must keep
            # the IFFT leakage of the frequency stream under the *tightest*
            # point's reserved margin — rerun with E_min for that side.
            _, m_f = adaptive_quant_bits(
                plan.quant_bits,
                k_s,
                float(np.min(plan.E_grid)),
                float(np.min(plan.Delta)),
                sum_active_delta,
                n,
            )
        try:
            se = encode_edits(
                result.spat, plan.E_grid if plan.roi else plan.E, m=m_s, codec=plan.codec
            )
            fe = encode_edits(result.freq, plan.Delta, m=m_f, codec=plan.codec, half_spectrum=True)
        except (RuntimeError, MemoryError, OSError) as e:
            raise classify_exception(e, "encode") from e
        return se, fe

    def encode_pencils(
        self,
        spat_t: Any,
        freq_t: Any,
        tiles0: np.ndarray,
        plan: PencilPlan,
        codec: str = "zlib",
    ) -> Tuple[EncodedEdits, EncodedEdits]:
        """Polish + serialize one tensor's pencil edit streams.

        ``spat_t``/``freq_t`` are the device edit tiles from
        :meth:`correct`; ``tiles0`` the float64 tiling of the *initial*
        error (:meth:`tile_f64`).  The float64 polish reruns on the
        reconstructed loop state, then adaptive bit-widths are chosen per
        worst-case pencil.
        """
        spat = np.asarray(spat_t, dtype=np.float64)
        freq = np.asarray(freq_t, dtype=np.complex128)
        eps_now = tiles0 + np.fft.irfft(freq, n=plan.block, axis=-1) + spat
        _eps, spat, freq = polish_pocs_float64(
            eps_now, spat, freq, plan.E_proj, plan.Delta_proj, axes=(1,)
        )
        pair_w = np.asarray(rfft_pair_weights((plan.block,))).reshape(-1)
        k_s_max = int(np.count_nonzero(spat, axis=1).max()) if spat.size else 0
        wsum_max = float(((freq != 0) * pair_w).sum(axis=1).max()) if freq.size else 0.0
        m_s, m_f = adaptive_quant_bits(
            plan.quant_bits, k_s_max, plan.E, plan.Delta, wsum_max * plan.Delta, plan.block, cap=40
        )
        try:
            se = encode_edits(spat, plan.E, m=m_s, codec=codec)
            fe = encode_edits(freq, plan.Delta, m=m_f, codec=codec, half_spectrum=True)
        except (RuntimeError, MemoryError, OSError) as e:
            raise classify_exception(e, "encode") from e
        return se, fe


@functools.lru_cache(maxsize=None)
def default_engine() -> CorrectionEngine:
    """Process-wide batched engine the framework integrations share."""
    return CorrectionEngine(backend="batched")
