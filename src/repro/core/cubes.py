"""s-cube / f-cube projections (paper §IV-A/B, Fig. 3).

The spatial error vector ``eps`` lives in R^N.  The s-cube is the axis-aligned
box ``|eps_n| <= E``; projecting onto it clips each coordinate.  The f-cube is
axis-aligned in the *frequency basis*: its half-space normals are the DFT
cosine/sine rows, which are mutually orthogonal, so the exact Euclidean
projection onto the f-cube is

    FFT -> clip Re/Im to [-Delta, Delta] -> IFFT.

Clipping Re and Im with the same (Hermitian-symmetric) bound preserves the
Hermitian symmetry ``delta_{N-k} = conj(delta_k)`` of the spectrum of a real
error vector (clip is odd for Im, even for Re), so IFFT(clipped) stays real —
this is why the paper can clip components independently on the GPU.

These are the pure-jnp oracles; :mod:`repro.kernels.fcube` / ``scube`` are the
fused Pallas TPU kernels with identical semantics.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def project_scube(eps: jnp.ndarray, E) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Clip spatial errors to the s-cube.  Returns (clipped, displacement)."""
    clipped = jnp.clip(eps, -E, E)
    return clipped, clipped - eps


def project_fcube(delta: jnp.ndarray, Delta) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Clip complex frequency errors to the f-cube (independent Re/Im clip).

    Returns (clipped, displacement) — both complex, same shape as ``delta``.
    """
    re = jnp.clip(delta.real, -Delta, Delta)
    im = jnp.clip(delta.imag, -Delta, Delta)
    clipped = (re + 1j * im).astype(delta.dtype)
    return clipped, clipped - delta


def fcube_violations(delta: jnp.ndarray, Delta) -> jnp.ndarray:
    """Count of frequency components outside the f-cube (CheckConvergence)."""
    return jnp.sum((jnp.abs(delta.real) > Delta) | (jnp.abs(delta.imag) > Delta))


def scube_violations(eps: jnp.ndarray, E) -> jnp.ndarray:
    """Count of spatial components outside the s-cube."""
    return jnp.sum(jnp.abs(eps) > E)
