"""s-cube / f-cube projections (paper §IV-A/B, Fig. 3).

The spatial error vector ``eps`` lives in R^N.  The s-cube is the axis-aligned
box ``|eps_n| <= E``; projecting onto it clips each coordinate.  The f-cube is
axis-aligned in the *frequency basis*: its half-space normals are the DFT
cosine/sine rows, which are mutually orthogonal, so the exact Euclidean
projection onto the f-cube is

    FFT -> clip Re/Im to [-Delta, Delta] -> IFFT.

Clipping Re and Im with the same (Hermitian-symmetric) bound preserves the
Hermitian symmetry ``delta_{N-k} = conj(delta_k)`` of the spectrum of a real
error vector (clip is odd for Im, even for Re), so IFFT(clipped) stays real —
this is why the paper can clip components independently on the GPU.

That same symmetry means the full spectrum is redundant: the half-spectrum
kept by ``rfftn`` (last axis ``0..N//2``) holds every independent component.
The rFFT fast path of :mod:`repro.core.pocs` therefore projects only the
half-spectrum; :func:`rfft_pair_weights` supplies the conjugate-pair
multiplicities so violation *counts* still match full-spectrum semantics.

These are the pure-jnp oracles; :mod:`repro.kernels.fcube` / ``scube`` are the
fused Pallas TPU kernels with identical semantics.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np


def rfft_shape(shape: Tuple[int, ...]) -> Tuple[int, ...]:
    """Shape of ``rfftn`` output for a real field of ``shape``."""
    return tuple(shape[:-1]) + (shape[-1] // 2 + 1,)


def rfft_pair_weights(shape: Tuple[int, ...], dtype=jnp.int32) -> jnp.ndarray:
    """Conjugate-pair multiplicity of each half-spectrum component.

    For a real field of full ``shape``, a component at last-axis index
    ``0 < k < N/2`` stands for itself *and* its conjugate at ``N-k`` (which
    ``rfftn`` drops) — weight 2.  The ``k = 0`` plane and (even ``N``) the
    ``k = N/2`` plane are fully present in the half-spectrum, so each of
    their components counts once — weight 1.  (Those planes are internally
    Hermitian-redundant across the *other* axes, but both members of each
    such pair are stored, so per-component counting stays exact.)

    Returns a ``(1, ..., 1, N//2 + 1)`` array broadcastable against the
    half-spectrum; ``sum(weights * ones) == prod(shape)``.
    """
    n = shape[-1]
    h = n // 2 + 1
    w = np.full(h, 2, dtype=np.int64)
    w[0] = 1
    if n % 2 == 0:
        w[-1] = 1
    return jnp.asarray(w, dtype=dtype).reshape((1,) * (len(shape) - 1) + (h,))


def project_scube(eps: jnp.ndarray, E) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Clip spatial errors to the s-cube.  Returns (clipped, displacement)."""
    clipped = jnp.clip(eps, -E, E)
    return clipped, clipped - eps


def project_fcube(delta: jnp.ndarray, Delta) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Clip complex frequency errors to the f-cube (independent Re/Im clip).

    Returns (clipped, displacement) — both complex, same shape as ``delta``.
    Works identically on full and half spectra (the f-cube is axis-aligned,
    so restriction to the rfft half-plane is still the exact projection).
    """
    re = jnp.clip(delta.real, -Delta, Delta)
    im = jnp.clip(delta.imag, -Delta, Delta)
    clipped = (re + 1j * im).astype(delta.dtype)
    return clipped, clipped - delta


def project_box_relaxed(x: jnp.ndarray, bound, relax: float) -> jnp.ndarray:
    """Closed-form ``P(x + relax*(P(x) - x))`` for the box ``|x| <= bound``.

    Over-relaxed POCS re-projects the over-shot point; for a box that
    composition collapses to a single clip of the shrunk magnitude:

        P(x + r*(P(x)-x)) = sign(x) * clip(|x| - r*max(|x|-bound, 0), -bound, bound)

    (inside the box the excess term vanishes; outside, the magnitude is
    pulled ``r`` times the excess toward — and for r > 1 past — the face,
    and the final clip handles the large-overshoot reflection).  One pass
    over the data instead of project -> displace -> re-project.
    """
    a = jnp.abs(x)
    m = a - relax * jnp.maximum(a - bound, 0.0)
    return jnp.sign(x) * jnp.clip(m, -bound, bound)


def project_fcube_relaxed(delta: jnp.ndarray, Delta, relax: float) -> jnp.ndarray:
    """Relaxed f-cube projection, one clip per Re/Im channel (see above)."""
    re = project_box_relaxed(delta.real, Delta, relax)
    im = project_box_relaxed(delta.imag, Delta, relax)
    return (re + 1j * im).astype(delta.dtype)


def fcube_violations(delta: jnp.ndarray, Delta, weight: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Count of frequency components outside the f-cube (CheckConvergence).

    ``weight`` (broadcastable int array) scales each component's contribution;
    the rfft fast path passes :func:`rfft_pair_weights` so the count over the
    half-spectrum equals the count over the full spectrum.
    """
    viol = (jnp.abs(delta.real) > Delta) | (jnp.abs(delta.imag) > Delta)
    if weight is None:
        return jnp.sum(viol)
    return jnp.sum(viol.astype(weight.dtype) * weight)


def scube_violations(eps: jnp.ndarray, E) -> jnp.ndarray:
    """Count of spatial components outside the s-cube."""
    return jnp.sum(jnp.abs(eps) > E)
