"""Structured FFCz error taxonomy: stage, cause, and retry disposition.

Every failure that can escape the compression pipeline is classified along
two axes the serving layer acts on:

  transient vs permanent   will the same call plausibly succeed if repeated?
  retryable vs reject      should a caller with retry budget try again?

plus a ``disposition`` hint for failures that need a *different* retry, not
the same one:

  ``"retry"``    re-run the same work (backoff first) — host codec hiccups,
                 device dispatch failures.
  ``"bisect"``   the work unit is too large as batched — split it and run
                 the halves (device allocation failure on a batch).
  ``"reject"``   no retry will help — infeasible bounds, corrupt bytes.
  ``"timeout"``  the request's deadline passed; terminal by definition.

Errors carry the pipeline ``stage`` they surfaced in (``plan`` / ``base`` /
``execute`` / ``encode`` / ``decode`` / ``admit`` / ``session`` /
``service``) and the
original ``cause`` exception when they wrap one.  The decode-side
:class:`BlobCorruptError` and the plan-side :class:`InfeasibleBound` also
subclass ``ValueError`` so pre-taxonomy callers (and tests) that catch
``ValueError`` keep working unchanged.

:func:`classify_exception` maps arbitrary exceptions from the runtime onto
this taxonomy — it is how the engine stages and the serving layer turn a
raw ``XlaRuntimeError`` / ``zlib.error`` / ``MemoryError`` into a disposition
without string-matching at every call site.
"""

from __future__ import annotations

from concurrent.futures import CancelledError
from typing import Optional


class FFCzError(Exception):
    """Base of the FFCz failure taxonomy (see module docstring)."""

    transient: bool = False
    retryable: bool = False
    disposition: str = "reject"  # "retry" | "bisect" | "reject" | "timeout"

    def __init__(self, message: str, *, stage: Optional[str] = None, cause: Optional[BaseException] = None):
        super().__init__(message)
        self.stage = stage
        self.cause = cause

    def to_dict(self) -> dict:
        """Wire-friendly structured form for service rejection responses."""
        return {
            "type": type(self).__name__,
            "stage": self.stage,
            "message": str(self),
            "transient": self.transient,
            "retryable": self.retryable,
            "disposition": self.disposition,
            "cause": repr(self.cause) if self.cause is not None else None,
        }


class TransientError(FFCzError):
    """A failure the same call may not reproduce — retry with backoff."""

    transient = True
    retryable = True
    disposition = "retry"


class HostCodecError(TransientError):
    """Host-side codec (base compressor / entropy coder) raised mid-stream."""


class DeviceDispatchError(TransientError):
    """Device program dispatch / execution failed for a non-OOM reason."""


class ResourceExhausted(FFCzError):
    """Device allocation failure: not retryable as-is, but a *batch* is —
    split it and run the halves (``disposition == "bisect"``)."""

    transient = True
    retryable = False
    disposition = "bisect"


class PermanentError(FFCzError):
    """No retry will change the outcome — reject with reason."""


class InfeasibleBound(PermanentError, ValueError):
    """The requested spatial/frequency bound pair has no representable
    intersection (e.g. E underflows float32 after the quantization shrink).
    A *request* property, not a system fault: structured rejection, never a
    crash escaping the engine."""


class BlobCorruptError(PermanentError, ValueError):
    """Decode-side: truncated, bit-flipped, or foreign blob bytes.  Every
    decode path raises this (never a raw ``zlib.error`` / ``struct.error``)
    so untrusted inputs cannot crash a server with an unclassified
    exception."""

    def __init__(self, message: str, *, stage: str = "decode", cause: Optional[BaseException] = None):
        super().__init__(message, stage=stage, cause=cause)


class StreamStateError(PermanentError):
    """A stream encoder was driven through an illegal lifecycle transition
    (``add_frame`` after ``finish()``, double-``finish()``).  A caller bug,
    not a data fault: the encoder's committed state is left untouched so the
    already-emitted container stays valid."""


class SessionError(PermanentError):
    """Base for live-session failures (unknown/closed session, bad seq)."""

    def __init__(
        self,
        message: str,
        *,
        session_id: Optional[str] = None,
        stage: str = "session",
        cause: Optional[BaseException] = None,
    ):
        super().__init__(message, stage=stage, cause=cause)
        self.session_id = session_id


class SessionNotFound(SessionError):
    """The session id is unknown to this manager — never opened, already
    finalized/aborted, or evicted by lease expiry.  The message says which,
    so a client can distinguish "retry against the finalized container" from
    "open a new session"."""


class SessionSequenceError(SessionError, ValueError):
    """The client-assigned frame sequence number is unusable: a gap (frames
    would be silently skipped), a regression (negative / non-monotonic in a
    way no receipt covers), or a duplicate seq re-sent with *different* frame
    content (an idempotent retry must carry the same payload).  Structured
    reject — the session itself stays open and appendable."""

    def __init__(
        self,
        message: str,
        *,
        session_id: Optional[str] = None,
        expected: Optional[int] = None,
        got: Optional[int] = None,
        cause: Optional[BaseException] = None,
    ):
        super().__init__(message, session_id=session_id, cause=cause)
        self.expected = expected
        self.got = got


class DeadlineExceeded(PermanentError):
    """The request's deadline passed before the work completed."""

    disposition = "timeout"


class PipelineAborted(PermanentError):
    """The pipelined service tore down (or a stage's future was cancelled)
    while this request was in flight.  Terminal for the request — the work
    unit never ran to completion and will not be retried by this service
    instance — but carries no judgement about the request itself."""


_OOM_MARKERS = (
    "RESOURCE_EXHAUSTED",
    "out of memory",
    "Out of memory",
    "Allocation failure",
    "failed to allocate",
)


def is_oom(exc: BaseException) -> bool:
    """Device/host allocation failure, by type or by runtime message."""
    if isinstance(exc, MemoryError):
        return True
    msg = str(exc)
    return any(marker in msg for marker in _OOM_MARKERS)


def classify_exception(exc: BaseException, stage: str) -> FFCzError:
    """Map an arbitrary exception onto the taxonomy.

    Already-classified errors pass through (gaining ``stage`` if unset).
    Allocation failures become :class:`ResourceExhausted` (bisect), OS-level
    errors become :class:`HostCodecError` (retry), runtime/dispatch errors —
    including ``jaxlib``'s ``XlaRuntimeError``, a ``RuntimeError`` subclass —
    become :class:`DeviceDispatchError` (retry), and contract violations
    (``ValueError`` / ``TypeError`` / ``KeyError``) become
    :class:`PermanentError` (reject).  Anything else is conservatively
    permanent: an unknown failure must never spin a retry loop.

    Thread-boundary contract (the pipelined service resolves EXECUTE/ENCODE
    on a worker thread): an :class:`FFCzError` raised inside a
    ``concurrent.futures`` future re-raises *as the same object* in the
    waiting thread, so classification survives the hop — the stage set where
    the error surfaced is preserved, never overwritten.  A cancelled future
    (service teardown mid-flight) classifies as :class:`PipelineAborted`
    rather than escaping as the ``BaseException``-derived ``CancelledError``.
    """
    if isinstance(exc, FFCzError):
        if exc.stage is None:
            exc.stage = stage
        return exc
    msg = f"{type(exc).__name__}: {exc}"
    if isinstance(exc, CancelledError):
        return PipelineAborted(msg, stage=stage, cause=exc)
    if is_oom(exc):
        return ResourceExhausted(msg, stage=stage, cause=exc)
    if isinstance(exc, (OSError, EOFError)):
        return HostCodecError(msg, stage=stage, cause=exc)
    if isinstance(exc, RuntimeError):
        return DeviceDispatchError(msg, stage=stage, cause=exc)
    return PermanentError(msg, stage=stage, cause=exc)
