"""Dual-domain error-bound specification (paper §IV-A, Eq. (2)).

Spatial bound ``E`` applies pointwise to reconstruction errors
``eps_n = x_hat_n - x_n``; frequency bound ``Delta`` applies to the real and
imaginary parts of ``delta_k = FFT(eps)_k`` independently.  Both may be
scalars (global bounds, Eq. (2)) or arrays broadcastable to the data shape
(pointwise bounds ``E_n`` / ``Delta_k`` — footnote 1 and Observation 4).
"""

from __future__ import annotations

import dataclasses
from typing import Union

import jax.numpy as jnp
import numpy as np

from repro.core.errors import InfeasibleBound

ArrayLike = Union[float, np.ndarray, jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class DualBounds:
    """Resolved absolute bounds for one tensor.

    Attributes:
      E:     spatial L-inf bound (scalar or per-point array).
      Delta: frequency bound on |Re(delta_k)| and |Im(delta_k)| (scalar or
             per-component array over the *unnormalized* DFT of the error).
    """

    E: ArrayLike
    Delta: ArrayLike

    def shrink(self, factor_E: float, factor_D: float) -> "DualBounds":
        return DualBounds(E=self.E * factor_E, Delta=self.Delta * factor_D)


def resolve_bounds(
    x: jnp.ndarray,
    *,
    E_abs: ArrayLike | None = None,
    E_rel: float | None = None,
    Delta_abs: ArrayLike | None = None,
    Delta_rel: float | None = None,
    X: jnp.ndarray | None = None,
) -> DualBounds:
    """Resolve user bounds (absolute or relative) to absolute ``DualBounds``.

    Relative spatial bound follows the SZ convention: ``E = E_rel * range(x)``.
    Relative frequency bound follows the paper's evaluation scheme:
    ``Delta = Delta_rel * max_k |X_k|`` where ``X = FFT(x)``.

    A constant field has ``range(x) == 0``, so ``E_rel`` resolves to an
    empty spatial cube — a structured :class:`InfeasibleBound` names that
    cause here instead of letting a cryptic representability error surface
    later in the plan stage.
    """
    if (E_abs is None) == (E_rel is None):
        raise ValueError("exactly one of E_abs / E_rel required")
    if (Delta_abs is None) == (Delta_rel is None):
        raise ValueError("exactly one of Delta_abs / Delta_rel required")
    if E_abs is None:
        rng = jnp.max(x) - jnp.min(x)
        if float(rng) == 0.0:
            raise InfeasibleBound(
                f"E_rel={float(E_rel):g} on a constant field: range(x) == 0 "
                "resolves the spatial bound to E = 0 (an empty s-cube); pass "
                "E_abs for constant fields",
                stage="plan",
            )
        E_abs = E_rel * rng
    if Delta_abs is None:
        if X is None:
            # the rfft half-spectrum suffices: |X_{-k}| = |X_k| for real x,
            # so max_k |X_k| over the half-plane equals the full-plane max
            X = jnp.fft.rfftn(x)
        Delta_abs = Delta_rel * jnp.max(jnp.abs(X))
    return DualBounds(E=E_abs, Delta=Delta_abs)


def power_spectrum_delta(X: jnp.ndarray, rel: float, floor: float = 0.0) -> jnp.ndarray:
    """Per-component ``Delta_k`` guaranteeing a relative power-spectrum bound.

    The paper (Observation 4) preserves the power spectrum by assigning
    pointwise relative error bounds to individual frequency components.  The
    spectrum is computed on MEAN-NORMALIZED fluctuations (paper §III), so the
    guarantee has two parts whose budgets we split:

    1. component term: if ``|delta_k| <= t * |X_k|`` with
       ``t = sqrt(1 + rel/2) - 1`` then
       ``(1-t)^2 <= |X_hat_k|^2 / |X_k|^2 <= (1+t)^2 = 1 + rel/2``.
       Bounding Re/Im by ``Delta_k = t |X_k| / sqrt(2)`` implies it.
    2. normalization term: P(k) is built from (x - mean)/mean, and the DC
       component IS N*mean, so the mean error scales every shell by
       ``(mean/mean_hat)^2``.  Bounding the (real) DC error by
       ``Delta_0 = (rel/8) |X_0|`` keeps that factor within ``1 + rel/2``
       (with margin: (1-rel/8)^-2 <= 1 + rel/2 for rel <= 1).

    Total: ``|P_hat - P| / P <= (1+rel/2)^2 - 1 <= rel`` for rel <= 1 — this
    split is what makes the ribbon hold on fields whose mean the base
    compressor perturbs (measured: without it, the DC term alone overshoots
    a 0.1% ribbon by ~1.6x on the lognormal Nyx analogue).

    ``floor`` (absolute) keeps near-zero components from forcing Delta_k = 0,
    which would demand lossless reconstruction of dead frequencies.
    """
    t = float(np.sqrt(1.0 + rel / 2.0) - 1.0)
    delta = jnp.maximum(t * jnp.abs(X) / np.sqrt(2.0), floor)
    dc_bound = (rel / 8.0) * jnp.abs(X.reshape(-1)[0])
    delta = delta.reshape(-1).at[0].set(jnp.minimum(delta.reshape(-1)[0], dc_bound)).reshape(X.shape)
    return delta


def power_spectrum_delta_rfft(X_half: jnp.ndarray, rel: float, floor: float = 0.0) -> jnp.ndarray:
    """:func:`power_spectrum_delta` on the rfft half-spectrum.

    ``X_half = rfftn(x)`` keeps every independent component of a real
    field's Hermitian-symmetric spectrum, the DC component stays at flat
    index 0, and ``|X|``-derived grids are symmetric — so the pointwise
    ``Delta_k`` grid computed here *is* the half-plane restriction of the
    full-spectrum grid, at half the FFT work and memory.  This is the grid
    the rFFT POCS fast path consumes directly.
    """
    return power_spectrum_delta(X_half, rel, floor=floor)


def resolve_roi_bound_grid(E_roi, E_global: float, shape, scale: float = 0.1) -> np.ndarray:
    """Resolve a spatially varying ROI bound into a per-point ``E_n`` grid.

    ``E_roi`` is either

    * a **boolean mask** — ``True`` marks region-of-interest points, which
      get the tighter bound ``E_global * scale``; ``False`` is background
      (the global ``E``), or
    * a **float grid** of per-point absolute bounds — entries ``> 0`` are
      used directly (clamped to ``min(value, E_global)``: ROI bounds only
      ever *tighten*), entries ``<= 0`` mean background.

    The returned grid is float32 (the exact per-point values the blob
    stores and the s-cube clip consumes), shaped like the field.  Because
    every entry is ``<= E_global``, the scalar header ``E`` remains a valid
    global upper bound for readers that ignore the grid.
    """
    grid = np.asarray(E_roi)
    if grid.shape != tuple(shape):
        raise ValueError(
            f"E_roi shape {grid.shape} must match the field shape {tuple(shape)}"
        )
    if not 0.0 < scale <= 1.0:
        raise ValueError(f"E_roi_scale must be in (0, 1], got {scale}")
    if grid.dtype == np.bool_:
        out = np.where(grid, E_global * scale, E_global)
    else:
        vals = grid.astype(np.float64)
        out = np.where(vals > 0, np.minimum(vals, E_global), E_global)
    return np.asarray(out, dtype=np.float32)
