"""FFCz public codec: base compressor + alternating projection + coded edits.

This is the end-to-end pipeline of the paper (Fig. 4 / Alg. 1):

  compress(x):
    1. base.compress(x, E')           -> base blob (spatially bounded)
    2. eps = base.decompress(...) - x
    3. alternating_projection(eps)    -> spat_edits, freq_edits
    4. encode_edits(...)              -> flags + quantized + Huffman/zlib

  decompress(blob):
    x_hat_base + spat_edits + IRFFT(freq_edits)
    (the "complete spatial edits" of §IV-B)

rFFT fast path: the error vector is real, so the whole frequency side runs
on the Hermitian half-spectrum — the POCS loop (``use_rfft``), the pointwise
``pspec_rel`` Delta grids, the float64 polish, the adaptive quant-bit
cross-leakage accounting (conjugate-pair weighted), and the serialized
``freq_edits`` stream (roughly half the components to flag/quantize/store).
The blob marks half-spectrum streams via ``EncodedEdits.half_spectrum``
(bit 7 of the packed header byte); blobs written by the old full-spectrum
pipeline have the bit clear and decode through the legacy ``ifftn`` branch.

Bound discipline: the projection runs against bounds shrunk by
``(1 - 2^-m - slack)`` so that quantization error (direct term, <= bound*2^-m)
plus the cross-domain leakage of the *other* stream's quantization noise
(second order, absorbed by ``slack``) keeps the final reconstruction inside
the user's cubes.  ``compress`` verifies both bounds post-hoc and reports the
margins in :class:`FFCzStats`.
"""

from __future__ import annotations

import dataclasses
import struct
from typing import Any, Optional

import jax.numpy as jnp
import numpy as np

from repro.coding.quantize import DEFAULT_QUANT_BITS
from repro.core.bounds import power_spectrum_delta_rfft, resolve_bounds
from repro.core.cubes import rfft_pair_weights, rfft_shape
from repro.core.edits import EncodedEdits, decode_edits, encode_edits
from repro.core.pocs import alternating_projection


@dataclasses.dataclass(frozen=True)
class FFCzConfig:
    """User-facing dual-domain bound configuration.

    Exactly one of (E_abs, E_rel) and one of (Delta_abs, Delta_rel,
    pspec_rel) must be set.  ``pspec_rel`` activates the per-component
    power-spectrum-preserving bounds of Observation 4.
    """

    E_abs: Optional[float] = None
    E_rel: Optional[float] = 1e-3
    Delta_abs: Optional[float] = None
    Delta_rel: Optional[float] = 1e-3
    pspec_rel: Optional[float] = None
    # Floor for pointwise Delta_k, relative to max_k Delta_k.  Near-dead
    # frequency components contribute nothing to P(k); flooring their bound
    # keeps the f-cube from becoming needle-thin along dead axes, which is
    # the slow nearly-tangential POCS regime (paper §III).
    pspec_floor_rel: float = 1e-4
    quant_bits: int = DEFAULT_QUANT_BITS
    max_iters: int = 1000
    codec: str = "huffman+zlib"
    use_kernels: bool = False
    verify: bool = True
    # Over-relaxation factor for the POCS loop (1.0 = paper-faithful plain
    # alternating projection; ~1.3 converges orders of magnitude faster in
    # the nearly-tangential regime — see EXPERIMENTS.md §Perf FFCz-iter).
    relax: float = 1.0

    def __post_init__(self):
        if (self.E_abs is None) == (self.E_rel is None):
            raise ValueError("exactly one of E_abs / E_rel required")
        n_freq = sum(x is not None for x in (self.Delta_abs, self.Delta_rel, self.pspec_rel))
        if n_freq != 1:
            raise ValueError("exactly one of Delta_abs / Delta_rel / pspec_rel required")


@dataclasses.dataclass(frozen=True)
class FFCzStats:
    iterations: int
    converged: bool
    n_active_spatial: int
    n_active_frequency: int
    base_bytes: int
    edit_bytes: int
    spatial_margin: float  # min(E - |eps|) over points, >= 0 means bound held
    frequency_margin: float  # min(Delta - max(|Re d|,|Im d|)), >= 0 means held

    @property
    def total_bytes(self) -> int:
        return self.base_bytes + self.edit_bytes


@dataclasses.dataclass(frozen=True)
class FFCzBlob:
    """Serialized FFCz compression result."""

    base_blob: bytes
    spat_edits: EncodedEdits
    # Frequency edit stream.  New blobs store the rfft half-spectrum (its
    # ``half_spectrum`` format flag set); legacy blobs store the full
    # spectrum and decode through the ifftn branch of ``FFCz.decompress``.
    freq_edits: EncodedEdits
    E: float
    Delta_scalar: float  # scalar Delta, or nan when pointwise (stored in blob)
    # float32 Delta_k grid bytes, or None; half-spectrum layout iff
    # ``freq_edits.half_spectrum`` (legacy blobs stored the full grid)
    pointwise_delta: Optional[bytes]
    shape: tuple
    stats: Optional[FFCzStats] = None

    def to_bytes(self) -> bytes:
        se = self.spat_edits.to_bytes()
        fe = self.freq_edits.to_bytes()
        pw = self.pointwise_delta or b""
        header = struct.pack(
            "<ddBQQQQ",
            self.E,
            self.Delta_scalar,
            len(self.shape),
            len(self.base_blob),
            len(se),
            len(fe),
            len(pw),
        )
        header += struct.pack(f"<{len(self.shape)}Q", *self.shape)
        return header + self.base_blob + se + fe + pw

    @staticmethod
    def from_bytes(data: bytes) -> "FFCzBlob":
        E, Delta, ndim, nb, ns, nf, npw = struct.unpack_from("<ddBQQQQ", data, 0)
        off = struct.calcsize("<ddBQQQQ")
        shape = struct.unpack_from(f"<{ndim}Q", data, off)
        off += 8 * ndim
        base = data[off : off + nb]
        off += nb
        se = EncodedEdits.from_bytes(data[off : off + ns])
        off += ns
        fe = EncodedEdits.from_bytes(data[off : off + nf])
        off += nf
        pw = data[off : off + npw] if npw else None
        return FFCzBlob(
            base_blob=base,
            spat_edits=se,
            freq_edits=fe,
            E=E,
            Delta_scalar=Delta,
            pointwise_delta=pw,
            shape=tuple(shape),
        )

    def nbytes(self) -> int:
        return len(self.to_bytes())


def _irfftn(a: np.ndarray, shape) -> np.ndarray:
    """numpy irfftn with explicit axes (required for odd last-axis sizes)."""
    return np.fft.irfftn(a, s=shape, axes=tuple(range(len(shape))))


def polish_pocs_float64(eps, spat, freq, E, Delta, axes=None, max_iters: int = 30):
    """Exact (float64) POCS iterations to absorb float32 FFT round-off.

    Runs on the rfft half-spectrum over ``axes`` (default: all axes —
    whole-field polish; the blockwise checkpoint codec passes the pencil
    axis), with ``freq`` the matching half-spectrum accumulator.  Residual
    violations after the float32 loop are O(eps32 * ||delta||_inf), orders
    of magnitude below the bounds, so this converges in a handful of
    iterations and contributes negligibly to the edit payload.
    """
    axes = tuple(range(eps.ndim)) if axes is None else tuple(axes)
    s = [eps.shape[a] for a in axes]
    for _ in range(max_iters):
        delta = np.fft.rfftn(eps, axes=axes)
        re = np.clip(delta.real, -Delta, Delta)
        im = np.clip(delta.imag, -Delta, Delta)
        clipped = re + 1j * im
        if np.array_equal(clipped, delta):
            break
        freq = freq + (clipped - delta)
        eps_f = np.fft.irfftn(clipped, s=s, axes=axes)
        eps_s = np.clip(eps_f, -E, E)
        spat = spat + (eps_s - eps_f)
        eps = eps_s
    return eps, spat, freq


def float32_bound_discipline(E, Delta, m: int, l2_norm: float, abs_max: float):
    """Shrink user bounds for quantization + float32-storage round-off.

    Reserves 2x the direct quantization term (one for the stream's own
    noise, one for the other stream's cross-domain leakage — matched by
    :func:`adaptive_quant_bits`), subtracts the absolute float32 slack
    (casting the reconstruction perturbs each frequency component by
    ~u32*l2_norm, 4-sigma statistical budget, and each point by
    u32*abs_max), and clamps Delta at 4x the frequency slack so the bound
    stays representable.  ``Delta`` may be a scalar or a pointwise grid.
    Shared by the whole-field pipeline (``FFCz.compress``) and the
    blockwise checkpoint codec (per-pencil norms), so the guarantee math
    lives in one place.

    Returns ``(E_proj, Delta_proj, Delta_floored, slack_f)``.
    """
    u32 = float(np.finfo(np.float32).eps)
    shrink = 1.0 - 2.0 ** (-m) - 2.0 ** (-m)
    slack_f = 4.0 * u32 * float(l2_norm)
    slack_s = u32 * float(abs_max)
    Delta = np.maximum(Delta, 4.0 * slack_f)
    return E * shrink - slack_s, Delta * shrink - slack_f, Delta, slack_f


def adaptive_quant_bits(m: int, k_s: int, E: float, min_delta: float, sum_w_delta: float, n: int, cap: int = 48):
    """Closed-form edit-stream bit-widths covering cross-domain quant leakage.

    The base width ``m`` covers each stream's *direct* quantization term;
    the widened widths also fit the cross terms inside the same reserved
    margin: ``k_s`` quantized spatial edits perturb every frequency
    component by up to ``k_s * E * 2^-m_s`` after the FFT (kept under
    ``min_delta * 2^-m``), and the active frequency edits — ``sum_w_delta``
    being their conjugate-pair-weighted Delta sum — perturb every spatial
    point by up to ``(sqrt2/n) * sum_w_delta * 2^-m_f`` after the IFFT
    (kept under ``E * 2^-m``).  Shared by the whole-field pipeline
    (``FFCz.compress``) and the blockwise checkpoint codec (per worst-case
    pencil), so the guarantee math lives in one place.
    """
    m_s = m
    if k_s > 0 and min_delta > 0 and E > 0:
        m_s = m + max(0, int(np.ceil(np.log2(max(k_s * E / min_delta, 1.0)))))
    m_f = m
    if sum_w_delta > 0 and E > 0 and n > 0:
        ratio = np.sqrt(2.0) * sum_w_delta / (n * E)
        m_f = m + max(0, int(np.ceil(np.log2(max(ratio, 1.0)))))
    return min(m_s, cap), min(m_f, cap)


class FFCz:
    """Spectrum-preserving codec wrapping an arbitrary base compressor.

    ``base`` must expose ``compress(x, E) -> bytes`` and
    ``decompress(blob) -> np.ndarray`` with a pointwise L-inf guarantee.
    """

    def __init__(self, base: Any, config: FFCzConfig = FFCzConfig()):
        self.base = base
        self.config = config

    # -- compression ------------------------------------------------------

    def compress(self, x: np.ndarray) -> FFCzBlob:
        cfg = self.config
        x = np.asarray(x, dtype=np.float32)
        # Hermitian fast path: all frequency-side work (bounds, POCS, polish,
        # edit stream) happens on the rfft half-spectrum
        X = np.fft.rfftn(x)

        # Resolve user bounds, then apply the shared float32 bound discipline
        # (quantization shrink + storage slack + representability Delta
        # floor — see :func:`float32_bound_discipline`; the 4-sigma
        # statistical slack was chosen over the deterministic u*||x||_1
        # bound, which is ~50x more conservative and was measured to
        # dominate weak shells' power-spectrum ribbon).
        if cfg.pspec_rel is not None:
            Delta_user = np.asarray(power_spectrum_delta_rfft(jnp.asarray(X), cfg.pspec_rel), dtype=np.float32)
            floor = float(Delta_user.max()) * cfg.pspec_floor_rel if Delta_user.max() > 0 else 1.0
            Delta_user = np.maximum(Delta_user, floor)
            bounds = resolve_bounds(jnp.asarray(x), E_abs=cfg.E_abs, E_rel=cfg.E_rel, Delta_abs=1.0)
        else:
            bounds = resolve_bounds(
                jnp.asarray(x),
                E_abs=cfg.E_abs,
                E_rel=cfg.E_rel,
                Delta_abs=cfg.Delta_abs,
                Delta_rel=cfg.Delta_rel,
                X=jnp.asarray(X),
            )
            Delta_user = float(bounds.Delta)
        E = float(bounds.E)
        E_proj, Delta_proj, Delta, slack_f = float32_bound_discipline(
            E,
            Delta_user,
            cfg.quant_bits,
            np.linalg.norm(x.ravel()),
            np.max(np.abs(x)) if x.size else 0.0,
        )
        if cfg.pspec_rel is not None:
            delta_scalar = float("nan")
            pointwise = Delta.astype(np.float32).tobytes()
        else:
            Delta = float(Delta)
            delta_scalar = Delta
            pointwise = None
        if E_proj <= 0:
            raise ValueError(f"spatial bound E={E:g} below float32 representability for this data")

        base_blob = self.base.compress(x, E_proj)
        x_hat = np.asarray(self.base.decompress(base_blob), dtype=np.float32)
        eps0 = x_hat - x

        res = alternating_projection(
            jnp.asarray(eps0),
            E_proj,
            jnp.asarray(Delta_proj),
            max_iters=cfg.max_iters,
            use_kernels=cfg.use_kernels,
            relax=cfg.relax,
            check_slack=0.5 * slack_f,
        )
        spat = np.asarray(res.spat_edits, dtype=np.float64)
        freq = np.asarray(res.freq_edits, dtype=np.complex128)

        # Float64 polish: the jitted POCS runs in float32 (the TPU perf
        # path, as the paper runs FP32 on A100); its convergence check is
        # therefore only float32-exact.  A few exact host-side POCS
        # iterations absorb the FFT round-off so the *shrunk* bounds hold in
        # float64, leaving the full quantization margin intact.
        eps_f = np.asarray(res.eps, dtype=np.float64)
        eps_f, spat, freq = polish_pocs_float64(
            eps_f, spat, freq, E_proj, np.asarray(Delta_proj, dtype=np.float64)
        )

        # Adaptive quantization bit-widths (beyond-paper refinement; the paper
        # fixes m = 16 which covers only the direct term): K_s and the active
        # weighted Delta sum are known exactly post-projection, so the widths
        # come from the closed form in :func:`adaptive_quant_bits`.  The
        # Delta sum runs over the *full* spectrum, so each active
        # half-spectrum edit contributes with its conjugate-pair multiplicity.
        k_s = int(np.count_nonzero(spat))
        pair_w = np.broadcast_to(np.asarray(rfft_pair_weights(x.shape)), freq.shape)
        delta_b = np.broadcast_to(np.asarray(Delta), freq.shape)
        sum_active_delta = float(np.sum((pair_w * delta_b)[freq != 0]))
        m_s, m_f = adaptive_quant_bits(
            cfg.quant_bits, k_s, E, float(np.min(Delta)), sum_active_delta, x.size
        )

        se = encode_edits(spat, E, m=m_s, codec=cfg.codec)
        fe = encode_edits(freq, Delta, m=m_f, codec=cfg.codec, half_spectrum=True)

        blob = FFCzBlob(
            base_blob=base_blob,
            spat_edits=se,
            freq_edits=fe,
            E=E,
            Delta_scalar=delta_scalar,
            pointwise_delta=pointwise,
            shape=x.shape,
        )

        stats = None
        if cfg.verify:
            x_final = self.decompress(blob)
            eps = x_final.astype(np.float64) - x.astype(np.float64)
            # half-spectrum check is exhaustive: every full-spectrum component
            # shares |Re|/|Im| (and its Delta_k) with its conjugate image here
            d = np.fft.rfftn(eps)
            spatial_margin = float(E - np.max(np.abs(eps)))
            freq_excess = np.maximum(np.abs(d.real), np.abs(d.imag)) - np.asarray(Delta)
            frequency_margin = float(-np.max(freq_excess))
            stats = FFCzStats(
                iterations=int(res.iterations),
                converged=bool(res.converged),
                n_active_spatial=se.n_active,
                n_active_frequency=fe.n_active,
                base_bytes=len(base_blob),
                edit_bytes=se.nbytes() + fe.nbytes(),
                spatial_margin=spatial_margin,
                frequency_margin=frequency_margin,
            )
        return dataclasses.replace(blob, stats=stats)

    # -- decompression ----------------------------------------------------

    def decompress(self, blob: FFCzBlob) -> np.ndarray:
        x_hat = np.asarray(self.base.decompress(blob.base_blob), dtype=np.float32)
        half = blob.freq_edits.half_spectrum
        if blob.pointwise_delta is not None:
            # pointwise Delta_k grid, stored in the blob (Observation 4 mode);
            # half-spectrum layout in rfft-era blobs, full grid in legacy ones
            dshape = rfft_shape(blob.shape) if half else blob.shape
            Delta = np.frombuffer(blob.pointwise_delta, dtype=np.float32).reshape(dshape)
        else:
            Delta = blob.Delta_scalar
        spat = decode_edits(blob.spat_edits, blob.E)
        freq = decode_edits(blob.freq_edits, Delta)
        if half:
            freq_spatial = _irfftn(freq, blob.shape)
        else:
            # legacy full-spectrum blob (pre-rfft format flag)
            freq_spatial = np.fft.ifftn(freq).real
        complete = spat + freq_spatial  # complete spatial edits (§IV-B)
        return (x_hat.astype(np.float64) + complete).astype(np.float32)

    def roundtrip(self, x: np.ndarray):
        blob = self.compress(x)
        return self.decompress(blob), blob


