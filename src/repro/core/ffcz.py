"""FFCz public codec: base compressor + alternating projection + coded edits.

This is the end-to-end pipeline of the paper (Fig. 4 / Alg. 1):

  compress(x):
    1. base.compress(x, E')           -> base blob (spatially bounded)
    2. eps = base.decompress(...) - x
    3. alternating_projection(eps)    -> spat_edits, freq_edits
    4. encode_edits(...)              -> flags + quantized + Huffman/zlib

  decompress(blob):
    x_hat_base + spat_edits + IFFT(freq_edits).real
    (the "complete spatial edits" of §IV-B)

Bound discipline: the projection runs against bounds shrunk by
``(1 - 2^-m - slack)`` so that quantization error (direct term, <= bound*2^-m)
plus the cross-domain leakage of the *other* stream's quantization noise
(second order, absorbed by ``slack``) keeps the final reconstruction inside
the user's cubes.  ``compress`` verifies both bounds post-hoc and reports the
margins in :class:`FFCzStats`.
"""

from __future__ import annotations

import dataclasses
import struct
from typing import Any, Optional

import jax.numpy as jnp
import numpy as np

from repro.coding.quantize import DEFAULT_QUANT_BITS
from repro.core.bounds import power_spectrum_delta, resolve_bounds
from repro.core.edits import EncodedEdits, decode_edits, encode_edits
from repro.core.pocs import alternating_projection


@dataclasses.dataclass(frozen=True)
class FFCzConfig:
    """User-facing dual-domain bound configuration.

    Exactly one of (E_abs, E_rel) and one of (Delta_abs, Delta_rel,
    pspec_rel) must be set.  ``pspec_rel`` activates the per-component
    power-spectrum-preserving bounds of Observation 4.
    """

    E_abs: Optional[float] = None
    E_rel: Optional[float] = 1e-3
    Delta_abs: Optional[float] = None
    Delta_rel: Optional[float] = 1e-3
    pspec_rel: Optional[float] = None
    # Floor for pointwise Delta_k, relative to max_k Delta_k.  Near-dead
    # frequency components contribute nothing to P(k); flooring their bound
    # keeps the f-cube from becoming needle-thin along dead axes, which is
    # the slow nearly-tangential POCS regime (paper §III).
    pspec_floor_rel: float = 1e-4
    quant_bits: int = DEFAULT_QUANT_BITS
    max_iters: int = 1000
    codec: str = "huffman+zlib"
    use_kernels: bool = False
    verify: bool = True
    # Over-relaxation factor for the POCS loop (1.0 = paper-faithful plain
    # alternating projection; ~1.3 converges orders of magnitude faster in
    # the nearly-tangential regime — see EXPERIMENTS.md §Perf FFCz-iter).
    relax: float = 1.0

    def __post_init__(self):
        if (self.E_abs is None) == (self.E_rel is None):
            raise ValueError("exactly one of E_abs / E_rel required")
        n_freq = sum(x is not None for x in (self.Delta_abs, self.Delta_rel, self.pspec_rel))
        if n_freq != 1:
            raise ValueError("exactly one of Delta_abs / Delta_rel / pspec_rel required")


@dataclasses.dataclass(frozen=True)
class FFCzStats:
    iterations: int
    converged: bool
    n_active_spatial: int
    n_active_frequency: int
    base_bytes: int
    edit_bytes: int
    spatial_margin: float  # min(E - |eps|) over points, >= 0 means bound held
    frequency_margin: float  # min(Delta - max(|Re d|,|Im d|)), >= 0 means held

    @property
    def total_bytes(self) -> int:
        return self.base_bytes + self.edit_bytes


@dataclasses.dataclass(frozen=True)
class FFCzBlob:
    """Serialized FFCz compression result."""

    base_blob: bytes
    spat_edits: EncodedEdits
    freq_edits: EncodedEdits
    E: float
    Delta_scalar: float  # scalar Delta, or nan when pointwise (stored in blob)
    pointwise_delta: Optional[bytes]  # float32 Delta_k array bytes, or None
    shape: tuple
    stats: Optional[FFCzStats] = None

    def to_bytes(self) -> bytes:
        se = self.spat_edits.to_bytes()
        fe = self.freq_edits.to_bytes()
        pw = self.pointwise_delta or b""
        header = struct.pack(
            "<ddBQQQQ",
            self.E,
            self.Delta_scalar,
            len(self.shape),
            len(self.base_blob),
            len(se),
            len(fe),
            len(pw),
        )
        header += struct.pack(f"<{len(self.shape)}Q", *self.shape)
        return header + self.base_blob + se + fe + pw

    @staticmethod
    def from_bytes(data: bytes) -> "FFCzBlob":
        E, Delta, ndim, nb, ns, nf, npw = struct.unpack_from("<ddBQQQQ", data, 0)
        off = struct.calcsize("<ddBQQQQ")
        shape = struct.unpack_from(f"<{ndim}Q", data, off)
        off += 8 * ndim
        base = data[off : off + nb]
        off += nb
        se = EncodedEdits.from_bytes(data[off : off + ns])
        off += ns
        fe = EncodedEdits.from_bytes(data[off : off + nf])
        off += nf
        pw = data[off : off + npw] if npw else None
        return FFCzBlob(
            base_blob=base,
            spat_edits=se,
            freq_edits=fe,
            E=E,
            Delta_scalar=Delta,
            pointwise_delta=pw,
            shape=tuple(shape),
        )

    def nbytes(self) -> int:
        return len(self.to_bytes())


def _polish_float64(eps, spat, freq, E, Delta, max_iters: int = 30):
    """Exact (float64) POCS iterations to absorb float32 FFT round-off.

    Residual violations after the float32 loop are O(eps32 * ||delta||_inf),
    orders of magnitude below the bounds, so this converges in a handful of
    iterations and contributes negligibly to the edit payload.
    """
    for _ in range(max_iters):
        delta = np.fft.fftn(eps)
        re = np.clip(delta.real, -Delta, Delta)
        im = np.clip(delta.imag, -Delta, Delta)
        clipped = re + 1j * im
        if np.array_equal(clipped, delta):
            break
        freq = freq + (clipped - delta)
        eps_f = np.fft.ifftn(clipped).real
        eps_s = np.clip(eps_f, -E, E)
        spat = spat + (eps_s - eps_f)
        eps = eps_s
    return eps, spat, freq


class FFCz:
    """Spectrum-preserving codec wrapping an arbitrary base compressor.

    ``base`` must expose ``compress(x, E) -> bytes`` and
    ``decompress(blob) -> np.ndarray`` with a pointwise L-inf guarantee.
    """

    def __init__(self, base: Any, config: FFCzConfig = FFCzConfig()):
        self.base = base
        self.config = config

    # -- compression ------------------------------------------------------

    def compress(self, x: np.ndarray) -> FFCzBlob:
        cfg = self.config
        x = np.asarray(x, dtype=np.float32)
        X = np.fft.fftn(x)

        # Representability floor: the reconstruction is stored in the data's
        # own precision (float32).  Per-point rounding noise is iid in
        # (-u|x|, u|x|), so each frequency component of the noise has std
        # <= u*||x||_2/sqrt(2); we budget 4 sigma as the absolute slack and
        # clamp Delta at 4x that (the deterministic u*||x||_1 bound is ~50x
        # more conservative and was measured to dominate weak shells'
        # power-spectrum ribbon).  The float64 post-hoc verification remains
        # the hard backstop on every compress.
        u32 = float(np.finfo(np.float32).eps)
        slack_stat = 4.0 * u32 * float(np.linalg.norm(x.ravel()))
        repr_floor = 4.0 * slack_stat

        if cfg.pspec_rel is not None:
            Delta = np.asarray(power_spectrum_delta(jnp.asarray(X), cfg.pspec_rel), dtype=np.float32)
            floor = float(Delta.max()) * cfg.pspec_floor_rel if Delta.max() > 0 else 1.0
            Delta = np.maximum(Delta, max(floor, repr_floor))
            bounds = resolve_bounds(jnp.asarray(x), E_abs=cfg.E_abs, E_rel=cfg.E_rel, Delta_abs=1.0)
            E = float(bounds.E)
            delta_scalar = float("nan")
            pointwise = Delta.astype(np.float32).tobytes()
        else:
            bounds = resolve_bounds(
                jnp.asarray(x),
                E_abs=cfg.E_abs,
                E_rel=cfg.E_rel,
                Delta_abs=cfg.Delta_abs,
                Delta_rel=cfg.Delta_rel,
                X=jnp.asarray(X),
            )
            E = float(bounds.E)
            Delta = max(float(bounds.Delta), repr_floor)
            delta_scalar = Delta
            pointwise = None

        # Shrink bounds: relative 2*2^-m for quantization (direct + cross-domain
        # leakage, matched by the adaptive bit-widths below), plus the
        # *absolute* float32-storage slack: casting the final reconstruction
        # to float32 perturbs each point by <= u*|x|, i.e. each frequency
        # component by <= u*||x||_1 and each spatial point by <= u*max|x|.
        shrink = 1.0 - 2.0 ** (-cfg.quant_bits) - 2.0 ** (-cfg.quant_bits)
        slack_f = slack_stat
        slack_s = u32 * float(np.max(np.abs(x))) if x.size else 0.0
        E_proj = E * shrink - slack_s
        Delta_proj = Delta * shrink - slack_f
        if E_proj <= 0:
            raise ValueError(f"spatial bound E={E:g} below float32 representability for this data")

        base_blob = self.base.compress(x, E_proj)
        x_hat = np.asarray(self.base.decompress(base_blob), dtype=np.float32)
        eps0 = x_hat - x

        res = alternating_projection(
            jnp.asarray(eps0),
            E_proj,
            jnp.asarray(Delta_proj),
            max_iters=cfg.max_iters,
            use_kernels=cfg.use_kernels,
            relax=cfg.relax,
            check_slack=0.5 * slack_f,
        )
        spat = np.asarray(res.spat_edits, dtype=np.float64)
        freq = np.asarray(res.freq_edits, dtype=np.complex128)

        # Float64 polish: the jitted POCS runs in float32 (the TPU perf
        # path, as the paper runs FP32 on A100); its convergence check is
        # therefore only float32-exact.  A few exact host-side POCS
        # iterations absorb the FFT round-off so the *shrunk* bounds hold in
        # float64, leaving the full quantization margin intact.
        eps_f = np.asarray(res.eps, dtype=np.float64)
        eps_f, spat, freq = _polish_float64(eps_f, spat, freq, E_proj, np.asarray(Delta_proj, dtype=np.float64))

        # Adaptive quantization bit-widths.  The paper fixes m = 16 and shrinks
        # each bound by (1 - 2^-m), which covers the *direct* quantization
        # term.  Quantization noise also leaks across domains: K_s quantized
        # spatial edits perturb every frequency component by up to
        # K_s * E * 2^-m_s after the FFT, and the active frequency edits
        # perturb every spatial point by up to (sqrt2/N) * sum(Delta_k) * 2^-m_f
        # after the IFFT.  We widen each stream's m (beyond-paper refinement)
        # so both the direct and the cross term fit inside the doubled shrink
        # margin reserved above; K_s/K_f are known exactly post-projection, so
        # this is a closed-form choice, not a search.
        n_total = x.size
        min_delta = float(np.min(Delta))
        k_s = int(np.count_nonzero(spat))
        sum_active_delta = float(np.sum(np.broadcast_to(np.asarray(Delta), freq.shape)[freq != 0]))
        m_s = cfg.quant_bits
        if k_s > 0 and min_delta > 0 and E > 0:
            m_s = max(m_s, cfg.quant_bits + int(np.ceil(np.log2(max(k_s * E / min_delta, 1.0)))))
        m_f = cfg.quant_bits
        if sum_active_delta > 0 and E > 0:
            ratio = np.sqrt(2.0) * sum_active_delta / (n_total * E)
            m_f = max(m_f, cfg.quant_bits + int(np.ceil(np.log2(max(ratio, 1.0)))))
        m_s, m_f = min(m_s, 48), min(m_f, 48)

        se = encode_edits(spat, E, m=m_s, codec=cfg.codec)
        fe = encode_edits(freq, Delta, m=m_f, codec=cfg.codec)

        blob = FFCzBlob(
            base_blob=base_blob,
            spat_edits=se,
            freq_edits=fe,
            E=E,
            Delta_scalar=delta_scalar,
            pointwise_delta=pointwise,
            shape=x.shape,
        )

        stats = None
        if cfg.verify:
            x_final = self.decompress(blob)
            eps = x_final.astype(np.float64) - x.astype(np.float64)
            d = np.fft.fftn(eps)
            spatial_margin = float(E - np.max(np.abs(eps)))
            freq_excess = np.maximum(np.abs(d.real), np.abs(d.imag)) - np.asarray(Delta)
            frequency_margin = float(-np.max(freq_excess))
            stats = FFCzStats(
                iterations=int(res.iterations),
                converged=bool(res.converged),
                n_active_spatial=se.n_active,
                n_active_frequency=fe.n_active,
                base_bytes=len(base_blob),
                edit_bytes=se.nbytes() + fe.nbytes(),
                spatial_margin=spatial_margin,
                frequency_margin=frequency_margin,
            )
        return dataclasses.replace(blob, stats=stats)

    # -- decompression ----------------------------------------------------

    def decompress(self, blob: FFCzBlob) -> np.ndarray:
        x_hat = np.asarray(self.base.decompress(blob.base_blob), dtype=np.float32)
        if blob.pointwise_delta is not None:
            # pointwise Delta_k grid, stored in the blob (Observation 4 mode)
            Delta = np.frombuffer(blob.pointwise_delta, dtype=np.float32).reshape(blob.shape)
        else:
            Delta = blob.Delta_scalar
        spat = decode_edits(blob.spat_edits, blob.E)
        freq = decode_edits(blob.freq_edits, Delta)
        complete = spat + np.fft.ifftn(freq).real  # complete spatial edits (§IV-B)
        return (x_hat.astype(np.float64) + complete).astype(np.float32)

    def roundtrip(self, x: np.ndarray):
        blob = self.compress(x)
        return self.decompress(blob), blob


