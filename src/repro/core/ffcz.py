"""FFCz public codec: a thin plan/execute/encode client of the CorrectionEngine.

This is the end-to-end pipeline of the paper (Fig. 4 / Alg. 1), expressed as
the three engine stages of :class:`repro.core.engine.CorrectionEngine`:

  compress(x):
    1. PLAN     engine.plan_field(x, cfg)   -> bounds resolved on device,
                float32/quantization discipline applied, pointwise Delta_k
                grids built from a device rfft (and only when a bound
                actually consumes the spectrum — Delta_abs skips the
                forward FFT entirely)
    2.          base.compress(x, E_proj)    -> base blob (spatially bounded)
    3. EXECUTE  engine.execute_field(x_hat - x, plan)
                -> one jitted device POCS program (Hermitian rfft
                half-spectrum loop) + exact float64 polish
    4. ENCODE   engine.encode_field(result, plan)
                -> pair-weighted adaptive bit-widths, flags + quantized +
                Huffman/zlib edit streams
    5.          byte assembly (FFCzBlob)

  decompress(blob):
    x_hat_base + spat_edits + IRFFT(freq_edits)
    (the "complete spatial edits" of §IV-B)

The class owns only what is irreducibly codec-shaped: base-compressor I/O,
post-hoc verification, and the wire format.  All bound discipline,
projection, pair-weight and bit-width math lives in the engine, shared with
the pencil-tiled checkpoint/KV/gradient paths.

Wire format: blobs carry a ``FFCZ`` magic + version byte (version 1) and
length-validated section table; version-0 (magic-less) blobs from older
writers are sniffed and still decode, including legacy full-spectrum
frequency streams (``EncodedEdits.half_spectrum`` clear) via the ``ifftn``
branch of :meth:`FFCz.decompress`.
"""

from __future__ import annotations

import dataclasses
import struct
from typing import Any, Optional

import numpy as np

from repro.coding.quantize import DEFAULT_QUANT_BITS
from repro.core.cubes import rfft_shape
from repro.core.edits import EncodedEdits, decode_edits
from repro.core.errors import BlobCorruptError, FFCzError
from repro.core.engine import (  # re-exported for backward compatibility
    CorrectionEngine,
    adaptive_quant_bits,
    default_engine,
    float32_bound_discipline,
    polish_pocs_float64,
)
from repro.sharding.dist_fft import ShardedField

__all__ = [
    "BlobCorruptError",
    "FFCz",
    "FFCzBlob",
    "FFCzConfig",
    "FFCzStats",
    "PadMeta",
    "ShardedField",
    "adaptive_quant_bits",
    "float32_bound_discipline",
    "polish_pocs_float64",
]


@dataclasses.dataclass(frozen=True)
class FFCzConfig:
    """User-facing dual-domain bound configuration.

    Exactly one of (E_abs, E_rel) and one of (Delta_abs, Delta_rel,
    pspec_rel) must be set.  ``pspec_rel`` activates the per-component
    power-spectrum-preserving bounds of Observation 4.
    """

    E_abs: Optional[float] = None
    E_rel: Optional[float] = 1e-3
    Delta_abs: Optional[float] = None
    Delta_rel: Optional[float] = 1e-3
    pspec_rel: Optional[float] = None
    # ROI bounds (region-aware spatial guarantees): a boolean mask (True =
    # region of interest, bound tightened to E * E_roi_scale) or a float
    # grid of per-point absolute bounds (entries <= 0 mean background E),
    # field-shaped.  See repro.core.bounds.resolve_roi_bound_grid.  The
    # resolved float32 E_n grid rides the blob in an optional FFCR tail
    # section; None (default) keeps uniform-E blobs byte-identical to
    # earlier writers.
    E_roi: Optional[Any] = None
    E_roi_scale: float = 0.1
    # Floor for pointwise Delta_k, relative to max_k Delta_k.  Near-dead
    # frequency components contribute nothing to P(k); flooring their bound
    # keeps the f-cube from becoming needle-thin along dead axes, which is
    # the slow nearly-tangential POCS regime (paper §III).
    pspec_floor_rel: float = 1e-4
    quant_bits: int = DEFAULT_QUANT_BITS
    max_iters: int = 1000
    codec: str = "huffman+zlib"
    use_kernels: bool = False
    verify: bool = True
    # Over-relaxation factor for the POCS loop (1.0 = paper-faithful plain
    # alternating projection; ~1.3 converges orders of magnitude faster in
    # the nearly-tangential regime — see EXPERIMENTS.md §Perf FFCz-iter).
    relax: float = 1.0
    # POCS loop transform selector: "xla" (default; blobs byte-identical to
    # earlier writers), "packed" (pack-trick C2R inverse — the measured CPU
    # fast path), or "pallas" (packed + fused clip/count epilogue kernels).
    # See repro.core.pocs / repro.kernels.rfft.  Non-"xla" impls are
    # "bound"-parity: sharded blobs may diverge from single-device ones at
    # float32-rounding level while the dual-bound guarantee holds.
    fft_impl: str = "xla"
    # Run the POCS convergence-check reduction every K-th iteration (the
    # final iteration always checks).  Extra iterations are always safe, so
    # K > 1 trades up-to-K-1 late convergence for one reduction (and one
    # psum, in distributed mode) per skipped iteration.
    check_every: int = 1
    # Temporal warm start (see repro.core.temporal / docs/streaming.md):
    # when True, execute_field seeds the POCS loop's freq_edits state from a
    # caller-supplied previous-frame spectrum.  False (default) ignores any
    # warm state — the bitwise-identical cold start, so non-stream callers
    # and disabled streams produce byte-identical blobs.
    warm_start: bool = False
    # Append a per-section CRC32 tail (``FFCC`` marker) to written blobs so
    # bit flips that structural validation cannot see are caught at decode.
    # Off by default: the tail changes the blob bytes, and the default path
    # stays byte-identical to earlier writers.  Decoding verifies the tail
    # whenever one is present, regardless of this flag.
    crc: bool = False
    # Derived-quantity verify-after-polish (pspec mode only): recheck in
    # float64 that every live shell's power-spectrum ratio satisfies
    # |P_hat(k)/P(k) - 1| <= pspec_rel on the decoded field, surfaced as
    # FFCzStats.pspec_shell_err / pspec_shell_ok.  Opt-in: it costs two
    # full-field float64 FFTs on the host.
    verify_pspec: bool = False

    def __post_init__(self):
        if (self.E_abs is None) == (self.E_rel is None):
            raise ValueError("exactly one of E_abs / E_rel required")
        n_freq = sum(x is not None for x in (self.Delta_abs, self.Delta_rel, self.pspec_rel))
        if n_freq != 1:
            raise ValueError("exactly one of Delta_abs / Delta_rel / pspec_rel required")
        if self.fft_impl not in ("xla", "packed", "pallas"):
            raise ValueError(
                f"fft_impl must be 'xla', 'packed' or 'pallas', got {self.fft_impl!r}"
            )
        if self.check_every < 1:
            raise ValueError(f"check_every must be >= 1, got {self.check_every}")
        if not 0.0 < self.E_roi_scale <= 1.0:
            raise ValueError(f"E_roi_scale must be in (0, 1], got {self.E_roi_scale}")


@dataclasses.dataclass(frozen=True)
class FFCzStats:
    iterations: int
    converged: bool
    n_active_spatial: int
    n_active_frequency: int
    base_bytes: int
    edit_bytes: int
    spatial_margin: float  # min(E - |eps|) over points, >= 0 means bound held
    frequency_margin: float  # min(Delta - max(|Re d|,|Im d|)), >= 0 means held
    # Pair-weighted count of frequency components still outside the shrunk
    # f-cube after the float64 polish; 0 whenever ``converged``.  Non-zero
    # means the POCS budget ran out: the spatial bound still holds, the
    # frequency bound is violated at exactly this many components.
    final_violations: int = 0
    # Derived-quantity shell recheck (cfg.verify_pspec, pspec mode only):
    # max over live shells of |P_hat(k)/P(k) - 1| measured in float64 on the
    # decoded field, and whether it sits within the claimed pspec_rel.
    # None when the recheck did not run.
    pspec_shell_err: Optional[float] = None
    pspec_shell_ok: Optional[bool] = None

    @property
    def total_bytes(self) -> int:
        return self.base_bytes + self.edit_bytes


_MAGIC = b"FFCZ"
_WIRE_VERSION = 1
_V0_HEADER = "<ddBQQQQ"  # E, Delta_scalar, ndim, len(base), len(se), len(fe), len(pw)
_PAD_MAGIC = b"FFCP"
_PAD_HEADER = "<IB"  # n_dev (u32), ndim (u8); then ndim * u64 padded shape
# Optional ROI spatial-bound section (sniffed like FFCP): u64 byte count,
# then the float32 per-point E_n grid in field shape/order.
_ROI_MAGIC = b"FFCR"
# Optional integrity tail (sniffed like FFCP): u8 count, then count * u32
# CRC32s — whole-blob-so-far, base, spat_edits, freq_edits, pointwise.
_CRC_MAGIC = b"FFCC"
_CRC_SECTIONS = ("header", "base", "spat_edits", "freq_edits", "pointwise")


@dataclasses.dataclass(frozen=True)
class PadMeta:
    """Slab-decomposition provenance of a blob written from an uneven
    :class:`~repro.sharding.dist_fft.ShardedField`.

    Purely informational: the edit streams are always encoded at the true
    field extents, so decoding never needs this — it records how the writer
    padded and sharded the field (mesh axis size + padded device shape) for
    tooling and re-scatter hints.  Serialized as an OPTIONAL trailing blob
    section introduced by a ``FFCP`` marker, sniffed by its presence exactly
    like the v0 magic sniff — older v1 blobs (no section) and v0 blobs
    parse unchanged, and this section's absence keeps evenly-decomposed and
    single-device blobs byte-identical to pre-pad writers.
    """

    n_dev: int
    padded_shape: tuple

    def to_bytes(self) -> bytes:
        return (
            _PAD_MAGIC
            + struct.pack(_PAD_HEADER, self.n_dev, len(self.padded_shape))
            + struct.pack(f"<{len(self.padded_shape)}Q", *self.padded_shape)
        )

    @staticmethod
    def from_bytes(data: bytes) -> "PadMeta":
        meta, end = PadMeta._parse_at(data, 0)
        if end != len(data):
            raise BlobCorruptError("corrupt FFCz blob: malformed pad-metadata section")
        return meta

    @staticmethod
    def _parse_at(data: bytes, pos: int) -> tuple:
        """Parse one FFCP section starting at ``pos``; returns (meta, end)."""
        head = pos + len(_PAD_MAGIC) + struct.calcsize(_PAD_HEADER)
        if len(data) < head or data[pos : pos + len(_PAD_MAGIC)] != _PAD_MAGIC:
            raise BlobCorruptError(
                "corrupt FFCz blob: trailing bytes are not a pad-metadata section"
            )
        n_dev, ndim = struct.unpack_from(_PAD_HEADER, data, pos + len(_PAD_MAGIC))
        if ndim > 16 or len(data) < head + 8 * ndim:
            raise BlobCorruptError("corrupt FFCz blob: malformed pad-metadata section")
        shape = struct.unpack_from(f"<{ndim}Q", data, head)
        return PadMeta(n_dev=n_dev, padded_shape=tuple(shape)), head + 8 * ndim


@dataclasses.dataclass(frozen=True)
class FFCzBlob:
    """Serialized FFCz compression result.

    Version-1 wire layout (what :meth:`to_bytes` writes)::

        b"FFCZ" | u8 version | <ddBQQQQ> E, Delta, ndim, nb, ns, nf, npw
        | ndim * u64 shape | base | spat_edits | freq_edits | pointwise
        [| b"FFCP" pad-metadata section] [| b"FFCR" ROI bound section]
        [| b"FFCC" CRC section]

    :meth:`from_bytes` length-validates every section against the payload
    and raises ``ValueError`` on truncated or foreign bytes.  Blobs written
    before the magic was introduced (version 0) start directly with the
    ``<ddBQQQQ>`` header; they are sniffed by the absent magic and decode
    unchanged.  The optional trailing :class:`PadMeta` section (uneven
    sharded writers only) is sniffed the same way — by its ``FFCP`` marker
    at the end of the core sections — so pad-free v1 blobs parse unchanged
    in both directions.
    """

    base_blob: bytes
    spat_edits: EncodedEdits
    # Frequency edit stream.  New blobs store the rfft half-spectrum (its
    # ``half_spectrum`` format flag set); legacy blobs store the full
    # spectrum and decode through the ifftn branch of ``FFCz.decompress``.
    freq_edits: EncodedEdits
    E: float
    Delta_scalar: float  # scalar Delta, or nan when pointwise (stored in blob)
    # float32 Delta_k grid bytes, or None; half-spectrum layout iff
    # ``freq_edits.half_spectrum`` (legacy blobs stored the full grid)
    pointwise_delta: Optional[bytes]
    shape: tuple
    stats: Optional[FFCzStats] = None
    # Optional slab-decomposition provenance (uneven sharded writers only);
    # informational — see PadMeta.
    pad_meta: Optional[PadMeta] = None
    # Optional float32 per-point spatial bound grid (ROI mode, FFCR tail
    # section; field shape/order).  SEMANTIC — unlike pad_meta/crc it is the
    # spatial bound the edits were encoded against, so decode must consume
    # it and payload_bytes() keeps it.  None for uniform-E writers (their
    # blobs stay byte-identical to pre-ROI writers).
    roi_bound: Optional[bytes] = None
    # Write (and re-write) the optional FFCC per-section CRC32 tail.  Set by
    # the parser when the section is present, so decode -> re-encode stays
    # byte-stable in both directions; blobs without the tail (every pre-CRC
    # writer) stay byte-identical.
    crc: bool = False

    def to_bytes(self) -> bytes:
        se = self.spat_edits.to_bytes()
        fe = self.freq_edits.to_bytes()
        pw = self.pointwise_delta or b""
        header = _MAGIC + struct.pack("<B", _WIRE_VERSION)
        header += struct.pack(
            _V0_HEADER,
            self.E,
            self.Delta_scalar,
            len(self.shape),
            len(self.base_blob),
            len(se),
            len(fe),
            len(pw),
        )
        header += struct.pack(f"<{len(self.shape)}Q", *self.shape)
        tail = self.pad_meta.to_bytes() if self.pad_meta is not None else b""
        if self.roi_bound is not None:
            tail += _ROI_MAGIC + struct.pack("<Q", len(self.roi_bound)) + self.roi_bound
        out = header + self.base_blob + se + fe + pw + tail
        if self.crc:
            import zlib

            crcs = [zlib.crc32(out)] + [zlib.crc32(s) for s in (self.base_blob, se, fe, pw)]
            out += _CRC_MAGIC + struct.pack("<B", len(crcs)) + struct.pack(f"<{len(crcs)}I", *crcs)
        return out

    def payload_bytes(self) -> bytes:
        """Blob bytes with the informational pad-metadata and CRC tails
        stripped — the unit of cross-backend byte parity for ``"bitwise"``
        shapes."""
        if self.pad_meta is None and not self.crc:
            return self.to_bytes()
        return dataclasses.replace(self, pad_meta=None, crc=False).to_bytes()

    @staticmethod
    def from_bytes(data: bytes) -> "FFCzBlob":
        try:
            if data[:4] == _MAGIC:
                if len(data) < 5:
                    raise BlobCorruptError("truncated FFCz blob: magic without version byte")
                version = data[4]
                if version != _WIRE_VERSION:
                    raise BlobCorruptError(f"unsupported FFCz blob version {version}")
                return FFCzBlob._parse(data, offset=5)
            # version-0 sniff: magic-less blobs start directly with the header
            return FFCzBlob._parse(data, offset=0)
        except FFCzError:
            raise
        except Exception as e:
            # untrusted bytes: struct/slice/decode failures all classify as
            # corruption, never an unstructured crash
            raise BlobCorruptError(f"corrupt FFCz blob: {type(e).__name__}: {e}", cause=e) from e

    @staticmethod
    def _parse(data: bytes, offset: int) -> "FFCzBlob":
        head = struct.calcsize(_V0_HEADER)
        if len(data) < offset + head:
            raise BlobCorruptError(
                f"truncated FFCz blob: {len(data)} bytes < {offset + head}-byte header"
            )
        E, Delta, ndim, nb, ns, nf, npw = struct.unpack_from(_V0_HEADER, data, offset)
        off = offset + head
        if ndim > 16:
            raise BlobCorruptError(f"not an FFCz blob: implausible rank {ndim}")
        if len(data) < off + 8 * ndim:
            raise BlobCorruptError("truncated FFCz blob: shape table cut off")
        shape = struct.unpack_from(f"<{ndim}Q", data, off)
        off += 8 * ndim
        expected = off + nb + ns + nf + npw
        if len(data) < expected:
            raise BlobCorruptError(
                f"corrupt FFCz blob: {len(data)} bytes, section table wants {expected}"
            )
        base = data[off : off + nb]
        se_raw = data[off + nb : off + nb + ns]
        fe_raw = data[off + nb + ns : off + nb + ns + nf]
        pw = data[off + nb + ns + nf : expected] if npw else None
        # optional tail sections, each sniffed by its marker: FFCP pad
        # metadata, then the FFCR ROI bound grid, then the FFCC integrity
        # section (always last, since its leading CRC covers every byte
        # before it); any other tail bytes are corruption.  v0 and tail-free
        # v1 blobs take none of these branches.
        pad_meta, roi_bound, has_crc, pos = None, None, False, expected
        if data[pos : pos + 4] == _PAD_MAGIC:
            pad_meta, pos = PadMeta._parse_at(data, pos)
        if data[pos : pos + 4] == _ROI_MAGIC:
            if len(data) < pos + 12:
                raise BlobCorruptError("corrupt FFCz blob: truncated ROI bound section")
            (n_roi,) = struct.unpack_from("<Q", data, pos + 4)
            n_expect = 4 * (int(np.prod(shape)) if shape else 1)
            if n_roi != n_expect:
                raise BlobCorruptError(
                    f"corrupt FFCz blob: ROI bound section is {n_roi} bytes, a "
                    f"float32 grid over shape {tuple(shape)} needs {n_expect}"
                )
            if len(data) < pos + 12 + n_roi:
                raise BlobCorruptError("corrupt FFCz blob: truncated ROI bound section")
            roi_bound = data[pos + 12 : pos + 12 + n_roi]
            pos += 12 + n_roi
        if data[pos : pos + 4] == _CRC_MAGIC:
            FFCzBlob._verify_crc(data, pos, (base, se_raw, fe_raw, pw or b""))
            # fixed-size tail: magic + count byte + 5 verified u32 CRCs
            has_crc, pos = True, pos + 4 + 1 + 4 * len(_CRC_SECTIONS)
        if pos != len(data):
            raise BlobCorruptError(
                "corrupt FFCz blob: trailing bytes are not a pad-metadata, "
                "ROI-bound, or CRC section"
            )
        se = EncodedEdits.from_bytes(se_raw)
        fe = EncodedEdits.from_bytes(fe_raw)
        return FFCzBlob(
            base_blob=base,
            spat_edits=se,
            freq_edits=fe,
            E=E,
            Delta_scalar=Delta,
            pointwise_delta=pw,
            shape=tuple(shape),
            pad_meta=pad_meta,
            roi_bound=roi_bound,
            crc=has_crc,
        )

    @staticmethod
    def _verify_crc(data: bytes, pos: int, sections: tuple) -> None:
        """Validate the FFCC tail at ``pos`` against the parsed sections.

        The leading CRC covers every byte before the tail (header included);
        the per-section CRCs localize a mismatch to the corrupt section for
        the error message.
        """
        import zlib

        tail_head = pos + 4 + 1
        if len(data) < tail_head:
            raise BlobCorruptError("corrupt FFCz blob: truncated CRC section")
        n = data[pos + 4]
        if n != len(_CRC_SECTIONS) or len(data) < tail_head + 4 * n:
            raise BlobCorruptError("corrupt FFCz blob: malformed CRC section")
        stored = struct.unpack_from(f"<{n}I", data, tail_head)
        actual = (zlib.crc32(data[:pos]),) + tuple(zlib.crc32(b) for b in sections)
        if stored == actual:
            return
        # All five must match: a mismatch confined to a stored per-section CRC
        # (leading CRC fine) still means the tail bytes were flipped.
        for name, s, a in zip(_CRC_SECTIONS[1:], stored[1:], actual[1:]):
            if s != a:
                raise BlobCorruptError(f"corrupt FFCz blob: CRC mismatch in {name} section")
        raise BlobCorruptError("corrupt FFCz blob: CRC mismatch in header section")

    def nbytes(self) -> int:
        return len(self.to_bytes())


def _irfftn(a: np.ndarray, shape) -> np.ndarray:
    """numpy irfftn with explicit axes (required for odd last-axis sizes)."""
    return np.fft.irfftn(a, s=shape, axes=tuple(range(len(shape))))


class FFCz:
    """Spectrum-preserving codec wrapping an arbitrary base compressor.

    ``base`` must expose ``compress(x, E) -> bytes`` and
    ``decompress(blob) -> np.ndarray`` with a pointwise L-inf guarantee.
    ``engine`` defaults to the shared process-wide engine.

    Sharded whole fields: passing a
    :class:`repro.sharding.dist_fft.ShardedField` to :meth:`compress` runs
    the PLAN spectra and the EXECUTE POCS loop distributed (pencil-
    decomposed rfftn under ``shard_map`` — device HBM never holds the
    gathered field), producing a blob bitwise identical to compressing the
    gathered field on one device.  The base compressor and the edit encoder
    are host codecs by contract, so they stage through the field's host
    copy exactly as the single-device pipeline does.  The engine *backend*
    still only selects how pencil batches execute via ``engine.correct``.
    """

    def __init__(self, base: Any, config: FFCzConfig = FFCzConfig(), engine: Optional[CorrectionEngine] = None):
        self.base = base
        self.config = config
        self.engine = engine or default_engine()

    # -- compression ------------------------------------------------------

    def compress(self, x) -> FFCzBlob:
        cfg = self.config
        sharded = isinstance(x, ShardedField)
        x32 = x.to_host() if sharded else np.asarray(x, dtype=np.float32)

        plan = self.engine.plan_field(x if sharded else x32, cfg)
        base_blob = self.base.compress(x32, plan.E_proj)
        x_hat = np.asarray(self.base.decompress(base_blob), dtype=np.float32)

        eps0 = x_hat - x32
        if sharded:
            eps0 = ShardedField(
                eps0, x.mesh, x.axis_name, x.parity_requested, x.overlap_chunks
            )
        result = self.engine.execute_field(eps0, plan)
        se, fe = self.engine.encode_field(result, plan)

        # Provenance for uneven slab decompositions: record how the field was
        # padded/sharded at write time.  Optional (absent for single-device
        # and evenly divisible writes, keeping those blobs byte-identical to
        # pre-pad writers) and ignored by decompress — the edit streams are
        # always encoded at the true extents.
        pad_meta = None
        if sharded and x.padded_shape != x.shape:
            pad_meta = PadMeta(n_dev=x.n_dev, padded_shape=x.padded_shape)

        blob = FFCzBlob(
            base_blob=base_blob,
            spat_edits=se,
            freq_edits=fe,
            E=plan.E,
            Delta_scalar=plan.delta_scalar,
            pointwise_delta=plan.pointwise_bytes(),
            shape=plan.shape,
            pad_meta=pad_meta,
            roi_bound=plan.roi_bytes(),
            crc=cfg.crc,
        )

        stats = None
        if cfg.verify:
            stats = self.verify_stats(blob, x32, result, plan=plan)
        return dataclasses.replace(blob, stats=stats)

    def verify_stats(self, blob: FFCzBlob, x32: np.ndarray, result, plan=None) -> FFCzStats:
        """Decode ``blob`` back and measure both bound margins against ``x32``.

        Factored out of :meth:`compress` so the serving layer can verify a
        blob it assembled through the staged engine path (plan / execute /
        encode) without re-running compression; ``plan`` is recomputed when
        the caller no longer holds it (planning is deterministic).
        """
        if plan is None:
            plan = self.engine.plan_field(x32, self.config)
        x_final = self.decompress(blob)
        eps = x_final.astype(np.float64) - x32.astype(np.float64)
        # half-spectrum check is exhaustive: every full-spectrum component
        # shares |Re|/|Im| (and its Delta_k) with its conjugate image here
        d = np.fft.rfftn(eps)
        if blob.roi_bound is not None:
            # ROI mode: the margin is against the STORED per-point grid, so
            # a held bound means every region's own E_n held, not just the
            # global envelope
            grid64 = np.frombuffer(blob.roi_bound, dtype=np.float32).reshape(
                blob.shape
            ).astype(np.float64)
            spatial_margin = float(np.min(grid64 - np.abs(eps)))
        else:
            spatial_margin = float(plan.E - np.max(np.abs(eps)))
        freq_excess = np.maximum(np.abs(d.real), np.abs(d.imag)) - np.asarray(plan.Delta)
        frequency_margin = float(-np.max(freq_excess))
        pspec_shell_err = pspec_shell_ok = None
        cfg = self.config
        if cfg.verify_pspec and cfg.pspec_rel is not None:
            from repro.core.spectrum import shell_ratio_error

            pspec_shell_err = float(shell_ratio_error(x_final, x32))
            pspec_shell_ok = bool(pspec_shell_err <= cfg.pspec_rel)
        return FFCzStats(
            iterations=result.iterations,
            converged=result.converged,
            n_active_spatial=blob.spat_edits.n_active,
            n_active_frequency=blob.freq_edits.n_active,
            base_bytes=len(blob.base_blob),
            edit_bytes=blob.spat_edits.nbytes() + blob.freq_edits.nbytes(),
            spatial_margin=spatial_margin,
            frequency_margin=frequency_margin,
            final_violations=result.final_violations,
            pspec_shell_err=pspec_shell_err,
            pspec_shell_ok=pspec_shell_ok,
        )

    # -- decompression ----------------------------------------------------

    def decompress(self, blob: FFCzBlob) -> np.ndarray:
        try:
            return self._decompress(blob)
        except FFCzError:
            raise
        except Exception as e:
            # decode consumes untrusted bytes end to end: any failure past
            # structural validation (codec garbage that entropy-decodes to the
            # wrong element count, off-shape buffers) is still corruption
            raise BlobCorruptError(f"corrupt FFCz blob: {type(e).__name__}: {e}", cause=e) from e

    def _decompress(self, blob: FFCzBlob) -> np.ndarray:
        x_hat = np.asarray(self.base.decompress(blob.base_blob), dtype=np.float32)
        if x_hat.shape != tuple(blob.shape):
            raise BlobCorruptError(
                f"corrupt FFCz blob: base section decodes to shape {x_hat.shape}, "
                f"header says {tuple(blob.shape)}"
            )
        half = blob.freq_edits.half_spectrum
        if blob.pointwise_delta is not None:
            # pointwise Delta_k grid, stored in the blob (Observation 4 mode);
            # half-spectrum layout in rfft-era blobs, full grid in legacy ones
            dshape = rfft_shape(blob.shape) if half else blob.shape
            Delta = np.frombuffer(blob.pointwise_delta, dtype=np.float32).reshape(dshape)
        else:
            Delta = blob.Delta_scalar
        if blob.roi_bound is not None:
            # per-point E_n grid (ROI mode): the spatial stream was quantized
            # against the stored grid, so decode must use the same values
            E_dec = np.frombuffer(blob.roi_bound, dtype=np.float32).reshape(blob.shape)
        else:
            E_dec = blob.E
        spat = decode_edits(blob.spat_edits, E_dec)
        freq = decode_edits(blob.freq_edits, Delta)
        if half:
            freq_spatial = _irfftn(freq, blob.shape)
        else:
            # legacy full-spectrum blob (pre-rfft format flag)
            freq_spatial = np.fft.ifftn(freq).real
        complete = spat + freq_spatial  # complete spatial edits (§IV-B)
        return (x_hat.astype(np.float64) + complete).astype(np.float32)

    def decompress_sharded(
        self,
        blob: FFCzBlob,
        mesh=None,
        axis_name: str = "data",
        parity="auto",
        strict_bitwise: Optional[bool] = None,
    ) -> ShardedField:
        """Decode a blob to a field resident on the mesh (slab-sharded, axis 0).

        Decoding itself is host-bound: the blob sections are host bytes, and
        the complete-spatial-edits inverse must run in float64 for the stored
        dual-bound guarantees to verify exactly (the device path is float32).
        The reconstructed field is scattered straight to its slabs (uneven
        extents re-pad automatically), so the result is bitwise identical to
        :meth:`decompress` while landing device-resident for distributed
        consumers.

        ``parity`` defaults to ``"auto"`` here — the scatter runs no
        distributed FFT, so the power-of-two bitwise precondition is
        irrelevant to decoding (and blobs written from ``"bound"``-parity
        fields must stay decodable).  A blob's :class:`PadMeta` (if any) is
        informational and not consulted: the target decomposition comes from
        ``mesh``, which need not match the writer's.
        """
        x = self.decompress(blob)
        return ShardedField.shard(
            x, mesh, axis_name=axis_name, parity=parity, strict_bitwise=strict_bitwise
        )

    def roundtrip(self, x):
        blob = self.compress(x)
        return self.decompress(blob), blob
