"""FFCz core: dual-domain error bounding via alternating projection (paper §IV)."""

from repro.core.bounds import DualBounds, power_spectrum_delta
from repro.core.cubes import project_fcube, project_scube
from repro.core.engine import CorrectionEngine, default_engine
from repro.core.ffcz import FFCz, FFCzConfig
from repro.core.pocs import AlternatingProjectionResult, alternating_projection
from repro.core.spectrum import power_spectrum, psnr, relative_frequency_error, ssnr
from repro.core.temporal import TemporalCodec, TemporalConfig, TemporalStream

__all__ = [
    "TemporalCodec",
    "TemporalConfig",
    "TemporalStream",
    "DualBounds",
    "power_spectrum_delta",
    "project_fcube",
    "project_scube",
    "alternating_projection",
    "AlternatingProjectionResult",
    "CorrectionEngine",
    "default_engine",
    "FFCz",
    "FFCzConfig",
    "power_spectrum",
    "ssnr",
    "psnr",
    "relative_frequency_error",
]
