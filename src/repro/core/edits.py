"""Compaction, quantization, and lossless coding of edits (paper §IV-B, Alg. 1 l.15-20).

Edit streams are sparse (Fig. 5: hundreds-to-thousands of active entries in a
512^3 field), so each stream is stored as

  flags:        N bits, bit-packed (1 = nonzero edit at this component)
  compact vals: the nonzero entries, quantized to the 2^m grid of the
                corresponding cube axis, Huffman + byte-coder compressed.

Spatial and frequency edits are stored separately (a frequency edit densifies
under IFFT — paper §IV-B), with the frequency stream holding interleaved
Re/Im code pairs per active component.

The GPU pipeline's exclusive prefix sum (CompactEdits) is ``np.flatnonzero``
here (host-side, as serialization is an I/O-adjacent stage); the on-device
quantizer is the Pallas kernel :mod:`repro.kernels.quantize`.
"""

from __future__ import annotations

import dataclasses
import struct

import numpy as np

from repro.coding.bitpack import pack_bits, unpack_bits
from repro.coding.lossless import lossless_compress, lossless_decompress
from repro.coding.quantize import DEFAULT_QUANT_BITS, dequantize_uniform, quantize_uniform
from repro.core.errors import BlobCorruptError


@dataclasses.dataclass(frozen=True)
class EncodedEdits:
    """One serialized edit stream (spatial or frequency).

    ``half_spectrum`` marks a frequency stream stored in rfft layout (last
    axis ``N//2 + 1`` of the field; ``shape`` is then the *half-spectrum*
    shape) — the decoder must reconstruct via ``irfftn``.  The flag rides in
    bit 7 of the packed header byte; pre-rfft blobs have that bit clear, so
    legacy full-spectrum streams decode unchanged.
    """

    shape: tuple
    is_complex: bool
    flags: bytes  # bit-packed nonzero mask
    payload: bytes  # lossless-compressed quantized values
    n_active: int
    quant_bits: int
    half_spectrum: bool = False

    def nbytes(self) -> int:
        # Exact serialized size: fixed header + one Q per shape dim + streams
        # (must match to_bytes(); a flat estimate here skews reported ratios).
        return len(self.flags) + len(self.payload) + struct.calcsize("<BBIQQ") + 8 * len(self.shape)

    def to_bytes(self) -> bytes:
        # packed byte: bit 0 complex, bits 1-6 quant_bits (< 64), bit 7 rfft layout
        if not 0 <= self.quant_bits < 64:
            raise ValueError(f"quant_bits={self.quant_bits} must fit in 6 header bits")
        header = struct.pack(
            "<BBIQQ",
            len(self.shape),
            (1 if self.is_complex else 0)
            | (self.quant_bits << 1)
            | (0x80 if self.half_spectrum else 0),
            self.n_active,
            len(self.flags),
            len(self.payload),
        )
        header += struct.pack(f"<{len(self.shape)}Q", *self.shape)
        return header + self.flags + self.payload

    @staticmethod
    def from_bytes(data: bytes) -> "EncodedEdits":
        try:
            ndim, packed, n_active, n_flags, n_payload = struct.unpack_from("<BBIQQ", data, 0)
            off = struct.calcsize("<BBIQQ")
            if ndim > 16:
                raise BlobCorruptError(f"corrupt edit stream: implausible rank {ndim}")
            shape = struct.unpack_from(f"<{ndim}Q", data, off)
        except struct.error as e:
            raise BlobCorruptError(f"truncated edit stream header: {e}", cause=e) from e
        off += 8 * ndim
        end = off + n_flags + n_payload
        if len(data) < end:
            raise BlobCorruptError(
                f"truncated edit stream: {len(data)} bytes, sections want {end}"
            )
        if len(data) > end:
            # every caller passes an exactly-sized slice (the container's
            # section table delimits the stream), so surplus bytes mean the
            # table and the stream disagree — corruption, not padding
            raise BlobCorruptError(
                f"corrupt edit stream: {len(data) - end} trailing byte(s) past "
                "the declared sections"
            )
        flags = data[off : off + n_flags]
        payload = data[off + n_flags : off + n_flags + n_payload]
        return EncodedEdits(
            shape=tuple(shape),
            is_complex=bool(packed & 1),
            flags=flags,
            payload=payload,
            n_active=n_active,
            quant_bits=(packed >> 1) & 0x3F,
            half_spectrum=bool(packed & 0x80),
        )


def encode_edits(
    edits: np.ndarray,
    bound,
    m: int = DEFAULT_QUANT_BITS,
    codec: str = "huffman+zlib",
    half_spectrum: bool = False,
) -> EncodedEdits:
    """Compact + quantize + losslessly compress one edit stream.

    ``bound`` may be scalar or a per-component array of the same shape as
    ``edits`` (pointwise Delta_k grids).  ``half_spectrum`` tags a frequency
    stream already living on the rfft half-spectrum (the shrunken edit
    stream of the rFFT fast path) so the decoder reconstructs via
    ``irfftn``.
    """
    edits = np.asarray(edits)
    is_complex = np.iscomplexobj(edits)
    flat = edits.ravel()
    bound = np.asarray(bound, dtype=np.float64)
    bound = bound.ravel() if bound.ndim else bound
    if is_complex:
        codes_full = np.stack(
            [quantize_uniform(flat.real, bound, m), quantize_uniform(flat.imag, bound, m)],
            axis=-1,
        )
        active = np.flatnonzero(codes_full.any(axis=-1))
        compact = codes_full[active].ravel()  # interleaved Re/Im codes
    else:
        codes_full = quantize_uniform(flat, bound, m)
        active = np.flatnonzero(codes_full)
        compact = codes_full[active]
    flags = np.zeros(flat.size, dtype=bool)
    flags[active] = True
    # Flag bitmaps are overwhelmingly sparse (Fig. 5) — deflating them takes
    # the fixed N/8-byte floor down to O(n_active) bytes (beyond-paper: the
    # paper stores the packed bitmap raw, which dominates edit storage when
    # few edits are active).
    import zlib

    return EncodedEdits(
        shape=tuple(edits.shape),
        is_complex=is_complex,
        flags=zlib.compress(pack_bits(flags), 6),
        payload=lossless_compress(compact, codec=codec),
        n_active=int(active.size),
        quant_bits=m,
        half_spectrum=half_spectrum,
    )


def decode_edits(enc: EncodedEdits, bound) -> np.ndarray:
    """Inverse of :func:`encode_edits`; returns the dense dequantized stream."""
    import zlib

    n = int(np.prod(enc.shape)) if enc.shape else 1
    try:
        flags = unpack_bits(zlib.decompress(enc.flags), n)
        active = np.flatnonzero(flags)
        codes = lossless_decompress(enc.payload)
    except BlobCorruptError:
        raise
    except Exception as e:
        # zlib.error / bad-magic ValueError / huffman garbage: the streams
        # are untrusted bytes, so every failure mode maps to one structured
        # corruption error instead of leaking codec internals
        raise BlobCorruptError(f"corrupt edit stream: {type(e).__name__}: {e}", cause=e) from e
    # Corruption that survives the entropy coder surfaces as a code count
    # that disagrees with the flag bitmap — catch it here with a structured
    # error instead of a downstream shape/broadcast crash.
    expected = 2 * active.size if enc.is_complex else active.size
    if codes.size != expected:
        raise BlobCorruptError(
            f"corrupt edit stream: {codes.size} codes for {active.size} active flags"
        )
    bound = np.asarray(bound, dtype=np.float64)
    b_active = bound.ravel()[active] if bound.ndim else bound
    if enc.is_complex:
        codes = codes.reshape(-1, 2)
        vals = dequantize_uniform(codes[:, 0], b_active, enc.quant_bits) + 1j * dequantize_uniform(
            codes[:, 1], b_active, enc.quant_bits
        )
        out = np.zeros(n, dtype=np.complex128)
    else:
        vals = dequantize_uniform(codes, b_active, enc.quant_bits)
        out = np.zeros(n, dtype=np.float64)
    out[active] = vals
    return out.reshape(enc.shape)
