"""Temporal stream codec: predictor residuals + POCS warm start (docs/streaming.md).

Every target domain produces *sequences* — cosmology snapshots, combustion
timesteps, EEG channels x time — yet one :class:`~repro.core.ffcz.FFCz` call
compresses a single frame from scratch.  :class:`TemporalCodec` is the engine
client that exploits the time axis, three ways:

  residuals     frame *t* is compressed as ``r_t = x_t - predict(decoded
                history)`` — the predictor (``identity`` hold or ``linear``
                extrapolation) is evaluated on the DECODED previous frames,
                never the originals, so quantization error cannot accumulate
                along the chain (the stream is self-correcting: encoder and
                decoder walk bitwise-identical histories).
  warm start    the POCS while_loop of frame *t* seeds its ``freq_edits``
                accumulator from frame *t-1*'s converged edit spectrum
                (``FFCzConfig.warm_start`` -> ``FieldPlan`` ->
                :func:`repro.core.pocs.alternating_projection`, all three
                backends).  Consecutive frames' base-compressor errors are
                correlated, so the warm loop re-converges in a fraction of
                the cold iteration count (the ``stream/warm-vs-cold`` bench
                row).  Encoder-side only: it changes iteration counts, never
                decodability or the bound guarantee, and ``warm_start=False``
                is bitwise-neutral (cold frames byte-identical to FFCz).
  pencil mode   EEG-style channels-x-time data routes through the engine's
                pencil ``correct_batch`` path (one pencil per channel by
                default), with per-block warm spectra threaded the same way.

Bound semantics: the stream claims ONE dual bound (E, Delta), resolved on
frame 0 and recorded in the container header; every frame — keyframe or
residual — reconstructs within it.  Residual frames compress against
slack-shrunk absolute bounds (``E - O(u32 * amax)``, ``Delta - O(u32 * l2)``,
the same 4-sigma float32 discipline as :func:`float32_bound_discipline`)
because reconstruction adds two more float32 roundings: the residual cast
``r32 = f32(x - pred)`` and the frame cast ``x_hat = f32(pred + r_hat)``.
``|x_hat - x| = |(pred + r_hat) - (pred + r)|`` by linearity, so the
residual-domain guarantee transfers to the frame.  Pointwise ``pspec`` bounds
are frame-dependent grids and are rejected for streams.

Wire format (``FFCS``, the :class:`~repro.core.ffcz.FFCzBlob` sibling
container)::

    b"FFCS" | u8 version
    | <BBIIddB> mode, predictor, keyframe_interval, n_frames, E, Delta, ndim
    | ndim * u64 frame shape | u32 block (0 in field mode)
    | n_frames * <QQB> frame (offset, length, flags: bit0 = keyframe)
    | u32 CRC32 of every preceding byte
    | concatenated frame payloads

The per-frame offset index makes the stream seekable: decode any frame by
walking forward from the latest keyframe at or before it
(:meth:`TemporalCodec.decode_frame`), without touching earlier bytes.
Keyframes recur every ``keyframe_interval`` frames and are resync points:
the predictor history (and the decode chain) restarts there, so a seek
decode is bitwise identical to the full sequential decode — gated by
tests/test_temporal.py.  Frame payloads are self-describing: whole-field
frames are ordinary ``FFCZ`` blobs, pencil frames the ``FFSB`` envelope
(defined here, shared with :class:`~repro.serving.ffcz_service.FFCzService`
pencil responses).

Streaming submission goes through ``FFCzService.submit_stream`` — one stream
is one unit of work, so per-stream frame order is trivially preserved across
the FRONT/BACK pipeline while other units still overlap.
"""

from __future__ import annotations

import dataclasses
import struct
import zlib
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.edits import EncodedEdits, decode_edits
from repro.core.engine import CorrectionEngine, default_engine
from repro.core.errors import (
    BlobCorruptError,
    FFCzError,
    InfeasibleBound,
    StreamStateError,
)
from repro.core.ffcz import FFCz, FFCzBlob, FFCzConfig

__all__ = [
    "StreamEncoder",
    "TemporalCodec",
    "TemporalConfig",
    "TemporalStream",
    "decode_pencil_blob",
]

# -- pencil frame envelope (FFSB) -------------------------------------------
#
# One pencil-planned tensor: magic, version, <ddIB> E/Delta/block/ndim,
# ndim * u64 shape, <QQQ> section lengths, sections, trailing u32 CRC32 of
# every preceding byte.  A new wire format (no legacy writers), so the CRC
# is unconditional.  Shared with the serving layer's pencil responses
# (repro.serving.ffcz_service re-exports the decoder).

_PENCIL_MAGIC = b"FFSB"
_PENCIL_VERSION = 1
_PENCIL_HEADER = "<ddIB"


def _pencil_blob(shape, base_blob: bytes, se, fe, plan, block: int) -> bytes:
    se_b, fe_b = se.to_bytes(), fe.to_bytes()
    out = _PENCIL_MAGIC + struct.pack("<B", _PENCIL_VERSION)
    out += struct.pack(_PENCIL_HEADER, plan.E, plan.Delta, block, len(shape))
    out += struct.pack(f"<{len(shape)}Q", *shape)
    out += struct.pack("<QQQ", len(base_blob), len(se_b), len(fe_b))
    out += base_blob + se_b + fe_b
    return out + struct.pack("<I", zlib.crc32(out))


def decode_pencil_blob(data: bytes, base: Any) -> np.ndarray:
    """Hardened decode of the pencil envelope (``FFSB``).

    Every malformation — bad magic/version, truncation, section overrun,
    CRC mismatch, codec garbage — raises :class:`BlobCorruptError`.
    """
    try:
        if data[:4] != _PENCIL_MAGIC:
            raise BlobCorruptError("not an FFCz service pencil blob: bad magic")
        if len(data) < 9 or data[4] != _PENCIL_VERSION:
            raise BlobCorruptError(
                f"unsupported service pencil blob version {data[4] if len(data) > 4 else '?'}"
            )
        if len(data) < 4 + 1 + 4:
            raise BlobCorruptError("truncated service pencil blob")
        body, (crc,) = data[:-4], struct.unpack_from("<I", data, len(data) - 4)
        if zlib.crc32(body) != crc:
            raise BlobCorruptError("corrupt service pencil blob: CRC mismatch")
        off = 5
        E, Delta, block, ndim = struct.unpack_from(_PENCIL_HEADER, body, off)
        off += struct.calcsize(_PENCIL_HEADER)
        if ndim > 16:
            raise BlobCorruptError(f"corrupt service pencil blob: implausible rank {ndim}")
        shape = struct.unpack_from(f"<{ndim}Q", body, off)
        off += 8 * ndim
        nb, ns, nf = struct.unpack_from("<QQQ", body, off)
        off += struct.calcsize("<QQQ")
        if len(body) != off + nb + ns + nf:
            raise BlobCorruptError(
                f"corrupt service pencil blob: {len(body)} bytes, sections want {off + nb + ns + nf}"
            )
        base_blob = body[off : off + nb]
        se = EncodedEdits.from_bytes(body[off + nb : off + nb + ns])
        fe = EncodedEdits.from_bytes(body[off + nb + ns : off + nb + ns + nf])
        x_hat = np.asarray(base.decompress(base_blob), dtype=np.float32)
        spat = decode_edits(se, E)
        freq = decode_edits(fe, Delta)
        complete = spat + np.fft.irfft(freq, n=block, axis=-1)
        size = int(np.prod(shape)) if shape else 1
        x = x_hat.astype(np.float64).reshape(-1) + complete.reshape(-1)[:size]
        return x.reshape(shape).astype(np.float32)
    except FFCzError:
        raise
    except Exception as e:  # noqa: BLE001 - untrusted bytes
        raise BlobCorruptError(
            f"corrupt service pencil blob: {type(e).__name__}: {e}", cause=e
        ) from e


# -- stream container (FFCS) ------------------------------------------------

_STREAM_MAGIC = b"FFCS"
_STREAM_VERSION = 1
# mode, predictor, keyframe_interval, n_frames, E, Delta, ndim
_STREAM_HEADER = "<BBIIddB"
_FRAME_ENTRY = "<QQB"  # payload offset (frames-relative), length, flags
_FLAG_KEYFRAME = 0x01

_MODES = ("field", "pencils")
_PREDICTORS = ("identity", "linear")


@dataclasses.dataclass(frozen=True)
class TemporalConfig:
    """Stream-shaped knobs of one :class:`TemporalCodec` (bound knobs stay in
    :class:`~repro.core.ffcz.FFCzConfig`, including ``warm_start``).

    ``predictor``: ``"identity"`` (zero-order hold) or ``"linear"``
    (two-point extrapolation ``2*x[t-1] - x[t-2]``, falling back to identity
    when only one frame of history exists — i.e. right after a keyframe).
    ``keyframe_interval``: every K-th frame is a self-contained keyframe and
    resync point (1 = every frame, degenerating to per-frame FFCz).
    ``mode``: ``"field"`` (whole-field frames) or ``"pencils"`` (the
    blockwise path; EEG-style channels x time).  ``block``: pencil length in
    pencils mode; 0 picks the frame's last-axis extent (one pencil per
    channel row).
    """

    predictor: str = "linear"
    keyframe_interval: int = 8
    mode: str = "field"
    block: int = 0

    def __post_init__(self):
        if self.predictor not in _PREDICTORS:
            raise ValueError(f"predictor must be one of {_PREDICTORS}, got {self.predictor!r}")
        if self.mode not in _MODES:
            raise ValueError(f"mode must be one of {_MODES}, got {self.mode!r}")
        if self.keyframe_interval < 1:
            raise ValueError(f"keyframe_interval must be >= 1, got {self.keyframe_interval}")
        if self.block < 0:
            raise ValueError(f"block must be >= 0, got {self.block}")


@dataclasses.dataclass(frozen=True)
class TemporalStream:
    """Parsed ``FFCS`` container: header + seek index + frame payload bytes.

    ``E``/``Delta`` are the stream-level claimed bounds (resolved on frame 0
    at encode time); ``entries[i]`` is ``(offset, length, keyframe)`` with
    offsets relative to the frames section.  Purely structural — decoding a
    frame still validates its payload through the frame format's own parser.
    """

    mode: str
    predictor: str
    keyframe_interval: int
    E: float
    Delta: float
    shape: Tuple[int, ...]
    block: int
    entries: Tuple[Tuple[int, int, bool], ...]
    data: bytes = dataclasses.field(repr=False)
    frames_base: int = 0

    @property
    def n_frames(self) -> int:
        return len(self.entries)

    def is_keyframe(self, t: int) -> bool:
        return self.entries[t][2]

    def latest_keyframe(self, t: int) -> int:
        """Index of the closest keyframe at or before frame ``t`` — the seek
        entry point for decoding frame ``t`` without earlier bytes."""
        for i in range(t, -1, -1):
            if self.entries[i][2]:
                return i
        raise BlobCorruptError("corrupt FFCS stream: no keyframe precedes the target frame")

    def frame_payload(self, t: int) -> bytes:
        off, length, _ = self.entries[t]
        start = self.frames_base + off
        return self.data[start : start + length]

    @staticmethod
    def from_bytes(data: bytes) -> "TemporalStream":
        try:
            if data[:4] != _STREAM_MAGIC:
                raise BlobCorruptError("not an FFCS stream: bad magic")
            if len(data) < 5 or data[4] != _STREAM_VERSION:
                raise BlobCorruptError(
                    f"unsupported FFCS stream version {data[4] if len(data) > 4 else '?'}"
                )
            off = 5
            head = struct.calcsize(_STREAM_HEADER)
            if len(data) < off + head:
                raise BlobCorruptError("truncated FFCS stream: header cut off")
            mode_id, pred_id, interval, n_frames, E, Delta, ndim = struct.unpack_from(
                _STREAM_HEADER, data, off
            )
            off += head
            if mode_id >= len(_MODES):
                raise BlobCorruptError(f"corrupt FFCS stream: unknown mode id {mode_id}")
            if pred_id >= len(_PREDICTORS):
                raise BlobCorruptError(f"corrupt FFCS stream: unknown predictor id {pred_id}")
            if interval < 1:
                raise BlobCorruptError("corrupt FFCS stream: keyframe interval 0")
            if ndim > 16:
                raise BlobCorruptError(f"not an FFCS stream: implausible rank {ndim}")
            if len(data) < off + 8 * ndim + 4:
                raise BlobCorruptError("truncated FFCS stream: shape table cut off")
            shape = struct.unpack_from(f"<{ndim}Q", data, off)
            off += 8 * ndim
            (block,) = struct.unpack_from("<I", data, off)
            off += 4
            entry = struct.calcsize(_FRAME_ENTRY)
            index_end = off + n_frames * entry
            if len(data) < index_end + 4:
                raise BlobCorruptError("truncated FFCS stream: seek index cut off")
            (crc,) = struct.unpack_from("<I", data, index_end)
            if zlib.crc32(data[:index_end]) != crc:
                raise BlobCorruptError("corrupt FFCS stream: header/index CRC mismatch")
            frames_base = index_end + 4
            entries = []
            for i in range(n_frames):
                foff, flen, flags = struct.unpack_from(_FRAME_ENTRY, data, off + i * entry)
                if frames_base + foff + flen > len(data):
                    raise BlobCorruptError(
                        f"corrupt FFCS stream: frame {i} overruns the payload section"
                    )
                entries.append((foff, flen, bool(flags & _FLAG_KEYFRAME)))
            if entries and not entries[0][2]:
                raise BlobCorruptError("corrupt FFCS stream: first frame is not a keyframe")
            return TemporalStream(
                mode=_MODES[mode_id],
                predictor=_PREDICTORS[pred_id],
                keyframe_interval=interval,
                E=E,
                Delta=Delta,
                shape=tuple(int(s) for s in shape),
                block=block,
                entries=tuple(entries),
                data=bytes(data),
                frames_base=frames_base,
            )
        except FFCzError:
            raise
        except Exception as e:  # noqa: BLE001 - untrusted bytes
            raise BlobCorruptError(
                f"corrupt FFCS stream: {type(e).__name__}: {e}", cause=e
            ) from e


def _predict(history: Sequence[np.ndarray], predictor: str) -> np.ndarray:
    """Evaluate the frame predictor on the decoded history, in float64.

    float64 on float32 inputs makes ``2*a - b`` effectively exact, so the
    encoder and decoder (walking identical histories) compute bitwise-equal
    predictions.  Falls back to identity with a single frame of history —
    deterministically, so both sides fall back together.
    """
    if predictor == "identity" or len(history) < 2:
        return history[-1].astype(np.float64)
    return 2.0 * history[-1].astype(np.float64) - history[-2].astype(np.float64)


# -- the codec ---------------------------------------------------------------


class StreamEncoder:
    """Incremental encoder state for one stream (create via
    :meth:`TemporalCodec.open_stream`).

    :meth:`add_frame` compresses one frame and returns its payload bytes;
    :meth:`finish` assembles the ``FFCS`` container.  Encoder state (decoded
    history, warm spectrum, frame list) mutates only after a frame fully
    succeeds, so a failed ``add_frame`` can be retried — the serving layer's
    per-frame retry ladder relies on this.  ``finish()`` is terminal:
    ``add_frame`` after it (or a second ``finish()``) raises
    :class:`~repro.core.errors.StreamStateError` instead of silently
    mutating/re-emitting against committed state — the session layer's
    finalize-vs-append serialization depends on this invariant.

    ``frame_stats`` records, per frame, ``{"keyframe", "iterations",
    "converged"}`` — the warm-vs-cold bench reads the iteration counts.
    :meth:`export_state` snapshots the committed state as plain data; the
    matching import hook is :meth:`TemporalCodec.restore_stream` (session
    crash recovery / spill-resume).
    """

    def __init__(self, codec: "TemporalCodec"):
        self._codec = codec
        self._frames: List[Tuple[bytes, bool]] = []
        self._history: List[np.ndarray] = []
        self._warm: Optional[Any] = None
        self._shape: Optional[Tuple[int, ...]] = None
        self._block = 0
        self._E0: Optional[float] = None
        self._Delta0: Optional[float] = None
        self._finished = False
        self.frame_stats: List[dict] = []

    @property
    def n_frames(self) -> int:
        return len(self._frames)

    @property
    def finished(self) -> bool:
        return self._finished

    @property
    def history_nbytes(self) -> int:
        """Resident decoded-history footprint — what session spill eviction
        reclaims (payload bytes stay journaled, not resident)."""
        return int(sum(h.nbytes for h in self._history))

    def export_state(self) -> dict:
        """The committed stream state as plain data: frame payloads + the
        scalars :meth:`TemporalCodec.restore_stream` needs to rebuild a live
        encoder.  Decoded history and the warm spectrum are derived state and
        deliberately excluded — history re-decodes bitwise from the payloads,
        and the first post-restore frame runs cold (bound-conformant either
        way; bitwise-identical under the default ``warm_start=False``)."""
        return {
            "frames": list(self._frames),
            "shape": self._shape,
            "block": self._block,
            "E0": self._E0,
            "Delta0": self._Delta0,
        }

    def add_frame(self, x: np.ndarray) -> bytes:
        if self._finished:
            raise StreamStateError(
                "add_frame on a finished stream: finish() already assembled "
                "the container",
                stage="encode",
            )
        codec = self._codec
        x32 = np.asarray(x, dtype=np.float32)
        if x32.size == 0:
            raise ValueError("cannot compress an empty frame")
        if self._shape is None:
            self._shape = x32.shape
            self._block = codec._resolve_block(x32.shape)
        elif x32.shape != self._shape:
            raise ValueError(
                f"stream frames must share one shape: got {x32.shape}, stream is {self._shape}"
            )
        t = len(self._frames)
        is_key = t % codec.stream.keyframe_interval == 0
        warm = self._warm if codec.config.warm_start else None
        if is_key:
            payload, decoded, warm_next, iters, conv = codec._compress_key(
                self, x32, first=(t == 0), warm=None  # keyframes restart cold
            )
            history = [decoded]  # resync: the predictor chain restarts here
        else:
            pred = _predict(self._history, codec.stream.predictor)
            r32 = (x32.astype(np.float64) - pred).astype(np.float32)
            E_res, D_res = codec._residual_bounds(x32, pred, self._E0, self._Delta0, self._block)
            payload, r_hat, warm_next, iters, conv = codec._compress_frame(
                r32, E_res, D_res, self._block, warm
            )
            decoded = (pred + r_hat.astype(np.float64)).astype(np.float32)
            history = (self._history + [decoded])[-2:]
        # commit point: nothing above mutated encoder state
        self._frames.append((payload, is_key))
        self._history = history
        self._warm = warm_next
        self.frame_stats.append({"keyframe": is_key, "iterations": iters, "converged": conv})
        return payload

    def finish(self) -> bytes:
        if self._finished:
            raise StreamStateError(
                "finish() called twice on one stream: the container was "
                "already assembled",
                stage="encode",
            )
        if not self._frames:
            raise ValueError("cannot finish an empty stream")
        codec = self._codec
        header = _STREAM_MAGIC + struct.pack("<B", _STREAM_VERSION)
        header += struct.pack(
            _STREAM_HEADER,
            _MODES.index(codec.stream.mode),
            _PREDICTORS.index(codec.stream.predictor),
            codec.stream.keyframe_interval,
            len(self._frames),
            float(self._E0),
            float(self._Delta0),
            len(self._shape),
        )
        header += struct.pack(f"<{len(self._shape)}Q", *self._shape)
        header += struct.pack("<I", self._block if codec.stream.mode == "pencils" else 0)
        off = 0
        index = b""
        for payload, is_key in self._frames:
            flags = _FLAG_KEYFRAME if is_key else 0
            index += struct.pack(_FRAME_ENTRY, off, len(payload), flags)
            off += len(payload)
        head = header + index
        head += struct.pack("<I", zlib.crc32(head))
        self._finished = True
        return head + b"".join(p for p, _ in self._frames)


class TemporalCodec:
    """Predictor-residual stream codec over the shared CorrectionEngine.

    ``base``/``config``/``engine`` as in :class:`~repro.core.ffcz.FFCz`
    (``config.warm_start`` enables the POCS warm start); ``stream`` holds the
    stream-shaped knobs (:class:`TemporalConfig`).  ``pspec_rel`` bounds are
    rejected: a pointwise grid resolved per frame would change the claimed
    bound mid-stream.

    Encoding: :meth:`compress_stream` (whole sequence) or
    :meth:`open_stream` + ``add_frame`` (incremental, what the service stream
    kind drives).  Decoding: :meth:`decompress_stream` (all frames) or
    :meth:`decode_frame` (seek: walks forward from the latest keyframe at or
    before the target).  Decoding is driven entirely by the container header
    — a codec constructed with any stream config decodes any stream.
    """

    def __init__(
        self,
        base: Any,
        config: FFCzConfig = FFCzConfig(),
        stream: TemporalConfig = TemporalConfig(),
        engine: Optional[CorrectionEngine] = None,
    ):
        if config.pspec_rel is not None:
            raise ValueError(
                "pspec bounds are per-frame pointwise grids and cannot back a "
                "stream-level bound claim; use Delta_abs or Delta_rel"
            )
        self.base = base
        self.config = config
        self.stream = stream
        self.engine = engine or default_engine()
        self._ffcz = FFCz(base, config, engine=self.engine)

    # -- encode ------------------------------------------------------------

    def open_stream(self) -> StreamEncoder:
        return StreamEncoder(self)

    def restore_stream(
        self,
        frames: Sequence[Tuple[bytes, bool]],
        *,
        shape: Sequence[int],
        block: int = 0,
        E0: float,
        Delta0: float,
    ) -> StreamEncoder:
        """Rebuild a live :class:`StreamEncoder` from committed frame
        payloads — the state-import hook behind session crash recovery and
        spill-resume (the matching export is
        :meth:`StreamEncoder.export_state`).

        ``frames`` is the committed ``(payload, is_keyframe)`` list; ``shape``
        / ``block`` / ``E0`` / ``Delta0`` are the stream scalars resolved on
        frame 0.  The predictor history is re-decoded from the latest
        keyframe forward (the only frames a continuation depends on) — the
        same chain the decoder walks, so appends to the restored encoder are
        bitwise-identical to appends to the uninterrupted one.  The warm
        spectrum is not restorable state: the first post-restore frame runs
        cold (identical bytes under the default ``warm_start=False``).

        Raises :class:`BlobCorruptError` when a payload in the replayed chain
        does not decode, and when the keyframe flags disagree with this
        codec's ``keyframe_interval`` (a journal from a different stream
        config must not be silently continued).
        """
        frames = [(bytes(p), bool(k)) for p, k in frames]
        if not frames:
            raise ValueError("cannot restore an empty stream; open a fresh one")
        interval = self.stream.keyframe_interval
        for t, (_payload, is_key) in enumerate(frames):
            if is_key != (t % interval == 0):
                raise BlobCorruptError(
                    f"restored frame {t} keyframe flag disagrees with "
                    f"keyframe_interval={interval}: the journal belongs to a "
                    "different stream config"
                )
        shape = tuple(int(s) for s in shape)
        block = int(block) if self.stream.mode == "pencils" else 0
        if self.stream.mode == "pencils" and block == 0:
            block = self._resolve_block(shape)
        k = max(t for t, (_p, key) in enumerate(frames) if key)
        history: List[np.ndarray] = []
        for t in range(k, len(frames)):
            payload, is_key = frames[t]
            decoded = self._decode_payload_raw(payload, self.stream.mode, shape)
            if is_key:
                history = [decoded]
            else:
                pred = _predict(history, self.stream.predictor)
                x = (pred + decoded.astype(np.float64)).astype(np.float32)
                history = (history + [x])[-2:]
        enc = self.open_stream()
        enc._frames = frames
        enc._history = history
        enc._warm = None
        enc._shape = shape
        enc._block = block
        enc._E0 = float(E0)
        enc._Delta0 = float(Delta0)
        enc.frame_stats = [
            {"keyframe": key, "iterations": 0, "converged": None, "restored": True}
            for _p, key in frames
        ]
        return enc

    def compress_stream(self, frames: Sequence[np.ndarray]) -> bytes:
        """Compress a whole sequence into one ``FFCS`` container."""
        enc = self.open_stream()
        for x in frames:
            enc.add_frame(x)
        return enc.finish()

    def _resolve_block(self, shape: Tuple[int, ...]) -> int:
        if self.stream.mode != "pencils":
            return 0
        return self.stream.block or int(shape[-1])

    def _residual_bounds(self, x32, pred, E0: float, Delta0: float, block: int):
        """Slack-shrunk absolute bounds for one residual frame.

        Reconstruction adds two float32 roundings beyond the codec's own
        guarantee (``r32 = f32(x - pred)`` and ``x_hat = f32(pred +
        r_hat)``): each perturbs points by O(u32 * amax) and — after the
        FFT — frequency components by O(u32 * l2) (4-sigma statistical
        budget, mirroring :func:`float32_bound_discipline`).  Shrinking the
        residual-domain bounds by that slack keeps the frame within the
        stream's claimed (E0, Delta0).
        """
        u32 = float(np.finfo(np.float32).eps)
        amax = float(max(np.max(np.abs(x32)), np.max(np.abs(pred))))
        slack_s = 4.0 * u32 * (amax + E0)
        if self.stream.mode == "pencils":
            flat = np.asarray(x32, dtype=np.float64).reshape(-1)
            tiles = np.pad(flat, (0, (-flat.size) % block)).reshape(-1, block)
            l2ref = float(np.sqrt((tiles * tiles).sum(axis=-1).max()))
        else:
            x64 = np.asarray(x32, dtype=np.float64)
            l2ref = float(np.sqrt(np.sum(x64 * x64)))
        slack_f = 8.0 * u32 * l2ref
        E_res, D_res = E0 - slack_s, Delta0 - slack_f
        if E_res <= 0 or D_res <= 0:
            raise InfeasibleBound(
                f"stream bounds (E={E0:g}, Delta={Delta0:g}) leave no room for the "
                f"residual-frame float32 cast slack at this frame's magnitude",
                stage="plan",
            )
        return E_res, D_res

    def _compress_key(self, enc: StreamEncoder, x32, first: bool, warm):
        """Keyframe: compress the frame itself; frame 0 also resolves the
        stream-level bounds (later keyframes pin them absolutely so the
        claim cannot drift with per-frame ranges)."""
        cfg = self.config
        if self.stream.mode == "pencils":
            if first:
                plan = self.engine.plan_pencils(
                    x32,
                    E_rel=cfg.E_rel,
                    Delta_rel=cfg.Delta_rel,
                    E_abs=cfg.E_abs,
                    Delta_abs=cfg.Delta_abs,
                    block=enc._block,
                    quant_bits=cfg.quant_bits,
                )
                if plan is None:
                    raise InfeasibleBound(
                        "stream spatial bound underflows float32 for frame 0", stage="plan"
                    )
                enc._E0, enc._Delta0 = plan.E, plan.Delta
            payload, decoded, warm_next, iters, conv = self._compress_frame(
                x32, enc._E0, enc._Delta0, enc._block, warm
            )
            return payload, decoded, warm_next, iters, conv
        if first:
            run_cfg = cfg
        else:
            run_cfg = dataclasses.replace(
                cfg, E_abs=enc._E0, E_rel=None, Delta_abs=enc._Delta0, Delta_rel=None,
                pspec_rel=None,
            )
        plan = self.engine.plan_field(x32, run_cfg)
        if first:
            enc._E0, enc._Delta0 = plan.E, float(plan.Delta)
        base_blob = self.base.compress(x32, plan.E_proj)
        x_hat = np.asarray(self.base.decompress(base_blob), dtype=np.float32)
        result = self.engine.execute_field(x_hat - x32, plan, warm_freq=warm)
        se, fe = self.engine.encode_field(result, plan)
        blob = FFCzBlob(
            base_blob=base_blob,
            spat_edits=se,
            freq_edits=fe,
            E=plan.E,
            Delta_scalar=plan.delta_scalar,
            pointwise_delta=plan.pointwise_bytes(),
            shape=plan.shape,
            crc=cfg.crc,
        )
        decoded = self._ffcz.decompress(blob)
        warm_next = np.asarray(result.freq, dtype=np.complex64)
        return blob.to_bytes(), decoded, warm_next, int(result.iterations), bool(result.converged)

    def _compress_frame(self, data32, E_abs: float, Delta_abs: float, block: int, warm):
        """Compress one frame payload (a keyframe's field or a residual)
        against pinned absolute bounds; returns ``(payload, decoded,
        warm_next, iterations, converged)``."""
        cfg = self.config
        if self.stream.mode == "pencils":
            plan = self.engine.plan_pencils(
                data32, E_abs=E_abs, Delta_abs=Delta_abs, block=block,
                quant_bits=cfg.quant_bits,
            )
            if plan is None:
                raise InfeasibleBound(
                    "stream spatial bound underflows float32 for this frame", stage="plan"
                )
            base_blob = self.base.compress(data32, plan.E_proj)
            x_hat = np.asarray(self.base.decompress(base_blob), dtype=np.float32)
            eps0 = x_hat - data32
            tiles0 = self.engine.tile_f64(eps0, block)
            _corr, edits, stats = self.engine.correct(
                [eps0],
                [plan.E_proj],
                [plan.Delta_proj],
                block=block,
                max_iters=cfg.max_iters,
                return_edits=True,
                return_corrected=False,
                fft_impl=cfg.fft_impl,
                warm_freq=None if warm is None else [warm],
            )
            spat_t, freq_t = edits[0]
            warm_next = np.asarray(freq_t, dtype=np.complex64)
            se, fe = self.engine.encode_pencils(spat_t, freq_t, tiles0, plan, codec="zlib")
            payload = _pencil_blob(data32.shape, base_blob, se, fe, plan, block)
            decoded = decode_pencil_blob(payload, self.base)
            iters = int(np.max(np.asarray(stats.iterations))) if np.asarray(stats.iterations).size else 0
            conv = bool(np.all(np.asarray(stats.converged)))
            return payload, decoded, warm_next, iters, conv
        run_cfg = dataclasses.replace(
            cfg, E_abs=float(E_abs), E_rel=None, Delta_abs=float(Delta_abs),
            Delta_rel=None, pspec_rel=None,
        )
        plan = self.engine.plan_field(data32, run_cfg)
        base_blob = self.base.compress(data32, plan.E_proj)
        x_hat = np.asarray(self.base.decompress(base_blob), dtype=np.float32)
        result = self.engine.execute_field(x_hat - data32, plan, warm_freq=warm)
        se, fe = self.engine.encode_field(result, plan)
        blob = FFCzBlob(
            base_blob=base_blob,
            spat_edits=se,
            freq_edits=fe,
            E=plan.E,
            Delta_scalar=plan.delta_scalar,
            pointwise_delta=plan.pointwise_bytes(),
            shape=plan.shape,
            crc=cfg.crc,
        )
        decoded = self._ffcz.decompress(blob)
        warm_next = np.asarray(result.freq, dtype=np.complex64)
        return blob.to_bytes(), decoded, warm_next, int(result.iterations), bool(result.converged)

    # -- decode ------------------------------------------------------------

    def decompress_stream(self, data: bytes) -> List[np.ndarray]:
        """Decode every frame of an ``FFCS`` container, in order."""
        s = TemporalStream.from_bytes(data)
        out: List[np.ndarray] = []
        history: List[np.ndarray] = []
        for i in range(s.n_frames):
            out.append(self._decode_one(s, i, history))
        return out

    def decode_frame(self, data: bytes, t: int) -> np.ndarray:
        """Seek-decode frame ``t``: walk forward from the latest keyframe at
        or before it.  Bitwise identical to ``decompress_stream(data)[t]``
        (keyframes are resync points — the predictor history restarts
        there), touching only the frames in that chain."""
        s = TemporalStream.from_bytes(data)
        if not 0 <= t < s.n_frames:
            raise IndexError(f"frame {t} out of range for a {s.n_frames}-frame stream")
        k = s.latest_keyframe(t)
        history: List[np.ndarray] = []
        x: Optional[np.ndarray] = None
        for i in range(k, t + 1):
            x = self._decode_one(s, i, history)
        return x

    def _decode_one(self, s: TemporalStream, i: int, history: List[np.ndarray]) -> np.ndarray:
        payload = s.frame_payload(i)
        if s.is_keyframe(i):
            x = self._decode_payload(s, payload)
            history.clear()
            history.append(x)
            return x
        if not history:
            raise BlobCorruptError(
                f"corrupt FFCS stream: residual frame {i} has no decoded predecessor"
            )
        r_hat = self._decode_payload(s, payload)
        pred = _predict(history, s.predictor)
        x = (pred + r_hat.astype(np.float64)).astype(np.float32)
        history.append(x)
        del history[:-2]
        return x

    def _decode_payload(self, s: TemporalStream, payload: bytes) -> np.ndarray:
        return self._decode_payload_raw(payload, s.mode, s.shape)

    def _decode_payload_raw(
        self, payload: bytes, mode: str, shape: Tuple[int, ...]
    ) -> np.ndarray:
        if mode == "pencils":
            out = decode_pencil_blob(payload, self.base)
        else:
            out = self._ffcz.decompress(FFCzBlob.from_bytes(payload))
        if out.shape != tuple(shape):
            raise BlobCorruptError(
                f"corrupt FFCS stream: frame decodes to shape {out.shape}, "
                f"header says {tuple(shape)}"
            )
        return out
