"""Alternating projection-correction (paper Alg. 1) as one jitted while_loop.

The paper's CUDA pipeline launches per-iteration kernels from the host
(cuFFT -> CheckConvergence -> ProjectOntoFCube -> cuFFT -> ProjectOntoSCube)
with device<->host synchronization on the convergence flag.  On TPU/JAX the
whole loop is a single ``jax.lax.while_loop`` resident in HBM: no launch
overhead, no host sync, and XLA fuses the clip/accumulate stages around the
FFTs.  The convergence check is *fused into* the f-cube projection (one pass
over delta instead of the paper's two kernels) — a beyond-paper optimization
mirrored in the Pallas kernel (:mod:`repro.kernels.fcube`).

Semantics match Alg. 1 exactly:

  eps <- x_hat - x                       (inside the s-cube by construction)
  loop:
    delta <- FFT(eps)
    if delta inside f-cube: stop          (CheckConvergence)
    delta' <- clip(delta, +-Delta)        (ProjectOntoFCube)
    freq_edits += delta' - delta
    eps <- IFFT(delta')
    eps' <- clip(eps, +-E)                (ProjectOntoSCube)
    spat_edits += eps' - eps
    eps <- eps'

Both cubes are closed convex sets with (generically) non-empty intersection,
so POCS converges; ``max_iters`` guards the tangential-intersection slow case
(paper §III), after which a final s-cube projection guarantees the spatial
bound and the residual frequency excess is reported.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.cubes import fcube_violations, project_fcube, project_scube


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AlternatingProjectionResult:
    eps: Any  # final spatial error vector (inside s-cube; inside f-cube if converged)
    spat_edits: Any  # accumulated displacement along the spatial basis (real)
    freq_edits: Any  # accumulated displacement along the frequency basis (complex)
    iterations: Any  # int32 iteration count
    converged: Any  # bool: inside both cubes
    final_violations: Any  # int32: f-cube violations at exit (0 if converged)


@functools.partial(jax.jit, static_argnames=("max_iters", "use_kernels", "relax"))
def alternating_projection(
    eps0: jnp.ndarray,
    E,
    Delta,
    max_iters: int = 1000,
    use_kernels: bool = False,
    relax: float = 1.0,
    check_slack=0.0,
) -> AlternatingProjectionResult:
    """Run Alg. 1 from an initial spatial error vector ``eps0``.

    Args:
      eps0: x_hat - x from the base compressor (any rank, real dtype).
      E, Delta: scalar or broadcastable pointwise bounds (see core.bounds).
      max_iters: POCS iteration cap.
      use_kernels: route projections through the Pallas TPU kernels
        (``repro.kernels``) instead of the pure-jnp oracles.
      relax: over-relaxation factor (beyond-paper, addresses the paper's
        noted slow nearly-tangential convergence): the f-cube step moves
        ``relax`` times the projection displacement, then re-projects, i.e.
        relaxed POCS x <- P(x + (relax-1)(P(x) - x)).  1.0 is the
        paper-faithful plain alternating projection; 1.0 < relax < 2.0
        preserves Fejer monotonicity (convergence) for convex sets.  The
        final iterate is still an exact f-cube projection, so feasibility
        guarantees are unchanged.

    Returns an :class:`AlternatingProjectionResult` pytree.
    """
    if use_kernels:
        from repro.kernels.fcube import ops as fcube_ops
        from repro.kernels.scube import ops as scube_ops

        f_project = functools.partial(fcube_ops.project_fcube_fused, check_tol=1e-5)
        s_project = scube_ops.project_scube_fused
    else:
        # Convergence test uses a float32-resolution tolerance: below
        # ~1e-5 relative the float32 FFT round-trip oscillates and cannot
        # make progress; the exact float64 polish in FFCz.compress owns the
        # last digits (its workload is O(tolerance), i.e. negligible).
        _CHECK_TOL = 1e-5

        def f_project(delta, Delta):
            # check_slack: absolute float32-noise allowance for tiny
            # pointwise Delta_k (the caller reserves >= 2x this in its
            # bound shrink, and the float64 polish closes the gap exactly)
            viol = fcube_violations(delta, Delta * (1.0 + _CHECK_TOL) + check_slack)
            clipped, disp = project_fcube(delta, Delta)
            return clipped, disp, viol

        def s_project(eps, E):
            clipped, disp = project_scube(eps, E)
            return clipped, disp

    eps0 = jnp.asarray(eps0)
    cdtype = jnp.complex64 if eps0.dtype != jnp.float64 else jnp.complex128
    E = jnp.asarray(E, dtype=eps0.dtype)
    Delta_r = jnp.asarray(Delta, dtype=eps0.real.dtype)

    def cond(state):
        _eps, _se, _fe, it, done, _viol = state
        return jnp.logical_and(~done, it < max_iters)

    def body(state):
        eps, spat_edits, freq_edits, it, _done, _viol = state
        delta = jnp.fft.fftn(eps).astype(cdtype)
        clipped, f_disp, viol = f_project(delta, Delta_r)
        if relax != 1.0:
            # over-relax then re-project: still inside the f-cube, but
            # violating components land in the interior, not on the face
            over = delta + relax * f_disp
            clipped, _, _ = f_project(over, Delta_r)
            f_disp = clipped - delta
        done = viol == 0
        # When already inside the f-cube, the displacement is zero and the
        # projections below are no-ops; masking keeps the loop branch-free
        # (matches the GPU implementation, which exits before projecting).
        freq_edits = freq_edits + jnp.where(done, 0, 1) * f_disp
        eps_f = jnp.real(jnp.fft.ifftn(clipped)).astype(eps.dtype)
        eps_s, s_disp = s_project(eps_f, E)
        if relax != 1.0:
            over_s = eps_f + relax * s_disp
            eps_s, _ = s_project(over_s, E)
            s_disp = eps_s - eps_f
        spat_edits = spat_edits + jnp.where(done, 0, 1) * s_disp
        eps_next = jnp.where(done, eps, eps_s)
        return (eps_next, spat_edits, freq_edits, it + 1, done, viol)

    state0 = (
        eps0,
        jnp.zeros_like(eps0),
        jnp.zeros(eps0.shape, dtype=cdtype),
        jnp.int32(0),
        jnp.bool_(False),
        jnp.int32(-1),
    )
    eps, spat_edits, freq_edits, it, done, viol = jax.lax.while_loop(cond, body, state0)
    # Iteration accounting matches Table III: the terminating convergence
    # check counts as an iteration (pure-containment cases report 1).
    return AlternatingProjectionResult(
        eps=eps,
        spat_edits=spat_edits,
        freq_edits=freq_edits,
        iterations=it,
        converged=done,
        final_violations=jnp.where(done, 0, viol),
    )
