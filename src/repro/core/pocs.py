"""Alternating projection-correction (paper Alg. 1) as one jitted while_loop.

The paper's CUDA pipeline launches per-iteration kernels from the host
(cuFFT -> CheckConvergence -> ProjectOntoFCube -> cuFFT -> ProjectOntoSCube)
with device<->host synchronization on the convergence flag.  On TPU/JAX the
whole loop is a single ``jax.lax.while_loop`` resident in HBM: no launch
overhead, no host sync, and XLA fuses the clip/accumulate stages around the
FFTs.  The convergence check is *fused into* the f-cube projection (one pass
over delta instead of the paper's two kernels) — a beyond-paper optimization
mirrored in the Pallas kernel (:mod:`repro.kernels.fcube`).

Hermitian rFFT fast path (default, ``use_rfft=True``): the error vector is
real, so its spectrum is Hermitian-symmetric and the full complex ``fftn`` is
redundant.  The loop state, the f-cube projection, the convergence check and
the ``freq_edits`` accumulator all live on the ``rfftn`` half-spectrum (last
axis ``N//2 + 1``), halving FFT flops and frequency-state HBM traffic per
iteration.  Violation counts weight each half-spectrum component by its
conjugate-pair multiplicity (:func:`repro.core.cubes.rfft_pair_weights`), so
``final_violations`` keeps full-spectrum semantics.  ``use_rfft=False``
retains the complex-FFT path as the oracle (tests bit-compare the two).

Semantics match Alg. 1 exactly:

  eps <- x_hat - x                       (inside the s-cube by construction)
  loop:
    delta <- FFT(eps)
    if delta inside f-cube: stop          (CheckConvergence)
    delta' <- clip(delta, +-Delta)        (ProjectOntoFCube)
    freq_edits += delta' - delta
    eps <- IFFT(delta')
    eps' <- clip(eps, +-E)                (ProjectOntoSCube)
    spat_edits += eps' - eps
    eps <- eps'

Both cubes are closed convex sets with (generically) non-empty intersection,
so POCS converges; ``max_iters`` guards the tangential-intersection slow case
(paper §III), after which a final s-cube projection guarantees the spatial
bound and the residual frequency excess is reported.

Transform selector (``fft_impl``): XLA's C2R inverse is the slow half of the
loop (~2.1x the R2C forward on the CI CPU), so the loop's transforms are
pluggable through :mod:`repro.kernels.rfft`:

  ``"xla"``     ``jnp.fft.rfftn``/``irfftn`` (the default; blobs stay
                byte-identical to earlier writers).
  ``"packed"``  XLA's forward r2c (DUCC is already pack-trick fast) + the
                pure-XLA pack-trick C2R inverse (``packed_irfftn``: one
                Hermitian-mirror gather, twiddle recombination, half-length
                complex ``ifftn``, de-interleave) — 1.2-1.3x per iteration
                on CPU.  Composes with ``dist`` mode, where it swaps the
                local last-axis c2r pass.
  ``"pallas"``  the packed transforms with fused Pallas epilogues: the
                forward epilogue performs the f-cube clip, the pair-weighted
                violation count AND the inverse pack twiddle in one VMEM
                pass; the inverse epilogue fuses the s-cube clip into the
                de-interleave — one pass over the data instead of
                FFT-then-clip (interpret mode on CPU, Mosaic on TPU).

Packed/pallas trajectories differ from ``"xla"`` at float32-rounding level
(the 1/N normalization and twiddle roundings sit elsewhere), so distributed
parity for them is ``"bound"``, never ``"bitwise"`` — the dual-bound
guarantee is unconditional either way (float64 polish).  Shapes with an odd
last axis fall back statically: ``"packed"`` to the XLA transforms,
``"pallas"`` to XLA transforms + the fused fcube/scube projection kernels.

Convergence-check cadence (``check_every``): the violation-count reduction
(and its integer ``psum`` in dist mode) can run every K-th iteration instead
of every iteration — extra POCS iterations are always safe (projections are
no-ops once feasible), so the only cost is declaring convergence up to K-1
iterations late.  The final iteration before ``max_iters`` always checks, so
``final_violations`` stays meaningful.  Opt-in via the plan knob
(``FFCzConfig.check_every``); bound-conformance gated.

Warm start (``warm_freq``, ISSUE 8): a temporal stream's consecutive frames
produce highly correlated edit spectra, so the loop can seed its
``freq_edits`` accumulator from the PREVIOUS frame's converged spectrum
instead of zero.  The warm state is constructed to preserve the loop
invariant ``eps == eps0 + IFFT(freq_edits) + spat_edits`` exactly: the warm
spectrum is applied through the loop's own inverse transform and the result
is re-projected onto the s-cube (accumulating into ``spat_edits``) before
iteration 0, so a warm-started loop that converges immediately still
satisfies BOTH bounds by construction.  ``warm_freq=None`` (the default)
builds the exact legacy zero state — the trajectory, and therefore the edit
streams and blob bytes, are bitwise identical to pre-warm-start writers
(gated by tests/test_temporal.py).  See docs/streaming.md.

Distributed pencil mode (``dist=DistSpec(...)``): the loop body runs on a
*local slab* inside a ``shard_map`` region, with the FFT pair replaced by
the pencil-decomposed transforms of :mod:`repro.sharding.dist_fft`
(zero-padded all_to_all transposes between per-axis passes — any axis
extents, uneven slabs included) and the convergence count reduced with an
integer ``psum``.  Slab-pad rows of the local state are exactly zero and
stay exactly zero through the loop (clips and FFTs are zero-preserving, the
strict-inequality violation test never fires on zeros), so no pad masking
is needed in the body.  The per-axis pass order matches the fused
single-device transform bitwise, so a sharded whole-field loop reproduces
the single-device trajectory exactly on ``"bitwise"``-parity shapes — the
whole-field analogue of the PR 2 batched-vs-sharded parity bar.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.cubes import (
    project_box_relaxed,
    project_fcube,
    project_fcube_relaxed,
    project_scube,
    rfft_pair_weights,
    rfft_shape,
)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AlternatingProjectionResult:
    eps: Any  # final spatial error vector (inside s-cube; inside f-cube if converged)
    spat_edits: Any  # accumulated displacement along the spatial basis (real)
    # accumulated displacement along the frequency basis (complex); rfft
    # half-spectrum layout (last axis N//2+1) when use_rfft, else full spectrum
    freq_edits: Any
    iterations: Any  # int32 iteration count
    converged: Any  # bool: inside both cubes
    final_violations: Any  # int32: f-cube violations at exit (0 if converged)


_FFT_IMPLS = ("xla", "packed", "pallas")


def _alternating_projection(
    eps0: jnp.ndarray,
    E,
    Delta,
    max_iters: int = 1000,
    use_kernels: bool = False,
    relax: float = 1.0,
    check_slack=0.0,
    use_rfft: bool = True,
    dist: Optional[Any] = None,
    fft_impl: str = "xla",
    check_every: int = 1,
    warm_freq: Optional[Any] = None,
) -> AlternatingProjectionResult:
    """Run Alg. 1 from an initial spatial error vector ``eps0``.

    Args:
      eps0: x_hat - x from the base compressor (any rank, real dtype).
      E, Delta: scalar or broadcastable pointwise bounds (see core.bounds).
        Under ``use_rfft`` a pointwise ``Delta`` may be given either on the
        half-spectrum (``rfft_shape(eps0.shape)``) or on the full spectrum
        (``eps0.shape`` — sliced to the half-spectrum, exact for the
        Hermitian-symmetric grids ``core.bounds`` produces).
      max_iters: POCS iteration cap.
      use_kernels: route projections through the Pallas TPU kernels
        (``repro.kernels``) instead of the pure-jnp oracles.
      relax: over-relaxation factor (beyond-paper, addresses the paper's
        noted slow nearly-tangential convergence): the f-cube step moves
        ``relax`` times the projection displacement, then re-projects, i.e.
        relaxed POCS x <- P(x + (relax-1)(P(x) - x)).  1.0 is the
        paper-faithful plain alternating projection; 1.0 < relax < 2.0
        preserves Fejer monotonicity (convergence) for convex sets.  The
        final iterate is still an exact f-cube projection, so feasibility
        guarantees are unchanged.  For a box both projections collapse into
        the closed-form one-clip pass of ``project_box_relaxed``.
      use_rfft: run the loop on the Hermitian half-spectrum (the fast path;
        ``freq_edits`` then has rfft layout).  False keeps the full
        complex-FFT oracle.
      dist: a :class:`repro.sharding.dist_fft.DistSpec` — run the loop on a
        local slab inside a ``shard_map`` region with the pencil-decomposed
        distributed transforms (``eps0`` is then the local block — slab-pad
        rows zero, ``freq_edits`` the local half-spectrum block, and a
        pointwise ``Delta`` must already be the local frequency block,
        zero-padded to it).  Callers inside ``shard_map`` use the
        undecorated :func:`_alternating_projection` under the region's
        outer jit.
      fft_impl: loop transform selector — ``"xla"`` (default),
        ``"packed"`` (pack-trick C2R inverse, pure XLA, also composes with
        ``dist`` mode's local last-axis pass) or ``"pallas"`` (packed
        transforms with the fused clip/count epilogue kernels; requires the
        rfft path, ``relax == 1.0``, no ``dist``).  See the module
        docstring; shapes with an odd last axis fall back statically.
      check_every: run the convergence-check reduction every K-th iteration
        (and on the final one) instead of every iteration; 1 (default)
        preserves the exact legacy trajectory.
      warm_freq: optional complex seed for the ``freq_edits`` accumulator
        (``freq_shape`` layout: the rfft half-spectrum, the full spectrum
        when ``use_rfft=False``, or the local half-spectrum block in dist
        mode).  Applied through the loop's own inverse transform and
        s-cube-projected before iteration 0 so the loop invariant
        ``eps == eps0 + IFFT(freq_edits) + spat_edits`` holds exactly (see
        module docstring).  ``None`` (default) is the bitwise-identical
        legacy cold start.

    Returns an :class:`AlternatingProjectionResult` pytree.
    """
    if fft_impl not in _FFT_IMPLS:
        raise ValueError(f"fft_impl must be one of {_FFT_IMPLS}, got {fft_impl!r}")
    if check_every < 1:
        raise ValueError(f"check_every must be >= 1, got {check_every}")
    if fft_impl != "xla" and not use_rfft:
        raise ValueError("fft_impl='packed'/'pallas' require the rfft path (use_rfft=True)")
    if fft_impl == "pallas":
        if use_kernels:
            raise ValueError(
                "fft_impl='pallas' already fuses the projections into its "
                "epilogue kernels; drop use_kernels"
            )
        if relax != 1.0:
            raise ValueError("fft_impl='pallas' supports only relax == 1.0")
        if dist is not None:
            raise ValueError("dist mode supports fft_impl 'xla' or 'packed' only")
    eps0 = jnp.asarray(eps0)
    cdtype = jnp.complex64 if eps0.dtype != jnp.float64 else jnp.complex128
    E = jnp.asarray(E, dtype=eps0.dtype)
    Delta_r = jnp.asarray(Delta, dtype=eps0.real.dtype)

    shape = eps0.shape
    # Packed/pallas transforms need an even last axis; fall back statically
    # otherwise ("packed" -> the XLA transforms, "pallas" -> XLA transforms
    # + the fused fcube/scube projection kernels of the use_kernels path).
    if fft_impl != "xla":
        from repro.kernels.rfft import ops as _rfft_ops

        _packed_ok = _rfft_ops.supports_packed(dist.gshape if dist is not None else shape)
    else:
        _packed_ok = False
    pallas_fused = fft_impl == "pallas" and _packed_ok
    if fft_impl == "pallas" and not _packed_ok:
        use_kernels = True
    if dist is not None:
        if use_kernels or not use_rfft:
            raise ValueError("dist mode supports only the pure-jnp rfft path")
        from repro.sharding import dist_fft as _dfft

        axis_name, gshape = dist.axis_name, dist.gshape
        weights = None
        freq_shape = _dfft.local_freq_shape(gshape, dist.n_dev)
        if Delta_r.ndim and Delta_r.shape != freq_shape:
            raise ValueError(
                f"dist mode needs a scalar Delta or the local half-spectrum block "
                f"{freq_shape}, got {Delta_r.shape}"
            )
        if E.ndim and E.shape != eps0.shape:
            # pointwise spatial bounds (ROI grids) must arrive pre-sharded in
            # the padded local layout, exactly like a pointwise Delta grid
            raise ValueError(
                f"dist mode needs a scalar E or the local spatial block "
                f"{eps0.shape}, got {E.shape}"
            )
        inv_impl = "packed" if _packed_ok else "xla"
        fwd = lambda e: _dfft.rfftn_local(e, dist).astype(cdtype)  # noqa: E731
        inv = lambda d: _dfft.irfftn_local(d, dist, fft_impl=inv_impl).astype(eps0.dtype)  # noqa: E731
    elif use_rfft:
        # pair weights are only consumed by the fused kernels' reductions;
        # the jnp branch uses the cheaper 2*sum - self-conjugate-planes form
        weights = rfft_pair_weights(shape) if (use_kernels or pallas_fused) else None
        if Delta_r.ndim and Delta_r.shape == shape:
            # full-spectrum pointwise grid: Hermitian-symmetric by contract,
            # so the rfft half-plane slice is exact
            Delta_r = Delta_r[..., : shape[-1] // 2 + 1]
        freq_shape = rfft_shape(shape)
        fwd = lambda e: jnp.fft.rfftn(e).astype(cdtype)  # noqa: E731
        if _packed_ok:
            # the measured gap is the C2R inverse; the XLA forward (DUCC r2c,
            # already pack-trick fast) stays
            inv = lambda d: _rfft_ops.packed_irfftn(d, shape).astype(eps0.dtype)  # noqa: E731
        else:
            inv = lambda d: jnp.fft.irfftn(d, s=shape).astype(eps0.dtype)  # noqa: E731
    else:
        weights = None
        freq_shape = shape
        fwd = lambda e: jnp.fft.fftn(e).astype(cdtype)  # noqa: E731
        inv = lambda d: jnp.real(jnp.fft.ifftn(d)).astype(eps0.dtype)  # noqa: E731

    # Convergence test uses a float32-resolution tolerance: below
    # ~1e-5 relative the float32 FFT round-trip oscillates and cannot
    # make progress; the exact float64 polish in FFCz.compress owns the
    # last digits (its workload is O(tolerance), i.e. negligible).
    _CHECK_TOL = 1e-5

    if use_kernels:
        from repro.kernels.fcube import ops as fcube_ops
        from repro.kernels.scube import ops as scube_ops

        def f_project(delta, Delta):
            clipped, disp, viol = fcube_ops.project_fcube_fused(
                delta, Delta, weight=weights, check_tol=_CHECK_TOL, check_slack=check_slack
            )
            if relax != 1.0:
                clipped, _ = project_fcube(delta + relax * disp, Delta)
                disp = clipped - delta
            return clipped, disp, viol

        def s_project(eps, E):
            clipped, disp = scube_ops.project_scube_fused(eps, E)
            if relax != 1.0:
                clipped = jnp.clip(eps + relax * disp, -E, E)
                disp = clipped - eps
            return clipped, disp
    elif not pallas_fused:

        # Static layout facts for the cheap half-spectrum count below: the
        # last-axis k=0 plane (and the Nyquist plane for even N) is
        # self-conjugate and counts once; every other component stands for a
        # conjugate pair and counts twice.
        has_nyquist = use_rfft and shape and shape[-1] % 2 == 0 and shape[-1] // 2 + 1 > 1

        def _count_violations(delta):
            # check_slack: absolute float32-noise allowance for tiny
            # pointwise Delta_k (the caller reserves >= 2x this in its
            # bound shrink, and the float64 polish closes the gap exactly)
            dt = Delta_r * (1.0 + _CHECK_TOL) + check_slack
            vb = (jnp.abs(delta.real) > dt) | (jnp.abs(delta.imag) > dt)
            if dist is not None:
                # integer psum of pair-weighted local counts == the
                # single-device full-spectrum count, exactly
                w = _dfft.local_pair_weights(gshape, freq_shape, axis_name)
                viol = jax.lax.psum(jnp.sum(vb.astype(jnp.int32) * w), axis_name)
            elif use_rfft:
                # full-spectrum count without a weight-plane multiply:
                # 2 * total - (self-conjugate planes counted twice in it)
                viol = 2 * jnp.sum(vb) - jnp.sum(vb[..., 0])
                if has_nyquist:
                    viol = viol - jnp.sum(vb[..., -1])
            else:
                viol = jnp.sum(vb)
            return viol.astype(jnp.int32)

        def f_project(delta, Delta):
            if relax == 1.0:
                return project_fcube(delta, Delta)
            clipped = project_fcube_relaxed(delta, Delta, relax)
            return clipped, clipped - delta

        def s_project(eps, E):
            if relax == 1.0:
                return project_scube(eps, E)
            clipped = project_box_relaxed(eps, E, relax)
            return clipped, clipped - eps

    # Loop-invariant Hermitian-mirrored pointwise bound for the fused forward
    # epilogue (mirroring inside the body would re-gather every iteration).
    Delta_m = None
    if pallas_fused and Delta_r.ndim:
        Delta_m = _rfft_ops.mirror_half_spectrum(jnp.broadcast_to(Delta_r, freq_shape))

    def cond(state):
        _eps, _se, _fe, it, done, _viol = state
        return jnp.logical_and(~done, it < max_iters)

    def body(state):
        eps, spat_edits, freq_edits, it, _done, _viol = state
        delta = fwd(eps)
        if pallas_fused:
            # one VMEM pass: f-clip + edit displacement + pair-weighted
            # violation count + the inverse pack twiddle feeding ifftn
            clipped, f_disp, Z, viol = _rfft_ops.fwd_epilogue_fused(
                delta,
                Delta_r,
                Delta_m=Delta_m,
                weight=weights,
                check_tol=_CHECK_TOL,
                check_slack=check_slack,
            )
        elif use_kernels:
            clipped, f_disp, viol = f_project(delta, Delta_r)
        else:
            clipped, f_disp = f_project(delta, Delta_r)
            viol = None
        if check_every == 1:
            if viol is None:
                viol = _count_violations(delta)
            done = viol == 0
        else:
            # cadenced CheckConvergence: the reduction (and its psum in dist
            # mode) runs every K-th iteration and on the final one, so the
            # exit count is never stale; extra iterations are always safe
            # (projections are no-ops once feasible)
            do_check = jnp.logical_or(it % check_every == 0, it == max_iters - 1)
            if viol is None:
                viol = jax.lax.cond(
                    do_check, lambda: _count_violations(delta), lambda: jnp.int32(-1)
                )
            done = jnp.logical_and(do_check, viol == 0)
        # When already inside the f-cube, the displacement is zero and the
        # projections below are no-ops; masking keeps the loop branch-free
        # (matches the GPU implementation, which exits before projecting).
        freq_edits = freq_edits + jnp.where(done, 0, 1) * f_disp
        if pallas_fused:
            z = jnp.fft.ifftn(Z[..., : shape[-1] // 2])
            eps_s, s_disp = _rfft_ops.unpack_sclip_fused(z, E, shape)
            eps_s = eps_s.astype(eps0.dtype)
            s_disp = s_disp.astype(eps0.dtype)
        else:
            eps_f = inv(clipped)
            eps_s, s_disp = s_project(eps_f, E)
        spat_edits = spat_edits + jnp.where(done, 0, 1) * s_disp
        eps_next = jnp.where(done, eps, eps_s)
        return (eps_next, spat_edits, freq_edits, it + 1, done, viol)

    if warm_freq is None:
        eps_init, spat0 = eps0, jnp.zeros_like(eps0)
        if jnp.ndim(E) > 0:
            # Pointwise spatial bounds (ROI grids): the base compressor only
            # guarantees the *global* bound, so eps0 may already violate the
            # tighter per-point cube — and a trivially-converged loop (f-cube
            # satisfied at iteration 0) would return it unclipped.  Restore
            # the "state inside the s-cube" invariant before iteration 0,
            # same construction as the warm seed below.  Scalar E keeps the
            # exact legacy state (eps0 is inside the global cube by contract).
            eps_init, spat0 = project_scube(eps0, E)
            eps_init = eps_init.astype(eps0.dtype)
            spat0 = spat0.astype(eps0.dtype)
        state0 = (
            eps_init,
            spat0,
            jnp.zeros(freq_shape, dtype=cdtype),
            jnp.int32(0),
            jnp.bool_(False),
            jnp.int32(-1),
        )
    else:
        warm = jnp.asarray(warm_freq).astype(cdtype)
        if warm.shape != freq_shape:
            raise ValueError(
                f"warm_freq must have the loop's frequency-state shape "
                f"{freq_shape}, got {warm.shape}"
            )
        # Seed freq_edits with the previous frame's converged spectrum, then
        # restore the loop invariant: eps must equal
        # eps0 + IFFT(freq_edits) + spat_edits AND sit inside the s-cube
        # (the loop's convergence check only tests the f-cube, so skipping
        # this projection could declare a warm start converged with eps
        # outside the spatial bound).  `inv` is the loop's own inverse, so
        # this composes with packed/pallas transforms and dist-mode local
        # blocks (zero pad rows map to zero: linearity + clip(0) == 0).
        eps_w = eps0 + inv(warm)
        eps_s0, s_disp0 = project_scube(eps_w, E)
        state0 = (
            eps_s0.astype(eps0.dtype),
            s_disp0.astype(eps0.dtype),
            warm,
            jnp.int32(0),
            jnp.bool_(False),
            jnp.int32(-1),
        )
    eps, spat_edits, freq_edits, it, done, viol = jax.lax.while_loop(cond, body, state0)
    # Iteration accounting matches Table III: the terminating convergence
    # check counts as an iteration (pure-containment cases report 1).
    return AlternatingProjectionResult(
        eps=eps,
        spat_edits=spat_edits,
        freq_edits=freq_edits,
        iterations=it,
        converged=done,
        final_violations=jnp.where(done, 0, viol),
    )


# Public jitted entry point.  ``shard_map`` regions call the undecorated
# :func:`_alternating_projection` instead (the region's outer jit compiles it;
# a nested jit under manual collectives buys nothing and muddies the trace).
alternating_projection = functools.partial(
    jax.jit,
    static_argnames=(
        "max_iters", "use_kernels", "relax", "use_rfft", "dist", "fft_impl", "check_every",
    ),
)(_alternating_projection)
