"""Frequency-domain metrics: power spectrum, SSNR, RFE, PSNR (paper §III, §V-A).

All functions are jittable jnp; hosts can call them on numpy arrays directly.
:func:`power_spectrum` additionally accepts a slab-sharded
:class:`repro.sharding.dist_fft.ShardedField`, binning shells from the
distributed half-spectrum without ever gathering the field.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def power_spectrum(x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Radially binned power spectrum P(k) of an n-D real field (paper §III).

    Normalizes fluctuations (x - mean)/mean, FFTs, shifts the zero frequency
    to the center, and accumulates |X'|^2 over integer radial shells
    ``u^2 + v^2 + w^2 = k^2``.

    Returns (k values, P(k)) with ``k in [0, floor(min(N)/2)]``.

    A :class:`repro.sharding.dist_fft.ShardedField` input is dispatched to
    :func:`power_spectrum_sharded` (same semantics, field stays sharded).
    """
    from repro.sharding.dist_fft import ShardedField  # leaf-module laziness

    if isinstance(x, ShardedField):
        return power_spectrum_sharded(x)
    x = jnp.asarray(x)
    mean = jnp.mean(x)
    xp = (x - mean) / jnp.where(mean == 0, 1.0, mean)
    X = jnp.fft.fftshift(jnp.fft.fftn(xp))
    power = jnp.abs(X) ** 2

    grids = jnp.meshgrid(
        *[jnp.arange(n) - n // 2 for n in x.shape],
        indexing="ij",
    )
    r = jnp.sqrt(sum(g.astype(jnp.float32) ** 2 for g in grids))
    k_max = min(x.shape) // 2
    shell = jnp.rint(r).astype(jnp.int32)
    pk = jnp.zeros(k_max + 1, dtype=power.dtype).at[jnp.clip(shell, 0, k_max)].add(
        jnp.where(shell <= k_max, power, 0.0)
    )
    return jnp.arange(k_max + 1), pk


def power_spectrum_sharded(field) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """:func:`power_spectrum` of a slab-sharded field, never gathered.

    The distributed pencil rfftn yields the sharded half-spectrum; conjugate-
    pair multiplicities recover full-spectrum shell power, shell indices come
    from *global* frequency coordinates (``axis_index`` offsets the sharded
    axis), and one ``psum`` merges the per-device ``(k_max + 1,)`` shell
    histograms — the only cross-device traffic beyond the FFT transposes.
    Matches the gathered :func:`power_spectrum` to float tolerance (shell
    sums re-associate across shardings; this is a metric, not a bound).
    """
    k_max = min(field.shape) // 2
    fn = _power_spectrum_sharded_fn(field.mesh, field.dist_spec)
    return jnp.arange(k_max + 1), fn(field.array)


@functools.lru_cache(maxsize=None)
def _power_spectrum_sharded_fn(mesh, spec):
    """Compiled distributed shell-binning program, cached per (mesh, DistSpec).

    Pad-aware: the local slab carries zero pad rows (uneven decomposition),
    which the mean-fluctuation normalization would turn into ``-1`` rows —
    they are masked back to zero before the transform, and the shell weights
    exclude pad rows/columns of the half-spectrum (their power is exactly
    zero, so the masking is belt-and-braces for the weights and load-bearing
    only for the normalization).
    """
    from jax.sharding import PartitionSpec as P

    from repro.sharding import dist_fft
    from repro.sharding.shardmap import shard_map

    ax, gshape = spec.axis_name, spec.gshape
    nd = len(gshape)
    n_total = float(np.prod(gshape))
    k_max = min(gshape) // 2

    def body(local):
        # slab-pad rows are zero, so the sum needs no mask; n_total is the
        # TRUE element count
        mean = jax.lax.psum(jnp.sum(local), ax) / n_total
        xp = (local - mean) / jnp.where(mean == 0, 1.0, mean)
        # masked normalization: pad rows of (local - mean)/mean are -1, not 0
        row = jax.lax.axis_index(ax) * local.shape[0] + jnp.arange(local.shape[0])
        row_ok = (row < gshape[0]).reshape((-1,) + (1,) * (nd - 1))
        xp = jnp.where(row_ok, xp, 0.0)
        Xh = dist_fft.rfftn_local(xp, spec)
        w = dist_fft.local_pair_weights(gshape, Xh.shape, ax)
        power = (jnp.abs(Xh) ** 2) * w.astype(jnp.float32)
        coords = []
        pad_ok = jnp.ones((), dtype=bool)
        for a in range(nd):
            idx = jnp.arange(Xh.shape[a])
            if a == (0 if nd == 3 else nd - 1):  # the sharded spectrum axis
                idx = idx + jax.lax.axis_index(ax) * Xh.shape[a]
                # pad-excluding shell weights: half-spectrum pad rows (3-D)
                # / transit-pad columns (2-D) are not spectrum components
                n_true = gshape[0] if nd == 3 else gshape[-1] // 2 + 1
                shape_a = [1] * nd
                shape_a[a] = -1
                pad_ok = pad_ok & (idx < n_true).reshape(shape_a)
                idx = jnp.minimum(idx, n_true - 1)  # keep coords in range
            # fftshift convention of power_spectrum: bin k sits at signed
            # frequency ((k + n//2) % n) - n//2 (half axis: k itself)
            coords.append(((idx + gshape[a] // 2) % gshape[a]) - gshape[a] // 2)
        grids = jnp.meshgrid(*coords, indexing="ij")
        r = jnp.sqrt(sum(g.astype(jnp.float32) ** 2 for g in grids))
        shell = jnp.rint(r).astype(jnp.int32)
        power = jnp.where(pad_ok, power, 0.0)
        pk = jnp.zeros(k_max + 1, dtype=power.dtype).at[jnp.clip(shell, 0, k_max)].add(
            jnp.where(shell <= k_max, power, 0.0)
        )
        return jax.lax.psum(pk, ax)

    return jax.jit(shard_map(body, mesh=mesh, in_specs=P(ax), out_specs=P()))


def ssnr(X_hat: jnp.ndarray, X: jnp.ndarray) -> jnp.ndarray:
    """Spectral signal-to-noise ratio in dB (paper §V-A)."""
    num = jnp.sum(jnp.abs(X) ** 2)
    den = jnp.sum(jnp.abs(X - X_hat) ** 2)
    return 10.0 * jnp.log10(num / jnp.maximum(den, jnp.finfo(jnp.float32).tiny))


def ssnr_spatial(x_hat: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """SSNR computed from spatial fields (FFTs applied internally)."""
    return ssnr(jnp.fft.fftn(x_hat), jnp.fft.fftn(x))


def psnr(x_hat: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Peak signal-to-noise ratio in dB (spatial-domain metric).

    A constant reference field has ``range(x) == 0``; the range is clamped
    like the MSE term so the metric degrades to a finite (very low) value
    instead of ``-inf``/NaN.
    """
    tiny = jnp.finfo(jnp.float32).tiny
    rng = jnp.maximum(jnp.max(x) - jnp.min(x), tiny)
    mse = jnp.mean((x_hat - x) ** 2)
    return 20.0 * jnp.log10(rng) - 10.0 * jnp.log10(jnp.maximum(mse, tiny))


def relative_frequency_error(X_hat: jnp.ndarray, X: jnp.ndarray) -> jnp.ndarray:
    """RFE per component: |delta_k| / max_k |X_k| (paper §V-A).

    The denominator is clamped so an all-zero reference spectrum yields
    zeros (exact reconstruction) or large-but-finite values instead of NaN.
    """
    den = jnp.maximum(jnp.max(jnp.abs(X)), jnp.finfo(jnp.float32).tiny)
    return jnp.abs(X_hat - X) / den


def power_spectrum_relative_error(x_hat, x) -> Tuple[np.ndarray, np.ndarray]:
    """(P_hat(k) - P(k)) / P(k) per shell (paper Fig. 10 lower row)."""
    k, p = power_spectrum(x)
    _, p_hat = power_spectrum(x_hat)
    p = np.asarray(p)
    p_hat = np.asarray(p_hat)
    with np.errstate(divide="ignore", invalid="ignore"):
        rel = np.where(p > 0, (p_hat - p) / p, 0.0)
    return np.asarray(k), rel


def _power_spectrum_np64(x: np.ndarray) -> np.ndarray:
    """Float64 numpy mirror of :func:`power_spectrum` (same conventions:
    mean-normalized fluctuations, ``fftshift``, integer radial shells,
    ``k_max = min(shape)//2``).  The jnp path runs float32 on device; the
    verify-after-polish recheck needs exact float64 shell sums, hence this
    host twin."""
    x = np.asarray(x, dtype=np.float64)
    mean = x.mean()
    xp = (x - mean) / (mean if mean != 0 else 1.0)
    X = np.fft.fftshift(np.fft.fftn(xp))
    power = np.abs(X) ** 2
    grids = np.meshgrid(*[np.arange(n) - n // 2 for n in x.shape], indexing="ij")
    r = np.sqrt(sum(g.astype(np.float64) ** 2 for g in grids))
    k_max = min(x.shape) // 2
    shell = np.rint(r).astype(np.int64)
    pk = np.zeros(k_max + 1)
    np.add.at(pk, np.clip(shell, 0, k_max), np.where(shell <= k_max, power, 0.0))
    return pk


def shell_ratio_error(x_hat, x) -> float:
    """max over shells of ``|P_hat(k)/P(k) - 1|``, computed in float64.

    The derived-quantity verify for ``pspec_rel`` bounds (Observation 4
    guarantees the per-shell power-spectrum *ratio* ribbon; this measures
    it directly on the decoded field instead of trusting the per-component
    bound algebra).  Dead shells carry no ratio claim and are skipped — the
    liveness test is *relative* (``P(k) > 1e-12 * max_k P``) because the
    mean-normalized DC shell is an exact zero in theory but a ~1e-30
    round-off residue in float64, and a ratio against round-off is
    meaningless.  An exact reconstruction (or all-dead spectrum) returns
    0.0.
    """
    p = _power_spectrum_np64(x)
    p_hat = _power_spectrum_np64(x_hat)
    live = p > 1e-12 * (p.max() if p.size else 0.0)
    if not live.any():
        return 0.0
    return float(np.max(np.abs(p_hat[live] / p[live] - 1.0)))


def bitrate(compressed_bytes: int, n_values: int) -> float:
    """Bits per value (the paper's bitrate axis)."""
    return 8.0 * compressed_bytes / n_values
