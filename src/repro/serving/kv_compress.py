"""FFCz KV-cache compression (DESIGN.md §3 integration #3).

After prefill, the resident K/V tensors are quantized to ``bits`` and the
quantization error is FFCz-corrected blockwise along the sequence dimension:
spatial bound E keeps each cached activation within E of the exact value;
the frequency bound keeps the *spectrum over positions* — the structure
attention scores integrate over — within Delta.  The engine stores the
quantize+correct round-trip (memory model: codes at ``bits``/value + sparse
edits); tests verify both bounds and end-to-end logit drift.

Multi-tenant batching: ``compress_cache`` no longer dispatches one corrector
per layer/leaf — every K/V sub-tensor in the cache pytree is quantized, then
ALL quantization-error tensors go through ONE
:meth:`repro.core.engine.CorrectionEngine.correct` device program (donated
packed buffer, per-instance bounds and convergence masking; with a sharded
engine the packed pencils are corrected under ``shard_map`` across the
mesh).  This module owns only the KV-specific workload shaping (pencil
orientation over the sequence dim, quantizer, bound derivation).

Inapplicable to attention-free archs (mamba2: no KV cache; SSM state is tiny
and kept exact) — noted in DESIGN.md §Arch-applicability.
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.engine import CorrectionEngine, default_engine


def _quantize_pencils(kv: jnp.ndarray, bits: int, E_rel: float, batched: bool = False):
    """Swap to (..., hd, S) pencils and quantize; returns (xt, err, E).

    With ``batched`` the leading axis indexes independent sub-tensors, each
    quantized against its own amax (``E`` is then a vector).  The frequency
    bound is the caller's: Delta = Delta_rel * block * E.
    """
    x = kv.astype(jnp.float32)
    xt = jnp.swapaxes(x, -2, -1)  # pencils over the sequence dim
    reduce_axes = tuple(range(1, xt.ndim)) if batched else None
    amax = jnp.max(jnp.abs(xt), axis=reduce_axes)
    E = E_rel * jnp.maximum(amax, 1e-30)
    step = 2.0 * E / (2.0**bits)
    if batched:
        step = step.reshape((-1,) + (1,) * (xt.ndim - 1))
    q = jnp.rint(xt / step) * step
    return xt, q - xt, E


@functools.partial(jax.jit, static_argnames=("bits", "block", "max_iters", "engine"))
def compress_kv_tensor(
    kv: jnp.ndarray,  # (b, hkv, S, hd)
    *,
    bits: int = 8,
    E_rel: float = 1e-2,
    Delta_rel: float = 1e-2,
    block: int = 1024,
    max_iters: int = 8,
    engine: Optional[CorrectionEngine] = None,
) -> jnp.ndarray:
    """Quantize + FFCz-correct a KV tensor; returns the lossy round-trip.

    ``engine`` is a static jit argument routing the correction through its
    backend/mesh; engines hash by configuration (backend, axis, mesh), so
    equal-config instances share one compiled program.
    """
    xt, err, E = _quantize_pencils(kv, bits, E_rel)
    Delta = Delta_rel * block * E
    [corrected_err], _stats = (engine or default_engine()).correct(
        [err], E, Delta, block=block, max_iters=max_iters
    )
    out = jnp.swapaxes(xt + corrected_err, -2, -1)
    return out.astype(kv.dtype)


def compress_cache(
    cache: Any,
    comp,
    *,
    bits: int = 8,
    block: int = 1024,
    max_iters: int = 8,
    engine: Optional[CorrectionEngine] = None,
) -> Any:
    """Apply KV compression to every k/v leaf of a cache pytree.

    All layers'/leaves' quantization errors are corrected by ONE
    ``engine.correct`` device call (per-sub-tensor E/Delta, per-instance
    convergence), instead of a jit dispatch per leaf; the engine's backend
    decides whether that program is vmapped on one device or sharded over a
    mesh.
    """
    engine = engine or default_engine()
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    kv_idx = []
    for i, (path, leaf) in enumerate(flat):
        names = [str(p.key) for p in path if hasattr(p, "key")]
        if names and names[-1] in ("k", "v") and getattr(leaf, "ndim", 0) >= 4:
            kv_idx.append(i)
    if not kv_idx:
        return cache

    # quantize each leaf's sub-tensors in one vectorized pass (per-sub E from
    # a leading-axis-preserving amax), batch the POCS across everything.
    # Only the error tensors cross into the batched call (those buffers are
    # donated); the transposed float32 views are recomputed at assembly so
    # peak memory stays ~one cache copy.
    prepped = []  # (leaf_idx, n_sub, errs-list start, leaf shape, leaf dtype)
    errs, Es, Ds = [], [], []
    for i in kv_idx:
        leaf = flat[i][1]
        sub = leaf.reshape((-1,) + leaf.shape[-4:]) if leaf.ndim > 4 else leaf[None]
        start = len(errs)
        _xt, err, E = _quantize_pencils(sub, bits, comp.kv_E_rel, batched=True)
        errs.extend(err[j] for j in range(err.shape[0]))
        Es.extend(E[j] for j in range(E.shape[0]))
        Ds.extend(comp.kv_Delta_rel * block * E[j] for j in range(E.shape[0]))
        prepped.append((i, sub.shape[0], start, leaf.shape, leaf.dtype))

    corrected, _stats = engine.correct(errs, Es, Ds, block=block, max_iters=max_iters)

    leaves = [leaf for _, leaf in flat]
    for i, n_sub, start, shape, dtype in prepped:
        leaf = leaves[i]
        sub = leaf.reshape((-1,) + leaf.shape[-4:]) if leaf.ndim > 4 else leaf[None]
        xt = jnp.swapaxes(sub.astype(jnp.float32), -2, -1)
        corr = jnp.stack([corrected[start + j] for j in range(n_sub)])
        leaves[i] = jnp.swapaxes(xt + corr, -2, -1).reshape(shape).astype(dtype)
    return jax.tree_util.tree_unflatten(treedef, leaves)
