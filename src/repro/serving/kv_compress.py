"""FFCz KV-cache compression (DESIGN.md §3 integration #3).

After prefill, the resident K/V tensors are quantized to ``bits`` and the
quantization error is FFCz-corrected blockwise along the sequence dimension:
spatial bound E keeps each cached activation within E of the exact value;
the frequency bound keeps the *spectrum over positions* — the structure
attention scores integrate over — within Delta.  The engine stores the
quantize+correct round-trip (memory model: codes at ``bits``/value + sparse
edits); tests verify both bounds and end-to-end logit drift.

Inapplicable to attention-free archs (mamba2: no KV cache; SSM state is tiny
and kept exact) — noted in DESIGN.md §Arch-applicability.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.blockwise import blockwise_correct


@functools.partial(jax.jit, static_argnames=("bits", "block", "max_iters"))
def compress_kv_tensor(
    kv: jnp.ndarray,  # (b, hkv, S, hd)
    *,
    bits: int = 8,
    E_rel: float = 1e-2,
    Delta_rel: float = 1e-2,
    block: int = 1024,
    max_iters: int = 8,
) -> jnp.ndarray:
    """Quantize + FFCz-correct a KV tensor; returns the lossy round-trip."""
    x = kv.astype(jnp.float32)
    # blocks along the sequence dim: (b, hkv, S, hd) -> pencils over S
    xt = jnp.swapaxes(x, 2, 3)  # (b, hkv, hd, S)
    amax = jnp.max(jnp.abs(xt))
    E = E_rel * jnp.maximum(amax, 1e-30)
    step = 2.0 * E / (2.0**bits)
    q = jnp.rint(xt / step) * step
    err = q - xt
    Delta = Delta_rel * block * E
    corrected_err = blockwise_correct(err, E, Delta, block=block, max_iters=max_iters)
    out = jnp.swapaxes(xt + corrected_err, 2, 3)
    return out.astype(kv.dtype)


def compress_cache(cache: Any, comp) -> Any:
    """Apply KV compression to every k/v leaf of a cache pytree."""

    def visit(path, leaf):
        names = [str(p.key) for p in path if hasattr(p, "key")]
        if names and names[-1] in ("k", "v") and leaf.ndim >= 4:
            flat = leaf.reshape((-1,) + leaf.shape[-4:]) if leaf.ndim > 4 else leaf[None]
            out = jax.vmap(
                lambda t: compress_kv_tensor(
                    t, bits=8, E_rel=comp.kv_E_rel, Delta_rel=comp.kv_Delta_rel
                )
            )(flat)
            return out.reshape(leaf.shape)
        return leaf

    return jax.tree_util.tree_map_with_path(visit, cache)
