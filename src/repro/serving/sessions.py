"""Durable live stream sessions: WAL journaling, idempotent appends, leases.

``FFCzService.submit_stream`` compresses a *whole* sequence as one unit; a
streaming producer (EEG channels, per-timestep simulation dumps) instead
appends frames to a live stream one at a time, across network retries and
service restarts.  :class:`StreamSessionManager` hosts that lifecycle over
:class:`~repro.core.temporal.StreamEncoder`:

    open_session -> append_frame* -> [flush] -> finalize        (happy path)
                 \\-> abort                                      (client gives up)
                 \\-> lease expiry -> finalize to a partial FFCS (server-side)
    recover(journal) -> append_frame* -> finalize               (crash recovery)

Robustness is the contract, five ways:

  idempotent append   every frame carries a client-assigned, monotonically
                      increasing sequence number.  A duplicate seq with the
                      same frame content returns the ORIGINAL receipt (blob
                      digest + stats, ``duplicate=True``) — retries after an
                      ambiguous failure are always safe.  Gaps, negative
                      seqs, and a duplicate seq re-sent with *different*
                      content reject with
                      :class:`~repro.core.errors.SessionSequenceError`.
  write-ahead journal every committed frame is appended to a per-session
                      journal (CRC'd records, pluggable sink: in-memory for
                      tests, file-backed for ``launch/serve_ffcz.py``)
                      BEFORE its receipt is minted.  If the journal write
                      fails after the frame encoded, the frame is kept
                      *pending* (encoded-but-unjournaled, never acked) and
                      the retry re-journals without re-encoding.
  crash recovery      :meth:`StreamSessionManager.recover` rebuilds a live
                      encoder from the journal tail.  Truncated or
                      bit-flipped tails are detected by the per-record CRC
                      and dropped; if the surviving frame chain still fails
                      to replay, recovery degrades by whole keyframe groups
                      (keyframe resync — the PR 6 ladder philosophy) until a
                      durable prefix restores.  An intact journal restores
                      bitwise: finalize after recovery equals the
                      uninterrupted container byte-for-byte (under the
                      default ``warm_start=False``).
  leases + admission  sessions carry a deadline-style lease refreshed on
                      append; an expired lease finalizes the session to a
                      valid partial ``FFCS`` container (never a dangling
                      encoder).  ``max_sessions`` bounds live sessions and
                      rejects at admission with
                      :class:`~repro.core.errors.ResourceExhausted`; memory
                      pressure on decoded-history buffers
                      (``max_history_bytes``) spills idle sessions to their
                      journals, transparently restored on the next append.
  chaos sites         ``session_append`` fires before a frame encodes,
                      ``session_journal`` before a journal write — both with
                      the caller-supplied uid, so the per-(site, uid)
                      substream discipline keeps fault sequences
                      scheduling-invariant at both pipeline depths.

Journal wire format (``FFJR`` records, docs/streaming.md for the prose)::

    record  := b"FFJR" | u8 type | u32 body_len | body
               | u32 CRC32 of every preceding record byte
    OPEN    := type 1, body = JSON {v, session_id, cfg, stream}
    FRAME   := type 2, body = <IB32sddB> seq, flags (bit0 keyframe),
               sha256(frame bytes), E0, Delta0, ndim | ndim * u64 shape
               | u32 block | frame payload bytes
    CLOSE   := type 3, body = u8 reason (1 finalized, 2 aborted, 3 lease)

Parsing stops at the first damaged record (bad magic/CRC/truncation): the
journal is an append-only log, so everything before the damage is durable
and everything after it is by definition un-acked.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import struct
import threading
import time
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.engine import CorrectionEngine, default_engine
from repro.core.errors import (
    BlobCorruptError,
    FFCzError,
    ResourceExhausted,
    SessionError,
    SessionNotFound,
    SessionSequenceError,
)
from repro.core.ffcz import FFCzConfig
from repro.core.temporal import StreamEncoder, TemporalCodec, TemporalConfig

__all__ = [
    "FileJournal",
    "FrameReceipt",
    "MemoryJournal",
    "SessionStats",
    "StreamSessionManager",
    "parse_journal",
]

# -- journal wire format (FFJR) ----------------------------------------------

_J_MAGIC = b"FFJR"
_J_HEAD = "<BI"  # record type, body length
_J_OPEN, _J_FRAME, _J_CLOSE = 1, 2, 3
# seq, flags (bit0 keyframe), sha256(frame bytes), E0, Delta0, ndim
_J_FRAME_HEAD = "<IB32sddB"
_CLOSE_REASONS = {1: "finalized", 2: "aborted", 3: "lease_expired"}
_CLOSE_CODES = {v: k for k, v in _CLOSE_REASONS.items()}


def _record(rtype: int, body: bytes) -> bytes:
    rec = _J_MAGIC + struct.pack(_J_HEAD, rtype, len(body)) + body
    return rec + struct.pack("<I", zlib.crc32(rec))


def _frame_record(
    seq: int,
    keyframe: bool,
    frame_digest: bytes,
    E0: float,
    Delta0: float,
    shape: Tuple[int, ...],
    block: int,
    payload: bytes,
) -> bytes:
    body = struct.pack(
        _J_FRAME_HEAD, seq, 1 if keyframe else 0, frame_digest, E0, Delta0, len(shape)
    )
    body += struct.pack(f"<{len(shape)}Q", *shape)
    body += struct.pack("<I", block)
    return _record(_J_FRAME, body + payload)


@dataclasses.dataclass(frozen=True)
class _JournalFrame:
    """One durable FRAME record, parsed."""

    seq: int
    keyframe: bool
    frame_digest: bytes
    E0: float
    Delta0: float
    shape: Tuple[int, ...]
    block: int
    payload: bytes = dataclasses.field(repr=False)


@dataclasses.dataclass(frozen=True)
class ParsedJournal:
    """Everything durable in a journal byte string (see :func:`parse_journal`)."""

    open_info: Optional[dict]
    frames: Tuple[_JournalFrame, ...]
    closed: Optional[str]  # a _CLOSE_REASONS value when a CLOSE record survived
    damaged: bool  # True when parsing stopped at a corrupt/truncated record
    n_records: int


def parse_journal(data: bytes) -> ParsedJournal:
    """Walk ``FFJR`` records, stopping at the first damaged one.

    Never raises on malformed bytes — damage marks where durability ends,
    and the caller (recovery) resumes from the intact prefix.  Structural
    nonsense *within* an intact-CRC record (impossible rank, body shorter
    than its own header) also stops the walk: a CRC collision must not
    fabricate a frame.
    """
    open_info: Optional[dict] = None
    frames: List[_JournalFrame] = []
    closed: Optional[str] = None
    damaged = False
    n = 0
    off = 0
    head = struct.calcsize(_J_HEAD)
    while off < len(data):
        if data[off : off + 4] != _J_MAGIC or off + 4 + head + 4 > len(data):
            damaged = True
            break
        rtype, blen = struct.unpack_from(_J_HEAD, data, off + 4)
        end = off + 4 + head + blen
        if end + 4 > len(data):
            damaged = True
            break
        (crc,) = struct.unpack_from("<I", data, end)
        if zlib.crc32(data[off:end]) != crc:
            damaged = True
            break
        body = data[off + 4 + head : end]
        try:
            if rtype == _J_OPEN:
                open_info = json.loads(body.decode("utf-8"))
            elif rtype == _J_FRAME:
                fh = struct.calcsize(_J_FRAME_HEAD)
                seq, flags, digest, E0, Delta0, ndim = struct.unpack_from(
                    _J_FRAME_HEAD, body, 0
                )
                if ndim > 16 or len(body) < fh + 8 * ndim + 4:
                    raise ValueError("frame record body inconsistent")
                shape = struct.unpack_from(f"<{ndim}Q", body, fh)
                (block,) = struct.unpack_from("<I", body, fh + 8 * ndim)
                frames.append(
                    _JournalFrame(
                        seq=int(seq),
                        keyframe=bool(flags & 1),
                        frame_digest=digest,
                        E0=float(E0),
                        Delta0=float(Delta0),
                        shape=tuple(int(s) for s in shape),
                        block=int(block),
                        payload=body[fh + 8 * ndim + 4 :],
                    )
                )
            elif rtype == _J_CLOSE:
                closed = _CLOSE_REASONS.get(body[0] if body else 0, "finalized")
                n += 1
                off = end + 4
                break  # a close record ends the log
            else:
                raise ValueError(f"unknown record type {rtype}")
        except Exception:  # noqa: BLE001 - untrusted bytes end the walk
            damaged = True
            break
        n += 1
        off = end + 4
    return ParsedJournal(
        open_info=open_info,
        frames=tuple(frames),
        closed=closed,
        damaged=damaged,
        n_records=n,
    )


# -- journal sinks -----------------------------------------------------------


class MemoryJournal:
    """In-memory journal sink (tests, and the service default)."""

    def __init__(self, initial: bytes = b""):
        self._buf = bytearray(initial)

    def append(self, record: bytes) -> None:
        self._buf += record

    def read(self) -> bytes:
        return bytes(self._buf)

    def size(self) -> int:
        return len(self._buf)

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


class FileJournal:
    """File-backed journal sink: append + flush(+fsync) per record, so a
    record is durable before the frame it carries is acked."""

    def __init__(self, path: str, fsync: bool = True):
        self.path = path
        self._fsync = fsync
        self._f = open(path, "ab")

    def append(self, record: bytes) -> None:
        self._f.write(record)
        self._f.flush()
        if self._fsync:
            os.fsync(self._f.fileno())

    def read(self) -> bytes:
        self._f.flush()
        with open(self.path, "rb") as f:
            return f.read()

    def size(self) -> int:
        self._f.flush()
        return os.path.getsize(self.path)

    def flush(self) -> None:
        self._f.flush()
        if self._fsync:
            os.fsync(self._f.fileno())

    def close(self) -> None:
        self._f.close()


# -- receipts and stats ------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FrameReceipt:
    """Per-frame durable ack: what the client can verify and safely retry on.

    ``digest`` hashes the committed payload bytes, ``frame_digest`` the raw
    float32 frame the client sent (the idempotency check for duplicate
    seqs).  ``duplicate=True`` marks a cached receipt returned for a
    retried seq; ``restored=True`` marks receipts rebuilt from a journal
    (their ``iterations``/``converged`` are not recomputed)."""

    seq: int
    keyframe: bool
    digest: str
    frame_digest: str
    n_bytes: int
    iterations: int = 0
    converged: Optional[bool] = None
    duplicate: bool = False
    restored: bool = False


@dataclasses.dataclass(frozen=True)
class SessionStats:
    """Point-in-time accounting for one session (RequestStats' sibling)."""

    session_id: str
    state: str  # "open" | "spilled" | "finalized" | "aborted" | "lease_expired"
    n_frames: int
    duplicates: int
    sequence_rejects: int
    pending_replays: int
    restores: int
    journal_bytes: int
    lease_remaining_s: float


class _Session:
    def __init__(
        self,
        sid: str,
        cfg: FFCzConfig,
        stream_cfg: TemporalConfig,
        codec: TemporalCodec,
        journal: Any,
        lease_s: float,
        now: float,
    ):
        self.sid = sid
        self.cfg = cfg
        self.stream_cfg = stream_cfg
        self.codec = codec
        self.journal = journal
        self.enc: Optional[StreamEncoder] = codec.open_stream()
        self.receipts: List[FrameReceipt] = []
        # encoded-but-unjournaled frame: (payload, is_key, stats, frame_digest)
        self.pending: Optional[Tuple[bytes, bool, dict, bytes]] = None
        # container assembled by a finalize whose CLOSE write then failed —
        # the retry must not call finish() twice
        self.container: Optional[bytes] = None
        self.lease_s = lease_s
        self.lease_deadline = now + lease_s
        self.last_touch = now
        self.state = "open"
        self.stats = {
            "duplicates": 0,
            "sequence_rejects": 0,
            "pending_replays": 0,
            "restores": 0,
        }
        self.lock = threading.RLock()


def _frame_digest(frame: np.ndarray) -> bytes:
    """Canonical content hash of one frame (float32, C order) — the
    idempotency identity for duplicate-seq retries."""
    x32 = np.ascontiguousarray(np.asarray(frame, dtype=np.float32))
    return hashlib.sha256(x32.tobytes()).digest()


def _config_json(cfg: FFCzConfig, stream_cfg: TemporalConfig, sid: str) -> bytes:
    if cfg.E_roi is not None:
        raise ValueError(
            "sessions journal their config as JSON; ROI bound grids (E_roi) "
            "are per-request arrays and cannot back a durable session"
        )
    doc = {
        "v": 1,
        "session_id": sid,
        "cfg": dataclasses.asdict(cfg),
        "stream": dataclasses.asdict(stream_cfg),
    }
    return json.dumps(doc, sort_keys=True).encode("utf-8")


# -- the manager -------------------------------------------------------------


class StreamSessionManager:
    """Live-session registry over :class:`~repro.core.temporal.TemporalCodec`
    (see module docstring for the durability contract).

    Thread-safety: a registry lock guards the session table and manager
    counters; each session carries its own lock for append/finalize work, so
    concurrent appends to *different* sessions do not serialize.  When driven
    through :class:`~repro.serving.ffcz_service.FFCzService` the single
    encode worker already serializes per-session operations in submission
    order (per-session FIFO); the locks make direct concurrent use safe too.
    """

    def __init__(
        self,
        base: Any,
        engine: Optional[CorrectionEngine] = None,
        *,
        max_sessions: int = 8,
        lease_s: float = 60.0,
        max_history_bytes: int = 0,
        clock: Callable[[], float] = time.monotonic,
        injector: Any = None,
        journal_factory: Optional[Callable[[str], Any]] = None,
    ):
        self.base = base
        self.engine = engine or default_engine()
        self.max_sessions = int(max_sessions)
        self.lease_s = float(lease_s)
        self.max_history_bytes = int(max_history_bytes)
        self._clock = clock
        self.injector = injector
        self._journal_factory = journal_factory or (lambda sid: MemoryJournal())
        self._lock = threading.Lock()
        self._sessions: Dict[str, _Session] = {}
        self._closed: Dict[str, dict] = {}  # tombstones: reason/container/receipts
        self._next_sid = 0
        self.counters: Dict[str, int] = {
            "opened": 0,
            "finalized": 0,
            "aborted": 0,
            "lease_evictions": 0,
            "spills": 0,
            "restores": 0,
            "duplicates": 0,
            "sequence_rejects": 0,
            "recoveries": 0,
            "recovered_frames": 0,
            "resyncs": 0,
        }

    # -- helpers -----------------------------------------------------------

    def _count(self, name: str, n: int = 1) -> None:
        with self._lock:
            self.counters[name] += n

    def _fire(self, site: str, uid: str) -> None:
        if self.injector is not None:
            self.injector.fire(site, uid=uid)

    def _get(self, sid: str) -> _Session:
        with self._lock:
            s = self._sessions.get(sid)
            if s is not None:
                return s
            tomb = self._closed.get(sid)
        if tomb is not None:
            raise SessionNotFound(
                f"session {sid} is closed ({tomb['reason']})", session_id=sid
            )
        raise SessionNotFound(f"unknown session {sid}", session_id=sid)

    @property
    def live_sessions(self) -> List[str]:
        with self._lock:
            return sorted(self._sessions)

    def next_seq(self, session_id: str) -> int:
        """The seq the session expects next (what a recovered client must
        resume from after :meth:`recover` dropped a damaged tail)."""
        s = self._get(session_id)
        with s.lock:
            return len(s.receipts)

    def session_stats(self, session_id: str) -> SessionStats:
        with self._lock:
            s = self._sessions.get(session_id)
            tomb = self._closed.get(session_id)
        if s is None:
            if tomb is None:
                raise SessionNotFound(f"unknown session {session_id}", session_id=session_id)
            return SessionStats(
                session_id=session_id,
                state=tomb["reason"],
                n_frames=tomb["n_frames"],
                duplicates=tomb["stats"]["duplicates"],
                sequence_rejects=tomb["stats"]["sequence_rejects"],
                pending_replays=tomb["stats"]["pending_replays"],
                restores=tomb["stats"]["restores"],
                journal_bytes=tomb["journal_bytes"],
                lease_remaining_s=0.0,
            )
        with s.lock:
            return SessionStats(
                session_id=session_id,
                state="spilled" if s.enc is None else s.state,
                n_frames=len(s.receipts),
                duplicates=s.stats["duplicates"],
                sequence_rejects=s.stats["sequence_rejects"],
                pending_replays=s.stats["pending_replays"],
                restores=s.stats["restores"],
                journal_bytes=int(s.journal.size()),
                lease_remaining_s=max(0.0, s.lease_deadline - self._clock()),
            )

    def closed_info(self, session_id: str) -> dict:
        """Tombstone of a closed session: ``reason``, ``n_frames``, and — for
        finalized / lease-expired sessions — the ``container`` bytes, so a
        client racing a lease eviction can still fetch its stream."""
        with self._lock:
            tomb = self._closed.get(session_id)
        if tomb is None:
            raise SessionNotFound(
                f"no closed session {session_id}", session_id=session_id
            )
        return dict(tomb)

    # -- lifecycle ---------------------------------------------------------

    def open_session(
        self,
        cfg: FFCzConfig = FFCzConfig(),
        stream: TemporalConfig = TemporalConfig(),
        *,
        session_id: Optional[str] = None,
        lease_s: Optional[float] = None,
        journal: Any = None,
    ) -> str:
        """Admit a new live session; returns its id.

        Validates the config (pspec/ROI grids reject — the journal carries
        config as JSON), writes the OPEN record, and starts the lease.
        Raises :class:`ResourceExhausted` when ``max_sessions`` live
        sessions already exist (expired leases are swept first).
        """
        self.sweep()
        codec = TemporalCodec(self.base, cfg, stream=stream, engine=self.engine)
        now = self._clock()
        with self._lock:
            if len(self._sessions) >= self.max_sessions:
                raise ResourceExhausted(
                    f"admission rejected: {len(self._sessions)} live sessions "
                    f">= max_sessions={self.max_sessions}",
                    stage="admit",
                )
            sid = session_id
            if sid is None:
                self._next_sid += 1
                sid = f"sess-{self._next_sid}"
            if sid in self._sessions:
                raise SessionNotFound(f"session {sid} is already live", session_id=sid)
            self._closed.pop(sid, None)
        open_rec = _record(_J_OPEN, _config_json(cfg, stream, sid))
        jrn = journal if journal is not None else self._journal_factory(sid)
        jrn.append(open_rec)
        sess = _Session(
            sid, cfg, stream, codec, jrn, lease_s or self.lease_s, now
        )
        with self._lock:
            self._sessions[sid] = sess
            self.counters["opened"] += 1
        return sid

    def append_frame(
        self,
        session_id: str,
        seq: int,
        frame: np.ndarray,
        *,
        fire_uid: Optional[str] = None,
    ) -> FrameReceipt:
        """Append frame ``seq`` to a live session; returns its durable receipt.

        Idempotent: a duplicate seq with identical content returns the
        original receipt (``duplicate=True``); a duplicate with different
        content, a gap, or a negative seq raises
        :class:`SessionSequenceError`.  The receipt is minted only after the
        frame's journal record is durable — a journal failure leaves the
        encoded frame *pending* and the retry re-journals without
        re-encoding (``pending_replays``).  A successful append refreshes
        the lease and then applies history-memory pressure (idle sessions
        spill to their journals first).
        """
        self.sweep()
        sess = self._get(session_id)
        uid = fire_uid if fire_uid is not None else f"{session_id}#{seq}"
        with sess.lock:
            if sess.state != "open":
                raise SessionNotFound(
                    f"session {session_id} is closed ({sess.state})",
                    session_id=session_id,
                )
            if sess.container is not None:
                # a finalize assembled the container but its CLOSE write is
                # still retrying — the frame set is sealed
                raise SessionNotFound(
                    f"session {session_id} is finalizing; no further appends",
                    session_id=session_id,
                )
            seq = int(seq)
            next_seq = len(sess.receipts)
            if seq < 0:
                sess.stats["sequence_rejects"] += 1
                self._count("sequence_rejects")
                raise SessionSequenceError(
                    f"negative frame seq {seq}",
                    session_id=session_id,
                    expected=next_seq,
                    got=seq,
                )
            digest = _frame_digest(frame)
            if seq < next_seq:
                cached = sess.receipts[seq]
                if cached.frame_digest != digest.hex():
                    sess.stats["sequence_rejects"] += 1
                    self._count("sequence_rejects")
                    raise SessionSequenceError(
                        f"frame seq {seq} re-sent with different content "
                        f"(an idempotent retry must repeat the same frame)",
                        session_id=session_id,
                        expected=next_seq,
                        got=seq,
                    )
                sess.stats["duplicates"] += 1
                self._count("duplicates")
                sess.lease_deadline = self._clock() + sess.lease_s
                return dataclasses.replace(cached, duplicate=True)
            if seq > next_seq:
                sess.stats["sequence_rejects"] += 1
                self._count("sequence_rejects")
                raise SessionSequenceError(
                    f"frame seq gap: got {seq}, expected {next_seq} "
                    f"(frames must arrive densely in order)",
                    session_id=session_id,
                    expected=next_seq,
                    got=seq,
                )
            self._fire("session_append", uid)
            self._materialize(sess)
            enc = sess.enc
            if sess.pending is None:
                payload = enc.add_frame(frame)
                is_key = enc._frames[-1][1]
                fstats = enc.frame_stats[-1]
                sess.pending = (payload, is_key, fstats, digest)
            else:
                # journal write failed after the encoder committed: replay
                # the pending frame instead of re-encoding it
                payload, is_key, fstats, pdigest = sess.pending
                if pdigest != digest:
                    sess.stats["sequence_rejects"] += 1
                    self._count("sequence_rejects")
                    raise SessionSequenceError(
                        f"frame seq {seq} retried with different content than "
                        f"its pending (un-acked) encode",
                        session_id=session_id,
                        expected=next_seq,
                        got=seq,
                    )
                sess.stats["pending_replays"] += 1
            receipt = self._journal_frame(sess, seq, uid)
            sess.lease_deadline = self._clock() + sess.lease_s
            sess.last_touch = self._clock()
        self._enforce_memory(exclude=session_id)
        return receipt

    def _journal_frame(self, sess: _Session, seq: int, uid: str) -> FrameReceipt:
        """Write the pending frame's WAL record, then mint its receipt.
        Caller holds the session lock and has ``sess.pending`` set."""
        payload, is_key, fstats, digest = sess.pending
        enc = sess.enc
        rec = _frame_record(
            seq,
            is_key,
            digest,
            enc._E0,
            enc._Delta0,
            enc._shape,
            enc._block,
            payload,
        )
        self._fire("session_journal", uid)
        sess.journal.append(rec)
        receipt = FrameReceipt(
            seq=seq,
            keyframe=is_key,
            digest=hashlib.sha256(payload).hexdigest(),
            frame_digest=digest.hex(),
            n_bytes=len(payload),
            iterations=int(fstats.get("iterations", 0)),
            converged=fstats.get("converged"),
            restored=bool(fstats.get("restored", False)),
        )
        sess.receipts.append(receipt)
        sess.pending = None
        return receipt

    def flush(self, session_id: str) -> int:
        """Flush the session's journal sink; returns its durable byte size.
        (Appends are already written-ahead per frame — flush exists for
        sinks whose durability needs an explicit barrier.)"""
        sess = self._get(session_id)
        with sess.lock:
            sess.journal.flush()
            sess.lease_deadline = self._clock() + sess.lease_s
            return int(sess.journal.size())

    def finalize(
        self, session_id: str, *, fire_uid: Optional[str] = None, _reason: str = "finalized"
    ) -> bytes:
        """Assemble the session's ``FFCS`` container and close it.

        A pending (encoded-but-unjournaled) frame is journaled first, so the
        container never contains a frame the journal does not.  The
        tombstone keeps the container bytes — a client racing finalize (or a
        lease eviction) can fetch them via :meth:`closed_info`.
        """
        sess = self._get(session_id)
        uid = fire_uid if fire_uid is not None else f"{session_id}#finalize"
        with sess.lock:
            if sess.state != "open":
                raise SessionNotFound(
                    f"session {session_id} is closed ({sess.state})",
                    session_id=session_id,
                )
            if not sess.receipts and sess.pending is None:
                raise SessionError(
                    f"session {session_id} has no frames to finalize; abort it instead",
                    session_id=session_id,
                )
            self._materialize(sess)
            if sess.pending is not None:
                self._journal_frame(sess, len(sess.receipts), uid)
            if sess.container is None:
                sess.container = sess.enc.finish()
            container = sess.container
            self._fire("session_journal", uid)
            sess.journal.append(
                _record(_J_CLOSE, bytes([_CLOSE_CODES[_reason]]))
            )
            self._close(sess, _reason, container)
        self._count(
            "lease_evictions" if _reason == "lease_expired" else "finalized"
        )
        return container

    def abort(self, session_id: str, *, _reason: str = "aborted") -> None:
        """Drop a live session: CLOSE record (best-effort), no container."""
        sess = self._get(session_id)
        with sess.lock:
            if sess.state != "open":
                raise SessionNotFound(
                    f"session {session_id} is closed ({sess.state})",
                    session_id=session_id,
                )
            try:
                sess.journal.append(_record(_J_CLOSE, bytes([_CLOSE_CODES["aborted"]])))
            except Exception:  # noqa: BLE001 - abort must always succeed
                pass
            self._close(sess, _reason, None)
        self._count("lease_evictions" if _reason == "lease_expired" else "aborted")

    def _close(self, sess: _Session, reason: str, container: Optional[bytes]) -> None:
        """Caller holds the session lock."""
        sess.state = reason
        try:
            journal_bytes = int(sess.journal.size())
        except Exception:  # noqa: BLE001
            journal_bytes = 0
        try:
            sess.journal.close()
        except Exception:  # noqa: BLE001
            pass
        sess.enc = None
        sess.pending = None
        with self._lock:
            self._sessions.pop(sess.sid, None)
            self._closed[sess.sid] = {
                "reason": reason,
                "n_frames": len(sess.receipts),
                "container": container,
                "receipts": tuple(sess.receipts),
                "stats": dict(sess.stats),
                "journal_bytes": journal_bytes,
            }

    # -- leases ------------------------------------------------------------

    def sweep(self) -> List[str]:
        """Close every session whose lease expired: finalize to a valid
        partial container when it has frames, abort when empty.  Called on
        every manager operation, or explicitly by a serving loop."""
        now = self._clock()
        with self._lock:
            expired = [
                s for s in self._sessions.values() if s.lease_deadline < now
            ]
        evicted: List[str] = []
        for sess in expired:
            try:
                if sess.receipts or sess.pending is not None:
                    self.finalize(sess.sid, _reason="lease_expired")
                else:
                    self.abort(sess.sid, _reason="lease_expired")
            except SessionNotFound:
                continue  # raced another closer
            evicted.append(sess.sid)
        return evicted

    # -- memory pressure (spill / resume) -----------------------------------

    def _enforce_memory(self, exclude: str) -> None:
        if self.max_history_bytes <= 0:
            return
        with self._lock:
            live = list(self._sessions.values())
        total = sum(s.enc.history_nbytes for s in live if s.enc is not None)
        if total <= self.max_history_bytes:
            return
        idle = sorted(
            (s for s in live if s.sid != exclude and s.enc is not None),
            key=lambda s: s.last_touch,
        )
        for sess in idle:
            if total <= self.max_history_bytes:
                break
            with sess.lock:
                if sess.enc is None or sess.state != "open":
                    continue
                total -= sess.enc.history_nbytes
                # the journal already holds every acked frame; a pending
                # (un-acked) frame is deliberately dropped — its retry
                # re-encodes against the restored state
                sess.enc = None
                sess.pending = None
            self._count("spills")

    def _materialize(self, sess: _Session) -> None:
        """Rebuild a spilled session's encoder from its own journal.
        Caller holds the session lock."""
        if sess.enc is not None:
            return
        parsed = parse_journal(sess.journal.read())
        frames = [(f.payload, f.keyframe) for f in parsed.frames]
        if len(frames) != len(sess.receipts) or parsed.damaged:
            raise BlobCorruptError(
                f"session {sess.sid} journal lost frames while spilled: "
                f"{len(frames)} durable vs {len(sess.receipts)} acked",
                stage="session",
            )
        if not frames:
            sess.enc = sess.codec.open_stream()
        else:
            f0 = parsed.frames[0]
            sess.enc = sess.codec.restore_stream(
                frames,
                shape=f0.shape,
                block=f0.block,
                E0=f0.E0,
                Delta0=f0.Delta0,
            )
        sess.stats["restores"] += 1
        self._count("restores")

    # -- crash recovery ----------------------------------------------------

    def recover(
        self,
        journal: Any,
        *,
        session_id: Optional[str] = None,
        journal_out: Any = None,
        lease_s: Optional[float] = None,
    ) -> str:
        """Rebuild a live session from a journal (bytes or a sink).

        The durable prefix ends at the first damaged record (per-record
        CRC); if the surviving frame chain still fails to replay, recovery
        drops back whole keyframe groups until a prefix restores — the
        keyframe-resync degradation rung.  The recovered session writes a
        fresh compacted journal (``journal_out`` or the manager's factory),
        so it is immediately durable again; receipts for recovered frames
        carry ``restored=True``.  Clients resume from :meth:`next_seq`.
        """
        data = journal if isinstance(journal, (bytes, bytearray)) else journal.read()
        parsed = parse_journal(bytes(data))
        if parsed.open_info is None:
            raise BlobCorruptError(
                "journal has no intact OPEN record: nothing to recover",
                stage="session",
            )
        if parsed.closed is not None:
            raise SessionNotFound(
                f"journal records a closed session ({parsed.closed}); "
                "its container was already finalized",
                session_id=parsed.open_info.get("session_id"),
            )
        try:
            cfg = FFCzConfig(**parsed.open_info["cfg"])
            stream_cfg = TemporalConfig(**parsed.open_info["stream"])
            sid = session_id or str(parsed.open_info["session_id"])
        except (KeyError, TypeError, ValueError) as e:
            raise BlobCorruptError(
                f"journal OPEN record does not describe a session config: {e}",
                stage="session",
                cause=e,
            ) from e
        self.sweep()
        with self._lock:
            if sid in self._sessions:
                raise SessionNotFound(f"session {sid} is already live", session_id=sid)
            if len(self._sessions) >= self.max_sessions:
                raise ResourceExhausted(
                    f"admission rejected: {len(self._sessions)} live sessions "
                    f">= max_sessions={self.max_sessions}",
                    stage="admit",
                )
            self._closed.pop(sid, None)
        codec = TemporalCodec(self.base, cfg, stream=stream_cfg, engine=self.engine)

        # dense seq prefix: a journal replayed out of order (or with a gap
        # from interleaved writers) is only durable up to the break
        kept: List[_JournalFrame] = []
        for i, f in enumerate(parsed.frames):
            if f.seq != i:
                break
            kept.append(f)

        # keyframe-resync degradation: drop whole keyframe groups until the
        # chain replays (an intact journal replays on the first try)
        enc: Optional[StreamEncoder] = None
        resyncs = 0
        while kept:
            try:
                f0 = kept[0]
                enc = codec.restore_stream(
                    [(f.payload, f.keyframe) for f in kept],
                    shape=f0.shape,
                    block=f0.block,
                    E0=f0.E0,
                    Delta0=f0.Delta0,
                )
                break
            except FFCzError:
                last_key = max(i for i, f in enumerate(kept) if f.keyframe)
                if last_key == 0:
                    kept = []
                    break
                kept = kept[:last_key]
                resyncs += 1
        if enc is None:
            enc = codec.open_stream()
            kept = []

        now = self._clock()
        jrn = journal_out if journal_out is not None else self._journal_factory(sid)
        jrn.append(_record(_J_OPEN, _config_json(cfg, stream_cfg, sid)))
        receipts: List[FrameReceipt] = []
        for f in kept:
            jrn.append(
                _frame_record(
                    f.seq, f.keyframe, f.frame_digest, f.E0, f.Delta0,
                    f.shape, f.block, f.payload,
                )
            )
            receipts.append(
                FrameReceipt(
                    seq=f.seq,
                    keyframe=f.keyframe,
                    digest=hashlib.sha256(f.payload).hexdigest(),
                    frame_digest=f.frame_digest.hex(),
                    n_bytes=len(f.payload),
                    restored=True,
                )
            )
        sess = _Session(sid, cfg, stream_cfg, codec, jrn, lease_s or self.lease_s, now)
        sess.enc = enc
        sess.receipts = receipts
        with self._lock:
            self._sessions[sid] = sess
            self.counters["recoveries"] += 1
            self.counters["recovered_frames"] += len(kept)
            self.counters["resyncs"] += resyncs
            self.counters["opened"] += 1
        return sid
