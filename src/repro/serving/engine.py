"""Batched decode engine: continuous batching over a request queue.

Flow per admitted batch: right-align prompts -> prefill (one jitted call) ->
optional FFCz KV-cache compression -> N greedy decode steps (one jitted call
each).  Designed so every jitted shape is a function of (batch, max_len)
only — requests of different lengths share compiled programs via front
padding.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ArchConfig
from repro.models.model import build_model
from repro.serving.kv_compress import compress_cache


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_len: int = 512
    max_new_tokens: int = 32


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (prompt_len,) int32
    max_new_tokens: int = 16


class ServingEngine:
    def __init__(self, cfg: ArchConfig, serve: ServeConfig, params=None, rng_seed: int = 0):
        self.cfg = cfg
        self.serve = serve
        self.bundle = build_model(cfg)
        self.params = params if params is not None else self.bundle.init(jax.random.PRNGKey(rng_seed))
        self._prefill = jax.jit(self.bundle.prefill)
        self._decode = jax.jit(self.bundle.decode)
        self.queue: List[Request] = []
        self._uid = 0

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16) -> int:
        """Validate and queue one request.

        Validation happens at submission, not batch assembly: an empty
        prompt admitted here would crash ``_make_batch``'s max() (and an
        out-of-vocab id would index garbage embeddings) several steps later,
        in a batch shared with innocent requests.
        """
        prompt = np.asarray(prompt, dtype=np.int32)
        if prompt.ndim != 1 or prompt.size == 0:
            raise ValueError(f"prompt must be a non-empty 1-D token array, got shape {prompt.shape}")
        if prompt.size > self.serve.max_len:
            raise ValueError(f"prompt length {prompt.size} exceeds max_len={self.serve.max_len}")
        lo, hi = int(prompt.min()), int(prompt.max())
        if lo < 0 or hi >= self.cfg.vocab:
            raise ValueError(f"prompt ids must be in [0, {self.cfg.vocab}), got range [{lo}, {hi}]")
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {max_new_tokens}")
        self._uid += 1
        self.queue.append(Request(self._uid, prompt, max_new_tokens))
        return self._uid

    def _make_batch(self, reqs: List[Request]) -> Dict[str, Any]:
        """Front-pad prompts to a common length (pad tokens attend causally
        before every real token, and logits are taken from the last position,
        so padding affects only wasted compute, not outputs for greedy
        decoding from the final position)."""
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((len(reqs), plen), dtype=np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.prompt) :] = r.prompt
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (len(reqs), self.cfg.vision_tokens, self.cfg.vision_dim), dtype=jnp.float32
            )
        if self.cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (len(reqs), self.cfg.encoder_seq, self.cfg.d_model), dtype=jnp.float32
            )
        return batch

    def step(self) -> List[Dict[str, Any]]:
        """Serve one admitted batch from the queue; returns completions."""
        if not self.queue:
            return []
        reqs, self.queue = self.queue[: self.serve.max_batch], self.queue[self.serve.max_batch :]
        batch = self._make_batch(reqs)
        n_new = max(r.max_new_tokens for r in reqs)
        cache = self.bundle.init_cache(len(reqs), batch["tokens"].shape[1] + n_new)
        logits, cache = self._prefill(self.params, batch, cache)
        if self.cfg.compression.kv_cache_compression and self.cfg.family != "ssm":
            cache = compress_cache(cache, self.cfg.compression)
        outs = [jnp.argmax(logits[:, -1], axis=-1)]
        for _ in range(n_new - 1):
            logits, cache = self._decode(self.params, outs[-1][:, None], cache)
            outs.append(jnp.argmax(logits[:, -1], axis=-1))
        gen = np.stack([np.asarray(o) for o in outs], axis=1)  # (b, n_new)
        return [
            {"uid": r.uid, "tokens": gen[i, : r.max_new_tokens].tolist()}
            for i, r in enumerate(reqs)
        ]
