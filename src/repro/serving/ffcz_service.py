"""Fault-tolerant FFCz compression service: queue, retries, degradation ladder.

:class:`FFCzService` fronts one :class:`~repro.core.engine.CorrectionEngine`
with a request queue admitting heterogeneous (shape, dtype, bound) work:

  whole-field compress    the paper pipeline (plan / base / execute / encode),
                          one request per field
  pencil compress         blockwise requests bucketed — up to ``max_batch``
                          queued tensors run as ONE ``engine.correct`` call
                          on the donated batched buffer, each with its own
                          resolved (E, Delta)
  decompress              hardened decode of service or FFCz blobs

The headline is the failure path, not the happy path.  Every request drains
to exactly one of completed-within-bounds or rejected-with-reason:

  retries      transient errors (host codec, device dispatch) re-run the
               failing stage with exponential backoff + seeded jitter, up to
               ``max_retries`` per request, inside a per-request deadline.
  ladder       when retries exhaust on the POCS transform — or the loop ends
               non-converged — the service degrades instead of failing:
               first a relaxed re-run (``max_iters`` x4, over-relaxation),
               then fft_impl rungs pallas -> packed -> xla.  Each rung taken
               is recorded in the request's stats.
  bisect       a device allocation failure on a pencil bucket splits the
               bucket and runs the halves (recursively, down to one request,
               which is then rejected with the structured OOM).
  reject       infeasible bound intersections (:class:`InfeasibleBound`),
               corrupt blobs (:class:`BlobCorruptError`), and exhausted
               budgets return a structured error dict — never a raw
               exception out of :meth:`step`, and never a hang: every
               :meth:`step` retires at least one queued request.
  timeout      a request whose deadline passes mid-stage is rejected with
               :class:`DeadlineExceeded` (disposition ``"timeout"``).

A :class:`~repro.runtime.faults.FaultInjector` can be threaded through every
stage boundary for deterministic chaos testing (tests/test_faults.py).
"""

from __future__ import annotations

import dataclasses
import struct
import time
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.edits import EncodedEdits, decode_edits
from repro.core.engine import CorrectionEngine, default_engine
from repro.core.errors import (
    DeadlineExceeded,
    FFCzError,
    InfeasibleBound,
    ResourceExhausted,
    BlobCorruptError,
    classify_exception,
)
from repro.core.ffcz import FFCz, FFCzBlob, FFCzConfig

__all__ = [
    "ServiceConfig",
    "ServiceResponse",
    "RequestStats",
    "FFCzService",
    "decode_pencil_blob",
]

# fft_impl degradation rungs: each key falls back to its value when the POCS
# transform keeps failing (or won't converge); "xla" is the floor.
_LADDER = {"pallas": "packed", "packed": "xla"}

# service pencil-blob envelope: magic, version, <ddIB> E/Delta/block/ndim,
# ndim * u64 shape, <QQQ> section lengths, sections, trailing u32 CRC32 of
# every preceding byte.  A new wire format (no legacy writers), so the CRC
# is unconditional.
_PENCIL_MAGIC = b"FFSB"
_PENCIL_VERSION = 1
_PENCIL_HEADER = "<ddIB"


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Queue, retry, and degradation knobs for one :class:`FFCzService`."""

    max_batch: int = 8  # pencil requests fused per engine.correct call
    block: int = 256  # pencil length for blockwise requests
    max_iters: int = 50  # POCS budget for pencil buckets
    deadline_s: float = 30.0  # default per-request deadline
    max_retries: int = 3  # per-request transient-retry budget
    backoff_base_s: float = 0.002
    backoff_factor: float = 2.0
    backoff_jitter: float = 0.5  # uniform [0, jitter) fraction added per delay
    # Non-convergence rung: one re-run with max_iters x this and
    # over-relaxed projections before encoding a non-converged result.
    relax_on_nonconvergence: bool = True
    relax_iters_mult: int = 4
    relax_factor: float = 1.3
    seed: int = 0  # backoff-jitter stream (determinism under test)


@dataclasses.dataclass(frozen=True)
class RequestStats:
    """Per-request accounting: what the failure machinery actually did."""

    attempts: int  # transient retries consumed
    rungs: Tuple[str, ...]  # degradation rungs taken, in order
    latency_s: float  # admit -> retire (includes injected slowness)
    fft_impl: Optional[str] = None  # transform the final attempt ran with
    converged: Optional[bool] = None
    final_violations: int = 0


@dataclasses.dataclass(frozen=True)
class ServiceResponse:
    uid: str
    ok: bool
    payload: Any = None  # blob bytes (compress) or ndarray (decompress)
    error: Optional[dict] = None  # FFCzError.to_dict() when not ok
    stats: Optional[RequestStats] = None


@dataclasses.dataclass
class _Request:
    uid: str
    kind: str  # "field" | "pencils" | "decompress"
    payload: Any
    cfg: Any  # FFCzConfig (field) | (E_rel, Delta_rel) (pencils) | None
    deadline_s: float
    t0: float = 0.0
    penalty_s: float = 0.0  # injected slowness, charged against the deadline
    attempts: int = 0
    rungs: List[str] = dataclasses.field(default_factory=list)
    fft_impl: Optional[str] = None
    converged: Optional[bool] = None
    final_violations: int = 0

    def elapsed(self, now: float) -> float:
        return (now - self.t0) + self.penalty_s


class FFCzService:
    """Continuous-batching FFCz compress/decompress front end (see module
    docstring for the failure-path contract)."""

    def __init__(
        self,
        base: Any,
        engine: Optional[CorrectionEngine] = None,
        config: ServiceConfig = ServiceConfig(),
        injector: Any = None,
        clock: Callable[[], float] = time.monotonic,
        sleep: Callable[[float], None] = time.sleep,
    ):
        self.base = base
        self.engine = engine or default_engine()
        self.config = config
        self.injector = injector  # None, or a repro.runtime.faults.FaultInjector
        self._clock = clock
        self._sleep = sleep
        self._rng = np.random.default_rng(config.seed)
        self._queue: List[_Request] = []
        self._next_uid = 0
        self.counters: Dict[str, int] = {
            "completed": 0,
            "rejected": 0,
            "retries": 0,
            "fallbacks": 0,
            "relaxes": 0,
            "bisects": 0,
            "timeouts": 0,
        }

    # -- admission ---------------------------------------------------------

    def _admit(self, req: _Request) -> str:
        req.t0 = self._clock()
        if self.injector is not None:
            # injected slowness is charged to the request's clock, not slept,
            # so deadline tests run in real milliseconds
            req.penalty_s = self.injector.sleep_s()
        self._queue.append(req)
        return req.uid

    def _uid(self, uid: Optional[str]) -> str:
        if uid is not None:
            return uid
        self._next_uid += 1
        return f"req-{self._next_uid}"

    def submit_compress(
        self,
        x: np.ndarray,
        cfg: FFCzConfig,
        uid: Optional[str] = None,
        deadline_s: Optional[float] = None,
    ) -> str:
        """Queue one whole-field compression (the paper pipeline)."""
        x = np.asarray(x)
        if x.size == 0:
            raise ValueError("cannot compress an empty field")
        return self._admit(
            _Request(
                uid=self._uid(uid),
                kind="field",
                payload=x,
                cfg=cfg,
                deadline_s=self.config.deadline_s if deadline_s is None else deadline_s,
            )
        )

    def submit_pencils(
        self,
        x: np.ndarray,
        E_rel: float,
        Delta_rel: float,
        uid: Optional[str] = None,
        deadline_s: Optional[float] = None,
    ) -> str:
        """Queue one tensor for blockwise (pencil) compression.

        Queued pencil requests are fused: up to ``max_batch`` of them run as
        a single batched ``engine.correct`` call, each with its own resolved
        bounds — heterogeneous shapes and dtypes batch freely because the
        engine tiles every tensor into ``block``-length pencils.
        """
        x = np.asarray(x)
        if x.size == 0:
            raise ValueError("cannot compress an empty tensor")
        if not (E_rel > 0 and Delta_rel > 0):
            raise ValueError(f"bounds must be positive, got E_rel={E_rel}, Delta_rel={Delta_rel}")
        return self._admit(
            _Request(
                uid=self._uid(uid),
                kind="pencils",
                payload=x,
                cfg=(float(E_rel), float(Delta_rel)),
                deadline_s=self.config.deadline_s if deadline_s is None else deadline_s,
            )
        )

    def submit_decompress(
        self,
        blob: bytes,
        uid: Optional[str] = None,
        deadline_s: Optional[float] = None,
    ) -> str:
        """Queue a decode of service pencil bytes or a whole-field FFCz blob."""
        return self._admit(
            _Request(
                uid=self._uid(uid),
                kind="decompress",
                payload=bytes(blob),
                cfg=None,
                deadline_s=self.config.deadline_s if deadline_s is None else deadline_s,
            )
        )

    # -- scheduling --------------------------------------------------------

    def step(self) -> List[ServiceResponse]:
        """Retire one unit of work: a pencil bucket (up to ``max_batch``
        fused requests) or one field/decompress request.

        Always removes the popped requests from the queue — a request never
        re-enqueues, retries happen bounded *within* the step — so ``step``
        makes progress whenever the queue is non-empty and :meth:`drain`
        terminates by induction.
        """
        if not self._queue:
            return []
        if self._queue[0].kind == "pencils":
            bucket: List[_Request] = []
            rest: List[_Request] = []
            for r in self._queue:
                if r.kind == "pencils" and len(bucket) < self.config.max_batch:
                    bucket.append(r)
                else:
                    rest.append(r)
            self._queue = rest
            return self._run_pencil_bucket(bucket)
        req = self._queue.pop(0)
        if req.kind == "field":
            return [self._run_field(req)]
        return [self._run_decompress(req)]

    def drain(self) -> Dict[str, ServiceResponse]:
        """Run :meth:`step` until the queue is empty; responses keyed by uid."""
        out: Dict[str, ServiceResponse] = {}
        while self._queue:
            for resp in self.step():
                out[resp.uid] = resp
        return out

    # -- failure machinery -------------------------------------------------

    def _check_deadline(self, req: _Request) -> None:
        if req.elapsed(self._clock()) > req.deadline_s:
            raise DeadlineExceeded(
                f"request {req.uid} exceeded its {req.deadline_s:g}s deadline",
                stage="service",
            )

    def _fire(self, site: str, req: _Request) -> None:
        if self.injector is not None:
            self.injector.fire(site, uid=req.uid)

    def _attempt(self, req: _Request, stage: str, fn: Callable[[], Any]) -> Any:
        """Run ``fn`` with deadline enforcement and bounded transient retries.

        Non-retryable and budget-exhausted errors re-raise classified; each
        retry backs off exponentially with seeded jitter and records a
        ``retry:<stage>`` rung.
        """
        while True:
            self._check_deadline(req)
            try:
                return fn()
            except Exception as e:  # noqa: BLE001 - classified immediately below
                err = classify_exception(e, stage)
                if not err.retryable or req.attempts >= self.config.max_retries:
                    raise err from e
                req.attempts += 1
                self.counters["retries"] += 1
                req.rungs.append(f"retry:{stage}")
                delay = self.config.backoff_base_s * (
                    self.config.backoff_factor ** (req.attempts - 1)
                )
                delay *= 1.0 + self.config.backoff_jitter * float(self._rng.random())
                self._sleep(delay)

    def _reject(self, req: _Request, err: FFCzError) -> ServiceResponse:
        self.counters["rejected"] += 1
        if err.disposition == "timeout":
            self.counters["timeouts"] += 1
        return ServiceResponse(
            uid=req.uid, ok=False, error=err.to_dict(), stats=self._stats(req)
        )

    def _complete(self, req: _Request, payload: Any) -> ServiceResponse:
        self.counters["completed"] += 1
        return ServiceResponse(uid=req.uid, ok=True, payload=payload, stats=self._stats(req))

    def _stats(self, req: _Request) -> RequestStats:
        return RequestStats(
            attempts=req.attempts,
            rungs=tuple(req.rungs),
            latency_s=req.elapsed(self._clock()),
            fft_impl=req.fft_impl,
            converged=req.converged,
            final_violations=req.final_violations,
        )

    # -- whole-field path --------------------------------------------------

    def _run_field(self, req: _Request) -> ServiceResponse:
        try:
            blob = self._compress_field(req)
            return self._complete(req, blob.to_bytes())
        except FFCzError as err:
            return self._reject(req, err)
        except Exception as e:  # noqa: BLE001 - terminal safety net
            return self._reject(req, classify_exception(e, "service"))

    def _compress_field(self, req: _Request) -> FFCzBlob:
        cfg: FFCzConfig = req.cfg
        x32 = np.asarray(req.payload, dtype=np.float32)
        plan = self._attempt(req, "plan", lambda: self.engine.plan_field(x32, cfg))

        def _base():
            self._fire("codec", req)
            blob = self.base.compress(x32, plan.E_proj)
            return blob, np.asarray(self.base.decompress(blob), dtype=np.float32)

        base_blob, x_hat = self._attempt(req, "base", _base)
        eps0 = x_hat - x32

        result, plan = self._execute_with_ladder(req, eps0, plan)
        req.converged = bool(result.converged)
        req.final_violations = int(result.final_violations)

        def _encode():
            self._fire("codec", req)
            return self.engine.encode_field(result, plan)

        se, fe = self._attempt(req, "encode", _encode)
        return FFCzBlob(
            base_blob=base_blob,
            spat_edits=se,
            freq_edits=fe,
            E=plan.E,
            Delta_scalar=plan.delta_scalar,
            pointwise_delta=plan.pointwise_bytes(),
            shape=plan.shape,
            crc=cfg.crc,
        )

    def _execute_with_ladder(self, req: _Request, eps0: np.ndarray, plan):
        """EXECUTE with the degradation ladder (see module docstring).

        Terminates: the impl chain pallas -> packed -> xla is finite, the
        relax rung fires at most once, and each attempt's retries are
        bounded by ``_attempt``.
        """
        impl = plan.fft_impl
        relaxed = False
        while True:
            req.fft_impl = impl
            run_plan = dataclasses.replace(plan, fft_impl=impl)

            def _exec(p=run_plan):
                self._fire("dispatch", req)
                self._fire("oom", req)
                return self.engine.execute_field(eps0, p)

            try:
                result = self._attempt(req, "execute", _exec)
            except FFCzError as err:
                nxt = _LADDER.get(impl)
                if nxt is None or not err.transient:
                    raise
                # transient failure survived the retry budget on this rung:
                # descend rather than reject
                impl = nxt
                self.counters["fallbacks"] += 1
                req.rungs.append(f"fallback:{impl}")
                continue
            if result.converged or relaxed or not self.config.relax_on_nonconvergence:
                return result, run_plan
            # Non-convergence rung: one re-run with a bigger budget and
            # over-relaxed projections.  The pallas kernels require
            # relax == 1.0, so that rung implies the packed transform.
            relaxed = True
            self.counters["relaxes"] += 1
            req.rungs.append("relax")
            if impl == "pallas":
                impl = "packed"
                self.counters["fallbacks"] += 1
                req.rungs.append(f"fallback:{impl}")
            plan = dataclasses.replace(
                plan,
                max_iters=plan.max_iters * self.config.relax_iters_mult,
                relax=self.config.relax_factor,
            )

    # -- pencil bucket path ------------------------------------------------

    def _run_pencil_bucket(self, bucket: List[_Request]) -> List[ServiceResponse]:
        """Per-request plan/base, ONE fused correction, per-request encode."""
        responses: Dict[str, ServiceResponse] = {}
        work: List[Tuple[_Request, bytes, np.ndarray, np.ndarray, Any]] = []
        for req in bucket:
            try:
                E_rel, Delta_rel = req.cfg
                x32 = np.asarray(req.payload, dtype=np.float32)
                plan = self._attempt(
                    req,
                    "plan",
                    lambda x=x32, e=E_rel, d=Delta_rel: self.engine.plan_pencils(
                        x, E_rel=e, Delta_rel=d, block=self.config.block
                    ),
                )
                if plan is None:
                    raise InfeasibleBound(
                        f"E_rel={E_rel:g} underflows float32 for this tensor's range",
                        stage="plan",
                    )

                def _base(x=x32, p=plan, r=req):
                    self._fire("codec", r)
                    blob = self.base.compress(x, p.E_proj)
                    return blob, np.asarray(self.base.decompress(blob), dtype=np.float32)

                base_blob, x_hat = self._attempt(req, "base", _base)
                eps0 = x_hat - x32
                tiles0 = self.engine.tile_f64(eps0, self.config.block)
                work.append((req, base_blob, eps0, tiles0, plan))
            except FFCzError as err:
                responses[req.uid] = self._reject(req, err)
            except Exception as e:  # noqa: BLE001
                responses[req.uid] = self._reject(req, classify_exception(e, "plan"))

        for resp in self._execute_bucket(work):
            responses[resp.uid] = resp
        # preserve submission order in the returned list
        return [responses[r.uid] for r in bucket]

    def _execute_bucket(self, work: List[Tuple]) -> List[ServiceResponse]:
        """One fused correction; bisect on allocation failure.

        Recursion depth is log2(len(work)); a single-request OOM rejects, so
        the recursion always terminates with every request retired.
        """
        if not work:
            return []

        def _correct():
            # one fused device call per bucket -> one dispatch/OOM draw
            self._fire("dispatch", work[0][0])
            self._fire("oom", work[0][0])
            return self.engine.correct(
                [w[2] for w in work],
                [w[4].E_proj for w in work],
                [w[4].Delta_proj for w in work],
                block=self.config.block,
                max_iters=self.config.max_iters,
                return_edits=True,
                return_corrected=False,
            )

        # retry budget for the fused call is carried by the bucket's first
        # request; a transient mid-bucket failure re-runs the whole bucket
        lead = work[0][0]
        try:
            _corr, edits, stats = self._attempt(lead, "execute", _correct)
        except ResourceExhausted as err:
            if len(work) == 1:
                return [self._reject(work[0][0], err)]
            self.counters["bisects"] += 1
            for req, *_ in work:
                req.rungs.append("bisect")
            mid = len(work) // 2
            return self._execute_bucket(work[:mid]) + self._execute_bucket(work[mid:])
        except FFCzError as err:
            # non-OOM terminal failure: every request in the bucket rejects
            # with the same classified error
            return [self._reject(req, err) for req, *_ in work]

        conv = np.asarray(stats.converged)
        out = []
        for j, ((req, base_blob, _eps0, tiles0, plan), (spat_t, freq_t)) in enumerate(
            zip(work, edits)
        ):
            req.converged = bool(conv[j]) if conv.size else True
            try:

                def _encode(s=spat_t, f=freq_t, t=tiles0, p=plan, r=req):
                    self._fire("codec", r)
                    return self.engine.encode_pencils(s, f, t, p, codec="zlib")

                se, fe = self._attempt(req, "encode", _encode)
                x = np.asarray(req.payload)
                payload = _pencil_blob(x.shape, base_blob, se, fe, plan, self.config.block)
                out.append(self._complete(req, payload))
            except FFCzError as err:
                out.append(self._reject(req, err))
            except Exception as e:  # noqa: BLE001
                out.append(self._reject(req, classify_exception(e, "encode")))
        return out

    # -- decode path -------------------------------------------------------

    def _run_decompress(self, req: _Request) -> ServiceResponse:
        try:
            self._check_deadline(req)
            data: bytes = req.payload
            if data[:4] == _PENCIL_MAGIC:
                return self._complete(req, decode_pencil_blob(data, self.base))
            # decode consumes no bound config — the blob carries its bounds
            ffcz = FFCz(self.base, FFCzConfig(), engine=self.engine)
            return self._complete(req, ffcz.decompress(FFCzBlob.from_bytes(data)))
        except FFCzError as err:
            return self._reject(req, err)
        except Exception as e:  # noqa: BLE001
            return self._reject(req, classify_exception(e, "decode"))


# -- pencil wire format ----------------------------------------------------


def _pencil_blob(shape, base_blob: bytes, se, fe, plan, block: int) -> bytes:
    se_b, fe_b = se.to_bytes(), fe.to_bytes()
    out = _PENCIL_MAGIC + struct.pack("<B", _PENCIL_VERSION)
    out += struct.pack(_PENCIL_HEADER, plan.E, plan.Delta, block, len(shape))
    out += struct.pack(f"<{len(shape)}Q", *shape)
    out += struct.pack("<QQQ", len(base_blob), len(se_b), len(fe_b))
    out += base_blob + se_b + fe_b
    return out + struct.pack("<I", zlib.crc32(out))


def decode_pencil_blob(data: bytes, base: Any) -> np.ndarray:
    """Hardened decode of the service pencil envelope (``FFSB``).

    Every malformation — bad magic/version, truncation, section overrun,
    CRC mismatch, codec garbage — raises :class:`BlobCorruptError`.
    """
    try:
        if data[:4] != _PENCIL_MAGIC:
            raise BlobCorruptError("not an FFCz service pencil blob: bad magic")
        if len(data) < 9 or data[4] != _PENCIL_VERSION:
            raise BlobCorruptError(
                f"unsupported service pencil blob version {data[4] if len(data) > 4 else '?'}"
            )
        if len(data) < 4 + 1 + 4:
            raise BlobCorruptError("truncated service pencil blob")
        body, (crc,) = data[:-4], struct.unpack_from("<I", data, len(data) - 4)
        if zlib.crc32(body) != crc:
            raise BlobCorruptError("corrupt service pencil blob: CRC mismatch")
        off = 5
        E, Delta, block, ndim = struct.unpack_from(_PENCIL_HEADER, body, off)
        off += struct.calcsize(_PENCIL_HEADER)
        if ndim > 16:
            raise BlobCorruptError(f"corrupt service pencil blob: implausible rank {ndim}")
        shape = struct.unpack_from(f"<{ndim}Q", body, off)
        off += 8 * ndim
        nb, ns, nf = struct.unpack_from("<QQQ", body, off)
        off += struct.calcsize("<QQQ")
        if len(body) != off + nb + ns + nf:
            raise BlobCorruptError(
                f"corrupt service pencil blob: {len(body)} bytes, sections want {off + nb + ns + nf}"
            )
        base_blob = body[off : off + nb]
        se = EncodedEdits.from_bytes(body[off + nb : off + nb + ns])
        fe = EncodedEdits.from_bytes(body[off + nb + ns : off + nb + ns + nf])
        x_hat = np.asarray(base.decompress(base_blob), dtype=np.float32)
        spat = decode_edits(se, E)
        freq = decode_edits(fe, Delta)
        complete = spat + np.fft.irfft(freq, n=block, axis=-1)
        size = int(np.prod(shape)) if shape else 1
        x = x_hat.astype(np.float64).reshape(-1) + complete.reshape(-1)[:size]
        return x.reshape(shape).astype(np.float32)
    except FFCzError:
        raise
    except Exception as e:  # noqa: BLE001 - untrusted bytes
        raise BlobCorruptError(
            f"corrupt service pencil blob: {type(e).__name__}: {e}", cause=e
        ) from e
