"""Fault-tolerant FFCz compression service: queue, retries, degradation ladder.

:class:`FFCzService` fronts one :class:`~repro.core.engine.CorrectionEngine`
with a request queue admitting heterogeneous (shape, dtype, bound) work:

  whole-field compress    the paper pipeline (plan / base / execute / encode),
                          one request per field
  pencil compress         blockwise requests bucketed — up to ``max_batch``
                          queued tensors run as ONE packed ``(B, block)``
                          correction on the donated batched buffer, each with
                          its own resolved (E, Delta)
  temporal stream         one FFCS sequence (predictor residuals + POCS warm
                          start, :class:`~repro.core.temporal.TemporalCodec`)
                          compressed as ONE unit — the frame chain is
                          sequential, so per-stream frame order is preserved
                          by construction while other units still overlap
  live session            incremental frame arrival (``open_session`` /
                          ``submit_append`` / ``submit_finalize``) over the
                          durable session layer (serving/sessions.py):
                          write-ahead journaled, idempotent under retry,
                          lease-bounded, admission-controlled
  decompress              hardened decode of service pencil blobs, FFCS
                          streams, or FFCz blobs

Execution is a two-stage software pipeline (``pipeline_depth``, default 2).
Each unit of work — a pencil bucket, one field, one stream, one decode — is
split at the device fence:

  FRONT (scheduler thread)   per-request PLAN + base codec, pack the bucket
                             into a cached ``(B, block)`` host staging buffer,
                             and *dispatch* the POCS program asynchronously
                             (``engine.correct_async`` / ``execute_field_async``
                             return handles before ``jax.block_until_ready``).
  BACK (one worker thread)   fence the handle, run the retry/degradation
                             ladder on failure (re-dispatching synchronously),
                             then host ENCODE and blob assembly.

With ``pipeline_depth >= 2`` the ring keeps that many units in flight: unit
*i*'s host ENCODE overlaps unit *i+1*'s device EXECUTE.  ``pipeline_depth=1``
runs FRONT and BACK inline on the calling thread — the exact serial behaviour.
Both modes execute the same code in the same per-request order, so responses,
edit streams, and per-request stats are byte-identical across depths (the
parity suite in tests/test_service_pipeline.py gates this).

The headline is the failure path, not the happy path.  Every request drains
to exactly one of completed-within-bounds or rejected-with-reason:

  retries      transient errors (host codec, device dispatch) re-run the
               failing stage with exponential backoff + seeded jitter, up to
               ``max_retries`` per request, inside a per-request deadline.
  ladder       when retries exhaust on the POCS transform — or the loop ends
               non-converged — the service degrades instead of failing:
               first a relaxed re-run (``max_iters`` x4, over-relaxation),
               then fft_impl rungs pallas -> packed -> xla.  Each rung taken
               is recorded in the request's stats.
  bisect       a device allocation failure on a pencil bucket evicts the
               bucket's cached staging buffer (so the halves don't allocate
               against a stale full-size buffer), then splits the bucket and
               runs the halves (recursively, down to one request, which is
               then rejected with the structured OOM).  Injected bucket
               faults fire against the ORIGINAL bucket lead's uid through
               the whole recursion, so fault caps apply per bucket-unit.
  reject       infeasible bound intersections (:class:`InfeasibleBound`),
               corrupt blobs (:class:`BlobCorruptError`), and exhausted
               budgets return a structured error dict — never a raw
               exception out of :meth:`step`, and never a hang: every
               :meth:`step` retires at least one queued unit.
  timeout      a request whose deadline passes mid-stage is rejected with
               :class:`DeadlineExceeded` (disposition ``"timeout"``).

A :class:`~repro.runtime.faults.FaultInjector` can be threaded through every
stage boundary for deterministic chaos testing (tests/test_faults.py); its
per-request substreams make the injected faults identical in serial and
pipelined mode.

The prose version of this page — request kinds, error taxonomy, ladder,
pipeline diagram, and the generated flag reference — is docs/serving.md
(stream semantics: docs/streaming.md); keep them in sync.
"""

from __future__ import annotations

import collections
import dataclasses
import os
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.engine import CorrectionEngine, default_engine
from repro.core.errors import (
    DeadlineExceeded,
    FFCzError,
    InfeasibleBound,
    ResourceExhausted,
    BlobCorruptError,
    classify_exception,
)
from repro.core.ffcz import FFCz, FFCzBlob, FFCzConfig

# The pencil envelope (FFSB) lives in repro.core.temporal (the temporal codec
# shares it for pencil-mode stream frames); re-exported here because the
# service mints the format and callers decode through this module.
from repro.core.temporal import (  # noqa: F401 - decode_pencil_blob re-exported
    _PENCIL_MAGIC,
    _STREAM_MAGIC,
    TemporalCodec,
    TemporalConfig,
    _pencil_blob,
    decode_pencil_blob,
)
from repro.serving.sessions import FileJournal, StreamSessionManager

__all__ = [
    "ServiceConfig",
    "ServiceResponse",
    "RequestStats",
    "FFCzService",
    "decode_pencil_blob",
]

# fft_impl degradation rungs: each key falls back to its value when the POCS
# transform keeps failing (or won't converge); "xla" is the floor.
_LADDER = {"pallas": "packed", "packed": "xla"}


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Queue, retry, and degradation knobs for one :class:`FFCzService`."""

    max_batch: int = 8  # pencil requests fused per packed correction
    block: int = 256  # pencil length for blockwise requests
    max_iters: int = 50  # POCS budget for pencil buckets
    deadline_s: float = 30.0  # default per-request deadline
    max_retries: int = 3  # per-request transient-retry budget
    backoff_base_s: float = 0.002
    backoff_factor: float = 2.0
    backoff_jitter: float = 0.5  # uniform [0, jitter) fraction added per delay
    # Non-convergence rung: one re-run with max_iters x this and
    # over-relaxed projections before encoding a non-converged result.
    relax_on_nonconvergence: bool = True
    relax_iters_mult: int = 4
    relax_factor: float = 1.3
    seed: int = 0  # backoff-jitter stream (determinism under test)
    # In-flight units: 1 = serial (front + back inline), >= 2 = the back half
    # (fence + encode) of up to depth units runs on the worker thread while
    # the scheduler front-half dispatches the next units' device work.
    pipeline_depth: int = 2
    # Admission control (docs/serving.md): submits beyond max_queue queued
    # requests raise ResourceExhausted (stage "admit") instead of growing the
    # queue without bound; 0 disables the cap.  The session knobs
    # parameterize the live-session manager (serving/sessions.py):
    # max_sessions live sessions, session_lease_s lease refreshed on append,
    # session_history_bytes of resident decoded history before idle sessions
    # spill to their journals (0 = unbounded), and session_journal_dir for
    # file-backed write-ahead journals ("" = in-memory sinks).
    max_queue: int = 1024
    max_sessions: int = 8
    session_lease_s: float = 60.0
    session_history_bytes: int = 0
    session_journal_dir: str = ""


@dataclasses.dataclass(frozen=True)
class RequestStats:
    """Per-request accounting: what the failure machinery actually did."""

    attempts: int  # transient retries consumed
    rungs: Tuple[str, ...]  # degradation rungs taken, in order
    latency_s: float  # admit -> retire (includes injected slowness)
    fft_impl: Optional[str] = None  # transform the final attempt ran with
    converged: Optional[bool] = None
    final_violations: int = 0
    # Derived-quantity shell recheck (cfg.verify_pspec, field requests in
    # pspec mode): max live-shell |P_hat(k)/P(k) - 1| of the decoded blob.
    pspec_shell_err: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class ServiceResponse:
    uid: str
    ok: bool
    payload: Any = None  # blob bytes (compress) or ndarray (decompress)
    error: Optional[dict] = None  # FFCzError.to_dict() when not ok
    stats: Optional[RequestStats] = None


@dataclasses.dataclass
class _Request:
    uid: str
    kind: str  # "field" | "pencils" | "stream" | "session" | "decompress"
    payload: Any
    # FFCzConfig (field) | (E_rel, Delta_rel) (pencils)
    # | (FFCzConfig, TemporalConfig) (stream) | (op, session_id, seq)
    # (session) | None (decompress)
    cfg: Any
    deadline_s: float
    seq: int = 0  # submission order (drain() response ordering)
    t0: float = 0.0
    penalty_s: float = 0.0  # injected slowness, charged against the deadline
    attempts: int = 0
    rungs: List[str] = dataclasses.field(default_factory=list)
    fft_impl: Optional[str] = None
    converged: Optional[bool] = None
    final_violations: int = 0
    pspec_shell_err: Optional[float] = None

    def elapsed(self, now: float) -> float:
        return (now - self.t0) + self.penalty_s


@dataclasses.dataclass
class _Staged:
    """A unit of work after its FRONT half: what the BACK half needs.

    Exactly one of three shapes, by ``kind``:

      pencils     ``work`` (plan/base survivors), front-half ``responses``
                  for the rest, and the attempt-1 dispatch as ``handle`` /
                  ``exc`` (one of the two, or neither when ``work`` is empty)
      field       ``plan`` / ``base_blob`` / ``eps0`` plus the attempt-1
                  dispatch, or ``done`` when the request rejected at front
      stream      nothing staged — the frame chain is sequential, all BACK
      session     nothing staged — session state mutates on the single
                  worker only, which is what makes per-session FIFO hold
      decompress  nothing staged — decode is pure host work, all BACK
    """

    kind: str
    unit: List[_Request]
    responses: Dict[str, ServiceResponse] = dataclasses.field(default_factory=dict)
    work: List[Tuple] = dataclasses.field(default_factory=list)
    handle: Any = None  # in-flight async handle from the front-half dispatch
    exc: Optional[BaseException] = None  # raw front-half dispatch failure
    plan: Any = None
    base_blob: bytes = b""
    eps0: Any = None
    done: Optional[ServiceResponse] = None


class FFCzService:
    """Continuous-batching FFCz compress/decompress front end (see module
    docstring for the failure-path and pipelining contract)."""

    def __init__(
        self,
        base: Any,
        engine: Optional[CorrectionEngine] = None,
        config: ServiceConfig = ServiceConfig(),
        injector: Any = None,
        clock: Callable[[], float] = time.monotonic,
        sleep: Callable[[float], None] = time.sleep,
    ):
        self.base = base
        self.engine = engine or default_engine()
        self.config = config
        self.injector = injector  # None, or a repro.runtime.faults.FaultInjector
        self._clock = clock
        self._sleep = sleep
        self._rng = np.random.default_rng(config.seed)
        self._queue: List[_Request] = []
        self._next_uid = 0
        self._next_seq = 0
        self._submit_seq: Dict[str, int] = {}
        # counters / rng / timers are touched from both the scheduler and the
        # encode worker thread
        self._lock = threading.Lock()
        self.counters: Dict[str, int] = {
            "completed": 0,
            "rejected": 0,
            "retries": 0,
            "fallbacks": 0,
            "relaxes": 0,
            "bisects": 0,
            "timeouts": 0,
            "buffer_evictions": 0,
        }
        # cumulative stage clocks (seconds): front = plan/base/pack/dispatch
        # on the scheduler thread, execute = blocked on the device fence
        # (incl. ladder re-runs), encode/decode = host codec work.  The serve
        # bench turns these into host/device busy fractions.
        self.timers: Dict[str, float] = {
            "front_s": 0.0,
            "execute_s": 0.0,
            "encode_s": 0.0,
            "decode_s": 0.0,
        }
        # host staging buffers for packed pencil buckets, keyed (B, block);
        # populated by the scheduler front-half, evicted on allocation failure
        self._staging: Dict[Tuple[int, int], np.ndarray] = {}
        self._staging_lock = threading.Lock()
        # in-flight ring: (unit requests, back-half future), oldest first
        self._ring: Deque[Tuple[List[_Request], Future]] = collections.deque()
        self._worker: Optional[ThreadPoolExecutor] = None
        # live stream sessions (serving/sessions.py): shares the service
        # clock (frozen-clock tests freeze leases too) and the injector (the
        # session_* chaos sites fire with the append request's uid)
        journal_factory = None
        if config.session_journal_dir:
            jdir = config.session_journal_dir
            os.makedirs(jdir, exist_ok=True)
            journal_factory = lambda sid: FileJournal(os.path.join(jdir, f"{sid}.wal"))  # noqa: E731
        self.sessions = StreamSessionManager(
            base,
            engine=self.engine,
            max_sessions=config.max_sessions,
            lease_s=config.session_lease_s,
            max_history_bytes=config.session_history_bytes,
            clock=clock,
            injector=injector,
            journal_factory=journal_factory,
        )

    # -- admission ---------------------------------------------------------

    def _admit(self, req: _Request) -> str:
        if self.config.max_queue and len(self._queue) >= self.config.max_queue:
            raise ResourceExhausted(
                f"admission rejected: {len(self._queue)} queued requests "
                f">= max_queue={self.config.max_queue}",
                stage="admit",
            )
        req.t0 = self._clock()
        req.seq = self._next_seq
        self._next_seq += 1
        self._submit_seq[req.uid] = req.seq
        if self.injector is not None:
            # injected slowness is charged to the request's clock, not slept,
            # so deadline tests run in real milliseconds
            req.penalty_s = self.injector.sleep_s(uid=req.uid)
        self._queue.append(req)
        return req.uid

    def _uid(self, uid: Optional[str]) -> str:
        if uid is not None:
            return uid
        self._next_uid += 1
        return f"req-{self._next_uid}"

    def submit_compress(
        self,
        x: np.ndarray,
        cfg: FFCzConfig,
        uid: Optional[str] = None,
        deadline_s: Optional[float] = None,
    ) -> str:
        """Queue one whole-field compression (the paper pipeline)."""
        x = np.asarray(x)
        if x.size == 0:
            raise ValueError("cannot compress an empty field")
        return self._admit(
            _Request(
                uid=self._uid(uid),
                kind="field",
                payload=x,
                cfg=cfg,
                deadline_s=self.config.deadline_s if deadline_s is None else deadline_s,
            )
        )

    def submit_pencils(
        self,
        x: np.ndarray,
        E_rel: float,
        Delta_rel: float,
        uid: Optional[str] = None,
        deadline_s: Optional[float] = None,
    ) -> str:
        """Queue one tensor for blockwise (pencil) compression.

        Queued pencil requests are fused: up to ``max_batch`` of them run as
        a single packed batched correction, each with its own resolved
        bounds — heterogeneous shapes and dtypes batch freely because the
        engine tiles every tensor into ``block``-length pencils.
        """
        x = np.asarray(x)
        if x.size == 0:
            raise ValueError("cannot compress an empty tensor")
        if not (E_rel > 0 and Delta_rel > 0):
            raise ValueError(f"bounds must be positive, got E_rel={E_rel}, Delta_rel={Delta_rel}")
        return self._admit(
            _Request(
                uid=self._uid(uid),
                kind="pencils",
                payload=x,
                cfg=(float(E_rel), float(Delta_rel)),
                deadline_s=self.config.deadline_s if deadline_s is None else deadline_s,
            )
        )

    def submit_stream(
        self,
        frames: Sequence[np.ndarray],
        cfg: FFCzConfig,
        stream: TemporalConfig = TemporalConfig(),
        uid: Optional[str] = None,
        deadline_s: Optional[float] = None,
    ) -> str:
        """Queue one temporal sequence for FFCS stream compression.

        The whole sequence is ONE unit of work: frames of a stream are a
        sequential dependency chain (residuals against decoded history, POCS
        warm starts), so per-stream frame order is preserved trivially while
        the pipeline still overlaps this stream's encode with *other* units'
        device work.  The response payload is the ``FFCS`` container; the
        per-frame retry machinery applies inside the unit (a transient frame
        failure re-runs that frame, not the stream).
        """
        frames = [np.asarray(f) for f in frames]
        if not frames:
            raise ValueError("cannot compress an empty stream")
        if any(f.size == 0 for f in frames):
            raise ValueError("cannot compress an empty frame")
        return self._admit(
            _Request(
                uid=self._uid(uid),
                kind="stream",
                payload=frames,
                cfg=(cfg, stream),
                deadline_s=self.config.deadline_s if deadline_s is None else deadline_s,
            )
        )

    # -- live sessions (serving/sessions.py) --------------------------------

    def open_session(
        self,
        cfg: FFCzConfig = FFCzConfig(),
        stream: TemporalConfig = TemporalConfig(),
        session_id: Optional[str] = None,
        lease_s: Optional[float] = None,
    ) -> str:
        """Admit a live stream session (synchronous — admission is
        bookkeeping, not device work).  Raises
        :class:`~repro.core.errors.ResourceExhausted` at ``max_sessions``."""
        return self.sessions.open_session(
            cfg, stream, session_id=session_id, lease_s=lease_s
        )

    def _submit_session(
        self, op: str, session_id: str, seq: int, frame: Any, uid: Optional[str],
        deadline_s: Optional[float],
    ) -> str:
        return self._admit(
            _Request(
                uid=self._uid(uid),
                kind="session",
                payload=frame,
                cfg=(op, session_id, seq),
                deadline_s=self.config.deadline_s if deadline_s is None else deadline_s,
            )
        )

    def submit_append(
        self,
        session_id: str,
        seq: int,
        frame: np.ndarray,
        uid: Optional[str] = None,
        deadline_s: Optional[float] = None,
    ) -> str:
        """Queue one incremental frame append to a live session.

        The response payload is the frame's durable
        :class:`~repro.serving.sessions.FrameReceipt` — minted only after
        the write-ahead journal holds the frame, so an acked append survives
        a crash.  Duplicate seqs are idempotent; gaps reject with
        :class:`~repro.core.errors.SessionSequenceError`.  Session units run
        entirely in the back half on the single encode worker, so appends
        and finalizes for one session retire in submission order (per-
        session FIFO) at every pipeline depth.
        """
        frame = np.asarray(frame)
        if frame.size == 0:
            raise ValueError("cannot append an empty frame")
        return self._submit_session("append", session_id, int(seq), frame, uid, deadline_s)

    def submit_session_flush(
        self,
        session_id: str,
        uid: Optional[str] = None,
        deadline_s: Optional[float] = None,
    ) -> str:
        """Queue a journal flush barrier; the response payload is the
        session's durable journal byte count."""
        return self._submit_session("flush", session_id, -1, None, uid, deadline_s)

    def submit_finalize(
        self,
        session_id: str,
        uid: Optional[str] = None,
        deadline_s: Optional[float] = None,
    ) -> str:
        """Queue session finalization; the response payload is the ``FFCS``
        container (byte-identical to ``submit_stream`` over the same frames
        under the default ``warm_start=False``)."""
        return self._submit_session("finalize", session_id, -1, None, uid, deadline_s)

    def submit_abort(
        self,
        session_id: str,
        uid: Optional[str] = None,
        deadline_s: Optional[float] = None,
    ) -> str:
        """Queue a session abort (drops the session; no container)."""
        return self._submit_session("abort", session_id, -1, None, uid, deadline_s)

    def submit_decompress(
        self,
        blob: bytes,
        uid: Optional[str] = None,
        deadline_s: Optional[float] = None,
    ) -> str:
        """Queue a decode of service pencil bytes, an FFCS stream, or a
        whole-field FFCz blob (stream decodes return the stacked frames)."""
        return self._admit(
            _Request(
                uid=self._uid(uid),
                kind="decompress",
                payload=bytes(blob),
                cfg=None,
                deadline_s=self.config.deadline_s if deadline_s is None else deadline_s,
            )
        )

    # -- scheduling --------------------------------------------------------

    def _pop_unit(self) -> List[_Request]:
        """Pop the next unit of work off the queue: a pencil bucket (up to
        ``max_batch`` fused requests, collected queue-wide so interleaved
        field traffic can't break batching) or one field/decompress request.
        """
        if self._queue[0].kind == "pencils":
            bucket: List[_Request] = []
            rest: List[_Request] = []
            for r in self._queue:
                if r.kind == "pencils" and len(bucket) < self.config.max_batch:
                    bucket.append(r)
                else:
                    rest.append(r)
            self._queue = rest
            return bucket
        return [self._queue.pop(0)]

    def step(self) -> List[ServiceResponse]:
        """Retire one unit of work (a pencil bucket, one field, or one
        decode), returning its responses in submission order.

        Popped requests never re-enqueue — retries happen bounded *within*
        the unit — so ``step`` makes progress whenever work is queued or in
        flight, and :meth:`drain` terminates by induction.

        With ``pipeline_depth >= 2`` this first tops the in-flight ring up
        to depth (front-half + async dispatch per unit, back half submitted
        to the worker thread), then blocks on the OLDEST unit's back half:
        while that unit encodes on the worker, the younger units' device
        programs are already executing.
        """
        if self.config.pipeline_depth <= 1:
            if not self._queue:
                return []
            return self._back(self._front(self._pop_unit()))
        while self._queue and len(self._ring) < self.config.pipeline_depth:
            unit = self._pop_unit()
            staged = self._front(unit)
            self._ring.append((unit, self._executor().submit(self._back, staged)))
        if not self._ring:
            return []
        unit, fut = self._ring.popleft()
        try:
            return fut.result()
        except Exception as e:  # noqa: BLE001 - the back half never raises by
            # contract; anything here (e.g. a cancelled future at teardown)
            # still retires the unit with a structured rejection
            err = classify_exception(e, "service")
            return [self._reject(r, err) for r in unit]

    def drain(self) -> Dict[str, ServiceResponse]:
        """Run :meth:`step` until no work is queued or in flight.

        Responses are keyed AND ordered by submission, regardless of the
        order units retire (bucket fusion and the in-flight ring both reorder
        retirement) — clients can zip submissions to responses directly.
        """
        out: Dict[str, ServiceResponse] = {}
        while self._queue or self._ring:
            for resp in self.step():
                out[resp.uid] = resp
        order = sorted(out, key=lambda u: self._submit_seq.get(u, 1 << 62))
        return {u: out[u] for u in order}

    @property
    def pending(self) -> int:
        """Units of work queued or in flight (load generators poll this to
        decide whether :meth:`step` has anything to do)."""
        return len(self._queue) + len(self._ring)

    def _executor(self) -> ThreadPoolExecutor:
        if self._worker is None:
            # exactly one worker: back halves run in dispatch order, so encode
            # order (and therefore response order within a unit) stays
            # deterministic
            self._worker = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="ffcz-encode"
            )
        return self._worker

    def close(self) -> None:
        """Tear down the encode worker (call after :meth:`drain`).  In-flight
        back halves are cancelled; their requests reject as
        :class:`~repro.core.errors.PipelineAborted` if :meth:`step` is still
        polling them."""
        while self._ring:
            _unit, fut = self._ring.popleft()
            fut.cancel()
        if self._worker is not None:
            self._worker.shutdown(wait=True)
            self._worker = None

    # -- failure machinery -------------------------------------------------

    def _count(self, name: str, n: int = 1) -> None:
        with self._lock:
            self.counters[name] += n

    def _tick(self, name: str, t0: float) -> None:
        dt = self._clock() - t0
        with self._lock:
            self.timers[name] += dt

    def _check_deadline(self, req: _Request) -> None:
        if req.elapsed(self._clock()) > req.deadline_s:
            raise DeadlineExceeded(
                f"request {req.uid} exceeded its {req.deadline_s:g}s deadline",
                stage="service",
            )

    def _fire(self, site: str, uid: str) -> None:
        if self.injector is not None:
            self.injector.fire(site, uid=uid)

    def _attempt(self, req: _Request, stage: str, fn: Callable[[], Any]) -> Any:
        """Run ``fn`` with deadline enforcement and bounded transient retries.

        Non-retryable and budget-exhausted errors re-raise classified; each
        retry backs off exponentially with seeded jitter and records a
        ``retry:<stage>`` rung.  Runs on the scheduler thread (front halves)
        or the encode worker (back halves) — the jitter stream is shared and
        lock-guarded, so only delay *values* depend on thread interleaving,
        never retry outcomes.
        """
        while True:
            self._check_deadline(req)
            try:
                return fn()
            except Exception as e:  # noqa: BLE001 - classified immediately below
                err = classify_exception(e, stage)
                if not err.retryable or req.attempts >= self.config.max_retries:
                    raise err from e
                req.attempts += 1
                self._count("retries")
                req.rungs.append(f"retry:{stage}")
                delay = self.config.backoff_base_s * (
                    self.config.backoff_factor ** (req.attempts - 1)
                )
                with self._lock:
                    jitter = float(self._rng.random())
                delay *= 1.0 + self.config.backoff_jitter * jitter
                self._sleep(delay)

    def _reject(self, req: _Request, err: FFCzError) -> ServiceResponse:
        self._count("rejected")
        if err.disposition == "timeout":
            self._count("timeouts")
        return ServiceResponse(
            uid=req.uid, ok=False, error=err.to_dict(), stats=self._stats(req)
        )

    def _complete(self, req: _Request, payload: Any) -> ServiceResponse:
        self._count("completed")
        return ServiceResponse(uid=req.uid, ok=True, payload=payload, stats=self._stats(req))

    def _stats(self, req: _Request) -> RequestStats:
        return RequestStats(
            attempts=req.attempts,
            rungs=tuple(req.rungs),
            latency_s=req.elapsed(self._clock()),
            fft_impl=req.fft_impl,
            converged=req.converged,
            final_violations=req.final_violations,
            pspec_shell_err=req.pspec_shell_err,
        )

    # -- staging-buffer cache ----------------------------------------------

    def _bucket_rows(self, work: List[Tuple]) -> int:
        b = self.config.block
        return sum(-(-int(np.asarray(w[2]).size) // b) for w in work)

    def _staging_get(self, rows: int) -> np.ndarray:
        """Cached ``(rows, block)`` host buffer for packing a pencil bucket.
        Only the scheduler front-half packs, so handing out the shared buffer
        is race-free; the async dispatch copies it to the device before
        ``correct_async`` returns, after which it is reusable."""
        key = (rows, self.config.block)
        with self._staging_lock:
            buf = self._staging.get(key)
            if buf is None:
                buf = np.zeros(key, np.float32)
                self._staging[key] = buf
        return buf

    def _staging_evict(self, rows: int) -> None:
        """Drop the cached full-bucket buffer after an allocation failure so
        the bisected halves don't allocate against a stale full-size buffer."""
        key = (rows, self.config.block)
        with self._staging_lock:
            dropped = self._staging.pop(key, None) is not None
        if dropped:
            self._count("buffer_evictions")

    # -- pipeline halves ---------------------------------------------------

    def _front(self, unit: List[_Request]) -> _Staged:
        """FRONT half, scheduler thread: plan/base + async EXECUTE dispatch."""
        t0 = self._clock()
        try:
            kind = unit[0].kind
            if kind == "pencils":
                return self._front_pencils(unit)
            if kind == "field":
                return self._front_field(unit[0])
            # stream/session/decompress: nothing to pre-dispatch — the whole
            # unit runs in the back half, overlapping OTHER units at
            # depth >= 2.  Streams because the frame chain is sequential;
            # sessions additionally because running every session op on the
            # one ordered worker is what serializes a finalize racing queued
            # appends (per-session FIFO).
            return _Staged(kind=kind, unit=unit)
        finally:
            self._tick("front_s", t0)

    def _back(self, staged: _Staged) -> List[ServiceResponse]:
        """BACK half, worker thread (or inline at depth 1): fence + retry
        ladder + ENCODE.  Never raises — every request retires structured."""
        if staged.kind == "pencils":
            return self._back_pencils(staged)
        if staged.kind == "field":
            return [self._back_field(staged)]
        if staged.kind == "stream":
            t0 = self._clock()
            try:
                return [self._run_stream(staged.unit[0])]
            finally:
                self._tick("execute_s", t0)
        if staged.kind == "session":
            t0 = self._clock()
            try:
                return [self._run_session(staged.unit[0])]
            finally:
                self._tick("execute_s", t0)
        t0 = self._clock()
        try:
            return [self._run_decompress(staged.unit[0])]
        finally:
            self._tick("decode_s", t0)

    # -- whole-field path --------------------------------------------------

    def _dispatch_field(self, req: _Request, eps0: np.ndarray, run_plan):
        self._fire("dispatch", req.uid)
        self._fire("oom", req.uid)
        return self.engine.execute_field_async(eps0, run_plan)

    def _front_field(self, req: _Request) -> _Staged:
        try:
            cfg: FFCzConfig = req.cfg
            x32 = np.asarray(req.payload, dtype=np.float32)
            plan = self._attempt(req, "plan", lambda: self.engine.plan_field(x32, cfg))

            def _base():
                self._fire("codec", req.uid)
                blob = self.base.compress(x32, plan.E_proj)
                return blob, np.asarray(self.base.decompress(blob), dtype=np.float32)

            base_blob, x_hat = self._attempt(req, "base", _base)
            eps0 = x_hat - x32
            # attempt 1 of the first ladder rung dispatches here so the device
            # starts while the previous unit is still encoding; failures are
            # stashed raw and re-raised inside the back half's ladder, which
            # owns classification and the retry budget
            handle = exc = None
            try:
                handle = self._dispatch_field(
                    req, eps0, dataclasses.replace(plan, fft_impl=plan.fft_impl)
                )
            except Exception as e:  # noqa: BLE001 - re-raised in the back half
                exc = e
            return _Staged(
                kind="field",
                unit=[req],
                plan=plan,
                base_blob=base_blob,
                eps0=eps0,
                handle=handle,
                exc=exc,
            )
        except FFCzError as err:
            return _Staged(kind="field", unit=[req], done=self._reject(req, err))
        except Exception as e:  # noqa: BLE001 - terminal safety net
            return _Staged(
                kind="field", unit=[req], done=self._reject(req, classify_exception(e, "service"))
            )

    def _back_field(self, staged: _Staged) -> ServiceResponse:
        if staged.done is not None:
            return staged.done
        req = staged.unit[0]
        try:
            result, run_plan = self._execute_with_ladder(
                req, staged.eps0, staged.plan, first=(staged.handle, staged.exc)
            )
            req.converged = bool(result.converged)
            req.final_violations = int(result.final_violations)

            def _encode():
                self._fire("codec", req.uid)
                return self.engine.encode_field(result, run_plan)

            t0 = self._clock()
            try:
                se, fe = self._attempt(req, "encode", _encode)
                cfg: FFCzConfig = req.cfg
                blob = FFCzBlob(
                    base_blob=staged.base_blob,
                    spat_edits=se,
                    freq_edits=fe,
                    E=run_plan.E,
                    Delta_scalar=run_plan.delta_scalar,
                    pointwise_delta=run_plan.pointwise_bytes(),
                    shape=run_plan.shape,
                    roi_bound=run_plan.roi_bytes(),
                    crc=cfg.crc,
                )
                payload = blob.to_bytes()
                if getattr(cfg, "verify_pspec", False) and cfg.pspec_rel is not None:
                    # derived-quantity recheck rides the encode stage: decode
                    # the assembled blob and measure the live-shell power-
                    # spectrum ratio in float64 (opt-in; two host FFTs)
                    from repro.core.spectrum import shell_ratio_error

                    x_final = FFCz(self.base, cfg, engine=self.engine).decompress(blob)
                    req.pspec_shell_err = float(
                        shell_ratio_error(x_final, np.asarray(req.payload, dtype=np.float32))
                    )
            finally:
                self._tick("encode_s", t0)
            return self._complete(req, payload)
        except FFCzError as err:
            return self._reject(req, err)
        except Exception as e:  # noqa: BLE001 - terminal safety net
            return self._reject(req, classify_exception(e, "service"))

    def _execute_with_ladder(self, req: _Request, eps0: np.ndarray, plan, first=None):
        """EXECUTE with the degradation ladder (see module docstring).

        ``first`` carries the front half's attempt-1 dispatch — an in-flight
        handle or its raw dispatch exception — consumed by the first attempt
        so the per-request fire/attempt sequence is identical to serial mode.
        Later attempts (and rungs) re-dispatch synchronously right here.

        Terminates: the impl chain pallas -> packed -> xla is finite, the
        relax rung fires at most once, and each attempt's retries are
        bounded by ``_attempt``.
        """
        impl = plan.fft_impl
        relaxed = False
        pre = first if first is not None and first != (None, None) else None
        while True:
            req.fft_impl = impl
            run_plan = dataclasses.replace(plan, fft_impl=impl)

            def _exec(p=run_plan):
                nonlocal pre
                if pre is not None:
                    handle, exc = pre
                    pre = None
                    if exc is not None:
                        raise exc
                    return handle.result()
                return self._dispatch_field(req, eps0, p).result()

            t0 = self._clock()
            try:
                result = self._attempt(req, "execute", _exec)
            except FFCzError as err:
                self._tick("execute_s", t0)
                nxt = _LADDER.get(impl)
                if nxt is None or not err.transient:
                    raise
                # transient failure survived the retry budget on this rung:
                # descend rather than reject
                impl = nxt
                self._count("fallbacks")
                req.rungs.append(f"fallback:{impl}")
                continue
            self._tick("execute_s", t0)
            if result.converged or relaxed or not self.config.relax_on_nonconvergence:
                return result, run_plan
            # Non-convergence rung: one re-run with a bigger budget and
            # over-relaxed projections.  The pallas kernels require
            # relax == 1.0, so that rung implies the packed transform.
            relaxed = True
            self._count("relaxes")
            req.rungs.append("relax")
            if impl == "pallas":
                impl = "packed"
                self._count("fallbacks")
                req.rungs.append(f"fallback:{impl}")
            plan = dataclasses.replace(
                plan,
                max_iters=plan.max_iters * self.config.relax_iters_mult,
                relax=self.config.relax_factor,
            )

    # -- pencil bucket path ------------------------------------------------

    def _dispatch_bucket(self, work: List[Tuple], fire_uid: str, staging=None):
        """One fused dispatch per bucket attempt -> one dispatch/OOM draw,
        always against the ORIGINAL bucket lead's uid (``fire_uid``), so
        injected-fault caps span the whole bisect recursion."""
        self._fire("dispatch", fire_uid)
        self._fire("oom", fire_uid)
        return self.engine.correct_async(
            [w[2] for w in work],
            [w[4].E_proj for w in work],
            [w[4].Delta_proj for w in work],
            block=self.config.block,
            max_iters=self.config.max_iters,
            return_edits=True,
            return_corrected=False,
            staging=staging,
        )

    def _front_pencils(self, bucket: List[_Request]) -> _Staged:
        """Per-request plan/base, then ONE fused async dispatch."""
        responses: Dict[str, ServiceResponse] = {}
        work: List[Tuple[_Request, bytes, np.ndarray, np.ndarray, Any]] = []
        for req in bucket:
            try:
                E_rel, Delta_rel = req.cfg
                x32 = np.asarray(req.payload, dtype=np.float32)
                plan = self._attempt(
                    req,
                    "plan",
                    lambda x=x32, e=E_rel, d=Delta_rel: self.engine.plan_pencils(
                        x, E_rel=e, Delta_rel=d, block=self.config.block
                    ),
                )
                if plan is None:
                    raise InfeasibleBound(
                        f"E_rel={E_rel:g} underflows float32 for this tensor's range",
                        stage="plan",
                    )

                def _base(x=x32, p=plan, r=req):
                    self._fire("codec", r.uid)
                    blob = self.base.compress(x, p.E_proj)
                    return blob, np.asarray(self.base.decompress(blob), dtype=np.float32)

                base_blob, x_hat = self._attempt(req, "base", _base)
                eps0 = x_hat - x32
                tiles0 = self.engine.tile_f64(eps0, self.config.block)
                work.append((req, base_blob, eps0, tiles0, plan))
            except FFCzError as err:
                responses[req.uid] = self._reject(req, err)
            except Exception as e:  # noqa: BLE001
                responses[req.uid] = self._reject(req, classify_exception(e, "plan"))

        handle = exc = None
        if work:
            try:
                handle = self._dispatch_bucket(
                    work, work[0][0].uid, staging=self._staging_get(self._bucket_rows(work))
                )
            except Exception as e:  # noqa: BLE001 - re-raised in the back half
                exc = e
        return _Staged(
            kind="pencils", unit=bucket, responses=responses, work=work, handle=handle, exc=exc
        )

    def _back_pencils(self, staged: _Staged) -> List[ServiceResponse]:
        responses = dict(staged.responses)
        if staged.work:
            first = (staged.handle, staged.exc)
            for resp in self._execute_bucket(staged.work, staged.work[0][0].uid, first=first):
                responses[resp.uid] = resp
        # preserve submission order in the returned list
        return [responses[r.uid] for r in staged.unit]

    def _execute_bucket(
        self, work: List[Tuple], fire_uid: str, first=None
    ) -> List[ServiceResponse]:
        """Fence one fused correction; bisect on allocation failure.

        ``first`` carries the front half's attempt-1 dispatch (handle or raw
        exception); retries and bisected halves re-dispatch here, without the
        shared staging buffer (the scheduler thread may be packing the next
        bucket into it).  Recursion depth is log2(len(work)); a
        single-request OOM rejects, so the recursion always terminates with
        every request retired.
        """
        if not work:
            return []
        pre = first if first is not None and first != (None, None) else None

        def _correct():
            nonlocal pre
            if pre is not None:
                handle, exc = pre
                pre = None
                if exc is not None:
                    raise exc
                return handle.result()
            return self._dispatch_bucket(work, fire_uid, staging=None).result()

        # retry budget for the fused call is carried by the bucket's first
        # request; a transient mid-bucket failure re-runs the whole bucket
        lead = work[0][0]
        t0 = self._clock()
        try:
            _corr, edits, stats = self._attempt(lead, "execute", _correct)
        except ResourceExhausted as err:
            self._tick("execute_s", t0)
            # cache hygiene first: the bisected halves must not allocate
            # against the stale full-size staging buffer
            self._staging_evict(self._bucket_rows(work))
            if len(work) == 1:
                return [self._reject(work[0][0], err)]
            self._count("bisects")
            for req, *_ in work:
                req.rungs.append("bisect")
            mid = len(work) // 2
            return self._execute_bucket(work[:mid], fire_uid) + self._execute_bucket(
                work[mid:], fire_uid
            )
        except FFCzError as err:
            self._tick("execute_s", t0)
            # non-OOM terminal failure: every request in the bucket rejects
            # with the same classified error
            return [self._reject(req, err) for req, *_ in work]
        self._tick("execute_s", t0)

        conv = np.asarray(stats.converged)
        out = []
        t0 = self._clock()
        try:
            for j, ((req, base_blob, _eps0, tiles0, plan), (spat_t, freq_t)) in enumerate(
                zip(work, edits)
            ):
                req.converged = bool(conv[j]) if conv.size else True
                try:

                    def _encode(s=spat_t, f=freq_t, t=tiles0, p=plan, r=req):
                        self._fire("codec", r.uid)
                        return self.engine.encode_pencils(s, f, t, p, codec="zlib")

                    se, fe = self._attempt(req, "encode", _encode)
                    x = np.asarray(req.payload)
                    payload = _pencil_blob(x.shape, base_blob, se, fe, plan, self.config.block)
                    out.append(self._complete(req, payload))
                except FFCzError as err:
                    out.append(self._reject(req, err))
                except Exception as e:  # noqa: BLE001
                    out.append(self._reject(req, classify_exception(e, "encode")))
        finally:
            self._tick("encode_s", t0)
        return out

    # -- temporal stream path ----------------------------------------------

    def _run_stream(self, req: _Request) -> ServiceResponse:
        """Compress one temporal sequence into an FFCS container.

        Runs entirely in the back half: frame *t*'s predictor input and
        warm-start spectrum come from frame *t-1*'s results, so the chain
        cannot be split at the device fence.  Each frame runs under the
        per-request retry machinery (``StreamEncoder.add_frame`` mutates
        encoder state only after the frame fully succeeds, so a retried
        frame re-runs cleanly), with the standard codec/dispatch/oom fault
        sites fired per frame.
        """
        try:
            cfg, stream_cfg = req.cfg
            codec = TemporalCodec(self.base, cfg, stream=stream_cfg, engine=self.engine)
            enc = codec.open_stream()
            for frame in req.payload:
                self._check_deadline(req)

                def _frame(f=frame):
                    self._fire("codec", req.uid)
                    self._fire("dispatch", req.uid)
                    self._fire("oom", req.uid)
                    return enc.add_frame(f)

                self._attempt(req, "execute", _frame)
            req.converged = all(s["converged"] for s in enc.frame_stats)
            return self._complete(req, enc.finish())
        except FFCzError as err:
            return self._reject(req, err)
        except Exception as e:  # noqa: BLE001
            return self._reject(req, classify_exception(e, "execute"))

    # -- live session path -------------------------------------------------

    def _run_session(self, req: _Request) -> ServiceResponse:
        """Run one session op on the encode worker (or inline at depth 1).

        Appends go through the retry machinery: the manager's session sites
        fire with this request's uid, injected journal failures leave the
        frame pending (re-journaled on retry, not re-encoded), and terminal
        session errors — sequence gaps, closed sessions, exhausted budgets —
        reject structured like every other kind.
        """
        op, sid, seq = req.cfg
        try:
            self._check_deadline(req)
            if op == "append":

                def _append():
                    return self.sessions.append_frame(
                        sid, seq, req.payload, fire_uid=req.uid
                    )

                receipt = self._attempt(req, "execute", _append)
                req.converged = receipt.converged
                return self._complete(req, receipt)
            if op == "finalize":
                payload = self._attempt(
                    req,
                    "execute",
                    lambda: self.sessions.finalize(sid, fire_uid=req.uid),
                )
                return self._complete(req, payload)
            if op == "flush":
                n = self._attempt(req, "execute", lambda: self.sessions.flush(sid))
                return self._complete(req, n)
            if op == "abort":
                self.sessions.abort(sid)
                return self._complete(req, None)
            raise ValueError(f"unknown session op {op!r}")
        except FFCzError as err:
            return self._reject(req, err)
        except Exception as e:  # noqa: BLE001
            return self._reject(req, classify_exception(e, "session"))

    # -- decode path -------------------------------------------------------

    def _run_decompress(self, req: _Request) -> ServiceResponse:
        try:
            self._check_deadline(req)
            data: bytes = req.payload
            if data[:4] == _STREAM_MAGIC:
                codec = TemporalCodec(self.base, FFCzConfig(), engine=self.engine)
                return self._complete(req, np.stack(codec.decompress_stream(data)))
            if data[:4] == _PENCIL_MAGIC:
                return self._complete(req, decode_pencil_blob(data, self.base))
            # decode consumes no bound config — the blob carries its bounds
            ffcz = FFCz(self.base, FFCzConfig(), engine=self.engine)
            return self._complete(req, ffcz.decompress(FFCzBlob.from_bytes(data)))
        except FFCzError as err:
            return self._reject(req, err)
        except Exception as e:  # noqa: BLE001
            return self._reject(req, classify_exception(e, "decode"))

