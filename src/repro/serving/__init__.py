"""Batched serving engine with FFCz KV-cache compression."""

from repro.serving.engine import ServeConfig, ServingEngine

__all__ = ["ServingEngine", "ServeConfig"]
