"""Batched serving engines: LM decode + fault-tolerant FFCz compression."""

from repro.serving.engine import ServeConfig, ServingEngine
from repro.serving.ffcz_service import (
    FFCzService,
    RequestStats,
    ServiceConfig,
    ServiceResponse,
    decode_pencil_blob,
)
from repro.serving.sessions import (
    FileJournal,
    FrameReceipt,
    MemoryJournal,
    SessionStats,
    StreamSessionManager,
)

__all__ = [
    "ServingEngine",
    "ServeConfig",
    "FFCzService",
    "ServiceConfig",
    "ServiceResponse",
    "RequestStats",
    "decode_pencil_blob",
    "StreamSessionManager",
    "SessionStats",
    "FrameReceipt",
    "MemoryJournal",
    "FileJournal",
]
