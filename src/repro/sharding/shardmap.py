"""Version-portable ``shard_map``.

JAX moved ``shard_map`` from ``jax.experimental.shard_map`` (where the
replication check is spelled ``check_rep``) to ``jax.shard_map`` (where it is
``check_vma``).  Every manual-collective region in this repo — the pipeline
schedule, the compressed all-reduce, and the CorrectionEngine's sharded
pencil backend — goes through this one shim so the repo runs on both API
generations.
"""

from __future__ import annotations

import jax


def shard_map(f, mesh, in_specs, out_specs, check: bool = False):
    """``shard_map(f)`` over ``mesh`` with the replication check toggled.

    ``check=False`` (the default here) disables the static replication
    checker — the manual regions in this repo use collectives whose
    replication the checker cannot always infer.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check
    )
