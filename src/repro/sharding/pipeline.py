"""GPipe-style pipeline parallelism over a mesh axis (DESIGN.md §7, optional).

The multi-pod dry-run uses the "pod" axis as outer DP/FSDP by default; this
module provides the alternative: split the layer stack into S stages along a
mesh axis and stream M microbatches through the classic GPipe schedule
(T = M + S - 1 ticks, bubble fraction (S-1)/T), with inter-stage transfers as
``jax.lax.ppermute`` inside a ``shard_map`` that is manual over the pipeline
axis only (other axes keep their GSPMD sharding).

API is deliberately minimal and composable: the user supplies ``stage_fn``
(params-slice, activations) -> activations — typically a lax.scan over the
stage's layer group — and stacked per-stage params.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.sharding.shardmap import shard_map


def pipeline_apply(
    stage_fn: Callable,
    stage_params,
    microbatches: jnp.ndarray,  # (M, mb, ...) microbatch-major inputs
    mesh,
    axis: str = "pipe",
):
    """Run ``y_m = stage_{S-1}(...stage_0(x_m))`` for every microbatch m with
    the GPipe schedule.  ``stage_params`` leaves must have a leading axis of
    size S (the pipeline axis); returns outputs shaped like ``microbatches``.
    """
    n_stages = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    M = microbatches.shape[0]
    T = M + n_stages - 1

    def per_stage(params_local, mbs):
        # params_local: this stage's slice — shard_map leaves a size-1
        # leading stage axis; strip it
        params_local = jax.tree.map(lambda a: a[0], params_local)
        # mbs: full (M, mb, ...) input block (replicated across stages)
        idx = jax.lax.axis_index(axis)
        mb_shape = mbs.shape[1:]
        buf0 = jnp.zeros((M,) + mb_shape, mbs.dtype)  # last stage's outputs

        def tick(carry, t):
            recv, outbuf = carry
            # stage 0 feeds from the microbatch stream at time t
            mb_idx = jnp.clip(t, 0, M - 1)
            x_in = jnp.where(idx == 0, mbs[mb_idx], recv)
            y = stage_fn(params_local, x_in)
            # valid iff this stage is processing a real microbatch: 0 <= t - idx < M
            m_of_t = t - idx
            valid = jnp.logical_and(m_of_t >= 0, m_of_t < M)
            # last stage records its finished microbatch
            outbuf = jax.lax.cond(
                jnp.logical_and(valid, idx == n_stages - 1),
                lambda b: jax.lax.dynamic_update_index_in_dim(
                    b, y.astype(b.dtype), jnp.clip(m_of_t, 0, M - 1), 0
                ),
                lambda b: b,
                outbuf,
            )
            # ship activations downstream (stage i -> i+1); wrap-around to 0
            # is ignored (stage 0 always takes from the stream)
            sent = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)]
            )
            return (sent, outbuf), None

        recv0 = jnp.zeros(mb_shape, mbs.dtype)
        (_, outbuf), _ = jax.lax.scan(tick, (recv0, buf0), jnp.arange(T))
        return outbuf[None]  # leading stage axis for the P(axis) out_spec

    spec_params = jax.tree.map(lambda _: P(axis), stage_params)
    fn = shard_map(
        per_stage,
        mesh=mesh,
        in_specs=(spec_params, P()),
        out_specs=P(axis),  # (S, M, mb, ...): stage-major stack
    )
    stacked = fn(stage_params, microbatches)
    return stacked[-1]  # only the last stage's buffer holds real outputs


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    """GPipe bubble overhead: (S-1) / (M + S - 1)."""
    return (n_stages - 1) / (n_micro + n_stages - 1)


@functools.lru_cache(maxsize=None)
def _noop():  # pragma: no cover
    return None
