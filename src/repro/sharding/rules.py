"""Path-based partition rules for params, caches, and batches.

Strategy (MaxText-style GSPMD):

  * TP  — "model" axis: attention head projections, MLP hidden dim, vocab.
  * EP  — "model" axis on the expert dim of MoE tensors (all-to-all dispatch).
  * FSDP— "data" axis on the other large dim of every weight (ZeRO-3:
          GSPMD all-gathers params forward, reduce-scatters grads backward).
  * DP  — batch over ("pod", "data") when divisible (falls back gracefully
          for small serving batches, e.g. long_500k's global_batch=1).
  * PP  — optional GPipe schedule over a mesh axis (sharding/pipeline.py);
          the dry-run meshes use the pod axis as outer DP/FSDP instead.

Rules match on the param path (dict keys); specs are padded with None for
leading stacked-layer axes.  Uneven dims (e.g. vocab=49155 over 16) are
legal — GSPMD pads internally.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def _axis_size(mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def _dp_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


# ---------------------------------------------------------------------------
# parameter rules: (path suffix match) -> spec for the trailing dims


_RULES = [
    # vlm projector (small, replicate)
    (("projector", "w1"), P(None, None)),
    (("projector", "w2"), P(None, None)),
    # embeddings / head: vocab on model (TP), d_model on data (FSDP)
    (("embed",), P("model", "data")),
    (("lm_head",), P("data", "model")),
    # attention: head-major fused QKV (d, H, hd) / wo (hq, hd, d).  The head
    # axis gets "model" only when divisible (divisibility guard below) —
    # indivisible-head archs run attention DP+FSDP-only by construction.
    (("attn", "wqkv"), P("data", "model", None)),
    (("attn", "wo"), P("model", None, "data")),
    (("attn", "bqkv"), P("model", None)),
    (("self_attn", "wqkv"), P("data", "model", None)),
    (("self_attn", "wo"), P("model", None, "data")),
    (("cross_attn", "wqkv"), P("data", "model", None)),
    (("cross_attn", "wo"), P("model", None, "data")),
    # dense MLPs: fused gate+up (d, 2, f)
    (("mlp", "w_gu"), P("data", None, "model")),
    (("mlp", "w_down"), P("model", "data")),
    (("shared", "w_gu"), P("data", None, "model")),
    (("shared", "w_down"), P("model", "data")),
    # MoE experts (padded to a TP multiple): EP on model; f on data (FSDP).
    # The contraction dim d stays REPLICATED so the gate/up GEMMs are local
    # (sharding d forces buffer-sized partial-sum all-reduces — measured,
    # §Perf iter on granite-moe prefill).
    (("moe", "router"), P(None, None)),
    (("moe", "w_gate"), P("model", None, "data")),
    (("moe", "w_up"), P("model", None, "data")),
    (("moe", "w_down"), P("model", "data", None)),
    # mamba2
    (("in_proj",), P("data", "model")),
    (("out_proj",), P("model", "data")),
    (("conv_w",), P(None, "model")),
    (("conv_b",), P("model")),
    (("A_log",), P(None)),
    (("D",), P(None)),
    (("dt_bias",), P(None)),
    # norms / small
    (("scale",), P(None)),
]


def _match_rule(path_keys) -> P | None:
    for suffix, spec in _RULES:
        if len(path_keys) >= len(suffix) and tuple(path_keys[-len(suffix):]) == suffix:
            return spec
    return None


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            names.append(str(p.key))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            names.append(str(p.name))
    return tuple(names)


def param_pspecs(params: Any, mesh=None) -> Any:
    """PartitionSpec pytree for a param pytree (leading stack axes -> None).

    pjit input shardings require exact divisibility, so when a mesh is given
    every axis assignment whose dim is not divisible by that mesh axis is
    dropped (replicated along that dim).  Vocab padding in the model keeps
    the big tensors divisible; this guard covers the long tail (e.g. 14-head
    q projections over 16-way TP).
    """

    def leaf_spec(path, leaf):
        names = _path_names(path)
        rule = _match_rule(names)
        rank = np.ndim(leaf)
        if rule is None:
            return P(*([None] * rank))
        spec = list(rule)
        pad = rank - len(spec)
        if pad < 0:  # scalar-ish leaf, rule too long
            return P(*([None] * rank))
        full = [None] * pad + spec
        if mesh is not None:
            shape = np.shape(leaf)
            for i, ax in enumerate(full):
                if ax is None:
                    continue
                size = int(np.prod([_axis_size(mesh, a) for a in (ax if isinstance(ax, tuple) else (ax,))]))
                if shape[i] % size != 0:
                    full[i] = None
        return P(*full)

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


# ---------------------------------------------------------------------------
# cache + batch rules


def cache_pspecs(cache: Any, mesh) -> Any:
    """KV caches: heads on "model" when divisible, else head_dim, else
    replicated; batch on DP axes when divisible; SSM states on "model" heads."""
    dp = _dp_axes(mesh)
    dp_size = int(np.prod([_axis_size(mesh, a) for a in dp])) if dp else 1
    model_size = _axis_size(mesh, "model")

    def leaf_spec(path, leaf):
        names = _path_names(path)
        shape = np.shape(leaf)
        rank = len(shape)
        if rank == 0 or names[-1] == "pos":
            return P(*([None] * rank))
        spec = [None] * rank
        if names[-1] in ("k", "v") and rank >= 4:
            # (layers?, b, hkv, S, hd)
            b_i, h_i, hd_i = rank - 4, rank - 3, rank - 1
            spec[b_i] = _maybe(dp, shape[b_i], dp_size)
            if shape[h_i] % model_size == 0:
                spec[h_i] = "model"
            elif shape[hd_i] % model_size == 0:
                spec[hd_i] = "model"
        elif names[-1] == "state" and rank >= 4:
            # (layers?, b, h, p, n)
            b_i, h_i = rank - 4, rank - 3
            spec[b_i] = _maybe(dp, shape[b_i], dp_size)
            if shape[h_i] % model_size == 0:
                spec[h_i] = "model"
        elif names[-1] == "conv" and rank >= 3:
            # (layers?, b, k-1, conv_dim)
            b_i, c_i = rank - 3, rank - 1
            spec[b_i] = _maybe(dp, shape[b_i], dp_size)
            if shape[c_i] % model_size == 0:
                spec[c_i] = "model"
        elif rank >= 4:
            # whisper cross kv tuple leaves: (layers, b, hkv, S, hd)
            b_i, h_i, hd_i = rank - 4, rank - 3, rank - 1
            spec[b_i] = _maybe(dp, shape[b_i], dp_size)
            if shape[h_i] % model_size == 0:
                spec[h_i] = "model"
            elif shape[hd_i] % model_size == 0:
                spec[hd_i] = "model"
        return P(*spec)

    return jax.tree_util.tree_map_with_path(leaf_spec, cache)


def _maybe(dp_axes, dim: int, dp_size: int):
    if not dp_axes or dim % dp_size != 0:
        # try partial: just the "data" axis
        return None
    return dp_axes if len(dp_axes) > 1 else dp_axes[0]


def batch_pspec(batch: Any, mesh) -> Any:
    """Shard batch dim over DP axes when divisible (greedy prefix fallback)."""
    dp = _dp_axes(mesh)

    def leaf_spec(leaf):
        shape = np.shape(leaf)
        rank = len(shape)
        if rank == 0:
            return P()
        b = shape[0]
        # greedy: use the longest prefix of dp axes whose product divides b
        chosen = ()
        prod = 1
        for a in dp:
            if b % (prod * _axis_size(mesh, a)) == 0:
                chosen = chosen + (a,)
                prod *= _axis_size(mesh, a)
        spec = [None] * rank
        if chosen:
            spec[0] = chosen if len(chosen) > 1 else chosen[0]
        return P(*spec)

    return jax.tree.map(leaf_spec, batch)


def to_shardings(pspecs: Any, mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )
