"""Pencil-decomposed distributed rFFT: whole fields stay sharded end to end.

The paper's GPU pipeline assumes one device sees the whole spectrum; our
``sharded`` engine backend (PR 2) only shards *pencil batches*, so a whole
field still had to fit one device's HBM before ``rfftn``.  This module is the
missing distributed transform: a 1-D slab decomposition over one mesh axis
(the field sharded along axis 0), local FFTs along unsharded axes, and
``all_to_all`` transposes under the version-portable ``shard_map`` shim.

Bitwise discipline (the PR 2 parity bar, extended to whole fields): the
single-device ``jnp.fft.rfftn`` computes its passes in a fixed axis order —
r2c along the *last* axis, then c2c along axis 0, then axis 1 (verified
empirically on the CPU and TPU DUCC/FFT lowering; ``tests/test_dist_fft.py``
gates it).  The distributed transform applies the *same per-axis passes in
the same order*, transposing between them, and each local pass is
batch-invariant (a slab's rows transform identically whatever the slab
count).  ``all_to_all`` moves bits untouched and the convergence-count
collectives are integer ``psum``s, so the distributed POCS loop — and the
FFCz blobs built from it — are bitwise identical to the single-device path.

One genuine precondition: the *inverse* transform carries a ``1/N``
normalization per c2c axis whose placement the fused kernel chooses
internally; splitting the axes into separate passes reproduces it bit for
bit exactly when each c2c-axis length is a power of two (``1/N`` is then an
exponent shift — placement-invariant; the c2r last axis is unconstrained:
its scale sits inside the same final pass either way).
:func:`validate_pencil_shape` therefore requires power-of-two lengths on
all axes but the last by default; ``strict_bitwise=False`` lifts that for
callers who accept float32-rounding-level blob divergence (the dual-bound
guarantee itself never depends on parity — the float64 polish enforces the
bounds on whatever trajectory the float32 loop took).

Data layout (D = mesh axis size, ``H = N_last // 2 + 1``):

  3-D field (N0, N1, N2), local block (N0/D, N1, N2):
    rfft ax2 -> a2a(1->0) -> fft ax0 -> a2a(0->1) -> fft ax1
    spectrum local block (N0/D, N1, H): sharded along axis 0, like the field.
  2-D field (N0, N1), local block (N0/D, N1):
    rfft ax1 -> a2a(1->0) -> fft ax0
    spectrum local block (N0, H/D): sharded along the half axis.

Divisibility: axis 0 (both ranks) and the transpose split axis (N1 for 3-D,
H for 2-D) must divide by D; :func:`validate_pencil_shape` raises an
actionable error otherwise.

``*_local`` functions run *inside* a ``shard_map`` region on local blocks;
:func:`pencil_rfftn` / :func:`pencil_irfftn` are the global-array wrappers.
:class:`ShardedField` is the engine-facing handle (PLAN/EXECUTE accept it).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.sharding.shardmap import shard_map


def validate_pencil_shape(
    shape: Tuple[int, ...], n_dev: int, strict_bitwise: bool = True
) -> None:
    """Raise ValueError unless ``shape`` slab-decomposes over ``n_dev`` devices.

    With ``strict_bitwise`` (the default), additionally require every c2c
    axis (all but the last) to have power-of-two length: the fused inverse
    FFT's ``1/N`` normalization is placement-invariant only when it is a
    power of two, so that is exactly when the per-axis pencil passes can
    reproduce the fused single-device transform bit for bit.  Other lengths
    are numerically fine (the dual-bound guarantee never depends on bitwise
    parity — the float64 polish enforces bounds regardless), but blobs may
    then differ from the single-device path at float32-rounding level; pass
    ``strict_bitwise=False`` to accept that.
    """
    if len(shape) not in (2, 3):
        raise ValueError(
            f"pencil-decomposed FFT supports 2-D and 3-D fields, got rank {len(shape)} "
            f"(shape {shape}); tile other ranks through the engine's pencil batches instead"
        )
    if shape[0] % n_dev:
        raise ValueError(
            f"field axis 0 ({shape[0]}) is not divisible by the mesh axis size "
            f"({n_dev}); the slab decomposition shards axis 0 — pad the field or "
            f"pick a mesh axis that divides it"
        )
    if len(shape) == 3:
        if shape[1] % n_dev:
            raise ValueError(
                f"field axis 1 ({shape[1]}) is not divisible by the mesh axis size "
                f"({n_dev}); the pencil transpose splits axis 1 — pad the field or "
                f"pick a mesh axis that divides it"
            )
    else:
        h = shape[-1] // 2 + 1
        if h % n_dev:
            raise ValueError(
                f"rfft half axis ({shape[-1]} -> {h} components) is not divisible by "
                f"the mesh axis size ({n_dev}); the 2-D pencil transpose splits the "
                f"half axis — choose N1 with (N1//2 + 1) % {n_dev} == 0, or use a 3-D tiling"
            )
    if strict_bitwise:
        for a, n in enumerate(shape[:-1]):
            if n & (n - 1):
                raise ValueError(
                    f"axis {a} length {n} is not a power of two: the inverse FFT's "
                    f"1/{n} normalization then rounds differently split per-axis "
                    f"than fused, so blobs would not be bitwise identical to the "
                    f"single-device path; pass strict_bitwise=False to accept "
                    f"float32-rounding-level divergence (bounds still hold)"
                )


def freq_partition_spec(ndim: int, axis_name: str) -> P:
    """PartitionSpec of the distributed half-spectrum for a rank-``ndim`` field."""
    return P(axis_name) if ndim == 3 else P(None, axis_name)


def local_freq_shape(
    gshape: Tuple[int, ...], local_shape: Tuple[int, ...]
) -> Tuple[int, ...]:
    """Local half-spectrum block shape, from global + local spatial shapes."""
    h = gshape[-1] // 2 + 1
    if len(gshape) == 3:
        return (local_shape[0], gshape[1], h)
    n_dev = gshape[0] // local_shape[0]
    return (gshape[0], h // n_dev)


def local_pair_weights(
    gshape: Tuple[int, ...], freq_shape: Tuple[int, ...], axis_name: str
):
    """Conjugate-pair multiplicities for a *local* half-spectrum block.

    3-D blocks keep the whole half axis locally, so the static
    :func:`repro.core.cubes.rfft_pair_weights` plane broadcasts as-is.  2-D
    blocks shard the half axis, so global column indices come from
    ``axis_index`` (traced — call inside the ``shard_map`` region only).
    """
    # deferred: importing repro.core at module scope would cycle through
    # repro.core.__init__ -> engine -> this module
    from repro.core.cubes import rfft_pair_weights

    if len(gshape) == 3:
        return rfft_pair_weights(gshape)
    n = gshape[-1]
    h = n // 2 + 1
    h_loc = freq_shape[-1]
    col = jax.lax.axis_index(axis_name) * h_loc + jnp.arange(h_loc)
    w = jnp.where(col == 0, 1, 2)
    if n % 2 == 0:
        w = jnp.where(col == h - 1, 1, w)
    return w.astype(jnp.int32)[None, :]


def rfftn_local(
    block: jnp.ndarray, axis_name: str, gshape: Tuple[int, ...]
) -> jnp.ndarray:
    """Distributed ``rfftn`` body: local passes + all_to_all transposes.

    The pass order (r2c last axis, then c2c axis 0, then axis 1) mirrors the
    fused single-device ``jnp.fft.rfftn`` exactly, so results are bitwise
    identical to it (gated by tests/test_dist_fft.py).
    """
    nd = len(gshape)
    r = jnp.fft.rfft(block, axis=nd - 1)
    t = jax.lax.all_to_all(r, axis_name, split_axis=1, concat_axis=0, tiled=True)
    t = jnp.fft.fft(t, axis=0)
    if nd == 2:
        return t
    t = jax.lax.all_to_all(t, axis_name, split_axis=0, concat_axis=1, tiled=True)
    return jnp.fft.fft(t, axis=1)


def irfftn_local(
    block: jnp.ndarray, axis_name: str, gshape: Tuple[int, ...]
) -> jnp.ndarray:
    """Distributed ``irfftn`` body (inverse pass order: axis 0, axis 1, c2r last)."""
    nd = len(gshape)
    if nd == 2:
        t = jnp.fft.ifft(block, axis=0)
        t = jax.lax.all_to_all(t, axis_name, split_axis=0, concat_axis=1, tiled=True)
        return jnp.fft.irfft(t, n=gshape[1], axis=1)
    t = jax.lax.all_to_all(block, axis_name, split_axis=1, concat_axis=0, tiled=True)
    t = jnp.fft.ifft(t, axis=0)
    t = jax.lax.all_to_all(t, axis_name, split_axis=0, concat_axis=1, tiled=True)
    t = jnp.fft.ifft(t, axis=1)
    return jnp.fft.irfft(t, n=gshape[2], axis=2)


class ShardedField:
    """A real 2-D/3-D field slab-sharded along axis 0 over one mesh axis.

    The engine-facing handle for distributed whole-field FFCz:
    ``CorrectionEngine.plan_field`` / ``execute_field`` and ``FFCz.compress``
    accept it, keeping field-sized device state sharded through the whole
    spectral pipeline.  ``to_host()`` is the explicit (cached) host staging
    used only at the base-compressor and edit-encode boundaries — the same
    host-RAM boundary the single-device pipeline has; device HBM never holds
    the gathered field.
    """

    def __init__(
        self, array, mesh, axis_name: str = "data", strict_bitwise: bool = True
    ):
        shape = tuple(array.shape)
        validate_pencil_shape(shape, mesh.shape[axis_name], strict_bitwise)
        self.mesh = mesh
        self.axis_name = axis_name
        self.strict_bitwise = strict_bitwise
        self.array = jax.device_put(
            jnp.asarray(array, dtype=jnp.float32), NamedSharding(mesh, self.spec)
        )
        self._host: Optional[np.ndarray] = None

    @classmethod
    def shard(
        cls,
        x: np.ndarray,
        mesh=None,
        axis_name: str = "data",
        strict_bitwise: bool = True,
    ) -> "ShardedField":
        """Shard a host array over ``mesh[axis_name]`` (default: all devices)."""
        if mesh is None:
            mesh = jax.make_mesh((len(jax.devices()),), (axis_name,))
        return cls(x, mesh, axis_name, strict_bitwise)

    @property
    def spec(self) -> P:
        return P(self.axis_name)

    @property
    def freq_spec(self) -> P:
        return freq_partition_spec(self.ndim, self.axis_name)

    @property
    def shape(self) -> Tuple[int, ...]:
        return tuple(self.array.shape)

    @property
    def ndim(self) -> int:
        return self.array.ndim

    @property
    def n_dev(self) -> int:
        return self.mesh.shape[self.axis_name]

    def to_host(self) -> np.ndarray:
        """Gathered host copy (cached) — the base-codec/encode staging buffer."""
        if self._host is None:
            self._host = np.asarray(self.array)
        return self._host


@functools.lru_cache(maxsize=None)
def _pencil_fft_fn(mesh, axis_name: str, gshape: Tuple[int, ...], inverse: bool):
    fspec = freq_partition_spec(len(gshape), axis_name)
    if inverse:
        fn = lambda b: irfftn_local(b, axis_name, gshape)  # noqa: E731
        in_spec, out_spec = fspec, P(axis_name)
    else:
        fn = lambda b: rfftn_local(b, axis_name, gshape)  # noqa: E731
        in_spec, out_spec = P(axis_name), fspec
    return jax.jit(shard_map(fn, mesh=mesh, in_specs=in_spec, out_specs=out_spec))


def pencil_rfftn(field: ShardedField):
    """Distributed ``rfftn`` of a :class:`ShardedField` -> sharded half-spectrum.

    Returns a global complex array laid out per :func:`freq_partition_spec`,
    bitwise identical to ``jnp.fft.rfftn`` of the gathered field.
    """
    return _pencil_fft_fn(field.mesh, field.axis_name, field.shape, False)(field.array)


def pencil_irfftn(
    spectrum,
    gshape: Tuple[int, ...],
    mesh,
    axis_name: str = "data",
    strict_bitwise: bool = True,
):
    """Distributed ``irfftn`` -> real field sharded along axis 0."""
    validate_pencil_shape(tuple(gshape), mesh.shape[axis_name], strict_bitwise)
    spectrum = jax.device_put(
        spectrum, NamedSharding(mesh, freq_partition_spec(len(gshape), axis_name))
    )
    return _pencil_fft_fn(mesh, axis_name, tuple(gshape), True)(spectrum)
