"""Pencil-decomposed distributed rFFT: whole fields stay sharded end to end.

The paper's GPU pipeline assumes one device sees the whole spectrum; our
``sharded`` engine backend (PR 2) only shards *pencil batches*, so a whole
field still had to fit one device's HBM before ``rfftn``.  This module is the
missing distributed transform: a 1-D slab decomposition over one mesh axis
(the field sharded along axis 0), local FFTs along unsharded axes, and
``all_to_all`` transposes under the version-portable ``shard_map`` shim.

Generalized (uneven, padded) slab decomposition: ANY axis lengths are
accepted.  Axis 0 decomposes into ``ceil(N0/D)``-row slabs — the global
device array is zero-padded at the tail of axis 0 to ``D * ceil(N0/D)`` so
``shard_map`` sees an evenly divisible layout; the transpose split axes
(axis 1 for 3-D, the rfft half axis for 2-D) are zero-padded *in transit*
around each ``all_to_all`` and sliced back to their true extent before the
per-axis FFT runs.  Pad rows are exactly zero and every transform pass is
linear, so they stay exactly zero through forward, inverse and the whole
POCS loop: clips are no-ops on zeros, displacement accumulators stay zero,
and the strict-inequality violation test can never fire on a zero component
— convergence counts and shell binning therefore need no explicit pad mask
in the loop body (consumers that *normalize* — e.g. the mean-fluctuation
step of the sharded power spectrum — do mask pad rows explicitly).

Bitwise discipline (the PR 2 parity bar, extended to whole fields): the
single-device ``jnp.fft.rfftn`` computes its passes in a fixed axis order —
r2c along the *last* axis, then c2c along axis 0, then axis 1 (verified
empirically on the CPU and TPU DUCC/FFT lowering; ``tests/test_dist_fft.py``
gates it).  The distributed transform applies the *same per-axis passes in
the same order*, transposing between them, and each local pass is
batch-invariant (a slab's rows — or a chunk of its last axis — transform
identically whatever the slab or chunk count; the conformance suite gates
this).  ``all_to_all`` moves bits untouched, padding only ever inserts and
removes exact zeros, and the convergence-count collectives are integer
``psum``s, so the distributed POCS loop — and the FFCz blobs built from it —
are bitwise identical to the single-device path whenever the shape's parity
class is ``"bitwise"``.

Parity tri-state (:func:`classify_parity`): the *inverse* transform carries
a ``1/N`` normalization per c2c axis whose placement the fused kernel
chooses internally; splitting the axes into separate passes reproduces it
bit for bit exactly when each c2c-axis length is a power of two (``1/N`` is
then an exponent shift — placement-invariant; the c2r last axis is
unconstrained: its scale sits inside the same final pass either way).

  ``"bitwise"``  every c2c axis is a power of two: the distributed loop
                 trajectory, edit streams and blob payload reproduce the
                 single-device path bit for bit (uneven slabs included —
                 padding is bitwise-neutral).
  ``"bound"``    some c2c axis is not a power of two: blobs may diverge
                 from the single-device path at float32-rounding level, but
                 the dual-bound guarantee holds regardless (the float64
                 polish enforces the bounds on whatever trajectory the
                 float32 loop took).
  *error*        unsupported rank or degenerate extent —
                 :func:`classify_parity` raises ``ValueError``.

Overlapped (double-buffered) transposes: each 3-D ``all_to_all``+FFT pair is
split into ``overlap_chunks`` independent chunks along the last (half-
spectrum) axis — chunk ``i+1``'s ``all_to_all`` carries no data dependency
on chunk ``i``'s FFT, so XLA's async collectives can overlap communication
with compute on real meshes.  Chunking the last axis is bitwise-neutral
(per-line FFTs are batch-invariant; gated in tests).  2-D fields have no
free axis (the half axis is the transpose axis) and always run single-shot.

Data layout (D = mesh axis size, ``H = N_last // 2 + 1``, ``S0 =
ceil(N0/D)``, ``P0 = D * S0``):

  3-D field (N0, N1, N2), device array (P0, N1, N2), local slab (S0, N1, N2):
    rfft ax2 -> [pad ax1 | a2a(1->0) | slice ax0 to N0 | fft ax0]
             -> [pad ax0 | a2a(0->1) | slice ax1 to N1 | fft ax1]
    spectrum device array (P0, N1, H), local block (S0, N1, H): sharded
    along axis 0 like the field, pad rows exactly zero.
  2-D field (N0, N1), device array (P0, N1), local slab (S0, N1):
    rfft ax1 -> [pad ax1 to D*ceil(H/D) | a2a(1->0) | slice ax0 to N0 | fft ax0]
    spectrum device array (N0, D*ceil(H/D)): sharded along the half axis,
    pad columns exactly zero.

``*_local`` functions run *inside* a ``shard_map`` region on local blocks;
:func:`pencil_rfftn` / :func:`pencil_irfftn` are the global-array wrappers.
:class:`ShardedField` is the engine-facing handle (PLAN/EXECUTE accept it).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.sharding.shardmap import shard_map

#: Default number of last-axis chunks each 3-D all_to_all+FFT pair is split
#: into so communication can overlap compute (1 = single-shot).
DEFAULT_OVERLAP_CHUNKS = 2

_PARITY_STATES = ("bitwise", "bound")


def ceil_div(n: int, d: int) -> int:
    return -(-n // d)


def slab_rows(n0: int, n_dev: int) -> int:
    """Rows of axis 0 each device holds (the padded slab height)."""
    return ceil_div(n0, n_dev)


def padded_extent(n: int, n_dev: int) -> int:
    """``n`` zero-padded up to the next multiple of ``n_dev``."""
    return n_dev * ceil_div(n, n_dev)


def classify_parity(shape: Tuple[int, ...], n_dev: int) -> str:
    """Tri-state parity class of a slab decomposition: value or ValueError.

    Returns ``"bitwise"`` when every c2c axis (all but the last for 3-D,
    axis 0 for 2-D) has power-of-two length — the distributed transforms
    then reproduce the fused single-device ``rfftn``/``irfftn`` bit for bit,
    whatever the slab unevenness.  Returns ``"bound"`` otherwise: results
    may differ from the single-device path at float32-rounding level, but
    the FFCz dual-bound guarantee is unconditional on parity.  Raises
    ``ValueError`` (the *error* state) for unsupported ranks or degenerate
    extents — the only shape restrictions left; divisibility by the mesh is
    handled by the padded decomposition and never an error.
    """
    if len(shape) not in (2, 3):
        raise ValueError(
            f"pencil-decomposed FFT supports 2-D and 3-D fields, got rank {len(shape)} "
            f"(shape {shape}); tile other ranks through the engine's pencil batches instead"
        )
    if any(int(n) < 1 for n in shape):
        raise ValueError(f"field shape {shape} has a degenerate (< 1) axis extent")
    if n_dev < 1:
        raise ValueError(f"mesh axis size must be >= 1, got {n_dev}")
    c2c = shape[:-1]
    if all((int(n) & (int(n) - 1)) == 0 for n in c2c):
        return "bitwise"
    return "bound"


def validate_pencil_shape(
    shape: Tuple[int, ...], n_dev: int, strict_bitwise: bool = True
) -> str:
    """Classify ``shape``'s parity; raise when bitwise is demanded but absent.

    The divisibility constraints of the pre-padded decomposition are gone:
    any 2-D/3-D shape slab-decomposes over any mesh size.  With
    ``strict_bitwise`` (the default), a ``"bound"``-class shape (some c2c
    axis not a power of two) raises instead of silently losing blob parity;
    ``strict_bitwise=False`` accepts it.  Returns the parity class.
    """
    parity = classify_parity(tuple(int(n) for n in shape), n_dev)
    if strict_bitwise and parity != "bitwise":
        bad = [(a, int(n)) for a, n in enumerate(shape[:-1]) if int(n) & (int(n) - 1)]
        a, n = bad[0]
        raise ValueError(
            f"axis {a} length {n} is not a power of two: the inverse FFT's "
            f"1/{n} normalization then rounds differently split per-axis "
            f"than fused, so blobs would not be bitwise identical to the "
            f"single-device path; request parity='auto' (strict_bitwise=False) "
            f"to accept float32-rounding-level divergence (bounds still hold)"
        )
    return parity


@dataclasses.dataclass(frozen=True)
class DistSpec:
    """Static description of one slab decomposition (hashable, jit-static).

    Carried by the ``dist`` mode of
    :func:`repro.core.pocs.alternating_projection` and by the ``*_local``
    transform bodies: the true global shape, the mesh axis, its size (needed
    to size transit padding — unknowable from a traced block alone), and the
    transpose overlap chunk count.
    """

    axis_name: str
    gshape: Tuple[int, ...]
    n_dev: int
    overlap_chunks: int = DEFAULT_OVERLAP_CHUNKS


def freq_partition_spec(ndim: int, axis_name: str) -> P:
    """PartitionSpec of the distributed half-spectrum for a rank-``ndim`` field."""
    return P(axis_name) if ndim == 3 else P(None, axis_name)


def local_freq_shape(gshape: Tuple[int, ...], n_dev: int) -> Tuple[int, ...]:
    """Local (per-device) half-spectrum block shape, pad rows/columns included."""
    h = gshape[-1] // 2 + 1
    if len(gshape) == 3:
        return (slab_rows(gshape[0], n_dev), gshape[1], h)
    return (gshape[0], ceil_div(h, n_dev))


def padded_freq_shape(gshape: Tuple[int, ...], n_dev: int) -> Tuple[int, ...]:
    """Global (device-array) half-spectrum shape, pad rows/columns included."""
    h = gshape[-1] // 2 + 1
    if len(gshape) == 3:
        return (padded_extent(gshape[0], n_dev), gshape[1], h)
    return (gshape[0], padded_extent(h, n_dev))


def padded_spatial_shape(gshape: Tuple[int, ...], n_dev: int) -> Tuple[int, ...]:
    """Global (device-array) spatial shape: axis 0 padded to a slab multiple."""
    return (padded_extent(gshape[0], n_dev),) + tuple(gshape[1:])


def local_pair_weights(
    gshape: Tuple[int, ...], freq_shape: Tuple[int, ...], axis_name: str
):
    """Conjugate-pair multiplicities for a *local* half-spectrum block.

    3-D blocks keep the whole half axis locally, so the static
    :func:`repro.core.cubes.rfft_pair_weights` plane broadcasts as-is (pad
    rows carry weights, but their components are exactly zero, so weighted
    reductions over them vanish).  2-D blocks shard the half axis, so global
    column indices come from ``axis_index`` (traced — call inside the
    ``shard_map`` region only); transit-pad columns beyond the true half
    extent get weight 0.
    """
    # deferred: importing repro.core at module scope would cycle through
    # repro.core.__init__ -> engine -> this module
    from repro.core.cubes import rfft_pair_weights

    if len(gshape) == 3:
        return rfft_pair_weights(gshape)
    n = gshape[-1]
    h = n // 2 + 1
    h_loc = freq_shape[-1]
    col = jax.lax.axis_index(axis_name) * h_loc + jnp.arange(h_loc)
    w = jnp.where(col == 0, 1, 2)
    if n % 2 == 0:
        w = jnp.where(col == h - 1, 1, w)
    w = jnp.where(col >= h, 0, w)  # transit-pad columns: not spectrum at all
    return w.astype(jnp.int32)[None, :]


def _pad_axis_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _transpose_apply(
    t: jnp.ndarray,
    spec: DistSpec,
    split_axis: int,
    concat_axis: int,
    keep: int,
    apply_fn,
) -> jnp.ndarray:
    """One transpose+FFT pair: pad -> all_to_all -> slice -> per-axis pass.

    Pads ``split_axis`` with zeros to a mesh-size multiple so the tiled
    ``all_to_all`` is well formed on any extent, slices ``concat_axis`` back
    to its true extent ``keep`` (dropping slab padding before the transform
    sees it), then runs ``apply_fn`` (the c2c/c2r pass along
    ``concat_axis``).  When the last axis is free (3-D) and
    ``spec.overlap_chunks > 1``, the pair is double-buffered: the block is
    split into independent last-axis chunks so chunk ``i+1``'s all_to_all
    can overlap chunk ``i``'s FFT on meshes with async collectives.
    Chunking is bitwise-neutral (per-line FFTs are batch-invariant).
    """

    def one(piece: jnp.ndarray) -> jnp.ndarray:
        piece = _pad_axis_to(piece, split_axis, spec.n_dev)
        piece = jax.lax.all_to_all(
            piece,
            spec.axis_name,
            split_axis=split_axis,
            concat_axis=concat_axis,
            tiled=True,
        )
        if piece.shape[concat_axis] != keep:
            piece = jax.lax.slice_in_dim(piece, 0, keep, axis=concat_axis)
        return apply_fn(piece)

    last = t.ndim - 1
    chunks = spec.overlap_chunks
    if chunks <= 1 or last in (split_axis, concat_axis) or t.shape[last] < chunks:
        return one(t)
    base, rem = divmod(t.shape[last], chunks)
    sizes = [base + (1 if i < rem else 0) for i in range(chunks)]
    pieces, off = [], 0
    for sz in sizes:
        pieces.append(jax.lax.slice_in_dim(t, off, off + sz, axis=last))
        off += sz
    return jnp.concatenate([one(p) for p in pieces], axis=last)


def rfftn_local(block: jnp.ndarray, spec: DistSpec) -> jnp.ndarray:
    """Distributed ``rfftn`` body: local passes + padded all_to_all transposes.

    The pass order (r2c last axis, then c2c axis 0, then axis 1) mirrors the
    fused single-device ``jnp.fft.rfftn`` exactly; slab/transit padding is
    sliced away before each c2c pass, so every transform runs at its true
    length (gated by tests/test_dist_fft.py and the conformance suite).
    """
    gshape = spec.gshape
    nd = len(gshape)
    r = jnp.fft.rfft(block, axis=nd - 1)
    t = _transpose_apply(
        r,
        spec,
        split_axis=1,
        concat_axis=0,
        keep=gshape[0],
        apply_fn=lambda p: jnp.fft.fft(p, axis=0),
    )
    if nd == 2:
        return t
    return _transpose_apply(
        t,
        spec,
        split_axis=0,
        concat_axis=1,
        keep=gshape[1],
        apply_fn=lambda p: jnp.fft.fft(p, axis=1),
    )


def _c2r_last(p: jnp.ndarray, n: int, fft_impl: str) -> jnp.ndarray:
    """The local last-axis C2R pass, with the pack-trick fast path.

    ``fft_impl="packed"`` swaps XLA's C2R custom call (the measured slow
    half of the loop) for :func:`repro.kernels.rfft.ops.packed_irfft` — a
    per-line transform, so it composes with the pencil decomposition
    unchanged (every line it sees is a full half-spectrum of a real line).
    Odd last axes fall back to XLA.  The packed pass rounds differently
    from the fused single-device inverse, so distributed parity under it is
    ``"bound"``, never ``"bitwise"``.
    """
    if fft_impl == "packed" and n % 2 == 0 and n >= 2:
        from repro.kernels.rfft import ops as rfft_ops

        return rfft_ops.packed_irfft(p, n)
    return jnp.fft.irfft(p, n=n, axis=p.ndim - 1)


def irfftn_local(
    block: jnp.ndarray, spec: DistSpec, fft_impl: str = "xla"
) -> jnp.ndarray:
    """Distributed ``irfftn`` body (inverse pass order: axis 0, axis 1, c2r last).

    ``fft_impl="packed"`` runs the final local c2r pass through the
    pack-trick transform (see :func:`_c2r_last`).
    """
    gshape = spec.gshape
    nd = len(gshape)
    if nd == 2:
        t = jnp.fft.ifft(block, axis=0)
        return _transpose_apply(
            t,
            spec,
            split_axis=0,
            concat_axis=1,
            keep=gshape[-1] // 2 + 1,
            apply_fn=lambda p: _c2r_last(p, gshape[1], fft_impl),
        )
    t = _transpose_apply(
        block,
        spec,
        split_axis=1,
        concat_axis=0,
        keep=gshape[0],
        apply_fn=lambda p: jnp.fft.ifft(p, axis=0),
    )
    t = _transpose_apply(
        t,
        spec,
        split_axis=0,
        concat_axis=1,
        keep=gshape[1],
        apply_fn=lambda p: jnp.fft.ifft(p, axis=1),
    )
    return _c2r_last(t, gshape[2], fft_impl)


def _as_parity_request(parity, strict_bitwise) -> str:
    """Normalize the user's parity request; bools alias the legacy kwarg."""
    if strict_bitwise is not None:
        parity = strict_bitwise
    if parity is True:
        return "bitwise"
    if parity is False or parity is None or parity == "auto":
        return "auto"
    if parity in _PARITY_STATES:
        return parity
    raise ValueError(
        f"parity must be 'auto', 'bitwise' or 'bound' (or a legacy strict_bitwise "
        f"bool), got {parity!r}"
    )


class ShardedField:
    """A real 2-D/3-D field slab-sharded along axis 0 over one mesh axis.

    The engine-facing handle for distributed whole-field FFCz:
    ``CorrectionEngine.plan_field`` / ``execute_field`` and ``FFCz.compress``
    accept it, keeping field-sized device state sharded through the whole
    spectral pipeline.  ANY axis extents are accepted: the device array is
    the field zero-padded at the tail of axis 0 to an even slab multiple
    (``padded_shape``), while ``shape`` stays the true extent and every
    host-facing accessor (``to_host``, the engine's plan/encode staging)
    works on the unpadded field.

    ``parity`` is the requested parity class: ``"auto"`` (default) accepts
    whatever :func:`classify_parity` assigns the shape; ``"bitwise"``
    *requires* single-device blob parity and raises on a ``"bound"``-class
    shape; ``"bound"`` documents that the caller expects divergence.  The
    classification itself is always available as :attr:`parity`.  The
    legacy ``strict_bitwise`` bool is accepted as an alias
    (``True == "bitwise"``, ``False == "auto"``).

    ``to_host()`` is the explicit (cached) host staging used only at the
    base-compressor and edit-encode boundaries — the same host-RAM boundary
    the single-device pipeline has; device HBM never holds the gathered
    field.
    """

    def __init__(
        self,
        array,
        mesh,
        axis_name: str = "data",
        parity: Union[str, bool, None] = "auto",
        overlap_chunks: int = DEFAULT_OVERLAP_CHUNKS,
        strict_bitwise: Optional[bool] = None,
    ):
        shape = tuple(int(n) for n in array.shape)
        n_dev = mesh.shape[axis_name]
        self.parity_requested = _as_parity_request(parity, strict_bitwise)
        self.parity = classify_parity(shape, n_dev)
        if self.parity_requested == "bitwise" and self.parity != "bitwise":
            validate_pencil_shape(shape, n_dev, strict_bitwise=True)  # raises
        self.mesh = mesh
        self.axis_name = axis_name
        self.overlap_chunks = int(overlap_chunks)
        self.gshape = shape
        self.padded_shape = padded_spatial_shape(shape, n_dev)
        x32 = np.asarray(array, dtype=np.float32)
        pad0 = self.padded_shape[0] - shape[0]
        if pad0:
            x32 = np.pad(x32, [(0, pad0)] + [(0, 0)] * (len(shape) - 1))
        self.array = jax.device_put(x32, NamedSharding(mesh, self.spec))
        self._host: Optional[np.ndarray] = None

    @classmethod
    def shard(
        cls,
        x: np.ndarray,
        mesh=None,
        axis_name: str = "data",
        parity: Union[str, bool, None] = "auto",
        overlap_chunks: int = DEFAULT_OVERLAP_CHUNKS,
        strict_bitwise: Optional[bool] = None,
    ) -> "ShardedField":
        """Shard a host array over ``mesh[axis_name]`` (default: all devices)."""
        if mesh is None:
            mesh = jax.make_mesh((len(jax.devices()),), (axis_name,))
        return cls(
            x, mesh, axis_name, parity, overlap_chunks, strict_bitwise=strict_bitwise
        )

    @property
    def spec(self) -> P:
        return P(self.axis_name)

    @property
    def freq_spec(self) -> P:
        return freq_partition_spec(self.ndim, self.axis_name)

    @property
    def shape(self) -> Tuple[int, ...]:
        """The TRUE (unpadded) global field shape."""
        return self.gshape

    @property
    def ndim(self) -> int:
        return len(self.gshape)

    @property
    def n_dev(self) -> int:
        return self.mesh.shape[self.axis_name]

    @property
    def padded_freq_shape(self) -> Tuple[int, ...]:
        return padded_freq_shape(self.gshape, self.n_dev)

    @property
    def freq_shape(self) -> Tuple[int, ...]:
        """The TRUE (unpadded) rfft half-spectrum shape."""
        return tuple(self.gshape[:-1]) + (self.gshape[-1] // 2 + 1,)

    @property
    def dist_spec(self) -> DistSpec:
        return DistSpec(self.axis_name, self.gshape, self.n_dev, self.overlap_chunks)

    def unpad_spatial(self, a):
        """Slice a padded (device-layout) spatial array to the true extents."""
        return a[: self.gshape[0]]

    def unpad_freq(self, a):
        """Slice a padded (device-layout) half-spectrum to the true extents."""
        if self.ndim == 3:
            return a[: self.gshape[0]]
        return a[:, : self.freq_shape[-1]]

    def pad_spatial_np(self, grid: np.ndarray, fill: float = 0.0) -> np.ndarray:
        """Pad a true-extent spatial grid to the device layout.

        ``fill`` sets the pad-row value — bound grids (ROI ``E_n``) pad with
        the background bound so the zero pad rows of the sharded field stay
        inside their cube (``clip(0, ±fill) == 0`` needs ``fill > 0``).
        """
        pad0 = self.padded_shape[0] - self.gshape[0]
        if pad0:
            widths = [(0, pad0)] + [(0, 0)] * (self.ndim - 1)
            return np.pad(grid, widths, constant_values=fill)
        return grid

    def pad_freq_np(self, grid: np.ndarray) -> np.ndarray:
        """Zero-pad a true-extent half-spectrum grid to the device layout."""
        pfs = self.padded_freq_shape
        widths = [(0, p - t) for p, t in zip(pfs, grid.shape)]
        if any(w != (0, 0) for w in widths):
            return np.pad(grid, widths)
        return grid

    def to_host(self) -> np.ndarray:
        """Gathered UNPADDED host copy (cached) — the codec staging buffer."""
        if self._host is None:
            self._host = np.asarray(self.unpad_spatial(self.array))
        return self._host


@functools.lru_cache(maxsize=None)
def _pencil_fft_fn(mesh, spec: DistSpec, inverse: bool):
    fspec = freq_partition_spec(len(spec.gshape), spec.axis_name)
    if inverse:
        fn = lambda b: irfftn_local(b, spec)  # noqa: E731
        in_spec, out_spec = fspec, P(spec.axis_name)
    else:
        fn = lambda b: rfftn_local(b, spec)  # noqa: E731
        in_spec, out_spec = P(spec.axis_name), fspec
    return jax.jit(shard_map(fn, mesh=mesh, in_specs=in_spec, out_specs=out_spec))


def pencil_rfftn(field: ShardedField):
    """Distributed ``rfftn`` of a :class:`ShardedField` -> sharded half-spectrum.

    Returns a global complex array in the PADDED device layout
    (:attr:`ShardedField.padded_freq_shape`, laid out per
    :func:`freq_partition_spec`); pad rows/columns are exactly zero and
    ``field.unpad_freq`` slices them away.  The true-extent region is
    bitwise identical to ``jnp.fft.rfftn`` of the gathered field for
    ``"bitwise"``-class shapes.
    """
    return _pencil_fft_fn(field.mesh, field.dist_spec, False)(field.array)


def pencil_irfftn(
    spectrum,
    gshape: Tuple[int, ...],
    mesh,
    axis_name: str = "data",
    parity: Union[str, bool, None] = "auto",
    overlap_chunks: int = DEFAULT_OVERLAP_CHUNKS,
    strict_bitwise: Optional[bool] = None,
):
    """Distributed ``irfftn`` -> real field sharded along axis 0.

    ``spectrum`` may be given in the padded device layout of ANY writer mesh
    (what :func:`pencil_rfftn` returns — pad rows/columns are zero and sit
    at the tail, so a foreign mesh's padding is sliced off) or at the true
    half-spectrum extents; either is re-padded to THIS mesh's layout on
    host.  Returns the UNPADDED global field.
    """
    gshape = tuple(int(n) for n in gshape)
    n_dev = mesh.shape[axis_name]
    if _as_parity_request(parity, strict_bitwise) == "bitwise":
        validate_pencil_shape(gshape, n_dev, strict_bitwise=True)
    else:
        classify_parity(gshape, n_dev)
    pfs = padded_freq_shape(gshape, n_dev)
    if tuple(spectrum.shape) != pfs:
        true_fs = tuple(gshape[:-1]) + (gshape[-1] // 2 + 1,)
        if any(s < t for s, t in zip(spectrum.shape, true_fs)):
            raise ValueError(
                f"spectrum shape {tuple(spectrum.shape)} is smaller than the "
                f"half-spectrum {true_fs} of field shape {gshape}; pass the "
                f"true-extent spectrum or a padded device layout"
            )
        spectrum = np.asarray(spectrum)[tuple(slice(0, t) for t in true_fs)]
        widths = [(0, p - t) for p, t in zip(pfs, true_fs)]
        spectrum = np.pad(spectrum, widths)
    spectrum = jax.device_put(
        spectrum, NamedSharding(mesh, freq_partition_spec(len(gshape), axis_name))
    )
    spec = DistSpec(axis_name, gshape, n_dev, int(overlap_chunks))
    out = _pencil_fft_fn(mesh, spec, True)(spectrum)
    return out[: gshape[0]]
