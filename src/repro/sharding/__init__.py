"""Partition rules: DP/TP/EP/SP/FSDP over the production mesh."""

from repro.sharding.rules import batch_pspec, cache_pspecs, param_pspecs, to_shardings

__all__ = ["param_pspecs", "cache_pspecs", "batch_pspec", "to_shardings"]
