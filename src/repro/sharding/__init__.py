"""Partition rules (DP/TP/EP/SP/FSDP) + the pencil-decomposed distributed FFT."""

from repro.sharding.dist_fft import (
    DistSpec,
    ShardedField,
    classify_parity,
    pencil_irfftn,
    pencil_rfftn,
    validate_pencil_shape,
)
from repro.sharding.rules import batch_pspec, cache_pspecs, param_pspecs, to_shardings

__all__ = [
    "param_pspecs",
    "cache_pspecs",
    "batch_pspec",
    "to_shardings",
    "DistSpec",
    "ShardedField",
    "classify_parity",
    "pencil_rfftn",
    "pencil_irfftn",
    "validate_pencil_shape",
]
