"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40 experts top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base; hf].
"""

import dataclasses

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    head_dim=64,
    n_experts=40,
    top_k=8,
    moe_every=1,
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG,
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=64,
    vocab=256,
    n_experts=4,
    top_k=2,
    dtype="float32",
)
