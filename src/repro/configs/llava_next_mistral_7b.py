"""llava-next-mistral-7b [vlm] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000 — anyres tiling [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].

The vision frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings (anyres base 576 + 4 tiles = 2880 tokens,
CLIP-L dim 1024); the in-model part is the 2-layer MLP projector + the
Mistral-7B backbone.
"""

import dataclasses

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    head_dim=128,
    vision_tokens=2880,
    vision_dim=1024,
)

SMOKE = dataclasses.replace(
    CONFIG,
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=256,
    vision_tokens=16,
    vision_dim=32,
    dtype="float32",
)
