"""mamba2-2.7b [ssm] — 64L d_model=2560 (attention-free) vocab=50280,
ssm_state=128 — SSD (state-space duality) [arXiv:2405.21060; unverified].

d_inner = 2*d_model = 5120, headdim 64 => 80 SSD heads.  O(1) decode state,
so the long_500k cell runs natively (no KV cache).
"""

import dataclasses

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_ngroups=1,
    ssm_chunk=256,
    conv_kernel=4,
    pos_type="none",
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG,
    n_layers=2,
    d_model=64,
    ssm_state=16,
    ssm_headdim=16,
    ssm_chunk=32,
    vocab=256,
    dtype="float32",
)
