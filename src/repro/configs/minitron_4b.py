"""minitron-4b [dense] — 32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000.

Pruned Nemotron [arXiv:2407.14679; hf].  The 256k vocabulary makes the
embedding/LM head the dominant tensor — vocab-sharded on the model axis.
"""

import dataclasses

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="minitron-4b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=9216,
    vocab=256000,
    head_dim=128,
)

SMOKE = dataclasses.replace(
    CONFIG,
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=512,
    dtype="float32",
)
