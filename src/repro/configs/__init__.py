"""Architecture registry: one module per assigned architecture (+ paper's own
field configs in ffcz_fields.py).  ``get_config(name)`` returns the full
published config; ``get_smoke_config(name)`` returns the reduced same-family
config used by CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

ARCH_IDS = (
    "qwen2-0.5b",
    "qwen2-7b",
    "granite-3-2b",
    "minitron-4b",
    "granite-moe-3b-a800m",
    "llama4-maverick-400b-a17b",
    "mamba2-2.7b",
    "zamba2-7b",
    "llava-next-mistral-7b",
    "whisper-tiny",
)

SHAPE_IDS = ("train_4k", "prefill_32k", "decode_32k", "long_500k")

#: (seq_len, global_batch, kind) per shape cell
SHAPES = {
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    """FFCz integration knobs (first-class feature, DESIGN.md §3)."""

    grad_compression: bool = False
    grad_E_rel: float = 1e-2
    grad_Delta_rel: float = 1e-2
    grad_block: int = 4096
    grad_bits: int = 8
    checkpoint_compression: bool = False
    ckpt_E_rel: float = 1e-4
    ckpt_Delta_rel: float = 1e-4
    kv_cache_compression: bool = False
    kv_E_rel: float = 1e-2
    kv_Delta_rel: float = 1e-2


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    qkv_bias: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1  # apply MoE every k-th layer (others dense)
    shared_expert: bool = False
    capacity_factor: float = 1.25
    # --- SSM (Mamba2/SSD) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_ngroups: int = 1
    ssm_chunk: int = 256
    conv_kernel: int = 4
    # --- hybrid (Zamba2-style shared attention) ---
    attn_every: int = 0  # >0: weight-shared attention block every k core layers
    # --- encoder-decoder (Whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0  # stub frontend sequence length (audio frames)
    # --- VLM stub ---
    vision_tokens: int = 0
    vision_dim: int = 0
    # --- common ---
    pos_type: str = "rope"  # rope | sinusoidal | none
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- runtime ---
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    attention_impl: str = "xla_flash"  # xla_flash | pallas | naive
    remat: str = "dots"  # none | dots | full
    causal_scheduling: bool = True  # skip fully-masked causal kv blocks (perf)
    # Mesh axes ((name, size), ...) injected by launch.steps at step-build
    # time so model code can place adaptive sharding constraints
    # (attention-internal activation sharding — §Perf iteration 1).
    mesh_axes: tuple = ()
    # §Perf toggle: explicit attention activation sharding constraints
    shard_attn_activations: bool = True
    compression: CompressionConfig = dataclasses.field(default_factory=CompressionConfig)

    def axis_size(self, name: str) -> int:
        return dict(self.mesh_axes).get(name, 1)

    def dp_axes(self):
        return tuple(a for a, _ in self.mesh_axes if a in ("pod", "data"))

    @property
    def vocab_padded(self) -> int:
        """Vocab padded to a multiple of 128 (Megatron-style) so the
        embedding/LM-head stays TP-shardable for odd vocabularies
        (49155, 50280, 51865, 202048...).  Logits for padded ids are masked."""
        return ((self.vocab + 127) // 128) * 128

    @property
    def n_experts_padded(self) -> int:
        """Experts padded to a multiple of 16 so the expert axis EP-shards on
        the production TP degree (dead experts are never routed — the router
        stays at n_experts).  §Perf: even EP keeps the expert GEMMs local."""
        return ((self.n_experts + 15) // 16) * 16 if self.n_experts else 0

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def supports_long_context(self) -> bool:
        """Sub-quadratic decode state => long_500k is runnable."""
        return self.family in ("ssm", "hybrid")

    def has_decoder(self) -> bool:
        return True  # all assigned archs have a causal decoder (whisper is enc-dec)

    def cells(self) -> Tuple[str, ...]:
        """Runnable shape cells for this arch (skips noted in DESIGN.md)."""
        out = ["train_4k", "prefill_32k", "decode_32k"]
        if self.supports_long_context():
            out.append("long_500k")
        return tuple(out)


_MODULES = {arch: arch.replace("-", "_").replace(".", "_") for arch in ARCH_IDS}


def get_config(name: str, **overrides) -> ArchConfig:
    if name not in _MODULES:
        raise ValueError(f"unknown arch {name!r}; have {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    cfg = mod.CONFIG
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def get_smoke_config(name: str, **overrides) -> ArchConfig:
    if name not in _MODULES:
        raise ValueError(f"unknown arch {name!r}; have {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    cfg = mod.SMOKE
    return dataclasses.replace(cfg, **overrides) if overrides else cfg
