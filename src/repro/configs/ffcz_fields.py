"""The paper's own experiment configurations (Table I datasets + §V bounds).

Synthetic analogues of the benchmark datasets (DESIGN.md §6) at
container-feasible resolutions, with the spectral character of the originals:

  nyx-like    3D Gaussian random field, power-law P(k) ~ k^-alpha (cosmology)
  s3d-like    3D smooth field, exponential spectrum (combustion)
  hedm-like   2D sparse diffraction spots on noise floor
  eeg-like    1D 1/f noise series
"""

from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class FieldConfig:
    name: str
    shape: Tuple[int, ...]
    kind: str  # powerlaw | exponential | spots | pink
    alpha: float = 2.0
    seed: int = 0


FIELDS = {
    "nyx-like": FieldConfig("nyx-like", (64, 64, 64), "lognormal", alpha=2.0),
    "nyx-like-128": FieldConfig("nyx-like-128", (128, 128, 128), "lognormal", alpha=2.0),
    "grf-like": FieldConfig("grf-like", (64, 64, 64), "powerlaw", alpha=2.0),
    "s3d-like": FieldConfig("s3d-like", (64, 64, 64), "exponential", alpha=8.0),
    "hedm-like": FieldConfig("hedm-like", (256, 256), "spots"),
    "eeg-like": FieldConfig("eeg-like", (31_000,), "pink", alpha=1.0),
}

#: paper §V-B: relative spatial bound 0.1%; RFE bounds chosen to cut the max
#: frequency error of the base compressor by ~100x.
DEFAULT_E_REL = 1e-3
DEFAULT_DELTA_REL = 1e-3
PSPEC_REL = 1e-3  # Fig. 10: 0.1% relative power-spectrum bound
