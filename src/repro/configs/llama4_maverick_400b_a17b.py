"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 128 experts top-1, interleaved dense/MoE layers with a
shared expert [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].
"""

import dataclasses

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    head_dim=128,
    n_experts=128,
    top_k=1,
    moe_every=2,  # alternating dense / MoE
    shared_expert=True,
)

SMOKE = dataclasses.replace(
    CONFIG,
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=96,
    vocab=256,
    n_experts=8,
    top_k=1,
    dtype="float32",
)
