"""whisper-tiny [audio] — 4L d_model=384 6H (MHA kv=6) d_ff=1536 vocab=51865 —
encoder-decoder, conv frontend STUB [arXiv:2212.04356; unverified].

input_specs() provides precomputed mel-frame embeddings (1500 frames after
the conv downsampling, d=384); 4 encoder + 4 decoder layers with
cross-attention.  Whisper uses learned/sinusoidal positions, not RoPE.  The
32k decode cells exercise the assigned shape (far beyond Whisper's real
448-token context, noted in DESIGN.md).
"""

import dataclasses

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    head_dim=64,
    encoder_layers=4,
    encoder_seq=1500,
    pos_type="sinusoidal",
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG,
    n_layers=2,
    encoder_layers=2,
    encoder_seq=32,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab=256,
    dtype="float32",
)
