"""zamba2-7b [hybrid] — 81L d_model=3584 32H (GQA kv=32 => MHA) d_ff=14336
vocab=32000, ssm_state=64 — Mamba2 backbone + weight-SHARED attention block
every 6 core layers, fed concat(hidden, embedding) [arXiv:2411.15242;
unverified].

Hybrid family: decode state = SSM states + KV only at shared-attn
invocations => long_500k runs.
"""

import dataclasses

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    head_dim=112,
    ssm_state=64,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_ngroups=1,
    ssm_chunk=256,
    conv_kernel=4,
    attn_every=6,
)

SMOKE = dataclasses.replace(
    CONFIG,
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab=256,
    ssm_state=16,
    ssm_headdim=16,
    ssm_chunk=32,
    attn_every=2,
    dtype="float32",
)
