"""Mixture-of-Experts FFN with capacity-based dispatch and expert parallelism.

Top-k routing -> stable sort by expert -> capacity-bounded scatter into a
dense (experts, capacity, d) buffer -> batched per-expert SwiGLU GEMMs ->
weighted gather back.  All shapes are static; under the production mesh the
expert axis is sharded on "model" (EP) and the token axis on "data"/"pod"
(DP), so GSPMD materializes the dispatch/return as all-to-alls.

Tokens routed beyond an expert's capacity are dropped for that expert (their
other top-k choices and the residual connection still carry them) — the
standard capacity_factor trade-off; the router's softmax weights are
renormalized over the surviving choices.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

Params = Dict[str, Any]


def moe_init(key, d: int, f: int, n_experts: int, shared_expert: bool, dtype,
             n_experts_padded: int | None = None) -> Params:
    e_pad = n_experts_padded or n_experts
    kg, k1, k2, k3, ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(kg, d, n_experts, jnp.float32),  # router kept fp32
        "w_gate": _expert_init(k1, e_pad, d, f, dtype),
        "w_up": _expert_init(k2, e_pad, d, f, dtype),
        "w_down": _expert_init(k3, e_pad, f, d, dtype),
    }
    if shared_expert:
        from repro.models.layers import swiglu_init

        p["shared"] = swiglu_init(ks, d, f, dtype)
    return p


def _expert_init(key, e: int, d_in: int, d_out: int, dtype):
    scale = 1.0 / jnp.sqrt(d_in)
    return (jax.random.normal(key, (e, d_in, d_out), dtype=jnp.float32) * scale).astype(dtype)


def capacity_of(n_tokens: int, top_k: int, n_experts: int, capacity_factor: float) -> int:
    cap = int(n_tokens * top_k * capacity_factor / n_experts)
    return max(8, ((cap + 7) // 8) * 8)  # VPU-sublane aligned


def _constrain_ep(t: jnp.ndarray, mesh_axes: tuple, e_pad: int) -> jnp.ndarray:
    if not mesh_axes:
        return t
    tp = dict(mesh_axes).get("model", 1)
    if tp <= 1 or e_pad % tp != 0:
        return t
    from jax.sharding import PartitionSpec as P

    return jax.lax.with_sharding_constraint(t, P("model", None, None))


def _constrain_replicated(t: jnp.ndarray, mesh_axes: tuple) -> jnp.ndarray:
    if not mesh_axes:
        return t
    from jax.sharding import PartitionSpec as P

    return jax.lax.with_sharding_constraint(t, P(*([None] * t.ndim)))


def moe_apply(params: Params, x: jnp.ndarray, *, top_k: int, capacity_factor: float = 1.25,
              mesh_axes: tuple = ()) -> jnp.ndarray:
    """x: (b, s, d) -> (b, s, d)."""
    b, s, d = x.shape
    n_experts = params["router"].shape[1]  # routable (un-padded) experts
    e_pad = params["w_gate"].shape[0]
    tokens = x.reshape(-1, d)
    T = tokens.shape[0]
    C = capacity_of(T, top_k, n_experts, capacity_factor)

    logits = tokens.astype(jnp.float32) @ params["router"]  # (T, E)
    top_w, top_i = jax.lax.top_k(logits, top_k)  # (T, k)
    top_w = jax.nn.softmax(top_w, axis=-1)

    # flatten (token, choice) pairs and rank within each expert
    flat_e = top_i.reshape(-1)  # (T*k,)
    flat_w = top_w.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), top_k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    sorted_t = flat_t[order]
    sorted_w = flat_w[order]
    # position within expert group = index - first index of that expert
    seg_starts = jnp.searchsorted(sorted_e, jnp.arange(n_experts), side="left")
    pos_in_e = jnp.arange(T * top_k) - seg_starts[sorted_e]
    keep = pos_in_e < C
    # dropped pairs get an OUT-OF-RANGE slot: every scatter below uses
    # mode="drop", so they vanish instead of clobbering a real slot
    slot_e = jnp.where(keep, sorted_e, e_pad)
    slot_c = jnp.where(keep, pos_in_e, C)

    # dispatch: (E_pad, C, d) buffer; dropped pairs write zeros
    buf = jnp.zeros((e_pad, C, d), dtype=x.dtype)
    payload = jnp.where(keep[:, None], tokens[sorted_t], 0.0).astype(x.dtype)
    buf = buf.at[slot_e, slot_c].add(payload, mode="drop")

    # EP layout (§Perf): buffer + expert GEMMs sharded on the (padded,
    # TP-divisible) expert axis; expert d replicated, f FSDP-sharded -> the
    # gate/up GEMMs are fully local and only the row-parallel down GEMM
    # all-reduces its (E_loc, C, d) partials (small: C ~ tokens*k/E)
    buf = _constrain_ep(buf, mesh_axes, e_pad)
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"]))
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    eout = jnp.einsum("ecf,efd->ecd", g * u, params["w_down"])  # (E_pad, C, d)

    # Combine, expert-side (§Perf iter on granite-moe prefill): scatter the
    # per-slot routing weight and token index into EP-sharded (E_pad, C)
    # planes, weight the expert outputs locally, and scatter-add slot rows
    # into the (T, d) token output.  Each EP shard contributes partials for
    # its experts only, so the combine costs ONE token-sized all-reduce — a
    # token-indexed GATHER from the sharded buffer instead makes GSPMD
    # replicate a (T*k, d) tensor per layer (measured 3.3e12 B vs ~1e11 B).
    w_kept = jnp.where(keep, sorted_w, 0.0)
    denom = jnp.zeros((T,), jnp.float32).at[sorted_t].add(w_kept)
    w_norm = w_kept / jnp.maximum(denom[sorted_t], 1e-9)
    w_slot = jnp.zeros((e_pad, C), jnp.float32).at[slot_e, slot_c].add(
        jnp.where(keep, w_norm, 0.0), mode="drop"
    )
    tok_slot = jnp.full((e_pad, C), T, jnp.int32).at[slot_e, slot_c].set(
        jnp.where(keep, sorted_t, T).astype(jnp.int32), mode="drop"
    )
    contrib = eout * w_slot[..., None].astype(eout.dtype)  # (E_pad, C, d), EP-local
    out = (
        jnp.zeros((T, d), dtype=jnp.float32)
        .at[tok_slot.reshape(-1)]
        .add(contrib.reshape(-1, d).astype(jnp.float32), mode="drop")
    )
    out = out.astype(x.dtype)

    if "shared" in params:
        from repro.models.layers import swiglu

        out = out + swiglu(params["shared"], tokens)
    return out.reshape(b, s, d)


def moe_ref(params: Params, x: jnp.ndarray, *, top_k: int) -> jnp.ndarray:
    """Dense oracle (no capacity drops): every token through its top-k experts.

    Used by tests; O(E) FLOPs, tiny configs only.
    """
    b, s, d = x.shape
    tokens = x.reshape(-1, d)
    logits = tokens.astype(jnp.float32) @ params["router"]
    top_w, top_i = jax.lax.top_k(logits, top_k)
    top_w = jax.nn.softmax(top_w, axis=-1)
    g = jax.nn.silu(jnp.einsum("td,edf->tef", tokens, params["w_gate"]))
    u = jnp.einsum("td,edf->tef", tokens, params["w_up"])
    all_out = jnp.einsum("tef,efd->ted", g * u, params["w_down"])  # (T, E, d)
    sel = jnp.take_along_axis(all_out, top_i[:, :, None], axis=1)  # (T, k, d)
    out = jnp.sum(sel * top_w[:, :, None].astype(x.dtype), axis=1)
    if "shared" in params:
        from repro.models.layers import swiglu

        out = out + swiglu(params["shared"], tokens)
    return out.reshape(b, s, d)
