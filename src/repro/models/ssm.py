"""Mamba2 / SSD (state-space duality) blocks [arXiv:2405.21060].

Training/prefill uses the chunked SSD algorithm: quadratic attention-like
compute inside chunks of length Q, linear recurrence across chunk summaries
(a lax.scan over chunks), giving O(L*Q) work and O(1) decode state.  Decode
is the exact SSM recurrence on a (b, h, p, n) state plus a (k-1)-tap causal
conv cache — this is why the ssm/hybrid families run the long_500k cell.

Layout: b batch, l seq, h heads, p headdim, g B/C groups, n state dim.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rmsnorm, rmsnorm_init

Params = Dict[str, Any]


def mamba2_init(key, cfg) -> Params:
    d = cfg.d_model
    d_inner = cfg.d_inner
    h = cfg.ssm_nheads
    g = cfg.ssm_ngroups
    n = cfg.ssm_state
    conv_dim = d_inner + 2 * g * n
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dtype = jnp.dtype(cfg.dtype)
    # in_proj -> [z (d_inner), x (d_inner), B (g*n), C (g*n), dt (h)]
    return {
        "in_proj": dense_init(k1, d, 2 * d_inner + 2 * g * n + h, dtype),
        "conv_w": (jax.random.normal(k2, (cfg.conv_kernel, conv_dim), dtype=jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype=dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), dtype=jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((h,), 0.01))).astype(jnp.float32),
        "norm": rmsnorm_init(d_inner, dtype),
        "out_proj": dense_init(k3, d_inner, d, dtype),
    }


def _split_proj(proj: jnp.ndarray, cfg):
    d_inner, g, n, h = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    z = proj[..., :d_inner]
    xBC = proj[..., d_inner : 2 * d_inner + 2 * g * n]
    dt = proj[..., 2 * d_inner + 2 * g * n :]
    return z, xBC, dt


def _causal_conv(xBC: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv along seq; xBC (b, l, c), w (k, c)."""
    k = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xBC.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def _segsum_decay(dtA: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """dtA: (..., q, h) chunk-local decays.  Returns (cumsum (...,q,h),
    L (..., h, q, q)) with L[i,j] = exp(sum_{j<m<=i} dtA[m]) for i>=j else 0."""
    cum = jnp.cumsum(dtA, axis=-2)  # (..., q, h)
    ci = jnp.swapaxes(cum, -1, -2)[..., :, :, None]  # (..., h, q, 1)
    cj = jnp.swapaxes(cum, -1, -2)[..., :, None, :]  # (..., h, 1, q)
    q = dtA.shape[-2]
    mask = jnp.tril(jnp.ones((q, q), dtype=bool))
    # Double-where: the masked-out (i < j) exponents are *positive* sums of
    # |dtA| and overflow exp to inf for long chunks / large A, which turns the
    # where's backward pass into inf * 0 = NaN.  Zeroing diff before exp keeps
    # the untaken branch finite; in-mask values are untouched.
    diff = jnp.where(mask, ci - cj, 0.0)
    L = jnp.where(mask, jnp.exp(diff), 0.0)
    return cum, L


def ssd_chunked(
    x: jnp.ndarray,  # (b, l, h, p) already dt-weighted NOT — raw x
    dt: jnp.ndarray,  # (b, l, h) positive
    A: jnp.ndarray,  # (h,) positive decay rates (state uses exp(-dt*A))
    B: jnp.ndarray,  # (b, l, g, n)
    C: jnp.ndarray,  # (b, l, g, n)
    chunk: int,
    initial_state: jnp.ndarray | None = None,  # (b, h, p, n)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan.  Returns (y (b,l,h,p), final_state (b,h,p,n))."""
    bsz, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    pad = (-l) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    L = x.shape[1]
    nc = L // chunk

    xc = x.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h)
    Bc = B.reshape(bsz, nc, chunk, g, n)
    Cc = C.reshape(bsz, nc, chunk, g, n)
    Bh = jnp.repeat(Bc, rep, axis=3)  # (b,nc,q,h,n)
    Ch = jnp.repeat(Cc, rep, axis=3)

    dtA = -dtc * A[None, None, None, :]  # (b,nc,q,h) negative
    cum, Lmat = _segsum_decay(dtA)  # cum (b,nc,q,h); Lmat (b,nc,h,q,q)
    xdt = xc * dtc[..., None]  # (b,nc,q,h,p)

    # intra-chunk (quadratic, attention-like)
    scores = jnp.einsum("bcihn,bcjhn->bchij", Ch, Bh)  # (b,nc,h,q,q)
    y_intra = jnp.einsum("bchij,bchij,bcjhp->bcihp", scores, Lmat, xdt)

    # chunk summary states: decay from position to end of chunk
    decay_end = jnp.exp(cum[..., -1:, :] - cum)  # (b,nc,q,h)
    S_chunk = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn", Bh, decay_end, xdt)  # (b,nc,h,p,n)

    # inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(jnp.sum(dtA, axis=2))  # (b,nc,h)
    S0 = (
        initial_state
        if initial_state is not None
        else jnp.zeros((bsz, h, p, n), dtype=x.dtype)
    )

    def step(S_prev, inputs):
        S_c, dec = inputs  # (b,h,p,n), (b,h)
        S_new = S_prev * dec[:, :, None, None] + S_c
        return S_new, S_prev

    S_final, S_prevs = jax.lax.scan(
        step,
        S0.astype(jnp.float32),
        (jnp.moveaxis(S_chunk, 1, 0).astype(jnp.float32), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    S_prevs = jnp.moveaxis(S_prevs, 0, 1)  # (b,nc,h,p,n) state entering each chunk

    # inter-chunk contribution: C_i * decay_from_start * S_prev
    decay_in = jnp.exp(cum)  # (b,nc,q,h) decay from chunk start to position (inclusive)
    y_inter = jnp.einsum("bcqhn,bcqh,bchpn->bcqhp", Ch, decay_in, S_prevs.astype(x.dtype))

    y = (y_intra + y_inter).reshape(bsz, L, h, p)[:, :l]
    return y, S_final.astype(x.dtype)


def ssd_ref(x, dt, A, B, C, initial_state=None):
    """Sequential oracle: exact per-step recurrence (tests, tiny shapes)."""
    bsz, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=2)
    Ch = jnp.repeat(C, rep, axis=2)
    S = (
        initial_state.astype(jnp.float32)
        if initial_state is not None
        else jnp.zeros((bsz, h, p, n), dtype=jnp.float32)
    )
    ys = []
    for t in range(l):
        dA = jnp.exp(-dt[:, t] * A[None, :])  # (b,h)
        S = S * dA[:, :, None, None] + jnp.einsum(
            "bhp,bhn,bh->bhpn", x[:, t].astype(jnp.float32), Bh[:, t].astype(jnp.float32), dt[:, t]
        )
        ys.append(jnp.einsum("bhpn,bhn->bhp", S, Ch[:, t].astype(jnp.float32)))
    return jnp.stack(ys, axis=1).astype(x.dtype), S.astype(x.dtype)


def ssd_decode_step(state, x_t, dt_t, A, B_t, C_t):
    """One-token recurrence.  state (b,h,p,n); x_t (b,h,p); dt_t (b,h);
    B_t/C_t (b,g,n).  Returns (y (b,h,p), new state)."""
    h = x_t.shape[1]
    rep = h // B_t.shape[1]
    Bh = jnp.repeat(B_t, rep, axis=1)
    Ch = jnp.repeat(C_t, rep, axis=1)
    dA = jnp.exp(-dt_t * A[None, :])
    state = state * dA[:, :, None, None] + jnp.einsum("bhp,bhn,bh->bhpn", x_t, Bh, dt_t)
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch)
    return y, state


# ---------------------------------------------------------------------------
# full Mamba2 block


def mamba2_apply(
    params: Params,
    hidden: jnp.ndarray,  # (b, l, d_model)
    cfg,
    cache: Params | None = None,
) -> Tuple[jnp.ndarray, Params | None]:
    """Mamba2 block.  cache={"conv": (b,k-1,conv_dim), "state": (b,h,p,n)}
    enables single/few-token decode; cache=None is training/prefill."""
    bsz, l, _ = hidden.shape
    h, p, g, n = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_ngroups, cfg.ssm_state
    proj = hidden @ params["in_proj"]
    z, xBC_raw, dt_raw = _split_proj(proj, cfg)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (b,l,h)
    A = jnp.exp(params["A_log"])  # (h,) positive

    new_cache = None
    if cache is None:
        xBC = _causal_conv(xBC_raw, params["conv_w"], params["conv_b"])
    else:
        k = cfg.conv_kernel
        window = jnp.concatenate([cache["conv"].astype(xBC_raw.dtype), xBC_raw], axis=1)
        xBC = _causal_conv(window, params["conv_w"], params["conv_b"])[:, k - 1 :]
        new_conv = window[:, -(k - 1) :] if k > 1 else window[:, :0]

    x = xBC[..., : cfg.d_inner].reshape(bsz, l, h, p)
    B = xBC[..., cfg.d_inner : cfg.d_inner + g * n].reshape(bsz, l, g, n)
    C = xBC[..., cfg.d_inner + g * n :].reshape(bsz, l, g, n)

    if cache is None:
        y, _final = ssd_chunked(x, dt, A, B, C, cfg.ssm_chunk)
    elif l == 1:
        y1, state = ssd_decode_step(
            cache["state"].astype(jnp.float32),
            x[:, 0].astype(jnp.float32),
            dt[:, 0],
            A,
            B[:, 0].astype(jnp.float32),
            C[:, 0].astype(jnp.float32),
        )
        y = y1[:, None].astype(hidden.dtype)
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype), "state": state.astype(cache["state"].dtype)}
    else:
        y, state = ssd_chunked(x, dt, A, B, C, cfg.ssm_chunk, initial_state=cache["state"].astype(x.dtype))
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype), "state": state.astype(cache["state"].dtype)}

    y = y + x * params["D"][None, None, :, None].astype(x.dtype)
    y = y.reshape(bsz, l, cfg.d_inner).astype(hidden.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(params["norm"], y, cfg.norm_eps)
    out = (y @ params["out_proj"]).astype(hidden.dtype)
    return out, new_cache


def init_mamba_cache(batch: int, cfg, dtype) -> Params:
    conv_dim = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, conv_dim), dtype=dtype),
        "state": jnp.zeros((batch, cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state), dtype=jnp.float32),
    }
