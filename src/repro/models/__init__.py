"""Pure-JAX functional model zoo for the 10 assigned architectures."""
