"""Common layers: RMSNorm, RoPE, SwiGLU MLP, embeddings, initializers.

Functional style: params are nested dicts of jnp arrays; every layer is a
pure function ``f(params, x, ...)``.  Initializers take explicit PRNG keys.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# init


def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), dtype=jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms


def rmsnorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(params: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# rotary position embedding


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (b, h, s, d); positions: (b, s) or (s,) int32."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)  # (d/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[:, None, :, None].astype(jnp.float32) * freqs  # (b,1,s,d/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x1 * sin + x2 * cos
    out = jnp.stack([y1, y2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def sinusoidal_embed(positions: jnp.ndarray, d: int) -> jnp.ndarray:
    """Traced sinusoidal embeddings for given integer positions -> (s, d).

    jnp (not a table constant) so decode-time positions stay dynamic and the
    HLO carries no large embedded constants.
    """
    pos = positions.astype(jnp.float32)[:, None]
    i = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, 2.0 * i / d)
    out = jnp.stack([jnp.sin(angle), jnp.cos(angle)], axis=-1).reshape(positions.shape[0], d)
    return out


def sinusoidal_positions(seq: int, d: int) -> jnp.ndarray:
    pos = np.arange(seq)[:, None]
    i = np.arange(d // 2)[None, :]
    angle = pos / np.power(10000.0, 2 * i / d)
    out = np.zeros((seq, d), dtype=np.float32)
    out[:, 0::2] = np.sin(angle)
    out[:, 1::2] = np.cos(angle)
    return jnp.asarray(out)


# ---------------------------------------------------------------------------
# MLPs


def swiglu_init(key, d: int, f: int, dtype) -> Params:
    """Fused gate+up projection stored 3D (d, 2, f): one column-parallel
    matmul -> one dx all-reduce in the backward instead of two, and the
    gate/up split lands on the unsharded middle axis (communication-free;
    slicing a flat (d, 2f) activation across TP shards costs
    activation-sized collective-permutes — measured, §Perf iter 3)."""
    k1, k3 = jax.random.split(key)
    scale = 1.0 / np.sqrt(d)
    return {
        "w_gu": (jax.random.normal(k1, (d, 2, f), dtype=jnp.float32) * scale).astype(dtype),
        "w_down": dense_init(k3, f, d, dtype),
    }


def swiglu(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    gu = jnp.einsum("...d,dcf->...cf", x, params["w_gu"])
    return (jax.nn.silu(gu[..., 0, :]) * gu[..., 1, :]) @ params["w_down"]


def gelu_mlp_init(key, d: int, f: int, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {"w_up": dense_init(k1, d, f, dtype), "w_down": dense_init(k2, f, d, dtype)}


def gelu_mlp(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.gelu(x @ params["w_up"]) @ params["w_down"]


# ---------------------------------------------------------------------------
# losses


def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray, mask: jnp.ndarray | None = None):
    """Token-level CE; logits (..., V) float, labels (...) int32; mask optional.

    The gold logit is picked via an iota comparison instead of
    ``take_along_axis`` so a vocab-sharded logits tensor never gets
    all-gathered: both the logsumexp and the masked-sum reduce the sharded
    axis locally and all-reduce only (b, s)-sized partials (§Perf iter 2).
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(jnp.where(vocab_iota == labels[..., None], logits, 0.0), axis=-1)
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
