"""GQA attention with RoPE, KV cache, and three interchangeable impls.

  naive      full materialized scores — smoke tests / tiny shapes
  xla_flash  memory-efficient blockwise online softmax in pure XLA (lax.scan
             over q blocks x kv blocks).  With ``causal_scheduling`` the kv
             sweep for q block i runs as a dynamic-trip-count fori_loop over
             blocks 0..i, halving causal FLOPs (the pure-XLA analogue of the
             Pallas kernel's block skip).
  pallas     repro.kernels.flash_attention (TPU Mosaic; interpret on CPU)

All impls share one set of weights and agree to ~1e-5 (tested).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import apply_rope, dense_init

Params = Dict[str, Any]
_NEG_INF = -1e30


def attention_init(key, d_model: int, n_heads: int, n_kv_heads: int, head_dim: int, qkv_bias: bool, dtype) -> Params:
    """Head-major fused projections (§Perf iter 3):

      wqkv (d, H_total, hd)  H_total = hq + 2*hkv, layout [q | k | v]
      wo   (hq, hd, d)

    One fused matmul = one dx all-reduce in the backward (vs three).  The
    head axis shards on "model" when divisible (sharding rules drop it
    otherwise), so the q/k/v split is either shard-aligned or on a replicated
    axis — in both cases communication-free.  For TP-indivisible head counts
    the attention block degrades to DP+FSDP only (zero TP collectives), which
    measured far cheaper than GSPMD's resharding of flat-fused activations.
    """
    kq, ko = jax.random.split(key)
    n_total = n_heads + 2 * n_kv_heads
    scale = 1.0 / np.sqrt(d_model)
    p = {
        "wqkv": (jax.random.normal(kq, (d_model, n_total, head_dim), dtype=jnp.float32) * scale).astype(dtype),
        "wo": (
            jax.random.normal(ko, (n_heads, head_dim, d_model), dtype=jnp.float32)
            / np.sqrt(n_heads * head_dim)
        ).astype(dtype),
    }
    if qkv_bias:
        p["bqkv"] = jnp.zeros((n_total, head_dim), dtype=dtype)
    return p


def qkv_slices(params: Params, n_heads: int, n_kv_heads: int, head_dim: int):
    """(wq, wk, wv) head-axis slices of the fused projection (cross-attn use),
    each reshaped back to 2D (d, h*hd)."""
    w = params["wqkv"]
    d = w.shape[0]
    wq = w[:, :n_heads].reshape(d, n_heads * head_dim)
    wk = w[:, n_heads : n_heads + n_kv_heads].reshape(d, n_kv_heads * head_dim)
    wv = w[:, n_heads + n_kv_heads :].reshape(d, n_kv_heads * head_dim)
    return wq, wk, wv


def _project_qkv(params: Params, x: jnp.ndarray, n_heads: int, n_kv_heads: int, head_dim: int,
                 mesh_axes: tuple = ()):
    b, s, _ = x.shape
    qkv = jnp.einsum("bsd,dhf->bhsf", x, params["wqkv"])  # (b, H_total, s, hd)
    if "bqkv" in params:
        qkv = qkv + params["bqkv"][None, :, None, :]
    tp = dict(mesh_axes).get("model", 1)
    if tp > 1 and not (n_heads % tp == 0 and n_kv_heads % tp == 0):
        # sub-boundary split: replicate the head axis once, splits then free
        qkv = _constrain(qkv, _bhsd_spec(b, 1, mesh_axes))
    q = qkv[:, :n_heads]
    k = qkv[:, n_heads : n_heads + n_kv_heads]
    v = qkv[:, n_heads + n_kv_heads :]
    return q, k, v


def _bhsd_spec(b: int, h: int, mesh_axes) -> Optional["jax.sharding.PartitionSpec"]:
    """Adaptive PartitionSpec for (b, h, s, hd) attention activations.

    Heads on "model" when divisible by the TP degree; otherwise replicate the
    head dim EXPLICITLY — one resharding at the attention boundary instead of
    GSPMD re-deriving (and re-communicating) a layout per blockwise-flash
    step, which is the dominant collective in the baseline roofline
    (§Perf iteration 1).
    """
    if not mesh_axes:
        return None
    from jax.sharding import PartitionSpec as P

    sizes = dict(mesh_axes)
    tp = sizes.get("model", 1)
    dp_axes = tuple(a for a in ("pod", "data") if a in sizes)
    chosen = ()
    prod = 1
    for a in dp_axes:
        if b % (prod * sizes[a]) == 0:
            chosen = chosen + (a,)
            prod *= sizes[a]
    bspec = chosen if len(chosen) > 1 else (chosen[0] if chosen else None)
    hspec = "model" if (tp > 1 and h % tp == 0) else None
    return P(bspec, hspec, None, None)


def _constrain(x: jnp.ndarray, spec) -> jnp.ndarray:
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def _repeat_kv(k: jnp.ndarray, group: int) -> jnp.ndarray:
    if group == 1:
        return k
    b, h, s, d = k.shape
    return jnp.broadcast_to(k[:, :, None], (b, h, group, s, d)).reshape(b, h * group, s, d)


# ---------------------------------------------------------------------------
# core attention impls (q: (b,hq,sq,d), k/v: (b,hkv,sk,d))


def _attend_naive(q, k, v, *, causal: bool, kv_offset, scale: float):
    group = q.shape[1] // k.shape[1]
    kr, vr = _repeat_kv(k, group), _repeat_kv(v, group)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32)) * scale
    if causal:
        row = jnp.arange(q.shape[2])[:, None] + kv_offset
        col = jnp.arange(k.shape[2])[None, :]
        s = jnp.where(col <= row, s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32)).astype(q.dtype)


def _attend_xla_flash(
    q, k, v, *, causal: bool, kv_offset, scale: float,
    block_q: int = 512, block_k: int = 1024, causal_scheduling: bool = True,
    dynamic: bool = False,
):
    """Blockwise online-softmax attention in pure XLA.

    Memory: O(block_q * block_k) per (batch, head).  causal_scheduling saves
    the upper triangle's FLOPs two ways:

      * dynamic=False (training — differentiable): python-unrolled q blocks,
        each with a *static*-length kv scan of ceil((last_row+1)/block_k).
      * dynamic=True (inference — kv_offset may be traced, e.g. prefill at a
        dynamic cache position): lax.map over q blocks with a dynamic-trip
        fori_loop over kv blocks (XLA while loop; not differentiable).
    """
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    group = hq // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq, nk = q.shape[2] // block_q, k.shape[2] // block_k
    qb = q.reshape(b, hq, nq, block_q, d)
    kb = k.reshape(b, hkv, nk, block_k, d)
    vb = v.reshape(b, hkv, nk, block_k, d)

    # padded kv columns must never be attended: they are masked by causality
    # for real rows only if their col index > row; enforce explicitly.
    kv_valid = jnp.arange(nk * block_k) < sk  # (sk_pad,)
    kv_valid = kv_valid.reshape(nk, block_k)

    def one_q_block(i_q, qblk):  # qblk: (b,hq,block_q,d)
        m0 = jnp.full((b, hq, block_q), _NEG_INF, dtype=jnp.float32)
        l0 = jnp.zeros((b, hq, block_q), dtype=jnp.float32)
        a0 = jnp.zeros((b, hq, block_q, d), dtype=jnp.float32)
        rows = i_q * block_q + jnp.arange(block_q) + kv_offset  # (block_q,)

        def kv_step(carry, i_k):
            m, l, acc = carry
            kblk = _repeat_kv(jax.lax.dynamic_index_in_dim(kb, i_k, 2, keepdims=False), group)
            vblk = _repeat_kv(jax.lax.dynamic_index_in_dim(vb, i_k, 2, keepdims=False), group)
            s = jnp.einsum("bhqd,bhkd->bhqk", qblk.astype(jnp.float32), kblk.astype(jnp.float32)) * scale
            cols = i_k * block_k + jnp.arange(block_k)
            valid = jax.lax.dynamic_index_in_dim(kv_valid, i_k, 0, keepdims=False)
            mask = valid[None, :]
            if causal:
                mask = jnp.logical_and(mask, cols[None, :] <= rows[:, None])
            s = jnp.where(mask, s, _NEG_INF)
            m_cur = jnp.max(s, axis=-1)
            m_new = jnp.maximum(m, m_cur)
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vblk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        if causal and causal_scheduling:
            if dynamic:
                # dynamic trip count (traced kv_offset ok; inference only)
                last_row = i_q * block_q + (block_q - 1) + kv_offset
                n_run = jnp.clip((last_row // block_k) + 1, 0, nk)

                def body(i_k, carry):
                    new_carry, _ = kv_step(carry, i_k)
                    return new_carry

                m, l, acc = jax.lax.fori_loop(0, n_run, body, (m0, l0, a0))
            else:
                # static trip count per (python-static) q block index
                last_row = int(i_q) * block_q + (block_q - 1) + int(kv_offset)
                n_run = min(max(last_row // block_k + 1, 1), nk)
                (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(n_run))
        else:
            (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)

    if causal and causal_scheduling and not dynamic:
        # python-unrolled q blocks: static kv trip counts, differentiable
        outs = [one_q_block(i, qb[:, :, i]) for i in range(nq)]
        out = jnp.stack(outs, axis=0)
    else:
        out = jax.lax.map(lambda i: one_q_block(i, qb[:, :, i]), jnp.arange(nq))
    out = out.transpose(1, 2, 0, 3, 4).reshape(b, hq, nq * block_q, d)
    return out[:, :, :sq]


def _attend(q, k, v, *, impl: str, causal: bool, kv_offset, scale: float, causal_scheduling: bool = True):
    if impl == "naive":
        return _attend_naive(q, k, v, causal=causal, kv_offset=kv_offset, scale=scale)
    if impl == "xla_flash":
        return _attend_xla_flash(
            q, k, v, causal=causal, kv_offset=kv_offset, scale=scale,
            causal_scheduling=causal_scheduling,
        )
    if impl == "pallas":
        from repro.kernels.flash_attention.ops import flash_attention

        if not causal:
            return _attend_naive(q, k, v, causal=False, kv_offset=kv_offset, scale=scale)
        return flash_attention(q, k, v, causal=True, scale=scale)
    raise ValueError(f"unknown attention impl {impl!r}")


# ---------------------------------------------------------------------------
# public block API


def attention_apply(
    params: Params,
    x: jnp.ndarray,  # (b, s, d_model)
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    impl: str = "xla_flash",
    causal: bool = True,
    pos_type: str = "rope",
    rope_theta: float = 1e6,
    positions: Optional[jnp.ndarray] = None,
    cache: Optional[Params] = None,
    cross_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
    causal_scheduling: bool = True,
    from_zero: bool = False,
    mesh_axes: tuple = (),
) -> Tuple[jnp.ndarray, Optional[Params]]:
    """One attention call.  Modes:

      * training/prefill: cache=None -> full self-attention over x
      * decode:           cache={"k","v","pos"} -> append x's kv, attend cache
      * cross-attention:  cross_kv=(k, v) precomputed from the encoder

    Returns (output (b,s,d_model), updated cache or None).
    """
    b, s, _ = x.shape
    scale = 1.0 / float(head_dim) ** 0.5
    new_cache = None

    if cross_kv is not None:
        wq, _, _ = qkv_slices(params, n_heads, n_kv_heads, head_dim)
        q = (x @ wq).reshape(b, s, n_heads, head_dim).transpose(0, 2, 1, 3)
        if "bqkv" in params:
            q = q + params["bqkv"][None, :n_heads, None, :]
        k, v = cross_kv
        out = _attend(q, k, v, impl=impl, causal=False, kv_offset=0, scale=scale)
    else:
        q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, head_dim, mesh_axes)
        q_spec = _bhsd_spec(b, n_heads, mesh_axes)
        kv_spec = _bhsd_spec(b, n_kv_heads, mesh_axes)
        q = _constrain(q, q_spec)
        k = _constrain(k, kv_spec)
        v = _constrain(v, kv_spec)
        if cache is not None:
            pos = cache["pos"]  # int32 scalar: number of valid cache entries
            if positions is None:
                positions = pos + jnp.arange(s)
            if pos_type == "rope":
                q = apply_rope(q, positions, rope_theta)
                k = apply_rope(k, positions, rope_theta)
            ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, 0, pos, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, 0, pos, 0))
            ck = _constrain(ck, kv_spec)
            cv = _constrain(cv, kv_spec)
            new_cache = {"k": ck, "v": cv, "pos": pos + s}
            S = ck.shape[2]
            # Causality against absolute positions also hides cache slots
            # beyond pos+s (they sit in every query's causal future).
            if s > 8 and impl != "naive":
                # multi-token prefill: memory-efficient flash over the cache
                if from_zero:
                    # whole-prompt prefill: pos == 0 semantically, so the kv
                    # sweep has STATIC trip counts (exact causal accounting in
                    # the dry-run and causal FLOP savings without while loops)
                    bq = 2048 if s >= 8192 else 512
                    out = _attend_xla_flash(
                        q, ck, cv, causal=True, kv_offset=0, scale=scale,
                        causal_scheduling=causal_scheduling, dynamic=False,
                        block_q=bq, block_k=bq,
                    )
                else:
                    # chunked prefill at a dynamic cache position
                    out = _attend_xla_flash(
                        q, ck, cv, causal=True, kv_offset=pos, scale=scale,
                        causal_scheduling=causal_scheduling, dynamic=True,
                    )
            else:
                group = n_heads // n_kv_heads
                kr, vr = _repeat_kv(ck, group), _repeat_kv(cv, group)
                sc = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32)) * scale
                row = positions if positions.ndim == 2 else positions[None, :]  # (b|1, s)
                mask = jnp.arange(S)[None, None, None, :] <= row[:, None, :, None]
                sc = jnp.where(mask, sc, _NEG_INF)
                p = jax.nn.softmax(sc, axis=-1)
                out = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32)).astype(x.dtype)
        else:
            if positions is None:
                positions = jnp.arange(s)
            if pos_type == "rope":
                q = apply_rope(q, positions, rope_theta)
                k = apply_rope(k, positions, rope_theta)
            out = _attend(
                q, k, v, impl=impl, causal=causal, kv_offset=0, scale=scale,
                causal_scheduling=causal_scheduling,
            )
        out = _constrain(out, q_spec)

    # head-major output projection: contraction over (h, hd) — replicated or
    # model-sharded consistently with the attention internals
    return jnp.einsum("bhsf,hfd->bsd", out, params["wo"]), new_cache


def init_kv_cache(batch: int, n_kv_heads: int, max_len: int, head_dim: int, dtype) -> Params:
    return {
        "k": jnp.zeros((batch, n_kv_heads, max_len, head_dim), dtype=dtype),
        "v": jnp.zeros((batch, n_kv_heads, max_len, head_dim), dtype=dtype),
        "pos": jnp.int32(0),
    }
